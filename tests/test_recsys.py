"""Per-arch recsys smoke tests + embedding substrate."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry as R
from repro.recsys import embedding as E
from repro.recsys import models as RM

RS_ARCHS = [a for a in R.ASSIGNED if R.family_of(a) == "recsys"]


def _batch(cfg, B, with_labels=True):
    if cfg.kind in ("wide_deep", "autoint"):
        nf = len(cfg.field_vocabs)
        b = {"dense": jnp.ones((B, 13)),
             "sparse_ids": jnp.zeros((B, nf), jnp.int32)}
    elif cfg.kind == "dien":
        T = cfg.seq_len
        b = {"hist_items": jnp.zeros((B, T), jnp.int32),
             "hist_cates": jnp.zeros((B, T), jnp.int32),
             "hist_mask": jnp.ones((B, T), bool),
             "target_item": jnp.zeros((B,), jnp.int32),
             "target_cate": jnp.zeros((B,), jnp.int32)}
    else:
        T = cfg.seq_len
        b = {"item_seq": jnp.zeros((B, T), jnp.int32),
             "seq_mask": jnp.ones((B, T), bool)}
    if with_labels:
        if cfg.kind == "bert4rec":
            b["mlm_positions"] = jnp.zeros((B, 2), jnp.int32)
            b["mlm_labels"] = jnp.ones((B, 2), jnp.int32)
            b["neg_samples"] = jnp.arange(16, dtype=jnp.int32)
        else:
            b["labels"] = jnp.ones((B,))
    return b


@pytest.mark.slow
@pytest.mark.parametrize("arch", RS_ARCHS)
def test_smoke_train_score_retrieval(arch):
    cfg = R.get_config(arch, smoke=True)
    p = RM.init_params(jax.random.PRNGKey(0), cfg)
    B = 8
    batch = _batch(cfg, B)
    loss = RM.train_loss(p, batch, cfg)
    assert bool(jnp.isfinite(loss))
    jax.grad(RM.train_loss)(p, batch, cfg)
    sc = RM.score(p, _batch(cfg, B, with_labels=False), cfg)
    assert sc.shape == (B,) and not bool(jnp.isnan(sc).any())
    b2 = _batch(cfg, B, with_labels=False)
    b2["candidate_ids"] = jnp.arange(50, dtype=jnp.int32)
    rs = RM.retrieval_scores(p, b2, cfg)
    assert rs.shape == (B, 50) and not bool(jnp.isnan(rs).any())


def test_embedding_bag_modes(rng):
    table = jnp.asarray(rng.normal(size=(100, 8)), jnp.float32)
    ids = jnp.asarray([0, 1, 2, 3, 4, 5], jnp.int32)
    seg = jnp.asarray([0, 0, 1, 1, 1, 2], jnp.int32)
    out = E.embedding_bag(table, ids, seg, 3, mode="sum")
    np.testing.assert_allclose(np.asarray(out[0]),
                               np.asarray(table[0] + table[1]), rtol=1e-6)
    mean = E.embedding_bag(table, ids, seg, 3, mode="mean")
    np.testing.assert_allclose(np.asarray(mean[1]),
                               np.asarray((table[2] + table[3] + table[4]) / 3),
                               rtol=1e-6)
    mx = E.embedding_bag(table, ids, seg, 3, mode="max")
    np.testing.assert_allclose(
        np.asarray(mx[2]), np.asarray(table[5]), rtol=1e-6)


def test_mega_table_offsets():
    vocabs = (10, 20, 30)
    off = E.field_offsets(vocabs)
    np.testing.assert_array_equal(off, [0, 10, 30])
    assert E.mega_table_rows(vocabs) % E.ROW_PAD == 0


def test_weights_and_grads_flow_to_tables():
    cfg = R.get_config("wide-deep", smoke=True)
    p = RM.init_params(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg, 4)
    g = jax.grad(RM.train_loss)(p, batch, cfg)
    assert float(jnp.abs(g["table"]).sum()) > 0
