"""SchNet smoke tests (both regimes) + neighbor sampler."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry as R
from repro.gnn import sampler as S
from repro.gnn import schnet as G


@pytest.mark.slow
def test_graph_regime(rng):
    cfg = R.get_config("schnet", smoke=True)
    n, e, df, nc = 50, 200, 32, 7
    p = G.init_params(jax.random.PRNGKey(0), cfg, d_feat=df, n_classes=nc)
    batch = {"node_feat": jnp.asarray(rng.normal(size=(n, df)), jnp.float32),
             "positions": jnp.asarray(rng.normal(size=(n, 3)), jnp.float32),
             "edge_src": jnp.asarray(rng.integers(0, n, e), jnp.int32),
             "edge_dst": jnp.asarray(rng.integers(0, n, e), jnp.int32),
             "labels": jnp.asarray(rng.integers(0, nc, n), jnp.int32)}
    loss = G.train_loss(p, batch, cfg)
    assert bool(jnp.isfinite(loss))
    jax.grad(G.train_loss)(p, batch, cfg)
    logits = G.node_logits(p, batch, cfg)
    assert logits.shape == (n, nc) and not bool(jnp.isnan(logits).any())


@pytest.mark.slow
def test_molecule_regime(rng):
    cfg = R.get_config("schnet", smoke=True)
    p = G.init_params(jax.random.PRNGKey(0), cfg)
    batch = {"atom_types": jnp.asarray(rng.integers(0, 10, (4, 6)), jnp.int32),
             "positions": jnp.asarray(rng.normal(size=(4, 6, 3)), jnp.float32),
             "edge_src": jnp.asarray(rng.integers(0, 6, (4, 12)), jnp.int32),
             "edge_dst": jnp.asarray(rng.integers(0, 6, (4, 12)), jnp.int32),
             "edge_mask": jnp.ones((4, 12), bool),
             "targets": jnp.zeros((4,))}
    e = G.batched_energy(p, batch, cfg)
    assert e.shape == (4,) and bool(jnp.isfinite(e).all())
    loss = G.train_loss(p, batch, cfg)
    jax.grad(G.train_loss)(p, batch, cfg)
    assert bool(jnp.isfinite(loss))


def test_edge_mask_zeroes_contributions(rng):
    cfg = R.get_config("schnet", smoke=True)
    p = G.init_params(jax.random.PRNGKey(0), cfg)
    at = jnp.asarray(rng.integers(0, 10, (1, 6)), jnp.int32)
    pos = jnp.asarray(rng.normal(size=(1, 6, 3)), jnp.float32)
    es = jnp.asarray(rng.integers(0, 6, (1, 12)), jnp.int32)
    ed = jnp.asarray(rng.integers(0, 6, (1, 12)), jnp.int32)
    e_none = G.batched_energy(p, {"atom_types": at, "positions": pos,
                                  "edge_src": es, "edge_dst": ed,
                                  "edge_mask": jnp.zeros((1, 12), bool)}, cfg)
    # with all edges masked, energy equals the no-message readout
    e_self = G.batched_energy(p, {"atom_types": at, "positions": pos,
                                  "edge_src": jnp.zeros((1, 12), jnp.int32),
                                  "edge_dst": jnp.zeros((1, 12), jnp.int32),
                                  "edge_mask": jnp.zeros((1, 12), bool)}, cfg)
    assert float(jnp.abs(e_none - e_self).max()) < 1e-5


def test_sampler_shapes_and_locality():
    src, dst = S.make_powerlaw_graph(1000, 5000, seed=0)
    g = S.CSRGraph(1000, src, dst)
    sub = S.sample_subgraph(g, np.arange(16), (5, 3),
                            np.random.default_rng(0))
    assert sub["node_ids"].shape == (16 + 80 + 240,)
    assert sub["edge_src"].shape == (80 + 240,)
    # edges reference local indices within the padded layout
    assert sub["edge_src"].max() < len(sub["node_ids"])
    assert sub["edge_dst"].max() < 16 + 80


def test_sampled_subgraph_trains():
    cfg = R.get_config("schnet", smoke=True)
    src, dst = S.make_powerlaw_graph(500, 2000, seed=1)
    g = S.CSRGraph(500, src, dst)
    rng = np.random.default_rng(1)
    sub = S.sample_subgraph(g, np.arange(8), (4, 2), rng)
    feats = rng.normal(size=(500, 16)).astype(np.float32)
    coords = rng.normal(size=(500, 3)).astype(np.float32)
    p = G.init_params(jax.random.PRNGKey(0), cfg, d_feat=16, n_classes=5)
    batch = {"node_feat": jnp.asarray(feats[sub["node_ids"]]),
             "positions": jnp.asarray(coords[sub["node_ids"]]),
             "edge_src": jnp.asarray(sub["edge_src"]),
             "edge_dst": jnp.asarray(sub["edge_dst"]),
             "seed_labels": jnp.asarray(rng.integers(0, 5, 8), jnp.int32)}
    loss = G.train_loss(p, batch, cfg)
    assert bool(jnp.isfinite(loss))
