"""Unified token-budget serving: chunk-resumable selective prefill.

Pins the tentpole invariants of the chunked scheduler:

* chunked and monolithic prefill are bitwise identical — layer-0 chunk
  rows, Eq. 3 selection, logits, merged KV, and decoded tokens through
  the full serving loop, across {kv-reuse on/off} x {jnp, pallas};
* a mid-prefill preemption rolls `PrefillState` back cleanly (pages,
  store refs, chunk state) and the victim re-prefills to the same
  tokens;
* per-tick token accounting never exceeds the step budget except for a
  single indivisible oversized item;
* the pool's incremental mapped-table machinery (spare slots, private
  remap) preserves the ownership partition.
"""
import dataclasses

import numpy as np
import pytest

from repro.core import engine as ENG
from repro.serving import workload as WL
from repro.serving.batch_engine import BatchEngine
from repro.serving.batching import (ContinuousBatcher, JaxEngineBackend,
                                    PendingRequest)
from repro.serving.block_store import SharedBlockStore, check_partition
from repro.serving.kv_pool import PagedKVPool, pool_for


@pytest.fixture(scope="module")
def tiny_system():
    from repro.core.rcllm import make_tiny_system
    return make_tiny_system(n_items=60, n_requests_hist=30, k_instances=2,
                            n_layers=2, d_model=32)


@pytest.fixture(scope="module")
def heavy_workload(tiny_system):
    """Heavy-tail trace (some long prompts) + plans + reuse metadata."""
    system, pool_rv, prof, _ = tiny_system
    trace = WL.heavy_tail_trace(system.catalog, pool_rv, prof, 6, qps=8.0,
                                n_users=3, long_prompt_frac=0.4,
                                long_prompt_reviews=6, seed=5)
    pend, plans = WL.rcllm_workload(system, trace, decode_steps=3)
    reuse = WL.rcllm_reuse_info(system, trace, plans)
    return trace, pend, plans, reuse


def _items(system, trace):
    out = []
    for rq in trace:
        inst = system.best_instance(rq)
        plan = system.plan_for(rq, inst)
        ck, cv, have = system.cached_kv(plan, inst)
        out.append((plan, ck, cv, have))
    return out


# --------------------------------------------- core bitwise parity
@pytest.mark.parametrize("chunk", [64, 128, 96])
def test_chunked_prefill_matches_monolithic(tiny_system, chunk):
    """ChunkedPrefill (any chunk size, ragged tails included) reproduces
    the monolithic selective prefill bitwise: Eq. 3 selection, final
    logits and the merged pre-RoPE KV."""
    system, pool_rv, prof, _ = tiny_system
    from repro.data import synth as SY
    trace = SY.make_trace(system.catalog, pool_rv, prof, 3, qps=4.0,
                          n_users=3, n_candidates=8, reviews_per_user=1,
                          seed=9)
    sel = ENG.SelectiveConfig()
    for item in _items(system, trace):
        logits_m, stats_m, k_m, v_m = ENG.selective_prefill_with_kv(
            system.params, system.cfg, *item, sel, bucket=64)
        cp = ENG.ChunkedPrefill(system.params, system.cfg, *item, sel,
                                chunk_tokens=chunk, bucket=64)
        n_chunks = 0
        while not cp.scan_done:
            cp.run_chunk()
            n_chunks += 1
        assert n_chunks == -(-cp.n_pad // chunk)
        (logits_c, k_rest, v_rest), = ENG.selective_layers_batch(
            system.params, system.cfg, [cp.sel_item()])
        assert np.array_equal(stats_m.recompute_mask, cp.stats.recompute_mask)
        assert np.array_equal(logits_m, logits_c)
        k_c = np.concatenate([cp.k0_full()[:, None], k_rest[:cp.n]], axis=1)
        v_c = np.concatenate([cp.v0_full()[:, None], v_rest[:cp.n]], axis=1)
        assert np.array_equal(k_m, k_c)
        assert np.array_equal(v_m, v_c)


# ------------------------------------------ serving-loop token parity
def _run_sched(system, pend, plans, reuse, sched, attn_backend,
               chunk_tokens=64, step_tokens=256, n_pages=512,
               eager_kv_writes=None, decode_kernel="auto"):
    cfg = dataclasses.replace(system.cfg, attn_backend=attn_backend,
                              decode_kernel=decode_kernel)
    pool = pool_for(cfg, n_pages=n_pages)
    eng = BatchEngine(system.params, cfg, pool=pool,
                      store=SharedBlockStore(pool) if reuse else None,
                      chunk_tokens=chunk_tokens,
                      eager_kv_writes=eager_kv_writes)
    backend = JaxEngineBackend(eng, mode="rcllm", plans=plans, reuse=reuse)
    batcher = ContinuousBatcher(backend=backend, sched=sched,
                                chunk_tokens=chunk_tokens,
                                step_tokens=step_tokens)
    done = batcher.run([PendingRequest(r.arrival_s, r.rid, r.n_tokens,
                                       r.decode_steps, r.tokens)
                        for r in pend])
    check_partition(eng.pool, eng.store)
    assert eng.pool.stats().pages_in_use == 0          # all released
    assert not eng.prefill_states                      # no stragglers
    return backend.generated, done, batcher.workers[0]


@pytest.mark.parametrize("kv_reuse", [False, True])
@pytest.mark.parametrize("attn_backend,decode_kernel",
                         [("jnp", "auto"),      # gather decode (oracle)
                          ("pallas", "auto"),   # paged decode via backend
                          ("jnp", "paged")])    # paged decode isolated
def test_chunked_decoded_token_parity(tiny_system, heavy_workload,
                                      kv_reuse, attn_backend,
                                      decode_kernel):
    """Decoded tokens are bitwise identical between --sched wave and
    --sched chunked, with and without the shared block store, under
    both attention backends and both decode kernels — on the heavy-tail
    trace, so long-prompt chunking (many chunks, mid-stream finalizes)
    is actually exercised.  The ("jnp", "paged") rows pin the fused
    paged-decode kernel against the same-backend prefill, isolating
    decode-kernel effects from prefill-backend effects.
    """
    system, *_ = tiny_system
    _, pend, plans, reuse = heavy_workload
    reuse = reuse if kv_reuse else None
    gen_w, done_w, _ = _run_sched(system, pend, plans, reuse, "wave",
                                  attn_backend,
                                  decode_kernel=decode_kernel)
    gen_c, done_c, w = _run_sched(system, pend, plans, reuse, "chunked",
                                  attn_backend,
                                  decode_kernel=decode_kernel)
    assert gen_w == gen_c
    assert len(done_c) == len(pend)
    assert len(w.ticks) > 0
    for c in done_c:
        assert c.arrival_s <= c.admitted_s <= c.first_token_s <= c.done_s


def test_eager_kv_writes_mode_identical(tiny_system, heavy_workload):
    """Per-tick eager layer-0 pool writes (the TPU/donation incremental
    mode) and the CPU-default lazy fused-at-finalize mode decode the
    same tokens — nothing reads a request's rows before its decode."""
    system, *_ = tiny_system
    _, pend, plans, reuse = heavy_workload
    gen_lazy, _, _ = _run_sched(system, pend, plans, reuse, "chunked",
                                "jnp", eager_kv_writes=False)
    gen_eager, _, _ = _run_sched(system, pend, plans, reuse, "chunked",
                                 "jnp", eager_kv_writes=True)
    assert gen_lazy == gen_eager


def test_chunked_needs_chunk_capable_backend():
    """The simulator backend is wave-only; asking it for the chunked
    discipline is a configuration error, not a silent fallback."""
    with pytest.raises(ValueError, match="chunk-capable"):
        ContinuousBatcher(lambda n: 1e-3, lambda n: 1e-4,
                          sched="chunked").run(
            [PendingRequest(0.0, 0, 8, 1)])


# ------------------------------------------------ budget accounting
def test_tick_budget_property(tiny_system, heavy_workload):
    """Per-tick token accounting never exceeds the step budget: decode
    is mandatory (one token per running request), and chunk/finalize
    work packs into the remainder — except a tick may carry ONE
    indivisible oversized item (a selective finalize whose padded
    recompute budget exceeds any fixed step size must not starve)."""
    system, *_ = tiny_system
    _, pend, plans, _ = heavy_workload
    for chunk_tokens, step_tokens in ((64, 192), (128, 512), (128, 96)):
        _, _, w = _run_sched(system, pend, plans, None, "chunked",
                             "jnp", chunk_tokens=chunk_tokens,
                             step_tokens=step_tokens)
        assert w.ticks
        for t in w.ticks:
            prefill_charge = t.chunk_tokens + t.finalize_tokens
            if not t.oversized:
                assert prefill_charge <= max(0, step_tokens - t.decode_tokens)
            else:
                # oversized = a single item that alone beats the budget
                assert prefill_charge > max(0, step_tokens - t.decode_tokens)
                assert (t.chunk_tokens == 0) or (t.finalize_tokens == 0)


def test_engine_step_random_budgets(tiny_system):
    """Property-style: driving BatchEngine.step directly with random
    budgets per tick always respects the charge bound and finishes
    every request with the wave path's exact tokens."""
    system, pool_rv, prof, _ = tiny_system
    from repro.data import synth as SY
    trace = SY.make_trace(system.catalog, pool_rv, prof, 4, qps=50.0,
                          n_users=3, n_candidates=8, reviews_per_user=1,
                          seed=11)
    reqs = WL.rcllm_batch_requests(system, trace, n_reserve=2)
    ref_pool = pool_for(system.cfg, n_pages=512)
    ref_eng = BatchEngine(system.params, system.cfg, pool=ref_pool)
    ref_logits = ref_eng.prefill(list(reqs), mode="rcllm")
    ref = {r.rid: np.argmax(lg) for r, lg in zip(reqs, ref_logits)}

    rng = np.random.default_rng(0)
    eng = BatchEngine(system.params, system.cfg,
                      pool=pool_for(system.cfg, n_pages=512),
                      chunk_tokens=64)
    for r in reqs:
        eng.begin_prefill(r)
    queue = [r.rid for r in reqs]
    got = {}
    for _ in range(400):
        if not queue:
            break
        budget = int(rng.integers(16, 400))
        rep = eng.step(budget, [], [], queue)
        assert rep.charge_decode == 0
        if not rep.oversized:
            assert rep.charged <= budget
        got.update({rid: np.argmax(lg) for rid, lg in rep.finalized.items()})
        queue = [rid for rid in queue if rid not in rep.finalized]
    assert not queue
    assert got == ref


# ------------------------------------------- mid-prefill preemption
def test_abort_prefill_rolls_back_cleanly(tiny_system, heavy_workload):
    """Aborting between chunks releases every page and store ref, and a
    fresh begin_prefill re-runs the request to the same logits."""
    system, *_ = tiny_system
    _, _, plans, reuse = heavy_workload
    pool = pool_for(system.cfg, n_pages=512)
    eng = BatchEngine(system.params, system.cfg, pool=pool,
                      store=SharedBlockStore(pool), chunk_tokens=64)
    rid = sorted(plans)[0]
    plan, ck, cv, have = plans[rid]
    from repro.serving.batch_engine import BatchRequest
    req = BatchRequest(rid=rid, tokens=plan.tokens, plan=plan, cached_k=ck,
                       cached_v=cv, have=have, n_reserve=2, reuse=reuse[rid])
    eng.begin_prefill(req)
    eng.step(64, [], [], [rid])                  # one chunk in flight
    assert rid in eng.prefill_states
    eng.abort_prefill(rid)
    assert rid not in eng.prefill_states
    assert pool.stats().pages_in_use == 0
    for key in eng.store.blocks:
        assert eng.store.blocks[key].refcount == 0
    check_partition(pool, eng.store)
    # the victim re-prefills from its kept plan, to the same first token
    eng.begin_prefill(req)
    rep = eng.step(10_000, [], [], [rid])
    eng2 = BatchEngine(system.params, system.cfg,
                       pool=pool_for(system.cfg, n_pages=512))
    ref = eng2.prefill([dataclasses.replace(req, reuse=None)], mode="rcllm")
    assert np.array_equal(rep.finalized[rid], ref[0])


def test_midprefill_preemption_in_loop(tiny_system):
    """Decode-time PoolExhausted with a request mid-prefill: the
    batcher preempts the (younger) prefilling request, its chunk state
    rolls back, and both requests still finish with full outputs.

    The scenario runs under both decode kernels — the preemption/retry
    dance (append rollback, victim re-prefill) must decode the exact
    same tokens through the fused paged kernel as through the jnp
    gather path, with page_size=1 as the degenerate worst case for the
    page views (every slot its own page)."""
    system, pool_rv, prof, _ = tiny_system
    trace = WL.heavy_tail_trace(system.catalog, pool_rv, prof, 6, qps=8.0,
                                n_users=3, long_prompt_frac=0.5,
                                long_prompt_reviews=10, seed=13)
    _, all_plans = WL.rcllm_workload(system, trace, decode_steps=3)
    by_len = sorted(all_plans, key=lambda r: all_plans[r][0].n)
    short, long_ = by_len[0], by_len[-1]
    n_a = all_plans[short][0].n
    n_b = all_plans[long_][0].n
    assert n_b - n_a >= 128, "need a real length gap for the scenario"
    # rid 0: short, decoding (3 steps) with broken zero reservation;
    # rid 1: long, TTFT-only (reserves nothing).  Both arrive at t=0:
    # admission hands them every page, rid 0 finalizes while rid 1 is
    # still scanning, and rid 0's first un-reserved decode append hits
    # an empty free list — forcing a preemption whose victim is the
    # younger rid 1, mid-prefill.
    plans = {0: all_plans[short], 1: all_plans[long_]}

    class NoReserveBackend(JaxEngineBackend):
        def _batch_requests(self, batch):
            out = super()._batch_requests(batch)
            for br in out:
                br.n_reserve = 0              # simulate broken accounting
            return out

    def run(decode_kernel):
        cfg = dataclasses.replace(system.cfg, decode_kernel=decode_kernel)
        pend = [
            PendingRequest(0.0, 0, n_a, 3, plans[0][0].tokens),
            PendingRequest(0.0, 1, n_b, 1, plans[1][0].tokens),
        ]
        pool = PagedKVPool(cfg.n_layers, cfg.n_kv_heads,
                           cfg.resolved_head_dim, page_size=1,
                           n_pages=n_a + n_b + 1)
        eng = BatchEngine(system.params, cfg, pool=pool, chunk_tokens=64)
        backend = NoReserveBackend(eng, mode="rcllm", plans=plans)
        batcher = ContinuousBatcher(backend=backend, sched="chunked",
                                    chunk_tokens=64, step_tokens=128)
        done = batcher.run(pend)
        assert len(done) == 2                     # nobody was lost
        assert batcher.workers[0].preempted >= 1
        assert len(backend.generated[0]) == 3
        assert len(backend.generated[1]) == 1
        assert pool.stats().pages_in_use == 0
        assert not eng.prefill_states
        check_partition(pool)
        return backend.generated

    gen = {k: run(k) for k in ("gather", "paged")}
    assert gen["gather"] == gen["paged"]          # bitwise token parity


# --------------------------------------------------- pool machinery
def test_pool_remap_private_and_spare():
    """alloc_mapped(extra_pages=) banks spare private slots; remap
    repoints mapped positions at them (growing only when spares run
    out) and free() returns everything."""
    pool = PagedKVPool(n_layers=2, n_kv_heads=2, head_dim=4,
                       page_size=4, n_pages=32)
    shared = pool.alloc_pages(2)
    shared_slots = pool.page_slots(shared)
    mapped_pos = np.asarray([0, 1, 2, 3, 8, 9])
    pool.alloc_mapped(5, 20, mapped_pos, shared_slots[:6], extra_pages=2)
    table = pool.slot_tables[5]
    assert np.array_equal(table[mapped_pos], shared_slots[:6])
    spare0 = len(pool._spare[5])
    assert spare0 >= 2 * 4                        # the extra pages' slots
    free0 = pool.free_pages
    pool.remap_private(5, np.asarray([1, 8]))
    assert pool.free_pages == free0               # spares absorbed it
    assert len(pool._spare[5]) == spare0 - 2
    table = pool.slot_tables[5]
    own = set(pool.page_slots(pool.page_tables[5]))
    assert int(table[1]) in own and int(table[8]) in own
    assert np.array_equal(table[[0, 2, 3, 9]],
                          shared_slots[[0, 2, 3, 5]])
    # exhaust the spares: remap grows by fresh pages
    pool.remap_private(5, np.asarray([0, 2, 3, 9]))
    n_more = spare0 - 2 - 4
    assert len(pool._spare[5]) == max(n_more, 0)
    pages_before = len(pool.page_tables[5])
    big = np.arange(4, 8)                         # force page growth
    pool.slot_tables[5][big] = shared_slots[2:6]  # pretend mapped
    pool.remap_private(5, big)
    assert len(pool.page_tables[5]) >= pages_before
    pool.free(5)
    assert 5 not in pool._spare
    pool.release_pages(shared)
    check_partition(pool)
