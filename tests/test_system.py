"""End-to-end behaviour tests for the RcLLM system (the paper's claims,
scaled to CPU): beyond-prefix reuse beats prefix caching on TTFT, selective
recomputation preserves ranking fidelity, the full distributed pipeline
(placement → scheduling → assembly → selective attention) holds together."""
import numpy as np
import pytest

from repro.core import cost_model as CM
from repro.core import metrics as MET
from repro.core import simulator as SIM
from repro.core.engine import SelectiveConfig
from repro.core.rcllm import make_tiny_system
from repro.data import synth as SY


@pytest.fixture(scope="module")
def system():
    return make_tiny_system(n_items=60, n_requests_hist=40, k_instances=3,
                            n_layers=3, d_model=48)


def test_paper_claim_ttft_speedup(system):
    """Fig. 6 structure: RcLLM beats Prefix-Cache on P50 and P99 because the
    shared prefix is only ~7–10% of the prompt while items+history dominate."""
    reqs, placement, _ = SIM.make_sim_setup(k=8, n_requests=400, qps=20.0,
                                            n_items=3000, seed=42)
    from repro.configs import registry as REG
    qwen = REG.ARCHS["rcllm-qwen3-8b"]
    res = {m: SIM.simulate(qwen, CM.V5E_1, reqs, placement,
                           SIM.SimConfig(mode=m))
           for m in ("rcllm", "prefix")}
    p50_speedup = res["prefix"].pct(50) / res["rcllm"].pct(50)
    p99_speedup = res["prefix"].pct(99) / res["rcllm"].pct(99)
    assert p50_speedup > 1.31          # paper's lower bound
    assert p99_speedup > 1.2


def test_paper_claim_scheduling(system):
    """Fig. 10 structure: affinity ≤ min(hit-only, load-only) mean TTFT under
    high load."""
    reqs, placement, _ = SIM.make_sim_setup(k=8, n_requests=500, qps=35.0,
                                            n_items=3000, seed=43)
    from repro.configs import registry as REG
    qwen = REG.ARCHS["rcllm-qwen3-8b"]
    means = {}
    for pol in ("affinity", "hit_only", "load_only"):
        r = SIM.simulate(qwen, CM.V5E_1, reqs, placement,
                         SIM.SimConfig(mode="rcllm", policy=pol))
        means[pol] = r.ttft_s.mean()
    assert means["affinity"] <= min(means["hit_only"],
                                    means["load_only"]) * 1.05


def test_paper_claim_fidelity_vs_budget(system):
    """Fig. 7 structure: fidelity to Full-Recompute rises with budget r."""
    sys_, pool, prof, _ = system
    reqs = SY.make_trace(sys_.catalog, pool, prof, 3, qps=5.0, n_users=5,
                         n_candidates=6, reviews_per_user=2, seed=44)
    fid = {}
    for r_b in (0.1, 0.9):
        vals = []
        for rq in reqs:
            full, _ = sys_.rank(rq, "full")
            sc, _ = sys_.rank(rq, "rcllm",
                              SelectiveConfig(r_item=r_b, r_rev=r_b,
                                              window=12))
            vals.append(MET.ranking_agreement_ndcg(full, sc, k=5))
        fid[r_b] = np.mean(vals)
    assert fid[0.9] >= fid[0.1] - 0.02


def test_prompt_composition_matches_paper(system):
    """§IV-B: items should dominate the prompt mass, history second,
    instruction a small fraction."""
    sys_, pool, prof, _ = system
    reqs = SY.make_trace(sys_.catalog, pool, prof, 5, qps=5.0, n_users=5,
                         n_candidates=20, reviews_per_user=3, seed=45)
    tokens, kind, _ = reqs[0].prompt_segments(sys_.catalog, sys_.instruction)
    frac_items = (kind == 2).mean()
    frac_hist = (kind == 1).mean()
    assert frac_items > 0.5
    assert frac_hist > 0.05
    assert frac_items > frac_hist
