"""Tensor-parallel serving on a real mesh: config surface + parity.

The sharded cases need forced host devices, set in the environment
BEFORE jax initializes (the CI ``mesh`` job exports it; locally run
``XLA_FLAGS=--xla_force_host_platform_device_count=8 pytest
tests/test_mesh.py``).  It is deliberately NOT set from conftest: the
flag changes XLA:CPU's reduction partitioning, which would break the
bitwise chunked-vs-monolithic invariants the rest of the suite pins.
The two invariants the mesh carries (and these tests pin):

* tp=1 on an explicit (1, 1) mesh decodes tokens **bitwise identical**
  to the unsharded engine (same devices, same executable semantics);
* tp>1 decodes **the same tokens** (logits allclose — GSPMD's
  all-reduces reorder float sums, so bitwise equality is not expected).
"""
import dataclasses

import numpy as np
import pytest

import jax

from repro.serving import api as API
from repro.serving.api import MeshConfig, ServeConfig

needs_devices = pytest.mark.skipif(
    len(jax.devices()) < 8,
    reason="needs 8 host devices: run with XLA_FLAGS="
    "--xla_force_host_platform_device_count=8 (set before jax imports)")


# ------------------------------------------------------------ MeshConfig
def test_mesh_config_defaults_disabled():
    m = MeshConfig()
    assert not m.enabled
    assert m.build() is None
    assert ServeConfig().mesh == m


def test_mesh_config_shape_derives_tp_dp():
    m = MeshConfig(mesh_shape=(2, 4))
    assert (m.tp, m.dp) == (4, 2)
    assert m.enabled
    m = MeshConfig(tp=2)
    assert m.resolved_shape == (1, 2)
    m = MeshConfig(mesh_shape=(2, 2, 2), axis_names=("pod", "data", "model"))
    assert (m.tp, m.dp) == (2, 4)


@pytest.mark.parametrize("kw", [
    dict(tp=0),
    dict(tp=3, mesh_shape=(1, 2)),
    dict(dp=3, mesh_shape=(2, 4)),
    dict(axis_names=("data", "expert")),            # no model axis
    dict(axis_names=("model",)),                    # custom names, no shape
    dict(mesh_shape=(2, 2, 2)),                     # rank != axis_names
    dict(mesh_shape=(0, 2)),
])
def test_mesh_config_rejects(kw):
    with pytest.raises(ValueError, match="invalid MeshConfig"):
        MeshConfig(**kw)


@pytest.mark.parametrize("kw, names", [
    (dict(engine="sim", mesh=MeshConfig(tp=2)), ("mesh.tp", "engine")),
    (dict(attn_backend="pallas", mesh=MeshConfig(tp=2)),
     ("attn_backend", "mesh.tp")),
    (dict(decode_kernel="paged", mesh=MeshConfig(tp=2)),
     ("decode_kernel", "mesh.tp")),
])
def test_serve_config_cross_validates_mesh(kw, names):
    with pytest.raises(ValueError) as ei:
        ServeConfig(**kw)
    for name in names:      # the error names both conflicting knobs
        assert name.split(".")[0] in str(ei.value)


def test_apply_to_resolves_auto_to_gather_under_tp():
    from repro.configs.base import LMConfig
    from repro.core import engine as ENG

    lm = LMConfig(name="t", n_layers=2, d_model=32, n_heads=4, n_kv_heads=2,
                  head_dim=16, d_ff=128, vocab_size=4096)
    cfg = ServeConfig(mesh=MeshConfig(tp=2)).apply_to(lm)
    assert cfg.decode_kernel == "gather"
    assert not ENG.decode_uses_paged(cfg)
    # without a mesh, auto keeps its backend-driven resolution
    assert ServeConfig().apply_to(lm).decode_kernel == "auto"


# ------------------------------------------------- grammar + round trip
def test_parse_dotted_mesh_keys():
    c = ServeConfig.parse("mesh.tp=2,sched=chunked")
    assert c.mesh.tp == 2 and c.sched == "chunked"
    c = ServeConfig.parse("mesh.mesh_shape=2x4")
    assert (c.mesh.tp, c.mesh.dp) == (4, 2)
    c = ServeConfig.parse(
        "mesh.mesh_shape=2x2x2,mesh.axis_names=pod+data+model")
    assert c.mesh.axis_names == ("pod", "data", "model")
    with pytest.raises(ValueError, match="mesh.bogus"):
        ServeConfig.parse("mesh.bogus=1")
    with pytest.raises(ValueError, match="sub-config"):
        ServeConfig.parse("mesh=2")
    with pytest.raises(ValueError, match="int tuple"):
        ServeConfig.parse("mesh.mesh_shape=two")


@pytest.mark.parametrize("cfg", [
    ServeConfig(),
    ServeConfig(engine="sim", k=40, mode="prefix"),
    ServeConfig(sched="chunked", kv_reuse=True, step_tokens=256,
                chunk_tokens=64, r_item=0.5),
    ServeConfig(mesh=MeshConfig(tp=2)),
    ServeConfig(mesh=MeshConfig(mesh_shape=(2, 4))),
    ServeConfig(mesh=MeshConfig(mesh_shape=(2, 1, 2),
                                axis_names=("pod", "data", "model")),
                sched="chunked", kv_reuse=True),
])
def test_config_render_round_trip(cfg):
    """The --config grammar is total: parse(render(cfg)) == cfg for
    every field, including the nested mesh.* keys."""
    assert ServeConfig.parse(cfg.render()) == cfg


def test_from_args_warns_with_exact_config_keys():
    import argparse

    ns = argparse.Namespace(engine="jax", pages=64, kv_reuse="on")
    with pytest.warns(DeprecationWarning) as rec:
        cfg = ServeConfig.from_args(ns)
    assert cfg.n_pages == 64 and cfg.kv_reuse and cfg.engine == "jax"
    msg = str(rec[0].message)
    # the exact --config replacement, not just a generic pointer
    assert "engine=jax" in msg and "n_pages=64" in msg and "kv_reuse=on" in msg


def test_cluster_legacy_kwargs_warn_with_config_keys(tiny):
    from repro.serving.cluster import ClusterEngine

    system, _ = tiny
    with pytest.warns(DeprecationWarning, match=r"--config k=2"):
        ClusterEngine(system, k=2)


# --------------------------------------------------- production mesh fix
@needs_devices
def test_make_production_mesh_auto_factors():
    from repro.launch.mesh import factor_devices, make_production_mesh

    assert factor_devices(8) == (4, 2)
    assert factor_devices(256) == (16, 16)
    assert factor_devices(7) == (7, 1)
    mesh = make_production_mesh()
    assert dict(mesh.shape) == {"data": 4, "model": 2}
    mesh = make_production_mesh(multi_pod=True)
    assert dict(mesh.shape) == {"pod": 2, "data": 2, "model": 2}


def test_make_production_mesh_explicit_shape_error():
    from repro.launch.mesh import make_production_mesh

    with pytest.raises(RuntimeError, match=r"needs 256 devices"):
        make_production_mesh(shape=(16, 16))


# ------------------------------------------------------- parity fixtures
@pytest.fixture(scope="module")
def tiny():
    """One tiny system whose head counts divide every tested tp, plus a
    short trace — shared by the whole parity matrix."""
    from repro.core.rcllm import make_tiny_system
    from repro.data import synth as SY

    system, pool_rv, prof, _ = make_tiny_system(
        n_items=40, n_requests_hist=25, k_instances=2,
        n_layers=2, d_model=32, n_heads=4, n_kv_heads=4)
    trace = SY.make_trace(system.catalog, pool_rv, prof, 6, qps=50.0,
                          n_users=3, n_candidates=6, reviews_per_user=1,
                          seed=3)
    return system, trace


def _serve(system, trace, config):
    """Run the trace through the real batching stack; -> (tokens, engine)."""
    from repro.serving.workload import rcllm_reuse_info, rcllm_workload

    reqs, plans = rcllm_workload(system, trace,
                                 decode_steps=config.decode_steps)
    reuse = rcllm_reuse_info(system, trace, plans) if config.kv_reuse else None
    engine = API.build_engine(system.params, system.cfg, config)
    backend = API.build_backend(engine, config, plans=plans, reuse=reuse)
    API.build_batcher(backend, config).run(reqs)
    return {rid: [int(t) for t in toks]
            for rid, toks in backend.generated.items()}, engine


_REFS = {}


def _reference(system, trace, base):
    key = (base.sched, base.kv_reuse)
    if key not in _REFS:
        _REFS[key] = _serve(system, trace, base)[0]
    return _REFS[key]


@needs_devices
@pytest.mark.parametrize("kv_reuse", [False, True], ids=["priv", "reuse"])
@pytest.mark.parametrize("sched", ["wave", "chunked"])
@pytest.mark.parametrize("tp", [1, 2, 4])
def test_sharded_decode_token_parity(tiny, tp, sched, kv_reuse):
    """tp x {wave,chunked} x {reuse on,off}: decoded tokens equal the
    unsharded reference.  tp=1 runs on an explicit (1, 1) mesh — the
    enabled-but-single-device path must stay bitwise."""
    system, trace = tiny
    base = ServeConfig(engine="jax", sched=sched, kv_reuse=kv_reuse,
                       decode_steps=2)
    ref = _reference(system, trace, base)
    mesh = MeshConfig(mesh_shape=(1, 1)) if tp == 1 else MeshConfig(tp=tp)
    got, engine = _serve(system, trace, base.replace(mesh=mesh))
    assert got == ref
    # the arena really is sharded over the model axis
    msz = dict(engine.mesh.shape)["model"]
    shards = engine.pool.arena_k.addressable_shards
    assert len({s.device for s in shards}) == msz * dict(engine.mesh.shape)["data"]
    hkv = system.cfg.n_kv_heads
    for s in shards:
        assert s.data.shape[0] == engine.pool.n_pages   # pages replicated
        assert s.data.shape[3] == hkv // msz            # kv heads split


@needs_devices
def test_tp1_prefill_logits_bitwise(tiny):
    """Sharded-at-(1,1) params produce byte-identical prefill logits —
    the stronger form of the tp=1 invariant, straight off the jit."""
    from repro.core import engine as ENG
    from repro.sharding.specs import shard_lm_params

    system, _ = tiny
    mesh = MeshConfig(mesh_shape=(1, 1)).build()
    sharded = shard_lm_params(system.params, system.cfg, mesh)
    toks = np.arange(1, 33, dtype=np.int32)[None, :]
    last = np.asarray([31], np.int32)
    ref, rk, rv = ENG._jit_batched_prefill(system.params, toks, last,
                                           system.cfg)
    got, gk, gv = ENG._jit_batched_prefill(sharded, toks, last, system.cfg)
    assert np.array_equal(np.asarray(ref), np.asarray(got))
    assert np.array_equal(np.asarray(rk), np.asarray(gk))


@needs_devices
def test_tp2_prefill_logits_allclose(tiny):
    from repro.core import engine as ENG
    from repro.sharding.specs import shard_lm_params

    system, _ = tiny
    mesh = MeshConfig(tp=2).build()
    sharded = shard_lm_params(system.params, system.cfg, mesh)
    toks = np.arange(1, 33, dtype=np.int32)[None, :]
    last = np.asarray([31], np.int32)
    ref, _, _ = ENG._jit_batched_prefill(system.params, toks, last,
                                         system.cfg)
    got, _, _ = ENG._jit_batched_prefill(sharded, toks, last, system.cfg)
    np.testing.assert_allclose(np.asarray(ref), np.asarray(got),
                               atol=1e-5, rtol=1e-5)


# -------------------------------------------- arena partition invariant
@needs_devices
def test_arena_planes_never_alias_across_requests(tiny):
    """check_partition-style invariant under tp=2 + kv_reuse: page
    ownership stays a partition, and because every device plane indexes
    pages identically (pages replicated, only kv-heads split), disjoint
    page ownership on the host means disjoint planes on every device."""
    from repro.serving import block_store as BS
    from repro.serving.workload import rcllm_reuse_info, rcllm_workload

    system, trace = tiny
    config = ServeConfig(engine="jax", sched="chunked", kv_reuse=True,
                         decode_steps=2, mesh=MeshConfig(tp=2))
    reqs, plans = rcllm_workload(system, trace, decode_steps=2)
    reuse = rcllm_reuse_info(system, trace, plans)
    engine = API.build_engine(system.params, system.cfg, config)
    backend = API.build_backend(engine, config, plans=plans, reuse=reuse)
    batcher = API.build_batcher(backend, config)

    # mid-run + end-of-run: the partition holds at every boundary the
    # batcher exposes (here: after the full run, with live store pages)
    batcher.run(reqs)
    BS.check_partition(engine.pool, engine.store)
    # per-device planes: one page id addresses the same page on every
    # device, so a page owned by request A can never alias request B's
    # rows on any plane
    for arr in (engine.pool.arena_k, engine.pool.arena_v):
        for s in arr.addressable_shards:
            assert s.data.shape[0] == engine.pool.n_pages
    # slot tables are host-side numpy (device-agnostic by construction)
    for table in engine.pool.slot_tables.values():
        assert isinstance(table, np.ndarray)


# ----------------------------------------------------- divisibility guard
@needs_devices
def test_tp_must_divide_kv_heads(tiny):
    system, _ = tiny            # n_kv_heads=4: tp=8 does not divide... use 3
    config = ServeConfig(engine="jax", mesh=MeshConfig(tp=3))
    with pytest.raises(ValueError, match=r"n_kv_heads"):
        API.build_engine(system.params, system.cfg, config)


# -------------------------------------------------- measured transfers
@needs_devices
def test_shard_client_measured_transfers(tiny):
    """With home devices, a cross-shard pull is a real device_put D2D
    copy: measured_s lands in the TransferRecord and the pending
    accumulator, and the block bytes are unchanged."""
    from repro.core import item_cache as IC

    system, _ = tiny
    store = system.item_store
    devs = jax.devices()[:2]
    # find an item resident on shard 1 but not on shard 0
    remote = next(it for it in store.shards[1].blocks
                  if it not in store.shards[0].blocks)
    ledger = IC.ShardClient(store, 0)
    assert not ledger.measures
    blk_l = ledger.pull(remote)
    assert ledger.transfers[0].measured_s == 0.0

    client = IC.ShardClient(store, 0, devices=devs)
    assert client.measures
    blk = client.pull(remote)
    rec = client.transfers[0]
    assert rec.measured_s > 0.0
    assert client.measured_seconds() == rec.measured_s
    assert client.take_measured_s() == rec.measured_s
    assert client.take_measured_s() == 0.0          # drained
    np.testing.assert_array_equal(blk.k, blk_l.k)   # same bytes moved


@needs_devices
def test_cluster_bills_measured_transfer_time(tiny):
    """Under config.mesh the cluster bills the measured D2D seconds
    (sum of per-pull measurements == sum of per-worker billing) and
    decodes the same tokens as the ledgered path."""
    from repro.serving.cluster import ClusterEngine

    system, trace = tiny
    base = ServeConfig(engine="jax", k=2, decode_steps=2)
    rep0 = ClusterEngine(system, base).run(trace, decode_steps=2)
    ce = ClusterEngine(system,
                       base.replace(mesh=MeshConfig(mesh_shape=(1, 1))))
    assert ce.worker_devices is not None
    rep1 = ce.run(trace, decode_steps=2)
    tok = lambda rep: {r: [int(t) for t in ts]            # noqa: E731
                       for r, ts in rep.generated.items()}
    assert tok(rep0) == tok(rep1)
    measured = sum(b.shard.measured_seconds()
                   for b in ce.backends if b.shard)
    billed = sum(b.transfer_seconds for b in ce.backends)
    assert measured == pytest.approx(billed, abs=1e-9)
    n_pulls = sum(len(b.shard.transfers) for b in ce.backends if b.shard)
    if n_pulls:
        assert measured > 0.0


# --------------------------------------------------------- engine guard
@needs_devices
def test_batch_engine_rejects_paged_decode_on_tp_mesh(tiny):
    from repro.serving.batch_engine import BatchEngine

    system, _ = tiny
    mesh = MeshConfig(tp=2).build()
    cfg = dataclasses.replace(system.cfg, decode_kernel="paged")
    with pytest.raises(ValueError, match="paged"):
        BatchEngine(system.params, cfg, mesh=mesh)
