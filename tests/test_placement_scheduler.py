"""Algorithm 1 + Eq. 2 scheduler: invariants and property-based tests."""
import numpy as np
from _hypothesis_compat import given, settings, st  # optional dep

from repro.core import placement as PL
from repro.core import scheduler as SCH


def _random_log(n_items, n_requests, seed=0):
    rng = np.random.default_rng(seed)
    w = 1.0 / np.arange(1, n_items + 1) ** 1.1
    w /= w.sum()
    return [rng.choice(n_items, size=rng.integers(2, 10), replace=False,
                       p=w) for _ in range(n_requests)]


def test_algorithm1_invariants():
    log = _random_log(500, 300)
    pl = PL.place(500, log, k=8)
    # every item is either hot (-1) or on exactly one shard in [0, k)
    assert ((pl.shard_of == -1) | ((pl.shard_of >= 0) &
                                   (pl.shard_of < 8))).all()
    assert len(pl.hot_items) == max(1, int(np.ceil(0.001 * 500)))
    # hot items are the most popular ones
    pop = PL.popularity_from_requests(500, log)
    assert set(pl.hot_items) <= set(np.argsort(-pop)[:10])
    # balance: no shard holds more than slack × fair share of heat
    cold_heat = pop[pl.shard_of >= 0].sum()
    assert pl.balance.max() <= cold_heat / 8 * 1.1 + pop.max() + 1e-6


def _clustered_log(n_items, n_requests, n_clusters=20, seed=3):
    """Requests draw mostly from one cluster — the co-occurrence structure
    Algorithm 1 exploits (paper: 'books in a series')."""
    rng = np.random.default_rng(seed)
    cluster_of = rng.integers(0, n_clusters, n_items)
    log = []
    for _ in range(n_requests):
        c = rng.integers(0, n_clusters)
        members = np.where(cluster_of == c)[0]
        n = min(len(members), int(rng.integers(3, 9)))
        items = rng.choice(members, n, replace=False)
        if rng.random() < 0.3:
            items = np.concatenate([items,
                                    rng.choice(n_items, 2, replace=False)])
        log.append(items)
    return log


def test_similarity_placement_beats_random_on_hit_rate():
    log = _clustered_log(400, 400, seed=3)
    pop = PL.popularity_from_requests(400, log)
    smart = PL.place(400, log, k=8)
    # note: a distinct seed — sharing the log's RNG stream makes "random"
    # accidentally cluster-aligned (identical underlying uniforms)
    rand = PL.random_placement(400, pop, k=8, seed=1234)

    def mean_best_hit(pl):
        hits = []
        for items in log:
            hits.append(max(SCH.hit_vector(items, pl)))
        return np.mean(hits)

    assert mean_best_hit(smart) > mean_best_hit(rand) + 0.05


def test_scheduler_affinity_tradeoff():
    log = _random_log(200, 100, seed=1)
    pl = PL.place(200, log, k=4)
    st_ = SCH.SchedulerState.fresh(4)
    # idle cluster → affinity == hit-only choice
    items = log[0]
    a = SCH.route(items, pl, st_, policy="affinity")
    h = SCH.route(items, pl, st_, policy="hit_only")
    assert a == h
    # overload the hit-optimal node → affinity diverts, hit-only does not
    st_.queue_depth[a] = 1e6
    a2 = SCH.route(items, pl, st_, policy="affinity", alpha=0.2, beta=0.8)
    h2 = SCH.route(items, pl, st_, policy="hit_only")
    assert h2 == h
    assert a2 != a


def test_round_robin_cycles():
    pl = PL.random_placement(10, np.ones(10), k=4)
    st_ = SCH.SchedulerState.fresh(4)
    outs = [SCH.route(np.array([0]), pl, st_, policy="round_robin")
            for _ in range(8)]
    assert outs == [0, 1, 2, 3, 0, 1, 2, 3]


@given(st.integers(2, 6), st.integers(20, 60), st.integers(0, 1000))
@settings(max_examples=20, deadline=None)
def test_partition_property(k, n_items, seed):
    """Property: partition never loses items, respects hot set, and the
    reported edge cut only counts cross-shard cold edges."""
    log = _random_log(n_items, 50, seed=seed)
    pl = PL.place(n_items, log, k=k)
    assert len(pl.shard_of) == n_items
    assert (pl.shard_of >= -1).all() and (pl.shard_of < k).all()
    edges = PL.cooccurrence_graph(n_items, log)
    cut = sum(w for (a, b), w in edges.items()
              if pl.shard_of[a] >= 0 and pl.shard_of[b] >= 0
              and pl.shard_of[a] != pl.shard_of[b])
    assert abs(cut - pl.edge_cut) < 1e-9


@given(st.floats(0.05, 0.95))
@settings(max_examples=10, deadline=None)
def test_refresh_trigger_monotone(drift):
    old = np.ones(100)
    new = np.ones(100)
    new[:50] *= (1 + 4 * drift)
    fired = PL.needs_refresh(old, new, drift_threshold=0.25)
    tv = 0.5 * np.abs(old / old.sum() - new / new.sum()).sum()
    assert fired == (tv > 0.25)
