"""Optimizers, checkpointing, fault-tolerant train loop, MoE dispatch,
data pipeline."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st  # optional dep

from repro.checkpoint import checkpoint as CKPT
from repro.configs import registry as R
from repro.data.pipeline import BatchPipeline, lm_synthetic_batches
from repro.models import layers as L
from repro.models import transformer as T
from repro.training import optimizer as OPT
from repro.training.train_loop import TrainConfig, train


@pytest.mark.parametrize("opt_name", ["adamw", "adafactor", "sgd"])
def test_optimizer_decreases_quadratic(opt_name):
    init, update = OPT.get(opt_name, lr=0.1)
    params = {"w": jnp.asarray([3.0, -2.0]), "b": jnp.asarray(1.5)}
    state = init(params)
    loss = lambda p: jnp.sum(p["w"] ** 2) + p["b"] ** 2
    l0 = loss(params)
    for _ in range(50):
        g = jax.grad(loss)(params)
        params, state, _ = update(g, state, params)
    assert float(loss(params)) < float(l0) * 0.5


def test_adafactor_state_is_factored():
    init, _ = OPT.get("adafactor")
    params = {"w": jnp.zeros((64, 32)), "v": jnp.zeros((16,))}
    st_ = init(params)
    assert st_.inner["w"]["vr"].shape == (64,)
    assert st_.inner["w"]["vc"].shape == (32,)
    assert st_.inner["v"]["v"].shape == (16,)


def test_moe_matches_dense_reference(rng):
    cfg = R.get_config("kimi-k2-1t-a32b", smoke=True)
    x = jnp.asarray(rng.normal(size=(64, 64)), jnp.float32)
    mp = L.moe_init(jax.random.PRNGKey(3), 64, cfg.moe, cfg.mlp_type,
                    jnp.float32)
    y, _ = L.moe_apply(x, mp, n_experts=cfg.moe.n_experts,
                       top_k=cfg.moe.top_k, capacity_factor=8.0,
                       mlp_type=cfg.mlp_type)
    probs = jax.nn.softmax(x @ mp["router"])
    gv, ei = jax.lax.top_k(probs, cfg.moe.top_k)
    gv = gv / gv.sum(-1, keepdims=True)
    ref = jnp.zeros_like(x)
    for e in range(cfg.moe.n_experts):
        he = jax.nn.silu(x @ mp["w_gate"][e]) * (x @ mp["w_up"][e])
        ref += ((gv * (ei == e)).sum(-1))[:, None] * (he @ mp["w_down"][e])
    assert float(jnp.abs(y - ref).max() / jnp.abs(ref).max()) < 1e-5


def test_moe_capacity_drops_tokens(rng):
    cfg = R.get_config("kimi-k2-1t-a32b", smoke=True)
    x = jnp.asarray(rng.normal(size=(64, 64)), jnp.float32)
    mp = L.moe_init(jax.random.PRNGKey(3), 64, cfg.moe, cfg.mlp_type,
                    jnp.float32)
    y_small, _ = L.moe_apply(x, mp, n_experts=4, top_k=2,
                             capacity_factor=0.25, mlp_type=cfg.mlp_type)
    y_big, _ = L.moe_apply(x, mp, n_experts=4, top_k=2,
                           capacity_factor=8.0, mlp_type=cfg.mlp_type)
    # dropping must change results but keep them finite
    assert bool(jnp.isfinite(y_small).all())
    assert float(jnp.abs(y_small - y_big).max()) > 0


def test_masked_perm_gather_grad(rng):
    x = jnp.asarray(rng.normal(size=(16, 8)), jnp.float32)
    perm = jnp.asarray(rng.permutation(16), jnp.int32)
    inv = jnp.zeros(16, jnp.int32).at[perm].set(jnp.arange(16, dtype=jnp.int32))
    ones = jnp.ones(16, bool)
    f1 = lambda x: (L.masked_perm_gather(x, perm, ones, inv, ones) ** 2).sum()
    f2 = lambda x: (jnp.take(x, perm, axis=0) ** 2).sum()
    g1, g2 = jax.grad(f1)(x), jax.grad(f2)(x)
    assert float(jnp.abs(g1 - g2).max()) < 1e-6


def test_checkpoint_roundtrip_and_gc():
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
            "b": [jnp.zeros(4), {"c": jnp.ones((2, 2), jnp.bfloat16)}]}
    with tempfile.TemporaryDirectory() as d:
        for s in (1, 2, 3, 4, 5):
            CKPT.save(d, s, tree)
        CKPT.gc_old(d, keep=2)
        steps = sorted(int(f[5:13]) for f in os.listdir(d)
                       if f.endswith(".ckpt"))
        assert steps == [4, 5]
        out = CKPT.restore(d, 5, tree)
        for a, b in zip(jax.tree_util.tree_leaves(out),
                        jax.tree_util.tree_leaves(tree)):
            np.testing.assert_array_equal(np.asarray(a, np.float32),
                                          np.asarray(b, np.float32))


@pytest.mark.slow
def test_train_loop_resume_and_failures():
    cfg = R.get_config("gemma-7b", smoke=True)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    loss_fn = lambda p, b: T.loss_fn(p, b["tokens"], b["labels"], cfg)[0]
    pipe = BatchPipeline(lm_synthetic_batches(cfg.vocab_size, 4, 16))
    with tempfile.TemporaryDirectory() as d:
        fails = {3: 0}

        def inject(step):
            if step in fails and fails[step] < 2:
                fails[step] += 1
                raise RuntimeError("node failure")

        tc = TrainConfig(steps=8, ckpt_dir=d, ckpt_every=2, lr=1e-3)
        p2, _, hist = train(params, loss_fn, iter(pipe), tc,
                            fail_injector=inject)
        assert len(hist) == 8
        assert CKPT.latest_step(d) == 8
        # resume: running again with steps=12 continues from 8
        p3, _, hist2 = train(p2, loss_fn, iter(pipe),
                             TrainConfig(steps=12, ckpt_dir=d, ckpt_every=4,
                                         lr=1e-3))
        assert len(hist2) == 4
    pipe.close()


@given(st.sampled_from(["int8", "topk"]))
@settings(max_examples=4, deadline=None)
def test_grad_compression_preserves_direction(kind):
    from repro.training.train_loop import apply_compression, TrainConfig
    rng = np.random.default_rng(0)
    g = {"w": jnp.asarray(rng.normal(size=(64, 8)), jnp.float32)}
    err = {"w": jnp.zeros((64, 8))}
    cfg = TrainConfig(grad_compression=kind, topk_frac=0.25)
    cg, _ = apply_compression(g, cfg, err)
    cos = float((cg["w"] * g["w"]).sum() /
                (jnp.linalg.norm(cg["w"]) * jnp.linalg.norm(g["w"]) + 1e-9))
    assert cos > 0.5


def test_pipeline_host_sharding():
    make = lm_synthetic_batches(100, 8, 4)
    p0 = BatchPipeline(make, host_index=0, n_hosts=2)
    b = next(iter(p0))
    assert b["tokens"].shape == (4, 4)
    p0.close()


def test_checkpoint_elastic_restore_with_shardings():
    """Restore re-lays-out leaves for a different mesh (elastic scaling)."""
    import tempfile
    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P
    tree = {"w": jnp.arange(32, dtype=jnp.float32).reshape(4, 8)}
    mesh = jax.make_mesh((1, 1), ("data", "model"),
                         devices=jax.devices()[:1])
    sh = {"w": NamedSharding(mesh, P("data", None))}
    with tempfile.TemporaryDirectory() as d:
        CKPT.save(d, 1, tree)
        out = CKPT.restore(d, 1, tree, shardings=sh)
        assert out["w"].sharding == sh["w"]
        np.testing.assert_array_equal(np.asarray(out["w"]),
                                      np.asarray(tree["w"]))
