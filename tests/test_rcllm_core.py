"""RcLLM core: semantic cache, assembly, selective engine, baselines,
simulator — the paper's mechanisms end-to-end on a tiny model."""

import numpy as np
import pytest

from repro.configs.base import LMConfig
from repro.core import assembly as ASM
from repro.core import cost_model as CM
from repro.core import engine as ENG
from repro.core import metrics as MET
from repro.core import semantic_cache as SC
from repro.core import simulator as SIM
from repro.core.engine import SelectiveConfig
from repro.core.rcllm import make_tiny_system
from repro.data import synth as SY


@pytest.fixture(scope="module")
def tiny():
    system, pool, prof, hist = make_tiny_system(
        n_items=60, n_requests_hist=40, k_instances=3, n_layers=3,
        d_model=48)
    return system, pool, prof, hist


def test_semantic_match_rate(tiny):
    """Insight 1: most tokens of NEW reviews from the same phrase pool match
    a prototype (paper: >93%; synthetic pool is smaller, expect high)."""
    system, pool, prof, _ = tiny
    rng = np.random.default_rng(123)
    rev = SY.make_review(pool, prof.mean_review_tokens, rng)
    pos = np.arange(len(rev))
    emb = SC.embed_tokens_for_match(rev, pos, system.token_embed)
    pid, sim = system.semantic.match(rev, pos, emb)
    match = (pid >= 0).mean()
    assert match > 0.6
    assert sim[pid >= 0].mean() > 0.8


def test_plan_structure(tiny):
    system, pool, prof, _ = tiny
    reqs = SY.make_trace(system.catalog, pool, prof, 3, qps=5.0, n_users=5,
                         n_candidates=6, reviews_per_user=2, seed=77)
    plan = system.plan_for(reqs[0])
    # instruction tokens are never reused
    assert (plan.source[plan.seg_kind == 0] == ASM.RECOMPUTE).all()
    # item tokens resolve to item blocks with correct offsets
    it = plan.source == ASM.FROM_ITEM
    assert it.sum() > 0
    assert (plan.block_item[it] >= 0).all()
    # rope delta = position − block offset for item tokens
    idx = np.where(it)[0]
    np.testing.assert_array_equal(plan.rope_delta[idx],
                                  idx - plan.block_offset[idx])
    # full coverage: no misses at coverage=1
    assert plan.n_miss == 0


def test_selective_equals_full_at_r1(tiny):
    """r=1 + window ≥ n ⇒ every token recomputed ⇒ logits == full forward."""
    system, pool, prof, _ = tiny
    reqs = SY.make_trace(system.catalog, pool, prof, 1, qps=5.0, n_users=5,
                         n_candidates=5, reviews_per_user=2, seed=88)
    r = reqs[0]
    tokens, _, _ = r.prompt_segments(system.catalog, system.instruction)
    full = ENG.full_prefill_logits(system.params, system.cfg, tokens)
    sel = SelectiveConfig(r_item=1.0, r_rev=1.0, window=len(tokens))
    sc, stats = system.rank(r, "rcllm", sel)
    full_slots = full[SY.SLOT_BASE:SY.SLOT_BASE + len(r.candidate_items)]
    assert stats.recompute_fraction() == 1.0
    np.testing.assert_allclose(sc, full_slots, atol=2e-3, rtol=1e-3)


def test_selective_budget_controls_recompute(tiny):
    system, pool, prof, _ = tiny
    reqs = SY.make_trace(system.catalog, pool, prof, 1, qps=5.0, n_users=5,
                         n_candidates=6, reviews_per_user=2, seed=89)
    fr = []
    for r_b in (0.1, 0.5, 0.9):
        _, stats = system.rank(reqs[0], "rcllm",
                               SelectiveConfig(r_item=r_b, r_rev=r_b,
                                               window=8))
        fr.append(stats.recompute_fraction())
    assert fr[0] < fr[1] < fr[2]


def test_baselines_run(tiny):
    system, pool, prof, _ = tiny
    reqs = SY.make_trace(system.catalog, pool, prof, 1, qps=5.0, n_users=5,
                         n_candidates=5, reviews_per_user=2, seed=90)
    for m in ("cacheblend", "epic"):
        sc, stats = system.rank(reqs[0], m)
        assert np.isfinite(sc).all()
        assert 0 < stats.n_recomputed < stats.n_tokens


def test_fidelity_close_to_full(tiny):
    system, pool, prof, _ = tiny
    reqs = SY.make_trace(system.catalog, pool, prof, 3, qps=5.0, n_users=5,
                         n_candidates=6, reviews_per_user=2, seed=91)
    fids = []
    for r in reqs:
        full, _ = system.rank(r, "full")
        sc, _ = system.rank(r, "rcllm",
                            SelectiveConfig(r_item=0.3, r_rev=0.3, window=16))
        fids.append(MET.ranking_agreement_ndcg(full, sc, k=5))
    assert np.mean(fids) > 0.85


def test_cost_model_orderings():
    cfg = LMConfig(name="m", n_layers=8, d_model=256, n_heads=8,
                   n_kv_heads=4, head_dim=32, d_ff=1024, vocab_size=1000)
    hw = CM.V5E_1
    full = CM.full_prefill_ttft_s(cfg, hw, 3000)
    prefix = CM.prefix_cache_ttft_s(cfg, hw, 3000, 207)
    rc = CM.ttft_s(cfg, hw, 3000, n_recompute=900, n_local_tokens=2000,
                   n_remote_tokens=100)
    assert rc < prefix < full
    # remote fetches cost more than local
    rc_remote = CM.ttft_s(cfg, hw, 3000, 900, 100, 2000)
    assert rc_remote >= rc


def test_simulator_orderings_and_faults(tiny):
    # paper-scale prompts + cost model (Qwen3-8B-like): the tiny accuracy
    # prototype is compute-degenerate (network RTT would dominate)
    from repro.configs import registry as REG
    cfg = REG.ARCHS["rcllm-qwen3-8b"]
    reqs, placement, _ = SIM.make_sim_setup(k=4, n_requests=300, qps=12.0,
                                            n_items=2000, seed=5)
    res = {}
    for mode in ("rcllm", "prefix", "full"):
        sim = SIM.SimConfig(mode=mode, policy="affinity")
        res[mode] = SIM.simulate(cfg, CM.V5E_1, reqs, placement, sim)
    assert res["rcllm"].pct(50) < res["prefix"].pct(50) < res["full"].pct(50)
    # node failure: still completes, latency does not improve
    faults = [SIM.NodeFault(instance=0, t_fail_s=0.0, t_repair_s=0.3)]
    resf = SIM.simulate(cfg, CM.V5E_1, reqs, placement,
                        SIM.SimConfig(mode="rcllm"), faults=faults)
    assert resf.n_requests == len(reqs)
    assert resf.pct(50) >= res["rcllm"].pct(50) * 0.99
    # straggler + hedging: hedge should not hurt P99 much
    slow = np.ones(placement.k)
    slow[1] = 8.0
    r_noh = SIM.simulate(cfg, CM.V5E_1, reqs, placement,
                         SIM.SimConfig(mode="rcllm"),
                         straggler_factors=slow)
    r_h = SIM.simulate(cfg, CM.V5E_1, reqs, placement,
                       SIM.SimConfig(mode="rcllm", hedge_ms=5.0),
                       straggler_factors=slow)
    assert r_h.pct(99) <= r_noh.pct(99) * 1.05
