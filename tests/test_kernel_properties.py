"""Property-based kernel-parity harness: every Pallas kernel family vs
its pure-jnp `ref.py` oracle over randomized shapes.

Two drivers per family share one check function:

* a seeded-random sweep (`pytest.mark.parametrize` over fixed seeds) —
  always runs, so CI exercises randomized shapes even without
  hypothesis installed;
* a hypothesis `@given` explorer over the seed space — skips itself via
  `_hypothesis_compat` when hypothesis is absent.

Randomization covers what the fixed-shape sweeps in `test_kernels.py`
cannot: ragged `kv_valid` patterns (arbitrary interleaved dead slots,
not just padded tails), GQA group factors 1/2/4, pow2-padded batch
sizes, and page views whose slots scatter logical positions across
physical pages at arbitrary alignment — the layouts cross-request
sharing actually produces.

Every masked oracle relies on the same exactness property: a dead slot
scores `NEG_INF`, whose softmax weight underflows to exactly 0.0 in
fp32, and adding 0.0 terms never perturbs a float reduction — so a
masked computation equals the oracle run on the compacted live keys.
"""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st  # optional dep

from repro.kernels.block_gather.ops import assemble_kv
from repro.kernels.block_gather.ref import block_gather_ref
from repro.kernels.embedding_bag.ops import bag_sum
from repro.kernels.embedding_bag.ref import embedding_bag_ref
from repro.kernels.flash_attention.ops import mha_flash
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.paged_attention.ops import paged_decode_mha
from repro.kernels.paged_attention.ref import (
    NEG_INF,
    masked_decode_attention_ref,
    paged_decode_ref,
)
from repro.kernels.selective_attention.ops import selective_mha
from repro.kernels.selective_attention.ref import selective_attention_ref
from repro.serving.kv_pool import page_views

SWEEP_SEEDS = range(6)
GQA_GROUPS = (1, 2, 4)


# ------------------------------ flash ----------------------------------
def _check_flash(seed: int) -> None:
    rng = np.random.default_rng(seed)
    B = int(2 ** rng.integers(0, 3))              # pow2-padded batch
    G = int(rng.choice(GQA_GROUPS))
    Hkv = int(rng.choice([1, 2]))
    D = int(rng.choice([8, 16, 32]))
    Sq = int(rng.integers(1, 80))
    Skv = int(rng.integers(1, 120))
    causal = bool(rng.integers(0, 2)) and Sq <= Skv
    dtype = jnp.bfloat16 if rng.integers(0, 4) == 0 else jnp.float32
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    Hq = G * Hkv
    q = jnp.asarray(rng.normal(size=(B, Sq, Hq, D)), dtype)
    k = jnp.asarray(rng.normal(size=(B, Skv, Hkv, D)), dtype)
    v = jnp.asarray(rng.normal(size=(B, Skv, Hkv, D)), dtype)
    out = mha_flash(q, k, v, causal=causal, q_block=16, kv_block=32, interpret=True)
    kk = jnp.repeat(k, G, 2).transpose(0, 2, 1, 3).reshape(B * Hq, Skv, D)
    vv = jnp.repeat(v, G, 2).transpose(0, 2, 1, 3).reshape(B * Hq, Skv, D)
    qq = q.transpose(0, 2, 1, 3).reshape(B * Hq, Sq, D)
    ref = flash_attention_ref(qq, kk, vv, causal=causal)
    ref = ref.reshape(B, Hq, Sq, D).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=tol, rtol=tol
    )


def _check_flash_ragged(seed: int) -> None:
    """Arbitrary interleaved `kv_valid` patterns (not just padded tails):
    the masked kernel must equal the oracle run on each row's compacted
    live keys."""
    rng = np.random.default_rng(seed)
    B = int(2 ** rng.integers(0, 3))
    G = int(rng.choice(GQA_GROUPS))
    Hkv = int(rng.choice([1, 2]))
    D = int(rng.choice([8, 16]))
    Sq = int(rng.integers(1, 40))
    Skv = int(rng.integers(2, 100))
    Hq = G * Hkv
    q = jnp.asarray(rng.normal(size=(B, Sq, Hq, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, Skv, Hkv, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, Skv, Hkv, D)), jnp.float32)
    valid = rng.random((B, Skv)) < rng.uniform(0.2, 0.9)
    valid[np.arange(B), rng.integers(0, Skv, B)] = True  # >=1 live key
    out = mha_flash(
        q,
        k,
        v,
        kv_valid=jnp.asarray(valid),
        causal=False,
        q_block=16,
        kv_block=32,
        interpret=True,
    )
    for b in range(B):
        kb = jnp.repeat(k[b, valid[b]], G, 1).transpose(1, 0, 2)
        vb = jnp.repeat(v[b, valid[b]], G, 1).transpose(1, 0, 2)
        qb = q[b].transpose(1, 0, 2)
        ref = flash_attention_ref(qb, kb, vb, causal=False)
        np.testing.assert_allclose(
            np.asarray(out[b]),
            np.asarray(ref.transpose(1, 0, 2)),
            atol=1e-5,
            rtol=1e-5,
        )


# ---------------------------- selective --------------------------------
def _check_selective(seed: int) -> None:
    rng = np.random.default_rng(seed)
    B, Hkv, D = 1, int(rng.choice([1, 2])), 32
    G = int(rng.choice([1, 2]))
    Hq = G * Hkv
    S = int(rng.integers(32, 200))
    R_ = int(rng.integers(1, min(S, 48) + 1))
    window = int(rng.choice([8, 24, 64]))
    q = jnp.asarray(rng.normal(size=(B, R_, Hq, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, Hkv, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, Hkv, D)), jnp.float32)
    qpos = jnp.asarray(np.sort(rng.choice(S, R_, replace=False)), jnp.int32)
    hh = (rng.random(S) < rng.uniform(0, 0.3)).astype(np.int8)
    out = selective_mha(
        q,
        qpos,
        k,
        v,
        jnp.asarray(hh),
        window=window,
        q_block=16,
        kv_block=32,
        interpret=True,
    )
    qf = q.transpose(0, 2, 1, 3).reshape(B * Hq, R_, D)
    kf = jnp.repeat(k, G, 2).transpose(0, 2, 1, 3).reshape(B * Hq, S, D)
    vf = jnp.repeat(v, G, 2).transpose(0, 2, 1, 3).reshape(B * Hq, S, D)
    ref = selective_attention_ref(qf, qpos, kf, vf, jnp.asarray(hh), window=window)
    ref = ref.reshape(B, Hq, R_, D).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5, rtol=1e-5)


# --------------------------- block gather ------------------------------
def _check_block_gather(seed: int) -> None:
    rng = np.random.default_rng(seed)
    npages = int(rng.integers(4, 48))
    page = int(rng.choice([4, 8, 16]))
    d = int(rng.choice([16, 32, 64]))
    n_logical = int(rng.integers(1, npages + 1))
    rotate = bool(rng.integers(0, 2))
    pk = jnp.asarray(rng.normal(size=(npages, page, d)), jnp.float32)
    pv = jnp.asarray(rng.normal(size=(npages, page, d)), jnp.float32)
    bt = jnp.asarray(rng.choice(npages, n_logical, replace=False), jnp.int32)
    pos = jnp.asarray(rng.integers(0, 4096, (n_logical, page)), jnp.int32)
    ko, vo = assemble_kv(
        pk,
        pv,
        bt,
        pos,
        rope_theta=1e4,
        rotate=rotate,
        interpret=True,
    )
    kr, vr = block_gather_ref(pk, pv, bt, pos, rope_theta=1e4, rotate=rotate)
    np.testing.assert_allclose(np.asarray(ko), np.asarray(kr), atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(np.asarray(vo), np.asarray(vr), atol=1e-6)


# --------------------------- embedding bag -----------------------------
def _check_embedding_bag(seed: int) -> None:
    rng = np.random.default_rng(seed)
    rows = int(rng.integers(8, 600))
    d = int(rng.choice([8, 16, 32, 64]))
    B = int(2 ** rng.integers(0, 5))
    F = int(rng.integers(1, 16))
    dtype = jnp.bfloat16 if rng.integers(0, 4) == 0 else jnp.float32
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    table = jnp.asarray(rng.normal(size=(rows, d)), dtype)
    ids = jnp.asarray(rng.integers(0, rows, (B, F)), jnp.int32)
    out = bag_sum(table, ids, interpret=True)
    ref = embedding_bag_ref(table, ids)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=tol, rtol=tol
    )


# --------------------------- paged decode ------------------------------
def _random_layout(rng, n_pages, page, n_rows, max_len):
    """Random per-row slot tables the way serving produces them: each
    row's logical positions land in arbitrary (possibly shared, never
    page-aligned) physical slots, plus one freshly claimed decode slot.
    -> (tables (N, S), lens (N,), new_pages (N,), new_slots (N,))."""
    lens = rng.integers(0, max_len, n_rows)
    S = max(int(lens.max()) + 1, 1)
    tables = np.zeros((n_rows, S), np.int64)
    new_pages = np.zeros(n_rows, np.int64)
    new_slots = np.zeros(n_rows, np.int64)
    for i in range(n_rows):
        # slots off the scratch page, distinct within the row, arbitrary
        # alignment (a draw may interleave any pages at any offsets)
        slots = rng.choice(
            np.arange(page, n_pages * page), int(lens[i]) + 1, replace=False
        )
        tables[i, : lens[i]] = slots[:-1]
        new_pages[i] = slots[-1] // page
        new_slots[i] = slots[-1] % page
    return tables, lens.astype(np.int64), new_pages, new_slots


def _check_paged_decode(seed: int) -> None:
    rng = np.random.default_rng(seed)
    page = int(rng.choice([4, 8, 16]))
    n_pages = int(rng.integers(6, 24))
    L = int(rng.integers(1, 3))
    Hkv = int(rng.choice([1, 2]))
    G = int(rng.choice(GQA_GROUPS))
    D = int(rng.choice([8, 16, 32]))
    N = int(2 ** rng.integers(0, 4))              # pow2-padded batch
    Hq = G * Hkv
    max_len = min(n_pages * page - page - 1, int(rng.integers(2, 40)))
    tables, lens, new_pages, new_slots = _random_layout(rng, n_pages, page, N, max_len)
    pg_ids, sl_pos = page_views(tables, lens, new_pages, new_slots, page)
    ak = jnp.asarray(rng.normal(size=(n_pages, page, L, Hkv, D)), jnp.float32)
    av = jnp.asarray(rng.normal(size=(n_pages, page, L, Hkv, D)), jnp.float32)
    q = jnp.asarray(rng.normal(size=(N, Hq, D)), jnp.float32)
    for layer in range(L):
        out = paged_decode_mha(
            q,
            ak,
            av,
            jnp.asarray(pg_ids),
            jnp.asarray(sl_pos),
            layer=layer,
            rope_theta=1e4,
            interpret=True,
        )
        ref = paged_decode_ref(
            q,
            ak,
            av,
            jnp.asarray(pg_ids),
            jnp.asarray(sl_pos),
            layer=layer,
            rope_theta=1e4,
        )
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(ref), atol=1e-5, rtol=1e-5
        )


def _check_page_views(seed: int) -> None:
    """Structural invariants of the page view itself: every logical
    position 0..len appears exactly once, tagged at the physical slot
    the table maps it to; everything else is dead (-1); pad columns
    reference the scratch page."""
    rng = np.random.default_rng(seed)
    page = int(rng.choice([2, 4, 8, 16]))
    n_pages = int(rng.integers(4, 32))
    N = int(rng.integers(1, 9))
    max_len = min(n_pages * page - page - 1, int(rng.integers(1, 50)))
    tables, lens, new_pages, new_slots = _random_layout(rng, n_pages, page, N, max_len)
    pg_ids, sl_pos = page_views(tables, lens, new_pages, new_slots, page)
    assert pg_ids.shape[1] % 4 == 0
    assert sl_pos.shape == pg_ids.shape + (page,)
    for i in range(N):
        ln = int(lens[i])
        live = {}
        for j in range(pg_ids.shape[1]):
            for t in range(page):
                p = int(sl_pos[i, j, t])
                if p >= 0:
                    assert p not in live, "logical position served twice"
                    live[p] = int(pg_ids[i, j]) * page + t
        assert sorted(live) == list(range(ln + 1))
        for p in range(ln):
            assert live[p] == tables[i, p]
        assert live[ln] == new_pages[i] * page + new_slots[i]
        # pad view columns reference the scratch page, fully dead
        n_used = len({tables[i, p] // page for p in range(ln)} | {int(new_pages[i])})
        assert (pg_ids[i, n_used:] == 0).all()
        assert (sl_pos[i, n_used:] == -1).all()


_FAMILIES = {
    "flash": _check_flash,
    "flash_ragged": _check_flash_ragged,
    "selective": _check_selective,
    "block_gather": _check_block_gather,
    "embedding_bag": _check_embedding_bag,
    "paged_decode": _check_paged_decode,
    "page_views": _check_page_views,
}


@pytest.mark.parametrize("family", sorted(_FAMILIES))
@pytest.mark.parametrize("seed", SWEEP_SEEDS)
def test_kernel_parity_sweep(family, seed):
    """Seeded-random sweep — the always-on harness (CI runs this even
    without hypothesis)."""
    _FAMILIES[family](seed)


@pytest.mark.slow
@pytest.mark.parametrize("family", sorted(_FAMILIES))
def test_kernel_parity_hypothesis(family):
    """Hypothesis-driven seed exploration (skips without hypothesis)."""

    @given(st.integers(0, 2**31 - 1))
    @settings(max_examples=15, deadline=None)
    def explore(seed):
        _FAMILIES[family](seed)

    explore()


# ------------------------ oracle-drift regression -----------------------
def test_decode_oracles_cannot_drift():
    """`batch_engine._decode_attn` (the gather path) and the paged
    kernel's oracle must share one attention body: identical inputs ->
    bitwise-identical outputs, and the masking constant stays pinned."""
    from repro.serving.batch_engine import _decode_attn

    assert NEG_INF == -1e30
    rng = np.random.default_rng(7)
    N, T, Hkv, G, D = 4, 33, 2, 2, 16
    q = jnp.asarray(rng.normal(size=(N, G * Hkv, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(N, T, Hkv, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(N, T, Hkv, D)), jnp.float32)
    valid = rng.random((N, T)) < 0.6
    valid[:, -1] = True
    a = _decode_attn(q, k, v, jnp.asarray(valid))
    b = masked_decode_attention_ref(q, k, v, jnp.asarray(valid))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_decode_kernel_config_resolution():
    """`decode_kernel` plumbing: auto follows the backend, gather/paged
    pin either path, anything else is rejected."""
    from repro.configs.base import LMConfig
    from repro.core.engine import decode_uses_paged

    cfg = LMConfig(
        name="t",
        n_layers=1,
        d_model=32,
        n_heads=4,
        n_kv_heads=2,
        d_ff=64,
        vocab_size=64,
    )
    assert not decode_uses_paged(cfg)  # jnp + auto
    assert decode_uses_paged(dataclasses.replace(cfg, attn_backend="pallas"))
    assert decode_uses_paged(dataclasses.replace(cfg, decode_kernel="paged"))
    assert not decode_uses_paged(
        dataclasses.replace(cfg, attn_backend="pallas", decode_kernel="gather")
    )
    with pytest.raises(ValueError, match="decode_kernel"):
        decode_uses_paged(dataclasses.replace(cfg, decode_kernel="bogus"))
