"""Disaggregated prefill/decode serving: KV migration correctness.

Pins the tentpole invariants of role-typed serving:

* `export_request` then `import_request` is a lossless round-trip at
  the pool layer — arena bytes, slot-table semantics (private AND
  store-shared entries), seq_len, spare slots and the ownership
  partition all survive, including chunk-partial (truncated seq_len)
  exports, across random layouts (seeded sweep + hypothesis variant);
* a store payload rides its content key: a destination already holding
  the digest takes a reference and moves zero bytes;
* engine-level `import_request_kv` is transactional — `PoolExhausted`
  rolls back every page and store reference it took;
* a chunk-partial prefill handed to a *different* engine finalizes to
  the exact logits the source engine would have produced;
* the cluster decodes identical tokens with disaggregation on vs off
  across {wave, chunked} x {kv-reuse on, off} on the heavy-tail trace,
  and the unified default keeps every migration counter at zero;
* the `DisaggConfig` surface validates its invariants and round-trips
  through the `--config` grammar.
"""
import dataclasses

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.serving import api as API
from repro.serving import block_store as BS
from repro.serving import workload as WL
from repro.serving.batch_engine import (BatchEngine, BatchRequest, RequestKV,
                                        migration_bytes)
from repro.serving.block_store import SharedBlockStore, check_partition
from repro.serving.cluster import ClusterEngine
from repro.serving.kv_pool import PagedKVPool, PoolExhausted, pool_for

L, HKV, DH, PS = 2, 2, 4, 4  # tiny arena geometry for the pool tests


@pytest.fixture(scope="module")
def tiny_system():
    from repro.core.rcllm import make_tiny_system

    return make_tiny_system(
        n_items=60, n_requests_hist=30, k_instances=2, n_layers=2, d_model=32
    )


@pytest.fixture(scope="module")
def heavy_workload(tiny_system):
    """Heavy-tail trace (some long prompts) + plans + reuse metadata."""
    system, pool_rv, prof, _ = tiny_system
    trace = WL.heavy_tail_trace(system.catalog, pool_rv, prof, 6, qps=8.0,
                                n_users=3, long_prompt_frac=0.4,
                                long_prompt_reviews=6, seed=5)
    pend, plans = WL.rcllm_workload(system, trace, decode_steps=3)
    reuse = WL.rcllm_reuse_info(system, trace, plans)
    return trace, pend, plans, reuse


# ------------------------------------------- pool-layer round-trip
def _mk_pool(n_pages=64):
    pool = PagedKVPool(n_layers=L, n_kv_heads=HKV, head_dim=DH,
                       page_size=PS, n_pages=n_pages)
    return pool, SharedBlockStore(pool)


def _rand_kv(rng, t):
    return (rng.standard_normal((t, L, HKV, DH)).astype(np.float32),
            rng.standard_normal((t, L, HKV, DH)).astype(np.float32))


def _build_request(rng, pool, store, rid):
    """One random request in `pool`: optionally a store-mapped prefix,
    private tail bytes, random spare capacity, and (half the time) a
    truncated seq_len simulating a chunk-partial prefill. -> held keys."""
    n_tokens = int(rng.integers(5, 28))
    held = []
    t_blk = 0
    if rng.integers(0, 2):
        t_blk = int(rng.integers(1, n_tokens // 2 + 2))
        key = (BS.ITEM_TIER, f"blk-{rid}-{t_blk}")
        kb, vb = _rand_kv(rng, t_blk)
        blk = store.insert(key, BS.ITEM_TIER, kb, vb)
        assert blk is not None
        blk.refcount += 1
        held.append(key)
        pool.alloc_mapped(rid, n_tokens, np.arange(t_blk),
                          np.asarray(blk.slots, np.int64),
                          extra_pages=int(rng.integers(0, 3)))
    else:
        pool.alloc(rid, n_tokens)
    priv = np.arange(t_blk, n_tokens)
    if len(priv):
        kp, vp = _rand_kv(rng, len(priv))
        pool.write_at(rid, priv, kp, vp)
    else:
        pool.seq_lens[rid] = t_blk
    if rng.integers(0, 2):  # chunk-partial: decode hasn't caught up yet
        pool.seq_lens[rid] = int(rng.integers(max(t_blk, 1), n_tokens + 1))
    return held


def _migrate(export, held, store_src, pool_dst, store_dst):
    """The transport in miniature: resolve payloads by content key, then
    import the pool snapshot under the slot translation map."""
    fmap = {}
    for key in held:
        payload = store_src.export_payload(key)
        blk, _hit = store_dst.import_payload(payload)
        assert blk is not None
        for old, new in zip(payload.slots, blk.slots):
            fmap[int(old)] = int(new)
    pages = pool_dst.import_request(export, fmap)
    store_dst.flush_writes()
    return pages


def _roundtrip_case(rng):
    pool_a, store_a = _mk_pool()
    pool_b, store_b = _mk_pool()
    held = {}
    for rid in range(int(rng.integers(1, 4))):
        held[rid] = _build_request(rng, pool_a, store_a, rid)
    check_partition(pool_a, store_a)
    for rid, keys in held.items():
        export = pool_a.export_request(rid)
        assert export.nbytes == export.page_k.nbytes + export.page_v.nbytes
        _migrate(export, keys, store_a, pool_b, store_b)
        # bytes: the visible KV is bitwise identical on both sides
        ka, va = pool_a.gather(rid)
        kb, vb = pool_b.gather(rid)
        assert np.array_equal(ka, kb) and np.array_equal(va, vb)
        # table semantics: length, seq watermark, spare capacity
        assert pool_b.seq_lens[rid] == pool_a.seq_lens[rid]
        assert len(pool_b.slot_tables[rid]) == len(pool_a.slot_tables[rid])
        assert (len(pool_b._spare.get(rid, []))
                == len(pool_a._spare.get(rid, [])))
        # store-shared entries still point at store-owned slots
        shared = np.where(export.owner_page < 0)[0]
        store_slots = {
            int(s) for blk in store_b.blocks.values() for s in blk.slots
        }
        for pos in shared:
            assert int(pool_b.slot_tables[rid][pos]) in store_slots
    check_partition(pool_a, store_a)
    check_partition(pool_b, store_b)
    # both sides tear down to empty pools (store pages stay store-owned)
    for rid, keys in held.items():
        pool_a.free(rid)
        pool_b.free(rid)
        store_a.release_all(keys)
        store_b.release_all(keys)
    assert pool_a.stats().pages_in_use == 0
    assert pool_b.stats().pages_in_use == 0
    check_partition(pool_a, store_a)
    check_partition(pool_b, store_b)


@pytest.mark.parametrize("seed", range(10))
def test_export_import_roundtrip_sweep(seed):
    _roundtrip_case(np.random.default_rng(seed))


@settings(max_examples=15, deadline=None)
@given(st.integers(min_value=0, max_value=2**32 - 1))
def test_export_import_roundtrip_property(seed):
    _roundtrip_case(np.random.default_rng(seed))


def test_pool_import_validates_before_mutating():
    rng = np.random.default_rng(3)
    pool_a, store_a = _mk_pool()
    held = _build_request(rng, pool_a, store_a, 0)
    export = pool_a.export_request(0)

    # duplicate rid: the destination already serves this request
    pool_b, store_b = _mk_pool()
    _migrate(export, held, store_a, pool_b, store_b)
    with pytest.raises(KeyError):
        pool_b.import_request(export, {})

    # unmapped foreign slots (only when the export shares store rows)
    if np.any(export.owner_page < 0):
        pool_c, _ = _mk_pool()
        free0 = pool_c.free_pages
        with pytest.raises(KeyError):
            pool_c.import_request(export, {})
        assert pool_c.free_pages == free0
        assert 0 not in pool_c.page_tables

    # page-size mismatch is a geometry error, not silent corruption
    pool_d = PagedKVPool(n_layers=L, n_kv_heads=HKV, head_dim=DH,
                         page_size=2 * PS, n_pages=64)
    with pytest.raises(ValueError, match="page_size"):
        pool_d.import_request(export, {s: s for s in range(10**4)})


def test_pool_import_exhaustion_leaves_destination_untouched():
    rng = np.random.default_rng(5)
    pool_a, store_a = _mk_pool()
    pool_a.alloc(0, 24)  # 6 pages of private bytes
    k, v = _rand_kv(rng, 24)
    pool_a.write_at(0, np.arange(24), k, v)
    export = pool_a.export_request(0)
    pool_b, store_b = _mk_pool(n_pages=4)  # 3 usable pages < 6 needed
    free0 = pool_b.free_pages
    with pytest.raises(PoolExhausted):
        pool_b.import_request(export, {})
    assert pool_b.free_pages == free0
    assert 0 not in pool_b.page_tables and 0 not in pool_b.slot_tables
    check_partition(pool_b, store_b)


# ---------------------------------------- payload tier economics
def test_payload_digest_hit_moves_zero_bytes():
    rng = np.random.default_rng(7)
    pool_a, store_a = _mk_pool()
    pool_b, store_b = _mk_pool()
    key = (BS.ITEM_TIER, "shared-digest")
    kb, vb = _rand_kv(rng, 6)
    store_a.insert(key, BS.ITEM_TIER, kb, vb)
    assert store_a.export_payload(("item", "nope")) is None
    payload = store_a.export_payload(key)
    assert payload.nbytes == payload.host_k.nbytes + payload.host_v.nbytes

    blk1, hit1 = store_b.import_payload(payload)
    store_b.flush_writes()
    blk2, hit2 = store_b.import_payload(payload)
    assert (hit1, hit2) == (False, True)
    assert blk2 is blk1 and blk1.refcount == 2  # one ref per import
    assert np.array_equal(blk1.host_k, kb)

    # migration_bytes prices exactly what would travel
    pool_src, _ = _mk_pool()
    pool_src.alloc(0, 8)
    kp, vp = _rand_kv(rng, 8)
    pool_src.write_at(0, np.arange(8), kp, vp)
    rec = RequestKV(rid=0, export=pool_src.export_request(0),
                    held=[key], payloads={key: payload})
    assert migration_bytes(rec, None) == rec.export.nbytes + payload.nbytes
    assert migration_bytes(rec, store_b) == rec.export.nbytes  # digest hit


# ------------------------------------- engine-layer handoff
def _mk_engine(system, n_pages=512, with_store=True):
    pool = pool_for(system.cfg, n_pages=n_pages)
    return BatchEngine(system.params, system.cfg, pool=pool,
                       store=SharedBlockStore(pool) if with_store else None,
                       chunk_tokens=64)


def test_chunk_partial_handoff_matches_single_engine(tiny_system,
                                                     heavy_workload):
    """A request exported mid-prefill (one chunk in) and imported into a
    *different* engine finalizes to the exact logits a single engine
    produces, with both pools' partitions intact and fully drained."""
    system, *_ = tiny_system
    _, _, plans, reuse = heavy_workload
    rid = max(plans, key=lambda r: plans[r][0].n)  # longest: many chunks
    plan, ck, cv, have = plans[rid]
    req = BatchRequest(rid=rid, tokens=plan.tokens, plan=plan, cached_k=ck,
                       cached_v=cv, have=have, n_reserve=2, reuse=reuse[rid])
    eng_a = _mk_engine(system)
    eng_b = _mk_engine(system)
    eng_a.begin_prefill(req)
    rep = eng_a.step(64, [], [], [rid])  # exactly one chunk lands
    assert rid in eng_a.prefill_states and rid not in rep.finalized

    rec = eng_a.export_request_kv(rid)
    assert rec.prefill is not None  # chunk-partial: live scan state rides
    counters = eng_b.import_request_kv(rec)
    assert counters["pages"] >= rec.export.n_pages
    assert counters["bytes"] >= rec.export.nbytes
    eng_a.abort_prefill(rid)  # evacuate the source
    assert eng_a.pool.stats().pages_in_use == 0
    check_partition(eng_a.pool, eng_a.store)

    got = None
    for _ in range(64):
        rep = eng_b.step(10_000, [], [], [rid])
        if rid in rep.finalized:
            got = rep.finalized[rid]
            break
    assert got is not None, "migrated prefill never finalized"
    ref_eng = _mk_engine(system, with_store=False)
    ref = ref_eng.prefill([dataclasses.replace(req, reuse=None)],
                          mode="rcllm")
    assert np.array_equal(got, ref[0])
    eng_b.release(rid)
    assert eng_b.pool.stats().pages_in_use == 0
    check_partition(eng_b.pool, eng_b.store)


def test_engine_import_rolls_back_on_exhaustion(tiny_system, heavy_workload):
    """`import_request_kv` is transactional: a destination too small for
    the export keeps zero pages and zero store references."""
    system, *_ = tiny_system
    _, _, plans, reuse = heavy_workload
    rid = max(plans, key=lambda r: plans[r][0].n)
    plan, ck, cv, have = plans[rid]
    req = BatchRequest(rid=rid, tokens=plan.tokens, plan=plan, cached_k=ck,
                       cached_v=cv, have=have, n_reserve=2, reuse=reuse[rid])
    eng_a = _mk_engine(system)
    eng_a.begin_prefill(req)
    while rid in eng_a.prefill_states:
        eng_a.step(10_000, [], [], [rid])
    rec = eng_a.export_request_kv(rid)
    assert rec.export.n_pages > 3

    pool_b = pool_for(system.cfg, n_pages=4)
    eng_b = BatchEngine(system.params, system.cfg, pool=pool_b,
                        store=SharedBlockStore(pool_b), chunk_tokens=64)
    free0 = pool_b.free_pages
    with pytest.raises(PoolExhausted):
        eng_b.import_request_kv(rec)
    assert pool_b.free_pages >= free0 - 0  # no leaked private pages
    assert rid not in pool_b.page_tables
    assert rid not in eng_b.store_refs
    for blk in eng_b.store.blocks.values():
        assert blk.refcount == 0
    check_partition(pool_b, eng_b.store)
    eng_a.release(rid)


# --------------------------------------- cluster-level parity
def _run_cluster(system, trace, sched, kv_reuse, disagg=None):
    cfg = API.ServeConfig(engine="jax", k=2, sched=sched, kv_reuse=kv_reuse,
                          chunk_tokens=64,
                          disagg=disagg if disagg else API.DisaggConfig())
    eng = ClusterEngine(system, cfg)
    rep = eng.run(trace, decode_steps=3)
    for backend in eng.backends:
        assert backend.engine.pool.stats().pages_in_use == 0
        check_partition(backend.engine.pool, backend.engine.store)
    return rep


def _assert_parity(system, trace, sched, kv_reuse):
    ref = _run_cluster(system, trace, sched, kv_reuse)
    rep = _run_cluster(system, trace, sched, kv_reuse,
                       disagg=API.DisaggConfig(prefill_workers=1,
                                               decode_workers=1))
    assert len(rep.completions) == len(trace)
    for rid in range(len(trace)):
        assert rep.generated[rid] == ref.generated[rid], (
            f"request {rid} decoded differently under disagg "
            f"(sched={sched}, kv_reuse={kv_reuse})"
        )
    # the unified reference never migrates; the split cluster moves
    # every multi-step request from its prefill to its decode worker
    assert all(w.migrations == 0 for w in ref.workers)
    pre, dec = rep.workers[0], rep.workers[1]
    assert pre.migrated_out > 0 and pre.migrations == 0
    assert dec.migrations == pre.migrated_out
    assert dec.migrated_pages > 0 and dec.migration_bytes > 0
    assert dec.migration_s >= 0.0
    if kv_reuse:
        assert dec.migration_digest_hits > 0  # store keys dedup transfer
    return rep


def test_disagg_token_parity_chunked_reuse(tiny_system, heavy_workload):
    """Fast tier-1 witness: the full migration path (export, payload
    digest hits, import, decode handoff) decodes the unified tokens."""
    system, *_ = tiny_system
    trace, *_ = heavy_workload
    _assert_parity(system, trace, "chunked", True)


@pytest.mark.slow
@pytest.mark.parametrize("sched,kv_reuse",
                         [("wave", False), ("wave", True),
                          ("chunked", False)])
def test_disagg_token_parity_matrix(tiny_system, heavy_workload, sched,
                                    kv_reuse):
    """Remaining {sched} x {kv-reuse} combos of the parity matrix."""
    system, *_ = tiny_system
    trace, *_ = heavy_workload
    _assert_parity(system, trace, sched, kv_reuse)


def test_cluster_mid_chunk_migration_token_parity(tiny_system,
                                                  heavy_workload):
    """Cluster-path chunk-partial handoff: pool pressure mid-prefill on
    a prefill-role worker migrates the LIVE PrefillState to the decode
    worker (instead of preempting), which resumes chunking, finalizes
    on its own engine, and decodes the unified reference's tokens.

    Organic admission accounting never overcommits a prefill worker, so
    the pressure is injected: the prefill backend's step raises
    `PoolExhausted` once, the first time a request is mid-scan."""
    system, *_ = tiny_system
    trace, *_ = heavy_workload
    ref = _run_cluster(system, trace, "chunked", True)

    cfg = API.ServeConfig(engine="jax", k=2, sched="chunked", kv_reuse=True,
                          chunk_tokens=64,
                          disagg=API.DisaggConfig(prefill_workers=1,
                                                  decode_workers=1))
    eng = ClusterEngine(system, cfg)
    w0 = eng.batcher.workers[0]
    assert w0.role == "prefill"
    orig_step = w0.backend.step
    forced = {"done": False}

    def pressured_step(budget, decode_batch, prefilling):
        if not forced["done"] and any(
            w0.backend.engine.prefill_states.get(r.rid) is not None
            and w0.backend.engine.prefill_states[r.rid].started
            for r in prefilling
        ):
            forced["done"] = True
            raise PoolExhausted("injected mid-chunk pool pressure")
        return orig_step(budget, decode_batch, prefilling)

    w0.backend.step = pressured_step
    rep = eng.run(trace, decode_steps=3)
    assert forced["done"], "pressure was never injected"
    for backend in eng.backends:
        assert backend.engine.pool.stats().pages_in_use == 0
        check_partition(backend.engine.pool, backend.engine.store)
    assert len(rep.completions) == len(trace)
    for rid in range(len(trace)):
        assert rep.generated[rid] == ref.generated[rid], (
            f"request {rid} decoded differently after mid-chunk migration"
        )
    # the injected pressure migrated a mid-scan request without burning
    # a preemption: the victim's scan progress survived the hop
    pre = rep.workers[0]
    assert pre.migrated_out > 0
    assert w0.preempted == 0


def test_unified_default_has_no_migration_machinery(tiny_system,
                                                    heavy_workload):
    """disagg off is byte-for-byte the pre-disagg cluster: every worker
    unified, no migrate hook installed, all counters pinned to zero."""
    system, *_ = tiny_system
    trace, *_ = heavy_workload
    cfg = API.ServeConfig(engine="jax", k=2, sched="chunked",
                          chunk_tokens=64)
    assert not cfg.disagg.enabled
    eng = ClusterEngine(system, cfg)
    for worker in eng.batcher.workers:
        assert worker.role == "unified"
        assert worker.migrate is None
    rep = eng.run(trace, decode_steps=3)
    for w in rep.workers:
        assert (w.migrations, w.migrated_out, w.migrated_pages,
                w.migration_bytes, w.migration_s,
                w.migration_digest_hits) == (0, 0, 0, 0, 0.0, 0)


# --------------------------------------------- config surface
def test_disagg_config_validation():
    with pytest.raises(ValueError, match="must be >= 0"):
        API.DisaggConfig(prefill_workers=-1, decode_workers=2)
    with pytest.raises(ValueError, match="both roles"):
        API.DisaggConfig(prefill_workers=2, decode_workers=0)
    with pytest.raises(ValueError, match="mig_gamma"):
        API.DisaggConfig(prefill_workers=1, decode_workers=1,
                         mig_gamma=-0.1)
    off = API.DisaggConfig()
    assert not off.enabled and off.role_of(0) == "unified"
    d = API.DisaggConfig(prefill_workers=2, decode_workers=1)
    assert d.enabled and d.n_workers == 3
    assert [d.role_of(w) for w in range(3)] == ["prefill", "prefill",
                                                "decode"]


def test_disagg_serve_config_cross_validation_and_grammar():
    with pytest.raises(ValueError, match="engine='jax'"):
        API.ServeConfig(engine="sim", k=2,
                        disagg=API.DisaggConfig(prefill_workers=1,
                                                decode_workers=1))
    with pytest.raises(ValueError, match="must equal"):
        API.ServeConfig(engine="jax", k=3,
                        disagg=API.DisaggConfig(prefill_workers=1,
                                                decode_workers=1))
    cfg = API.ServeConfig.parse(
        "engine=jax,k=4,disagg.prefill_workers=2,disagg.decode_workers=2"
    )
    assert cfg.disagg == API.DisaggConfig(prefill_workers=2,
                                          decode_workers=2)
    assert API.ServeConfig.parse(cfg.render()) == cfg  # total grammar
    with pytest.raises(ValueError, match="sub-config"):
        API.ServeConfig.parse("disagg=2")
