import numpy as np
import pytest


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: long-running test (tier-1 CI runs -m 'not slow'; the full "
        "suite still covers these)")


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
