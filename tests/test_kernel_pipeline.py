"""§III-C3 end-to-end prefill pipeline at the kernel level:
(i) Assembly  — paged block gather from a physical KV pool,
(ii) Alignment — fused RoPE rotation to request positions,
(iii) Correction — selective attention over (window ∪ heavy hitters).

Composes the two Pallas kernels (interpret mode) and checks the result
against the pure-jnp oracles composed the same way."""
import jax.numpy as jnp
import numpy as np

from repro.kernels.block_gather.ops import assemble_kv
from repro.kernels.block_gather.ref import block_gather_ref
from repro.kernels.selective_attention.ops import selective_mha
from repro.kernels.selective_attention.ref import selective_attention_ref


def test_assembly_alignment_correction_pipeline(rng):
    page, d, n_pool = 16, 32, 24
    n_logical = 8                       # prompt = 128 tokens of cached blocks
    S = n_logical * page

    # physical pool: pre-RoPE keys of cached item/history blocks
    pool_k = jnp.asarray(rng.normal(size=(n_pool, page, d)), jnp.float32)
    pool_v = jnp.asarray(rng.normal(size=(n_pool, page, d)), jnp.float32)
    block_table = jnp.asarray(rng.choice(n_pool, n_logical, replace=False),
                              jnp.int32)
    positions = jnp.asarray(np.arange(S).reshape(n_logical, page), jnp.int32)

    # (i)+(ii): zero-copy assembly with fused RoPE realignment
    k_asm, v_asm = assemble_kv(pool_k, pool_v, block_table, positions,
                               rope_theta=1e4, interpret=True)
    k_ref, v_ref = block_gather_ref(pool_k, pool_v, block_table, positions,
                                    rope_theta=1e4)
    np.testing.assert_allclose(np.asarray(k_asm), np.asarray(k_ref),
                               atol=2e-4)

    # (iii): selective attention for the recomputed queries over the
    # assembled keys, restricted to window ∪ heavy hitters
    R_, window = 24, 16
    q = jnp.asarray(rng.normal(size=(1, R_, 1, d)), jnp.float32)
    qpos = jnp.asarray(np.sort(rng.choice(S, R_, replace=False)), jnp.int32)
    hh = np.zeros(S, np.int8)
    hh[rng.choice(S, 10, replace=False)] = 1

    k_flat = k_asm.reshape(1, S, 1, d)
    v_flat = v_asm.reshape(1, S, 1, d)
    out = selective_mha(q, qpos, k_flat, v_flat, jnp.asarray(hh),
                        window=window, q_block=8, kv_block=16,
                        interpret=True)
    ref = selective_attention_ref(
        q[:, :, 0], qpos, k_ref.reshape(1, S, d), v_ref.reshape(1, S, d),
        jnp.asarray(hh), window=window)
    np.testing.assert_allclose(np.asarray(out[:, :, 0]), np.asarray(ref),
                               atol=1e-3, rtol=1e-3)


def test_pipeline_flop_budget_matches_paper_claim(rng):
    """The correction step touches r·S·(W+HH) scores instead of S² — the
    quadratic-bypass the paper claims (§IV-B)."""
    from repro.kernels.selective_attention.ops import flop_reduction
    S = 2500
    red = flop_reduction(r=int(0.37 * S), s=S, n_hh=int(0.05 * S),
                         window=256)
    assert red < 0.15                   # >85% of attention flops bypassed
