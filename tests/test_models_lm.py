"""Per-arch LM smoke tests (reduced configs, same code paths as the full
configs) + attention/decode consistency checks."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry as R
from repro.models import layers as L
from repro.models import transformer as T

LM_ARCHS = [a for a in R.ASSIGNED if R.family_of(a) == "lm"]


@pytest.mark.slow
@pytest.mark.parametrize("arch", LM_ARCHS)
def test_smoke_forward_and_train(arch):
    cfg = R.get_config(arch, smoke=True)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 48), 0,
                              cfg.vocab_size)
    logits, aux = T.forward(params, toks, cfg)
    assert logits.shape == (2, 48, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any())
    (loss, nll), grads = jax.value_and_grad(T.loss_fn, has_aux=True)(
        params, toks, toks, cfg)
    assert bool(jnp.isfinite(loss))
    gn = sum(float(jnp.abs(g).sum()) for g in jax.tree_util.tree_leaves(grads))
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_smoke_prefill_decode(arch):
    cfg = R.get_config(arch, smoke=True)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0,
                              cfg.vocab_size)
    lg, cache = T.prefill(params, toks, cfg)
    assert lg.shape == (2, cfg.vocab_size)
    dh = cfg.resolved_head_dim
    assert cache["k"].shape == (cfg.n_layers, 2, 32, cfg.n_kv_heads, dh)
    pad = 8
    ck = jnp.pad(cache["k"], ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    cv = jnp.pad(cache["v"], ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    lg2, c2 = T.decode_step(params, jnp.argmax(lg, -1)[:, None],
                            {"k": ck, "v": cv}, jnp.array([32, 32]), cfg)
    assert lg2.shape == (2, cfg.vocab_size)
    assert not bool(jnp.isnan(lg2).any())


def test_decode_matches_forward():
    cfg = R.get_config("nemotron-4-15b", smoke=True)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 33), 0,
                              cfg.vocab_size)
    logits_full, _ = T.forward(params, toks, cfg)
    _, cache = T.prefill(params, toks[:, :32], cfg)
    ck = jnp.pad(cache["k"], ((0, 0), (0, 0), (0, 8), (0, 0), (0, 0)))
    cv = jnp.pad(cache["v"], ((0, 0), (0, 0), (0, 8), (0, 0), (0, 0)))
    lg, _ = T.decode_step(params, toks[:, 32:33], {"k": ck, "v": cv},
                          jnp.array([32, 32]), cfg)
    err = float(jnp.abs(lg - logits_full[:, 32]).max())
    scale = float(jnp.abs(logits_full[:, 32]).max())
    assert err / scale < 2e-2


def test_block_pairing_exact():
    cfg = R.get_config("gemma-7b", smoke=True)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 96), 0,
                              cfg.vocab_size)
    base, _ = T.forward(params, toks, cfg)
    cfg_bp = dataclasses.replace(cfg, causal_block_pairing=True)
    bp, _ = T.forward(params, toks, cfg_bp)
    assert float(jnp.abs(base - bp).max()) < 1e-5


def test_flash_vjp_matches_naive():
    rng = np.random.default_rng(0)
    B, Sq, Hq, Hkv, D = 2, 37, 8, 4, 16
    q = jnp.asarray(rng.normal(size=(B, Sq, Hq, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, Sq, Hkv, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, Sq, Hkv, D)), jnp.float32)
    pos = jnp.arange(Sq)

    def naive(q, k, v):
        G = Hq // Hkv
        kk = jnp.repeat(k, G, axis=2)
        vv = jnp.repeat(v, G, axis=2)
        s = jnp.einsum("bqhd,bkhd->bhqk", q, kk) / D ** 0.5
        m = jnp.tril(jnp.ones((Sq, Sq), bool))
        s = jnp.where(m[None, None], s, -1e30)
        return jnp.einsum("bhqk,bkhd->bqhd", jax.nn.softmax(s, -1), vv)

    f1 = lambda *a: (L.chunked_attention(
        *a, causal=True, q_positions=pos, kv_positions=pos,
        q_chunk=16, kv_chunk=8) ** 2).sum()
    f2 = lambda *a: (naive(*a) ** 2).sum()
    g1 = jax.grad(f1, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f2, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        assert float(jnp.abs(a - b).max()) < 1e-4


def test_rope_group_property():
    """R(p+d) == R(d)∘R(p): the realignment identity assembly relies on."""
    rng = np.random.default_rng(1)
    k = jnp.asarray(rng.normal(size=(1, 5, 2, 16)), jnp.float32)
    p1 = jnp.asarray([3.0, 7.0, 11.0, 2.0, 0.0])
    delta = 9.0
    a = L.apply_rope(L.apply_rope(k, p1, 1e4), jnp.full((5,), delta), 1e4)
    b = L.apply_rope(k, p1 + delta, 1e4)
    assert float(jnp.abs(a - b).max()) < 1e-4
