"""Continuous batching, cost-model properties, partial cache coverage,
and dry-run artifact integrity."""
import glob
import json

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st  # optional dep

from repro.configs import registry as REG
from repro.core import cost_model as CM
from repro.serving.batching import ContinuousBatcher, PendingRequest


def test_continuous_batcher_completes_all():
    rng = np.random.default_rng(0)
    reqs = [PendingRequest(arrival_s=float(rng.exponential(0.05) * i),
                           rid=i, n_tokens=int(rng.integers(100, 2000)),
                           decode_steps=4)
            for i in range(50)]
    b = ContinuousBatcher(prefill_time_fn=lambda tok: tok * 1e-5,
                          decode_time_fn=lambda n: 2e-3,
                          max_batch_tokens=4096)
    done = b.run(reqs)
    assert len(done) == 50
    assert all(c.first_token_s >= c.arrival_s for c in done)
    assert all(c.done_s >= c.first_token_s for c in done)


def test_continuous_batcher_batching_beats_serial():
    reqs = [PendingRequest(arrival_s=0.0, rid=i, n_tokens=500,
                           decode_steps=1) for i in range(8)]
    batched = ContinuousBatcher(lambda tok: 1e-4 + tok * 1e-6,
                                lambda n: 1e-4, max_batch_tokens=4000)
    serial = ContinuousBatcher(lambda tok: 1e-4 + tok * 1e-6,
                               lambda n: 1e-4, max_batch_tokens=500)
    tb = max(c.first_token_s for c in batched.run(reqs))
    ts = max(c.first_token_s for c in serial.run(reqs))
    assert tb < ts


@given(st.integers(500, 4000), st.integers(0, 100))
@settings(max_examples=20, deadline=None)
def test_cost_model_monotone_in_recompute(n_total, seed):
    cfg = REG.ARCHS["rcllm-qwen3-8b"]
    rng = np.random.default_rng(seed)
    r1, r2 = sorted(rng.integers(1, n_total, 2))
    t1 = CM.prefill_time_s(cfg, CM.V5E_1, n_total, int(r1))
    t2 = CM.prefill_time_s(cfg, CM.V5E_1, n_total, int(r2))
    assert t1 <= t2 + 1e-12


@given(st.integers(100, 2000))
@settings(max_examples=10, deadline=None)
def test_cost_model_selective_never_slower_than_full(n):
    cfg = REG.ARCHS["rcllm-qwen3-8b"]
    full = CM.full_prefill_ttft_s(cfg, CM.V5E_1, n)
    sel = CM.ttft_s(cfg, CM.V5E_1, n, n_recompute=n // 3,
                    n_local_tokens=n // 2, n_remote_tokens=0)
    assert sel <= full * 1.05


def test_partial_cache_coverage_produces_misses():
    from repro.core.rcllm import make_tiny_system
    system, pool, prof, _ = make_tiny_system(
        n_items=40, n_requests_hist=25, k_instances=2, n_layers=2,
        d_model=32, item_coverage=0.4)
    from repro.data import synth as SY
    req = SY.make_trace(system.catalog, pool, prof, 1, qps=1.0, n_users=3,
                        n_candidates=8, reviews_per_user=1, seed=3)[0]
    plan = system.plan_for(req)
    assert plan.n_miss > 0                   # cold items get recomputed
    scores, stats = system.rank(req, "rcllm")
    assert np.isfinite(scores).all()
    assert stats.n_recomputed > plan.n_miss  # misses forced into recompute


@pytest.mark.skipif(not glob.glob("results/dryrun/*.json"),
                    reason="dry-run results not present")
def test_dryrun_artifacts_complete():
    """All 40 cells × 2 meshes recorded ok with roofline terms."""
    recs = [json.load(open(f)) for f in glob.glob("results/dryrun/*.json")]
    ok = [r for r in recs if r.get("ok")]
    assert len(ok) >= 80
    cells = {(r["arch"], r["shape"], r["mesh"]) for r in ok}
    from repro.configs.registry import cells as all_cells
    for arch, shape in all_cells():
        assert (arch, shape, "pod_16x16") in cells
        assert (arch, shape, "multipod_2x16x16") in cells
    for r in ok:
        assert "roofline" in r and "bottleneck" in r["roofline"]
        assert r["flops_per_device"] > 0
