"""Multi-instance serving path: scheduler-over-real-engines parity,
per-worker backpressure isolation, explicit shard transfers, and the
runtime-facing scheduler/placement/item-cache APIs."""
import numpy as np
import pytest

from repro.core import item_cache as IC
from repro.core import scheduler as SCH
from repro.serving import api as API
from repro.serving.batching import (
    ClusterBatcher,
    ContinuousBatcher,
    JaxEngineBackend,
    PendingRequest,
)
from repro.serving.cluster import ClusterEngine


@pytest.fixture(scope="module")
def tiny_system():
    from repro.core.rcllm import make_tiny_system

    return make_tiny_system(
        n_items=60, n_requests_hist=40, k_instances=2, n_layers=2, d_model=32
    )


@pytest.fixture(scope="module")
def trace(tiny_system):
    from repro.data import synth as SY

    system, pool_rv, prof, _ = tiny_system
    return SY.make_trace(
        system.catalog,
        pool_rv,
        prof,
        6,
        qps=4.0,
        n_users=3,
        n_candidates=8,
        reviews_per_user=1,
        seed=21,
        cluster_bias=0.85,
    )


# ------------------------------------------------------- runtime-facing APIs
def test_shard_client_transfers_are_explicit(tiny_system):
    system, _, _, _ = tiny_system
    store = system.item_store
    placement = system.placement
    cold0 = [
        int(i) for i in np.where(placement.shard_of == 0)[0]
        if int(i) in store.shards[0].blocks
    ]
    assert cold0, "shard 0 should hold some long-tail items"
    client = IC.ShardClient(store, instance=1)
    it = cold0[0]
    assert not client.resident(it)
    assert client.local_block(it) is None
    blk = client.pull(it)
    assert blk is not None
    assert len(client.transfers) == 1
    rec = client.transfers[0]
    assert rec.item_id == it and rec.src_instance == 0
    assert rec.n_bytes == blk.nbytes()
    # staging dedups items and only bills non-resident ones
    hot = int(placement.hot_items[0])
    staged, moved = client.stage([it, it, hot])
    assert set(staged) == {it, hot}
    assert moved == len(blk.tokens)
    # the ledger-backed view never falls back silently
    view = IC.StagedBlocks(staged)
    assert view.get_block(it) is blk
    assert view.get_block(10**6) is None


def test_cluster_scheduler_live_depths(tiny_system):
    system, _, _, _ = tiny_system
    sch = SCH.ClusterScheduler(system.placement, policy="least_loaded")
    assert sch.dispatch(np.asarray([0, 1]), [5.0, 0.5]) == 1
    rr = SCH.ClusterScheduler(system.placement, policy="round_robin")
    assert [rr.dispatch([], [0, 0]) for _ in range(4)] == [0, 1, 0, 1]
    with pytest.raises(ValueError):
        SCH.ClusterScheduler(system.placement, policy="nope")
    # placement runtime API agrees with the scheduler's hit accounting
    items = np.asarray([int(system.placement.hot_items[0])])
    assert system.placement.hit_rate(items, 0) == 1.0
    assert SCH.hit_ratio(items, system.placement, 0) == 1.0


# ------------------------------------------------------------------ parity
@pytest.mark.slow
def test_dispatch_policy_parity_decoded_tokens(tiny_system, trace):
    """Placement changes *where* a request runs, never *what* it decodes:
    per-request token streams must be identical under affinity and
    round-robin dispatch (staged blocks carry identical bytes, so the
    selective path is instance-invariant)."""
    system, _, _, _ = tiny_system
    reports = {}
    for policy in ("affinity", "round_robin"):
        rep = ClusterEngine(
            system, API.ServeConfig(engine="jax", k=2, policy=policy)
        ).run(trace, decode_steps=3)
        assert len(rep.completions) == len(trace)
        reports[policy] = rep
    aff, rr = reports["affinity"], reports["round_robin"]
    assert aff.assigned != rr.assigned, "policies should route differently"
    for rid in range(len(trace)):
        assert aff.generated[rid] == rr.generated[rid], (
            f"request {rid} decoded differently under affinity "
            f"({aff.generated[rid]}) vs round_robin ({rr.generated[rid]})"
        )
    # affinity must not lose item-cache locality to round-robin
    assert aff.mean_hit_rate() >= rr.mean_hit_rate()


@pytest.mark.slow
def test_cluster_transfer_step_is_billed(tiny_system, trace):
    """Non-resident item blocks show up as ledgered transfers with a
    non-zero modeled cost added to the worker clock, and hot items are
    never transferred."""
    system, _, _, _ = tiny_system
    eng = ClusterEngine(
        system, API.ServeConfig(engine="jax", k=2, policy="round_robin")
    )
    rep = eng.run(trace, decode_steps=2)
    n_blocks = sum(w.transfer_blocks for w in rep.workers)
    assert n_blocks > 0, "round-robin on a sharded catalog must transfer"
    for w in rep.workers:
        if w.transfer_blocks:
            assert w.transfer_seconds > 0.0
            assert w.transfer_bytes > 0
    # ledger-level check: no transfer ever names a hot (replicated) item,
    # and every transfer names a real peer shard
    hot = set(int(h) for h in system.placement.hot_items)
    for wid, backend in enumerate(eng.backends):
        for rec in backend.shard.transfers:
            assert rec.item_id not in hot
            assert rec.src_instance != wid


# -------------------------------------------------------------- backpressure
def _mk_backend(params, cfg, n_pages):
    from repro.serving.batch_engine import BatchEngine
    from repro.serving.kv_pool import pool_for

    eng = BatchEngine(
        params, cfg, pool=pool_for(cfg, page_size=8, n_pages=n_pages),
        bucket=32,
    )
    return JaxEngineBackend(eng, mode="full")


def test_backpressure_stalls_only_the_full_worker():
    """Worker 0's pool fits one request at a time, worker 1's fits all of
    its load: admission must stall (serialize) only on worker 0 while
    worker 1 streams through unaffected."""
    import jax

    # local generator, not the session rng fixture: later modules'
    # order-sensitive sweeps draw from that shared stream
    rng = np.random.default_rng(11)

    from repro.configs.base import LMConfig
    from repro.models import transformer as T

    cfg = LMConfig(
        name="bp-test", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        head_dim=16, d_ff=128, vocab_size=512, mlp_type="swiglu",
        dtype="float32", attn_q_chunk=32, attn_kv_chunk=32, remat=False,
    )
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    # 8 usable pages on worker 0: one 40-token request (5 pages + reserve)
    # at a time; worker 1 has room for everything
    b0 = _mk_backend(params, cfg, n_pages=9)
    b1 = _mk_backend(params, cfg, n_pages=128)
    reqs = [
        PendingRequest(
            arrival_s=0.0, rid=i, n_tokens=40, decode_steps=2,
            tokens=rng.integers(1, 512, 40).astype(np.int32),
        )
        for i in range(6)
    ]
    batcher = ClusterBatcher(
        [b0, b1], dispatch=lambda req, t, ws: req.rid % 2,
        max_batch_tokens=4096,
    )
    done = batcher.run(reqs)
    assert len(done) == 6
    by_worker = {0: [], 1: []}
    for c in done:
        by_worker[c.worker].append(c)
    assert len(by_worker[0]) == 3 and len(by_worker[1]) == 3
    # worker 1 admitted everything at t=0: one shared prefill batch, so
    # all three requests share one TTFT
    ttft1 = sorted(c.first_token_s for c in by_worker[1])
    assert ttft1[0] == pytest.approx(ttft1[2])
    # worker 0 could not: its requests went through in strictly
    # serialized waves (each TTFT after the previous request finished)
    w0 = sorted(by_worker[0], key=lambda c: c.first_token_s)
    assert w0[0].first_token_s < w0[1].first_token_s < w0[2].first_token_s
    assert w0[1].first_token_s >= w0[0].done_s
    assert w0[2].first_token_s >= w0[1].done_s
    # the stall never leaked across the seam: worker 1 finished before
    # worker 0's second wave even started
    assert max(c.done_s for c in by_worker[1]) <= w0[1].first_token_s
    # pools fully drained on both workers
    assert b0.engine.pool.stats().pages_in_use == 0
    assert b1.engine.pool.stats().pages_in_use == 0


def test_single_worker_cluster_matches_continuous_batcher():
    """ClusterBatcher with one worker reproduces the seed single-instance
    semantics exactly (the ContinuousBatcher is that wrapper)."""
    reqs = [
        PendingRequest(arrival_s=0.0, rid=0, n_tokens=100, decode_steps=2),
        PendingRequest(arrival_s=5.0, rid=1, n_tokens=50, decode_steps=1),
    ]
    done = ContinuousBatcher(lambda tok: 1e-3, lambda n: 1e-4).run(reqs)
    assert [c.rid for c in done] == [0, 1]
    assert done[0].done_s == pytest.approx(1e-3 + 1e-4)
    assert done[1].first_token_s == pytest.approx(5.0 + 1e-3)
    assert all(c.worker == 0 for c in done)
