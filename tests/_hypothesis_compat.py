"""Optional-hypothesis shim.

`hypothesis` is a dev-only dependency (requirements-dev.txt).  Importing
`given / settings / st` from here instead of from `hypothesis` keeps a
mixed test module importable without it: plain tests run as usual, and
each property test skips itself via ``pytest.importorskip`` at call time
(a module-level importorskip would skip the plain tests too).
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st

    HAS_HYPOTHESIS = True
except ImportError:
    HAS_HYPOTHESIS = False

    class _AnyStrategy:
        """Stands in for `hypothesis.strategies` at decoration time."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _AnyStrategy()

    def given(*a, **k):
        def deco(fn):
            def skipper(*args, **kwargs):
                pytest.importorskip("hypothesis")
            skipper.__name__ = fn.__name__
            skipper.__doc__ = fn.__doc__
            return skipper
        return deco

    def settings(*a, **k):
        return lambda fn: fn
