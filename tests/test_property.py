"""Hypothesis property tests on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np

from _hypothesis_compat import given, settings, st  # optional dep

from repro.core import metrics as MET
from repro.core.semantic_cache import LSH, position_features
from repro.models import layers as L


@given(st.integers(2, 40), st.integers(0, 10_000))
@settings(max_examples=25, deadline=None)
def test_metrics_bounds(n, seed):
    rng = np.random.default_rng(seed)
    scores = rng.normal(size=n)
    ranks = MET.ranks_from_scores(scores)
    # ranks are a permutation
    assert sorted(ranks) == list(range(n))
    gold = rng.integers(0, n, size=5)
    rg = ranks[gold]
    m = MET.table_iii_metrics(rg)
    for k, v in m.items():
        assert 0.0 <= v <= 1.0
    # HR monotone in K
    assert m["HR@1"] <= m["HR@3"] <= m["HR@5"] <= m["HR@10"]


@given(st.integers(1, 200), st.integers(0, 1000))
@settings(max_examples=20, deadline=None)
def test_ranking_agreement_perfect_for_identical(n, seed):
    rng = np.random.default_rng(seed)
    s = rng.normal(size=max(n, 2))
    assert MET.ranking_agreement_ndcg(s, s.copy(), k=10) > 0.999


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=20, deadline=None)
def test_lsh_identical_inputs_same_bucket(seed):
    rng = np.random.default_rng(seed)
    lsh = LSH.make(16, 8, seed=seed % 97)
    x = rng.normal(size=(5, 16)).astype(np.float32)
    c1 = lsh.codes(x)
    c2 = lsh.codes(x.copy())
    np.testing.assert_array_equal(c1, c2)
    # scaling a vector by a positive constant keeps its bucket
    c3 = lsh.codes(3.0 * x)
    np.testing.assert_array_equal(c1, c3)


@given(st.integers(0, 500), st.integers(1, 64))
@settings(max_examples=20, deadline=None)
def test_position_features_locality(p, small_delta):
    """Nearby positions produce closer features than distant ones."""
    f = position_features(np.asarray([p, p + small_delta, p + 4096]))
    d_near = np.linalg.norm(f[0] - f[1])
    d_far = np.linalg.norm(f[0] - f[2])
    assert d_near <= d_far + 1e-6


@given(st.integers(1, 31), st.floats(0.0, 1000.0), st.floats(0.0, 1000.0))
@settings(max_examples=20, deadline=None)
def test_rope_realign_group_property(dim_half, p, d):
    """R(p+d) == R(d)R(p) for arbitrary positions — exactness of assembly."""
    dh = dim_half * 2
    rng = np.random.default_rng(dim_half)
    k = jnp.asarray(rng.normal(size=(1, 1, 1, dh)), jnp.float32)
    a = L.apply_rope(L.apply_rope(k, jnp.asarray([p]), 1e4),
                     jnp.asarray([d]), 1e4)
    b = L.apply_rope(k, jnp.asarray([p + d]), 1e4)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-3)


@given(st.integers(1, 64), st.integers(1, 8), st.integers(0, 1000))
@settings(max_examples=15, deadline=None)
def test_segment_sum_matches_numpy(n, b, seed):
    rng = np.random.default_rng(seed)
    ids = rng.integers(0, b, n)
    vals = rng.normal(size=(n, 3)).astype(np.float32)
    out = jax.ops.segment_sum(jnp.asarray(vals), jnp.asarray(ids),
                              num_segments=b)
    ref = np.zeros((b, 3), np.float32)
    np.add.at(ref, ids, vals)
    np.testing.assert_allclose(np.asarray(out), ref, atol=1e-5)


@given(st.integers(2, 64), st.integers(0, 100))
@settings(max_examples=15, deadline=None)
def test_flash_attention_softmax_rows_normalized(n, seed):
    """Flash output is a convex combination of V rows (max-norm bound)."""
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(1, n, 2, 8)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, n, 2, 8)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, n, 2, 8)), jnp.float32)
    out = L.chunked_attention(q, k, v, causal=True,
                              q_positions=jnp.arange(n),
                              kv_positions=jnp.arange(n),
                              q_chunk=16, kv_chunk=16)
    assert float(jnp.abs(out).max()) <= float(jnp.abs(v).max()) + 1e-4
