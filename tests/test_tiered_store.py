"""Tiered quantized KV store: int8 payload round-trips, host-RAM spill
tier (spill <-> promote preserves digests/refcounts, LRU order survives
the hop), affinity prefetch budgeting, the `StoreConfig` surface, and
fp32-mode bitwise decoded-token parity with spill enabled."""
import numpy as np
import pytest

from repro.serving import api as API
from repro.serving import workload as WL
from repro.serving.batch_engine import BatchEngine
from repro.serving.batching import ContinuousBatcher, JaxEngineBackend
from repro.serving.block_store import (BlockPayload, SharedBlockStore,
                                       check_partition, dequantize_rows,
                                       quantize_rows)
from repro.serving.kv_pool import PagedKVPool, pool_for

from _hypothesis_compat import given, settings, st


def _tiny_pool(n_pages=16, page_size=4):
    return PagedKVPool(n_layers=2, n_kv_heads=2, head_dim=4,
                       page_size=page_size, n_pages=n_pages)


def _blk(rng, n, L=2, H=2, D=4):
    return (rng.normal(size=(n, L, H, D)).astype(np.float32),
            rng.normal(size=(n, L, H, D)).astype(np.float32))


@pytest.fixture(scope="module")
def tiny_system():
    from repro.core.rcllm import make_tiny_system
    return make_tiny_system(n_items=60, n_requests_hist=30, k_instances=2,
                            n_layers=2, d_model=32)


# ------------------------------------------------------- quantization
def test_quantize_rows_shapes_and_bounds():
    rng = np.random.default_rng(0)
    x = rng.normal(size=(5, 2, 3, 8)).astype(np.float32) * 10
    q, s = quantize_rows(x)
    assert q.dtype == np.int8 and s.dtype == np.float32
    assert q.shape == x.shape and s.shape == (5, 2, 3, 1)
    # per-(row, kv-head) scaling: the absmax element of every row maps
    # exactly to +-127
    assert np.abs(q).max(axis=-1).min() == 127
    err = np.abs(dequantize_rows(q, s) - x)
    assert err.max() <= (np.abs(x).max() / 127.0) * 0.5 + 1e-6


def test_quantize_rows_zero_rows_exact():
    x = np.zeros((3, 1, 2, 4), np.float32)
    q, s = quantize_rows(x)
    np.testing.assert_array_equal(dequantize_rows(q, s), x)
    np.testing.assert_array_equal(s, np.ones_like(s))


def test_quantize_rows_idempotent():
    """q(dq(q(x))) == q(x): a block can hop store->payload->store any
    number of times without drift (migration relies on this)."""
    rng = np.random.default_rng(1)
    x = rng.normal(size=(6, 2, 2, 8)).astype(np.float32)
    q1, s1 = quantize_rows(x)
    q2, s2 = quantize_rows(dequantize_rows(q1, s1))
    np.testing.assert_array_equal(q1, q2)
    np.testing.assert_array_equal(s1, s2)


def test_int8_store_arena_holds_dequantized_bytes():
    """Under kv_store_dtype=int8 the arena receives dq(q(x)) — the same
    bytes host_k reports — and the prefix tier stays bit-exact fp32."""
    pool = _tiny_pool()
    store = SharedBlockStore(pool, kv_store_dtype="int8")
    rng = np.random.default_rng(2)
    k, v = _blk(rng, 6)
    blk = store.insert(("item", "a"), "item", k, v)
    assert blk.scale_k is not None and blk.data_k.dtype == np.int8
    q, s = quantize_rows(k)
    np.testing.assert_array_equal(blk.host_k, dequantize_rows(q, s))
    gk = np.asarray(pool.arena_k).reshape(-1, 2, 2, 4)[blk.slots]
    np.testing.assert_array_equal(gk, blk.host_k)
    assert store.dequant_s > 0.0
    # prefix tier: never quantized
    pk, pv = _blk(rng, 4)
    pblk = store.insert(("prefix", "p"), "prefix", pk, pv)
    assert pblk.scale_k is None
    np.testing.assert_array_equal(pblk.host_k, pk)
    check_partition(pool, store)


def test_fp32_store_is_bit_exact():
    pool = _tiny_pool()
    store = SharedBlockStore(pool)          # default fp32
    rng = np.random.default_rng(3)
    k, v = _blk(rng, 5)
    blk = store.insert(("item", "x"), "item", k, v)
    assert blk.scale_k is None
    np.testing.assert_array_equal(blk.host_k, k)
    assert store.dequant_s == 0.0


# --------------------------------------------------------- spill tier
def test_evict_spills_and_promotes_on_reinsert():
    pool = _tiny_pool(n_pages=16, page_size=4)
    store = SharedBlockStore(pool, spill_mb=4)
    rng = np.random.default_rng(4)
    k, v = _blk(rng, 8)
    store.insert(("item", "a"), "item", k, v)
    assert store._evict_lru()
    assert not store.has(("item", "a"))
    assert store.in_spill(("item", "a")) and store.resident(("item", "a"))
    assert store.counters["spills"] == 1
    check_partition(pool, store)
    # re-insert under the same key: served from the spill tier, counted
    # as a spill hit, bytes identical
    blk = store.insert(("item", "a"), "item", k, v)
    assert blk is not None and store.has(("item", "a"))
    assert not store.in_spill(("item", "a"))
    assert store.counters["spill_hits"] == 1
    store.flush_writes()
    np.testing.assert_array_equal(blk.host_k, k)
    check_partition(pool, store)


def test_spill_capacity_trims_oldest():
    """LRU order survives the spill hop: the device-tier last_used stamp
    rides along, so capacity trimming drops the coldest block first."""
    pool = _tiny_pool(n_pages=32, page_size=4)
    rng = np.random.default_rng(5)
    k, v = _blk(rng, 4)
    one_block = 2 * k.nbytes               # k + v, fp32
    cap_mb = max(1, int(np.ceil(2.5 * one_block / 2**20)))
    # capacity for ~2 blocks when one_block is a whole MB multiple;
    # easier: use a store whose cap we compute in bytes directly
    store = SharedBlockStore(pool, spill_mb=cap_mb)
    store.spill_cap = int(2.5 * one_block)  # precise 2.5-block budget
    keys = [("item", f"b{i}") for i in range(3)]
    for i, key in enumerate(keys):
        ki, vi = _blk(rng, 4)
        store.insert(key, "item", ki, vi)
    # touch b1 then b2 so b0 is coldest, then evict everything
    store.get(keys[1])
    store.get(keys[2])
    while store._evict_lru():
        pass
    # three spills against a 2.5-block budget: b0 (coldest) was trimmed
    assert store.counters["spills"] == 3
    assert store.counters["spill_drops"] == 1
    assert not store.in_spill(keys[0])
    assert store.in_spill(keys[1]) and store.in_spill(keys[2])
    assert store.spill_nbytes == 2 * one_block
    check_partition(pool, store)


def test_import_payload_spill_hit_is_digest_hit():
    """A migration payload whose key sits in the spill tier re-stages
    from host RAM and reports digest_hit=True (zero transport bytes)."""
    pool = _tiny_pool(n_pages=16, page_size=4)
    store = SharedBlockStore(pool, spill_mb=4)
    rng = np.random.default_rng(6)
    k, v = _blk(rng, 6)
    store.insert(("item", "m"), "item", k, v)
    store._evict_lru()
    assert store.in_spill(("item", "m"))
    payload = BlockPayload(key=("item", "m"), kind="item",
                           slots=np.arange(6), host_k=k, host_v=v)
    blk, hit = store.import_payload(payload)
    assert hit and blk is not None and blk.refcount == 1
    assert store.counters["spill_hits"] == 1
    store.flush_writes()
    np.testing.assert_array_equal(blk.host_k, k)
    check_partition(pool, store)


@settings(max_examples=25, deadline=None)
@given(
    n_tokens=st.lists(st.integers(min_value=1, max_value=10),
                      min_size=1, max_size=6),
    dtype=st.sampled_from(["fp32", "int8"]),
    seed=st.integers(min_value=0, max_value=2**16),
)
def test_spill_promote_roundtrip_property(n_tokens, dtype, seed):
    """Property: evict-to-spill then promote preserves every block's
    content digest (the stored bytes hash to the same key-determining
    payload) and refcounts, and the partition invariant holds at every
    hop."""
    pytest.importorskip("hypothesis")
    pool = _tiny_pool(n_pages=64, page_size=4)
    store = SharedBlockStore(pool, kv_store_dtype=dtype, spill_mb=64)
    rng = np.random.default_rng(seed)
    before = {}
    for i, n in enumerate(n_tokens):
        k, v = _blk(rng, n)
        key = ("item", f"p{i}")
        blk = store.insert(key, "item", k, v)
        assert blk is not None
        store.flush_writes()
        before[key] = (blk.host_k.copy(), blk.host_v.copy())
    check_partition(pool, store)
    while store._evict_lru():           # demote everything
        pass
    assert not store.blocks and len(store.spill) == len(before)
    assert store.spill_nbytes == sum(
        s.nbytes for s in store.spill.values())
    check_partition(pool, store)
    for key, (hk, hv) in before.items():  # promote everything back
        blk = store._promote(key)
        assert blk is not None and blk.refcount == 0
        np.testing.assert_array_equal(blk.host_k, hk)
        np.testing.assert_array_equal(blk.host_v, hv)
    store.flush_writes()
    assert not store.spill and store.spill_nbytes == 0
    check_partition(pool, store)


# ----------------------------------------------------------- prefetch
def test_prefetch_budget_is_respected():
    pool = _tiny_pool(n_pages=16, page_size=4)
    store = SharedBlockStore(pool, spill_mb=4, prefetch_pages_per_tick=2)
    rng = np.random.default_rng(7)
    keys = [("item", f"f{i}") for i in range(3)]
    for key in keys:
        k, v = _blk(rng, 8)              # 2 pages each
        store.insert(key, "item", k, v)
    while store._evict_lru():
        pass
    store.hint(keys)
    # budget 2 pages/tick, blocks are 2 pages: one promotion per tick
    assert store.prefetch() == 1
    assert store.prefetch() == 1
    assert store.prefetch() == 1
    assert store.prefetch() == 0         # hints drained
    assert store.counters["prefetch_promotions"] == 3
    assert all(store.has(k) for k in keys)
    store.flush_writes()
    check_partition(pool, store)


def test_prefetch_never_steals_referenced_pages():
    """With every resident block referenced, a hinted promotion is
    refused (in-use pages are never stolen) and the hint is dropped —
    the insert path promotes it on demand instead."""
    pool = _tiny_pool(n_pages=8, page_size=4)     # 7 usable
    store = SharedBlockStore(pool, max_pages=4, spill_mb=4,
                             prefetch_pages_per_tick=8)
    rng = np.random.default_rng(8)
    k, v = _blk(rng, 8)
    store.insert(("item", "cold"), "item", k, v)
    store._evict_lru()
    for i in range(2):                   # refill the device tier
        ki, vi = _blk(rng, 8)
        blk = store.insert(("item", f"hot{i}"), "item", ki, vi)
        blk.refcount = 1                 # referenced: not evictable
    assert store.pages_held() == store.max_pages
    store.hint([("item", "cold")])
    assert store.prefetch() == 0
    assert store.in_spill(("item", "cold"))       # still spilled
    assert len(store._hints) == 0                 # refused hint dropped
    assert store.counters["evictions"] == 1       # residents untouched
    store.flush_writes()
    check_partition(pool, store)


def test_prefetch_demand_swaps_cold_blocks():
    """At steady-state budget occupancy, a hinted promotion evicts the
    LRU refcount-0 victim — which demotes to the spill tier rather than
    dropping, so the swap reorders the device tier without losing bytes."""
    pool = _tiny_pool(n_pages=8, page_size=4)     # 7 usable
    store = SharedBlockStore(pool, max_pages=4, spill_mb=4,
                             prefetch_pages_per_tick=8)
    rng = np.random.default_rng(11)
    k, v = _blk(rng, 8)
    store.insert(("item", "wanted"), "item", k, v)
    store._evict_lru()
    for i in range(2):                   # fill the budget with cold blocks
        ki, vi = _blk(rng, 8)
        store.insert(("item", f"cold{i}"), "item", ki, vi)
    assert store.pages_held() == store.max_pages
    store.hint([("item", "wanted")])
    assert store.prefetch() == 1
    assert store.has(("item", "wanted"))
    assert not store.in_spill(("item", "wanted"))
    assert store.in_spill(("item", "cold0"))      # victim spilled, not lost
    assert store.counters["prefetch_promotions"] == 1
    assert store.counters["spill_drops"] == 0
    store.flush_writes()
    check_partition(pool, store)


def test_prefetch_drops_oversized_hint():
    pool = _tiny_pool(n_pages=32, page_size=4)
    store = SharedBlockStore(pool, spill_mb=4, prefetch_pages_per_tick=1)
    rng = np.random.default_rng(9)
    k, v = _blk(rng, 8)                  # 2 pages > 1-page tick budget
    store.insert(("item", "big"), "item", k, v)
    store._evict_lru()
    store.hint([("item", "big")])
    assert store.prefetch() == 0
    assert len(store._hints) == 0        # dropped, not queued forever
    assert store.in_spill(("item", "big"))


# ----------------------------------------------------- config surface
def test_store_config_validation():
    with pytest.raises(ValueError, match="kv_store_dtype"):
        API.StoreConfig(kv_store_dtype="int4")
    with pytest.raises(ValueError, match="spill_mb"):
        API.StoreConfig(spill_mb=-1)
    with pytest.raises(ValueError, match="prefetch_pages_per_tick"):
        API.StoreConfig(spill_mb=16, prefetch_pages_per_tick=-2)
    with pytest.raises(ValueError, match="needs spill_mb"):
        API.StoreConfig(prefetch_pages_per_tick=4)
    assert not API.StoreConfig().enabled
    assert API.StoreConfig(kv_store_dtype="int8").enabled
    assert API.StoreConfig(spill_mb=16).enabled


def test_store_config_requires_reuse():
    with pytest.raises(ValueError, match="kv_reuse"):
        API.ServeConfig(store=API.StoreConfig(spill_mb=16))
    with pytest.raises(ValueError, match="engine='jax'"):
        API.ServeConfig(engine="sim", mode="prefix",
                        store=API.StoreConfig(kv_store_dtype="int8"))
    cfg = API.ServeConfig(kv_reuse=True, store=API.StoreConfig(
        kv_store_dtype="int8", spill_mb=16, prefetch_pages_per_tick=4))
    assert cfg.store.enabled


def test_store_config_grammar_roundtrip():
    cfg = API.ServeConfig.parse(
        "kv_reuse=on,store.kv_store_dtype=int8,store.spill_mb=64,"
        "store.prefetch_pages_per_tick=8")
    assert cfg.store == API.StoreConfig(
        kv_store_dtype="int8", spill_mb=64, prefetch_pages_per_tick=8)
    assert API.ServeConfig.parse(cfg.render()) == cfg
    with pytest.raises(ValueError, match="sub-config"):
        API.ServeConfig.parse("store=int8")
    with pytest.raises(ValueError, match="StoreConfig field"):
        API.ServeConfig.parse("store.dtype=int8")


def test_build_engine_threads_store_config(tiny_system):
    system, *_ = tiny_system
    cfg = API.ServeConfig(kv_reuse=True, n_pages=64, store=API.StoreConfig(
        kv_store_dtype="int8", spill_mb=16, prefetch_pages_per_tick=4))
    eng = API.build_engine(system.params, system.cfg, cfg)
    assert eng.store.kv_store_dtype == "int8"
    assert eng.store.spill_cap == 16 * 2**20
    assert eng.store.prefetch_pages_per_tick == 4


# ---------------------------------------------- fp32 spill parity
def _run_reuse(system, pend, plans, reuse, sched, store_kw, n_pages=96):
    pool = pool_for(system.cfg, n_pages=n_pages)
    store = SharedBlockStore(pool, **store_kw)
    engine = BatchEngine(system.params, system.cfg, pool=pool, store=store)
    backend = JaxEngineBackend(engine, mode="rcllm", plans=plans,
                               reuse=reuse)
    ContinuousBatcher(backend=backend, max_batch_tokens=4096,
                      sched=sched).run(list(pend))
    assert engine.pool.stats().pages_in_use == 0
    check_partition(engine.pool, engine.store)
    return backend, engine


@pytest.mark.parametrize("sched", ["wave", "chunked"])
def test_fp32_spill_decoded_parity(tiny_system, sched):
    """kv_store_dtype=fp32 with the spill tier enabled decodes bitwise
    identical tokens to the plain store — demotion/promotion changes
    where bytes wait, never what they are.  The small pool forces real
    eviction traffic through the spill tier."""
    system, pool_rv, prof, _ = tiny_system
    trace = WL.zipf_repeat_trace(system.catalog, pool_rv, prof, 8,
                                 qps=12.0, n_users=3, zipf_a=1.4, seed=3)
    pend, plans = WL.rcllm_workload(system, trace, decode_steps=3)
    reuse = WL.rcllm_reuse_info(system, trace, plans)
    b_plain, e_plain = _run_reuse(system, pend, plans, reuse, sched, {})
    b_spill, e_spill = _run_reuse(
        system, pend, plans, reuse, sched,
        {"spill_mb": 64, "prefetch_pages_per_tick": 4})
    for rid in b_plain.generated:
        assert b_plain.generated[rid] == b_spill.generated[rid]
    st_plain = e_plain.store.stats()
    st_spill = e_spill.store.stats()
    if st_plain["evictions"] > 0:
        assert st_spill["spills"] > 0
