"""Async session server + typed serving API.

Pins the PR's tentpole contracts:

* the server is a *front end*, not a new scheduler: a speed-0 trace
  replay through `AsyncSessionServer` decodes tokens bitwise identical
  to the closed-loop `ContinuousBatcher.run` on the same trace, across
  {wave, chunked} x {kv-reuse on, off};
* cancellation rolls pool state back through the preemption seams —
  queued, mid-prefill (`preempt_prefill`/`abort_prefill`) and
  mid-decode (`finish`) — leaving the ownership partition intact and
  zero pages in use;
* stop sequences and ``max_tokens`` bound the stream with the right
  finish reason; non-greedy sampling replays exactly from its seed;
* `ServeConfig` rejects invalid knob combinations at construction, and
  the legacy flag/kwarg shims (`from_args`, `ClusterEngine(**legacy)`)
  keep old invocations working behind one `DeprecationWarning`.
"""
import argparse
import asyncio
import dataclasses

import numpy as np
import pytest

from repro.serving import api as API
from repro.serving import workload as WL
from repro.serving.batching import PendingRequest, WorkerState
from repro.serving.block_store import check_partition
from repro.serving.server import AsyncSessionServer, replay, serve_trace


@pytest.fixture(scope="module")
def tiny_system():
    from repro.core.rcllm import make_tiny_system
    return make_tiny_system(n_items=60, n_requests_hist=30, k_instances=2,
                            n_layers=2, d_model=32)


@pytest.fixture(scope="module")
def heavy_workload(tiny_system):
    system, pool_rv, prof, _ = tiny_system
    trace = WL.heavy_tail_trace(system.catalog, pool_rv, prof, 6, qps=8.0,
                                n_users=3, long_prompt_frac=0.4,
                                long_prompt_reviews=6, seed=5)
    pend, plans = WL.rcllm_workload(system, trace, decode_steps=3)
    reuse = WL.rcllm_reuse_info(system, trace, plans)
    return trace, pend, plans, reuse


def _build(system, scfg, plans=None, reuse=None):
    engine = API.build_engine(system.params, system.cfg, scfg)
    backend = API.build_backend(engine, scfg, plans=plans, reuse=reuse)
    return engine, backend


def _submits(pend, plans, reuse=None, max_tokens=None, stop=None,
             sampling=API.GREEDY):
    out = []
    for p in pend:
        out.append((p.arrival_s, API.SubmitRequest(
            rid=p.rid,
            tokens=p.tokens,
            max_tokens=max_tokens.get(p.rid, p.decode_steps)
            if max_tokens else p.decode_steps,
            stop=stop.get(p.rid, ()) if stop else (),
            sampling=sampling,
            context=plans.get(p.rid),
            reuse=(reuse or {}).get(p.rid),
        )))
    return out


def _assert_clean(engine):
    assert engine.pool.stats().pages_in_use == 0
    assert not engine.prefill_states
    check_partition(engine.pool, engine.store)


# ------------------------------------------- closed-loop token parity
@pytest.mark.parametrize("sched", ["wave", "chunked"])
@pytest.mark.parametrize("kv_reuse", [False, True])
def test_server_replay_matches_closed_loop(tiny_system, heavy_workload,
                                           sched, kv_reuse):
    """A speed-0 replay through the async server decodes every session
    bitwise identical to the closed-loop batcher on the same trace —
    the server changes *when* work is admitted, never *what* a request
    computes."""
    system, *_ = tiny_system
    _, pend, plans, reuse = heavy_workload
    reuse = reuse if kv_reuse else None
    scfg = API.ServeConfig(engine="jax", sched=sched, kv_reuse=kv_reuse,
                           n_pages=256, chunk_tokens=64)

    eng_ref, backend_ref = _build(system, scfg, plans=plans, reuse=reuse)
    done_ref = API.build_batcher(backend_ref, scfg).run(
        [PendingRequest(p.arrival_s, p.rid, p.n_tokens, p.decode_steps,
                        p.tokens) for p in pend])
    ref = {rid: tuple(t) for rid, t in backend_ref.generated.items()}
    _assert_clean(eng_ref)
    assert len(done_ref) == len(pend)

    eng, backend = _build(system, scfg)
    completions, server = serve_trace(
        backend, scfg, _submits(pend, plans, reuse=reuse))
    assert set(completions) == set(ref)
    for rid, comp in completions.items():
        assert comp.tokens == ref[rid], f"rid {rid} diverged"
        assert comp.reason == "length"
        # speed-0 replay stamps submitted_s in *trace* time while
        # first_token_s is server wall time, so ttft_s is only
        # meaningful for wall-clock submissions (speed > 0); the
        # closed-loop latency split lives in server.worker.done
        assert comp.ttft_s is not None
    assert server.metrics.completed == len(pend)
    assert len(server.worker.done) == len(pend)
    for c in server.worker.done:
        assert c.arrival_s <= c.first_token_s <= c.done_s
    _assert_clean(eng)


def test_stream_events_well_formed(tiny_system, heavy_workload):
    """Each session's stream: one event per token with contiguous
    indices, then exactly one finished event carrying the reason."""
    system, *_ = tiny_system
    _, pend, plans, _ = heavy_workload
    scfg = API.ServeConfig(engine="jax", sched="chunked", n_pages=256,
                           chunk_tokens=64)
    _, backend = _build(system, scfg)

    async def drive():
        server = AsyncSessionServer(backend, scfg)
        sessions = [server.submit(req, arrival_s=t)
                    for t, req in _submits(pend, plans)]
        events = {s.rid: [] for s in sessions}
        async with server:
            for sess in sessions:
                async for ev in sess:
                    events[sess.rid].append(ev)
        return sessions, events

    sessions, events = asyncio.run(drive())
    for sess in sessions:
        evs = events[sess.rid]
        assert [e.finished for e in evs] == [False] * (len(evs) - 1) + [True]
        assert [e.index for e in evs[:-1]] == list(range(len(evs) - 1))
        assert evs[-1].reason == "length"
        comp = sess.completion
        assert tuple(e.token for e in evs[:-1]) == comp.tokens
        assert len(comp.tokens) == sess.request.max_tokens


# ------------------------------------------------------- cancellation
def test_cancel_queued_session(tiny_system, heavy_workload):
    """A cancel that lands before admission finishes the session as
    'cancelled' without the request ever touching the engine."""
    system, *_ = tiny_system
    _, pend, plans, _ = heavy_workload
    scfg = API.ServeConfig(engine="jax", sched="chunked", n_pages=256)
    engine, backend = _build(system, scfg)
    server = AsyncSessionServer(backend, scfg)
    sess = server.submit(API.SubmitRequest(rid=7, tokens=pend[0].tokens,
                                           context=plans.get(pend[0].rid)))
    sess.cancel()

    async def drive():
        async with server:
            return await sess.result()

    comp = asyncio.run(drive())
    assert comp.reason == "cancelled"
    assert comp.tokens == ()
    assert server.metrics.cancelled == 1
    _assert_clean(engine)


def test_cancel_mid_prefill(tiny_system, heavy_workload):
    """Cancelling a request between prefill chunks rolls its chunk
    state, pages and store refs back (the `preempt_prefill` seam) and
    keeps the pool partition intact."""
    system, *_ = tiny_system
    _, pend, plans, reuse = heavy_workload
    scfg = API.ServeConfig(engine="jax", sched="chunked", kv_reuse=True,
                           n_pages=256, chunk_tokens=64)
    engine, backend = _build(system, scfg, plans=plans, reuse=reuse)
    worker = WorkerState(backend, sched="chunked", chunk_tokens=64)
    victim = max(pend, key=lambda p: p.n_tokens)
    worker.waiting.append(PendingRequest(0.0, victim.rid, victim.n_tokens,
                                         victim.decode_steps, victim.tokens))
    worker.step()                      # admits + runs the first chunk
    assert victim.rid in engine.prefill_states
    assert worker.cancel(victim.rid) == "prefilling"
    assert victim.rid not in engine.prefill_states
    assert not worker.has_work()
    for blk in (engine.store.blocks if engine.store else {}).values():
        assert blk.refcount == 0
    _assert_clean(engine)
    assert worker.cancel(victim.rid) is None    # unknown now: no-op


def test_cancel_mid_decode(tiny_system, heavy_workload):
    """Cancelling a decoding session through the async client handle:
    the stream ends with a 'cancelled' event after the tokens already
    emitted, every other session completes normally, and no pages
    leak."""
    system, *_ = tiny_system
    _, pend, plans, _ = heavy_workload
    scfg = API.ServeConfig(engine="jax", sched="chunked", n_pages=256,
                           chunk_tokens=64)
    engine, backend = _build(system, scfg)
    victim = pend[0].rid

    async def drive():
        server = AsyncSessionServer(backend, scfg)
        sessions = {}
        async with server:
            for t, req in _submits(pend, plans,
                                   max_tokens={victim: 64}):
                sessions[req.rid] = server.submit(req, arrival_s=t)
            vs = sessions[victim]
            got = 0
            async for ev in vs:
                if ev.finished:
                    break
                got += 1
                if got == 2:
                    vs.cancel()
            await server.drain()
        return server, sessions

    server, sessions = asyncio.run(drive())
    comp = sessions[victim].completion
    assert comp.reason == "cancelled"
    assert 2 <= len(comp.tokens) < 64       # stopped well short of budget
    for rid, sess in sessions.items():
        if rid != victim:
            assert sess.completion.reason == "length"
            assert len(sess.completion.tokens) == sess.request.max_tokens
    assert server.metrics.cancelled == 1
    _assert_clean(engine)


def test_cancel_after_completion_is_idempotent_noop(tiny_system,
                                                    heavy_workload):
    """Regression: cancelling a finished (or never-submitted) session is
    a status-returning no-op.  A stale cancel used to enqueue the rid
    unconditionally, where it could linger and shoot down a later
    session reusing the id; now it reports 'done'/'unknown' and leaves
    the cancel queue untouched."""
    system, *_ = tiny_system
    _, pend, plans, _ = heavy_workload
    scfg = API.ServeConfig(engine="jax", sched="chunked", n_pages=256,
                           chunk_tokens=64)
    engine, backend = _build(system, scfg)
    completions, server = serve_trace(backend, scfg, _submits(pend, plans))
    assert len(completions) == len(pend)
    for rid in completions:
        assert server.cancel(rid) == "done"
        assert server.cancel(rid) == "done"      # idempotent
    assert server.cancel(10**9) == "unknown"     # never submitted
    assert not server._cancels                   # nothing was enqueued
    assert server.metrics.cancelled == 0
    for comp in completions.values():
        assert comp.reason == "length"           # nobody got shot down
    _assert_clean(engine)


# --------------------------------------- stop sequences / max_tokens
@pytest.mark.parametrize("sched", ["wave", "chunked"])
def test_stop_sequence_ends_stream(tiny_system, heavy_workload, sched):
    """A stop sequence derived from the greedy reference stream ends
    generation the moment the stream ends with it (inclusive
    semantics), with reason 'stop' — under both disciplines."""
    system, *_ = tiny_system
    _, pend, plans, _ = heavy_workload
    scfg = API.ServeConfig(engine="jax", sched=sched, n_pages=256,
                           chunk_tokens=64)
    _, backend_ref = _build(system, scfg)
    ref, _ = serve_trace(backend_ref, scfg, _submits(pend, plans))
    rid = next(r for r in sorted(ref) if len(ref[r].tokens) >= 3)
    stop_seq = ref[rid].tokens[1:2]          # second generated token

    engine, backend = _build(system, scfg)
    completions, _ = serve_trace(
        backend, scfg, _submits(pend, plans, stop={rid: (stop_seq,)}))
    assert completions[rid].reason == "stop"
    assert completions[rid].tokens == ref[rid].tokens[:2]
    for other in ref:
        if other != rid:
            assert completions[other].tokens == ref[other].tokens
    _assert_clean(engine)


def test_max_tokens_bounds_stream(tiny_system, heavy_workload):
    """`max_tokens` is the total generated budget — 1 means prefill's
    token only, N means exactly N, reason 'length'."""
    system, *_ = tiny_system
    _, pend, plans, _ = heavy_workload
    scfg = API.ServeConfig(engine="jax", sched="chunked", n_pages=256,
                           chunk_tokens=64)
    budgets = {p.rid: 1 + (i % 3) for i, p in enumerate(pend)}
    engine, backend = _build(system, scfg)
    completions, _ = serve_trace(
        backend, scfg, _submits(pend, plans, max_tokens=budgets))
    for rid, comp in completions.items():
        assert len(comp.tokens) == budgets[rid]
        assert comp.reason == "length"
    _assert_clean(engine)


# ------------------------------------------------------------ sampling
def test_sampling_replays_from_seed(tiny_system, heavy_workload):
    """temperature > 0: a (seed, prompt) pair replays the exact same
    stream across fresh engines; changing the seed changes at least one
    stream (vocab is tiny, so assert across all sessions)."""
    system, *_ = tiny_system
    _, pend, plans, _ = heavy_workload
    scfg = API.ServeConfig(engine="jax", sched="chunked", n_pages=256,
                           chunk_tokens=64)

    def run(seed):
        engine, backend = _build(system, scfg)
        sp = API.SamplingParams(temperature=1.0, top_k=4, seed=seed)
        completions, _ = serve_trace(
            backend, scfg, _submits(pend, plans, sampling=sp))
        _assert_clean(engine)
        return {rid: c.tokens for rid, c in completions.items()}

    a, b, c = run(7), run(7), run(8)
    assert a == b
    assert a != c


def test_sample_token_greedy_and_topk():
    logits = np.asarray([0.1, 3.0, -1.0, 2.9])
    assert API.sample_token(logits) == 1
    rng = np.random.default_rng(0)
    sp = API.SamplingParams(temperature=0.5, top_k=2, seed=0)
    draws = {API.sample_token(logits, sp, rng) for _ in range(64)}
    assert draws <= {1, 3}                   # top-2 support only
    assert API.match_stop([5, 6, 7], [(6, 7)])
    assert not API.match_stop([5, 6, 7], [(5, 6)])
    assert not API.match_stop([7], [(6, 7)])


# ----------------------------------------------------- config surface
def test_serveconfig_rejects_invalid_combos():
    with pytest.raises(ValueError, match="decode_kernel"):
        API.ServeConfig(engine="sim", decode_kernel="paged")
    with pytest.raises(ValueError, match="attn_backend"):
        API.ServeConfig(engine="sim", attn_backend="pallas")
    with pytest.raises(ValueError, match="kv_reuse"):
        API.ServeConfig(engine="sim", kv_reuse=True)
    with pytest.raises(ValueError, match="chunked"):
        API.ServeConfig(engine="sim", sched="chunked")
    with pytest.raises(ValueError, match="prefix"):
        API.ServeConfig(engine="jax", mode="prefix")
    with pytest.raises(ValueError, match="kv_reuse"):
        API.ServeConfig(engine="jax", mode="full", kv_reuse=True)
    with pytest.raises(ValueError, match="not in"):
        API.ServeConfig(engine="tpu")
    with pytest.raises(ValueError, match="k="):
        API.ServeConfig(k=0)
    cfg = API.ServeConfig(chunk_tokens=64)
    assert cfg.resolved_step_tokens == 512
    assert cfg.replace(step_tokens=192).resolved_step_tokens == 192


def test_from_args_legacy_shim_single_warning():
    ns = argparse.Namespace(engine="jax", kv_reuse="on", pages=256,
                            sched=None, mode=None, k=None)
    with pytest.warns(DeprecationWarning, match="--kv-reuse"):
        cfg = API.ServeConfig.from_args(ns)
    assert cfg.kv_reuse is True and cfg.n_pages == 256
    with pytest.warns(DeprecationWarning) as rec:
        API.ServeConfig.from_args(ns)
    assert len(rec) == 1                     # one warning names them all
    import warnings as W
    with W.catch_warnings():
        W.simplefilter("error")              # no flags -> no warning
        assert API.ServeConfig.from_args(argparse.Namespace()) \
            == API.ServeConfig()


def test_config_parse_spec():
    cfg = API.ServeConfig.parse("sched=chunked,kv_reuse=on,pages=0"
                                .replace("pages=0", "n_pages=128"))
    assert cfg.sched == "chunked" and cfg.kv_reuse and cfg.n_pages == 128
    with pytest.raises(ValueError, match="not a ServeConfig field"):
        API.ServeConfig.parse("pages=128")
    with pytest.raises(ValueError, match="key=value"):
        API.ServeConfig.parse("chunked")


def test_cluster_engine_legacy_kwargs(tiny_system):
    from repro.serving.cluster import ClusterEngine
    system, *_ = tiny_system
    with pytest.warns(DeprecationWarning, match="ServeConfig"):
        ce = ClusterEngine(system, k=2, policy="round_robin")
    assert ce.config.k == 2 and ce.config.policy == "round_robin"
    with pytest.raises(TypeError, match="nonsense"):
        ClusterEngine(system, nonsense=1)


# --------------------------------------------------- server guardrails
def test_server_rejects_multiworker_and_duplicate_rid(tiny_system,
                                                      heavy_workload):
    system, *_ = tiny_system
    _, pend, plans, _ = heavy_workload
    scfg = API.ServeConfig(engine="jax", n_pages=256)
    _, backend = _build(system, scfg)
    with pytest.raises(ValueError, match="one worker"):
        AsyncSessionServer(backend, scfg.replace(k=2))
    server = AsyncSessionServer(backend, scfg)
    req = API.SubmitRequest(rid=1, tokens=pend[0].tokens)
    server.submit(req)
    with pytest.raises(ValueError, match="duplicate"):
        server.submit(req)


def test_replay_speed_gt0_preserves_tokens(tiny_system, heavy_workload):
    """Open-loop (wall-clock) submission changes batch composition but
    not decoded tokens — the cross-cutting invariance, at the server
    level (bench_openloop sweeps this at scale)."""
    system, *_ = tiny_system
    _, pend, plans, _ = heavy_workload
    scfg = API.ServeConfig(engine="jax", sched="chunked", n_pages=256,
                           chunk_tokens=64)
    _, backend_ref = _build(system, scfg)
    ref, _ = serve_trace(backend_ref, scfg, _submits(pend, plans))
    engine, backend = _build(system, scfg)
    fast, _ = serve_trace(backend, scfg, _submits(pend, plans),
                          speed=200.0)
    assert {r: c.tokens for r, c in fast.items()} \
        == {r: c.tokens for r, c in ref.items()}
    _assert_clean(engine)
