"""Batched serving path: paged KV pool, batched prefill/decode parity
with the single-request engine, the continuous batcher over the real
JAX backend, and adversarial slot-table layouts through the fused
paged-decode kernel (gather path as oracle)."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs.base import LMConfig
from repro.core import assembly as ASM
from repro.core import engine as ENG
from repro.models import transformer as T
from repro.serving.batch_engine import BatchEngine, BatchRequest
from repro.serving.batching import (ContinuousBatcher, JaxEngineBackend,
                                    PendingRequest)
from repro.serving.kv_pool import PagedKVPool, PoolExhausted, pool_for


@pytest.fixture(scope="module")
def tiny():
    cfg = LMConfig(name="serve-test", n_layers=2, d_model=64, n_heads=4,
                   n_kv_heads=2, head_dim=16, d_ff=128, vocab_size=512,
                   mlp_type="swiglu", dtype="float32", attn_q_chunk=32,
                   attn_kv_chunk=32, remat=False)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    return params, cfg


@pytest.fixture(scope="module")
def tiny_system():
    from repro.core.rcllm import make_tiny_system
    return make_tiny_system(n_items=60, n_requests_hist=30, k_instances=2,
                            n_layers=2, d_model=32)


# ---------------------------------------------------------------- kv pool
def test_pool_alloc_free_and_exhaustion():
    pool = PagedKVPool(n_layers=2, n_kv_heads=2, head_dim=4,
                       page_size=4, n_pages=9)          # 8 usable (page 0
    pool.alloc(0, 13)                                   # reserved: scratch)
    assert len(pool.page_tables[0]) == 4
    pool.alloc(1, 16)
    with pytest.raises(PoolExhausted):
        pool.alloc(2, 5)
    assert not pool.can_admit(5)
    pool.free(0)
    assert pool.can_admit(5)
    pool.alloc(2, 5)                                    # reuses freed pages
    assert pool.stats().pages_in_use == 6
    assert pool.peak_pages == 8


def test_pool_write_gather_roundtrip(rng):
    pool = PagedKVPool(n_layers=3, n_kv_heads=2, head_dim=4,
                       page_size=4, n_pages=32)
    n = 11
    k = rng.normal(size=(n, 3, 2, 4)).astype(np.float32)
    v = rng.normal(size=(n, 3, 2, 4)).astype(np.float32)
    pool.alloc(7, n)
    pool.write_prompt(7, k, v)
    gk, gv = pool.gather(7)
    np.testing.assert_allclose(gk, k)
    np.testing.assert_allclose(gv, v)
    # decode append crosses a page boundary transparently
    pages0 = len(pool.page_tables[7])
    for _ in range(6):
        pool.append_slots([7])
    assert pool.seq_len(7) == n + 6
    assert len(pool.page_tables[7]) > pages0


def test_plan_spans_partition(tiny_system):
    from repro.data import synth as SY
    system, pool_rv, prof, _ = tiny_system
    req = SY.make_trace(system.catalog, pool_rv, prof, 1, qps=1.0,
                        n_users=3, n_candidates=8, reviews_per_user=1,
                        seed=5)[0]
    plan = system.plan_for(req)
    spans = ASM.plan_spans(plan)
    assert spans[0].start == 0 and spans[-1].end == plan.n
    for a, b in zip(spans, spans[1:]):
        assert a.end == b.start                     # exact partition
    for s in spans:
        assert (plan.source[s.start:s.end] == s.source).all()
        if s.source == ASM.FROM_ITEM:               # contiguous block run
            off = plan.block_offset[s.start:s.end]
            assert (np.diff(off) == 1).all()


# ------------------------------------------------------- prefill parity
def test_batched_prefill_matches_single_request(tiny, rng):
    params, cfg = tiny
    lens = [37, 52, 41, 64]
    reqs = [BatchRequest(rid=i,
                         tokens=rng.integers(1, 512, n).astype(np.int32))
            for i, n in enumerate(lens)]
    eng = BatchEngine(params, cfg, pool=pool_for(cfg, page_size=8,
                                                 n_pages=128), bucket=32)
    logits = eng.prefill(reqs, mode="full")
    for i, r in enumerate(reqs):
        ref = ENG.full_prefill_logits(params, cfg, r.tokens)
        np.testing.assert_allclose(logits[i], ref, atol=2e-3, rtol=1e-3)


def test_paged_decode_matches_full_forward(tiny, rng):
    """Greedy decode through page tables == full forward over the
    extended sequence (exact K/V in the pool -> fp32 tolerance)."""
    params, cfg = tiny
    lens = [23, 40]
    reqs = [BatchRequest(rid=i,
                         tokens=rng.integers(1, 512, n).astype(np.int32))
            for i, n in enumerate(lens)]
    eng = BatchEngine(params, cfg, pool=pool_for(cfg, page_size=8,
                                                 n_pages=64), bucket=32)
    logits = eng.prefill(reqs, mode="full")
    toks = {r.rid: list(r.tokens) for r in reqs}
    last = {r.rid: int(np.argmax(logits[i])) for i, r in enumerate(reqs)}
    for _ in range(3):
        rids = [r.rid for r in reqs]
        out = eng.decode(rids, [last[r] for r in rids])
        for i, rid in enumerate(rids):
            toks[rid].append(last[rid])
            ref = ENG.full_prefill_logits(
                params, cfg, np.asarray(toks[rid], np.int32))
            np.testing.assert_allclose(out[i], ref, atol=2e-3, rtol=1e-3)
            last[rid] = int(np.argmax(out[i]))


@pytest.mark.slow
def test_selective_batch_prefill_matches_engine(tiny_system):
    """The rcllm-mode batched prefill is the same selective path as the
    single-request engine — logits must agree exactly, and the pool must
    hold a full merged KV cache for decode."""
    from repro.data import synth as SY
    from repro.serving.workload import rcllm_batch_requests
    system, pool_rv, prof, _ = tiny_system
    trace = SY.make_trace(system.catalog, pool_rv, prof, 2, qps=1.0,
                          n_users=3, n_candidates=8, reviews_per_user=1,
                          seed=11)
    brs = rcllm_batch_requests(system, trace)
    eng = BatchEngine(system.params, system.cfg,
                      pool=pool_for(system.cfg, n_pages=256), bucket=64)
    logits = eng.prefill(brs, mode="rcllm")
    for i, br in enumerate(brs):
        ref, stats = ENG.selective_prefill_logits(
            system.params, system.cfg, br.plan, br.cached_k, br.cached_v,
            br.have, eng.sel, bucket=64)
        np.testing.assert_allclose(logits[i], ref, atol=2e-3, rtol=1e-3)
        assert eng.pool.seq_len(br.rid) == br.plan.n
        # recomputed tokens hold fresh KV, reused tokens the cached block
        k_pool, _ = eng.pool.gather(br.rid)
        st = eng.last_stats[br.rid]
        reused = ~st.recompute_mask & br.have
        if reused.any():
            np.testing.assert_allclose(k_pool[reused][:, 1:],
                                       br.cached_k[reused][:, 1:],
                                       atol=1e-6)
    out = eng.decode([0, 1], [int(np.argmax(l)) for l in logits])
    assert np.isfinite(out).all()


# ------------------------------------- paged decode kernel, adversarial
def _adversarial_pool(cfg, seed):
    """A pool whose slot tables are maximally hostile to the paged
    kernel's page views: rid 0's table interleaves store-shared and
    private slots at a non-page-aligned boundary (store run starts at
    logical position 3, enters the shared pages at slot offset 2, and
    crosses their page boundary mid-run), and both rids' lengths are not
    multiples of the page size.  Seeded so the gather/paged twin engines
    see bit-identical arenas."""
    r = np.random.default_rng(seed)
    pool = pool_for(cfg, page_size=4, n_pages=64)
    hd = cfg.resolved_head_dim

    def kv(t):
        return (r.normal(size=(t, cfg.n_layers, cfg.n_kv_heads, hd))
                .astype(np.float32))

    shared = pool.alloc_pages(3)                  # store-owned pages
    sslots = pool.page_slots(shared)
    pool.write_slots(sslots, kv(len(sslots)), kv(len(sslots)))
    # rid 0: S=13 (% 4 != 0); positions 3..9 served by shared slots 2..8
    pool.alloc_mapped(0, 13, np.arange(3, 10), sslots[2:9])
    priv = np.asarray([0, 1, 2, 10, 11, 12])
    pool.write_at(0, priv, kv(len(priv)), kv(len(priv)))
    # rid 1: S=6 (% 4 != 0), fully private
    pool.alloc(1, 6)
    pool.write_prompt(1, kv(6), kv(6))
    return pool


def test_paged_kernel_adversarial_slot_tables(tiny):
    """Greedy decode through the fused paged kernel must emit the same
    tokens as the jnp gather path over interleaved store/private slot
    tables at arbitrary alignment — including decode appends that grow
    the tables across page boundaries mid-sequence."""
    params, cfg = tiny
    runs = {}
    for kern in ("gather", "paged"):
        eng = BatchEngine(params,
                          dataclasses.replace(cfg, decode_kernel=kern),
                          pool=_adversarial_pool(cfg, seed=3), bucket=32)
        last = [3, 7]
        toks, logits = [], []
        for _ in range(6):                # rid 0: 13->19, rid 1: 6->12
            out = eng.decode([0, 1], last)
            last = [int(np.argmax(row)) for row in out]
            toks.append(tuple(last))
            logits.append(np.asarray(out))
        runs[kern] = (toks, logits)
    assert runs["gather"][0] == runs["paged"][0]   # bitwise token parity
    for lg, lp in zip(runs["gather"][1], runs["paged"][1]):
        np.testing.assert_allclose(lg, lp, atol=1e-5, rtol=1e-5)


def test_requeued_victim_decodes_through_paged_kernel(tiny_system):
    """A preempted-then-requeued victim (chunk in flight, abort rolls
    pages and chunk state back, fresh begin_prefill) must decode the
    same tokens through the paged kernel as through the gather path."""
    from repro.data import synth as SY
    system, pool_rv, prof, _ = tiny_system
    rq = SY.make_trace(system.catalog, pool_rv, prof, 1, qps=1.0,
                       n_users=3, n_candidates=8, reviews_per_user=2,
                       seed=23)[0]
    plan = system.plan_for(rq)
    ck, cv, have = system.cached_kv(plan)
    req = BatchRequest(rid=0, tokens=plan.tokens, plan=plan, cached_k=ck,
                       cached_v=cv, have=have, n_reserve=4)

    def run(decode_kernel):
        cfg = dataclasses.replace(system.cfg, decode_kernel=decode_kernel)
        eng = BatchEngine(system.params, cfg,
                          pool=pool_for(cfg, n_pages=256), chunk_tokens=64)
        eng.begin_prefill(req)
        eng.step(64, [], [], [0])              # one chunk in flight
        assert 0 in eng.prefill_states
        eng.abort_prefill(0)                   # preempted
        assert eng.pool.stats().pages_in_use == 0
        eng.begin_prefill(req)                 # requeued from its plan
        rep = eng.step(10_000, [], [], [0])
        last = int(np.argmax(rep.finalized[0]))
        toks = [last]
        for _ in range(4):
            out = eng.decode([0], [last])
            last = int(np.argmax(out[0]))
            toks.append(last)
        return toks

    assert run("gather") == run("paged")


# ------------------------------------------------ batcher over real engine
def test_continuous_batcher_jax_backend(tiny, rng):
    """Tight pool: 11 usable pages vs ~27 pages of total demand, so the
    loop must interleave admission waves under KV-pool backpressure, and
    decode-page reservation must keep in-flight requests from starving
    the free list mid-decode."""
    params, cfg = tiny
    eng = BatchEngine(params, cfg, pool=pool_for(cfg, page_size=8,
                                                 n_pages=12), bucket=32)
    backend = JaxEngineBackend(eng, mode="full")
    reqs = [PendingRequest(
        arrival_s=0.01 * i, rid=i, n_tokens=n, decode_steps=3,
        tokens=rng.integers(1, 512, n).astype(np.int32))
        for i, n in enumerate([30, 45, 25, 50, 33])]
    done = ContinuousBatcher(backend=backend, max_batch_tokens=128).run(reqs)
    assert len(done) == 5
    for c in done:
        assert c.first_token_s >= c.arrival_s
        assert c.done_s >= c.first_token_s
        assert len(backend.generated[c.rid]) == 3     # prefill + 2 decodes
    # every request released its pages back to the pool
    assert eng.pool.stats().pages_in_use == 0


def test_sim_and_jax_share_batching_loop():
    """The same loop semantics hold for both backends: one request,
    decode_steps tokens, completion ordering."""
    reqs = [PendingRequest(arrival_s=0.0, rid=0, n_tokens=100,
                           decode_steps=2)]
    done = ContinuousBatcher(lambda tok: 1e-3, lambda n: 1e-4).run(reqs)
    assert len(done) == 1
    assert done[0].done_s == pytest.approx(1e-3 + 1e-4)
