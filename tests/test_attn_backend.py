"""Attention-backend seam parity (the PR-3 tentpole).

Two invariants pin the seam down:

* backend parity — `attn_backend="pallas"` (interpret mode on CPU) must
  decode the exact same token sequences as the jnp reference through
  full prefill, rcllm (beyond-prefix selective) prefill, and N paged
  decode steps — under pallas the decode steps route through the fused
  paged-attention kernel (`decode_kernel="auto"`), and pinning
  `decode_kernel="paged"` under jnp isolates the decode kernel from the
  prefill backend;
* path parity — the batched rcllm prefill (bucketed, stacked, one jitted
  step per bucket) must match the legacy per-request loop bit-for-bit on
  logits and on paged-pool contents.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import engine as ENG
from repro.kernels.selective_attention.ops import (build_block_liveness,
                                                   selective_mha)
from repro.kernels.selective_attention.ref import selective_attention_ref
from repro.serving.batch_engine import BatchEngine
from repro.serving.kv_pool import pool_for
from repro.serving.workload import rcllm_batch_requests

DECODE_STEPS = 3


@pytest.fixture(scope="module")
def tiny_system():
    from repro.core.rcllm import make_tiny_system
    return make_tiny_system(n_items=60, n_requests_hist=30, k_instances=2,
                            n_layers=2, d_model=32)


@pytest.fixture(scope="module")
def batch_reqs(tiny_system):
    from repro.data import synth as SY
    system, pool_rv, prof, _ = tiny_system
    trace = SY.make_trace(system.catalog, pool_rv, prof, 4, qps=2.0,
                          n_users=3, n_candidates=8, reviews_per_user=1,
                          seed=21)
    return rcllm_batch_requests(system, trace, n_reserve=DECODE_STEPS)


def _decode_seqs(system, brs, backend: str, mode: str,
                 batched_selective: bool = True,
                 decode_kernel: str = "auto"):
    """Prefill + DECODE_STEPS greedy decode steps under one backend.
    -> ({rid: tokens}, prefill logits, engine)."""
    cfg = dataclasses.replace(system.cfg, attn_backend=backend,
                              decode_kernel=decode_kernel)
    eng = BatchEngine(system.params, cfg, pool=pool_for(cfg, n_pages=256),
                      bucket=64, batched_selective=batched_selective)
    logits = eng.prefill(brs, mode=mode)
    last = {r.rid: int(np.argmax(lg)) for r, lg in zip(brs, logits)}
    toks = {rid: [t] for rid, t in last.items()}
    rids = [r.rid for r in brs]
    for _ in range(DECODE_STEPS):
        out = eng.decode(rids, [last[r] for r in rids])
        for i, rid in enumerate(rids):
            last[rid] = int(np.argmax(out[i]))
            toks[rid].append(last[rid])
    return toks, logits, eng


@pytest.mark.parametrize("mode", ["full", "rcllm"])
def test_backend_parity_decoded_tokens(tiny_system, batch_reqs, mode):
    """jnp and pallas backends must emit identical token sequences through
    prefill + N paged decode steps (both modes).  Under pallas, decode
    runs the fused paged-attention kernel (decode_kernel="auto"), so
    this also pins gather-decode vs paged-decode token parity."""
    system = tiny_system[0]
    toks_j, logits_j, _ = _decode_seqs(system, batch_reqs, "jnp", mode)
    toks_p, logits_p, _ = _decode_seqs(system, batch_reqs, "pallas", mode)
    np.testing.assert_allclose(logits_j, logits_p, atol=1e-4, rtol=1e-4)
    assert toks_j == toks_p


@pytest.mark.parametrize("mode", ["full", "rcllm"])
def test_decode_kernel_parity_under_jnp(tiny_system, batch_reqs, mode):
    """Isolate the decode kernel from the prefill backend: with the jnp
    backend fixed, decode_kernel="paged" must reproduce the gather
    path's prefill logits bitwise (the knob touches decode only) and
    decode the exact same greedy token sequences."""
    system = tiny_system[0]
    toks_g, logits_g, _ = _decode_seqs(system, batch_reqs, "jnp", mode,
                                       decode_kernel="gather")
    toks_p, logits_p, _ = _decode_seqs(system, batch_reqs, "jnp", mode,
                                       decode_kernel="paged")
    np.testing.assert_array_equal(logits_g, logits_p)
    assert toks_g == toks_p


def test_batched_rcllm_matches_per_request_bitwise(tiny_system, batch_reqs):
    """The batched selective prefill is the same math as the per-request
    loop — logits and pool contents must agree bit-for-bit, and so must
    the Eq. 3 recompute selection."""
    system = tiny_system[0]
    toks_b, logits_b, eng_b = _decode_seqs(system, batch_reqs, "jnp",
                                           "rcllm", batched_selective=True)
    toks_l, logits_l, eng_l = _decode_seqs(system, batch_reqs, "jnp",
                                           "rcllm", batched_selective=False)
    np.testing.assert_array_equal(logits_b, logits_l)
    assert toks_b == toks_l
    for r in batch_reqs:
        sb, sl = eng_b.last_stats[r.rid], eng_l.last_stats[r.rid]
        np.testing.assert_array_equal(sb.recompute_mask, sl.recompute_mask)
        kb, vb = eng_b.pool.gather(r.rid)
        kl, vl = eng_l.pool.gather(r.rid)
        np.testing.assert_array_equal(kb, kl)
        np.testing.assert_array_equal(vb, vl)


def test_selective_mha_traceable_with_precomputed_liveness():
    """The jit seam: with a precomputed block-liveness map the wrapper
    traces end-to-end (per-request batched masks included) and matches
    the oracle."""
    rng = np.random.default_rng(17)
    B, R, S, Hq, Hkv, D = 2, 16, 64, 4, 2, 32
    q = jnp.asarray(rng.normal(size=(B, R, Hq, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, Hkv, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, Hkv, D)), jnp.float32)
    qpos = np.stack([np.sort(rng.choice(S, R, replace=False))
                     for _ in range(B)]).astype(np.int32)
    hh = (rng.random((B, S)) < 0.3).astype(np.int8)
    live = build_block_liveness(qpos, hh, window=8, q_block=16, kv_block=32)

    @jax.jit
    def traced(q, qp, k, v, m, lv):
        return selective_mha(q, qp, k, v, m, live=lv, window=8,
                             q_block=16, kv_block=32, interpret=True)

    out = traced(q, jnp.asarray(qpos), k, v, jnp.asarray(hh),
                 jnp.asarray(live))
    g = Hq // Hkv
    for b in range(B):
        qf = q[b].transpose(1, 0, 2)
        kf = jnp.repeat(k[b], g, 1).transpose(1, 0, 2)
        vf = jnp.repeat(v[b], g, 1).transpose(1, 0, 2)
        ref = selective_attention_ref(qf, jnp.asarray(qpos[b]), kf, vf,
                                      jnp.asarray(hh[b]), window=8)
        np.testing.assert_allclose(np.asarray(out[b]),
                                   np.asarray(ref.transpose(1, 0, 2)),
                                   atol=1e-5, rtol=1e-5)


def test_engine_selective_backend_parity_with_kv(tiny_system):
    """Engine level: selective_prefill_with_kv under pallas returns the
    same merged KV (bitwise — the KV path never goes through the kernel)
    and near-identical logits."""
    from repro.data import synth as SY
    system, pool_rv, prof, _ = tiny_system
    trace = SY.make_trace(system.catalog, pool_rv, prof, 1, qps=1.0,
                          n_users=3, n_candidates=8, reviews_per_user=1,
                          seed=33)
    plan = system.plan_for(trace[0])
    ck, cv, have = system.cached_kv(plan)
    sel = ENG.SelectiveConfig()
    cfg_p = dataclasses.replace(system.cfg, attn_backend="pallas")
    lj, sj, kj, vj = ENG.selective_prefill_with_kv(
        system.params, system.cfg, plan, ck, cv, have, sel, bucket=64)
    lp, sp, kp, vp = ENG.selective_prefill_with_kv(
        system.params, cfg_p, plan, ck, cv, have, sel, bucket=64)
    np.testing.assert_array_equal(sj.recompute_mask, sp.recompute_mask)
    np.testing.assert_array_equal(kj, kp)
    np.testing.assert_array_equal(vj, vp)
    np.testing.assert_allclose(lj, lp, atol=1e-4, rtol=1e-4)