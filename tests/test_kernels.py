"""Pallas kernel validation: shape/dtype sweeps vs pure-jnp oracles,
interpret=True (CPU executes the kernel bodies).

Every test owns a local `np.random.default_rng(seed)`: the session-scoped
`rng` fixture is a shared stream, so a new test consuming it anywhere in
the session would silently shift the draws these order-sensitive sweeps
assert on."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.block_gather.ops import assemble_kv
from repro.kernels.block_gather.ref import block_gather_ref
from repro.kernels.embedding_bag.ops import bag_sum
from repro.kernels.embedding_bag.ref import embedding_bag_ref
from repro.kernels.flash_attention.ops import mha_flash
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.selective_attention.ops import selective_mha
from repro.kernels.selective_attention.ref import selective_attention_ref


def _tol(dtype):
    return 2e-2 if dtype == jnp.bfloat16 else 1e-5


@pytest.mark.parametrize("shape,causal", [
    ((1, 64, 64, 2, 2, 32), True),
    ((2, 100, 100, 4, 2, 16), True),
    ((2, 33, 77, 4, 4, 64), False),
    ((1, 128, 256, 8, 2, 128), False),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(shape, causal, dtype):
    rng = np.random.default_rng(0)
    B, Sq, Skv, Hq, Hkv, D = shape
    q = jnp.asarray(rng.normal(size=(B, Sq, Hq, D)), dtype)
    k = jnp.asarray(rng.normal(size=(B, Skv, Hkv, D)), dtype)
    v = jnp.asarray(rng.normal(size=(B, Skv, Hkv, D)), dtype)
    out = mha_flash(q, k, v, causal=causal, q_block=32, kv_block=32,
                    interpret=True)
    G = Hq // Hkv
    kk = jnp.repeat(k, G, 2).transpose(0, 2, 1, 3).reshape(B * Hq, Skv, D)
    vv = jnp.repeat(v, G, 2).transpose(0, 2, 1, 3).reshape(B * Hq, Skv, D)
    qq = q.transpose(0, 2, 1, 3).reshape(B * Hq, Sq, D)
    ref = flash_attention_ref(qq, kk, vv, causal=causal)
    ref = ref.reshape(B, Hq, Sq, D).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=_tol(dtype), rtol=_tol(dtype))


@pytest.mark.parametrize("R_,S,window,n_hh", [
    (32, 160, 24, 12), (16, 64, 8, 0), (48, 300, 64, 30)])
def test_selective_attention_sweep(R_, S, window, n_hh):
    rng = np.random.default_rng(1)
    B, Hq, Hkv, D = 1, 2, 2, 32
    q = jnp.asarray(rng.normal(size=(B, R_, Hq, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, Hkv, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, Hkv, D)), jnp.float32)
    qpos = jnp.asarray(np.sort(rng.choice(S, R_, replace=False)), jnp.int32)
    hh = np.zeros(S, np.int8)
    if n_hh:
        hh[rng.choice(S, n_hh, replace=False)] = 1
    out = selective_mha(q, qpos, k, v, jnp.asarray(hh), window=window,
                        q_block=16, kv_block=32, interpret=True)
    qf = q.transpose(0, 2, 1, 3).reshape(B * Hq, R_, D)
    kf = k.transpose(0, 2, 1, 3).reshape(B * Hq, S, D)
    vf = v.transpose(0, 2, 1, 3).reshape(B * Hq, S, D)
    ref = selective_attention_ref(qf, qpos, kf, vf, jnp.asarray(hh),
                                  window=window)
    ref = ref.reshape(B, Hq, R_, D).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-5, rtol=1e-5)


def test_selective_mha_rejects_jit_tracing():
    """selective_mha is documented as not jit-traceable end-to-end (the
    block-liveness map needs concrete positions/mask); it must fail with
    a clear error at the wrapper, not deep inside the host-side
    computation."""
    rng = np.random.default_rng(3)
    B, R_, S, Hq, Hkv, D = 1, 16, 64, 2, 2, 32
    q = jnp.asarray(rng.normal(size=(B, R_, Hq, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, Hkv, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, Hkv, D)), jnp.float32)
    qpos = jnp.asarray(np.sort(rng.choice(S, R_, replace=False)), jnp.int32)
    hh = jnp.asarray(np.zeros(S, np.int8))

    jitted = jax.jit(lambda qp, m: selective_mha(
        q, qp, k, v, m, window=8, q_block=16, kv_block=32, interpret=True))
    with pytest.raises(TypeError, match="not .*jit|jit.*host-side|traced"):
        jitted(qpos, hh)
    # closing over concrete positions/mask and jitting around the wrapper
    # stays supported
    out = selective_mha(q, qpos, k, v, hh, window=8, q_block=16,
                        kv_block=32, interpret=True)
    assert np.isfinite(np.asarray(out)).all()


@pytest.mark.parametrize("npages,page,d,n_logical,rotate", [
    (16, 8, 32, 6, True), (8, 16, 64, 8, False), (32, 8, 128, 4, True)])
def test_block_gather_sweep(npages, page, d, n_logical, rotate):
    rng = np.random.default_rng(2)
    pk = jnp.asarray(rng.normal(size=(npages, page, d)), jnp.float32)
    pv = jnp.asarray(rng.normal(size=(npages, page, d)), jnp.float32)
    bt = jnp.asarray(rng.choice(npages, n_logical, replace=False), jnp.int32)
    pos = jnp.asarray(
        rng.integers(0, 4096, (n_logical, page)), jnp.int32)
    ko, vo = assemble_kv(pk, pv, bt, pos, rope_theta=1e4, rotate=rotate,
                         interpret=True)
    kr, vr = block_gather_ref(pk, pv, bt, pos, rope_theta=1e4, rotate=rotate)
    np.testing.assert_allclose(np.asarray(ko), np.asarray(kr),
                               atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(np.asarray(vo), np.asarray(vr), atol=1e-6)


@pytest.mark.parametrize("rows,d,B,F", [(256, 16, 8, 5), (1000, 32, 4, 13),
                                        (64, 128, 16, 3)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_embedding_bag_sweep(rows, d, B, F, dtype):
    rng = np.random.default_rng(4)
    table = jnp.asarray(rng.normal(size=(rows, d)), dtype)
    ids = jnp.asarray(rng.integers(0, rows, (B, F)), jnp.int32)
    out = bag_sum(table, ids, interpret=True)
    ref = embedding_bag_ref(table, ids)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=_tol(dtype), rtol=_tol(dtype))


def test_block_gather_matches_transformer_rope():
    """Kernel RoPE == model RoPE (the realignment the engine relies on)."""
    rng = np.random.default_rng(5)
    from repro.models.layers import apply_rope
    page, d = 8, 32
    pk = jnp.asarray(rng.normal(size=(4, page, d)), jnp.float32)
    pos = jnp.arange(4 * page).reshape(4, page)
    ko, _ = assemble_kv(pk, pk, jnp.arange(4, dtype=jnp.int32), pos,
                        rope_theta=1e4, interpret=True)
    ref = apply_rope(pk.reshape(1, 4 * page, 1, d),
                     jnp.arange(4 * page), 1e4).reshape(4, page, d)
    np.testing.assert_allclose(np.asarray(ko), np.asarray(ref), atol=2e-4)
