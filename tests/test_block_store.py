"""Cross-request KV reuse: shared block store, pool-lifecycle crash
fixes, allocator/store ownership invariants, and bitwise decoded-token
parity with reuse on vs off (jnp and pallas attention backends)."""
import dataclasses

import numpy as np
import pytest

from repro.serving import api as API
from repro.serving import workload as WL
from repro.serving.batch_engine import BatchEngine, BatchRequest
from repro.serving.batching import (ClusterBatcher, ContinuousBatcher,
                                    JaxEngineBackend, PendingRequest)
from repro.serving.block_store import (SharedBlockStore, check_partition,
                                       content_key)
from repro.serving.kv_pool import PagedKVPool, PoolExhausted, pool_for


@pytest.fixture(scope="module")
def tiny_system():
    from repro.core.rcllm import make_tiny_system
    return make_tiny_system(n_items=60, n_requests_hist=30, k_instances=2,
                            n_layers=2, d_model=32)


@pytest.fixture(scope="module")
def zipf_workload(tiny_system):
    """Repeat-user Zipf trace + plans + reuse metadata (8 requests)."""
    system, pool_rv, prof, _ = tiny_system
    trace = WL.zipf_repeat_trace(system.catalog, pool_rv, prof, 8, qps=12.0,
                                 n_users=3, zipf_a=1.4, seed=3)
    pend, plans = WL.rcllm_workload(system, trace, decode_steps=3)
    reuse = WL.rcllm_reuse_info(system, trace, plans)
    return trace, pend, plans, reuse


def _tiny_pool(n_pages=16, page_size=4):
    return PagedKVPool(n_layers=2, n_kv_heads=2, head_dim=4,
                       page_size=page_size, n_pages=n_pages)


# ------------------------------------------------- pool lifecycle fixes
def test_free_is_idempotent():
    """Double-free and free-of-unknown-rid are no-ops (a duplicate
    `finish()` used to raise bare KeyError and kill the batcher loop)."""
    pool = _tiny_pool()
    pool.alloc(0, 10)
    free0 = pool.free_pages
    pool.free(0)
    pool.free(0)                                  # double free: no-op
    pool.free(123)                                # never allocated: no-op
    assert pool.free_pages == free0 + 3
    check_partition(pool)


def test_engine_release_is_idempotent(tiny_system):
    system, *_ = tiny_system
    eng = BatchEngine(system.params, system.cfg,
                      pool=pool_for(system.cfg, n_pages=64))
    rng = np.random.default_rng(0)
    req = BatchRequest(rid=7, tokens=rng.integers(1, 512, 20).astype(np.int32))
    eng.prefill([req], mode="full")
    eng.release(7)
    eng.release(7)                                # duplicate finish: no-op
    assert eng.pool.stats().pages_in_use == 0


def test_append_slots_rolls_back_on_exhaustion():
    """A mid-batch PoolExhausted in append_slots must leave no phantom
    seq_len bumps and no leaked pages (the preemption path retries)."""
    pool = _tiny_pool(n_pages=7, page_size=4)     # 6 usable
    pool.alloc(0, 12)                             # 3 pages, full
    pool.alloc(1, 12)                             # 3 pages, full
    pool.write_at(0, np.arange(12),
                  np.zeros((12, 2, 2, 4), np.float32),
                  np.zeros((12, 2, 2, 4), np.float32))
    pool.write_at(1, np.arange(12),
                  np.zeros((12, 2, 2, 4), np.float32),
                  np.zeros((12, 2, 2, 4), np.float32))
    lens_before = dict(pool.seq_lens)
    tables_before = {r: len(pool.page_tables[r]) for r in (0, 1)}
    with pytest.raises(PoolExhausted):
        pool.append_slots([0, 1])                 # both need growth, 0 free
    assert pool.seq_lens == lens_before
    assert {r: len(pool.page_tables[r]) for r in (0, 1)} == tables_before
    check_partition(pool)


def test_cluster_backend_preempt_keeps_plans(tiny_system):
    """`ClusterWorkerBackend.finish` drops the bound plan (plans bind
    once at dispatch) — but a decode-time *preemption* must keep it, or
    the victim's re-prefill dies on a KeyError."""
    from repro.serving.cluster import ClusterWorkerBackend
    system, *_ = tiny_system
    eng = BatchEngine(system.params, system.cfg,
                      pool=pool_for(system.cfg, n_pages=32))
    backend = ClusterWorkerBackend(eng, shard=None, mode="rcllm")
    backend.plans[3] = ("plan", None, None, None)
    backend.reuse[3] = object()
    eng.pool.alloc(3, 8)
    req = PendingRequest(arrival_s=0.0, rid=3, n_tokens=8, decode_steps=2)
    backend.preempt(req)
    assert 3 in backend.plans and 3 in backend.reuse   # still re-runnable
    assert eng.pool.stats().pages_in_use == 0          # pages released
    backend.finish(req)                                # real finish drops
    assert 3 not in backend.plans and 3 not in backend.reuse


def test_decode_preemption_tiny_pool(tiny_system):
    """Decode-time PoolExhausted must preempt the youngest request (free
    + requeue) instead of killing the worker: an under-reserving backend
    over a pool that cannot hold every request's decode growth."""
    system, *_ = tiny_system

    class NoReserveBackend(JaxEngineBackend):
        def _batch_requests(self, batch):
            out = super()._batch_requests(batch)
            for br in out:
                br.n_reserve = 0              # simulate broken accounting
            return out

    eng = BatchEngine(system.params, system.cfg,
                      pool=pool_for(system.cfg, page_size=8, n_pages=8))
    backend = NoReserveBackend(eng, mode="full")
    rng = np.random.default_rng(1)
    reqs = [PendingRequest(arrival_s=0.01 * i, rid=i, n_tokens=24,
                           decode_steps=4,
                           tokens=rng.integers(1, 512, 24).astype(np.int32))
            for i in range(2)]
    batcher = ClusterBatcher([backend])
    done = batcher.run(reqs)
    assert len(done) == 2                         # nobody was lost
    assert batcher.workers[0].preempted >= 1
    for c in done:
        assert len(backend.generated[c.rid]) == 4
    assert eng.pool.stats().pages_in_use == 0     # nothing leaked
    check_partition(eng.pool)


# ------------------------------------------------- store unit behaviour
def _blk(rng, n, L=2, H=2, D=4):
    return (rng.normal(size=(n, L, H, D)).astype(np.float32),
            rng.normal(size=(n, L, H, D)).astype(np.float32))


def test_store_refcounts_lru_and_pinning():
    pool = _tiny_pool(n_pages=10, page_size=4)    # 9 usable
    store = SharedBlockStore(pool, max_user_pages=2)
    rng = np.random.default_rng(2)
    ka, va = _blk(rng, 8)
    kb, vb = _blk(rng, 8)
    kc, vc = _blk(rng, 8)
    a = store.insert(("item", "a"), "item", ka, va)
    b = store.insert(("item", "b"), "item", kb, vb)
    assert pool.free_pages == 5
    assert store.acquire(("item", "a")) is a      # hit + ref
    store.get(("item", "b"))                      # b is now most recent
    check_partition(pool, store)
    # pressure: c needs 2 pages while keeping 4 free, but only 5 are ->
    # evict; a is referenced so only b is evictable (despite a being LRU)
    c = store.insert(("item", "c"), "item", kc, vc, keep_free=4)
    assert c is not None
    assert store.has(("item", "a")) and not store.has(("item", "b"))
    check_partition(pool, store)
    # release a; a pinned user block never evicts even under pressure
    store.release(("item", "a"))
    u = store.insert(("user", "u"), "user", ka[:4], va[:4], pinned=True)
    assert u is not None and u.pinned
    assert not store.evict_for(pool.n_pages)      # can't evict pinned u
    assert store.has(("user", "u"))
    # user-tier budget: a second user block over max_user_pages is skipped
    assert store.insert(("user", "u2"), "user", kb, vb, pinned=True) is None
    assert store.counters["insert_skips"] >= 1
    check_partition(pool, store)


def test_store_mapped_request_roundtrip():
    """alloc_mapped + shared slots: gather returns the store block's
    bytes at mapped positions and privately written rows elsewhere."""
    pool = _tiny_pool(n_pages=16, page_size=4)
    store = SharedBlockStore(pool)
    rng = np.random.default_rng(3)
    kb, vb = _blk(rng, 6)
    blk = store.insert(content_key("item", np.arange(6)), "item", kb, vb)
    n = 10
    mapped_pos = np.asarray([2, 3, 4, 7, 8])      # arbitrary alignment
    mapped_off = np.asarray([0, 1, 2, 4, 5])
    pool.alloc_mapped(5, n, mapped_pos, blk.slots[mapped_off])
    priv = np.setdiff1d(np.arange(n), mapped_pos)
    kw, vw = _blk(rng, len(priv))
    pool.write_at(5, priv, kw, vw)
    gk, gv = pool.gather(5)
    np.testing.assert_array_equal(gk[mapped_pos], kb[mapped_off])
    np.testing.assert_array_equal(gv[mapped_pos], vb[mapped_off])
    np.testing.assert_array_equal(gk[priv], kw)
    check_partition(pool, store)
    pool.free(5)
    check_partition(pool, store)


def test_partition_invariant_random_walk():
    """Property-style allocator+store invariant: after every random op,
    each page is owned by exactly one of {free list, a request, the
    store}, refcounted blocks survive, zero-ref pages return on free."""
    rng = np.random.default_rng(4)
    pool = _tiny_pool(n_pages=24, page_size=4)
    store = SharedBlockStore(pool, max_user_pages=6)
    next_rid, next_bid = 0, 0
    live_rids, keys = [], []
    held = {}                                     # rid -> keys
    for step in range(250):
        op = rng.integers(0, 7)
        try:
            if op == 0:                           # plain alloc
                pool.alloc(next_rid, int(rng.integers(1, 20)))
                live_rids.append(next_rid)
                held[next_rid] = []
                next_rid += 1
            elif op == 1 and keys:                # mapped alloc over a block
                key = keys[rng.integers(len(keys))]
                blk = store.acquire(key)
                if blk is not None:
                    n = blk.n_tokens
                    pos = np.sort(rng.choice(
                        np.arange(n + 4), size=min(n, 3), replace=False))
                    off = np.sort(rng.choice(
                        np.arange(n), size=len(pos), replace=False))
                    pool.alloc_mapped(next_rid, n + 4, pos, blk.slots[off])
                    live_rids.append(next_rid)
                    held[next_rid] = [key]
                    next_rid += 1
                elif blk is None:
                    pass
            elif op == 2 and live_rids:           # free (sometimes double)
                rid = live_rids[rng.integers(len(live_rids))]
                pool.free(rid)
                store.release_all(held.pop(rid, []))
                live_rids.remove(rid)
                if rng.random() < 0.3:
                    pool.free(rid)                # double free: no-op
            elif op == 3:                         # store insert
                nb = int(rng.integers(2, 10))
                k, v = _blk(rng, nb)
                kind = "user" if rng.random() < 0.3 else "item"
                store.insert((kind, f"b{next_bid}"), kind, k, v,
                             pinned=kind == "user")
                if store.has((kind, f"b{next_bid}")):
                    keys.append((kind, f"b{next_bid}"))
                next_bid += 1
            elif op == 4 and keys:                # ref churn, no mapping
                key = keys[rng.integers(len(keys))]
                if store.acquire(key) is not None:
                    store.release(key)
            elif op == 5:                         # eviction pressure
                store.evict_for(int(rng.integers(1, 8)))
                keys = [k for k in keys if store.has(k)]
            elif op == 6 and live_rids:           # decode append growth
                rid = live_rids[rng.integers(len(live_rids))]
                pool.seq_lens[rid] = len(pool.slot_tables[rid])
                pool.append_slots([rid])
        except PoolExhausted:
            pass
        check_partition(pool, store)
    # drain everything: every page must come home to the free list
    for rid in list(live_rids):
        pool.free(rid)
        store.release_all(held.pop(rid, []))
    for key in list(store.blocks):
        store.blocks[key].refcount = 0
        store.blocks[key].pinned = False
    store.evict_for(pool.n_pages - 1)
    assert pool.free_pages == pool.n_pages - 1
    check_partition(pool, store)


# --------------------------------------------- reuse on/off parity
def _run_batcher(system, pend, plans, reuse, kv_reuse, cfg=None,
                 n_pages=256):
    cfg = cfg or system.cfg
    pool = pool_for(cfg, n_pages=n_pages)
    store = SharedBlockStore(pool) if kv_reuse else None
    engine = BatchEngine(system.params, cfg, pool=pool, store=store)
    backend = JaxEngineBackend(engine, mode="rcllm", plans=plans,
                               reuse=reuse if kv_reuse else None)
    ContinuousBatcher(backend=backend, max_batch_tokens=4096).run(list(pend))
    return backend, engine


def test_kv_reuse_decoded_parity_jnp(tiny_system, zipf_workload):
    """Decoded tokens must be bitwise identical with the shared block
    store on vs off — reuse changes where decode reads, never what."""
    system, *_ = tiny_system
    _, pend, plans, reuse = zipf_workload
    b_off, e_off = _run_batcher(system, pend, plans, reuse, False)
    b_on, e_on = _run_batcher(system, pend, plans, reuse, True)
    for rid in b_off.generated:
        assert b_off.generated[rid] == b_on.generated[rid]
    stats = e_on.store.stats()
    # the workload really shared: all three tiers saw hits (prefix hits
    # additionally shrink the recompute set — and tokens still match)
    assert stats["hits_user"] > 0 and stats["hits_item"] > 0
    assert stats["hits_prefix"] > 0
    # admission accounting credits resident blocks: with the store warm,
    # a repeat request's private-page bound sits strictly below its full
    # (reuse-off) page demand — that credit is what buys admission
    from repro.serving.block_store import admission_pages
    bounds = []
    for rid, (plan, _, _, have) in plans.items():
        bound, _ = admission_pages(e_on.pool, e_on.store, plan, have,
                                   e_on.sel, reuse[rid], 2)
        bounds.append((bound, e_on.pool.pages_for(plan.n + 2)))
    assert any(b < full for b, full in bounds)
    assert all(b <= full for b, full in bounds)
    assert e_on.pool.stats().pages_in_use == 0
    check_partition(e_on.pool, e_on.store)


@pytest.mark.slow
def test_kv_reuse_decoded_parity_pallas(tiny_system, zipf_workload):
    """The same bitwise on/off parity with attention through the Pallas
    kernels (interpret mode on CPU)."""
    system, *_ = tiny_system
    _, pend, plans, reuse = zipf_workload
    cfg = dataclasses.replace(system.cfg, attn_backend="pallas")
    short = [p for p in pend if p.rid < 4]
    b_off, _ = _run_batcher(system, short, plans, reuse, False, cfg=cfg)
    b_on, e_on = _run_batcher(system, short, plans, reuse, True, cfg=cfg)
    for rid in b_off.generated:
        assert b_off.generated[rid] == b_on.generated[rid]
    assert e_on.store.stats()["hits_item"] > 0


@pytest.mark.slow
def test_cluster_kv_reuse_parity_and_transfers(tiny_system):
    """K=2 cluster: kv_reuse changes costs (fewer cross-shard transfers,
    tier hit rates reported per worker), never decoded tokens."""
    from repro.serving.cluster import ClusterEngine
    system, pool_rv, prof, _ = tiny_system
    trace = WL.zipf_repeat_trace(system.catalog, pool_rv, prof, 8, qps=12.0,
                                 n_users=3, zipf_a=1.4, seed=6)
    cfg = API.ServeConfig(engine="jax", k=2, n_pages=256)
    rep_off = ClusterEngine(system, cfg).run(trace, decode_steps=2)
    rep_on = ClusterEngine(system, cfg.replace(kv_reuse=True)).run(
        trace, decode_steps=2)
    assert rep_off.generated == rep_on.generated
    xfer_off = sum(w.transfer_blocks for w in rep_off.workers)
    xfer_on = sum(w.transfer_blocks for w in rep_on.workers)
    assert xfer_on <= xfer_off
    stats = [w.kv_reuse for w in rep_on.workers if w.kv_reuse]
    assert stats and any(s["hits_item"] > 0 for s in stats)
    assert all("user_hit_rate" in s and "item_hit_rate" in s for s in stats)
    assert all(w.kv_reuse is None for w in rep_off.workers)
