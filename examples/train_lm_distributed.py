"""Distributed LM training driver: any assigned architecture (reduced or
full), the production sharding rules, the fault-tolerant loop, and
gradient compression on the DP axis.

    # CPU-feasible reduced config:
    PYTHONPATH=src python examples/train_lm_distributed.py \
        --arch gemma-7b --smoke --steps 20

    # full-config lowering check (no execution; dry-run proper lives in
    # repro.launch.dryrun):
    PYTHONPATH=src python examples/train_lm_distributed.py \
        --arch nemotron-4-15b --lower-only
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import registry as R
from repro.data.pipeline import BatchPipeline, lm_synthetic_batches
from repro.models import transformer as T
from repro.training.train_loop import TrainConfig, train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-7b", choices=list(R.ARCHS))
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--compression", default=None,
                    choices=[None, "int8", "topk"])
    ap.add_argument("--lower-only", action="store_true")
    args = ap.parse_args()

    if args.lower_only:
        from repro.launch.dryrun import run_cell
        run_cell(args.arch, "train_4k", multi_pod=False,
                 out_dir="results/dryrun", skip_existing=False)
        return

    cfg = R.get_config(args.arch, smoke=args.smoke)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    n_params = sum(int(np.prod(p.shape))
                   for p in jax.tree_util.tree_leaves(params))
    print(f"{cfg.name}: {n_params / 1e6:.1f}M params "
          f"({'reduced' if args.smoke else 'full'})")

    loss_fn = lambda p, b: T.loss_fn(p, b["tokens"], b["labels"], cfg)[0]
    pipe = BatchPipeline(lm_synthetic_batches(cfg.vocab_size, args.batch,
                                              args.seq))
    t0 = time.time()
    _, _, hist = train(params, loss_fn, iter(pipe),
                       TrainConfig(steps=args.steps, ckpt_dir=args.ckpt,
                                   optimizer=cfg.optimizer, lr=1e-3,
                                   grad_compression=args.compression))
    pipe.close()
    dt = time.time() - t0
    print(f"loss {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f} "
          f"in {len(hist)} steps ({dt / len(hist):.2f}s/step)")


if __name__ == "__main__":
    main()
