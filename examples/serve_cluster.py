"""Distributed serving demo: K=40 instances, paper-scale prompts, the
Fig. 6 experiment in one script.

    PYTHONPATH=src python examples/serve_cluster.py [--k 40] [--qps 150]

Simulated cluster (the paper's own distributed evaluation is Vidur-based —
see DESIGN.md §2): Poisson arrivals → Eq. 2 affinity scheduler → paged
assembly + selective recompute per instance → TTFT percentiles, vs
Prefix-Cache and Full-Recompute on the same trace.  Also demonstrates
fault tolerance: a node failure mid-trace and a straggler with hedging.
"""
import argparse

import numpy as np

from repro.configs import registry as REG
from repro.core import cost_model as CM
from repro.core import simulator as SIM


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--k", type=int, default=40)
    ap.add_argument("--qps", type=float, default=None)
    ap.add_argument("--requests", type=int, default=1500)
    args = ap.parse_args()
    qps = args.qps if args.qps is not None else 3.0 * args.k

    cfg = REG.ARCHS["rcllm-qwen3-8b"]
    reqs, placement, _ = SIM.make_sim_setup(
        k=args.k, n_requests=args.requests, qps=qps, n_items=8000, seed=1)
    print(f"cluster: K={args.k}, qps={qps:.0f}, "
          f"median prompt={np.median([r.n_total for r in reqs]):.0f} tokens")

    for mode in ("full", "prefix", "rcllm"):
        res = SIM.simulate(cfg, CM.V5E_1, reqs, placement,
                           SIM.SimConfig(mode=mode))
        s = res.summary()
        print(f"  {mode:7s} p50={s['p50']:.3f}s p90={s['p90']:.3f}s "
              f"p99={s['p99']:.3f}s  hit={s['mean_hit']:.2f}")

    print("fault tolerance: instance 0 down for 5s mid-trace")
    faults = [SIM.NodeFault(instance=0, t_fail_s=1.0, t_repair_s=6.0)]
    res = SIM.simulate(cfg, CM.V5E_1, reqs, placement,
                       SIM.SimConfig(mode="rcllm"), faults=faults)
    print(f"  rcllm+fault p99={res.pct(99):.3f}s "
          f"({res.n_requests} requests, none dropped)")

    print("straggler mitigation: one 8x-slow node, hedged requests")
    slow = np.ones(args.k)
    slow[1] = 8.0
    for hedge in (None, 20.0):
        res = SIM.simulate(cfg, CM.V5E_1, reqs, placement,
                           SIM.SimConfig(mode="rcllm", hedge_ms=hedge),
                           straggler_factors=slow)
        tag = f"hedge={hedge}ms" if hedge else "no hedge"
        print(f"  {tag:12s} p99={res.pct(99):.3f}s")


if __name__ == "__main__":
    main()
