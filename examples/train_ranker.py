"""End-to-end driver: train the ranking LM for a few hundred steps on the
planted-preference task, rebuild the RcLLM caches with the trained weights,
and report Table III metrics for Full vs RcLLM vs CacheBlend vs EPIC.

    PYTHONPATH=src python examples/train_ranker.py [--steps 300]

Uses the fault-tolerant train loop (checkpointing to results/ranker_ckpt,
auto-resume on restart).
"""
import argparse
import time

import numpy as np

from repro.core import metrics as MET
from repro.core import ranker_training as RT
from repro.core.engine import SelectiveConfig
from repro.core.rcllm import RcLLMSystem, make_tiny_system


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--eval", type=int, default=40)
    args = ap.parse_args()

    t0 = time.time()
    system, pool, prof, hist = make_tiny_system(n_items=150,
                                                n_requests_hist=80)
    reqs, gold = RT.make_planted_trace(system.catalog, pool, prof,
                                       n_requests=300 + args.eval,
                                       n_candidates=8, n_users=120, seed=5)
    n_train = len(reqs) - args.eval
    print(f"training ranker: {args.steps} steps on {n_train} requests")
    params, history = RT.train_ranker(
        system.params, system.cfg, system.catalog, system.instruction,
        reqs[:n_train], gold[:n_train], steps=args.steps)
    for s, l in history:
        print(f"  step {s:4d}  loss {l:.4f}")

    print("rebuilding RcLLM caches with trained weights")
    corpus, seen = [], set()
    for r in hist:
        if r.user_id not in seen:
            corpus.append(r.history_tokens)
            seen.add(r.user_id)
    system = RcLLMSystem.build(params, system.cfg, system.catalog, corpus,
                               hist, k_instances=4)

    sel = SelectiveConfig(r_item=0.3, r_rev=0.3, window=16)
    res = {m: [] for m in ("full", "rcllm", "cacheblend", "epic")}
    for r, g in zip(reqs[n_train:], gold[n_train:]):
        for m in res:
            sc, _ = system.rank(r, m, sel)
            res[m].append(MET.ranks_from_scores(sc)[g])
    print(f"\nTable III (planted gold, {args.eval} held-out requests):")
    print(f"{'method':12s} {'HR@1':>6s} {'HR@3':>6s} {'HR@5':>6s} "
          f"{'MRR':>6s} {'NDCG@5':>7s}")
    for m, v in res.items():
        v = np.asarray(v)
        print(f"{m:12s} {MET.hr_at_k(v, 1):6.3f} {MET.hr_at_k(v, 3):6.3f} "
              f"{MET.hr_at_k(v, 5):6.3f} {MET.mrr(v):6.3f} "
              f"{MET.ndcg_at_k(v, 5):7.3f}")
    print(f"\ntotal: {time.time() - t0:.0f}s")


if __name__ == "__main__":
    main()
