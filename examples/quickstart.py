"""Quickstart: build an RcLLM system end-to-end and serve one request.

    PYTHONPATH=src python examples/quickstart.py

Walks the full pipeline on CPU: synthetic catalog/reviews → offline phase
(LSH semantic pool + item-KV precompute + Algorithm-1 placement) → online
phase (affinity routing → assembly plan → selective-recompute prefill) →
ranked candidates, compared against the Full-Recompute oracle.
"""
import numpy as np

from repro.core.engine import SelectiveConfig
from repro.core.metrics import ranking_agreement_ndcg
from repro.core.rcllm import make_tiny_system
from repro.data import synth as SY


def main():
    print("== offline phase: building RcLLM caches ==")
    system, pool, prof, hist = make_tiny_system(n_items=120,
                                                n_requests_hist=60,
                                                k_instances=4)
    print(f"  semantic prototypes : {system.semantic.n_prototypes}")
    print(f"  semantic pool bytes : {system.semantic.size_bytes():,}")
    print(f"  hot replicas        : {len(system.placement.hot_items)}")
    print(f"  placement edge cut  : {system.placement.edge_cut:.0f}")
    per_replica = [s.n_tokens() for s in system.item_store.shards]
    print(f"  item tokens/replica : {per_replica}")

    print("== online phase: one request ==")
    req = SY.make_trace(system.catalog, pool, prof, 1, qps=1.0, n_users=3,
                        n_candidates=8, reviews_per_user=2, seed=7)[0]
    inst = system.best_instance(req)
    plan = system.plan_for(req, inst)
    print(f"  routed to instance  : {inst}")
    print(f"  prompt tokens       : {plan.n}")
    print(f"  beyond-prefix reuse : {plan.reuse_fraction():.1%} "
          f"(local={plan.n_local} remote={plan.n_remote} miss={plan.n_miss})")

    sel = SelectiveConfig(r_item=0.3, r_rev=0.3, window=16)
    scores, stats = system.rank(req, "rcllm", sel)
    full, _ = system.rank(req, "full")
    print(f"  recomputed tokens   : {stats.n_recomputed}/{stats.n_tokens} "
          f"({stats.recompute_fraction():.1%}), "
          f"{stats.n_heavy_hitters} heavy hitters")
    print(f"  RcLLM ranking       : {np.argsort(-scores).tolist()}")
    print(f"  Full  ranking       : {np.argsort(-full).tolist()}")
    print(f"  fidelity NDCG@5     : "
          f"{ranking_agreement_ndcg(full, scores, k=5):.4f}")


if __name__ == "__main__":
    main()
