"""Disaggregated prefill/decode serving benchmark: migration vs re-prefill.

One heavy-tail trace (`serving/workload.heavy_tail_trace`) runs through
the two-worker jax cluster twice — unified (both workers admit and
decode) and disaggregated (`disagg.prefill_workers=1,decode_workers=1`,
every multi-step request migrates its KV from the prefill worker to the
decode worker over the block-store transport).  Decoded tokens must be
identical (`token_parity`, gated at 0.99 by check_regression; asserted
== 1.0 on full runs) — disaggregation is a placement change, not a
numerics change.

The relay question RelayGR/MTServe pose is *what a handoff costs*: a
decode stage can take over a request either by importing the prefill
stage's KV bytes (migration) or by recomputing the prefill from the
prompt (re-prefill).  The second half of the bench measures both, per
request, with wall clocks: `mig_s` times a `jax.device_put` of exactly
the bytes `migration_bytes` says would travel (private pages + store
payloads whose content key misses on the destination — digest hits ride
for free, the beyond-prefix fast path), mirroring the measured
`ShardClient.pull` billing the cluster uses; `reprefill_s` times the
same request's full chunked prefill on a warm engine.  Charging each
discipline's handoff latency ahead of first-token delivery gives the
relay TTFT distributions whose p99 ratio
(`p99_ttft_reprefill_vs_migration`) is the headline: moving a few
megabytes of KV beats re-running the model over hundreds of prompt
tokens.

Emits the standard ``name,us_per_call,derived`` CSV rows plus
``disagg.json`` in `out_dir`; ``--quick`` shrinks the trace (CI).
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from benchmarks.common import emit
from repro.core.rcllm import make_tiny_system
from repro.serving import api as API
from repro.serving.batch_engine import BatchEngine, migration_bytes
from repro.serving.block_store import SharedBlockStore
from repro.serving.cluster import ClusterEngine
from repro.serving.kv_pool import pool_for
from repro.serving.workload import heavy_tail_trace, rcllm_batch_requests

POOL_PAGES = 1024
LONG_PROMPT_FRAC = 0.4
CHUNK_TOKENS = 256


def _ttfts(report):
    out = {}
    for c in report.completions:
        out[c.rid] = c.first_token_s - c.arrival_s
    return out


def _stats(vals):
    arr = np.asarray(sorted(vals))
    return {
        "ttft_p50_s": float(np.percentile(arr, 50)),
        "ttft_p99_s": float(np.percentile(arr, 99)),
        "ttft_mean_s": float(arr.mean()),
    }


def _run_cluster(system, trace, disagg, decode_steps):
    cfg = API.ServeConfig(
        engine="jax",
        k=2,
        sched="chunked",
        kv_reuse=True,
        n_pages=POOL_PAGES,
        chunk_tokens=CHUNK_TOKENS,
        disagg=disagg,
    )
    return ClusterEngine(system, cfg).run(trace, decode_steps=decode_steps)


def _mk_engine(system):
    pool = pool_for(system.cfg, n_pages=POOL_PAGES)
    return BatchEngine(
        system.params,
        system.cfg,
        pool=pool,
        store=SharedBlockStore(pool),
        chunk_tokens=CHUNK_TOKENS,
    )


def _prefill_chunked(eng, req):
    """Full chunked prefill of one request on `eng`. -> seconds."""
    t0 = time.perf_counter()
    eng.begin_prefill(req)
    while req.rid in eng.prefill_states:
        eng.step(10_000, [], [], [req.rid])
    return time.perf_counter() - t0


def _handoff_economics(system, trace):
    """Measured per-request handoff cost: KV transfer vs recompute."""
    import jax

    eng_src = _mk_engine(system)  # the prefill stage
    eng_dst = _mk_engine(system)  # the decode stage (import target)
    eng_rep = _mk_engine(system)  # the re-prefill counterfactual
    reqs = rcllm_batch_requests(system, trace, n_reserve=2)
    # warm pass: jax jit caches by shape globally, so after the source
    # prefills everything once, the re-prefill timings below are pure
    # recompute — the comparison is deliberately generous to re-prefill
    mig_s, reprefill_s, moved_mb, digest_hits = [], [], [], 0
    for req in reqs:
        _prefill_chunked(eng_src, req)
        rec = eng_src.export_request_kv(req.rid)
        # exactly the bytes the content-addressed transport would move:
        # private pages always, store payloads only on a digest miss
        store_d = eng_dst.store
        moved = [rec.export.page_k, rec.export.page_v]
        for key, payload in rec.payloads.items():
            if store_d is None or not store_d.has(key):
                moved += [payload.host_k, payload.host_v]
        assert sum(a.nbytes for a in moved) == migration_bytes(rec, store_d)
        t0 = time.perf_counter()
        staged = jax.device_put(moved)
        jax.block_until_ready(staged)
        mig = time.perf_counter() - t0
        counters = eng_dst.import_request_kv(rec)
        digest_hits += counters["digest_hits"]
        moved_mb.append(counters["bytes"] / 1e6)
        rep = _prefill_chunked(eng_rep, req)
        mig_s.append(mig)
        reprefill_s.append(rep)
        for eng in (eng_src, eng_dst, eng_rep):
            eng.release(req.rid)
    return (
        {r.rid: s for r, s in zip(reqs, mig_s)},
        {r.rid: s for r, s in zip(reqs, reprefill_s)},
        float(np.mean(moved_mb)),
        digest_hits,
    )


def run(out_dir: str = "results/bench", quick: bool = False) -> None:
    os.makedirs(out_dir, exist_ok=True)
    n_req = 10 if quick else 20
    decode_steps = 4

    system, pool_rv, prof, _ = make_tiny_system(
        n_items=60, n_requests_hist=30, k_instances=2, n_layers=4, d_model=32
    )
    trace = heavy_tail_trace(
        system.catalog,
        pool_rv,
        prof,
        n_req,
        qps=60.0,
        n_users=n_req,
        long_prompt_frac=LONG_PROMPT_FRAC,
        long_prompt_reviews=6,
        seed=5,
    )

    rep_uni = _run_cluster(system, trace, API.DisaggConfig(), decode_steps)
    rep_dis = _run_cluster(
        system,
        trace,
        API.DisaggConfig(prefill_workers=1, decode_workers=1),
        decode_steps,
    )
    gen_uni = {r: tuple(t) for r, t in rep_uni.generated.items()}
    gen_dis = {r: tuple(t) for r, t in rep_dis.generated.items()}
    parity = float(
        np.mean([gen_uni[r] == gen_dis.get(r) for r in gen_uni])
    )
    dec = rep_dis.workers[1]
    ttft_uni, ttft_dis = _ttfts(rep_uni), _ttfts(rep_dis)

    mig_s, reprefill_s, moved_mb, digest_hits = _handoff_economics(
        system, trace
    )
    # relay TTFT: first-token delivery with each handoff discipline's
    # measured latency charged ahead of it (migration ships KV bytes;
    # re-prefill recomputes the prompt on the decode stage)
    relay_mig = [ttft_dis[r] + mig_s[r] for r in ttft_dis]
    relay_rep = [ttft_dis[r] + reprefill_s[r] for r in ttft_dis]
    p99_mig = float(np.percentile(relay_mig, 99))
    p99_rep = float(np.percentile(relay_rep, 99))

    out = {
        "requests": n_req,
        "long_prompt_frac": LONG_PROMPT_FRAC,
        "chunk_tokens": CHUNK_TOKENS,
        "decode_steps": decode_steps,
        "protocol": "unified vs disagg(1+1) on one heavy-tail trace; "
        "handoff economics measured per request (device_put of the "
        "exact migration bytes vs full chunked re-prefill on a warm "
        "engine), charged ahead of first-token delivery",
        "token_parity": parity,
        "unified": _stats(ttft_uni.values()),
        "disagg": {
            **_stats(ttft_dis.values()),
            "migrations": dec.migrations,
            "migrated_pages": dec.migrated_pages,
            "migration_mbytes": round(dec.migration_bytes / 1e6, 3),
            "migration_s": round(dec.migration_s, 6),
            "migration_digest_hits": dec.migration_digest_hits,
        },
        "p99_ttft_vs_unified": float(
            np.percentile(list(ttft_uni.values()), 99)
            / max(np.percentile(list(ttft_dis.values()), 99), 1e-9)
        ),
        "handoff": {
            "mig_p50_s": float(np.percentile(list(mig_s.values()), 50)),
            "mig_p99_s": float(np.percentile(list(mig_s.values()), 99)),
            "reprefill_p50_s": float(
                np.percentile(list(reprefill_s.values()), 50)
            ),
            "reprefill_p99_s": float(
                np.percentile(list(reprefill_s.values()), 99)
            ),
            "moved_mbytes_mean": round(moved_mb, 3),
            "digest_hits": digest_hits,
        },
        "relay_ttft_p99_migration_s": p99_mig,
        "relay_ttft_p99_reprefill_s": p99_rep,
        "p99_ttft_reprefill_vs_migration": p99_rep / max(p99_mig, 1e-9),
    }
    emit(
        "disagg/unified",
        out["unified"]["ttft_p99_s"] * 1e6,
        f"ttft_mean={out['unified']['ttft_mean_s']:.4f}s",
    )
    emit(
        "disagg/disagg",
        out["disagg"]["ttft_p99_s"] * 1e6,
        f"migrations={dec.migrations} "
        f"moved={out['disagg']['migration_mbytes']:.2f}MB "
        f"digest_hits={dec.migration_digest_hits} "
        f"parity={parity:.2f}",
    )
    emit(
        "disagg/handoff",
        out["handoff"]["mig_p99_s"] * 1e6,
        f"reprefill_p99={out['handoff']['reprefill_p99_s']:.4f}s "
        f"relay_speedup={out['p99_ttft_reprefill_vs_migration']:.2f}x",
    )
    assert parity == 1.0, (
        "disaggregation changed decoded tokens (must be bitwise equal): "
        f"parity={parity:.3f}"
    )
    if not quick:
        assert out["p99_ttft_reprefill_vs_migration"] > 1.0, (
            "migrating KV must beat re-prefilling it on relay p99 TTFT: "
            f"{out['p99_ttft_reprefill_vs_migration']:.3f}x"
        )

    with open(os.path.join(out_dir, "disagg.json"), "w") as f:
        json.dump(out, f, indent=1)


if __name__ == "__main__":
    run(quick=True)
