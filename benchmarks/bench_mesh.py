"""Tensor-parallel serving benchmark: TTFT / decode step time vs mesh.tp.

The same heavy-tail trace streams through the chunked-scheduler jax
engine unsharded and then on a real mesh at each tensor-parallel degree
(``--config mesh.tp=N``), with decoded tokens compared against the
unsharded run (``token_parity`` — gated at 1.0-ish by
``check_regression``; tp=1 on an explicit (1, 1) mesh must be bitwise).

Honesty note: these numbers come from FORCED HOST DEVICES — one CPU
carved into 8 XLA devices.  Every "device" shares the same socket, so
tp>1 pays GSPMD's all-reduces without any extra FLOP throughput and is
*expected to be slower* than tp=1 here.  The benchmark pins the cost
surface and the token-parity invariant, not a speedup: on a real
multi-chip backend the same config is where the TP win would appear.

Forcing host devices only works BEFORE jax initializes, and
``benchmarks.run`` imports jax long before this module; ``run()``
therefore re-executes itself as a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` when the current
process cannot see enough devices.

Emits the standard ``name,us_per_call,derived`` CSV rows plus
``mesh.json`` in `out_dir`; ``--quick`` shrinks the sweep (CI).
"""
from __future__ import annotations

import os
import sys

N_DEVICES = 8
_FLAG = f"--xla_force_host_platform_device_count={N_DEVICES}"
_FLAG_KEY = "--xla_force_host_platform_device_count"

if (
    __name__ == "__main__"
    and "jax" not in sys.modules
    and _FLAG_KEY not in os.environ.get("XLA_FLAGS", "")
):
    # direct invocation: grab the devices while we still can
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") + " " + _FLAG).strip()

import json  # noqa: E402
import subprocess  # noqa: E402
import time  # noqa: E402

import numpy as np  # noqa: E402

POOL_PAGES = 512
DECODE_STEPS = 4
LONG_PROMPT_FRAC = 0.3


def _stats(ttfts, tbts, wall):
    ttft = np.concatenate(ttfts)
    tbt = np.asarray(tbts)
    return {
        "ttft_mean_s": float(ttft.mean()),
        "ttft_p50_s": float(np.percentile(ttft, 50)),
        "ttft_p99_s": float(np.percentile(ttft, 99)),
        "decode_step_mean_s": float(tbt.mean()) if tbt.size else None,
        "decode_step_p99_s": float(np.percentile(tbt, 99)) if tbt.size else None,
        "wall_s_per_pass": float(np.mean(wall)),
    }


def _serve(system, pend, plans, mesh_cfg, measured):
    """1 warm + `measured` passes of the trace on one engine. -> (stats,
    decoded tokens as plain ints)."""
    from repro.serving import api as API

    scfg = API.ServeConfig(
        engine="jax",
        sched="chunked",
        n_pages=POOL_PAGES,
        decode_steps=DECODE_STEPS,
        mesh=mesh_cfg,
    )
    engine = API.build_engine(system.params, system.cfg, scfg)
    backend = API.build_backend(engine, scfg, plans=plans)
    ttfts, tbts, wall = [], [], []
    for i in range(1 + measured):
        batcher = API.build_batcher(backend, scfg)
        t0 = time.perf_counter()
        done = batcher.run(list(pend))
        dt = time.perf_counter() - t0
        if i == 0:
            continue
        done = sorted(done, key=lambda c: c.rid)
        ttfts.append(np.asarray([c.first_token_s - c.arrival_s for c in done]))
        tbts.extend(batcher.workers[0].tbt)
        wall.append(dt)
    gen = {rid: [int(t) for t in toks] for rid, toks in backend.generated.items()}
    return _stats(ttfts, tbts, wall), gen


def _measure(out_dir: str, quick: bool) -> None:
    import jax

    from benchmarks.common import emit
    from repro.core.rcllm import make_tiny_system
    from repro.serving.api import MeshConfig
    from repro.serving.workload import heavy_tail_trace, rcllm_workload

    tps = [1, 2] if quick else [1, 2, 4]
    n_req = 8 if quick else 16
    measured = 1 if quick else 2
    assert len(jax.devices()) >= max(tps), "run() spawns with XLA_FLAGS set"

    system, pool_rv, prof, _ = make_tiny_system(
        n_items=60,
        n_requests_hist=30,
        k_instances=2,
        n_layers=2,
        d_model=32,
        n_heads=4,
        n_kv_heads=4,
    )
    trace = heavy_tail_trace(
        system.catalog,
        pool_rv,
        prof,
        n_req,
        qps=60.0,
        n_users=n_req,
        long_prompt_frac=LONG_PROMPT_FRAC,
        long_prompt_reviews=6,
        seed=5,
    )
    pend, plans = rcllm_workload(system, trace, decode_steps=DECODE_STEPS)

    ref_stats, ref_gen = _serve(system, pend, plans, MeshConfig(), measured)
    emit(
        "mesh/unsharded",
        ref_stats["ttft_mean_s"] * 1e6,
        f"ttft_p99={ref_stats['ttft_p99_s']:.4f}s",
    )

    per_tp = {}
    parities = []
    for tp in tps:
        mesh_cfg = MeshConfig(mesh_shape=(1, 1)) if tp == 1 else MeshConfig(tp=tp)
        stats, gen = _serve(system, pend, plans, mesh_cfg, measured)
        parity = float(np.mean([gen[r] == ref_gen[r] for r in ref_gen]))
        stats["token_parity"] = parity
        stats["ttft_vs_unsharded"] = stats["ttft_mean_s"] / max(
            ref_stats["ttft_mean_s"], 1e-9
        )
        per_tp[str(tp)] = stats
        parities.append(parity)
        emit(
            f"mesh/tp{tp}",
            stats["ttft_mean_s"] * 1e6,
            f"ttft_p99={stats['ttft_p99_s']:.4f}s "
            f"vs_unsharded={stats['ttft_vs_unsharded']:.2f}x "
            f"token_parity={parity:.2f}",
        )

    out = {
        "requests": n_req,
        "decode_steps": DECODE_STEPS,
        "measured_passes": measured,
        "host_devices": len(jax.devices()),
        "backend": jax.devices()[0].platform,
        "note": "forced host devices share one CPU: tp>1 pays GSPMD "
        "all-reduces with no added FLOP throughput, so slowdowns vs "
        "tp=1 are expected here; the gates pin cost + token parity, "
        "not a speedup",
        "unsharded": ref_stats,
        "tp": per_tp,
        "token_parity": min(parities),
    }
    assert out["token_parity"] == 1.0, (
        f"sharding changed decoded tokens (parity={out['token_parity']})"
    )
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, "mesh.json"), "w") as f:
        json.dump(out, f, indent=1)


def run(out_dir: str = "results/bench", quick: bool = False) -> None:
    """Entry point for ``benchmarks.run``.  jax is already initialized
    (single host device) by the time this runs, so the sweep executes in
    a child process that forces the device count first."""
    need = 2 if quick else 4
    if "jax" in sys.modules:
        import jax

        if len(jax.devices()) >= need:
            _measure(out_dir, quick)
            return
    env = dict(os.environ)
    if _FLAG_KEY not in env.get("XLA_FLAGS", ""):
        env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") + " " + _FLAG).strip()
    cmd = [sys.executable, "-m", "benchmarks.bench_mesh", "--out", out_dir]
    if quick:
        cmd.append("--quick")
    res = subprocess.run(cmd, env=env)
    if res.returncode:
        raise RuntimeError(f"bench_mesh subprocess failed ({res.returncode})")


def main(argv=None) -> int:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--out", default="results/bench")
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args(argv)
    _measure(args.out, args.quick)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
