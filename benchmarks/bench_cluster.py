"""Cluster serving benchmark: dispatch policies over real JAX engines.

One synthetic trace runs through the `serving.cluster.ClusterEngine`
(K workers, each a real `BatchEngine` + paged pool + Algorithm-1 item
shard) once per dispatch policy — Eq. 2 affinity vs round-robin vs
least-loaded — so the policies are compared on *real* TTFT, real
per-worker item-cache hit rates and real (cost-modeled, ledgered)
cross-shard transfers, not the analytic simulator.

Emits the standard ``name,us_per_call,derived`` CSV rows plus
``cluster.json`` in `out_dir`; ``--quick`` shrinks the trace (CI).
"""
from __future__ import annotations

import json
import os

from benchmarks.common import emit
from repro.core.rcllm import make_tiny_system
from repro.data import synth as SY
from repro.serving.api import ServeConfig
from repro.serving.cluster import ClusterEngine

POLICIES = ("affinity", "round_robin", "least_loaded")


def run(out_dir: str = "results/bench", quick: bool = False) -> None:
    os.makedirs(out_dir, exist_ok=True)
    k = 2 if quick else 4
    n_req = 8 if quick else 24
    decode_steps = 2 if quick else 4

    system, pool_rv, prof, _ = make_tiny_system(
        n_items=80, n_requests_hist=60, k_instances=k, n_layers=2, d_model=32
    )
    trace = SY.make_trace(
        system.catalog,
        pool_rv,
        prof,
        n_req,
        qps=6.0,
        n_users=max(3, n_req // 2),
        n_candidates=8,
        reviews_per_user=1,
        seed=7,
        cluster_bias=0.85,
    )

    out = {"k": k, "requests": n_req, "policies": {}}
    for policy in POLICIES:
        # two passes per policy: the first warms the jit caches at every
        # shape bucket, the second is measured
        scfg = ServeConfig(engine="jax", k=k, policy=policy)
        for _ in range(2):
            rep = ClusterEngine(system, scfg).run(trace, decode_steps=decode_steps)
        s = rep.summary()
        s["per_worker_hit_rate"] = [
            round(w.mean_hit_rate, 4) if w.mean_hit_rate is not None else None
            for w in rep.workers
        ]
        s["per_worker_requests"] = [w.n_requests for w in rep.workers]
        s["decoded_tokens"] = sum(len(g) for g in rep.generated.values())
        out["policies"][policy] = s
        emit(
            f"cluster/{policy}",
            s["ttft_p50_s"] * 1e6,
            f"mean_hit={s['mean_hit_rate']:.3f} "
            f"xfer_blocks={s['transfer_blocks']}",
        )

    pol = out["policies"]
    out["affinity_hit_gain_vs_round_robin"] = round(
        pol["affinity"]["mean_hit_rate"] - pol["round_robin"]["mean_hit_rate"],
        4,
    )
    # dispatch moves requests, never tokens: every policy must have decoded
    # the same measured total (the parity tests pin the stronger
    # per-request property)
    counts = {p: pol[p]["decoded_tokens"] for p in POLICIES}
    assert len(set(counts.values())) == 1, counts

    with open(os.path.join(out_dir, "cluster.json"), "w") as f:
        json.dump(out, f, indent=1)


if __name__ == "__main__":
    run(quick=True)
