"""Serving-path benchmark: sim-engine vs jax-engine TTFT / throughput.

The same request set runs through the `ContinuousBatcher` twice — once
over the analytic cost model (virtual clock, the simulator's engine) and
once over the real batched JAX engine with the paged KV pool (wall
clock) — in both full-recompute and rcllm (beyond-prefix selective)
modes.  Latency is reported as p50/p99 TTFT plus time-between-tokens
percentiles, not just central tendency — scheduler work lives in the
tail.  Emits the standard ``name,us_per_call,derived`` CSV rows plus a
JSON artifact in `out_dir`.

Flags (via benchmarks/run.py): ``--quick`` shrinks the request count.
"""
from __future__ import annotations

import json
import os

import numpy as np

from benchmarks.common import emit
from repro.core import cost_model as CM
from repro.core.rcllm import make_tiny_system
from repro.data import synth as SY
from repro.serving import api as API
from repro.serving.batching import ContinuousBatcher, PendingRequest
from repro.serving.workload import rcllm_workload


def _summarize(done, workers=None, generated=None):
    ttft = np.asarray([c.first_token_s - c.arrival_s for c in done])
    total = max(c.done_s for c in done) - min(c.arrival_s for c in done)
    n_tok = sum(len(generated[c.rid]) for c in done) if generated else len(done)
    out = {
        "ttft_p50_s": float(np.percentile(ttft, 50)),
        "ttft_p90_s": float(np.percentile(ttft, 90)),
        "ttft_p99_s": float(np.percentile(ttft, 99)),
        "ttft_mean_s": float(ttft.mean()),
        "throughput_per_s": n_tok / max(total, 1e-9),
    }
    tbt = [dt for w in (workers or []) for dt in w.tbt]
    if tbt:
        out["tbt_p50_s"] = float(np.percentile(tbt, 50))
        out["tbt_p99_s"] = float(np.percentile(tbt, 99))
    return out


def run(out_dir: str = "results/bench", quick: bool = False) -> None:
    os.makedirs(out_dir, exist_ok=True)
    n_req = 6 if quick else 16
    decode_steps = 3 if quick else 4

    system, pool_rv, prof, _ = make_tiny_system(
        n_items=60, n_requests_hist=30, k_instances=2, n_layers=2, d_model=32
    )
    cfg = system.cfg
    trace = SY.make_trace(
        system.catalog,
        pool_rv,
        prof,
        n_req,
        qps=4.0,
        n_users=max(3, n_req // 2),
        n_candidates=8,
        reviews_per_user=1,
        seed=9,
    )
    pend, plans = rcllm_workload(system, trace, decode_steps=decode_steps)

    out = {}
    # --- sim engine: analytic cost model on the virtual clock ---
    for mode in ("full", "rcllm"):

        def prefill_t(tok, _m=mode):
            if _m == "full":
                return CM.full_prefill_ttft_s(cfg, CM.V5E_1, tok)
            return CM.prefill_time_s(cfg, CM.V5E_1, tok, int(0.4 * tok))

        batcher = ContinuousBatcher(
            prefill_t, lambda n: CM.decode_step_time_s(cfg, CM.V5E_1, n)
        )
        done = batcher.run(
            [
                PendingRequest(r.arrival_s, r.rid, r.n_tokens, r.decode_steps)
                for r in pend
            ]
        )
        s = _summarize(done, batcher.workers)
        s["throughput_req_s"] = s.pop("throughput_per_s")
        out[f"sim/{mode}"] = s
        emit(
            f"serving/sim/{mode}",
            s["ttft_p50_s"] * 1e6,
            f"ttft_p90={s['ttft_p90_s']:.4f}s",
        )

    # --- jax engine: real batched prefill + paged decode, wall clock ---
    for mode in ("full", "rcllm"):
        # three passes over the same workload: the first warms the jit
        # caches, the second warms the *steady-state* shape buckets (a
        # fast clock composes different prefill batches than the
        # compile-heavy first pass), the third is measured — without
        # this, trace/compile time dominates sub-ms steps on tiny models
        scfg = API.ServeConfig(engine="jax", mode=mode)
        for _pass in range(3):
            engine = API.build_engine(system.params, cfg, scfg)
            backend = API.build_backend(
                engine, scfg, plans=plans if mode == "rcllm" else {}
            )
            batcher = API.build_batcher(backend, scfg)
            done = batcher.run(list(pend))
        s = _summarize(done, batcher.workers, backend.generated)
        s["throughput_tok_s"] = s.pop("throughput_per_s")
        s["pool_peak_pages"] = engine.pool.peak_pages
        out[f"jax/{mode}"] = s
        emit(
            f"serving/jax/{mode}",
            s["ttft_p50_s"] * 1e6,
            f"ttft_p90={s['ttft_p90_s']:.4f}s "
            f"tok_per_s={s['throughput_tok_s']:.2f}",
        )

    with open(os.path.join(out_dir, "serving.json"), "w") as f:
        json.dump(out, f, indent=1)
