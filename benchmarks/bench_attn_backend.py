"""Attention-backend benchmark: jnp vs pallas-interpret, batched vs loop.

Two comparisons on the real serving engine (tiny CPU model):

* **backend** — the same batched rcllm prefill and one paged decode
  iteration timed under ``attn_backend="jnp"`` (masked-einsum reference)
  and ``attn_backend="pallas"`` (flash/selective kernels through the
  Pallas *interpreter* — CPU has no Mosaic lowering, so this measures
  the seam's overhead off-TPU, not kernel speed; on TPU the same code
  path compiles for real).

* **batched_prefill** — the beyond-prefix selective prefill as one
  bucketed batched step (`engine.selective_prefill_batch` + the fused
  pool scatter) vs the legacy per-request loop, at growing batch sizes.
  Requests are drawn from one (padded length) bucket — the composition
  the continuous batcher produces under load and the case batching
  exists for; the batched path amortizes layer-0 dispatch, host scoring
  rounds, the selective-layer dispatch and the arena copies across the
  bucket.  The JSON asserts it is strictly faster at batch 4 (the CI
  regression guard reads this artifact).

Emits the standard ``name,us_per_call,derived`` CSV rows plus
``attn_backend.json`` in `out_dir`; ``--quick`` shrinks repeats (CI).
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from collections import Counter

import numpy as np

from benchmarks.common import emit
from repro.core.rcllm import make_tiny_system
from repro.data import synth as SY
from repro.serving.batch_engine import BatchEngine
from repro.serving.kv_pool import pool_for
from repro.serving.workload import rcllm_batch_requests

DECODE_STEPS = 2


def _best_of(fn, repeats: int) -> float:
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return min(ts)


def _engine(system, backend: str, batched: bool) -> BatchEngine:
    cfg = dataclasses.replace(system.cfg, attn_backend=backend)
    return BatchEngine(
        system.params,
        cfg,
        pool=pool_for(cfg, n_pages=512),
        bucket=64,
        batched_selective=batched,
    )


def _prefill_pass(eng: BatchEngine, brs) -> None:
    eng.prefill(brs, mode="rcllm")
    for r in brs:
        eng.release(r.rid)


def run(out_dir: str = "results/bench", quick: bool = False) -> None:
    os.makedirs(out_dir, exist_ok=True)
    repeats = 3 if quick else 6
    batches = (1, 4) if quick else (1, 2, 4)

    system, pool_rv, prof, _ = make_tiny_system(
        n_items=60, n_requests_hist=30, k_instances=2, n_layers=2, d_model=32
    )
    trace = SY.make_trace(
        system.catalog,
        pool_rv,
        prof,
        3 * max(batches),
        qps=4.0,
        n_users=6,
        n_candidates=8,
        reviews_per_user=1,
        seed=13,
    )
    # one shape bucket: keep the requests whose padded length lands in
    # the trace's most common 64-token bucket, so a batch really stacks
    # into one jitted step (the composition continuous batching forms
    # under load — heterogeneous batches split across buckets and are
    # measured end-to-end by bench_serving instead)
    all_brs = rcllm_batch_requests(system, trace, n_reserve=DECODE_STEPS)
    pads = [-(-r.plan.n // 64) * 64 for r in all_brs]
    bucket_pad = Counter(pads).most_common(1)[0][0]
    brs = [r for r, p in zip(all_brs, pads) if p == bucket_pad]
    assert len(brs) >= max(batches), (len(brs), bucket_pad)
    out = {"quick": quick, "decode_steps": DECODE_STEPS, "backend": {}}

    # --- jnp vs pallas-interpret: batched rcllm prefill + one decode ---
    bsz = min(4, max(batches))
    for backend in ("jnp", "pallas"):
        eng = _engine(system, backend, batched=True)
        _prefill_pass(eng, brs[:bsz])               # warm the jit caches
        prefill_s = _best_of(lambda: _prefill_pass(eng, brs[:bsz]), repeats)
        logits = eng.prefill(brs[:bsz], mode="rcllm")
        rids = [r.rid for r in brs[:bsz]]
        last = [int(np.argmax(lg)) for lg in logits]
        eng.decode(rids, last)                      # warm decode shapes
        decode_s = _best_of(lambda: eng.decode(rids, last), repeats)
        for r in brs[:bsz]:
            eng.release(r.rid)
        out["backend"][backend] = {
            "prefill_batch%d_s" % bsz: prefill_s,
            "decode_step_s": decode_s,
        }
        emit(
            f"attn_backend/{backend}",
            prefill_s * 1e6,
            f"decode_step_us={decode_s * 1e6:.1f}",
        )
    jnp_s = out["backend"]["jnp"]["prefill_batch%d_s" % bsz]
    pallas_s = out["backend"]["pallas"]["prefill_batch%d_s" % bsz]
    out["pallas_interpret_over_jnp_prefill"] = round(pallas_s / jnp_s, 3)

    # --- batched rcllm prefill vs the per-request loop ---
    out["batched_prefill"] = {}
    for bsz in batches:
        eng_b = _engine(system, "jnp", batched=True)
        eng_l = _engine(system, "jnp", batched=False)
        _prefill_pass(eng_b, brs[:bsz])
        _prefill_pass(eng_l, brs[:bsz])
        t_batched = _best_of(lambda: _prefill_pass(eng_b, brs[:bsz]), repeats)
        t_loop = _best_of(lambda: _prefill_pass(eng_l, brs[:bsz]), repeats)
        speedup = t_loop / t_batched
        out["batched_prefill"][str(bsz)] = {
            "loop_s": t_loop,
            "batched_s": t_batched,
            "speedup": round(speedup, 3),
        }
        emit(
            f"attn_backend/batched_b{bsz}",
            t_batched * 1e6,
            f"loop_us={t_loop * 1e6:.1f} speedup={speedup:.2f}",
        )
        # the acceptance bar: batching must pay for itself at batch 4.
        # Full runs (the committed artifact) demand strictly > 1; quick
        # CI runs on noisy shared runners get a slack bar that still
        # catches a structurally slower batched path.
        bar = 0.85 if quick else 1.0
        assert bsz < 4 or speedup > bar, (
            f"batched rcllm prefill slower than the per-request loop at "
            f"batch {bsz}: {t_batched:.4f}s vs {t_loop:.4f}s "
            f"(speedup {speedup:.2f} <= {bar})"
        )
    out["batched_speedup_at_4"] = out["batched_prefill"]["4"]["speedup"]

    with open(os.path.join(out_dir, "attn_backend.json"), "w") as f:
        json.dump(out, f, indent=1)


if __name__ == "__main__":
    run(quick=True)
