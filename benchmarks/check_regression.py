"""Bench-smoke regression guard.

CI's bench-smoke job used to only *upload* the quick-run artifacts; this
turns them into a gate: the fresh quick-run numbers are compared against
the committed full-run baselines in ``results/bench/*.json`` and the job
fails on regression instead of silently archiving one.

Quick runs are smaller than the committed full runs (fewer requests, so
less queueing) and CI machines vary, hence the *generous* tolerances:

* ``time`` metrics (lower is better) may be up to ``--time-slack`` times
  the baseline;
* ``rate`` metrics (higher is better, already in [0, 1]) may drop at
  most ``--rate-slack`` absolutely;
* ``floor`` metrics must stay above an absolute bar regardless of the
  baseline (e.g. batched-prefill speedup > 1: batching must never
  regress into being slower than the per-request loop);
* ``max`` metrics must stay *below* an absolute ceiling (accuracy-style
  deltas where growth is the regression, e.g. the int8 store's fidelity
  drop vs the committed tableIII baseline).

A metric whose file or key is missing from the *baseline* is skipped
(new benchmarks adopt the guard on their first committed artifact); a
file missing from the *current* run fails — the smoke didn't produce
what it was asked for.

Usage (what CI runs)::

    PYTHONPATH=src python -m benchmarks.run \\
        --only serving,cluster,attn_backend --quick --out /tmp/bench
    PYTHONPATH=src python -m benchmarks.check_regression \\
        --baseline results/bench --current /tmp/bench
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from dataclasses import dataclass
from typing import Optional, Tuple


@dataclass(frozen=True)
class Metric:
    file: str
    path: Tuple[str, ...]
    kind: str  # "time" | "rate" | "floor" | "max"
    floor: float = 0.0  # the bar for kind="floor" (>) and kind="max" (<=)

    @property
    def name(self) -> str:
        return f"{self.file}:{'.'.join(self.path)}"


METRICS = (
    Metric("serving.json", ("jax/full", "ttft_p50_s"), "time"),
    Metric("serving.json", ("jax/rcllm", "ttft_p50_s"), "time"),
    Metric("cluster.json", ("policies", "affinity", "ttft_p50_s"), "time"),
    Metric("cluster.json", ("policies", "affinity", "mean_hit_rate"), "rate"),
    Metric("cluster.json", ("affinity_hit_gain_vs_round_robin",), "rate"),
    Metric("attn_backend.json", ("batched_prefill", "4", "batched_s"), "time"),
    # the committed full-run artifact shows > 1; quick runs on shared
    # runners get timing noise, so the guard's bar is the structural one
    Metric("attn_backend.json", ("batched_speedup_at_4",), "floor", floor=0.85),
    Metric("reuse.json", ("on", "ttft_mean_s"), "time"),
    # user-tier hits are workload-deterministic (repeat users always
    # hit); the item tier's rate depends on LRU churn under the store
    # budget, too volatile to gate
    Metric("reuse.json", ("on", "user_hit_rate"), "rate"),
    # committed full runs show well over 1x (reuse buys admission
    # capacity, so deferred waves vanish); the quick bar only guards
    # against reuse structurally regressing into a slowdown
    Metric("reuse.json", ("mean_ttft_speedup",), "floor", floor=0.9),
    # unified-step scheduler: tail latency, not just means.  The pooled
    # p99 win comes from the closed chunk-shape set (wave keeps hitting
    # fresh batch-composition compiles); quick bars guard the structure
    Metric("serving.json", ("jax/rcllm", "ttft_p99_s"), "time"),
    Metric("chunked.json", ("chunked", "ttft_p99_s"), "time"),
    # the committed full run shows ~4x (and bench_chunked asserts > 1.0
    # on every full run); quick runs on shared runners swing hard, so
    # the bars only guard against chunked structurally regressing into
    # a slowdown
    Metric("chunked.json", ("p99_ttft_speedup",), "floor", floor=0.9),
    # decode never waits out a prefill wave — committed full run ~2.3x
    # (runs swing up to ~17x: wave's TBT tail is its wave duration)
    Metric("chunked.json", ("tbt_p99_speedup",), "floor", floor=1.2),
    # paged-decode kernel: step time gated per kernel against its own
    # committed baseline (the CPU paged path runs the Pallas interpreter,
    # so gather-vs-paged ratios mean nothing off-TPU), plus a hard floor
    # on greedy-token agreement with the gather oracle
    Metric("paged_decode.json", ("gather", "decode_step_s"), "time"),
    Metric("paged_decode.json", ("paged", "decode_step_s"), "time"),
    Metric("paged_decode.json", ("token_parity",), "floor", floor=0.5),
    # open-loop session server: wall-clock latency is runner-dependent,
    # so the gates are structural — at the lowest offered rate the SLO
    # must hold, and open-loop scheduling must never change decoded
    # tokens (composition invariance; bench_openloop also asserts ==1.0)
    Metric("openloop.json", ("rates", "4qps", "attainment"), "rate"),
    Metric("openloop.json", ("rates", "4qps", "ttft_p50_s"), "time"),
    Metric("openloop.json", ("token_parity",), "floor", floor=0.99),
    # mesh serving on forced host devices: per-tp step cost gated against
    # its own baseline (tp>1 is *slower* here — one CPU carved into 8
    # XLA devices pays GSPMD all-reduces with no added FLOPs, so a
    # vs-tp1 ratio gate would be meaningless), plus a hard floor on
    # decoded-token agreement with the unsharded engine
    Metric("mesh.json", ("tp", "1", "ttft_mean_s"), "time"),
    Metric("mesh.json", ("tp", "2", "ttft_mean_s"), "time"),
    Metric("mesh.json", ("token_parity",), "floor", floor=0.99),
    # disaggregated serving: role-splitting must never change decoded
    # tokens (bench_disagg also asserts == 1.0), and migrating KV bytes
    # must beat re-prefilling them on relay p99 TTFT with the measured
    # transfer billing included; the disagg cluster's own tail is gated
    # against its committed baseline, and the vs-unified ratio only
    # guards structural collapse (1 prefill + 1 decode worker trades
    # peak throughput for tail isolation, so parity is not guaranteed)
    Metric("disagg.json", ("token_parity",), "floor", floor=0.99),
    Metric("disagg.json", ("disagg", "ttft_p99_s"), "time"),
    Metric(
        "disagg.json", ("p99_ttft_reprefill_vs_migration",), "floor",
        floor=1.0,
    ),
    Metric("disagg.json", ("p99_ttft_vs_unified",), "floor", floor=0.4),
    # tiered store at catalog >> arena capacity: spilling evicted blocks
    # to host RAM must keep producing store hits where drop-on-evict
    # misses, and fp32 spill mode must never change decoded tokens
    # (bench_tiered also asserts == 1.0); int8 trades exactness for
    # capacity, so its token agreement gets a floor and its ranking-
    # fidelity *drop* vs the committed tableIII rcllm accuracy gets a
    # ceiling.  Spill TTFT is gated against its own committed baseline.
    Metric("tiered.json", ("token_parity_fp32",), "floor", floor=0.999),
    # int8 rounding can legitimately flip near-tied greedy tokens on the
    # tiny random-init bench model (observed 0.83-1.0 across configs);
    # ranking fidelity below is the real accuracy gate
    Metric("tiered.json", ("token_parity_int8",), "floor", floor=0.5),
    # absolute floor, not a vs-baseline rate: the hit rate scales with
    # the trace's revisit fraction (quick 4/12 revisits ~0.4, full
    # 32/40 ~0.8), so a baseline-relative drop gate would fail quick
    # runs by construction
    Metric(
        "tiered.json", ("spill_fp32", "spill_hit_rate"), "floor", floor=0.2
    ),
    Metric("tiered.json", ("spill_fp32", "ttft_mean_s"), "time"),
    Metric("tiered.json", ("int8_fidelity_drop",), "max", floor=0.02),
)


def _load(dirname: str, fname: str) -> Optional[dict]:
    p = os.path.join(dirname, fname)
    if not os.path.exists(p):
        return None
    with open(p) as f:
        return json.load(f)


def _dig(doc: dict, path: Tuple[str, ...]):
    cur = doc
    for key in path:
        if not isinstance(cur, dict) or key not in cur:
            return None
        cur = cur[key]
    return cur


def check(
    baseline_dir: str, current_dir: str, time_slack: float, rate_slack: float
) -> int:
    """Compare current quick-run artifacts against the baselines.
    Prints one line per metric; -> number of failures."""
    failures = 0
    cur_docs, base_docs = {}, {}
    for m in METRICS:
        if m.file not in base_docs:
            base_docs[m.file] = _load(baseline_dir, m.file)
            cur_docs[m.file] = _load(current_dir, m.file)
        base_doc, cur_doc = base_docs[m.file], cur_docs[m.file]
        if base_doc is None:
            print(f"SKIP  {m.name}: no committed baseline")
            continue
        base = _dig(base_doc, m.path)
        if base is None:
            print(f"SKIP  {m.name}: metric absent from baseline")
            continue
        if cur_doc is None:
            print(f"FAIL  {m.name}: {m.file} missing from current run")
            failures += 1
            continue
        cur = _dig(cur_doc, m.path)
        if cur is None:
            print(f"FAIL  {m.name}: metric missing from current run")
            failures += 1
            continue
        if m.kind == "time":
            ok = cur <= base * time_slack
            detail = (
                f"current={cur:.6g}s baseline={base:.6g}s "
                f"(allowed <= {time_slack:g}x)"
            )
        elif m.kind == "rate":
            ok = cur >= base - rate_slack
            detail = (
                f"current={cur:.4g} baseline={base:.4g} "
                f"(allowed drop <= {rate_slack:g})"
            )
        elif m.kind == "max":
            ok = cur <= m.floor
            detail = f"current={cur:.4g} (must stay <= {m.floor:g})"
        else:  # floor
            ok = cur > m.floor
            detail = f"current={cur:.4g} (must stay > {m.floor:g})"
        print(f"{'ok   ' if ok else 'FAIL '} {m.name}: {detail}")
        failures += 0 if ok else 1
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument(
        "--baseline",
        default="results/bench",
        help="committed full-run artifacts",
    )
    ap.add_argument(
        "--current", required=True, help="fresh quick-run artifacts to vet"
    )
    ap.add_argument(
        "--time-slack",
        type=float,
        default=4.0,
        help="time metrics may be up to this x baseline",
    )
    ap.add_argument(
        "--rate-slack",
        type=float,
        default=0.15,
        help="rate metrics may drop at most this (absolute)",
    )
    args = ap.parse_args(argv)
    failures = check(args.baseline, args.current, args.time_slack, args.rate_slack)
    if failures:
        print(f"{failures} benchmark regression(s) vs {args.baseline}")
        return 1
    print("no benchmark regressions")
    return 0


if __name__ == "__main__":
    sys.exit(main())
