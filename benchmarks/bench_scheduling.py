"""Fig. 10: scheduling policy vs load — affinity vs Hit-Only vs Load-Only
vs round-robin, mean TTFT under rising QPS."""
from __future__ import annotations

import json
import os

from benchmarks.common import emit
from repro.configs import registry as REG
from repro.core import cost_model as CM
from repro.core import simulator as SIM


def run(out_dir: str = "results/bench", quick: bool = False) -> None:
    os.makedirs(out_dir, exist_ok=True)
    cfg = REG.ARCHS["rcllm-qwen3-8b"]
    k = 8
    loads = [10, 30] if quick else [10, 20, 30, 40, 60]
    out = {}
    for qps in loads:
        reqs, placement, _ = SIM.make_sim_setup(
            k=k, n_requests=800, qps=float(qps), n_items=4000, seed=30)
        row = {}
        for pol in ("affinity", "hit_only", "load_only", "round_robin"):
            res = SIM.simulate(cfg, CM.V5E_1, reqs, placement,
                               SIM.SimConfig(mode="rcllm", policy=pol))
            row[pol] = {"mean": float(res.ttft_s.mean()),
                        "hit": float(res.hit_rates.mean())}
            emit(f"fig10/qps={qps}/{pol}", 0.0,
                 f"mean={row[pol]['mean']:.3f}s hit={row[pol]['hit']:.3f}")
        out[qps] = row
    with open(os.path.join(out_dir, "fig10_scheduling.json"), "w") as f:
        json.dump(out, f, indent=1)
