"""Open-loop serving benchmark: Poisson arrivals vs the wall clock.

Every other serving benchmark is *closed-loop*: the whole trace is
handed to `ContinuousBatcher.run` and the scheduler's virtual clock
decides what "latency" means.  This one drives the same heavy-tail
trace through `serving.server.AsyncSessionServer` as real wall-clock
traffic: `server.replay(..., speed=s)` sleeps the trace's Poisson
arrival gaps (divided by ``s``), so submissions race the scheduler
exactly like production ingress.  Sweeping ``s`` sweeps the offered
rate, which turns per-request wall TTFT into the paper-style
*SLO-attainment curve*: the fraction of requests whose first token
lands inside ``SLO_TTFT_S``, per offered rate — flat at 1.0 while the
server keeps up, collapsing once the queue outruns service capacity.

Two guarantees are asserted, not just reported:

* **token parity** — open-loop admission order and batch composition
  differ from the closed-loop run, but per-request compute is
  composition-invariant (the cross-cutting property of PRs 1-6), so
  every session must decode tokens bitwise identical to the
  closed-loop reference;
* the engine gets ONE closed-loop warm pass before the sweep so jit
  compilation (the chunked shape set closes after one pass — see
  bench_chunked) is not billed to the first open-loop requests.

Emits the standard ``name,us_per_call,derived`` CSV rows plus
``openloop.json`` in `out_dir`; ``--quick`` shrinks the trace (CI).
"""
from __future__ import annotations

import json
import os

import numpy as np

from benchmarks.common import emit
from repro.core.rcllm import make_tiny_system
from repro.serving import api as API
from repro.serving.server import serve_trace
from repro.serving.workload import heavy_tail_trace, rcllm_workload

POOL_PAGES = 1024
LONG_PROMPT_FRAC = 0.4
BASE_QPS = 4.0          # trace-stamp rate; offered rate = BASE_QPS * speed
SPEEDS = (1.0, 4.0, 16.0)  # identical in --quick so baseline keys line up
SLO_TTFT_S = 2.0        # generous: shared CI runners, interpreted kernels


def _rate_key(speed: float) -> str:
    return f"{BASE_QPS * speed:g}qps"


def run(out_dir: str = "results/bench", quick: bool = False) -> None:
    os.makedirs(out_dir, exist_ok=True)
    n_req = 8 if quick else 16
    decode_steps = 3

    system, pool_rv, prof, _ = make_tiny_system(
        n_items=60, n_requests_hist=30, k_instances=2, n_layers=4, d_model=32
    )
    trace = heavy_tail_trace(
        system.catalog,
        pool_rv,
        prof,
        n_req,
        qps=BASE_QPS,
        n_users=n_req,
        long_prompt_frac=LONG_PROMPT_FRAC,
        long_prompt_reviews=6,
        seed=5,
    )
    pend, plans = rcllm_workload(system, trace, decode_steps=decode_steps)

    scfg = API.ServeConfig(
        engine="jax",
        sched="chunked",
        n_pages=POOL_PAGES,
    )
    engine = API.build_engine(system.params, system.cfg, scfg)
    backend = API.build_backend(engine, scfg, plans=plans)

    # closed-loop warm pass: compiles the chunked shape set AND pins the
    # reference token streams the open-loop runs must reproduce
    API.build_batcher(backend, scfg).run(list(pend))
    reference = {rid: tuple(toks) for rid, toks in backend.generated.items()}

    submits = [
        (
            p.arrival_s,
            API.SubmitRequest(
                rid=p.rid,
                tokens=p.tokens,
                max_tokens=p.decode_steps,
                context=plans.get(p.rid),
            ),
        )
        for p in pend
    ]

    rates = {}
    token_parity = 1.0
    for speed in SPEEDS:
        completions, server = serve_trace(backend, scfg, submits, speed=speed)
        ttft = np.asarray([c.ttft_s for c in completions.values()])
        parity = float(
            np.mean([completions[rid].tokens == reference[rid] for rid in reference])
        )
        token_parity = min(token_parity, parity)
        attainment = float(np.mean(ttft <= SLO_TTFT_S))
        key = _rate_key(speed)
        rates[key] = {
            "offered_qps": BASE_QPS * speed,
            "attainment": attainment,
            "ttft_p50_s": float(np.percentile(ttft, 50)),
            "ttft_p99_s": float(np.percentile(ttft, 99)),
            "ttft_mean_s": float(ttft.mean()),
            "preempted": server.worker.preempted,
            "completed": server.metrics.completed,
            "token_parity": parity,
        }
        emit(
            f"openloop/{key}",
            rates[key]["ttft_p99_s"] * 1e6,
            f"attainment={attainment:.2f} "
            f"ttft_p50={rates[key]['ttft_p50_s']:.4f}s parity={parity:.2f}",
        )

    assert token_parity == 1.0, (
        "open-loop serving changed decoded tokens vs the closed-loop "
        f"reference (parity={token_parity:.3f}; per-request compute must "
        "be composition-invariant)"
    )

    out = {
        "requests": n_req,
        "decode_steps": decode_steps,
        "long_prompt_frac": LONG_PROMPT_FRAC,
        "base_qps": BASE_QPS,
        "slo_ttft_s": SLO_TTFT_S,
        "sched": scfg.sched,
        "protocol": "1 closed-loop warm pass (jit + reference tokens), "
        "then one open-loop wall-clock replay per offered rate; "
        "attainment = fraction of requests with TTFT <= slo_ttft_s",
        "token_parity": token_parity,
        "rates": rates,
    }
    with open(os.path.join(out_dir, "openloop.json"), "w") as f:
        json.dump(out, f, indent=1)


if __name__ == "__main__":
    run(quick=True)
