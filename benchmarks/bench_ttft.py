"""Fig. 6: TTFT CDF at K=40 instances — RcLLM vs Prefix-Cache vs
Full-Recompute, for the 8B-class (single-chip instances) and 72B-class
(TP=4 instances) cost models, across the three dataset profiles."""
from __future__ import annotations

import json
import os


from benchmarks.common import emit, time_call
from repro.configs import registry as REG
from repro.configs.base import LMConfig
from repro.core import cost_model as CM
from repro.core import simulator as SIM

QWEN72B = LMConfig(name="qwen-72b", n_layers=80, d_model=8192, n_heads=64,
                   n_kv_heads=8, head_dim=128, d_ff=29568,
                   vocab_size=152064, mlp_type="swiglu")


def run(out_dir: str = "results/bench", k: int = 40, n_requests: int = 1500,
        quick: bool = False) -> None:
    os.makedirs(out_dir, exist_ok=True)
    cfgs = {"qwen3-8b": (REG.ARCHS["rcllm-qwen3-8b"], CM.V5E_1, 30.0),
            "qwen-72b": (QWEN72B, CM.V5E_TP4, 12.0)}
    profiles = ["amazon"] if quick else ["amazon", "yelp", "goodreads"]
    results = {}
    for prof in profiles:
        reqs, placement, _ = SIM.make_sim_setup(
            profile_name=prof, k=k, n_requests=n_requests,
            qps=30.0 * k / 8, n_items=4000, seed=10)
        for mname, (cfg, hw, _q) in cfgs.items():
            row = {}
            for mode in ("rcllm", "prefix", "full"):
                us = time_call(lambda m=mode, c=cfg, h=hw: SIM.simulate(
                    c, h, reqs, placement, SIM.SimConfig(mode=m)), repeats=1)
                res = SIM.simulate(cfg, hw, reqs, placement,
                                   SIM.SimConfig(mode=mode))
                row[mode] = res.summary()
                emit(f"fig6/{prof}/{mname}/{mode}", us,
                     f"p50={row[mode]['p50']:.3f}s p99={row[mode]['p99']:.3f}s")
            for pct in ("p50", "p99"):
                sp = row["prefix"][pct] / row["rcllm"][pct]
                emit(f"fig6/{prof}/{mname}/speedup_{pct}", 0.0, f"{sp:.2f}x")
            results[f"{prof}/{mname}"] = row
    with open(os.path.join(out_dir, "fig6_ttft.json"), "w") as f:
        json.dump(results, f, indent=1)
