"""Unified token-budget scheduler benchmark: --sched wave vs chunked.

One heavy-tail trace (`serving/workload.heavy_tail_trace`: a fraction
of users carries a lognormal pile of extra reviews, so long prompts mix
with short ones — the long-sequence head-of-line shape RelayGR/MTServe
target) streams through the single-instance jax engine under both
scheduling disciplines.  Decoded tokens must be bitwise identical
(chunked prefill is a scheduling change, not a numerics change —
asserted here and pinned by tests/test_chunked.py).

Protocol: each discipline gets ONE identical warm pass, then
``measured`` passes over the same trace; the reported distributions
pool every measured request.  This deliberately measures *serving*
steady state rather than *microbenchmark* steady state: the wave
scheduler keeps discovering new (n_pad, r_pad, batch) jit compositions
for several passes after warmup — every new batch mix is a fresh
compile, the recompilation hazard CHANGES.md flags — while the chunked
step's shape set (fixed chunk widths, B=1 finalizes, pow2 decode) is
closed after one pass.  Production traffic never repeats a
composition, so the pooled distribution is the representative one;
``steady_*`` keys report each discipline's best single pass for
transparency (at exhaustive warmth the two run TTFT-comparable, and
chunked keeps the large time-between-tokens win from never stalling
decode behind a prefill wave).

Emits the standard ``name,us_per_call,derived`` CSV rows plus
``chunked.json`` in `out_dir`; ``--quick`` shrinks the trace (CI).
"""
from __future__ import annotations

import json
import os

import numpy as np

from benchmarks.common import emit
from repro.core.rcllm import make_tiny_system
from repro.serving import api as API
from repro.serving.workload import heavy_tail_trace, rcllm_workload

POOL_PAGES = 1024
LONG_PROMPT_FRAC = 0.4
CHUNK_TOKENS = 256
STEP_TOKENS = 2048


def _serve(system, pend, plans, sched, measured):
    """1 warm + `measured` passes of one discipline on one engine."""
    scfg = API.ServeConfig(
        engine="jax",
        sched=sched,
        n_pages=POOL_PAGES,
        chunk_tokens=CHUNK_TOKENS,
        step_tokens=STEP_TOKENS,
    )
    engine = API.build_engine(system.params, system.cfg, scfg)
    backend = API.build_backend(engine, scfg, plans=plans)
    ttfts, tbts, ticks, oversized = [], [], 0, 0
    steady = None
    for i in range(1 + measured):
        batcher = API.build_batcher(backend, scfg)
        done = batcher.run(list(pend))
        ttft = np.asarray(
            [
                c.first_token_s - c.arrival_s
                for c in sorted(done, key=lambda c: c.rid)
            ]
        )
        if i == 0:
            continue
        w = batcher.workers[0]
        ttfts.append(ttft)
        tbts.extend(w.tbt)
        ticks += len(w.ticks)
        oversized += sum(1 for t in w.ticks if t.oversized)
        if steady is None or ttft.mean() < steady.mean():
            steady = ttft
    pooled = np.concatenate(ttfts)
    tbt = np.asarray(tbts)
    stats = {
        "ttft_mean_s": float(pooled.mean()),
        "ttft_p50_s": float(np.percentile(pooled, 50)),
        "ttft_p99_s": float(np.percentile(pooled, 99)),
        "tbt_p50_s": float(np.percentile(tbt, 50)),
        "tbt_p99_s": float(np.percentile(tbt, 99)),
        "steady_ttft_mean_s": float(steady.mean()),
        "steady_ttft_p99_s": float(np.percentile(steady, 99)),
    }
    if sched == "chunked":
        stats["ticks"] = ticks
        stats["oversized_ticks"] = oversized
    return stats, backend.generated


def run(out_dir: str = "results/bench", quick: bool = False) -> None:
    os.makedirs(out_dir, exist_ok=True)
    n_req = 12 if quick else 20
    measured = 2 if quick else 3
    decode_steps = 4

    system, pool_rv, prof, _ = make_tiny_system(
        n_items=60, n_requests_hist=30, k_instances=2, n_layers=4, d_model=32
    )
    trace = heavy_tail_trace(
        system.catalog,
        pool_rv,
        prof,
        n_req,
        qps=60.0,
        n_users=n_req,
        long_prompt_frac=LONG_PROMPT_FRAC,
        long_prompt_reviews=6,
        seed=5,
    )
    pend, plans = rcllm_workload(system, trace, decode_steps=decode_steps)

    wave, gen_wave = _serve(system, pend, plans, "wave", measured)
    chunked, gen_chunk = _serve(system, pend, plans, "chunked", measured)

    identical = gen_wave == gen_chunk
    assert identical, "sched changed decoded tokens (must be bitwise equal)"

    out = {
        "requests": n_req,
        "long_prompt_frac": LONG_PROMPT_FRAC,
        "chunk_tokens": CHUNK_TOKENS,
        "step_tokens": STEP_TOKENS,
        "decode_steps": decode_steps,
        "measured_passes": measured,
        "protocol": "1 warm pass each; distributions pool all measured "
        "passes (wave keeps compiling new batch compositions after "
        "warmup; the chunked shape set closes after one pass)",
        "decoded_identical": identical,
        "wave": wave,
        "chunked": chunked,
        "p99_ttft_speedup": wave["ttft_p99_s"] / max(chunked["ttft_p99_s"], 1e-9),
        "mean_ttft_speedup": wave["ttft_mean_s"] / max(chunked["ttft_mean_s"], 1e-9),
        "tbt_p99_speedup": wave["tbt_p99_s"] / max(chunked["tbt_p99_s"], 1e-9),
    }
    emit(
        "chunked/wave",
        wave["ttft_p99_s"] * 1e6,
        f"ttft_mean={wave['ttft_mean_s']:.4f}s tbt_p99={wave['tbt_p99_s']:.4f}s",
    )
    emit(
        "chunked/chunked",
        chunked["ttft_p99_s"] * 1e6,
        f"ttft_mean={chunked['ttft_mean_s']:.4f}s "
        f"tbt_p99={chunked['tbt_p99_s']:.4f}s "
        f"p99_speedup={out['p99_ttft_speedup']:.2f}x "
        f"tbt_speedup={out['tbt_p99_speedup']:.2f}x",
    )
    if not quick:
        assert out["p99_ttft_speedup"] > 1.0, (
            "chunked must improve p99 TTFT on the heavy-tail trace: "
            f"{out['p99_ttft_speedup']:.3f}x"
        )
        assert out["tbt_p99_speedup"] > 1.0, (
            "chunked must improve p99 TBT (decode never waits out a "
            f"prefill wave): {out['tbt_p99_speedup']:.3f}x"
        )

    with open(os.path.join(out_dir, "chunked.json"), "w") as f:
        json.dump(out, f, indent=1)


if __name__ == "__main__":
    run(quick=True)
