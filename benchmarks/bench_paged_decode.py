"""Paged-decode kernel benchmark: fused Pallas kernel vs jnp gather.

Times one batched greedy decode step on the real serving engine (tiny
CPU model, rcllm prefill) under both decode kernels:

* ``decode_kernel="gather"`` — the jnp oracle: materialize every
  request's K/V with a full ``(N, S, L, Hkv, Dh)`` arena gather, then
  masked attention;
* ``decode_kernel="paged"`` — the fused Pallas paged-attention kernel
  reading the arena through per-request page views (BlockSpec index
  maps), run through the Pallas *interpreter* on CPU.  Off-TPU this
  measures the seam's overhead, not kernel speed — on TPU the same
  path compiles for real and skips the gather's HBM round-trip.

Both engines decode the same requests; the artifact records the greedy
token sequences' agreement (``token_parity``), which the run asserts
and the CI regression guard floors — a silently diverging kernel fails
the bench before it fails a user.

Emits the standard ``name,us_per_call,derived`` CSV rows plus
``paged_decode.json`` in `out_dir`; ``--quick`` shrinks repeats (CI).
"""
from __future__ import annotations

import dataclasses
import json
import os
import time

import numpy as np

from benchmarks.common import emit
from repro.core.rcllm import make_tiny_system
from repro.data import synth as SY
from repro.serving.batch_engine import BatchEngine
from repro.serving.kv_pool import pool_for
from repro.serving.workload import rcllm_batch_requests


def _best_of(fn, repeats: int) -> float:
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return min(ts)


def run(out_dir: str = "results/bench", quick: bool = False) -> None:
    os.makedirs(out_dir, exist_ok=True)
    repeats = 3 if quick else 6
    steps = 3 if quick else 6
    bsz = 4

    system, pool_rv, prof, _ = make_tiny_system(
        n_items=60, n_requests_hist=30, k_instances=2, n_layers=2, d_model=32
    )
    trace = SY.make_trace(
        system.catalog,
        pool_rv,
        prof,
        bsz,
        qps=4.0,
        n_users=4,
        n_candidates=8,
        reviews_per_user=1,
        seed=29,
    )
    brs = rcllm_batch_requests(system, trace, n_reserve=steps + repeats + 2)
    out = {"quick": quick, "batch": bsz, "decode_steps": steps}

    toks = {}
    for kern in ("gather", "paged"):
        cfg = dataclasses.replace(system.cfg, decode_kernel=kern)
        eng = BatchEngine(
            system.params, cfg, pool=pool_for(cfg, n_pages=512), bucket=64
        )
        logits = eng.prefill(brs, mode="rcllm")
        rids = [r.rid for r in brs]
        last = [int(np.argmax(lg)) for lg in logits]
        seq = []
        for _ in range(steps):            # greedy run doubles as jit warmup
            step_logits = eng.decode(rids, last)
            last = [int(np.argmax(row)) for row in step_logits]
            seq.append(tuple(last))
        toks[kern] = seq
        decode_s = _best_of(lambda: eng.decode(rids, last), repeats)
        out[kern] = {"decode_step_s": decode_s}
        emit(
            f"paged_decode/{kern}",
            decode_s * 1e6,
            f"batch={bsz} steps={steps}",
        )

    # the acceptance bar: the kernel must decode the gather path's exact
    # greedy tokens — timing is environment-dependent, correctness is not
    assert toks["gather"] == toks["paged"], (
        "paged decode kernel diverged from the jnp gather oracle: "
        f"{toks['gather']} vs {toks['paged']}"
    )
    out["token_parity"] = 1.0
    out["paged_over_gather"] = round(
        out["paged"]["decode_step_s"] / out["gather"]["decode_step_s"], 3
    )

    with open(os.path.join(out_dir, "paged_decode.json"), "w") as f:
        json.dump(out, f, indent=1)


if __name__ == "__main__":
    run(quick=True)
