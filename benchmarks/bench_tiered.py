"""Tiered quantized KV store benchmark: spill tier vs drop-on-evict.

One Zipf repeat-user trace over a catalog whose KV footprint is several
times the device arena runs through the two-worker jax cluster three
times, varying only the store config:

  * drop-on-evict (``StoreConfig()``): LRU eviction discards block
    bytes; a re-touched evicted item pays the cross-shard pull and
    re-enters admission with its full private-page bound;
  * spill fp32 (``store.spill_mb`` + ``store.prefetch_pages_per_tick``):
    evicted blocks demote to host RAM, the router's ``_bind`` hints the
    destination store pre-admission (the Eq. 2 scheduler knows the
    worker before the request queues), and the chunked tick's budgeted
    prefetch promotes them back to device pages — so the readmitted
    request maps those positions at shared slots instead of claiming
    private pages;
  * spill int8: same, with item/user-tier bytes held as per-(row,
    kv-head)-scaled int8 (prefix stays fp32).

Cross-shard item pulls are billed identically in every config on a
modeled disaggregated-pool fabric (see ``HW``): a bind that pulls
anything pays one network round-trip.  Drop-on-evict re-pays that trip
on every revisit whose blocks churned out of the device tier; the spill
tier (eviction demotions + write-around of admission-refused inserts)
answers the same revisit from host RAM.

fp32 spill mode must never change decoded tokens (``token_parity_fp32``,
asserted == 1.0 on every run — spilling is a capacity change, not a
numerics change); int8 trades exactness for ~4x tier capacity, so its
token agreement is reported (``token_parity_int8``, gated by
check_regression) and its ranking-fidelity cost is measured under the
tableIII protocol: NDCG@10 agreement with the Full-Recompute oracle,
before and after round-tripping the offline item + semantic KV through
the store's int8 codec (``int8_fidelity_drop``, ceiling-gated).

Emits the standard ``name,us_per_call,derived`` CSV rows plus
``tiered.json`` in `out_dir`; ``--quick`` shrinks the trace (CI).
"""
from __future__ import annotations

import dataclasses
import json
import os

import numpy as np

from benchmarks.common import emit
from repro.core import cost_model as CM
from repro.core import metrics as MET
from repro.core.engine import SelectiveConfig
from repro.core.rcllm import make_tiny_system
from repro.data import synth as SY
from repro.serving import api as API
from repro.serving import block_store as BS
from repro.serving.cluster import ClusterEngine
from repro.serving.workload import zipf_repeat_trace

POOL_PAGES = 96           # per-worker arena; store budget is half of it
CHUNK_TOKENS = 256
N_ITEMS = 600             # catalog KV footprint >= 4x the arena
N_CANDIDATES = 16
QPS = 6.0                 # spread arrivals: revisit binds see the
                          # post-churn store, not the t=0 snapshot
SPILL_MB = 24
PREFETCH_PAGES = 16
WORKING_SET_REQS = 8      # candidate sets revisit with this period

# All three configs bill cross-shard item pulls on a 10 Gbps / 25 ms
# RTT fabric — a disaggregated KV pool reaching across zones, not a
# co-located 100 Gbps LAN.  `ShardClient.pull` never caches remotely
# fetched blocks into the local shard, and `fetch_time_s` charges one
# RTT per bind that pulls anything, so under drop-on-evict every
# revisit whose blocks were evicted re-pays the hop; the spill tier
# serves the same bytes from host RAM and skips it.  That differential
# is a deterministic ledger of avoided round-trips — unlike the ~±10%
# wall noise on a sub-second CPU trace.
HW = CM.Hardware(network_bw=1.25e9, network_rtt=25e-3)


def _ttfts(report):
    out = {}
    for c in report.completions:
        out[c.rid] = c.first_token_s - c.arrival_s
    return out


def _stats(vals):
    arr = np.asarray(sorted(vals))
    return {
        "ttft_p50_s": float(np.percentile(arr, 50)),
        "ttft_p99_s": float(np.percentile(arr, 99)),
        "ttft_mean_s": float(arr.mean()),
    }


def _run(system, trace, store_cfg, decode_steps):
    """One cluster pass under `store_cfg`. -> (report, summed store stats)."""
    cfg = API.ServeConfig(
        engine="jax",
        k=2,
        sched="chunked",
        kv_reuse=True,
        # round_robin ablates the affinity router so the drop-vs-spill
        # comparison isolates the store tier: workers see a balanced
        # share, and the revisit period is a multiple of k, so a revisit
        # lands on the worker that served the original candidate set
        policy="round_robin",
        n_pages=POOL_PAGES,
        chunk_tokens=CHUNK_TOKENS,
        store=store_cfg,
    )
    eng = ClusterEngine(system, cfg, hw=HW)
    rep = eng.run(trace, decode_steps=decode_steps)
    agg = {}
    for backend in eng.backends:
        for k, v in backend.engine.store.stats().items():
            if isinstance(v, (int, float)):
                agg[k] = agg.get(k, 0) + v
    agg["transfers_avoided"] = sum(
        b.transfers_avoided for b in eng.backends
    )
    agg["transfer_seconds"] = sum(
        b.transfer_seconds for b in eng.backends
    )
    return rep, agg


def _int8_roundtrip(arr):
    return BS.dequantize_rows(*BS.quantize_rows(arr))


def _fidelity(system, reqs, sel):
    """Mean NDCG@10 of the rcllm ranking vs the Full-Recompute oracle."""
    fid = []
    for rq in reqs:
        full, _ = system.rank(rq, "full")
        sc, _ = system.rank(rq, "rcllm", sel)
        fid.append(MET.ranking_agreement_ndcg(full, sc, k=10))
    return float(np.mean(fid))


def _quantize_offline_caches(system):
    """Round-trip the offline item + semantic KV through the int8 codec
    in place — exactly the bytes the serving store's item/user tiers
    quantize (the recomputed prefix stays fp32 in both worlds)."""
    for shard in system.item_store.shards:
        for blk in shard.blocks.values():
            blk.k = _int8_roundtrip(blk.k)
            blk.v = _int8_roundtrip(blk.v)
    sc = system.semantic
    if sc is not None and sc.proto_k is not None:
        sc.proto_k = _int8_roundtrip(sc.proto_k)
        sc.proto_v = _int8_roundtrip(sc.proto_v)


def run(out_dir: str = "results/bench", quick: bool = False) -> None:
    os.makedirs(out_dir, exist_ok=True)
    n_req = 12 if quick else 40
    decode_steps = 4

    system, pool_rv, prof, _ = make_tiny_system(
        n_items=N_ITEMS,
        n_requests_hist=30,
        k_instances=2,
        n_layers=4,
        d_model=32,
    )
    catalog_tokens = int(
        sum(len(t) + 1 for t in system.catalog.item_tokens)
    )
    arena_tokens = POOL_PAGES * 16
    catalog_vs_arena = catalog_tokens / arena_tokens
    trace = zipf_repeat_trace(
        system.catalog,
        pool_rv,
        prof,
        n_req,
        qps=QPS,
        n_users=8,
        n_candidates=N_CANDIDATES,
        reviews_per_user=2,
        seed=7,
    )
    # periodic working-set sweep: candidate sets revisit with period
    # WORKING_SET_REQS, and one period's item KV overflows the store's
    # item budget — the LRU-thrash shape where drop-on-evict pays the
    # cross-shard pull and the full private admission bound on every
    # revisit, while the spill tier keeps the bytes one hint away
    trace = [
        r if i < WORKING_SET_REQS else dataclasses.replace(
            r, candidate_items=trace[i % WORKING_SET_REQS].candidate_items
        )
        for i, r in enumerate(trace)
    ]

    spill_cfg = API.StoreConfig(
        spill_mb=SPILL_MB, prefetch_pages_per_tick=PREFETCH_PAGES
    )
    int8_cfg = API.StoreConfig(
        kv_store_dtype="int8",
        spill_mb=SPILL_MB,
        prefetch_pages_per_tick=PREFETCH_PAGES,
    )
    # warm passes: jax jit caches by shape globally, but the chunk
    # compositions (and so the compiled shapes) each config reaches
    # depend on its own admission timeline — warm every config once so
    # the measured TTFTs come from admission capacity + staging, not
    # compilation order
    for cfg in (API.StoreConfig(), spill_cfg, int8_cfg):
        _run(system, trace, cfg, decode_steps)

    rep_drop, st_drop = _run(system, trace, API.StoreConfig(), decode_steps)
    rep_spill, st_spill = _run(system, trace, spill_cfg, decode_steps)
    rep_int8, st_int8 = _run(system, trace, int8_cfg, decode_steps)

    gen_drop = {r: tuple(t) for r, t in rep_drop.generated.items()}
    gen_spill = {r: tuple(t) for r, t in rep_spill.generated.items()}
    gen_int8 = {r: tuple(t) for r, t in rep_int8.generated.items()}
    parity_fp32 = float(
        np.mean([gen_drop[r] == gen_spill.get(r) for r in gen_drop])
    )
    parity_int8 = float(
        np.mean([gen_drop[r] == gen_int8.get(r) for r in gen_drop])
    )
    ttft_drop = _stats(_ttfts(rep_drop).values())
    ttft_spill = _stats(_ttfts(rep_spill).values())
    ttft_int8 = _stats(_ttfts(rep_int8).values())

    def tier_counters(st):
        return {
            "evictions": int(st["evictions"]),
            "spills": int(st["spills"]),
            "insert_spills": int(st["insert_spills"]),
            "spill_drops": int(st["spill_drops"]),
            "spill_hits": int(st["spill_hits"]),
            "prefetch_promotions": int(st["prefetch_promotions"]),
            "transfers_avoided": int(st["transfers_avoided"]),
            "spill_hit_rate": st["spill_hits"] / max(st["spills"], 1),
            "dequant_s": round(float(st["dequant_s"]), 6),
            "transfer_seconds": round(float(st["transfer_seconds"]), 6),
        }

    # int8 ranking fidelity under the tableIII protocol, measured on the
    # same system: fp32 caches first, then the in-place int8 round-trip
    sel = SelectiveConfig(r_item=0.3, r_rev=0.3, window=16)
    eval_reqs = SY.make_trace(
        system.catalog, pool_rv, prof, 8 if quick else 20, qps=5.0,
        n_users=12, n_candidates=10, reviews_per_user=2, seed=99,
    )
    fid_fp32 = _fidelity(system, eval_reqs, sel)
    _quantize_offline_caches(system)
    fid_int8 = _fidelity(system, eval_reqs, sel)
    fidelity_drop = fid_fp32 - fid_int8
    baseline_path = os.path.join("results", "bench", "tableIII_accuracy.json")
    table3 = None
    if os.path.exists(baseline_path):
        with open(baseline_path) as f:
            doc = json.load(f)
        table3 = doc.get("r=0.3", {}).get("rcllm", {}).get("fidelity_ndcg10")

    out = {
        "requests": n_req,
        "decode_steps": decode_steps,
        "n_items": N_ITEMS,
        "catalog_tokens": catalog_tokens,
        "arena_tokens": arena_tokens,
        "catalog_vs_arena": round(catalog_vs_arena, 3),
        "spill_mb": SPILL_MB,
        "prefetch_pages_per_tick": PREFETCH_PAGES,
        "working_set_reqs": WORKING_SET_REQS,
        "protocol": "one Zipf repeat-user trace whose candidate sets "
        "revisit with a period overflowing the store budget, two-worker "
        "round-robin chunked cluster, three store configs (drop-on-"
        "evict / spill fp32 / spill int8); cross-shard pulls are billed "
        "on a 10 Gbps / 25 ms-RTT disaggregated-pool fabric in every "
        "config, so the spill tier's avoided re-pull round-trips appear "
        "in TTFT deterministically; int8 ranking fidelity measured via "
        "the tableIII NDCG@10-vs-full protocol after an in-place int8 "
        "round-trip of the offline item + semantic KV",
        "token_parity_fp32": parity_fp32,
        "token_parity_int8": parity_int8,
        "drop": {**ttft_drop, **tier_counters(st_drop)},
        "spill_fp32": {**ttft_spill, **tier_counters(st_spill)},
        "spill_int8": {**ttft_int8, **tier_counters(st_int8)},
        "mean_ttft_drop_vs_spill": ttft_drop["ttft_mean_s"]
        / max(ttft_spill["ttft_mean_s"], 1e-9),
        "fidelity_ndcg10_fp32": fid_fp32,
        "fidelity_ndcg10_int8": fid_int8,
        "int8_fidelity_drop": fidelity_drop,
        "tableIII_baseline_ndcg10": table3,
    }
    emit(
        "tiered/drop",
        ttft_drop["ttft_mean_s"] * 1e6,
        f"evictions={out['drop']['evictions']} "
        f"transfers_avoided={out['drop']['transfers_avoided']}",
    )
    emit(
        "tiered/spill_fp32",
        ttft_spill["ttft_mean_s"] * 1e6,
        f"spill_hits={out['spill_fp32']['spill_hits']} "
        f"promotions={out['spill_fp32']['prefetch_promotions']} "
        f"hit_rate={out['spill_fp32']['spill_hit_rate']:.2f} "
        f"parity={parity_fp32:.2f}",
    )
    emit(
        "tiered/spill_int8",
        ttft_int8["ttft_mean_s"] * 1e6,
        f"parity={parity_int8:.2f} "
        f"fidelity_drop={fidelity_drop:.4f}",
    )
    assert parity_fp32 == 1.0, (
        "fp32 spill mode changed decoded tokens (must be bitwise equal): "
        f"parity={parity_fp32:.3f}"
    )
    assert st_drop["evictions"] > 0 and st_spill["spills"] > 0, (
        "catalog must overflow the store budget (no churn, no bench): "
        f"evictions={st_drop['evictions']} spills={st_spill['spills']}"
    )
    assert st_spill["spill_hits"] > 0, (
        "the Zipf trace must re-touch spilled blocks: "
        f"spill_hits={st_spill['spill_hits']}"
    )
    if not quick:
        assert catalog_vs_arena >= 4.0, (
            f"catalog must be >= 4x the arena: {catalog_vs_arena:.2f}x"
        )
        assert (
            st_spill["transfer_seconds"] < st_drop["transfer_seconds"]
        ), (
            "the spill tier must bill less cross-shard transfer time "
            "than drop-on-evict: "
            f"spill={st_spill['transfer_seconds']:.4f}s "
            f"drop={st_drop['transfer_seconds']:.4f}s"
        )
        assert (
            ttft_spill["ttft_mean_s"] <= ttft_drop["ttft_mean_s"]
        ), (
            "the spill tier must beat drop-on-evict on mean TTFT: "
            f"spill={ttft_spill['ttft_mean_s']:.4f}s "
            f"drop={ttft_drop['ttft_mean_s']:.4f}s"
        )

    with open(os.path.join(out_dir, "tiered.json"), "w") as f:
        json.dump(out, f, indent=1)


if __name__ == "__main__":
    run(quick=True)
