"""Fig. 11: latency cost of fidelity — TTFT distribution vs recompute
budget r (r_rev = r_item = r), K=40, vs the Prefix-Cache reference."""
from __future__ import annotations

import json
import os

from benchmarks.common import emit
from repro.configs import registry as REG
from repro.core import cost_model as CM
from repro.core import simulator as SIM


def run(out_dir: str = "results/bench", quick: bool = False) -> None:
    os.makedirs(out_dir, exist_ok=True)
    cfg = REG.ARCHS["rcllm-qwen3-8b"]
    k = 8 if quick else 40
    reqs, placement, _ = SIM.make_sim_setup(
        k=k, n_requests=1000, qps=3.5 * k, n_items=4000, seed=40)
    ratios = [0.1, 0.3, 0.8] if quick else [0.1, 0.2, 0.3, 0.5, 0.8]
    out = {}
    px = SIM.simulate(cfg, CM.V5E_1, reqs, placement,
                      SIM.SimConfig(mode="prefix"))
    out["prefix"] = px.summary()
    emit("fig11/prefix", 0.0, f"p50={px.pct(50):.3f}s p90={px.pct(90):.3f}s")
    prev_p50 = 0.0
    for r in ratios:
        res = SIM.simulate(cfg, CM.V5E_1, reqs, placement,
                           SIM.SimConfig(mode="rcllm", r_item=r, r_rev=r))
        out[f"r={r}"] = res.summary()
        emit(f"fig11/r={r}", 0.0,
             f"p50={res.pct(50):.3f}s p90={res.pct(90):.3f}s "
             f"speedup_p90={px.pct(90)/res.pct(90):.2f}x")
        assert res.pct(50) >= prev_p50 * 0.98   # CDF shifts right with r
        prev_p50 = res.pct(50)
    with open(os.path.join(out_dir, "fig11_recompute.json"), "w") as f:
        json.dump(out, f, indent=1)
