"""Cross-request KV reuse benchmark: the shared block store off vs on.

One repeat-user Zipfian trace (a handful of heavy users + the catalog's
own Zipf item popularity — the workload shape §III-A says dominates
generative recommendation) streams twice through the single-instance
jax engine as a pure TTFT workload (``decode_steps=1``: every request
completes at its first token, the paper's headline metric): once with
every request staging and recomputing privately, once against the
stratified shared block store (`serving/block_store.py`) at steady
state (warm caches).  The win is *compute*, not timer luck: a
prefix-tier hit feeds the stored instruction rows back as cached KV,
so the selective pass drops them from its recompute set — fewer
recomputed rows through layers 1..L-1 — on top of the skipped staging
writes and the admission-capacity credit.

Decoded tokens must be bitwise identical in both runs (the store maps
byte-equal pages and the dropped rows are byte-equal to their cached
copies; asserted here and pinned by tests/test_block_store).

Emits the standard ``name,us_per_call,derived`` CSV rows plus
``reuse.json`` in `out_dir`; ``--quick`` shrinks the trace (CI).
"""
from __future__ import annotations

import json
import os

import numpy as np

from benchmarks.common import emit
from repro.core.rcllm import make_tiny_system
from repro.serving import api as API
from repro.serving.kv_pool import pool_for
from repro.serving.workload import (
    rcllm_reuse_info,
    rcllm_workload,
    zipf_repeat_trace,
)

POOL_PAGES = 72
ZIPF_A = 1.3


def _warm_buckets(system, plans):
    """Compile every prefill shape the batcher can reach.

    Admission waves are wall-clock sensitive: two passes over the same
    trace can compose different prefill batches, so "warm then measure"
    alone still lets the measured pass hit a cold (n_pad, r_pad, B)
    bucket and book compile time as TTFT.  Instead, group the requests
    by their jit bucket and pre-run every power-of-two batch size a wave
    could form — on a throwaway big pool, since the prefill jits don't
    depend on arena shape.
    """
    from repro.serving.batch_engine import BatchEngine, BatchRequest
    from repro.serving.block_store import shape_bucket

    pool = pool_for(system.cfg, n_pages=2048)
    engine = BatchEngine(system.params, system.cfg, pool=pool)
    n_instr = len(system.instruction)
    groups = {}
    rid_gen = iter(range(10_000_000))
    for plan, ck, cv, have in plans.values():
        # the (n_pad, r_pad) jit bucket is deterministic from the plan
        # shape (shape_bucket), so every reachable compile is known
        # without running layer 0 — including the *prefix-hit* variant,
        # where the cached instruction shrinks the recompute set
        variants = [have]
        have_hit = have.copy()
        have_hit[:n_instr] = True
        variants.append(have_hit)
        for hv in variants:
            key = shape_bucket(plan, hv, engine.sel, engine.bucket)
            groups.setdefault(key, []).append(
                BatchRequest(
                    rid=next(rid_gen),
                    tokens=plan.tokens,
                    plan=plan,
                    cached_k=ck,
                    cached_v=cv,
                    have=hv,
                )
            )
    for reqs in groups.values():
        # every power-of-two batch size a wave could form in this bucket
        size = 1
        while True:
            engine.prefill(reqs[: min(size, len(reqs))], mode="rcllm")
            for r in reqs[: min(size, len(reqs))]:
                engine.release(r.rid)
            if size >= len(reqs):
                break
            size *= 2


def _run(system, pend, plans, reuse, kv_reuse: bool, measured: int = 3):
    """Steady-state serving: ONE engine (one pool, one store) serves the
    trace repeatedly — two warm passes fill the jit caches *and* the
    block store (steady state for a serving instance is warm caches),
    then `measured` passes keep the lowest mean TTFT (wave composition
    is wall-clock sensitive, so a single pass can catch a straggler —
    one late compile, one scheduler burp — that swamps the structural
    difference; min-of-N is the standard robust estimator and both
    modes get the same N).
    """
    scfg = API.ServeConfig(engine="jax", kv_reuse=kv_reuse, n_pages=POOL_PAGES)
    engine = API.build_engine(system.params, system.cfg, scfg)
    backend = API.build_backend(
        engine, scfg, plans=plans, reuse=reuse if kv_reuse else None
    )
    best = None
    for i in range(2 + measured):
        batcher = API.build_batcher(backend, scfg)
        done = batcher.run(list(pend))
        ttft = np.asarray(
            [
                c.first_token_s - c.arrival_s
                for c in sorted(done, key=lambda c: c.rid)
            ]
        )
        if i >= 2 and (best is None or ttft.mean() < best[0].mean()):
            best = (ttft, backend, engine)
    return best


def run(out_dir: str = "results/bench", quick: bool = False) -> None:
    os.makedirs(out_dir, exist_ok=True)
    n_req = 6 if quick else 14
    # TTFT is a prefill metric: requests complete at their first token,
    # so the measured quantity is the prefill stream itself (decode
    # parity has its own tests; scheduling noise has no decode phases
    # to hide in)
    decode_steps = 1

    system, pool_rv, prof, _ = make_tiny_system(
        n_items=60, n_requests_hist=30, k_instances=2, n_layers=4, d_model=32
    )
    trace = zipf_repeat_trace(
        system.catalog,
        pool_rv,
        prof,
        n_req,
        qps=200.0,
        n_users=max(3, n_req // 3),
        zipf_a=ZIPF_A,
        seed=5,
    )
    pend, plans = rcllm_workload(system, trace, decode_steps=decode_steps)
    reuse = rcllm_reuse_info(system, trace, plans)

    _warm_buckets(system, plans)
    ttft_off, b_off, _ = _run(system, pend, plans, reuse, kv_reuse=False)
    ttft_on, b_on, e_on = _run(system, pend, plans, reuse, kv_reuse=True)

    identical = all(b_off.generated[r] == b_on.generated[r] for r in b_off.generated)
    assert identical, "kv-reuse changed decoded tokens (must be bitwise off==on)"

    store = e_on.store.stats()
    hits_u, miss_u = store["hits_user"], store["misses_user"]
    hits_i, miss_i = store["hits_item"], store["misses_item"]
    out = {
        "requests": n_req,
        "pool_pages": POOL_PAGES,
        "zipf_a": ZIPF_A,
        "decode_steps": decode_steps,
        "decoded_identical": identical,
        "off": {
            "ttft_mean_s": float(ttft_off.mean()),
            "ttft_p50_s": float(np.percentile(ttft_off, 50)),
            "ttft_p90_s": float(np.percentile(ttft_off, 90)),
        },
        "on": {
            "ttft_mean_s": float(ttft_on.mean()),
            "ttft_p50_s": float(np.percentile(ttft_on, 50)),
            "ttft_p90_s": float(np.percentile(ttft_on, 90)),
            "user_hit_rate": hits_u / max(hits_u + miss_u, 1),
            "item_hit_rate": hits_i / max(hits_i + miss_i, 1),
            "block_store": store,
        },
        "mean_ttft_speedup": float(ttft_off.mean() / max(ttft_on.mean(), 1e-9)),
    }
    emit(
        "reuse/off",
        out["off"]["ttft_mean_s"] * 1e6,
        f"ttft_p50={out['off']['ttft_p50_s']:.4f}s",
    )
    emit(
        "reuse/on",
        out["on"]["ttft_mean_s"] * 1e6,
        f"user_hit={out['on']['user_hit_rate']:.3f} "
        f"item_hit={out['on']['item_hit_rate']:.3f} "
        f"speedup={out['mean_ttft_speedup']:.2f}x",
    )
    if not quick:
        assert out["mean_ttft_speedup"] > 1.0, (
            "kv-reuse must lower mean TTFT on the repeat-user workload: "
            f"{out['mean_ttft_speedup']:.3f}x"
        )

    with open(os.path.join(out_dir, "reuse.json"), "w") as f:
        json.dump(out, f, indent=1)


if __name__ == "__main__":
    run(quick=True)
