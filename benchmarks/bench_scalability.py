"""Fig. 8 + Fig. 9: speedup vs cluster size K, hit-rate vs K, and the
per-replica cached-item footprint vs K (similarity placement vs random)."""
from __future__ import annotations

import json
import os

import numpy as np

from benchmarks.common import emit
from repro.configs import registry as REG
from repro.core import cost_model as CM
from repro.core import scheduler as SCH
from repro.core import simulator as SIM


def run(out_dir: str = "results/bench", quick: bool = False) -> None:
    os.makedirs(out_dir, exist_ok=True)
    cfg = REG.ARCHS["rcllm-qwen3-8b"]
    ks = [1, 8, 20] if quick else [1, 20, 40, 80, 100]
    out = {}
    for k in ks:
        # load scales with K at ~0.6 utilization of the Full-Recompute
        # service rate, so queueing does not degenerate at K=1
        reqs, placement, catalog = SIM.make_sim_setup(
            k=max(k, 1), n_requests=800, qps=1.2 * max(k, 1),
            n_items=4000, seed=20)
        res_rc = SIM.simulate(cfg, CM.V5E_1, reqs, placement,
                              SIM.SimConfig(mode="rcllm"))
        res_px = SIM.simulate(cfg, CM.V5E_1, reqs, placement,
                              SIM.SimConfig(mode="prefix"))
        # Fig. 9b: per-replica footprint (tokens) under sharding
        tokens_total = sum(len(t) for t in catalog.item_tokens)
        hot = set(placement.hot_items.tolist())
        hot_tokens = sum(len(catalog.item_tokens[i]) for i in hot)
        per_replica = hot_tokens + (tokens_total - hot_tokens) / max(k, 1)
        # Fig. 9a: best-replica locality (same metric for both placements)
        _, rand_pl, _ = SIM.make_sim_setup(k=max(k, 1), n_requests=50,
                                           qps=10.0, n_items=4000, seed=20,
                                           placement_kind="random")
        sim_hit = np.mean([max(SCH.hit_vector(r.item_ids, placement))
                           for r in reqs[:200]])
        rand_hit = np.mean([max(SCH.hit_vector(r.item_ids, rand_pl))
                            for r in reqs[:200]])
        sp50 = res_px.pct(50) / res_rc.pct(50)
        sp99 = res_px.pct(99) / res_rc.pct(99)
        # §IV-D1 ablation: same trace served with hash-random placement
        res_rand = SIM.simulate(cfg, CM.V5E_1, reqs, rand_pl,
                                SIM.SimConfig(mode="rcllm"))
        placement_gain = res_rand.pct(50) / res_rc.pct(50)
        emit(f"fig8/K={k}/speedup", 0.0, f"p50={sp50:.2f}x p99={sp99:.2f}x")
        emit(f"fig9a/K={k}/hit_rate", 0.0,
             f"similarity={sim_hit:.3f} random={rand_hit:.3f}")
        emit(f"fig9b/K={k}/tokens_per_replica", 0.0, f"{per_replica:.0f}")
        emit(f"ablation/K={k}/placement_p50_gain", 0.0,
             f"{placement_gain:.2f}x vs random placement")
        out[k] = {"speedup_p50": sp50, "speedup_p99": sp99,
                  "hit_similarity": float(sim_hit),
                  "hit_random": float(rand_hit),
                  "placement_p50_gain": float(placement_gain),
                  "tokens_per_replica": per_replica}
    with open(os.path.join(out_dir, "fig8_9_scalability.json"), "w") as f:
        json.dump(out, f, indent=1)
