"""Shared benchmark utilities: timing + CSV emission.

Every benchmark prints ``name,us_per_call,derived`` CSV rows; `derived`
carries the paper-facing quantity (speedup, hit rate, NDCG, ...).
"""
from __future__ import annotations

import time
from typing import Callable


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)


def time_call(fn: Callable, repeats: int = 3) -> float:
    fn()                                     # warmup / compile
    t0 = time.perf_counter()
    for _ in range(repeats):
        fn()
    return (time.perf_counter() - t0) / repeats * 1e6
