"""Benchmark harness — one entry per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  ``--quick`` shrinks sweeps (CI);
default sizes reproduce the paper's structure in full.

  fig6        TTFT distributions, K=40, RcLLM vs Prefix vs Full (8B + 72B)
  fig8_9      speedup / hit-rate / footprint vs cluster size K
  fig10       scheduling policies under rising load
  fig11       recompute budget r vs TTFT
  tableIII    ranking accuracy: Full vs RcLLM vs CacheBlend vs EPIC
  kernels     Pallas kernel probes + analytic FLOP reductions
  serving     continuous batching: sim-engine vs real jax-engine TTFT
  cluster     K real engines + sharded item caches: dispatch policies
  attn_backend  jnp vs pallas attention; batched vs per-request prefill
  reuse       cross-request KV reuse (shared block store) off vs on
  chunked     unified token-budget scheduler: wave vs chunked prefill
  paged_decode  fused paged-attention decode kernel vs jnp gather
  openloop    async session server: Poisson wall-clock arrivals, SLO curve
  mesh        tensor-parallel serving on forced host devices: TTFT vs tp
  disagg      disaggregated prefill/decode: KV migration vs re-prefill
  tiered      tiered quantized store: host-RAM spill vs drop-on-evict

Each entry also writes a JSON artifact into ``--out`` (see
docs/benchmarks.md for the full flag and output reference).
"""
from __future__ import annotations

import argparse
import functools
import time

print = functools.partial(print, flush=True)   # keep CSV ordered through pipes


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="all",
                    help="comma-separated subset of fig6|fig8_9|fig10|fig11|"
                         "tableIII|kernels|serving|cluster|attn_backend|"
                         "reuse|chunked|paged_decode|openloop|mesh|disagg|"
                         "tiered, or all")
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--planted", action="store_true",
                    help="tableIII: train the planted-preference ranker")
    ap.add_argument("--out", default="results/bench")
    args = ap.parse_args(argv)

    print("name,us_per_call,derived")
    t0 = time.time()
    jobs = {
        "fig6": lambda: __import__(
            "benchmarks.bench_ttft", fromlist=["run"]).run(
                args.out, quick=args.quick),
        "fig8_9": lambda: __import__(
            "benchmarks.bench_scalability", fromlist=["run"]).run(
                args.out, quick=args.quick),
        "fig10": lambda: __import__(
            "benchmarks.bench_scheduling", fromlist=["run"]).run(
                args.out, quick=args.quick),
        "fig11": lambda: __import__(
            "benchmarks.bench_recompute", fromlist=["run"]).run(
                args.out, quick=args.quick),
        "tableIII": lambda: __import__(
            "benchmarks.bench_accuracy", fromlist=["run"]).run(
                args.out, quick=args.quick, planted=args.planted),
        "kernels": lambda: __import__(
            "benchmarks.bench_kernels", fromlist=["run"]).run(
                args.out, quick=args.quick),
        "serving": lambda: __import__(
            "benchmarks.bench_serving", fromlist=["run"]).run(
                args.out, quick=args.quick),
        "cluster": lambda: __import__(
            "benchmarks.bench_cluster", fromlist=["run"]).run(
                args.out, quick=args.quick),
        "attn_backend": lambda: __import__(
            "benchmarks.bench_attn_backend", fromlist=["run"]).run(
                args.out, quick=args.quick),
        "reuse": lambda: __import__(
            "benchmarks.bench_reuse", fromlist=["run"]).run(
                args.out, quick=args.quick),
        "chunked": lambda: __import__(
            "benchmarks.bench_chunked", fromlist=["run"]).run(
                args.out, quick=args.quick),
        "paged_decode": lambda: __import__(
            "benchmarks.bench_paged_decode", fromlist=["run"]).run(
                args.out, quick=args.quick),
        "openloop": lambda: __import__(
            "benchmarks.bench_openloop", fromlist=["run"]).run(
                args.out, quick=args.quick),
        "mesh": lambda: __import__(
            "benchmarks.bench_mesh", fromlist=["run"]).run(
                args.out, quick=args.quick),
        "disagg": lambda: __import__(
            "benchmarks.bench_disagg", fromlist=["run"]).run(
                args.out, quick=args.quick),
        "tiered": lambda: __import__(
            "benchmarks.bench_tiered", fromlist=["run"]).run(
                args.out, quick=args.quick),
    }
    only = {s.strip() for s in args.only.split(",") if s.strip()}
    unknown = only - set(jobs) - {"all"}
    if unknown:
        ap.error(f"unknown --only entries {sorted(unknown)}; "
                 f"choose from {['all', *jobs]}")
    for name, job in jobs.items():
        if "all" not in only and name not in only:
            continue
        job()
    print(f"# total_bench_seconds,{time.time() - t0:.1f},")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
