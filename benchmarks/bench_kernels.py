"""§III-C pipeline microbenchmarks: kernel-level quantities — selective
attention FLOP reduction, block-gather bytes moved, embedding-bag
throughput (interpret mode: correctness + analytic derived metrics; real
timing requires TPU)."""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks.common import emit, time_call
from repro.kernels.block_gather.ops import assemble_kv
from repro.kernels.embedding_bag.ops import bag_sum
from repro.kernels.flash_attention.ops import mha_flash
from repro.kernels.selective_attention.ops import flop_reduction


def run(out_dir: str = "results/bench", quick: bool = False) -> None:
    rng = np.random.default_rng(0)
    # selective attention: analytic FLOP reduction at paper-like settings
    for (n, r_frac, window, hh_frac) in [(2500, 0.3, 256, 0.05),
                                         (3000, 0.2, 256, 0.05)]:
        red = flop_reduction(int(r_frac * n), n, int(hh_frac * n), window)
        emit(f"kernels/selective/n={n}/r={r_frac}", 0.0,
             f"attn_flops_vs_full={red:.3f}")

    # interpret-mode correctness/latency probes (small shapes)
    B, S, H, D = 1, 128, 2, 64
    q = jnp.asarray(rng.normal(size=(B, S, H, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, H, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, H, D)), jnp.float32)
    us = time_call(lambda: mha_flash(q, k, v, q_block=64, kv_block=64,
                                     interpret=True).block_until_ready(),
                   repeats=1)
    emit("kernels/flash_attention/interp_128", us, "interpret-mode")

    pool_k = jnp.asarray(rng.normal(size=(64, 16, 64)), jnp.float32)
    bt = jnp.asarray(rng.choice(64, 8, replace=False), jnp.int32)
    pos = jnp.asarray(np.arange(8 * 16).reshape(8, 16), jnp.int32)
    us = time_call(lambda: assemble_kv(pool_k, pool_k, bt, pos,
                                       interpret=True)[0].block_until_ready(),
                   repeats=1)
    moved = 2 * 8 * 16 * 64 * 4
    emit("kernels/block_gather/8pages", us, f"bytes_moved={moved}")

    table = jnp.asarray(rng.normal(size=(4096, 32)), jnp.float32)
    ids = jnp.asarray(rng.integers(0, 4096, (64, 13)), jnp.int32)
    us = time_call(lambda: bag_sum(table, ids,
                                   interpret=True).block_until_ready(),
                   repeats=1)
    emit("kernels/embedding_bag/64x13", us,
         f"rows_gathered={64 * 13}")
