"""Table III / Fig. 7: ranking accuracy — Full-Recompute vs RcLLM vs
CacheBlend vs EPIC on the real JAX model.

Two protocols:
  * fidelity (default, fast): ranking agreement vs the Full-Recompute
    oracle (NDCG of the approx ranking with full's ranking as graded truth)
    across recompute budgets — the Fig. 7 sweep;
  * planted (--planted): trains the tiny LM on the planted-preference task
    first, then reports Table III metrics vs gold labels.
"""
from __future__ import annotations

import json
import os

import numpy as np

from benchmarks.common import emit
from repro.core import metrics as MET
from repro.core.engine import SelectiveConfig
from repro.core.rcllm import RcLLMSystem, make_tiny_system
from repro.data import synth as SY


def run(out_dir: str = "results/bench", quick: bool = False,
        planted: bool = False) -> None:
    os.makedirs(out_dir, exist_ok=True)
    system, pool, prof, hist = make_tiny_system(
        n_items=100 if quick else 150,
        n_requests_hist=60, k_instances=4)
    params = system.params
    if planted:
        from repro.core import ranker_training as RT
        reqs_t, gold_t = RT.make_planted_trace(system.catalog, pool, prof,
                                               n_requests=300,
                                               n_candidates=8, seed=5)
        params, _ = RT.train_ranker(params, system.cfg, system.catalog,
                                    system.instruction, reqs_t[:240],
                                    gold_t[:240], steps=200)
        corpus, seen = [], set()
        for r in hist:
            if r.user_id not in seen:
                corpus.append(r.history_tokens)
                seen.add(r.user_id)
        system = RcLLMSystem.build(params, system.cfg, system.catalog,
                                   corpus, hist, k_instances=4)

    n_eval = 8 if quick else 20
    reqs = SY.make_trace(system.catalog, pool, prof, n_eval, qps=5.0,
                         n_users=12, n_candidates=10, reviews_per_user=2,
                         seed=99)
    ratios = [0.3] if quick else [0.1, 0.3, 0.5]
    out = {}
    for r_budget in ratios:
        sel = SelectiveConfig(r_item=r_budget, r_rev=r_budget, window=16)
        fid = {m: [] for m in ("rcllm", "cacheblend", "epic")}
        rec_frac = {m: [] for m in fid}
        for rq in reqs:
            full, _ = system.rank(rq, "full")
            for m in fid:
                sc, stats = system.rank(rq, m, sel)
                fid[m].append(MET.ranking_agreement_ndcg(full, sc, k=10))
                rec_frac[m].append(stats.recompute_fraction())
        for m in fid:
            emit(f"tableIII/fidelity/r={r_budget}/{m}", 0.0,
                 f"NDCG@10_vs_full={np.mean(fid[m]):.4f} "
                 f"recompute={np.mean(rec_frac[m]):.2f}")
        out[f"r={r_budget}"] = {
            m: {"fidelity_ndcg10": float(np.mean(fid[m])),
                "recompute_frac": float(np.mean(rec_frac[m]))} for m in fid}
    # reuse statistics (Insights 1-2): plan composition
    plan = system.plan_for(reqs[0])
    emit("tableIII/plan", 0.0,
         f"reuse_frac={plan.reuse_fraction():.2f} local={plan.n_local} "
         f"remote={plan.n_remote} miss={plan.n_miss}")
    with open(os.path.join(out_dir, "tableIII_accuracy.json"), "w") as f:
        json.dump(out, f, indent=1)
