# OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY
# for compute hot-spots the paper itself optimizes with a custom
# kernel. Leave this package empty if the paper has none.
from __future__ import annotations

import jax


def default_interpret() -> bool:
    """Whether Pallas kernels should run in interpret mode.

    Real Mosaic lowering needs a TPU; everywhere else (CPU CI, tests,
    laptops) the kernels execute through the Pallas interpreter so the
    exact same kernel bodies stay on the hot path.
    """
    return jax.default_backend() != "tpu"
