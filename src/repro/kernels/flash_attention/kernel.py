"""Pallas TPU flash-attention (prefill) kernel.

Tiling: grid (batch·kv_heads·groups, nq, nk) — the trailing kv axis is
sequential on TPU, so the (m, l, acc) running-softmax state lives in VMEM
scratch across kv steps.  Block shapes are MXU-aligned (q_block × d and
kv_block × d tiles, d a multiple of 128 for full MXU utilization; smaller
d still lowers, padded by Mosaic).

Two mask sources compose:

* ``causal`` — the static iota-based triangle (contiguous positions);
* ``kv_valid`` — an optional per-row key-liveness bitmap, the serving
  engine's ragged-batch mask (padded prompt tails, paged-decode slots
  past a request's length).  It rides in as a normal kernel input tiled
  (1, kv_block) with NB mask rows shared across each row's heads by
  BlockSpec index arithmetic — never materialized per head — so the
  wrapper stays jit-traceable end-to-end.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(*refs, causal: bool, sm_scale: float, q_block: int,
                  kv_block: int, kv_len: int, has_valid: bool):
    if has_valid:
        q_ref, k_ref, v_ref, valid_ref, o_ref, m_scr, l_scr, acc_scr = refs
    else:
        q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr = refs
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0]                                    # (q_block, d)
    k = k_ref[0]                                    # (kv_block, d)
    v = v_ref[0]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * sm_scale

    q_pos = qi * q_block + jax.lax.broadcasted_iota(jnp.int32,
                                                    (q_block, kv_block), 0)
    k_pos = ki * kv_block + jax.lax.broadcasted_iota(jnp.int32,
                                                     (q_block, kv_block), 1)
    mask = k_pos < kv_len
    if has_valid:
        mask &= valid_ref[0][None, :] > 0
    if causal:
        mask &= q_pos >= k_pos
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    l_prev = l_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + p.sum(axis=1)
    acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(ki == nk - 1)
    def _finish():
        o_ref[0] = (acc_scr[...] /
                    jnp.maximum(l_scr[...], 1e-30)[:, None]
                    ).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    kv_valid: jax.Array = None,
                    causal: bool = True, q_block: int = 128,
                    kv_block: int = 128,
                    interpret: bool = False) -> jax.Array:
    """q: (BH, Sq, D); k, v: (BH, Skv, D) — heads pre-flattened (GQA groups
    expanded by the ops wrapper).  `kv_valid`: optional (NB, Skv) bool/int8
    key-liveness mask with NB dividing BH — mask row b·NB/BH serves
    flattened row b, so a per-request mask is shared by that request's
    heads without per-head copies.  Returns (BH, Sq, D)."""
    bh, sq, d = q.shape
    skv = k.shape[1]
    sq_p = ((sq + q_block - 1) // q_block) * q_block
    skv_p = ((skv + kv_block - 1) // kv_block) * kv_block
    if sq_p != sq:
        q = jnp.pad(q, ((0, 0), (0, sq_p - sq), (0, 0)))
    if skv_p != skv:
        k = jnp.pad(k, ((0, 0), (0, skv_p - skv), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, skv_p - skv), (0, 0)))
    nq = sq_p // q_block
    nk = skv_p // kv_block

    in_specs = [
        pl.BlockSpec((1, q_block, d), lambda b, qi, ki: (b, qi, 0)),
        pl.BlockSpec((1, kv_block, d), lambda b, qi, ki: (b, ki, 0)),
        pl.BlockSpec((1, kv_block, d), lambda b, qi, ki: (b, ki, 0)),
    ]
    args = [q, k, v]
    if kv_valid is not None:
        nb = kv_valid.shape[0]
        if bh % nb:
            raise ValueError(f"kv_valid batch {nb} must divide BH={bh}")
        kvv = jnp.pad(kv_valid.astype(jnp.int8),
                      ((0, 0), (0, skv_p - skv)))
        in_specs.append(pl.BlockSpec((1, kv_block),
                                     lambda b, qi, ki: (b * nb // bh, ki)))
        args.append(kvv)

    kernel = functools.partial(
        _flash_kernel, causal=causal, sm_scale=1.0 / d ** 0.5,
        q_block=q_block, kv_block=kv_block, kv_len=skv,
        has_valid=kv_valid is not None)
    out = pl.pallas_call(
        kernel,
        grid=(bh, nq, nk),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, q_block, d), lambda b, qi, ki: (b, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq_p, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((q_block,), jnp.float32),
            pltpu.VMEM((q_block,), jnp.float32),
            pltpu.VMEM((q_block, d), jnp.float32),
        ],
        interpret=interpret,
    )(*args)
    return out[:, :sq]
