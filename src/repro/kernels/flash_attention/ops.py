"""Jit'd public wrapper: (B, S, H, D) GQA layout → kernel layout."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention


@functools.partial(
    jax.jit,
    static_argnames=("causal", "q_block", "kv_block", "interpret"),
)
def mha_flash(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    kv_valid: jax.Array = None,
    causal: bool = True,
    q_block: int = 128,
    kv_block: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """q: (B, Sq, Hq, D); k, v: (B, Skv, Hkv, D) with Hq % Hkv == 0.

    `kv_valid`: optional (B, Skv) bool mask of attendable keys per batch
    row (the serving engine's ragged-batch mask); the kernel shares each
    row's mask across its query heads by BlockSpec index arithmetic, so
    no per-head copy is ever materialized.  Fully traceable — the mask
    is a kernel input, so this wrapper jits end-to-end.
    """
    b, sq, hq, d = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    k = jnp.repeat(k, g, axis=2)
    v = jnp.repeat(v, g, axis=2)
    qf = q.transpose(0, 2, 1, 3).reshape(b * hq, sq, d)
    kf = k.transpose(0, 2, 1, 3).reshape(b * hq, -1, d)
    vf = v.transpose(0, 2, 1, 3).reshape(b * hq, -1, d)
    of = flash_attention(
        qf,
        kf,
        vf,
        kv_valid=kv_valid,
        causal=causal,
        q_block=q_block,
        kv_block=kv_block,
        interpret=interpret,
    )
    return of.reshape(b, hq, sq, d).transpose(0, 2, 1, 3)
