"""Jit'd public wrapper: (B, S, H, D) GQA layout → kernel layout."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention


@functools.partial(jax.jit, static_argnames=("causal", "q_block", "kv_block",
                                             "interpret"))
def mha_flash(q: jax.Array, k: jax.Array, v: jax.Array, *,
              causal: bool = True, q_block: int = 128, kv_block: int = 128,
              interpret: bool = False) -> jax.Array:
    """q: (B, Sq, Hq, D); k, v: (B, Skv, Hkv, D) with Hq % Hkv == 0."""
    b, sq, hq, d = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    k = jnp.repeat(k, g, axis=2)
    v = jnp.repeat(v, g, axis=2)
    qf = q.transpose(0, 2, 1, 3).reshape(b * hq, sq, d)
    kf = k.transpose(0, 2, 1, 3).reshape(b * hq, -1, d)
    vf = v.transpose(0, 2, 1, 3).reshape(b * hq, -1, d)
    of = flash_attention(qf, kf, vf, causal=causal, q_block=q_block,
                         kv_block=kv_block, interpret=interpret)
    return of.reshape(b, hq, sq, d).transpose(0, 2, 1, 3)
