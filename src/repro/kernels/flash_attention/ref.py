"""Pure-jnp oracle for the flash attention kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True) -> jax.Array:
    """q: (BH, Sq, D); k, v: (BH, Skv, D)."""
    d = q.shape[-1]
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / d ** 0.5
    if causal:
        sq, skv = q.shape[1], k.shape[1]
        mask = jnp.arange(sq)[:, None] >= jnp.arange(skv)[None, :]
        s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)
