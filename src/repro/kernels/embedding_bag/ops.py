"""Jit'd wrapper for the embedding-bag kernel."""
from __future__ import annotations

import functools

import jax

from repro.kernels.embedding_bag.kernel import embedding_bag


@functools.partial(jax.jit, static_argnames=("interpret",))
def bag_sum(table, ids, *, interpret: bool = False):
    return embedding_bag(table, ids, interpret=interpret)
