"""Pallas TPU embedding-bag kernel (RecSys hot path).

Fixed-fanout CTR lookup: ids (B, F) into a (rows, d) table → (B, d) sum.
The row index is scalar-prefetched so each (b, f) grid step's BlockSpec
index_map pulls exactly one table row into VMEM; the trailing f axis is
sequential on TPU so the bag accumulates in the output block (revisited
across f — legal under TPU's sequential-last-axis grid semantics).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _bag_kernel(ids_ref, row_ref, out_ref, acc_ref):
    f = pl.program_id(1)
    nf = pl.num_programs(1)

    @pl.when(f == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # accumulate in fp32 VMEM scratch regardless of table dtype (bf16
    # accumulation loses a bit per add over wide bags)
    acc_ref[...] += row_ref[...].astype(jnp.float32)

    @pl.when(f == nf - 1)
    def _finish():
        out_ref[...] = acc_ref[...].astype(out_ref.dtype)


def embedding_bag(table: jax.Array, ids: jax.Array, *,
                  interpret: bool = False) -> jax.Array:
    """table: (rows, d); ids: (B, F) → (B, d) per-sample sum of F rows."""
    rows, d = table.shape
    b, f = ids.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, f),
        in_specs=[
            pl.BlockSpec((1, d), lambda i, j, ids_ref: (ids_ref[i, j], 0)),
        ],
        out_specs=pl.BlockSpec((1, d), lambda i, j, ids_ref: (i, 0)),
        scratch_shapes=[pltpu.VMEM((1, d), jnp.float32)],
    )
    return pl.pallas_call(
        _bag_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, d), table.dtype),
        interpret=interpret,
    )(ids.astype(jnp.int32), table)
