"""Pure-jnp oracle for the embedding-bag kernel (take + sum — the same
formulation the recsys models use via jax.ops.segment_sum)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def embedding_bag_ref(table: jax.Array, ids: jax.Array) -> jax.Array:
    """table: (rows, d); ids: (B, F) -> (B, d)."""
    return jnp.take(table, ids, axis=0).sum(axis=1)
