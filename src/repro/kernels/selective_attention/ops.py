"""Jit'd wrapper for the selective-attention kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.selective_attention.kernel import selective_attention


def selective_mha(q, q_positions, k, v, hh_mask, *, window: int = 256,
                  q_block: int = 128, kv_block: int = 128,
                  interpret: bool = False):
    """q: (B, R, Hq, D); k, v: (B, S, Hkv, D); hh_mask: (S,).

    Note: the block-liveness map is computed host-side from concrete
    positions/mask (it IS the point of the kernel — static tile skipping),
    so this wrapper is not jit-traceable end-to-end; callers jit around it.
    """
    if isinstance(q_positions, jax.core.Tracer) or \
            isinstance(hh_mask, jax.core.Tracer):
        raise TypeError(
            "selective_mha cannot be traced end-to-end by jax.jit: the "
            "block-liveness map is computed host-side from *concrete* "
            "q_positions/hh_mask (static tile skipping is the point of the "
            "kernel). Call it outside jit — or close over concrete "
            "positions/mask and jit only the surrounding computation.")
    b, r, hq, d = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    kk = jnp.repeat(k, g, axis=2)
    vv = jnp.repeat(v, g, axis=2)
    qf = q.transpose(0, 2, 1, 3).reshape(b * hq, r, d)
    kf = kk.transpose(0, 2, 1, 3).reshape(b * hq, -1, d)
    vf = vv.transpose(0, 2, 1, 3).reshape(b * hq, -1, d)
    of = selective_attention(qf, q_positions, kf, vf, hh_mask,
                             window=window, q_block=q_block,
                             kv_block=kv_block, interpret=interpret)
    return of.reshape(b, hq, r, d).transpose(0, 2, 1, 3)


def flop_reduction(r: int, s: int, n_hh: int, window: int) -> float:
    """Analytic FLOP ratio vs full attention (paper's ~r·n² savings)."""
    full = s * s
    sel = r * min(window + n_hh, s)
    return sel / full
