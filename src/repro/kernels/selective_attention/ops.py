"""Jit'd wrapper for the selective-attention kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.selective_attention.kernel import (
    block_liveness,
    selective_attention,
)


def build_block_liveness(
    q_positions,
    hh_mask,
    *,
    window: int,
    q_block: int = 128,
    kv_block: int = 128,
):
    """Precompute the (NB, nq, nk) block-liveness map host-side.

    This is the jit seam: the map depends only on *concrete* query
    positions and the heavy-hitter bitmap — both known on the host before
    the engine dispatches its jitted selective step — so callers bake it
    per shape bucket and pass it to `selective_mha(..., live=...)`, which
    is then traceable end-to-end (the map rides into the kernel as data).
    """
    return block_liveness(
        q_positions,
        hh_mask,
        window=window,
        q_block=q_block,
        kv_block=kv_block,
    )


def selective_mha(
    q,
    q_positions,
    k,
    v,
    hh_mask,
    *,
    live=None,
    window: int = 256,
    q_block: int = 128,
    kv_block: int = 128,
    interpret: bool = False,
):
    """q: (B, R, Hq, D); k, v: (B, S, Hkv, D); q_positions: (R,) or
    (B, R); hh_mask: (S,) or (B, S).

    With ``live=None`` the block-liveness map is computed host-side from
    concrete positions/mask, so the call is NOT jit-traceable (the
    pre-seam behaviour, kept for direct kernel use).  Pass a precomputed
    ``live`` (`build_block_liveness`) and the wrapper traces end-to-end —
    this is how the serving engine runs it inside its jitted selective
    prefill.  Per-request masks (2-D q_positions/hh_mask) are shared
    across that request's heads inside the kernel without materializing
    per-head copies.
    """
    if live is None and (
        isinstance(q_positions, jax.core.Tracer)
        or isinstance(hh_mask, jax.core.Tracer)
    ):
        raise TypeError(
            "selective_mha cannot be traced end-to-end by jax.jit without "
            "a precomputed liveness map: the block-liveness map is "
            "computed host-side from *concrete* q_positions/hh_mask "
            "(static tile skipping is the point of the kernel). Either "
            "call it outside jit, or precompute the map with "
            "build_block_liveness(...) and pass it via live=."
        )
    b, r, hq, d = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    kk = jnp.repeat(k, g, axis=2)
    vv = jnp.repeat(v, g, axis=2)
    qf = q.transpose(0, 2, 1, 3).reshape(b * hq, r, d)
    kf = kk.transpose(0, 2, 1, 3).reshape(b * hq, -1, d)
    vf = vv.transpose(0, 2, 1, 3).reshape(b * hq, -1, d)
    of = selective_attention(
        qf,
        q_positions,
        kf,
        vf,
        hh_mask,
        live=live,
        window=window,
        q_block=q_block,
        kv_block=kv_block,
        interpret=interpret,
    )
    return of.reshape(b, hq, r, d).transpose(0, 2, 1, 3)


def flop_reduction(r: int, s: int, n_hh: int, window: int) -> float:
    """Analytic FLOP ratio vs full attention (paper's ~r·n² savings)."""
    full = s * s
    sel = r * min(window + n_hh, s)
    return sel / full
