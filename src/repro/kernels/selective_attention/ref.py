"""Pure-jnp oracle for selective attention."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def selective_attention_ref(q, q_positions, k, v, hh_mask, *,
                            window: int = 256) -> jax.Array:
    """q: (BH, R, D), q_positions: (R,), k/v: (BH, S, D), hh_mask: (S,).
    Attend where causal AND (within window OR heavy-hitter)."""
    d = q.shape[-1]
    s_len = k.shape[1]
    s = jnp.einsum("brd,bkd->brk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / d ** 0.5
    k_pos = jnp.arange(s_len)
    causal = q_positions[:, None] >= k_pos[None, :]
    in_window = causal & (q_positions[:, None] - k_pos[None, :] < window)
    valid = causal & (in_window | (hh_mask[None, :] > 0))
    s = jnp.where(valid[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("brk,bkd->brd", p, v.astype(jnp.float32)).astype(q.dtype)
