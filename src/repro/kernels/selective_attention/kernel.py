"""Pallas TPU selective-attention kernel (§III-C2b on TPU).

Computes attention for the R recomputed queries against keys restricted to
(heavy hitters ∪ causal sliding window ∪ recomputed tokens): the paper's
per-token mask becomes a *block-sparse* pattern — the host precomputes a
(nq, nk) block liveness map; dead (query-block, key-block) tiles are
skipped entirely (`@pl.when`), live tiles apply the fine-grained bitmap in
VREGs.  This is the TPU-native form of the CUDA selective mask: static
128×128 MXU tiles + predicated skip, instead of per-row divergence.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _sel_kernel(qpos_ref, live_ref, q_ref, k_ref, v_ref, mask_ref,
                o_ref, m_scr, l_scr, acc_scr,
                *, sm_scale: float, q_block: int, kv_block: int,
                window: int, kv_len: int):
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    @pl.when(live_ref[0, 0] > 0)
    def _compute():
        q = q_ref[0]
        k = k_ref[0]
        v = v_ref[0]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * sm_scale
        q_pos = qpos_ref[...][:, None]                      # (q_block, 1)
        k_pos = ki * kv_block + jax.lax.broadcasted_iota(
            jnp.int32, (q_block, kv_block), 1)
        in_window = (q_pos >= k_pos) & (q_pos - k_pos < window)
        hh = mask_ref[0][None, :] > 0                       # heavy hitters
        causal = q_pos >= k_pos
        valid = (k_pos < kv_len) & causal & (in_window | hh)
        s = jnp.where(valid, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=1)
        acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _finish():
        o_ref[0] = (acc_scr[...] /
                    jnp.maximum(l_scr[...], 1e-30)[:, None]
                    ).astype(o_ref.dtype)


def selective_attention(q: jax.Array, q_positions: jax.Array,
                        k: jax.Array, v: jax.Array, hh_mask: jax.Array, *,
                        window: int = 256, q_block: int = 128,
                        kv_block: int = 128,
                        interpret: bool = False) -> jax.Array:
    """q: (BH, R, D) recomputed queries with absolute positions
    q_positions: (R,); k, v: (BH, S, D) assembled keys; hh_mask: (S,) int8
    marking heavy-hitter/recomputed keys.  Attend where causal AND
    (within `window` OR hh_mask)."""
    bh, r, d = q.shape
    s_len = k.shape[1]
    r_p = ((r + q_block - 1) // q_block) * q_block
    s_p = ((s_len + kv_block - 1) // kv_block) * kv_block
    q = jnp.pad(q, ((0, 0), (0, r_p - r), (0, 0)))
    qpos = jnp.pad(q_positions.astype(jnp.int32), (0, r_p - r),
                   constant_values=-1)
    k = jnp.pad(k, ((0, 0), (0, s_p - s_len), (0, 0)))
    v = jnp.pad(v, ((0, 0), (0, s_p - s_len), (0, 0)))
    hh = jnp.pad(hh_mask.astype(jnp.int8), (0, s_p - s_len))
    nq, nk = r_p // q_block, s_p // kv_block

    # host-side block liveness: tile (qi, kj) is live iff any query in it can
    # see any key in the tile (window hit or any HH key causally visible)
    qpos_r = np.asarray(qpos).reshape(nq, q_block)
    hh_r = np.asarray(hh).reshape(nk, kv_block)
    live = np.zeros((nq, nk), np.int32)
    for qi in range(nq):
        qmax = int(qpos_r[qi].max())
        qmin_valid = qpos_r[qi][qpos_r[qi] >= 0]
        qmin = int(qmin_valid.min()) if len(qmin_valid) else -1
        if qmin < 0 and qmax < 0:
            continue
        for kj in range(nk):
            k_lo, k_hi = kj * kv_block, (kj + 1) * kv_block - 1
            if k_lo > qmax:
                continue                         # fully acausal
            # window liveness: ∃ q∈[qmin,qmax], k∈[k_lo,k_hi] with
            # 0 ≤ q−k < window ⟺ [qmin−window+1, qmax] ∩ [k_lo, k_hi] ≠ ∅
            # (conservative superset for non-contiguous q positions)
            win_hit = k_hi > qmin - window and k_lo <= qmax
            hh_hit = bool(hh_r[kj].any())
            if win_hit or hh_hit:
                live[qi, kj] = 1

    kernel = functools.partial(
        _sel_kernel, sm_scale=1.0 / d ** 0.5, q_block=q_block,
        kv_block=kv_block, window=window, kv_len=s_len)
    out = pl.pallas_call(
        kernel,
        grid=(bh, nq, nk),
        in_specs=[
            pl.BlockSpec((q_block,), lambda b, qi, ki: (qi,)),
            pl.BlockSpec((1, 1), lambda b, qi, ki: (qi, ki)),
            pl.BlockSpec((1, q_block, d), lambda b, qi, ki: (b, qi, 0)),
            pl.BlockSpec((1, kv_block, d), lambda b, qi, ki: (b, ki, 0)),
            pl.BlockSpec((1, kv_block, d), lambda b, qi, ki: (b, ki, 0)),
            pl.BlockSpec((1, kv_block), lambda b, qi, ki: (0, ki)),
        ],
        out_specs=pl.BlockSpec((1, q_block, d), lambda b, qi, ki: (b, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, r_p, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((q_block,), jnp.float32),
            pltpu.VMEM((q_block,), jnp.float32),
            pltpu.VMEM((q_block, d), jnp.float32),
        ],
        interpret=interpret,
    )(qpos, jnp.asarray(live), q, k, v, hh[None])
    return out[:, :r]
