"""Pallas TPU selective-attention kernel (§III-C2b on TPU).

Computes attention for the R recomputed queries against keys restricted to
(heavy hitters ∪ causal sliding window ∪ recomputed tokens): the paper's
per-token mask becomes a *block-sparse* pattern — a (nq, nk) block liveness
map marks which (query-block, key-block) tiles can contribute; dead tiles
are skipped entirely (`@pl.when`), live tiles apply the fine-grained bitmap
in VREGs.  This is the TPU-native form of the CUDA selective mask: static
128×128 MXU tiles + predicated skip, instead of per-row divergence.

The liveness map is *data* (a kernel input), not trace-time control flow:
callers precompute it host-side with `block_liveness` from concrete
positions/mask and pass it in, which makes the whole wrapper jit-traceable
— the serving engine bakes the map per shape bucket and runs the kernel
inside its jitted selective-prefill step.  When `live` is omitted the
kernel computes it on the host (concrete inputs only, the pre-seam
behaviour).

Masks are per *mask row*: `q_positions`/`hh_mask`/`live` carry a leading
NB dim that divides the flattened BH batch·head dim, so one request's
masks are shared by its heads without materializing BH copies (NB=1 is
the fully-shared single-request case).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def block_liveness(q_positions, hh_mask, *, window: int,
                   q_block: int = 128, kv_block: int = 128) -> np.ndarray:
    """Host-side block-liveness map for `selective_attention`.

    q_positions: (R,) or (NB, R) int absolute query positions (pad = -1);
    hh_mask: (S,) or (NB, S) heavy-hitter/recomputed key bitmap.  Tile
    (qi, kj) is live iff any query in it can see any key in the tile
    (window hit, or any HH key causally visible).  -> (NB, nq, nk) int32.
    """
    qp = np.asarray(q_positions)
    hh = np.asarray(hh_mask)
    if qp.ndim == 1:
        qp = qp[None]
    if hh.ndim == 1:
        hh = hh[None]
    nb, r = qp.shape
    s_len = hh.shape[1]
    r_p = ((r + q_block - 1) // q_block) * q_block
    s_p = ((s_len + kv_block - 1) // kv_block) * kv_block
    qp = np.pad(qp.astype(np.int64), ((0, 0), (0, r_p - r)),
                constant_values=-1)
    hh = np.pad(hh.astype(np.int8), ((0, 0), (0, s_p - s_len)))
    nq, nk = r_p // q_block, s_p // kv_block
    live = np.zeros((nb, nq, nk), np.int32)
    for bi in range(nb):
        qpos_r = qp[bi].reshape(nq, q_block)
        hh_r = hh[bi].reshape(nk, kv_block)
        for qi in range(nq):
            qmax = int(qpos_r[qi].max())
            qmin_valid = qpos_r[qi][qpos_r[qi] >= 0]
            qmin = int(qmin_valid.min()) if len(qmin_valid) else -1
            if qmin < 0 and qmax < 0:
                continue
            for kj in range(nk):
                k_lo, k_hi = kj * kv_block, (kj + 1) * kv_block - 1
                if k_lo > qmax:
                    continue                         # fully acausal
                # window liveness: ∃ q∈[qmin,qmax], k∈[k_lo,k_hi] with
                # 0 ≤ q−k < window ⟺ [qmin−window+1, qmax] ∩ [k_lo, k_hi] ≠ ∅
                # (conservative superset for non-contiguous q positions)
                win_hit = k_hi > qmin - window and k_lo <= qmax
                hh_hit = bool(hh_r[kj].any())
                if win_hit or hh_hit:
                    live[bi, qi, kj] = 1
    return live


def _sel_kernel(qpos_ref, live_ref, q_ref, k_ref, v_ref, mask_ref,
                o_ref, m_scr, l_scr, acc_scr,
                *, sm_scale: float, q_block: int, kv_block: int,
                window: int, kv_len: int):
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    @pl.when(live_ref[0, 0, 0] > 0)
    def _compute():
        q = q_ref[0]
        k = k_ref[0]
        v = v_ref[0]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * sm_scale
        q_pos = qpos_ref[0][:, None]                        # (q_block, 1)
        k_pos = ki * kv_block + jax.lax.broadcasted_iota(
            jnp.int32, (q_block, kv_block), 1)
        in_window = (q_pos >= k_pos) & (q_pos - k_pos < window)
        hh = mask_ref[0][None, :] > 0                       # heavy hitters
        causal = q_pos >= k_pos
        valid = (k_pos < kv_len) & causal & (in_window | hh)
        s = jnp.where(valid, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=1)
        acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _finish():
        o_ref[0] = (acc_scr[...] /
                    jnp.maximum(l_scr[...], 1e-30)[:, None]
                    ).astype(o_ref.dtype)


def selective_attention(q: jax.Array, q_positions: jax.Array,
                        k: jax.Array, v: jax.Array, hh_mask: jax.Array, *,
                        live=None, window: int = 256, q_block: int = 128,
                        kv_block: int = 128,
                        interpret: bool = False) -> jax.Array:
    """q: (BH, R, D) recomputed queries; q_positions: (R,) or (NB, R)
    absolute positions; k, v: (BH, S, D) assembled keys; hh_mask: (S,) or
    (NB, S) int8 marking heavy-hitter/recomputed keys.  NB must divide BH
    (mask row b·NB/BH serves flattened row b).  Attend where causal AND
    (within `window` OR hh_mask).  `live`: optional precomputed
    (NB, nq, nk) block-liveness map (`block_liveness`); required for
    jit-traced calls, computed host-side when omitted."""
    bh, r, d = q.shape
    s_len = k.shape[1]
    qp2 = q_positions if q_positions.ndim == 2 else q_positions[None]
    hh2 = hh_mask if hh_mask.ndim == 2 else hh_mask[None]
    nb = qp2.shape[0]
    if bh % nb or hh2.shape[0] != nb:
        raise ValueError(
            f"mask batch {nb}/{hh2.shape[0]} must divide BH={bh}")
    r_p = ((r + q_block - 1) // q_block) * q_block
    s_p = ((s_len + kv_block - 1) // kv_block) * kv_block
    q = jnp.pad(q, ((0, 0), (0, r_p - r), (0, 0)))
    qpos = jnp.pad(qp2.astype(jnp.int32), ((0, 0), (0, r_p - r)),
                   constant_values=-1)
    k = jnp.pad(k, ((0, 0), (0, s_p - s_len), (0, 0)))
    v = jnp.pad(v, ((0, 0), (0, s_p - s_len), (0, 0)))
    hh = jnp.pad(hh2.astype(jnp.int8), ((0, 0), (0, s_p - s_len)))
    nq, nk = r_p // q_block, s_p // kv_block

    if live is None:
        # host-side fallback: needs concrete positions/mask (the ops
        # wrapper raises a clear TypeError under tracing before this)
        live = block_liveness(np.asarray(qp2), np.asarray(hh2),
                              window=window, q_block=q_block,
                              kv_block=kv_block)
    live = jnp.asarray(live, jnp.int32)
    if live.ndim == 2:
        live = live[None]

    kernel = functools.partial(
        _sel_kernel, sm_scale=1.0 / d ** 0.5, q_block=q_block,
        kv_block=kv_block, window=window, kv_len=s_len)
    out = pl.pallas_call(
        kernel,
        grid=(bh, nq, nk),
        in_specs=[
            pl.BlockSpec((1, q_block), lambda b, qi, ki: (b * nb // bh, qi)),
            pl.BlockSpec((1, 1, 1),
                         lambda b, qi, ki: (b * nb // bh, qi, ki)),
            pl.BlockSpec((1, q_block, d), lambda b, qi, ki: (b, qi, 0)),
            pl.BlockSpec((1, kv_block, d), lambda b, qi, ki: (b, ki, 0)),
            pl.BlockSpec((1, kv_block, d), lambda b, qi, ki: (b, ki, 0)),
            pl.BlockSpec((1, kv_block), lambda b, qi, ki: (b * nb // bh, ki)),
        ],
        out_specs=pl.BlockSpec((1, q_block, d), lambda b, qi, ki: (b, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, r_p, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((q_block,), jnp.float32),
            pltpu.VMEM((q_block,), jnp.float32),
            pltpu.VMEM((q_block, d), jnp.float32),
        ],
        interpret=interpret,
    )(qpos, live, q, k, v, hh)
    return out[:, :r]
