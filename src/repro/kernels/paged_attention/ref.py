"""Pure-jnp oracles for the fused paged-decode attention kernel.

Two layers of reference share ONE attention body:

* `masked_decode_attention_ref` — the GQA masked-softmax decode
  attention the serving gather path (`batch_engine._decode_attn`) calls
  directly.  Keeping the masking constant (`NEG_INF`) and the dtype
  discipline (fp32 scores, value-dtype probabilities) in this single
  helper is what guarantees the gather oracle and the paged oracle can
  never drift apart — `tests/test_kernel_properties.py` pins their
  bitwise equality.

* `paged_decode_ref` — the materializing counterpart of the Pallas
  paged kernel: gather the referenced physical pages, rotate keys to
  their logical positions (RoPE group property — cached keys are stored
  pre-RoPE), then run the shared attention body over the flattened
  (page, slot) axis.  Attention is permutation-invariant over keys, so
  physical-page order needs no unscramble back to logical order.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.block_gather.ref import rope_rotate

# The one masking constant both decode oracles (and the Pallas kernels)
# share: large enough that exp underflows to exactly 0.0 in fp32, small
# enough not to overflow to -inf when scores are added to it.
NEG_INF = -1e30


def masked_decode_attention_ref(
    q: jax.Array, k: jax.Array, v: jax.Array, kv_valid: jax.Array
) -> jax.Array:
    """One-token-per-request GQA attention under a key-liveness mask.

    q: (N, Hq, Dh); k, v: (N, T, Hkv, Dh) with Hkv dividing Hq;
    kv_valid: (N, T) bool — dead keys (padding, slots past a request's
    length, unused page slots) are masked to `NEG_INF` *before* softmax.
    Scores accumulate in fp32; probabilities are cast to the value dtype
    for the weighted sum (the exact discipline `_decode_attn` has always
    used).  -> (N, Hq, Dh).
    """
    n, hq, d = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    scale = 1.0 / (d**0.5)
    qr = q.reshape(n, hkv, g, d)
    s = jnp.einsum("nhgd,nshd->nhgs", qr, k, preferred_element_type=jnp.float32)
    s = jnp.where(kv_valid[:, None, None, :], s * scale, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("nhgs,nshd->nhgd", p.astype(v.dtype), v)
    return o.reshape(n, hq, d)


def paged_decode_ref(
    q: jax.Array,
    arena_k: jax.Array,
    arena_v: jax.Array,
    page_ids: jax.Array,
    slot_pos: jax.Array,
    *,
    layer: int,
    rope_theta: float,
) -> jax.Array:
    """Materializing oracle for `paged_attention.kernel`.

    q: (N, Hq, Dh) post-RoPE single-token queries;
    arena_k/arena_v: (P, page, L, Hkv, Dh) paged pool (keys pre-RoPE);
    page_ids: (N, Pmax) physical page per referenced page-view column;
    slot_pos: (N, Pmax, page) logical position served by each slot of
    the referenced page, or -1 for slots holding no live token of the
    row.  -> (N, Hq, Dh).
    """
    n, pmax = page_ids.shape
    page = arena_k.shape[1]
    hkv, d = arena_k.shape[3], arena_k.shape[4]
    flat = page_ids.reshape(-1)
    kg = jnp.take(arena_k[:, :, layer], flat, axis=0)
    vg = jnp.take(arena_v[:, :, layer], flat, axis=0)
    kg = kg.reshape(n, pmax * page, hkv, d)
    vg = vg.reshape(n, pmax * page, hkv, d)
    pos = slot_pos.reshape(n, pmax * page)
    kg = rope_rotate(kg, pos[:, :, None], rope_theta)
    return masked_decode_attention_ref(q, kg, vg, pos >= 0)
