"""Pallas TPU fused paged-decode attention kernel.

Decode's steady state is one query token per request attending over that
request's whole paged KV — ROADMAP open item 1.  The jnp gather path
materializes every request's K/V as an (N, S, L, Hkv, Dh) tensor first;
this kernel never does: the per-request **page view** (`kv_pool.
page_views`) is scalar-prefetched, so the BlockSpec index map reads each
referenced physical page of the arena directly — the indirection happens
in the DMA descriptor, not as a gather in HBM.

Tiling: grid (N, Hkv, Pmax) with the trailing page axis sequential on
TPU, so the (m, l, acc) running-softmax state lives in VMEM scratch
across a row's pages — the flash recurrence, one KV tile per physical
page.  GQA folds the `n_heads // n_kv_heads` group axis into the query
block: queries arrive as (N, Hkv, G_pad, Dh), so each KV head's pages
stream through VMEM exactly once per request while all of its grouped
query heads ride in the same q tile.

Per-slot `slot_pos` carries each arena slot's *logical* position
(-1 = slot holds no live token of this row): it is simultaneously the
key-liveness mask (ragged lengths, pad slots, interleaved store/private
slots at arbitrary alignment) and the RoPE realignment angle — keys are
stored pre-RoPE, so the kernel fuses the one rotation decode needs
(group property) right before the dot product.  Causality never needs
checking: the newest token is, by construction, the largest live
position in its row, so key-liveness IS the causal mask.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.paged_attention.ref import NEG_INF


def _paged_decode_kernel(
    pids_ref,
    spos_ref,
    q_ref,
    k_ref,
    v_ref,
    o_ref,
    m_scr,
    l_scr,
    acc_scr,
    *,
    sm_scale: float,
    rope_theta: float,
    head_dim: int,
):
    j = pl.program_id(2)
    nj = pl.num_programs(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    pos = spos_ref[0, 0]  # (page,) logical or -1
    live = pos >= 0

    # pad pages (and store pages none of whose slots serve this row)
    # carry no live slot: skip their rotate+matmul entirely.  Skipped
    # blocks leave (m, l, acc) untouched, which the flash recurrence is
    # already exact under — a masked-out block contributes corr=1, p=0.
    @pl.when(jnp.any(live))
    def _attend():
        q = q_ref[0, 0]  # (g_pad, d)
        k = k_ref[0, :, 0, 0].astype(jnp.float32)  # (page, d) pre-RoPE
        v = v_ref[0, :, 0, 0]
        half = head_dim // 2
        freqs = 1.0 / (rope_theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
        ang = pos[:, None].astype(jnp.float32) * freqs[None, :]
        cos, sin = jnp.cos(ang), jnp.sin(ang)
        k1, k2 = k[:, :half], k[:, half:]
        k = jnp.concatenate([k1 * cos - k2 * sin, k1 * sin + k2 * cos], axis=-1)
        s = jax.lax.dot_general(
            q,
            k.astype(q.dtype),
            (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        s = jnp.where(live[None, :], s * sm_scale, NEG_INF)

        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + p.sum(axis=1)
        pv = jax.lax.dot_general(
            p.astype(v.dtype),
            v,
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        acc_scr[...] = acc_scr[...] * corr[:, None] + pv
        m_scr[...] = m_new
        l_scr[...] = l_new

    @pl.when(j == nj - 1)
    def _finish():
        denom = jnp.maximum(l_scr[...], 1e-30)[:, None]
        o_ref[0, 0] = (acc_scr[...] / denom).astype(o_ref.dtype)


def paged_decode_attention(
    q: jax.Array,
    arena_k: jax.Array,
    arena_v: jax.Array,
    page_ids: jax.Array,
    slot_pos: jax.Array,
    *,
    layer: int,
    rope_theta: float = 10_000.0,
    interpret: bool = False,
) -> jax.Array:
    """q: (N, Hkv, G_pad, Dh) post-RoPE queries, group axis pre-padded by
    the ops wrapper; arena_k/arena_v: (P, page, L, Hkv, Dh) paged pool
    (keys pre-RoPE); page_ids: (N, Pmax) int32 physical page per view
    column; slot_pos: (N, Pmax, page) int32 logical position per slot or
    -1.  `layer` is static — one pallas_call per layer reads only that
    layer's plane of each referenced page.  -> (N, Hkv, G_pad, Dh).
    """
    n, hkv, g_pad, d = q.shape
    page = arena_k.shape[1]
    pmax = page_ids.shape[1]

    kernel = functools.partial(
        _paged_decode_kernel,
        sm_scale=1.0 / d**0.5,
        rope_theta=rope_theta,
        head_dim=d,
    )
    arena_spec = pl.BlockSpec(
        (1, page, 1, 1, d), lambda i, h, j, pids: (pids[i, j], 0, layer, h, 0)
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n, hkv, pmax),
        in_specs=[
            pl.BlockSpec((1, 1, page), lambda i, h, j, pids: (i, j, 0)),
            pl.BlockSpec((1, 1, g_pad, d), lambda i, h, j, pids: (i, h, 0, 0)),
            arena_spec,
            arena_spec,
        ],
        out_specs=pl.BlockSpec((1, 1, g_pad, d), lambda i, h, j, pids: (i, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g_pad,), jnp.float32),
            pltpu.VMEM((g_pad,), jnp.float32),
            pltpu.VMEM((g_pad, d), jnp.float32),
        ],
    )
    fn = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n, hkv, g_pad, d), q.dtype),
        interpret=interpret,
    )
    return fn(
        page_ids.astype(jnp.int32), slot_pos.astype(jnp.int32), q, arena_k, arena_v
    )
