"""JAX-facing wrapper for the fused paged-decode attention kernel.

`paged_decode_mha` takes the serving layout — (N, Hq, Dh) single-token
queries and the pool arenas — folds the GQA group axis into the query
tile (padded to `q_block` so tiny group factors still fill the MXU's
sublane dimension), and dispatches one kernel launch for one layer.
The layer index is static: the decode step's Python layer loop issues
one call per layer, and each call's BlockSpec index maps touch only
that layer's (page, Hkv, Dh) planes of the referenced pages.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.paged_attention.kernel import paged_decode_attention


@functools.partial(
    jax.jit, static_argnames=("layer", "rope_theta", "q_block", "interpret")
)
def paged_decode_mha(
    q: jax.Array,
    arena_k: jax.Array,
    arena_v: jax.Array,
    page_ids: jax.Array,
    slot_pos: jax.Array,
    *,
    layer: int,
    rope_theta: float = 10_000.0,
    q_block: int = 8,
    interpret: bool = False,
) -> jax.Array:
    """q: (N, Hq, Dh) post-RoPE decode queries; arena_k/arena_v:
    (P, page, L, Hkv, Dh) paged pool; page_ids: (N, Pmax); slot_pos:
    (N, Pmax, page) logical position per slot or -1 (see
    `kv_pool.page_views`).  -> (N, Hq, Dh).
    """
    n, hq, d = q.shape
    hkv = arena_k.shape[3]
    g = hq // hkv
    if g * hkv != hq:
        raise ValueError(f"n_heads {hq} not divisible by n_kv_heads {hkv}")
    g_pad = -(-g // q_block) * q_block
    qg = q.reshape(n, hkv, g, d)
    if g_pad != g:
        qg = jnp.pad(qg, ((0, 0), (0, 0), (0, g_pad - g), (0, 0)))
    out = paged_decode_attention(
        qg,
        arena_k,
        arena_v,
        page_ids,
        slot_pos,
        layer=layer,
        rope_theta=rope_theta,
        interpret=interpret,
    )
    return out[:, :, :g].reshape(n, hq, d)
