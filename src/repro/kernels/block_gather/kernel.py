"""Pallas TPU paged-KV block-gather kernel with fused RoPE realignment.

The TPU-native 'zero-copy assembly' (§III-C2a): logical prompt pages map to
scattered physical pages of the KV pool via a block table.  The page id is
*scalar-prefetched* so the BlockSpec index_map itself performs the
indirection — the kernel body only rotates the keys to their request
positions (RoPE group property: cached pre-RoPE keys → one rotation).
No contiguous copy of the pool ever exists.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gather_kernel(block_table_ref, pos_ref, k_page_ref, v_page_ref,
                   k_out_ref, v_out_ref, *, page_size: int, head_dim: int,
                   rope_theta: float, rotate: bool):
    # k_page_ref: (1, page_size, d) — the physical page selected by the
    # scalar-prefetched block table via the index_map.
    k = k_page_ref[0].astype(jnp.float32)            # (page, d)
    v = v_page_ref[0]
    if rotate:
        pos = pos_ref[0]                             # (page,) target positions
        half = head_dim // 2
        freqs = 1.0 / (rope_theta **
                       (jnp.arange(0, half, dtype=jnp.float32) / half))
        ang = pos[:, None].astype(jnp.float32) * freqs[None, :]
        cos, sin = jnp.cos(ang), jnp.sin(ang)
        k1, k2 = k[:, :half], k[:, half:]
        k = jnp.concatenate([k1 * cos - k2 * sin, k1 * sin + k2 * cos],
                            axis=-1)
    k_out_ref[0] = k.astype(k_out_ref.dtype)
    v_out_ref[0] = v


def block_gather(kv_pool_k: jax.Array, kv_pool_v: jax.Array,
                 block_table: jax.Array, positions: jax.Array, *,
                 rope_theta: float = 10_000.0, rotate: bool = True,
                 interpret: bool = False):
    """kv_pool_{k,v}: (n_pages, page_size, d) physical pool (keys pre-RoPE);
    block_table: (n_logical,) int32 physical page per logical page;
    positions: (n_logical, page_size) target absolute positions.
    -> assembled (k, v): (n_logical, page_size, d)."""
    n_pages, page_size, d = kv_pool_k.shape
    n_logical = block_table.shape[0]

    kernel = functools.partial(_gather_kernel, page_size=page_size,
                               head_dim=d, rope_theta=rope_theta,
                               rotate=rotate)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_logical,),
        in_specs=[
            pl.BlockSpec((1, page_size), lambda i, bt: (i, 0)),   # positions
            pl.BlockSpec((1, page_size, d), lambda i, bt: (bt[i], 0, 0)),
            pl.BlockSpec((1, page_size, d), lambda i, bt: (bt[i], 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, page_size, d), lambda i, bt: (i, 0, 0)),
            pl.BlockSpec((1, page_size, d), lambda i, bt: (i, 0, 0)),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((n_logical, page_size, d), kv_pool_k.dtype),
            jax.ShapeDtypeStruct((n_logical, page_size, d), kv_pool_v.dtype),
        ],
        interpret=interpret,
    )(block_table.astype(jnp.int32), positions.astype(jnp.int32),
      kv_pool_k, kv_pool_v)
