"""Pure-jnp oracle for the paged block-gather + RoPE realignment."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rope_rotate(k: jax.Array, positions: jax.Array,
                theta: float) -> jax.Array:
    """k: (..., d) pre-RoPE keys; positions broadcastable to k[..., 0]."""
    d = k.shape[-1]
    half = d // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions[..., None].astype(jnp.float32) * freqs
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    k1, k2 = k[..., :half].astype(jnp.float32), k[..., half:].astype(jnp.float32)
    out = jnp.concatenate([k1 * cos - k2 * sin, k1 * sin + k2 * cos], axis=-1)
    return out.astype(k.dtype)


def block_gather_ref(kv_pool_k, kv_pool_v, block_table, positions, *,
                     rope_theta: float = 10_000.0, rotate: bool = True):
    k = jnp.take(kv_pool_k, block_table, axis=0)     # (n_logical, page, d)
    v = jnp.take(kv_pool_v, block_table, axis=0)
    if rotate:
        k = rope_rotate(k, positions, rope_theta)
    return k, v
