"""Jit'd wrapper for the paged block-gather kernel."""
from __future__ import annotations

import functools

import jax

from repro.kernels.block_gather.kernel import block_gather


@functools.partial(jax.jit,
                   static_argnames=("rope_theta", "rotate", "interpret"))
def assemble_kv(kv_pool_k, kv_pool_v, block_table, positions, *,
                rope_theta: float = 10_000.0, rotate: bool = True,
                interpret: bool = False):
    return block_gather(kv_pool_k, kv_pool_v, block_table, positions,
                        rope_theta=rope_theta, rotate=rotate,
                        interpret=interpret)
