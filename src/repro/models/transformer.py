"""Decoder-only transformer LM with scan-over-layers.

Parameters are stored *stacked* over the layer dimension so the whole stack
lowers to a single `lax.scan` body — keeping HLO size and compile time O(1)
in depth (61-layer / 1T-param configs compile on one CPU core).

Three entry points (the dry-run lowers exactly these):
  * ``train_step``   — next-token loss + grads + optimizer update
  * ``prefill``      — full-prompt forward, returns last-position logits + KV cache
  * ``decode_step``  — one token against a KV cache (serve_step for decode shapes)
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import LMConfig
from repro.models import layers as L

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def init_params(key: jax.Array, cfg: LMConfig) -> Params:
    dt = jnp.dtype(cfg.dtype)
    dh = cfg.resolved_head_dim
    D = cfg.d_model
    keys = jax.random.split(key, 8)

    def stack_init(fn, key, n):
        ks = jax.random.split(key, n)
        return jax.vmap(fn)(ks)

    def layer_init(k):
        ka, kb = jax.random.split(k)
        std = D ** -0.5
        p = {
            "attn_norm": jnp.zeros((D,), dt),
            "mlp_norm": jnp.zeros((D,), dt),
            "wq": jax.random.normal(ka, (D, cfg.n_heads, dh), dt) * std,
            "wk": jax.random.normal(jax.random.fold_in(ka, 1),
                                    (D, cfg.n_kv_heads, dh), dt) * std,
            "wv": jax.random.normal(jax.random.fold_in(ka, 2),
                                    (D, cfg.n_kv_heads, dh), dt) * std,
            "wo": jax.random.normal(jax.random.fold_in(ka, 3),
                                    (cfg.n_heads, dh, D), dt) * (cfg.n_heads * dh) ** -0.5,
        }
        if cfg.moe is not None:
            p["moe"] = L.moe_init(kb, D, cfg.moe, cfg.mlp_type, dt)
        else:
            p["mlp"] = L.mlp_init(kb, D, cfg.d_ff, cfg.mlp_type, dt)
        return p

    params: Params = {
        "embed": jax.random.normal(keys[0], (cfg.vocab_size, D), dt) * 1.0,
        "layers": stack_init(layer_init, keys[1], cfg.n_layers),
        "final_norm": jnp.zeros((D,), dt),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = jax.random.normal(keys[2], (D, cfg.vocab_size), dt) * D ** -0.5
    return params


def abstract_params(cfg: LMConfig) -> Params:
    """Parameter ShapeDtypeStructs without allocation (for the dry-run)."""
    return jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

def _attention_block(x, lp, cfg: LMConfig, positions, *, causal=True,
                     block_pairing=False):
    h = L.rms_norm(x, lp["attn_norm"], cfg.norm_eps)
    q = jnp.einsum("bsd,dhe->bshe", h, lp["wq"])
    k = jnp.einsum("bsd,dhe->bshe", h, lp["wk"])
    v = jnp.einsum("bsd,dhe->bshe", h, lp["wv"])
    q = L.apply_rope(q, positions, cfg.rope_theta)
    k = L.apply_rope(k, positions, cfg.rope_theta)
    o = L.chunked_attention(
        q, k, v, causal=causal, q_positions=positions, kv_positions=positions,
        sliding_window=cfg.sliding_window,
        q_chunk=cfg.attn_q_chunk, kv_chunk=cfg.attn_kv_chunk,
        block_pairing=block_pairing)
    return jnp.einsum("bshe,hed->bsd", o, lp["wo"]), (k, v)


def _ffn_block(x, lp, cfg: LMConfig):
    h = L.rms_norm(x, lp["mlp_norm"], cfg.norm_eps)
    if cfg.moe is not None:
        B, S, D = h.shape
        y, aux = L.moe_apply(h.reshape(B * S, D), lp["moe"],
                             n_experts=cfg.moe.n_experts, top_k=cfg.moe.top_k,
                             capacity_factor=cfg.moe.capacity_factor,
                             mlp_type=cfg.mlp_type)
        return y.reshape(B, S, D), aux
    return L.mlp_apply(h, lp["mlp"], cfg.mlp_type), jnp.float32(0.0)


def forward(params: Params, tokens: jax.Array, cfg: LMConfig,
            *, return_cache: bool = False,
            collect_attn_stats: bool = False):
    """tokens: (B, S) -> logits (B, S, V); optionally the per-layer KV cache."""
    B, S = tokens.shape
    x = params["embed"][tokens].astype(jnp.dtype(cfg.dtype))
    if cfg.tie_embeddings:
        x = x * (cfg.d_model ** 0.5)
    positions = jnp.arange(S)

    from repro.sharding import ctx as SHCTX

    def body(carry, lp):
        x, aux = carry
        attn_out, (k, v) = _attention_block(
            x, lp, cfg, positions, block_pairing=cfg.causal_block_pairing)
        x = x + attn_out
        ffn_out, aux_l = _ffn_block(x, lp, cfg)
        x = x + ffn_out
        # Megatron-style sequence sharding of the saved residual stream:
        # the (L, B, S, D) activation stack that backward needs shrinks by
        # the model-axis size; attention/FFN re-gather S internally.
        x = SHCTX.hint(x, "dp", "mp", None)
        out = (k, v) if return_cache else None
        return (x, aux + aux_l), out

    body_fn = body
    if cfg.remat:
        body_fn = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    (x, aux), caches = lax.scan(body_fn, (x, jnp.float32(0.0)), params["layers"])

    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, head)
    if return_cache:
        # caches: tuple of stacked (L, B, S, Hkv, Dh)
        cache = {"k": caches[0], "v": caches[1]}
        return logits, aux, cache
    return logits, aux


# ---------------------------------------------------------------------------
# Steps
# ---------------------------------------------------------------------------

def loss_fn(params: Params, tokens, labels, cfg: LMConfig):
    logits, aux = forward(params, tokens, cfg)
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = (logz - gold).mean()
    return nll + 0.01 * aux, nll


def prefill(params: Params, tokens: jax.Array, cfg: LMConfig):
    """Prompt prefill: returns last-token logits (the TTFT-critical output)
    and the populated KV cache."""
    logits, _, cache = forward(params, tokens, cfg, return_cache=True)
    return logits[:, -1], cache


def decode_step(params: Params, tokens: jax.Array, cache: Dict[str, jax.Array],
                positions: jax.Array, cfg: LMConfig):
    """One decode step. tokens: (B, 1); cache[k|v]: (L, B, S, Hkv, Dh);
    positions: (B,) current lengths. Returns (logits, new_cache)."""
    B = tokens.shape[0]
    x = params["embed"][tokens[:, 0]].astype(jnp.dtype(cfg.dtype))[:, None]
    if cfg.tie_embeddings:
        x = x * (cfg.d_model ** 0.5)

    def body(x, inputs):
        lp, k_cache, v_cache = inputs
        h = L.rms_norm(x, lp["attn_norm"], cfg.norm_eps)
        q = jnp.einsum("bsd,dhe->bshe", h, lp["wq"])
        k = jnp.einsum("bsd,dhe->bshe", h, lp["wk"])
        v = jnp.einsum("bsd,dhe->bshe", h, lp["wv"])
        q = L.apply_rope(q, positions[:, None], cfg.rope_theta)
        k = L.apply_rope(k, positions[:, None], cfg.rope_theta)
        bidx = jnp.arange(B)
        k_cache = k_cache.at[bidx, positions].set(k[:, 0])
        v_cache = v_cache.at[bidx, positions].set(v[:, 0])
        o = L.decode_attention(q, k_cache, v_cache, positions + 1,
                               sliding_window=cfg.sliding_window)
        x = x + jnp.einsum("bshe,hed->bsd", o, lp["wo"])
        ffn_out, _ = _ffn_block(x, lp, cfg)
        return x + ffn_out, (k_cache, v_cache)

    x, (new_k, new_v) = lax.scan(
        body, x, (params["layers"], cache["k"], cache["v"]))
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, head)[:, 0]
    return logits, {"k": new_k, "v": new_v}
