"""Transformer building blocks: RMSNorm, RoPE, chunked (flash-style) GQA
attention, dense MLPs, and sort-based top-k MoE with expert parallelism.

All attention paths avoid materializing O(S^2) score tensors: prefill/train
use a two-level scan over (q-chunk, kv-chunk) tiles with a running-softmax
carry (the standard FlashAttention recurrence, expressed in pure JAX so the
CPU dry-run lowers it; the Pallas TPU kernel in repro/kernels/flash_attention
implements the same tiling for real hardware).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

NEG_INF = -1e30


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return ((x * lax.rsqrt(var + eps)) * (1.0 + scale.astype(jnp.float32))).astype(dt)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                      # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, D/2)
    cos = jnp.cos(angles)[..., None, :]               # (..., S, 1, D/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def rope_realign(k: jax.Array, delta: jax.Array, theta: float) -> jax.Array:
    """Rotate cached keys by a position delta (RcLLM §III-C3 'Alignment').

    RoPE is a group action: R(p+d) = R(d) R(p), so a block cached at canonical
    positions can be realigned to its position in the assembled prompt by one
    extra rotation — no recomputation of the projection.
    k: (..., S, H, D), delta: scalar or (...,) offsets added to positions.
    """
    s = k.shape[-3]
    pos = jnp.zeros((s,), jnp.float32) + jnp.asarray(delta, jnp.float32)[..., None]
    return apply_rope(k, pos, theta)


# ---------------------------------------------------------------------------
# Chunked flash attention (pure JAX)
# ---------------------------------------------------------------------------

def _pad_dim(x: jax.Array, axis: int, mult: int):
    s = x.shape[axis]
    pad = (-s) % mult
    if pad == 0:
        return x, s
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), s


def _attn_impl(
    q: jax.Array,                      # (B, Sq, Hq, D)
    k: jax.Array,                      # (B, Skv, Hkv, D)
    v: jax.Array,                      # (B, Skv, Hkv, D)
    *,
    causal: bool,
    q_positions: jax.Array,            # (Sq,) absolute positions of queries
    kv_positions: jax.Array,           # (Skv,)
    kv_valid: Optional[jax.Array] = None,   # (B, Skv) bool — for padded caches
    sliding_window: Optional[int] = None,
    q_chunk: int = 512,
    kv_chunk: int = 1024,
    block_pairing: bool = False,
    extra_mask: Optional[jax.Array] = None,  # (Sq, Skv) bool, True = attend
    return_lse: bool = False,
):
    """FlashAttention recurrence over (q-chunk × kv-chunk) tiles.

    With ``block_pairing=True`` and causal masking, fully-masked kv chunks are
    skipped by enumerating only the (qi, kj <= qi-aligned) tile pairs — the
    §Perf 'causal block pairing' optimization (≈2× fewer attention FLOPs).
    """
    B, Sq, Hq, D = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    scale = 1.0 / (D ** 0.5)

    q, _ = _pad_dim(q, 1, q_chunk)
    qpos_p, _ = _pad_dim(q_positions, 0, q_chunk)
    k, _ = _pad_dim(k, 1, kv_chunk)
    v, _ = _pad_dim(v, 1, kv_chunk)
    kpos_p, Skv0 = _pad_dim(kv_positions, 0, kv_chunk)
    kv_pad_valid = jnp.arange(k.shape[1]) < Skv0      # (Skv_p,)
    if kv_valid is not None:
        kv_valid_p, _ = _pad_dim(kv_valid, 1, kv_chunk)
        kv_valid_p = kv_valid_p & kv_pad_valid[None, :]
    else:
        kv_valid_p = jnp.broadcast_to(kv_pad_valid[None, :], (B, k.shape[1]))
    if extra_mask is not None:
        em, _ = _pad_dim(extra_mask, 0, q_chunk)
        em, _ = _pad_dim(em, 1, kv_chunk)
    else:
        em = None

    nq = q.shape[1] // q_chunk
    nk = k.shape[1] // kv_chunk
    qr = q.reshape(B, nq, q_chunk, Hkv, G, D)
    kr = k.reshape(B, nk, kv_chunk, Hkv, D)
    vr = v.reshape(B, nk, kv_chunk, Hkv, D)
    qpos_r = qpos_p.reshape(nq, q_chunk)
    kpos_r = kpos_p.reshape(nk, kv_chunk)
    kval_r = kv_valid_p.reshape(B, nk, kv_chunk)

    def tile(qc, qpos, kc, vc, kpos, kval, emc, m, l, acc):
        # qc: (B, qC, Hkv, G, D)  kc/vc: (B, kC, Hkv, D)
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qc, kc,
                       preferred_element_type=jnp.float32) * scale
        mask = kval[:, None, None, None, :]                    # (B,1,1,1,kC)
        if causal:
            cm = qpos[:, None] >= kpos[None, :]                # (qC, kC)
            if sliding_window is not None:
                cm &= (qpos[:, None] - kpos[None, :]) < sliding_window
            mask = mask & cm[None, None, None, :, :]
        elif sliding_window is not None:
            cm = jnp.abs(qpos[:, None] - kpos[None, :]) < sliding_window
            mask = mask & cm[None, None, None, :, :]
        if emc is not None:
            mask = mask & emc[None, None, None, :, :]
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhgqk,bkhd->bhgqd", p.astype(vc.dtype), vc,
            preferred_element_type=jnp.float32)
        return m_new, l_new, acc_new

    def init_carry():
        m = jnp.full((B, Hkv, G, q_chunk), NEG_INF, jnp.float32)
        l = jnp.zeros((B, Hkv, G, q_chunk), jnp.float32)
        acc = jnp.zeros((B, Hkv, G, q_chunk, D), jnp.float32)
        return m, l, acc

    if block_pairing and causal and em is None:
        # enumerate only live (q-chunk, kv-chunk) tile pairs; q/kv chunk grids
        # are aligned via positions so tile (qi, kj) is live iff
        # max(qpos[qi]) >= min(kpos[kj]).  Static for self-attention.
        # valid only for self-attention with positions == arange (asserted by
        # caller); tile (qi, kj) is live iff its last query can see the first
        # key of the kv chunk.
        outs, lses = [], []
        for qi in range(nq):
            m, l, acc = init_carry()
            live = [kj for kj in range(nk)
                    if (qi + 1) * q_chunk - 1 >= kj * kv_chunk]
            for kj in live:
                m, l, acc = tile(qr[:, qi], qpos_r[qi], kr[:, kj], vr[:, kj],
                                 kpos_r[kj], kval_r[:, kj], None, m, l, acc)
            outs.append(acc / jnp.maximum(l[..., None], 1e-30))
            lses.append(m + jnp.log(jnp.maximum(l, 1e-30)))
        out = jnp.stack(outs, axis=1)                         # (B,nq,Hkv,G,qC,D)
        lse = jnp.stack(lses, axis=1)                         # (B,nq,Hkv,G,qC)
    else:
        def q_step(qi):
            qc = lax.dynamic_index_in_dim(qr, qi, 1, keepdims=False)
            qpos = lax.dynamic_index_in_dim(qpos_r, qi, 0, keepdims=False)

            def kv_step(carry, kj):
                m, l, acc = carry
                kc = lax.dynamic_index_in_dim(kr, kj, 1, keepdims=False)
                vc = lax.dynamic_index_in_dim(vr, kj, 1, keepdims=False)
                kpos = lax.dynamic_index_in_dim(kpos_r, kj, 0, keepdims=False)
                kval = lax.dynamic_index_in_dim(kval_r, kj, 1, keepdims=False)
                emc = None
                if em is not None:
                    emq = lax.dynamic_slice_in_dim(em, qi * q_chunk, q_chunk, 0)
                    emc = lax.dynamic_slice_in_dim(emq, kj * kv_chunk, kv_chunk, 1)
                return tile(qc, qpos, kc, vc, kpos, kval, emc, m, l, acc), None

            (m, l, acc), _ = lax.scan(kv_step, init_carry(), jnp.arange(nk))
            return (acc / jnp.maximum(l[..., None], 1e-30),
                    m + jnp.log(jnp.maximum(l, 1e-30)))

        out, lse = lax.map(q_step, jnp.arange(nq))            # (nq,B,Hkv,G,qC,·)
        out = jnp.moveaxis(out, 0, 1)
        lse = jnp.moveaxis(lse, 0, 1)

    out = out.transpose(0, 1, 4, 2, 3, 5).reshape(B, nq * q_chunk, Hq, D)
    out = out[:, :Sq].astype(q.dtype)
    if return_lse:
        # lse: (B, nq, Hkv, G, qC) -> (B, Sq, Hkv, G)
        lse = lse.transpose(0, 1, 4, 2, 3).reshape(B, nq * q_chunk, Hkv, G)
        return out, lse[:, :Sq]
    return out


def chunked_attention(q, k, v, *, causal, q_positions, kv_positions,
                      kv_valid=None, sliding_window=None, q_chunk=512,
                      kv_chunk=1024, block_pairing=False, extra_mask=None):
    """Public flash attention.  The differentiable path (self-attention in
    training) routes through a custom VJP whose backward recomputes tiles —
    naive autodiff of the scan stores O(S²/chunk) fp32 softmax stats
    (measured 51 GB/device on the 15B train cell)."""
    if kv_valid is None and extra_mask is None:
        return _flash(q, k, v, q_positions, kv_positions, causal,
                      sliding_window, q_chunk, kv_chunk, block_pairing)
    return _attn_impl(q, k, v, causal=causal, q_positions=q_positions,
                      kv_positions=kv_positions, kv_valid=kv_valid,
                      sliding_window=sliding_window, q_chunk=q_chunk,
                      kv_chunk=kv_chunk, block_pairing=block_pairing,
                      extra_mask=extra_mask)


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6, 7, 8, 9))
def _flash(q, k, v, q_positions, kv_positions, causal, sliding_window,
           q_chunk, kv_chunk, block_pairing):
    return _attn_impl(q, k, v, causal=causal, q_positions=q_positions,
                      kv_positions=kv_positions,
                      sliding_window=sliding_window, q_chunk=q_chunk,
                      kv_chunk=kv_chunk, block_pairing=block_pairing)


def _flash_fwd(q, k, v, q_positions, kv_positions, causal, sliding_window,
               q_chunk, kv_chunk, block_pairing):
    out, lse = _attn_impl(q, k, v, causal=causal, q_positions=q_positions,
                          kv_positions=kv_positions,
                          sliding_window=sliding_window, q_chunk=q_chunk,
                          kv_chunk=kv_chunk, block_pairing=block_pairing,
                          return_lse=True)
    return out, (q, k, v, q_positions, kv_positions, out, lse)


def _flash_bwd(causal, sliding_window, q_chunk, kv_chunk, block_pairing,
               res, dout):
    q, k, v, q_positions, kv_positions, out, lse = res
    B, Sq, Hq, D = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    scale = 1.0 / (D ** 0.5)

    # delta_i = rowsum(dout ⊙ out): the softmax-backward correction term
    delta = jnp.einsum("bshd,bshd->bsh", dout.astype(jnp.float32),
                       out.astype(jnp.float32))               # (B, Sq, Hq)

    qp, _ = _pad_dim(q, 1, q_chunk)
    dop, _ = _pad_dim(dout, 1, q_chunk)
    dlp, _ = _pad_dim(delta, 1, q_chunk)
    lsep, _ = _pad_dim(lse, 1, q_chunk)
    qpos_p, _ = _pad_dim(q_positions, 0, q_chunk)
    kp, Skv0 = _pad_dim(k, 1, kv_chunk)
    vp, _ = _pad_dim(v, 1, kv_chunk)
    kpos_p, _ = _pad_dim(kv_positions, 0, kv_chunk)
    kvalid = jnp.arange(kp.shape[1]) < Skv0

    nq = qp.shape[1] // q_chunk
    nk = kp.shape[1] // kv_chunk
    qr = qp.reshape(B, nq, q_chunk, Hkv, G, D)
    dor = dop.reshape(B, nq, q_chunk, Hkv, G, D)
    dlr = dlp.reshape(B, nq, q_chunk, Hkv, G)
    lser = lsep.reshape(B, nq, q_chunk, Hkv, G)
    kr = kp.reshape(B, nk, kv_chunk, Hkv, D)
    vr = vp.reshape(B, nk, kv_chunk, Hkv, D)
    qpos_r = qpos_p.reshape(nq, q_chunk)
    kpos_r = kpos_p.reshape(nk, kv_chunk)
    kval_r = kvalid.reshape(nk, kv_chunk)

    def tile_mask(qpos, kpos, kval):
        mask = kval[None, :]
        if causal:
            cm = qpos[:, None] >= kpos[None, :]
            if sliding_window is not None:
                cm &= (qpos[:, None] - kpos[None, :]) < sliding_window
            mask = mask & cm
        elif sliding_window is not None:
            mask = mask & (jnp.abs(qpos[:, None] - kpos[None, :])
                           < sliding_window)
        return mask                                            # (qC, kC)

    def p_ds(qi_data, kc, kpos, kval):
        qc, doc, dlc, lsec, qpos = qi_data
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qc, kc,
                       preferred_element_type=jnp.float32) * scale
        mask = tile_mask(qpos, kpos, kval)
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        p = jnp.exp(s - lsec.transpose(0, 2, 3, 1)[..., None])  # (B,h,g,q,k)
        return p

    # pass 1: dq — map over q chunks, scan over kv chunks
    def dq_step(qi):
        qc = lax.dynamic_index_in_dim(qr, qi, 1, keepdims=False)
        doc = lax.dynamic_index_in_dim(dor, qi, 1, keepdims=False)
        dlc = lax.dynamic_index_in_dim(dlr, qi, 1, keepdims=False)
        lsec = lax.dynamic_index_in_dim(lser, qi, 1, keepdims=False)
        qpos = lax.dynamic_index_in_dim(qpos_r, qi, 0, keepdims=False)

        def kv_step(dq, kj):
            kc = lax.dynamic_index_in_dim(kr, kj, 1, keepdims=False)
            vc = lax.dynamic_index_in_dim(vr, kj, 1, keepdims=False)
            kpos = lax.dynamic_index_in_dim(kpos_r, kj, 0, keepdims=False)
            kval = lax.dynamic_index_in_dim(kval_r, kj, 0, keepdims=False)
            p = p_ds((qc, doc, dlc, lsec, qpos), kc, kpos, kval)
            dp = jnp.einsum("bqhgd,bkhd->bhgqk", doc, vc,
                            preferred_element_type=jnp.float32)
            ds = p * (dp - dlc.transpose(0, 2, 3, 1)[..., None]) * scale
            dq = dq + jnp.einsum("bhgqk,bkhd->bqhgd", ds.astype(kc.dtype), kc,
                                 preferred_element_type=jnp.float32)
            return dq, None

        dq0 = jnp.zeros((B, q_chunk, Hkv, G, D), jnp.float32)
        dq, _ = lax.scan(kv_step, dq0, jnp.arange(nk))
        return dq

    dq = lax.map(dq_step, jnp.arange(nq))                      # (nq,B,qC,...)
    dq = jnp.moveaxis(dq, 0, 1).reshape(B, nq * q_chunk, Hq, D)[:, :Sq]

    # pass 2: dk/dv — map over kv chunks, scan over q chunks
    def dkv_step(kj):
        kc = lax.dynamic_index_in_dim(kr, kj, 1, keepdims=False)
        vc = lax.dynamic_index_in_dim(vr, kj, 1, keepdims=False)
        kpos = lax.dynamic_index_in_dim(kpos_r, kj, 0, keepdims=False)
        kval = lax.dynamic_index_in_dim(kval_r, kj, 0, keepdims=False)

        def q_step(carry, qi):
            dk, dv = carry
            qc = lax.dynamic_index_in_dim(qr, qi, 1, keepdims=False)
            doc = lax.dynamic_index_in_dim(dor, qi, 1, keepdims=False)
            dlc = lax.dynamic_index_in_dim(dlr, qi, 1, keepdims=False)
            lsec = lax.dynamic_index_in_dim(lser, qi, 1, keepdims=False)
            qpos = lax.dynamic_index_in_dim(qpos_r, qi, 0, keepdims=False)
            p = p_ds((qc, doc, dlc, lsec, qpos), kc, kpos, kval)
            dv = dv + jnp.einsum("bhgqk,bqhgd->bkhd", p.astype(doc.dtype),
                                 doc, preferred_element_type=jnp.float32)
            dp = jnp.einsum("bqhgd,bkhd->bhgqk", doc, vc,
                            preferred_element_type=jnp.float32)
            ds = p * (dp - dlc.transpose(0, 2, 3, 1)[..., None]) * scale
            dk = dk + jnp.einsum("bhgqk,bqhgd->bkhd", ds.astype(qc.dtype), qc,
                                 preferred_element_type=jnp.float32)
            return (dk, dv), None

        z = jnp.zeros((B, kv_chunk, Hkv, D), jnp.float32)
        (dk, dv), _ = lax.scan(q_step, (z, z), jnp.arange(nq))
        return dk, dv

    dk, dv = lax.map(dkv_step, jnp.arange(nk))                 # (nk,B,kC,...)
    dk = jnp.moveaxis(dk, 0, 1).reshape(B, nk * kv_chunk, Hkv, D)[:, :k.shape[1]]
    dv = jnp.moveaxis(dv, 0, 1).reshape(B, nk * kv_chunk, Hkv, D)[:, :k.shape[1]]

    return (dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype),
            None, None)


_flash.defvjp(_flash_fwd, _flash_bwd)


def decode_attention(
    q: jax.Array,                      # (B, 1, Hq, D) — one new token
    k_cache: jax.Array,                # (B, S, Hkv, D)
    v_cache: jax.Array,
    positions: jax.Array,              # (B,) current length per sequence
    *,
    sliding_window: Optional[int] = None,
) -> jax.Array:
    """Single-token attention against a (possibly huge) KV cache.

    O(S·D): one masked matvec per head.  Under GSPMD a sequence-sharded cache
    yields partial max/sum per shard which XLA combines with all-reduce —
    the flash-decoding split-K pattern.
    """
    B, S, Hkv, D = k_cache.shape
    Hq = q.shape[2]
    G = Hq // Hkv
    scale = 1.0 / (D ** 0.5)
    qr = q.reshape(B, Hkv, G, D)
    s = jnp.einsum("bhgd,bshd->bhgs", qr, k_cache,
                   preferred_element_type=jnp.float32) * scale
    idx = jnp.arange(S)[None, :]                       # (1, S)
    valid = idx < positions[:, None]
    if sliding_window is not None:
        valid &= idx >= (positions[:, None] - sliding_window)
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, 1, Hq, D).astype(q.dtype)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def mlp_apply(x: jax.Array, params: dict, mlp_type: str) -> jax.Array:
    if mlp_type in ("swiglu", "geglu"):
        act = jax.nn.silu if mlp_type == "swiglu" else \
            functools.partial(jax.nn.gelu, approximate=True)
        h = act(x @ params["w_gate"]) * (x @ params["w_up"])
        return h @ params["w_down"]
    h = x @ params["w_up"]
    if mlp_type == "relu2":
        h = jnp.square(jax.nn.relu(h))
    elif mlp_type == "gelu":
        h = jax.nn.gelu(h, approximate=True)
    else:
        raise ValueError(mlp_type)
    return h @ params["w_down"]


def mlp_init(key, d_model: int, d_ff: int, mlp_type: str, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    std_in = d_model ** -0.5
    std_out = d_ff ** -0.5
    p = {"w_up": jax.random.normal(k1, (d_model, d_ff), dtype) * std_in,
         "w_down": jax.random.normal(k2, (d_ff, d_model), dtype) * std_out}
    if mlp_type in ("swiglu", "geglu"):
        p["w_gate"] = jax.random.normal(k3, (d_model, d_ff), dtype) * std_in
    return p


# ---------------------------------------------------------------------------
# Mixture of Experts — sort-based dispatch (expert parallel)
# ---------------------------------------------------------------------------

@jax.custom_vjp
def masked_perm_gather(x, idx, valid, dual_idx, dual_valid):
    """out[i] = valid[i] ? x[idx[i]] : 0, where idx restricted to valid
    entries is a partial permutation whose inverse is (dual_idx, dual_valid).

    The custom VJP turns the backward pass into *another gather* (by the dual
    index) instead of the scatter-add jax would emit — scatters make GSPMD
    replicate a (tokens·top_k, d_model) fp32 buffer (measured 51 GB/device on
    the 16B MoE train cell); gathers partition cleanly.
    """
    n = x.shape[0]
    out = jnp.take(x, jnp.clip(idx, 0, n - 1), axis=0)
    return jnp.where(valid[..., None], out, 0)


def _mpg_fwd(x, idx, valid, dual_idx, dual_valid):
    return masked_perm_gather(x, idx, valid, dual_idx, dual_valid), \
        (idx.size, dual_idx, dual_valid)


def _mpg_bwd(res, g):
    m, dual_idx, dual_valid = res
    gf = g.reshape(m, g.shape[-1])
    dx = jnp.take(gf, jnp.clip(dual_idx, 0, m - 1), axis=0)
    dx = jnp.where(dual_valid[..., None], dx, 0)
    return dx, None, None, None, None


masked_perm_gather.defvjp(_mpg_fwd, _mpg_bwd)


@jax.custom_vjp
def moe_dispatch(x, slot_tok, slot_valid, dest_tk, keep_tk):
    """Fused token→slot dispatch: out[e,c] = slot_valid ? x[slot_tok[e,c]] : 0.

    slot_tok (E,C): source token of each expert slot; (dest_tk, keep_tk)
    (T,K): the dual map (flat slot index fed by token t's k-th route).
    Backward = K gathers — never materializes a (T·K, D) buffer and never
    emits a scatter-add.
    """
    n = x.shape[0]
    out = jnp.take(x, jnp.clip(slot_tok, 0, n - 1), axis=0)
    return jnp.where(slot_valid[..., None], out, 0)


def _md_fwd(x, slot_tok, slot_valid, dest_tk, keep_tk):
    return moe_dispatch(x, slot_tok, slot_valid, dest_tk, keep_tk), \
        (dest_tk, keep_tk)


def _md_bwd(res, g):
    dest_tk, keep_tk = res
    ec = g.shape[0] * g.shape[1]
    gf = g.reshape(ec, g.shape[-1])
    k = dest_tk.shape[1]
    dx = None
    for j in range(k):
        dj = jnp.take(gf, jnp.clip(dest_tk[:, j], 0, ec - 1), axis=0)
        dj = jnp.where(keep_tk[:, j, None], dj, 0)
        dx = dj if dx is None else dx + dj
    return dx, None, None, None, None


moe_dispatch.defvjp(_md_fwd, _md_bwd)

def moe_apply(x: jax.Array, params: dict, *, n_experts: int, top_k: int,
              capacity_factor: float, mlp_type: str) -> Tuple[jax.Array, jax.Array]:
    """x: (T, D) -> (T, D), plus aux load-balancing loss.

    Sort-based dispatch: flatten (token, slot) assignments, order by expert,
    drop beyond capacity C, gather into a dense (E, C, D) buffer, run the
    expert MLPs as batched einsums (E sharded over the 'model' axis = EP),
    and scatter back weighted by the router gates.  No (T, E, C) one-hot
    dispatch tensor is ever materialized (GShard-style dispatch is O(T·E·C)
    memory — prohibitive at E=384).
    """
    from repro.sharding import ctx as SHCTX
    T, D = x.shape
    E, K = n_experts, top_k
    x = SHCTX.hint(x, "dp", None)
    logits = (x.astype(jnp.float32) @ params["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                    # (T, E)
    gate_vals, expert_idx = lax.top_k(probs, K)                # (T, K)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # aux loss (Switch-style): E * sum_e f_e * p_e
    density = jnp.mean(jax.nn.one_hot(expert_idx[:, 0], E), axis=0)
    mean_prob = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(density * mean_prob)

    C = max(1, int(capacity_factor * T * K / E))
    flat_e = expert_idx.reshape(T * K)

    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    seg_start = jnp.searchsorted(sorted_e, jnp.arange(E))      # (E,)
    pos = jnp.arange(T * K) - seg_start[sorted_e]
    keep = pos < C

    # Fused gather-only dispatch (see moe_dispatch): slot (e, c) reads sorted
    # position seg_start[e]+c, which is token order[...]//K.  Index plumbing
    # is int32 (T·K,) arrays; no (T·K, D) activation is ever materialized and
    # no scatter-add appears in fwd or bwd.
    inv_order = jnp.zeros((T * K,), jnp.int32).at[order].set(
        jnp.arange(T * K, dtype=jnp.int32))
    slot_idx = seg_start[:, None] + jnp.arange(C)[None, :]     # (E, C)
    slot_valid = (slot_idx < T * K) & \
        (jnp.take(sorted_e, jnp.clip(slot_idx, 0, T * K - 1)) ==
         jnp.arange(E)[:, None])
    slot_tok = jnp.take(order, jnp.clip(slot_idx, 0, T * K - 1)) // K
    dest = sorted_e * C + jnp.clip(pos, 0, C - 1)              # (T·K,) sorted
    dest_tk = jnp.take(dest, inv_order).reshape(T, K)          # dual, by (t,k)
    keep_tk = jnp.take(keep, inv_order).reshape(T, K)
    expert_in = moe_dispatch(x, slot_tok, slot_valid, dest_tk, keep_tk)
    expert_in = SHCTX.hint(expert_in, "mp", "dp", None)

    if mlp_type in ("swiglu", "geglu"):
        act = jax.nn.silu if mlp_type == "swiglu" else \
            functools.partial(jax.nn.gelu, approximate=True)
        h = act(jnp.einsum("ecd,edf->ecf", expert_in, params["w_gate"])) * \
            jnp.einsum("ecd,edf->ecf", expert_in, params["w_up"])
    else:
        h = jnp.einsum("ecd,edf->ecf", expert_in, params["w_up"])
        h = jnp.square(jax.nn.relu(h)) if mlp_type == "relu2" else \
            jax.nn.gelu(h, approximate=True)
    h = SHCTX.hint(h, "mp", "dp", None)
    expert_out = jnp.einsum("ecf,efd->ecd", h, params["w_down"])  # (E, C, D)
    expert_out = SHCTX.hint(expert_out, "mp", "dp", None)

    # combine: K per-route gathers straight from the expert outputs back to
    # token order (duals precomputed), gate-weighted sum.  Max intermediate
    # is one (T, D) buffer per route, fused by XLA into the accumulation.
    flat_out = expert_out.reshape(E * C, D)
    tk_of_slot = jnp.take(order, jnp.clip(slot_idx.reshape(-1), 0, T * K - 1))
    y = None
    for j in range(K):
        dual_valid_j = slot_valid.reshape(-1) & (tk_of_slot % K == j)
        yj = masked_perm_gather(flat_out, dest_tk[:, j], keep_tk[:, j],
                                tk_of_slot // K, dual_valid_j)
        yj = yj * gate_vals[:, j, None].astype(yj.dtype)
        y = yj if y is None else y + yj
    y = SHCTX.hint(y, "dp", None)
    return y.astype(x.dtype), aux


def moe_init(key, d_model: int, cfg_moe, mlp_type: str, dtype) -> dict:
    E, F = cfg_moe.n_experts, cfg_moe.d_ff
    k0, k1, k2, k3 = jax.random.split(key, 4)
    std_in, std_out = d_model ** -0.5, F ** -0.5
    p = {"router": jax.random.normal(k0, (d_model, E), jnp.float32) * std_in,
         "w_up": jax.random.normal(k1, (E, d_model, F), dtype) * std_in,
         "w_down": jax.random.normal(k2, (E, F, d_model), dtype) * std_out}
    if mlp_type in ("swiglu", "geglu"):
        p["w_gate"] = jax.random.normal(k3, (E, d_model, F), dtype) * std_in
    return p
