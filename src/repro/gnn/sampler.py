"""Host-side neighbor sampler for `minibatch_lg` (fanout 15-10).

Builds a CSR adjacency once, then draws uniform fixed-fanout neighbor
samples per seed batch, emitting *padded, fixed-shape* arrays so the jitted
train step never recompiles.  Layout of the emitted node array:
  [seeds (B) | hop-1 neighbors (B*f1) | hop-2 neighbors (B*f1*f2)]
and edges connect hop-(i+1) sources to hop-i destinations (local indices).
"""
from __future__ import annotations

from typing import Dict, Tuple

import numpy as np


class CSRGraph:
    def __init__(self, n_nodes: int, edge_src: np.ndarray, edge_dst: np.ndarray):
        self.n_nodes = n_nodes
        order = np.argsort(edge_dst, kind="stable")
        self.col = edge_src[order].astype(np.int32)
        counts = np.bincount(edge_dst, minlength=n_nodes)
        self.indptr = np.concatenate([[0], np.cumsum(counts)]).astype(np.int64)

    def sample_neighbors(self, nodes: np.ndarray, fanout: int,
                         rng: np.random.Generator) -> np.ndarray:
        """Uniform with-replacement fanout sample: (N,) -> (N, fanout).
        Isolated nodes self-loop."""
        starts = self.indptr[nodes]
        degs = self.indptr[nodes + 1] - starts
        r = rng.integers(0, 1 << 31, size=(len(nodes), fanout))
        safe_deg = np.maximum(degs, 1)
        idx = starts[:, None] + (r % safe_deg[:, None])
        nbrs = self.col[np.minimum(idx, len(self.col) - 1)]
        return np.where(degs[:, None] > 0, nbrs, nodes[:, None]).astype(np.int32)


def sample_subgraph(graph: CSRGraph, seeds: np.ndarray,
                    fanout: Tuple[int, ...],
                    rng: np.random.Generator) -> Dict[str, np.ndarray]:
    """Multi-hop fixed-fanout sample -> padded local-index subgraph."""
    layers = [seeds.astype(np.int32)]
    edge_src_l, edge_dst_l = [], []
    offset = 0
    next_offset = len(seeds)
    frontier = seeds
    for f in fanout:
        nbrs = graph.sample_neighbors(frontier, f, rng)        # (N, f)
        n_new = nbrs.size
        src_local = np.arange(next_offset, next_offset + n_new, dtype=np.int32)
        dst_local = np.repeat(np.arange(offset, offset + len(frontier),
                                        dtype=np.int32), f)
        edge_src_l.append(src_local)
        edge_dst_l.append(dst_local)
        layers.append(nbrs.reshape(-1))
        offset = next_offset
        next_offset += n_new
        frontier = nbrs.reshape(-1)
    nodes = np.concatenate(layers)                             # global ids
    return {"node_ids": nodes,
            "edge_src": np.concatenate(edge_src_l),
            "edge_dst": np.concatenate(edge_dst_l)}


def make_powerlaw_graph(n_nodes: int, n_edges: int,
                        seed: int = 0) -> Tuple[np.ndarray, np.ndarray]:
    """Synthetic heavy-tailed graph (Zipf-ish degree distribution)."""
    rng = np.random.default_rng(seed)
    # preferential-attachment-flavored sampling without building the graph
    w = 1.0 / np.arange(1, n_nodes + 1) ** 0.75
    w /= w.sum()
    src = rng.choice(n_nodes, size=n_edges, p=w).astype(np.int32)
    dst = rng.integers(0, n_nodes, size=n_edges).astype(np.int32)
    return src, dst
