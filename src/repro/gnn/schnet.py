"""SchNet [arXiv:1706.08566]: continuous-filter convolutions.

Message passing is built from edge-index gather + ``jax.ops.segment_sum``
(JAX sparse is BCOO-only; scatter-based aggregation IS the system here).
Two operating modes share the interaction core:
  * molecule regime: atom types + 3D positions, energy regression (batched
    small graphs via vmap with edge masks);
  * citation/product graphs (full_graph_sm / ogb_products / minibatch_lg):
    node features are linearly projected into the hidden space, synthetic 3D
    positions supply the radial geometry, node classification head.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import GNNConfig

Params = Dict[str, Any]


def ssp(x):
    """Shifted softplus — SchNet's activation."""
    return jax.nn.softplus(x) - jnp.log(2.0)


def rbf_expand(d: jax.Array, n_rbf: int, cutoff: float) -> jax.Array:
    """Gaussian radial basis: (E,) -> (E, n_rbf)."""
    mu = jnp.linspace(0.0, cutoff, n_rbf)
    gamma = 1.0 / ((cutoff / n_rbf) ** 2)
    return jnp.exp(-gamma * (d[..., None] - mu) ** 2)


def cosine_cutoff(d: jax.Array, cutoff: float) -> jax.Array:
    return jnp.where(d < cutoff, 0.5 * (jnp.cos(jnp.pi * d / cutoff) + 1.0), 0.0)


def init_params(key: jax.Array, cfg: GNNConfig, d_feat: Optional[int] = None,
                n_classes: Optional[int] = None) -> Params:
    ks = jax.random.split(key, 4 + cfg.n_interactions)
    H, R = cfg.d_hidden, cfg.n_rbf
    p: Params = {"interactions": []}
    if d_feat is None:
        p["atom_embed"] = jax.random.normal(ks[0], (cfg.n_atom_types, H)) * 0.1
    else:
        p["in_proj"] = jax.random.normal(ks[0], (d_feat, H)) * d_feat ** -0.5
    for i in range(cfg.n_interactions):
        k = ks[1 + i]
        p["interactions"].append({
            "w_in": jax.random.normal(jax.random.fold_in(k, 0), (H, H)) * H ** -0.5,
            "filt_w1": jax.random.normal(jax.random.fold_in(k, 1), (R, H)) * R ** -0.5,
            "filt_b1": jnp.zeros((H,)),
            "filt_w2": jax.random.normal(jax.random.fold_in(k, 2), (H, H)) * H ** -0.5,
            "filt_b2": jnp.zeros((H,)),
            "w_out1": jax.random.normal(jax.random.fold_in(k, 3), (H, H)) * H ** -0.5,
            "b_out1": jnp.zeros((H,)),
            "w_out2": jax.random.normal(jax.random.fold_in(k, 4), (H, H)) * H ** -0.5,
            "b_out2": jnp.zeros((H,)),
        })
    kh = ks[-1]
    if n_classes is None:        # energy regression readout
        p["head_w1"] = jax.random.normal(jax.random.fold_in(kh, 0), (H, H // 2)) * H ** -0.5
        p["head_b1"] = jnp.zeros((H // 2,))
        p["head_w2"] = jax.random.normal(jax.random.fold_in(kh, 1), (H // 2, 1)) * (H // 2) ** -0.5
    else:
        p["cls_w"] = jax.random.normal(kh, (H, n_classes)) * H ** -0.5
        p["cls_b"] = jnp.zeros((n_classes,))
    return p


def interactions(params: Params, h: jax.Array, positions: jax.Array,
                 edge_src: jax.Array, edge_dst: jax.Array, cfg: GNNConfig,
                 edge_mask: Optional[jax.Array] = None) -> jax.Array:
    """Core cfconv stack. h: (N, H); edges: (E,) index arrays."""
    n = h.shape[0]
    diff = positions[edge_src] - positions[edge_dst]            # (E, 3)
    d = jnp.sqrt(jnp.sum(diff * diff, axis=-1) + 1e-12)
    rbf = rbf_expand(d, cfg.n_rbf, cfg.cutoff)                  # (E, R)
    env = cosine_cutoff(d, cfg.cutoff)                          # (E,)
    if edge_mask is not None:
        env = env * edge_mask.astype(env.dtype)
    for ip in params["interactions"]:
        w = ssp(rbf @ ip["filt_w1"] + ip["filt_b1"])
        w = (w @ ip["filt_w2"] + ip["filt_b2"]) * env[:, None]  # (E, H)
        src_feat = (h @ ip["w_in"])[edge_src]                   # gather (E, H)
        msg = src_feat * w
        agg = jax.ops.segment_sum(msg, edge_dst, num_segments=n)
        upd = ssp(agg @ ip["w_out1"] + ip["b_out1"])
        h = h + (upd @ ip["w_out2"] + ip["b_out2"])
    return h


def node_logits(params: Params, batch: Dict, cfg: GNNConfig) -> jax.Array:
    """Graph-regime forward: node classification logits (N, n_classes)."""
    h = batch["node_feat"] @ params["in_proj"]
    h = interactions(params, h, batch["positions"], batch["edge_src"],
                     batch["edge_dst"], cfg)
    return h @ params["cls_w"] + params["cls_b"]


def molecule_energy(params: Params, atom_types: jax.Array, positions: jax.Array,
                    edge_src: jax.Array, edge_dst: jax.Array,
                    edge_mask: jax.Array, cfg: GNNConfig) -> jax.Array:
    """Single-molecule energy (summed atomwise readout)."""
    h = params["atom_embed"][atom_types]
    h = interactions(params, h, positions, edge_src, edge_dst, cfg,
                     edge_mask=edge_mask)
    e_atom = ssp(h @ params["head_w1"] + params["head_b1"]) @ params["head_w2"]
    return e_atom[:, 0].sum()


def batched_energy(params: Params, batch: Dict, cfg: GNNConfig) -> jax.Array:
    """(B,)-energy for the `molecule` shape via vmap over small graphs."""
    fn = lambda a, p, s, d, m: molecule_energy(params, a, p, s, d, m, cfg)
    return jax.vmap(fn)(batch["atom_types"], batch["positions"],
                        batch["edge_src"], batch["edge_dst"],
                        batch["edge_mask"])


def train_loss(params: Params, batch: Dict, cfg: GNNConfig) -> jax.Array:
    if "atom_types" in batch:                   # molecule: energy MAE
        e = batched_energy(params, batch, cfg)
        return jnp.abs(e - batch["targets"]).mean()
    logits = node_logits(params, batch, cfg)
    if "seed_labels" in batch:                  # minibatch: loss on seeds only
        n_seed = batch["seed_labels"].shape[0]
        logits = logits[:n_seed]
        labels = batch["seed_labels"]
    else:
        labels = batch["labels"]
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    return (logz - gold).mean()


def abstract_params(cfg: GNNConfig, d_feat: Optional[int] = None,
                    n_classes: Optional[int] = None) -> Params:
    return jax.eval_shape(
        lambda: init_params(jax.random.PRNGKey(0), cfg, d_feat, n_classes))
