"""Gemma-7B [arXiv:2403.08295]: GeGLU, head_dim=256, 16 KV heads (MHA)."""
from repro.configs.base import LMConfig

CONFIG = LMConfig(
    name="gemma-7b",
    n_layers=28, d_model=3072, n_heads=16, n_kv_heads=16, head_dim=256,
    d_ff=24576, vocab_size=256000, mlp_type="geglu", rope_theta=10_000.0,
    tie_embeddings=True)
