"""DIEN [arXiv:1809.03672]: interest evolution w/ GRU + AUGRU."""
from repro.configs.base import RecsysConfig

CONFIG = RecsysConfig(
    name="dien", kind="dien", embed_dim=18, seq_len=100, gru_dim=108,
    mlp_dims=(200, 80), n_items=1_000_000, n_cates=10_000,
    rcllm_enabled=True)  # sharded-embedding store + affinity routing analogue
