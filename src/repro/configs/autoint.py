"""AutoInt [arXiv:1810.11921]: self-attention feature interaction."""
from repro.configs.base import RecsysConfig

_VOCABS = tuple([1_000_000] * 8 + [100_000] * 8 + [10_000] * 12 + [1_000] * 11)

CONFIG = RecsysConfig(
    name="autoint", kind="autoint", embed_dim=16, n_dense=13,
    field_vocabs=_VOCABS, n_attn_layers=3, n_heads=2, d_attn=32,
    mlp_dims=(), rcllm_enabled=True)
