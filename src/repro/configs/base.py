"""Config dataclasses for all model families.

Every assigned architecture gets one module in this package exporting
``CONFIG`` (the exact published hyperparameters from the assignment block)
and the registry exposes reduced variants for CPU smoke tests.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple


# Canonical serving-execution knob values.  These live here (not in
# repro.serving.api) because LMConfig owns the fields; the serving API
# re-exports them so every layer validates against one tuple.
ATTN_BACKENDS = ("jnp", "pallas")
DECODE_KERNELS = ("auto", "gather", "paged")


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff: int                      # per-expert hidden dim
    capacity_factor: float = 1.25
    router_dtype: str = "float32"


@dataclass(frozen=True)
class LMConfig:
    """Decoder-only transformer LM (dense or MoE)."""
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int                      # dense MLP hidden (ignored if moe set)
    vocab_size: int
    head_dim: Optional[int] = None  # default: d_model // n_heads
    mlp_type: str = "swiglu"        # swiglu | geglu | relu2 | gelu
    moe: Optional[MoEConfig] = None
    rope_theta: float = 10_000.0
    sliding_window: Optional[int] = None
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"
    tie_embeddings: bool = False
    # execution knobs
    attn_q_chunk: int = 512
    attn_kv_chunk: int = 1024
    remat: bool = True
    use_pallas: bool = False        # Pallas kernels (TPU); pure-JAX otherwise
    # Serving attention backend: "jnp" (einsum/chunked reference) or
    # "pallas" (flash/selective kernels — interpret mode off-TPU, real
    # Mosaic lowering on TPU).  Layer-0 Eq. 3 scoring always runs jnp
    # (it needs materialized attention probabilities).
    attn_backend: str = "jnp"
    # Serving decode K/V read strategy: "auto" follows attn_backend
    # (pallas -> fused paged-attention kernel, jnp -> arena gather),
    # "gather"/"paged" force one path regardless of backend — "paged"
    # under jnp runs the kernel in interpret mode against the jnp
    # prefill, the isolation mode the parity tests lean on.
    decode_kernel: str = "auto"
    causal_block_pairing: bool = False  # §Perf: skip fully-masked causal blocks
    optimizer: str = "adamw"        # adamw | adafactor
    # RcLLM serving integration
    rcllm_enabled: bool = True      # item-KV reuse + selective attention apply
    selective_window: int = 256     # sliding window for selective recompute
    selective_hh_frac: float = 0.05  # heavy-hitter fraction (r budget contribution)

    def __post_init__(self):
        # frozen dataclass: dataclasses.replace re-runs this, so an
        # invalid execution knob can never be smuggled in via replace
        if self.attn_backend not in ATTN_BACKENDS:
            raise ValueError(
                f"attn_backend={self.attn_backend!r} not in {ATTN_BACKENDS}"
            )
        if self.decode_kernel not in DECODE_KERNELS:
            raise ValueError(
                f"decode_kernel={self.decode_kernel!r} not in {DECODE_KERNELS}"
            )

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    @property
    def is_moe(self) -> bool:
        return self.moe is not None

    def param_count(self) -> int:
        """Total parameters (embedding included)."""
        dh = self.resolved_head_dim
        attn = self.d_model * (self.n_heads * dh) * 2  # wq, wo
        attn += self.d_model * (self.n_kv_heads * dh) * 2  # wk, wv
        if self.moe is not None:
            n_mats = 3 if self.mlp_type in ("swiglu", "geglu") else 2
            ffn = self.moe.n_experts * n_mats * self.d_model * self.moe.d_ff
            ffn += self.d_model * self.moe.n_experts  # router
        else:
            n_mats = 3 if self.mlp_type in ("swiglu", "geglu") else 2
            ffn = n_mats * self.d_model * self.d_ff
        norms = 2 * self.d_model
        per_layer = attn + ffn + norms
        embed = self.vocab_size * self.d_model * (1 if self.tie_embeddings else 2)
        return self.n_layers * per_layer + embed + self.d_model

    def active_param_count(self) -> int:
        """Active parameters per token (MoE: only top-k experts count)."""
        if self.moe is None:
            return self.param_count()
        dh = self.resolved_head_dim
        attn = self.d_model * (self.n_heads * dh) * 2
        attn += self.d_model * (self.n_kv_heads * dh) * 2
        n_mats = 3 if self.mlp_type in ("swiglu", "geglu") else 2
        ffn = self.moe.top_k * n_mats * self.d_model * self.moe.d_ff
        ffn += self.d_model * self.moe.n_experts
        per_layer = attn + ffn + 2 * self.d_model
        embed = self.vocab_size * self.d_model * (1 if self.tie_embeddings else 2)
        return self.n_layers * per_layer + embed + self.d_model


@dataclass(frozen=True)
class RecsysConfig:
    """Sparse-embedding CTR / sequential recommendation models."""
    name: str
    kind: str                       # wide_deep | autoint | dien | bert4rec
    embed_dim: int
    n_dense: int = 13
    # CTR models: per-field vocab sizes (huge sparse tables)
    field_vocabs: Tuple[int, ...] = ()
    mlp_dims: Tuple[int, ...] = ()
    # autoint
    n_attn_layers: int = 0
    n_heads: int = 0
    d_attn: int = 0
    # dien
    seq_len: int = 0
    gru_dim: int = 0
    # bert4rec
    n_blocks: int = 0
    n_items: int = 0
    n_cates: int = 0
    dtype: str = "float32"
    use_pallas: bool = False
    # RcLLM analogue: sharded embedding store w/ affinity routing
    rcllm_enabled: bool = False

    def table_rows(self) -> int:
        rows = sum(self.field_vocabs)
        rows += self.n_items + self.n_cates
        return rows


@dataclass(frozen=True)
class GNNConfig:
    """SchNet-style interaction network."""
    name: str
    n_interactions: int = 3
    d_hidden: int = 64
    n_rbf: int = 300
    cutoff: float = 10.0
    n_atom_types: int = 100
    readout: str = "sum"
    dtype: str = "float32"
    rcllm_enabled: bool = False


def reduced(cfg):
    """Return a CPU-smoke-testable reduction of any config (same family/code path)."""
    if isinstance(cfg, LMConfig):
        moe = None
        if cfg.moe is not None:
            moe = MoEConfig(n_experts=4, top_k=2, d_ff=64,
                            capacity_factor=cfg.moe.capacity_factor)
        return dataclasses.replace(
            cfg, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
            d_ff=128, vocab_size=512, moe=moe, dtype="float32",
            attn_q_chunk=32, attn_kv_chunk=32, sliding_window=None,
            remat=False)
    if isinstance(cfg, RecsysConfig):
        return dataclasses.replace(
            cfg,
            field_vocabs=tuple(min(v, 1000) for v in cfg.field_vocabs),
            n_items=min(cfg.n_items, 1000) if cfg.n_items else 0,
            n_cates=min(cfg.n_cates, 50) if cfg.n_cates else 0,
            seq_len=min(cfg.seq_len, 16) if cfg.seq_len else 0)
    if isinstance(cfg, GNNConfig):
        return dataclasses.replace(cfg, n_interactions=2, d_hidden=16, n_rbf=8)
    raise TypeError(type(cfg))
