"""Moonlight/moonshot-v1 16B-A3B [hf:moonshotai/Moonlight-16B-A3B]: MoE 64e top-6."""
from repro.configs.base import LMConfig, MoEConfig

CONFIG = LMConfig(
    name="moonshot-v1-16b-a3b",
    n_layers=48, d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128,
    d_ff=1408, vocab_size=163840, mlp_type="swiglu",
    moe=MoEConfig(n_experts=64, top_k=6, d_ff=1408, capacity_factor=1.25),
    rope_theta=50_000.0)
