"""Qwen3-8B-like config: the paper's primary accuracy/serving model [arXiv:2505.09388]."""
from repro.configs.base import LMConfig

CONFIG = LMConfig(
    name="rcllm-qwen3-8b",
    n_layers=36, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=12288, vocab_size=151936, mlp_type="swiglu", rope_theta=1_000_000.0)
