"""StarCoder2-15B [arXiv:2402.19173]: GQA kv=4, RoPE (sliding window 4096)."""
from repro.configs.base import LMConfig

CONFIG = LMConfig(
    name="starcoder2-15b",
    n_layers=40, d_model=6144, n_heads=48, n_kv_heads=4, head_dim=128,
    d_ff=24576, vocab_size=49152, mlp_type="gelu", rope_theta=100_000.0,
    sliding_window=4096)
