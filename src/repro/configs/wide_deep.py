"""Wide&Deep [arXiv:1606.07792]: 40 sparse fields, concat interaction."""
from repro.configs.base import RecsysConfig

# Heavy-tailed per-field vocabularies (Criteo-style): a few huge ID spaces,
# many small categorical fields. Total ~9.1M embedding rows.
_VOCABS = tuple([1_000_000] * 8 + [100_000] * 8 + [10_000] * 12 + [1_000] * 12)

CONFIG = RecsysConfig(
    name="wide-deep", kind="wide_deep", embed_dim=32, n_dense=13,
    field_vocabs=_VOCABS, mlp_dims=(1024, 512, 256), rcllm_enabled=True)
