"""BERT4Rec [arXiv:1904.06690]: bidirectional sequential recommendation."""
from repro.configs.base import RecsysConfig

CONFIG = RecsysConfig(
    name="bert4rec", kind="bert4rec", embed_dim=64, n_blocks=2, n_heads=2,
    seq_len=200, n_items=1_000_000, mlp_dims=(),
    rcllm_enabled=True)  # item-embedding reuse maps to the item-KV pool
