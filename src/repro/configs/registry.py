"""Architecture registry: the 10 assigned archs (+ the paper's own model),
their input-shape sets (40 dry-run cells), and ShapeDtypeStruct input specs.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Iterator, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import GNNConfig, LMConfig, RecsysConfig, reduced
from repro.configs import (autoint, bert4rec, dien, gemma_7b, kimi_k2_1t_a32b,
                           moonshot_v1_16b_a3b, nemotron_4_15b, rcllm_qwen3_8b,
                           schnet, starcoder2_15b, wide_deep)

ARCHS: Dict[str, Any] = {
    "nemotron-4-15b": nemotron_4_15b.CONFIG,
    "starcoder2-15b": starcoder2_15b.CONFIG,
    "gemma-7b": gemma_7b.CONFIG,
    "kimi-k2-1t-a32b": kimi_k2_1t_a32b.CONFIG,
    "moonshot-v1-16b-a3b": moonshot_v1_16b_a3b.CONFIG,
    "schnet": schnet.CONFIG,
    "dien": dien.CONFIG,
    "wide-deep": wide_deep.CONFIG,
    "autoint": autoint.CONFIG,
    "bert4rec": bert4rec.CONFIG,
    # the paper's own serving model (not part of the 40 assigned cells)
    "rcllm-qwen3-8b": rcllm_qwen3_8b.CONFIG,
}

ASSIGNED = [a for a in ARCHS if a != "rcllm-qwen3-8b"]


def family_of(arch: str) -> str:
    cfg = ARCHS[arch]
    if isinstance(cfg, LMConfig):
        return "lm"
    if isinstance(cfg, GNNConfig):
        return "gnn"
    if isinstance(cfg, RecsysConfig):
        return "recsys"
    raise KeyError(arch)


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    step: str            # train | prefill | decode | score | retrieval
    dims: Dict[str, int]


LM_SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", dict(seq=4096, batch=256)),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", dict(seq=32768, batch=32)),
    "decode_32k": ShapeSpec("decode_32k", "decode", dict(seq=32768, batch=128)),
    "long_500k": ShapeSpec("long_500k", "decode", dict(seq=524288, batch=1)),
}

GNN_SHAPES = {
    "full_graph_sm": ShapeSpec("full_graph_sm", "train",
                               dict(n_nodes=2708, n_edges=10556, d_feat=1433,
                                    n_classes=7)),
    "minibatch_lg": ShapeSpec("minibatch_lg", "train",
                              dict(n_nodes=232_965, n_edges=114_615_892,
                                   batch_nodes=1024, fanout=(15, 10),
                                   d_feat=602, n_classes=41,
                                   # sampled-subgraph padded sizes:
                                   sub_nodes=1024 + 1024 * 15 + 1024 * 15 * 10,
                                   sub_edges=1024 * 15 + 1024 * 15 * 10)),
    "ogb_products": ShapeSpec("ogb_products", "train",
                              dict(n_nodes=2_449_029, n_edges=61_859_140,
                                   d_feat=100, n_classes=47)),
    "molecule": ShapeSpec("molecule", "train",
                          dict(n_nodes=30, n_edges=64, batch=128)),
}

RECSYS_SHAPES = {
    "train_batch": ShapeSpec("train_batch", "train", dict(batch=65536)),
    "serve_p99": ShapeSpec("serve_p99", "score", dict(batch=512)),
    "serve_bulk": ShapeSpec("serve_bulk", "score", dict(batch=262144)),
    "retrieval_cand": ShapeSpec("retrieval_cand", "retrieval",
                                dict(batch=1, n_candidates=1_000_000)),
}

SHAPES = {"lm": LM_SHAPES, "gnn": GNN_SHAPES, "recsys": RECSYS_SHAPES}


def shapes_of(arch: str) -> Dict[str, ShapeSpec]:
    return SHAPES[family_of(arch)]


def cells() -> Iterator[Tuple[str, str]]:
    """All 40 (architecture, input-shape) dry-run cells."""
    for arch in ASSIGNED:
        for shape in shapes_of(arch):
            yield arch, shape


def get_config(arch: str, smoke: bool = False):
    cfg = ARCHS[arch]
    return reduced(cfg) if smoke else cfg


def _sd(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def lm_kv_cache_specs(cfg: LMConfig, batch: int, seq: int):
    dh = cfg.resolved_head_dim
    kv = (cfg.n_layers, batch, seq, cfg.n_kv_heads, dh)
    return {"k": _sd(kv, cfg.dtype), "v": _sd(kv, cfg.dtype)}


def input_specs(arch: str, shape_name: str) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of one dry-run cell.

    Weak-type-correct, shardable, no device allocation.
    """
    cfg = ARCHS[arch]
    fam = family_of(arch)
    spec = SHAPES[fam][shape_name]
    d = spec.dims

    if fam == "lm":
        b, s = d["batch"], d["seq"]
        if spec.step == "train":
            return {"tokens": _sd((b, s), jnp.int32),
                    "labels": _sd((b, s), jnp.int32)}
        if spec.step == "prefill":
            return {"tokens": _sd((b, s), jnp.int32)}
        if spec.step == "decode":
            return {"tokens": _sd((b, 1), jnp.int32),
                    "cache": lm_kv_cache_specs(cfg, b, s),
                    "positions": _sd((b,), jnp.int32)}

    if fam == "gnn":
        if shape_name == "molecule":
            b, n, e = d["batch"], d["n_nodes"], d["n_edges"]
            return {"atom_types": _sd((b, n), jnp.int32),
                    "positions": _sd((b, n, 3), jnp.float32),
                    "edge_src": _sd((b, e), jnp.int32),
                    "edge_dst": _sd((b, e), jnp.int32),
                    "edge_mask": _sd((b, e), jnp.bool_),
                    "targets": _sd((b,), jnp.float32)}
        if shape_name == "minibatch_lg":
            n, e = d["sub_nodes"], d["sub_edges"]
            return {"node_feat": _sd((n, d["d_feat"]), jnp.float32),
                    "positions": _sd((n, 3), jnp.float32),
                    "edge_src": _sd((e,), jnp.int32),
                    "edge_dst": _sd((e,), jnp.int32),
                    "seed_labels": _sd((d["batch_nodes"],), jnp.int32)}
        n, e = d["n_nodes"], d["n_edges"]
        return {"node_feat": _sd((n, d["d_feat"]), jnp.float32),
                "positions": _sd((n, 3), jnp.float32),
                "edge_src": _sd((e,), jnp.int32),
                "edge_dst": _sd((e,), jnp.int32),
                "labels": _sd((n,), jnp.int32)}

    if fam == "recsys":
        b = d["batch"]
        base: Dict[str, Any] = {}
        if cfg.kind in ("wide_deep", "autoint"):
            nf = len(cfg.field_vocabs)
            base = {"dense": _sd((b, cfg.n_dense), jnp.float32),
                    "sparse_ids": _sd((b, nf), jnp.int32)}
        elif cfg.kind == "dien":
            base = {"hist_items": _sd((b, cfg.seq_len), jnp.int32),
                    "hist_cates": _sd((b, cfg.seq_len), jnp.int32),
                    "hist_mask": _sd((b, cfg.seq_len), jnp.bool_),
                    "target_item": _sd((b,), jnp.int32),
                    "target_cate": _sd((b,), jnp.int32)}
        elif cfg.kind == "bert4rec":
            base = {"item_seq": _sd((b, cfg.seq_len), jnp.int32),
                    "seq_mask": _sd((b, cfg.seq_len), jnp.bool_)}
        if spec.step == "train":
            if cfg.kind == "bert4rec":
                # fixed-count masked positions (sampled-softmax MLM; a dense
                # (B, T, 1M-vocab) loss tensor is infeasible at batch 65536)
                n_mask = max(1, cfg.seq_len // 10)
                base["mlm_positions"] = _sd((b, n_mask), jnp.int32)
                base["mlm_labels"] = _sd((b, n_mask), jnp.int32)
                base["neg_samples"] = _sd((8192,), jnp.int32)
            else:
                base["labels"] = _sd((b,), jnp.float32)
        if spec.step == "retrieval":
            base["candidate_ids"] = _sd((d["n_candidates"],), jnp.int32)
        return base

    raise KeyError((arch, shape_name))
