"""SchNet [arXiv:1706.08566]: continuous-filter convolutions."""
from repro.configs.base import GNNConfig

CONFIG = GNNConfig(
    name="schnet", n_interactions=3, d_hidden=64, n_rbf=300, cutoff=10.0)
