"""Nemotron-4-15B [arXiv:2402.16819]: GQA, squared-ReLU MLP."""
from repro.configs.base import LMConfig

CONFIG = LMConfig(
    name="nemotron-4-15b",
    n_layers=32, d_model=6144, n_heads=48, n_kv_heads=8, head_dim=128,
    d_ff=24576, vocab_size=256000, mlp_type="relu2", rope_theta=10_000.0)
