"""Kimi K2 1T-A32B [arXiv:2501.kimi2, paper-table]: 61L MoE 384e top-8.

Trains with Adafactor by default: 1.03T params make Adam moments exceed the
single-pod v5e HBM budget (see DESIGN.md §8 / EXPERIMENTS §Dry-run).
"""
from repro.configs.base import LMConfig, MoEConfig

CONFIG = LMConfig(
    name="kimi-k2-1t-a32b",
    n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8, head_dim=112,
    d_ff=2048, vocab_size=163840, mlp_type="swiglu",
    moe=MoEConfig(n_experts=384, top_k=8, d_ff=2048, capacity_factor=1.25),
    rope_theta=50_000.0, optimizer="adafactor")
