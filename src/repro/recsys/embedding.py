"""Embedding substrate for the RecSys family.

JAX has no native ``nn.EmbeddingBag`` and no CSR sparse — the lookup-reduce
path is built from ``jnp.take`` + ``jax.ops.segment_sum`` (this IS part of
the system, per the assignment).  All per-field tables are concatenated into
one mega-table so a single row-sharded array serves every field (the same
layout the RcLLM item-KV pool uses: one sharded store, id-indexed).
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np


ROW_PAD = 4096   # tables padded to a shard boundary (any mesh ≤ 4096 chips)


def pad_rows(n: int) -> int:
    return ((n + ROW_PAD - 1) // ROW_PAD) * ROW_PAD


def field_offsets(vocabs: Sequence[int]) -> np.ndarray:
    """Start row of each field inside the concatenated mega-table."""
    return np.concatenate([[0], np.cumsum(np.asarray(vocabs))[:-1]]).astype(np.int32)


def mega_table_rows(vocabs: Sequence[int]) -> int:
    return pad_rows(int(np.sum(np.asarray(vocabs))))


def lookup(table: jax.Array, ids: jax.Array) -> jax.Array:
    """Plain row gather: (rows, dim)[ids] -> ids.shape + (dim,)."""
    return jnp.take(table, ids, axis=0)


def embedding_bag(table: jax.Array, ids: jax.Array, segment_ids: jax.Array,
                  num_segments: int, *, mode: str = "sum",
                  weights: Optional[jax.Array] = None) -> jax.Array:
    """EmbeddingBag(sum|mean|max) over ragged bags.

    ids, segment_ids: flat (nnz,) arrays; bag b = rows where segment_ids == b.
    """
    rows = jnp.take(table, ids, axis=0)                       # (nnz, dim)
    if weights is not None:
        rows = rows * weights[:, None]
    if mode == "sum":
        return jax.ops.segment_sum(rows, segment_ids, num_segments=num_segments)
    if mode == "mean":
        s = jax.ops.segment_sum(rows, segment_ids, num_segments=num_segments)
        n = jax.ops.segment_sum(jnp.ones((ids.shape[0],), rows.dtype),
                                segment_ids, num_segments=num_segments)
        return s / jnp.maximum(n, 1.0)[:, None]
    if mode == "max":
        return jax.ops.segment_max(rows, segment_ids, num_segments=num_segments)
    raise ValueError(mode)


def fielded_lookup(table: jax.Array, sparse_ids: jax.Array,
                   offsets: jax.Array) -> jax.Array:
    """CTR-style lookup: sparse_ids (B, F) of per-field local ids ->
    (B, F, dim) via the mega-table."""
    return jnp.take(table, sparse_ids + offsets[None, :], axis=0)


def init_mega_table(key: jax.Array, vocabs: Sequence[int], dim: int,
                    dtype=jnp.float32) -> jax.Array:
    rows = mega_table_rows(vocabs)
    return jax.random.normal(key, (rows, dim), dtype) * 0.05
