"""RecSys model family: Wide&Deep, AutoInt, DIEN, BERT4Rec.

Shared structure: huge sparse embedding tables (the hot path — see
repro/kernels/embedding_bag) → feature interaction → small MLP.  Every model
exposes init_params / forward(logits) / train_step loss / retrieval scoring.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import RecsysConfig
from repro.recsys import embedding as E

Params = Dict[str, Any]


def _mlp_init(key, dims: Tuple[int, ...], dtype=jnp.float32) -> list:
    ps = []
    for i, (din, dout) in enumerate(zip(dims[:-1], dims[1:])):
        k = jax.random.fold_in(key, i)
        ps.append({"w": jax.random.normal(k, (din, dout), dtype) * din ** -0.5,
                   "b": jnp.zeros((dout,), dtype)})
    return ps


def _mlp_apply(ps: list, x: jax.Array, final_act: bool = False) -> jax.Array:
    for i, p in enumerate(ps):
        x = x @ p["w"] + p["b"]
        if i < len(ps) - 1 or final_act:
            x = jax.nn.relu(x)
    return x


# ---------------------------------------------------------------------------
# Wide & Deep
# ---------------------------------------------------------------------------

def widedeep_init(key: jax.Array, cfg: RecsysConfig) -> Params:
    ks = jax.random.split(key, 5)
    nf = len(cfg.field_vocabs)
    deep_in = cfg.n_dense + nf * cfg.embed_dim
    return {
        "table": E.init_mega_table(ks[0], cfg.field_vocabs, cfg.embed_dim),
        "wide_table": E.init_mega_table(ks[1], cfg.field_vocabs, 1),
        "wide_dense": jax.random.normal(ks[2], (cfg.n_dense, 1)) * 0.1,
        "deep": _mlp_init(ks[3], (deep_in,) + tuple(cfg.mlp_dims) + (1,)),
        "user_proj": jax.random.normal(ks[4], (cfg.mlp_dims[-1], cfg.embed_dim))
                     * cfg.mlp_dims[-1] ** -0.5,
    }


def widedeep_forward(params: Params, batch: Dict, cfg: RecsysConfig,
                     return_user: bool = False):
    offsets = jnp.asarray(E.field_offsets(cfg.field_vocabs))
    emb = E.fielded_lookup(params["table"], batch["sparse_ids"], offsets)  # (B, F, d)
    B = emb.shape[0]
    deep_in = jnp.concatenate([batch["dense"], emb.reshape(B, -1)], axis=-1)
    hidden = deep_in
    for i, p in enumerate(params["deep"][:-1]):
        hidden = jax.nn.relu(hidden @ p["w"] + p["b"])
    deep_logit = (hidden @ params["deep"][-1]["w"] + params["deep"][-1]["b"])[:, 0]
    wide = E.fielded_lookup(params["wide_table"], batch["sparse_ids"],
                            offsets)[..., 0].sum(-1)
    wide = wide + (batch["dense"] @ params["wide_dense"])[:, 0]
    logit = deep_logit + wide
    if return_user:
        return logit, hidden @ params["user_proj"]
    return logit


# ---------------------------------------------------------------------------
# AutoInt
# ---------------------------------------------------------------------------

def autoint_init(key: jax.Array, cfg: RecsysConfig) -> Params:
    ks = jax.random.split(key, 6)
    nf = len(cfg.field_vocabs)
    d, da, H = cfg.embed_dim, cfg.d_attn, cfg.n_heads
    layers = []
    for i in range(cfg.n_attn_layers):
        k = jax.random.fold_in(ks[1], i)
        din = d if i == 0 else da * H
        layers.append({
            "wq": jax.random.normal(jax.random.fold_in(k, 0), (din, H, da)) * din ** -0.5,
            "wk": jax.random.normal(jax.random.fold_in(k, 1), (din, H, da)) * din ** -0.5,
            "wv": jax.random.normal(jax.random.fold_in(k, 2), (din, H, da)) * din ** -0.5,
            "wres": jax.random.normal(jax.random.fold_in(k, 3), (din, H * da)) * din ** -0.5,
        })
    out_dim = (nf + cfg.n_dense) * cfg.d_attn * H
    return {
        "table": E.init_mega_table(ks[0], cfg.field_vocabs, d),
        "dense_emb": jax.random.normal(ks[2], (cfg.n_dense, d)) * 0.05,
        "attn": layers,
        "w_out": jax.random.normal(ks[3], (out_dim, 1)) * out_dim ** -0.5,
        "user_proj": jax.random.normal(ks[4], (out_dim, d)) * out_dim ** -0.5,
    }


def autoint_forward(params: Params, batch: Dict, cfg: RecsysConfig,
                    return_user: bool = False):
    offsets = jnp.asarray(E.field_offsets(cfg.field_vocabs))
    emb = E.fielded_lookup(params["table"], batch["sparse_ids"], offsets)  # (B, F, d)
    dense_emb = batch["dense"][..., None] * params["dense_emb"][None]  # (B,13,d)
    x = jnp.concatenate([emb, dense_emb], axis=1)              # (B, F+13, d)
    for lp in params["attn"]:
        q = jnp.einsum("bfd,dhe->bfhe", x, lp["wq"])
        k = jnp.einsum("bfd,dhe->bfhe", x, lp["wk"])
        v = jnp.einsum("bfd,dhe->bfhe", x, lp["wv"])
        s = jnp.einsum("bfhe,bghe->bhfg", q, k) / (lp["wq"].shape[-1] ** 0.5)
        a = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhfg,bghe->bfhe", a, v)
        B, F = x.shape[:2]
        x = jax.nn.relu(o.reshape(B, F, -1) + x @ lp["wres"])
    flat = x.reshape(x.shape[0], -1)
    logit = (flat @ params["w_out"])[:, 0]
    if return_user:
        return logit, flat @ params["user_proj"]
    return logit


# ---------------------------------------------------------------------------
# DIEN (GRU interest extraction + AUGRU interest evolution)
# ---------------------------------------------------------------------------

def _gru_init(key, d_in, d_h):
    k1, k2, k3 = jax.random.split(key, 3)
    return {"wz": jax.random.normal(k1, (d_in + d_h, d_h)) * (d_in + d_h) ** -0.5,
            "wr": jax.random.normal(k2, (d_in + d_h, d_h)) * (d_in + d_h) ** -0.5,
            "wh": jax.random.normal(k3, (d_in + d_h, d_h)) * (d_in + d_h) ** -0.5,
            "bz": jnp.zeros((d_h,)), "br": jnp.zeros((d_h,)), "bh": jnp.zeros((d_h,))}


def _gru_cell(p, h, x, att=None):
    xh = jnp.concatenate([x, h], axis=-1)
    z = jax.nn.sigmoid(xh @ p["wz"] + p["bz"])
    r = jax.nn.sigmoid(xh @ p["wr"] + p["br"])
    xrh = jnp.concatenate([x, r * h], axis=-1)
    hh = jnp.tanh(xrh @ p["wh"] + p["bh"])
    if att is not None:                     # AUGRU: attention scales update gate
        z = z * att[:, None]
    return (1 - z) * h + z * hh


def dien_init(key: jax.Array, cfg: RecsysConfig) -> Params:
    ks = jax.random.split(key, 8)
    d = cfg.embed_dim
    d_in = 2 * d                              # item ⊕ cate
    dh = cfg.gru_dim
    mlp_in = dh + 2 * d + 2 * d               # final interest + target + sum-pool
    return {
        "item_table": jax.random.normal(ks[0], (E.pad_rows(cfg.n_items), d)) * 0.05,
        "cate_table": jax.random.normal(ks[1], (E.pad_rows(cfg.n_cates), d)) * 0.05,
        "gru1": _gru_init(ks[2], d_in, dh),
        "gru2": _gru_init(ks[3], d_in if dh == d_in else dh, dh),
        "att_w": jax.random.normal(ks[4], (dh, 2 * d)) * dh ** -0.5,
        "mlp": _mlp_init(ks[5], (mlp_in,) + tuple(cfg.mlp_dims) + (1,)),
        "user_proj": jax.random.normal(ks[6], (dh, d)) * dh ** -0.5,
    }


def dien_forward(params: Params, batch: Dict, cfg: RecsysConfig,
                 return_user: bool = False):
    it = E.lookup(params["item_table"], batch["hist_items"])   # (B, T, d)
    ct = E.lookup(params["cate_table"], batch["hist_cates"])
    x = jnp.concatenate([it, ct], axis=-1)                     # (B, T, 2d)
    mask = batch["hist_mask"].astype(x.dtype)                  # (B, T)
    tgt = jnp.concatenate([E.lookup(params["item_table"], batch["target_item"]),
                           E.lookup(params["cate_table"], batch["target_cate"])],
                          axis=-1)                             # (B, 2d)
    B, T, _ = x.shape
    dh = cfg.gru_dim

    def step1(h, xt):
        xv, mt = xt
        h_new = _gru_cell(params["gru1"], h, xv)
        h = jnp.where(mt[:, None] > 0, h_new, h)
        return h, h

    h0 = jnp.zeros((B, dh))
    _, hs = lax.scan(step1, h0, (x.transpose(1, 0, 2), mask.T))   # (T, B, dh)
    hs = hs.transpose(1, 0, 2)                                    # (B, T, dh)

    # attention of target on interest states
    att_logits = jnp.einsum("btd,de,be->bt", hs, params["att_w"], tgt)
    att_logits = jnp.where(mask > 0, att_logits, -1e30)
    att = jax.nn.softmax(att_logits, axis=-1)                     # (B, T)

    def step2(h, xt):
        hv, at, mt = xt
        h_new = _gru_cell(params["gru2"], h, hv, att=at)
        h = jnp.where(mt[:, None] > 0, h_new, h)
        return h, None

    hfin, _ = lax.scan(step2, jnp.zeros((B, dh)),
                       (hs.transpose(1, 0, 2), att.T, mask.T))

    pooled = (x * mask[..., None]).sum(1) / jnp.maximum(mask.sum(1), 1)[:, None]
    feats = jnp.concatenate([hfin, tgt, pooled], axis=-1)
    logit = _mlp_apply(params["mlp"], feats)[:, 0]
    if return_user:
        return logit, hfin @ params["user_proj"]
    return logit


# ---------------------------------------------------------------------------
# BERT4Rec
# ---------------------------------------------------------------------------

def bert4rec_init(key: jax.Array, cfg: RecsysConfig) -> Params:
    ks = jax.random.split(key, 4)
    d, H = cfg.embed_dim, cfg.n_heads
    dh = d // H
    blocks = []
    for i in range(cfg.n_blocks):
        k = jax.random.fold_in(ks[1], i)
        blocks.append({
            "ln1": jnp.zeros((d,)), "ln2": jnp.zeros((d,)),
            "wq": jax.random.normal(jax.random.fold_in(k, 0), (d, H, dh)) * d ** -0.5,
            "wk": jax.random.normal(jax.random.fold_in(k, 1), (d, H, dh)) * d ** -0.5,
            "wv": jax.random.normal(jax.random.fold_in(k, 2), (d, H, dh)) * d ** -0.5,
            "wo": jax.random.normal(jax.random.fold_in(k, 3), (H, dh, d)) * d ** -0.5,
            "w1": jax.random.normal(jax.random.fold_in(k, 4), (d, 4 * d)) * d ** -0.5,
            "b1": jnp.zeros((4 * d,)),
            "w2": jax.random.normal(jax.random.fold_in(k, 5), (4 * d, d)) * (4 * d) ** -0.5,
            "b2": jnp.zeros((d,)),
        })
    return {
        # +2 rows: PAD and MASK tokens (padded to shard boundary)
        "item_table": jax.random.normal(ks[0], (E.pad_rows(cfg.n_items + 2), d)) * 0.05,
        "pos_table": jax.random.normal(ks[2], (cfg.seq_len, d)) * 0.05,
        "blocks": blocks,
        "final_ln": jnp.zeros((d,)),
    }


def _ln(x, scale):
    mu = x.mean(-1, keepdims=True)
    var = ((x - mu) ** 2).mean(-1, keepdims=True)
    return (x - mu) * lax.rsqrt(var + 1e-6) * (1.0 + scale)


def bert4rec_encode(params: Params, batch: Dict, cfg: RecsysConfig) -> jax.Array:
    x = E.lookup(params["item_table"], batch["item_seq"])      # (B, T, d)
    x = x + params["pos_table"][None]
    mask = batch["seq_mask"]                                   # (B, T) bool
    bias = jnp.where(mask[:, None, None, :], 0.0, -1e30)       # (B,1,1,T)
    for bp in params["blocks"]:
        h = _ln(x, bp["ln1"])
        q = jnp.einsum("btd,dhe->bthe", h, bp["wq"])
        k = jnp.einsum("btd,dhe->bthe", h, bp["wk"])
        v = jnp.einsum("btd,dhe->bthe", h, bp["wv"])
        s = jnp.einsum("bthe,bshe->bhts", q, k) / (q.shape[-1] ** 0.5) + bias
        a = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhts,bshe->bthe", a, v)
        x = x + jnp.einsum("bthe,hed->btd", o, bp["wo"])
        h = _ln(x, bp["ln2"])
        x = x + jax.nn.gelu(h @ bp["w1"] + bp["b1"]) @ bp["w2"] + bp["b2"]
    return _ln(x, params["final_ln"])                          # (B, T, d)


def bert4rec_mlm_loss(params: Params, batch: Dict, cfg: RecsysConfig) -> jax.Array:
    """Sampled-softmax MLM at the given masked positions (vocab is 1M —
    dense softmax over items is infeasible at batch 65536)."""
    h = bert4rec_encode(params, batch, cfg)                    # (B, T, d)
    pos = batch["mlm_positions"]                               # (B, M)
    hm = jnp.take_along_axis(h, pos[..., None], axis=1)        # (B, M, d)
    pos_emb = E.lookup(params["item_table"], batch["mlm_labels"])   # (B, M, d)
    neg_emb = E.lookup(params["item_table"], batch["neg_samples"])  # (N, d)
    pos_logit = jnp.einsum("bmd,bmd->bm", hm, pos_emb)
    neg_logit = jnp.einsum("bmd,nd->bmn", hm, neg_emb)
    logz = jax.nn.logsumexp(
        jnp.concatenate([pos_logit[..., None], neg_logit], axis=-1), axis=-1)
    return (logz - pos_logit).mean()


# ---------------------------------------------------------------------------
# Unified dispatch
# ---------------------------------------------------------------------------

INIT = {"wide_deep": widedeep_init, "autoint": autoint_init,
        "dien": dien_init, "bert4rec": bert4rec_init}
FORWARD = {"wide_deep": widedeep_forward, "autoint": autoint_forward,
           "dien": dien_forward}


def init_params(key: jax.Array, cfg: RecsysConfig) -> Params:
    return INIT[cfg.kind](key, cfg)


def abstract_params(cfg: RecsysConfig) -> Params:
    return jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))


def score(params: Params, batch: Dict, cfg: RecsysConfig) -> jax.Array:
    """CTR logit (B,) — lowered for serve_p99 / serve_bulk."""
    if cfg.kind == "bert4rec":
        h = bert4rec_encode(params, batch, cfg)
        # next-item scoring uses the last valid position's representation
        last = jnp.maximum(batch["seq_mask"].sum(-1) - 1, 0)
        hu = jnp.take_along_axis(h, last[:, None, None], axis=1)[:, 0]
        # score against the *observed* items (cheap serving proxy score)
        return jnp.einsum("bd,bd->b", hu, h[:, 0])
    return FORWARD[cfg.kind](params, batch, cfg)


def user_repr(params: Params, batch: Dict, cfg: RecsysConfig) -> jax.Array:
    if cfg.kind == "bert4rec":
        h = bert4rec_encode(params, batch, cfg)
        last = jnp.maximum(batch["seq_mask"].sum(-1) - 1, 0)
        return jnp.take_along_axis(h, last[:, None, None], axis=1)[:, 0]
    _, u = FORWARD[cfg.kind](params, batch, cfg, return_user=True)
    return u


def retrieval_scores(params: Params, batch: Dict, cfg: RecsysConfig) -> jax.Array:
    """Score one query against n_candidates items as a single batched dot —
    never a loop (retrieval_cand shape)."""
    u = user_repr(params, batch, cfg)                          # (B, d)
    table = params["item_table"] if cfg.kind in ("dien", "bert4rec") \
        else params["table"]
    cand = E.lookup(table, batch["candidate_ids"])             # (N, d)
    return jnp.einsum("bd,nd->bn", u, cand)                    # (B, N)


def train_loss(params: Params, batch: Dict, cfg: RecsysConfig) -> jax.Array:
    if cfg.kind == "bert4rec":
        return bert4rec_mlm_loss(params, batch, cfg)
    logit = FORWARD[cfg.kind](params, batch, cfg)
    y = batch["labels"]
    # BCE with logits
    return jnp.mean(jnp.maximum(logit, 0) - logit * y +
                    jnp.log1p(jnp.exp(-jnp.abs(logit))))
