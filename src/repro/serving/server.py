"""Asyncio session server: the serving stack fronted by real sessions.

Everything before this module replays a *trace*: requests, arrival
times and token budgets are known up front, the loop runs to
completion, and the answer is a list of completions.  This module turns
the same scheduling loop into a *server* (Jetstream-style): clients
`AsyncSessionServer.submit` requests whenever they like and consume an
async iterator of `api.StreamEvent` per session, while one background
scheduler task drives `batching.WorkerState.step` — the identical
wave/chunked tick the closed-loop runner uses, engine and all.

Event flow, one tick::

    client ──submit()──▶ arrival queue ─┐        (asyncio side)
    client ──cancel()──▶ cancel set  ───┤
    ........................................................
                                        ▼        (tick boundary)
              drain arrivals ▶ worker.waiting (bisect by arrival)
              apply cancels  ▶ worker.cancel(rid)  [abort_prefill /
                                                    finish seams]
              worker.step()  ─ one wave batch or one unified
                               budgeted chunk+decode tick
    ........................................................
              publish: new tokens in backend.generated[rid]
                       ──▶ per-session asyncio queues (StreamEvent)
                       new worker.done entries ──▶ api.Completion
              metrics.tick(): rolling p50/p99 TTFT+TBT, queue
                       depth, pool occupancy, store hit rates

The worker's state is touched *only* between steps, by the scheduler
task — `submit`/`cancel` just enqueue.  The engine step itself runs in
a thread (`asyncio.to_thread`) so the event loop keeps accepting
arrivals mid-step; they are admitted at the next tick boundary, exactly
like a real continuous-batching server.

Determinism: scheduling decisions depend only on the *order and
stamped arrival times* of requests, never on the wall clock — the
per-request compute is composition-invariant (the cross-cutting parity
property of PRs 1–6).  `replay(..., speed=0)` therefore submits a whole
trace up front with its trace arrival stamps and decodes tokens
bitwise-identical to the closed-loop `ContinuousBatcher.run`; with
``speed > 0`` the same trace becomes open-loop wall-clock traffic
(arrival gaps slept for real), which is what the SLO benchmark
(`benchmarks/bench_openloop.py`) measures.
"""

from __future__ import annotations

import asyncio
import bisect
import time
from collections import deque
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.serving.api import (
    Completion,
    ServeConfig,
    StreamEvent,
    SubmitRequest,
)
from repro.serving.batching import PendingRequest, WorkerState


class Session:
    """One submitted request's client handle: an async iterator of
    `StreamEvent`s (exactly one has ``finished=True``), plus `result()`
    for the terminal `api.Completion` and `cancel()`."""

    def __init__(self, server: "AsyncSessionServer", request: SubmitRequest):
        self.request = request
        self.rid = request.rid
        self._server = server
        self._queue: asyncio.Queue = asyncio.Queue()
        self._done = asyncio.Event()
        self._exhausted = False
        self.completion: Optional[Completion] = None
        # server-side bookkeeping (scheduler task only)
        self.state = "queued"  # queued | running | done
        self.submitted_s = 0.0
        self.first_token_s: Optional[float] = None
        self.arrival = None  # PendingRequest once admitted

    def __aiter__(self) -> "Session":
        return self

    async def __anext__(self) -> StreamEvent:
        if self._exhausted:
            raise StopAsyncIteration
        ev = await self._queue.get()
        if ev.finished:
            self._exhausted = True
        return ev

    async def result(self) -> Completion:
        """Wait for the session to finish; -> its `api.Completion`."""
        await self._done.wait()
        return self.completion

    def cancel(self) -> str:
        """Ask the server to cancel this session at the next tick
        boundary (mid-prefill: chunk state and pages roll back through
        `abort_prefill`; mid-decode: pages release through `finish`).
        Idempotent — see `AsyncSessionServer.cancel`."""
        return self._server.cancel(self.rid)

    # -- server side -------------------------------------------------------
    def _emit(self, ev: StreamEvent) -> None:
        self._queue.put_nowait(ev)
        if ev.finished:
            self._done.set()


class OnlineMetrics:
    """Rolling serving metrics over the last `window` observations —
    what a dashboard scrapes, not a post-hoc report."""

    def __init__(self, window: int = 512):
        self.ttft_s: deque = deque(maxlen=window)
        self.tbt_s: deque = deque(maxlen=window)
        self.completed = 0
        self.cancelled = 0
        self.rejected = 0

    @staticmethod
    def _pcts(xs: deque) -> Tuple[Optional[float], Optional[float]]:
        if not xs:
            return None, None
        arr = np.asarray(xs)
        return float(np.percentile(arr, 50)), float(np.percentile(arr, 99))

    def snapshot(self, server: "AsyncSessionServer") -> dict:
        """One point-in-time view (JSON-ready)."""
        worker = server.worker
        ttft_p50, ttft_p99 = self._pcts(self.ttft_s)
        tbt_p50, tbt_p99 = self._pcts(self.tbt_s)
        snap = {
            "t_s": round(server.now(), 6),
            "queue_depth": len(worker.waiting),
            "prefilling": len(worker.prefilling),
            "decoding": len(worker.decoding),
            "completed": self.completed,
            "cancelled": self.cancelled,
            "rejected": self.rejected,
            "preempted": worker.preempted,
            "ttft_p50_s": ttft_p50,
            "ttft_p99_s": ttft_p99,
            "tbt_p50_s": tbt_p50,
            "tbt_p99_s": tbt_p99,
        }
        engine = getattr(worker.backend, "engine", None)
        pool = getattr(engine, "pool", None)
        if pool is not None:
            used = pool.n_pages - pool.free_pages
            snap["pool_pages_in_use"] = used
            snap["pool_occupancy"] = round(used / pool.n_pages, 4)
        store = getattr(engine, "store", None)
        if store is not None:
            stats = store.stats()
            for tier in ("prefix", "user", "item"):
                h = stats.get(f"hits_{tier}", 0)
                m = stats.get(f"misses_{tier}", 0)
                snap[f"store_{tier}_hit_rate"] = round(h / max(h + m, 1), 4)
            snap["store_device_blocks"] = stats["device_blocks"]
            snap["store_spill_blocks"] = stats["spill_blocks"]
            snap["store_spill_hits"] = stats["spill_hits"]
            snap["store_prefetch_promotions"] = stats["prefetch_promotions"]
            snap["store_dequant_s"] = round(stats["dequant_s"], 6)
        return snap


class AsyncSessionServer:
    """The serving loop as a long-lived asyncio service (single worker:
    one engine, one KV pool — the cluster dispatcher stays a closed-loop
    construct for now, `config.k` must be 1).

    Construction wants a chunk-capable backend (`JaxEngineBackend` or a
    subclass) plus the `api.ServeConfig` that built it; `start` spawns
    the scheduler task, `submit` returns a `Session`.  Use as an async
    context manager to guarantee shutdown.
    """

    def __init__(self, backend, config: ServeConfig):
        if config.k != 1:
            raise ValueError(
                f"AsyncSessionServer drives one worker (config.k={config.k}); "
                "multi-worker serving is the closed-loop ClusterEngine"
            )
        self.config = config
        self.worker = WorkerState(
            backend,
            wid=0,
            max_batch_tokens=config.max_batch_tokens,
            max_decode_batch=config.max_decode_batch,
            sched=config.sched,
            chunk_tokens=config.chunk_tokens,
            step_tokens=config.step_tokens,
        )
        self.backend = backend
        self.metrics = OnlineMetrics()
        self.metrics_log: deque = deque(maxlen=4096)
        self._sessions: Dict[int, Session] = {}
        self._arrivals: deque = deque()  # sessions awaiting admission
        self._cancels: set = set()
        self._kick = asyncio.Event()
        self._task: Optional[asyncio.Task] = None
        self._running = False
        self._t0 = time.perf_counter()
        self._emitted: Dict[int, int] = {}  # rid -> tokens streamed
        self._last_emit: Dict[int, float] = {}
        self._n_done_seen = 0

    def now(self) -> float:
        """Server wall clock (seconds since construction)."""
        return time.perf_counter() - self._t0

    # ----------------------------- client API -----------------------------
    def submit(
        self, request: SubmitRequest, arrival_s: Optional[float] = None
    ) -> Session:
        """Register a session; its request joins the worker's queue at
        the next tick boundary.  ``arrival_s`` overrides the arrival
        stamp (trace replay); by default the request arrives *now*.
        Safe to call before `start` — replay mode stages a whole trace,
        then starts the loop."""
        rid = request.rid
        if rid in self._sessions:
            raise ValueError(f"duplicate session rid {rid}")
        sess = Session(self, request)
        sess.submitted_s = self.now() if arrival_s is None else arrival_s
        self._sessions[rid] = sess
        self._arrivals.append(sess)
        self._kick.set()
        return sess

    def cancel(self, rid: int) -> str:
        """Request cancellation of one session.  Idempotent no-op on a
        session the server doesn't know ("unknown") or one that already
        finished ("done") — neither enqueues anything, so a stale cancel
        can never reach the scheduler task or shoot down a later session
        that reuses the rid.  -> "unknown" | "done" | "cancelling"."""
        sess = self._sessions.get(rid)
        if sess is None:
            return "unknown"
        if sess.state == "done":
            return "done"
        self._cancels.add(rid)
        self._kick.set()
        return "cancelling"

    async def start(self) -> "AsyncSessionServer":
        if self._task is not None:
            raise RuntimeError("server already started")
        self._running = True
        self._task = asyncio.create_task(self._loop(), name="session-server")
        return self

    async def stop(self) -> None:
        self._running = False
        self._kick.set()
        if self._task is not None:
            await self._task
            self._task = None

    async def __aenter__(self) -> "AsyncSessionServer":
        return await self.start()

    async def __aexit__(self, *exc) -> None:
        await self.stop()

    async def drain(self) -> None:
        """Wait until every submitted session has finished."""
        for sess in list(self._sessions.values()):
            await sess._done.wait()

    def metrics_snapshot(self) -> dict:
        return self.metrics.snapshot(self)

    # --------------------------- scheduler task ---------------------------
    async def _loop(self) -> None:
        worker = self.worker
        while self._running:
            self._admit_arrivals()
            self._apply_cancels()
            if not worker.has_work():
                # idle: park until a submit/cancel kicks the loop
                self._kick.clear()
                if not self._arrivals and not self._cancels and self._running:
                    await self._kick.wait()
                continue
            try:
                # the engine step runs in a thread so the event loop
                # keeps accepting submissions mid-step
                await asyncio.to_thread(worker.step)
            except RuntimeError as e:
                if "never be admitted" not in str(e):
                    raise
                # head-of-queue request can never be admitted (pool too
                # small even empty): reject that session, keep serving
                self._reject_head()
            self._publish()
            self.metrics_log.append(self.metrics.snapshot(self))

    def _admit_arrivals(self) -> None:
        worker = self.worker
        while self._arrivals:
            sess = self._arrivals.popleft()
            req = sess.request
            if req.rid in self._cancels:
                self._cancels.discard(req.rid)
                self._finish_session(sess, "cancelled")
                self.metrics.cancelled += 1
                continue
            backend = self.backend
            if req.context is not None:
                backend.plans[req.rid] = req.context
            if req.reuse is not None:
                backend.reuse[req.rid] = req.reuse
            if hasattr(backend, "set_session"):
                backend.set_session(req.rid, req.sampling, req.stop)
            pend = PendingRequest(
                arrival_s=sess.submitted_s,
                rid=req.rid,
                n_tokens=len(req.tokens),
                decode_steps=req.max_tokens,
                tokens=req.tokens,
            )
            sess.arrival = pend
            sess.state = "running"
            # keep the queue arrival-ordered: wall submissions are
            # monotone, replayed stamps may not be
            bisect.insort(worker.waiting, pend)

    def _apply_cancels(self) -> None:
        for rid in sorted(self._cancels):
            self._cancels.discard(rid)
            sess = self._sessions.get(rid)
            if sess is None or sess.state == "done":
                continue
            stage = self.worker.cancel(rid)
            if stage is None and sess.state != "queued":
                continue  # finished in the same tick; completion wins
            self._finish_session(sess, "cancelled")
            self.metrics.cancelled += 1

    def _reject_head(self) -> None:
        worker = self.worker
        if not worker.waiting:
            return
        pend = worker.waiting.pop(0)
        sess = self._sessions.get(pend.rid)
        if sess is not None:
            self._finish_session(sess, "rejected")
            self.metrics.rejected += 1

    def _publish(self) -> None:
        """Stream everything the last tick produced."""
        now = self.now()
        generated = getattr(self.backend, "generated", {})
        for rid, sess in self._sessions.items():
            if sess.state != "running":
                continue
            toks = generated.get(rid)
            if toks is None:
                continue
            emitted = self._emitted.get(rid, 0)
            # after a preemption the victim regenerates its stream from
            # scratch (deterministic); only ever emit past the watermark
            for i in range(emitted, len(toks)):
                if sess.first_token_s is None:
                    sess.first_token_s = now
                    self.metrics.ttft_s.append(now - sess.submitted_s)
                else:
                    self.metrics.tbt_s.append(now - self._last_emit[rid])
                self._last_emit[rid] = now
                sess._emit(StreamEvent(rid=rid, index=i, token=toks[i], t_s=now))
            if len(toks) > emitted:
                self._emitted[rid] = len(toks)
        done = self.worker.done
        for c in done[self._n_done_seen:]:
            sess = self._sessions.get(c.rid)
            if sess is not None and sess.state == "running":
                self._finish_session(sess, c.reason)
                self.metrics.completed += 1
        self._n_done_seen = len(done)

    def _finish_session(self, sess: Session, reason: str) -> None:
        sess.state = "done"
        generated = getattr(self.backend, "generated", {})
        toks = tuple(generated.get(sess.rid, ()))
        now = self.now()
        sess.completion = Completion(
            rid=sess.rid,
            tokens=toks,
            reason=reason,
            submitted_s=sess.submitted_s,
            first_token_s=sess.first_token_s,
            done_s=now,
        )
        self._emitted.pop(sess.rid, None)
        self._last_emit.pop(sess.rid, None)
        sess._emit(
            StreamEvent(
                rid=sess.rid,
                index=len(toks),
                token=None,
                t_s=now,
                finished=True,
                reason=reason,
            )
        )


# ------------------------------ trace driving ------------------------------
async def replay(
    server: AsyncSessionServer,
    submits: Sequence[Tuple[float, SubmitRequest]],
    speed: float = 0.0,
) -> Dict[int, Completion]:
    """Drive ``(arrival_s, request)`` pairs through a server.

    ``speed == 0`` — deterministic replay: every request is staged
    before the loop starts, stamped with its trace arrival time, so
    scheduling (and therefore every decoded token) is bitwise-identical
    to the closed-loop runner on the same trace.  ``speed > 0`` —
    open-loop: the trace's arrival gaps are slept for real (divided by
    `speed`), submissions race the scheduler on the wall clock.
    """
    ordered = sorted(submits, key=lambda ar: (ar[0], ar[1].rid))
    if speed <= 0:
        for arrival_s, req in ordered:
            server.submit(req, arrival_s=arrival_s)
        async with server:
            await server.drain()
    else:
        async with server:
            t_start = server.now()
            base = ordered[0][0] if ordered else 0.0
            for arrival_s, req in ordered:
                due = t_start + (arrival_s - base) / speed
                delay = due - server.now()
                if delay > 0:
                    await asyncio.sleep(delay)
                server.submit(req)
            await server.drain()
    return {rid: sess.completion for rid, sess in server._sessions.items()}


def serve_trace(
    backend,
    config: ServeConfig,
    submits: Sequence[Tuple[float, SubmitRequest]],
    speed: float = 0.0,
) -> Tuple[Dict[int, Completion], AsyncSessionServer]:
    """Synchronous convenience: build a server, replay a trace, return
    (completions by rid, the stopped server — its `worker`/`metrics_log`
    hold the run's scheduling record)."""
    server = AsyncSessionServer(backend, config)
    completions = asyncio.run(replay(server, submits, speed=speed))
    return completions, server
