"""Cross-request beyond-prefix KV reuse: a shared block store over the pool.

This is the serving-path realization of the paper's **stratified
storage** (§III-B): KV blocks are content-addressed (blake2b over the
bytes that determine them), held in pool pages owned by the store, and
shared across concurrent requests through refcounts.  Two tiers:

* **user tier** — one pinned block per (instruction + user history)
  prefix, replicated per worker.  Its bytes are the *deterministic*
  part of the prefix KV: the always-fresh layer-0 plane (a pure
  function of the token ids — bitwise reproducible across padding
  buckets) plus the semantic-prototype deep layers.  Positions the
  selective pass recomputes vary per request and are never part of the
  block; each request overlays them privately.
* **item tier** — one block per item description, fed by the cluster's
  `StagedBlocks` / transfer ledger, holding the offline-precomputed
  block bytes for every layer (the offline layer-0 KV is bitwise equal
  to the online fresh layer-0 for the same tokens).  Unpinned:
  LRU-evicted when unreferenced and the pool is under pressure.

Because every stored byte equals what the no-reuse path would have
written for the same position, mapping a request's slot-table entries
at shared slots changes *where* decode reads, never *what* — decoded
tokens are bitwise identical with reuse on or off.  The store also
keeps the host-side block bytes, so a cluster worker whose store holds
an item block skips the cross-shard transfer entirely (a zero-latency
hit in the ledger's terms).

The store is additionally a *two-tier, optionally quantized* hierarchy:

* **quantized payloads** (``kv_store_dtype="int8"``) — user/item block
  bytes are held as symmetric per-(row, kv-head)-scaled int8
  (`quantize_rows`), ~4x more catalog blocks per host byte, and
  dequantized on assembly into the arena.  The default ``fp32`` keeps
  every bitwise invariant; int8 is accuracy-gated (tableIII fidelity).
* **host-RAM spill tier** (``spill_mb > 0``) — device-tier evictions
  demote to a capacity-bounded, content-addressed host tier instead of
  being dropped; a key hit there re-stages through the normal admission
  path (`_promote`), avoiding re-transfer/recompute.  `prefetch` drains
  router-issued affinity `hint`s into free headroom, budgeted pages per
  chunked-scheduler tick, so queued requests find their blocks already
  on device.
"""
from __future__ import annotations

import hashlib
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.assembly import FROM_SEMANTIC, AssemblyPlan
from repro.serving.kv_pool import PagedKVPool

USER_TIER = "user"
ITEM_TIER = "item"
# The instruction prefix: identical recomputed rows for every request.
# Keyed by (digest, n_pad, r_pad) — the jit-bucket shape — because the
# rows come out of the selective stack's trace; within one trace shape
# they are bitwise request-invariant (and the batched↔loop parity test
# pins batch-size invariance).  This tier is what subsumes classic
# prefix caching inside the beyond-prefix store.
PREFIX_TIER = "prefix"


def content_key(kind: str, *arrays) -> Tuple[str, str]:
    """Content address: blake2b over the arrays that determine the bytes."""
    h = hashlib.blake2b(digest_size=16)
    for a in arrays:
        a = np.ascontiguousarray(a)
        h.update(str(a.dtype).encode())
        h.update(str(a.shape).encode())
        h.update(a.tobytes())
    return (kind, h.hexdigest())


# --------------------------- quantized payloads ----------------------------
def quantize_rows(x: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Symmetric per-(row, kv-head) int8 quantization of KV bytes.

    ``x``: (t, L, Hkv, Dh) fp32.  The scale is the absmax over the head
    dimension divided by 127 (so the largest element of every row maps
    exactly to ±127), kept fp32 at shape (t, L, Hkv, 1).  All-zero rows
    get scale 1.0 so dequantization is exact for them too.  The scheme
    is *idempotent*: quantizing ``dequantize_rows(q, s)`` reproduces
    (q, s) bitwise, so a block can hop store→payload→store any number
    of times without drift.
    """
    x = np.ascontiguousarray(x, np.float32)
    amax = np.max(np.abs(x), axis=-1, keepdims=True)
    scale = np.where(amax > 0, amax / 127.0, 1.0).astype(np.float32)
    q = np.clip(np.rint(x / scale), -127, 127).astype(np.int8)
    return q, scale


def dequantize_rows(q: np.ndarray, scale: np.ndarray) -> np.ndarray:
    """Inverse of `quantize_rows`: (t, L, Hkv, Dh) fp32."""
    return q.astype(np.float32) * scale


def _dequant(
    data: np.ndarray,
    scale: Optional[np.ndarray],
    store: Optional["SharedBlockStore"],
) -> np.ndarray:
    """Materialize a block's fp32 bytes, billing dequant wall time."""
    if scale is None:
        return data
    t0 = time.perf_counter()
    out = dequantize_rows(data, scale)
    if store is not None:
        store.dequant_s += time.perf_counter() - t0
    return out


@dataclass
class BlockRef:
    """One reusable block inside a request's prompt, as seen by the
    engine: where it lands (`positions`), which block rows those map to
    (`offsets`), and the host bytes to insert on a store miss."""

    key: Tuple[str, str]
    positions: np.ndarray
    offsets: np.ndarray
    k: Optional[np.ndarray] = None
    v: Optional[np.ndarray] = None
    tokens: Optional[np.ndarray] = None


@dataclass
class RequestReuse:
    """Per-request reuse metadata attached to a `BatchRequest`."""

    user_key: Optional[Tuple[str, str]] = None
    prefix_end: int = 0
    blocks: List[BlockRef] = field(default_factory=list)
    # instruction-prefix tier: content digest + how many leading tokens
    # it covers; the engine appends the (n_pad, r_pad) bucket at runtime
    prefix_key: Optional[Tuple[str, str]] = None
    prefix_len: int = 0


@dataclass(frozen=True)
class BlockPayload:
    """One store block as it rides a KV migration: the content key plus
    everything needed to re-insert on a destination-store miss, and the
    SOURCE store's physical slot ids so the importer can translate the
    migrating request's shared slot-table entries.  Content addressing
    is what makes this a *tier* rather than a copy: the key travels
    first, and a destination that already holds it never moves the
    bytes."""

    key: Tuple[str, str]
    kind: str
    slots: np.ndarray  # (n_tokens,) SOURCE physical slot ids
    host_k: np.ndarray
    host_v: np.ndarray
    tokens: Optional[np.ndarray] = None
    positions: Optional[np.ndarray] = None
    pinned: bool = False

    @property
    def nbytes(self) -> int:
        return self.host_k.nbytes + self.host_v.nbytes


@dataclass
class StoredBlock:
    """A device-tier block.  Payload bytes live in ``data_k``/``data_v``
    — fp32, or per-row-scaled int8 when the store quantizes (then
    ``scale_k``/``scale_v`` hold the fp32 scales).  ``host_k``/``host_v``
    materialize the fp32 view on demand so every existing consumer
    (staging, migration, arena writes) is representation-oblivious."""

    key: Tuple[str, str]
    kind: str
    pages: List[int]
    slots: np.ndarray  # (n_tokens,) physical slot ids, block-row order
    data_k: np.ndarray  # host copies: staging + re-insert after eviction
    data_v: np.ndarray
    scale_k: Optional[np.ndarray] = None  # None => data is fp32
    scale_v: Optional[np.ndarray] = None
    tokens: Optional[np.ndarray] = None
    positions: Optional[np.ndarray] = None  # user tier: covered positions
    pinned: bool = False
    refcount: int = 0
    last_used: int = 0
    hits: int = 0
    store: Optional["SharedBlockStore"] = field(
        default=None, repr=False, compare=False
    )

    @property
    def n_tokens(self) -> int:
        return len(self.slots)

    @property
    def nbytes(self) -> int:
        n = self.data_k.nbytes + self.data_v.nbytes
        if self.scale_k is not None:
            n += self.scale_k.nbytes + self.scale_v.nbytes
        return n

    @property
    def host_k(self) -> np.ndarray:
        return _dequant(self.data_k, self.scale_k, self.store)

    @property
    def host_v(self) -> np.ndarray:
        return _dequant(self.data_v, self.scale_v, self.store)


@dataclass
class SpilledBlock:
    """A block demoted to the host-RAM spill tier: same (possibly
    quantized) payload, no pool pages, no slots — it cannot back a
    slot-table entry until promoted back to device.  ``last_used``
    carries the device-tier LRU stamp across the hop so spill-capacity
    trimming continues in true LRU order."""

    key: Tuple[str, str]
    kind: str
    data_k: np.ndarray
    data_v: np.ndarray
    scale_k: Optional[np.ndarray] = None
    scale_v: Optional[np.ndarray] = None
    tokens: Optional[np.ndarray] = None
    positions: Optional[np.ndarray] = None
    last_used: int = 0
    hits: int = 0

    @property
    def n_tokens(self) -> int:
        return int(self.data_k.shape[0])

    @property
    def nbytes(self) -> int:
        n = self.data_k.nbytes + self.data_v.nbytes
        if self.scale_k is not None:
            n += self.scale_k.nbytes + self.scale_v.nbytes
        return n

    @property
    def host_k(self) -> np.ndarray:
        return _dequant(self.data_k, self.scale_k, None)

    @property
    def host_v(self) -> np.ndarray:
        return _dequant(self.data_v, self.scale_v, None)


def user_reuse_positions(
    plan: AssemblyPlan, have: np.ndarray, prefix_end: int
) -> np.ndarray:
    """Prefix positions whose bytes are deterministic per user: semantic
    reuse hits inside [0, prefix_end).  Everything else in the prefix
    (markers, separators, instruction) is always recomputed."""
    pos = np.where((plan.source == FROM_SEMANTIC) & have)[0]
    return pos[pos < prefix_end]


class SharedBlockStore:
    """Content-addressed, ref-counted KV block sharing over a pool.

    Pages the store allocates (`pool.alloc_pages`) belong to the store
    until a block is evicted; requests reference them through slot-table
    entries and per-request refcounts (`acquire`/`release`).  Eviction
    only ever touches unpinned blocks with refcount 0, in LRU order.
    """

    def __init__(
        self,
        pool: PagedKVPool,
        max_pages: Optional[int] = None,
        max_user_pages: Optional[int] = None,
        *,
        kv_store_dtype: str = "fp32",
        spill_mb: int = 0,
        prefetch_pages_per_tick: int = 0,
    ):
        if kv_store_dtype not in ("fp32", "int8"):
            raise ValueError(f"kv_store_dtype must be fp32|int8, got {kv_store_dtype}")
        self.pool = pool
        self.kv_store_dtype = kv_store_dtype
        # host-RAM spill tier: capacity-bounded demotion target for
        # device-tier evictions (0 = drop-on-evict, the legacy behavior)
        self.spill_cap = int(spill_mb) * 2**20
        self.prefetch_pages_per_tick = int(prefetch_pages_per_tick)
        self.spill: Dict[Tuple[str, str], SpilledBlock] = {}
        self.spill_nbytes = 0
        # affinity prefetch hints from the router, oldest first; bounded
        # so a misbehaving scheduler can't grow it without limit
        self._hints: Deque[Tuple[str, str]] = deque(maxlen=512)
        # keys a bound-but-unadmitted request declared it will need:
        # still device-resident when hinted, but if one is evicted before
        # that request admits, the demotion auto-queues a prefetch hint
        # so the block is swapped back ahead of the admission gate
        self._interest: set = set()
        self.dequant_s = 0.0
        # the store must never crowd requests out of their own pool:
        # total budget is half the pages (LRU keeps the hot set), and
        # PINNED pages — which eviction can never reclaim, so they can
        # permanently wedge admission on a small pool — are capped at a
        # quarter across tiers (a too-small pool simply gets no prefix
        # tier rather than a deadlocked batcher)
        self.max_pages = (
            max_pages if max_pages is not None else max(pool.n_pages // 2, 1)
        )
        self.max_pinned_pages = max(pool.n_pages // 4, 1)
        self.max_user_pages = (
            max_user_pages
            if max_user_pages is not None
            else max(pool.n_pages // 4, 1)
        )
        self.blocks: Dict[Tuple[str, str], StoredBlock] = {}
        self._pending_writes: List[tuple] = []
        self._tick = 0
        # bumped on every insert/eviction: lets admission accounting
        # memoize per-request page bounds until the resident set changes
        self.version = 0
        self.counters = {
            "hits_user": 0,
            "hits_item": 0,
            "hits_prefix": 0,
            "misses_user": 0,
            "misses_item": 0,
            "misses_prefix": 0,
            "inserts": 0,
            "insert_skips": 0,
            "evictions": 0,
            "spills": 0,
            "insert_spills": 0,
            "spill_drops": 0,
            "spill_hits": 0,
            "prefetch_promotions": 0,
        }

    # ------------------------------- lookup --------------------------------
    def has(self, key) -> bool:
        """Device-tier membership ONLY: a spilled block has no slots, so
        admission accounting and slot-table mapping must not see it."""
        return key in self.blocks

    def in_spill(self, key) -> bool:
        return key in self.spill

    def resident(self, key) -> bool:
        """Held in either tier — the bytes exist on this worker, so a
        migration or transfer of this key moves zero bytes."""
        return key in self.blocks or key in self.spill

    def peek(self, key) -> Optional[StoredBlock]:
        """Lookup without touching LRU state or counters (admission)."""
        return self.blocks.get(key)

    def spill_peek(self, key) -> Optional[SpilledBlock]:
        return self.spill.get(key)

    def get(self, key) -> Optional[StoredBlock]:
        blk = self.blocks.get(key)
        if blk is not None:
            self._tick += 1
            blk.last_used = self._tick
        return blk

    def acquire(self, key) -> Optional[StoredBlock]:
        """Lookup + take a reference (protects the block from eviction
        for the holder's lifetime).  Counts a tier hit/miss."""
        blk = self.get(key)
        kind = key[0]
        self._interest.discard(key)          # demand arrived; hint served
        if blk is None:
            self.counters[f"misses_{kind}"] += 1
            return None
        blk.refcount += 1
        self.count_hit(blk)
        return blk

    def count_hit(self, blk: StoredBlock) -> None:
        """Record a tier hit on an already-referenced block (the engine
        acquires refs batch-wide *before* resolving, so hit accounting
        happens separately at resolution time)."""
        self._tick += 1
        blk.last_used = self._tick
        blk.hits += 1
        self.counters[f"hits_{blk.kind}"] += 1

    def release(self, key) -> None:
        blk = self.blocks.get(key)
        if blk is not None and blk.refcount > 0:
            blk.refcount -= 1

    def release_all(self, keys: Sequence) -> None:
        for key in keys:
            self.release(key)

    # ------------------------------ capacity -------------------------------
    def pages_held(self, kind: Optional[str] = None) -> int:
        return sum(
            len(b.pages) for b in self.blocks.values() if kind is None or b.kind == kind
        )

    def reclaimable_pages(self, exclude: Sequence = ()) -> int:
        """Pages eviction could free right now: unpinned, unreferenced
        blocks whose key is not in `exclude` (blocks an admission
        candidate counts on must not double as reclaimable space)."""
        ex = set(exclude)
        return sum(
            len(b.pages)
            for b in self.blocks.values()
            if not b.pinned and b.refcount == 0 and b.key not in ex
        )

    def _evict_lru(self) -> bool:
        """Evict the least-recently-used unpinned, unreferenced block.

        With a spill tier configured the victim's payload is demoted to
        host RAM (pages freed, bytes kept) instead of dropped; the spill
        tier itself trims oldest-first — the device LRU stamp rides the
        hop — whenever the demotion pushes it over capacity."""
        victims = [b for b in self.blocks.values() if not b.pinned and b.refcount == 0]
        if not victims:
            return False
        victim = min(victims, key=lambda b: b.last_used)
        del self.blocks[victim.key]
        self.pool.release_pages(victim.pages)
        self.counters["evictions"] += 1
        if self.spill_cap > 0:
            self._spill_put(
                SpilledBlock(
                    key=victim.key,
                    kind=victim.kind,
                    data_k=victim.data_k,
                    data_v=victim.data_v,
                    scale_k=victim.scale_k,
                    scale_v=victim.scale_v,
                    tokens=victim.tokens,
                    positions=victim.positions,
                    last_used=victim.last_used,
                    hits=victim.hits,
                )
            )
            if victim.key in self._interest:
                # a bound-but-unadmitted request declared it needs this
                # block: queue it for prefetch promotion right away
                self._interest.discard(victim.key)
                self._hints.append(victim.key)
        self.version += 1
        return True

    def _spill_put(self, sp: SpilledBlock) -> None:
        """Land one encoded payload in the host tier (replacing any stale
        entry under the same key) and trim oldest-first back under
        capacity."""
        old = self.spill.pop(sp.key, None)
        if old is not None:
            self.spill_nbytes -= old.nbytes
        self.spill[sp.key] = sp
        self.spill_nbytes += sp.nbytes
        self.counters["spills"] += 1
        while self.spill_nbytes > self.spill_cap and self.spill:
            drop = min(self.spill.values(), key=lambda s: s.last_used)
            del self.spill[drop.key]
            self.spill_nbytes -= drop.nbytes
            self.counters["spill_drops"] += 1

    def evict_for(self, n_pages: int) -> bool:
        """LRU-evict until `n_pages` are free in the pool.  -> success."""
        while self.pool.free_pages < n_pages:
            if not self._evict_lru():
                return False
        return True

    # ------------------------------- insert --------------------------------
    def insert(
        self,
        key,
        kind: str,
        k: np.ndarray,
        v: np.ndarray,
        tokens: Optional[np.ndarray] = None,
        positions: Optional[np.ndarray] = None,
        pinned: bool = False,
        keep_free: int = 0,
        defer_write: bool = False,
    ) -> Optional[StoredBlock]:
        """Insert a block's bytes into store-owned pages.

        Insertion is *optional*: it returns None (and counts a skip)
        when the tier budget is exhausted or when taking the pages would
        leave fewer than `keep_free` free pages even after LRU eviction
        — the caller falls back to private writes.  k/v: (t, L, Hkv, Dh)
        pre-RoPE bytes, row order matching `BlockRef.offsets`.

        ``defer_write`` stages the arena scatter in `_pending_writes`
        instead of paying an eager full-arena copy per block; the engine
        calls `flush_writes` once per prefill batch (the bytes must land
        before anything reads the arena — decode does, prefill doesn't).
        """
        if key in self.blocks:
            return self.blocks[key]
        n = np.asarray(k).shape[0]
        if n == 0:
            return None
        if key in self.spill:
            # content addressing: same key = same bytes, so the spilled
            # payload (already quantized) is the block — promote it
            # instead of re-quantizing the caller's copy
            blk = self._promote(key, keep_free=keep_free, defer_write=defer_write)
            if blk is not None:
                self.counters["spill_hits"] += 1
                return blk
            self.counters["insert_skips"] += 1
            return None
        data_k, scale_k = self._quant(kind, k)
        data_v, scale_v = self._quant(kind, v)
        blk = self._admit(
            key,
            kind,
            data_k,
            data_v,
            scale_k,
            scale_v,
            tokens=tokens,
            positions=positions,
            pinned=pinned,
            keep_free=keep_free,
            defer_write=defer_write,
        )
        if blk is None:
            if self.spill_cap > 0:
                # write-around: the device tier refused the bytes (tier
                # budget / pinned cap / keep_free), but the host tier can
                # still keep the encoded payload — a revisit then stages
                # from RAM instead of re-pulling across shards or
                # recomputing, and a prefetch hint can promote it later
                self._tick += 1
                self._spill_put(
                    SpilledBlock(
                        key=key,
                        kind=kind,
                        data_k=data_k,
                        data_v=data_v,
                        scale_k=scale_k,
                        scale_v=scale_v,
                        tokens=tokens,
                        positions=positions,
                        last_used=self._tick,
                    )
                )
                self.counters["insert_spills"] += 1
            self.counters["insert_skips"] += 1
            return None
        self.counters["inserts"] += 1
        return blk

    def _quant(
        self, kind: str, arr: np.ndarray
    ) -> Tuple[np.ndarray, Optional[np.ndarray]]:
        """Store-tier representation of incoming bytes.  Only the user
        and item tiers quantize: the prefix tier's shared content IS the
        recomputed content (admission credits it without a steal
        allowance), so it must stay bit-exact fp32."""
        arr = np.asarray(arr, np.float32)
        if self.kv_store_dtype == "int8" and kind != PREFIX_TIER:
            return quantize_rows(arr)
        return arr, None

    def _admit(
        self,
        key,
        kind: str,
        data_k: np.ndarray,
        data_v: np.ndarray,
        scale_k: Optional[np.ndarray],
        scale_v: Optional[np.ndarray],
        tokens: Optional[np.ndarray],
        positions: Optional[np.ndarray],
        pinned: bool,
        keep_free: int,
        defer_write: bool,
        hits: int = 0,
    ) -> Optional[StoredBlock]:
        """Budget gates + page allocation + arena write for an already
        store-encoded payload.  Shared by fresh inserts and spill
        promotions.  -> None on any budget refusal (caller counts it)."""
        n = data_k.shape[0]
        need = self.pool.pages_for(n)
        if kind == USER_TIER:
            if self.pages_held(USER_TIER) + need > self.max_user_pages:
                return None
        if pinned:
            held = sum(
                len(b.pages) for b in self.blocks.values() if b.pinned
            )
            if held + need > self.max_pinned_pages:
                return None
        while self.pages_held() + need > self.max_pages:
            if not self._evict_lru():
                return None
        if not self.evict_for(need + keep_free):
            return None
        pages = self.pool.alloc_pages(need)
        slots = self.pool.page_slots(pages)[:n]
        self._tick += 1
        blk = StoredBlock(
            key=key,
            kind=kind,
            pages=pages,
            slots=slots,
            data_k=data_k,
            data_v=data_v,
            scale_k=scale_k,
            scale_v=scale_v,
            tokens=tokens,
            positions=positions,
            pinned=pinned,
            last_used=self._tick,
            hits=hits,
            store=self,
        )
        # the arena always holds the fp32 view the engine reads; under
        # int8 that is dequantize(quantize(x)) — the accuracy-gated path
        if defer_write:
            self._pending_writes.append((slots, blk.host_k, blk.host_v))
        else:
            self.pool.write_slots(slots, blk.host_k, blk.host_v)
        self.blocks[key] = blk
        self.version += 1
        return blk

    def _promote(
        self, key, keep_free: int = 0, defer_write: bool = True
    ) -> Optional[StoredBlock]:
        """Re-stage a spilled block into device pages under its existing
        key.  The spill entry is only removed on success — a refusal
        leaves the bytes in the spill tier for a later attempt.  (The
        admission path may itself spill other victims and trim the spill
        tier, so the entry is re-popped defensively afterwards.)"""
        sp = self.spill.get(key)
        if sp is None:
            return None
        blk = self._admit(
            key,
            sp.kind,
            sp.data_k,
            sp.data_v,
            sp.scale_k,
            sp.scale_v,
            tokens=sp.tokens,
            positions=sp.positions,
            pinned=False,
            keep_free=keep_free,
            defer_write=defer_write,
            hits=sp.hits,
        )
        if blk is None:
            return None
        gone = self.spill.pop(key, None)
        if gone is not None:
            self.spill_nbytes -= gone.nbytes
        return blk

    # ------------------------------- prefetch -------------------------------
    def hint(self, keys: Sequence) -> None:
        """Affinity prefetch hints: content keys a queued request will
        need on this worker (the Eq. 2 router knows the destination
        before admission).  A key already in the spill tier queues for
        promotion directly; a still-resident (or absent) key registers
        *interest* — if churn demotes it before the hinting request
        admits, the eviction auto-queues the prefetch hint, so the
        bytes are swapped back ahead of the admission gate instead of
        re-entering through the insert path.  Duplicates are cheap
        no-ops at promote time."""
        for key in keys:
            if key in self.spill and key not in self.blocks:
                self._hints.append(key)
            else:
                self._interest.add(key)
                if len(self._interest) > 4 * (self._hints.maxlen or 512):
                    self._interest.clear()     # advisory state: shed, don't grow

    def prefetch(self, budget_pages: Optional[int] = None) -> int:
        """Promote hinted spill blocks to device, oldest hint first,
        within a per-tick page budget.  A promotion may demand-swap:
        the admission gates inside `_promote` evict LRU refcount-0
        blocks to make room, and with the spill tier on those victims
        demote to host RAM instead of dropping — the device tier is
        reordered toward hinted (imminently demanded) bytes, nothing is
        lost, and pinned or in-use pages are never touched.  A hint
        needing more pages than a whole tick's budget is dropped; so is
        one whose promotion is refused (every resident block still
        referenced) — the insert path promotes it on demand instead.
        -> promotions.
        """
        budget = (
            self.prefetch_pages_per_tick if budget_pages is None else budget_pages
        )
        if budget <= 0:
            return 0
        promoted = 0
        remaining = int(budget)
        while self._hints and remaining > 0:
            key = self._hints[0]
            sp = self.spill.get(key)
            if sp is None or key in self.blocks:
                self._hints.popleft()
                continue
            need = self.pool.pages_for(sp.n_tokens)
            if need > budget:
                self._hints.popleft()
                continue
            if need > remaining:
                break
            if self._promote(key, defer_write=True) is None:
                self._hints.popleft()
                continue
            self._hints.popleft()
            self.counters["prefetch_promotions"] += 1
            promoted += 1
            remaining -= need
        return promoted

    def flush_writes(self) -> None:
        """Land every deferred insert's bytes in ONE fused arena scatter."""
        self.pool.write_slots_batch(self._pending_writes)
        self._pending_writes = []

    # ------------------------------ migration ------------------------------
    def export_payload(self, key) -> Optional["BlockPayload"]:
        """Snapshot one block as a migration payload riding its existing
        content key.  Read-only; None for a key this store doesn't hold."""
        blk = self.blocks.get(key)
        if blk is None:
            return None
        return BlockPayload(
            key=blk.key,
            kind=blk.kind,
            slots=np.asarray(blk.slots, np.int64),
            host_k=blk.host_k,
            host_v=blk.host_v,
            tokens=blk.tokens,
            positions=blk.positions,
            pinned=blk.pinned,
        )

    def import_payload(
        self, payload: "BlockPayload", keep_free: int = 0
    ) -> Tuple[Optional[StoredBlock], bool]:
        """Resolve a migration payload against THIS store.

        -> (block holding the bytes with one reference taken for the
        migrating request, digest_hit).  A digest hit — the destination
        already holds the content key — pays zero transfer: the payload
        bytes are dead weight the transport never had to move (the
        beyond-prefix reuse fast path).  On a miss the payload is
        inserted under its original key/tier/pinning (deferred write;
        the importer flushes once per migration); a budget refusal
        returns (None, False) and the caller privatizes those positions
        instead.
        """
        blk = self.get(payload.key)
        if blk is not None:
            blk.refcount += 1
            return blk, True
        if payload.key in self.spill:
            # spill hit: the bytes are already on this worker's host RAM
            # — re-stage them through the normal admission path instead
            # of consuming the transported payload (still a digest hit:
            # the transport never needed to move the bytes)
            blk = self._promote(payload.key, keep_free=keep_free, defer_write=True)
            if blk is None:
                return None, False
            self.counters["spill_hits"] += 1
            blk.refcount += 1
            return blk, True
        blk = self.insert(
            payload.key,
            payload.kind,
            payload.host_k,
            payload.host_v,
            tokens=payload.tokens,
            positions=payload.positions,
            pinned=payload.pinned,
            keep_free=keep_free,
            defer_write=True,
        )
        if blk is None:
            return None, False
        blk.refcount += 1
        return blk, False

    # -------------------------------- stats --------------------------------
    def stats(self) -> dict:
        tiers = (USER_TIER, ITEM_TIER, PREFIX_TIER)
        hits = sum(self.counters[f"hits_{t}"] for t in tiers)
        misses = sum(self.counters[f"misses_{t}"] for t in tiers)
        return {
            "blocks": len(self.blocks),
            "device_blocks": len(self.blocks),
            "spill_blocks": len(self.spill),
            "spill_mbytes": self.spill_nbytes / 2**20,
            "dequant_s": self.dequant_s,
            "pages_user": self.pages_held(USER_TIER),
            "pages_item": self.pages_held(ITEM_TIER),
            "pages_prefix": self.pages_held(PREFIX_TIER),
            "hit_rate": hits / max(hits + misses, 1),
            **self.counters,
        }


def recompute_base_and_topk(
    plan: AssemblyPlan, have: np.ndarray, sel
) -> Tuple[np.ndarray, int]:
    """The deterministic half of `engine.select_recompute`: the base
    recompute mask (misses + trailing window; instruction tokens have
    no cache entry so ~have covers them — and under a prefix-tier hit
    they really are cached) plus the per-class top-k COUNT the Eq. 3
    budgets will add.  The chosen top-k *set* is score-dependent, its
    size is not — this single helper is what admission accounting, the
    prefix-tier content key and benchmark bucket pre-warming all build
    on, so they cannot drift from the engine's selection rule.
    """
    n = plan.n
    base = ~np.asarray(have, bool)
    base[max(0, n - sel.window) :] = True
    k_top = 0
    for kind, budget in ((2, sel.r_item), (1, sel.r_rev)):
        cls = int(((plan.seg_kind == kind) & ~base).sum())
        if cls:
            k_top += int(np.ceil(budget * cls))
    return base, k_top


def shape_bucket(
    plan: AssemblyPlan, have: np.ndarray, sel, bucket: int = 64
) -> Tuple[int, int]:
    """The (n_pad, r_pad) jit bucket one request's selective prefill
    lands in — known without running layer 0 (`recompute_base_and_topk`).
    """
    base, k_top = recompute_base_and_topk(plan, have, sel)
    r_count = int(base.sum()) + k_top
    n_pad = -(-plan.n // bucket) * bucket
    return n_pad, max(64, -(-r_count // 64) * 64)


def admission_pages(
    pool: PagedKVPool,
    store: Optional[SharedBlockStore],
    plan: AssemblyPlan,
    have: np.ndarray,
    sel,
    reuse: Optional[RequestReuse],
    n_reserve: int,
    bucket: int = 64,
) -> Tuple[int, int]:
    """Upper bound on the private pages one request consumes at prefill.

    -> (private page bound, number of blocks it may insert).  Without a
    store this is the plain `pages_for` demand.  With one, positions
    mappable from resident blocks are credited, minus a worst-case
    allowance for the selective pass stealing mapped positions back to
    private (the recompute *count* is deterministic from the plan shape
    even though the chosen set is score-dependent), so the bound stays
    a true upper bound and batcher-admitted prefills can never hit
    `PoolExhausted`.  Inserts need no extra charge: they are optional,
    and the engine's keep_free gate refuses any insert that would eat
    mandatory demand.  Prefix-tier positions are credited without a
    steal allowance — their shared content IS the recomputed content.
    """
    base_pages = pool.pages_for(plan.n + n_reserve)
    if store is None or reuse is None:
        return base_pages, 0
    n = plan.n
    mappable = np.zeros(n, bool)
    n_missing = 0
    for ref in reuse.blocks:
        if store.has(ref.key):
            mappable[ref.positions] = True
        elif ref.k is not None:
            n_missing += 1
    u_pos = None
    if reuse.user_key is not None:
        u_pos = user_reuse_positions(plan, have, reuse.prefix_end)
        ublk = store.peek(reuse.user_key)
        if ublk is not None:
            mappable[u_pos[np.isin(u_pos, ublk.positions)]] = True
        elif len(u_pos):
            n_missing += 1
    base_rec, k_top = recompute_base_and_topk(plan, have, sel)
    steal = int(mappable[base_rec].sum())
    steal += min(k_top, int(mappable[~base_rec].sum()))
    n_shared_min = max(int(mappable.sum()) - steal, 0)
    # prefix tier: credited without a steal allowance — its shared
    # content IS the recomputed content, so selection can't unshare it
    if reuse.prefix_key is not None and reuse.prefix_len:
        full_key = reuse.prefix_key + shape_bucket(plan, have, sel, bucket)
        if store.has(full_key):
            n_shared_min += min(reuse.prefix_len, n)
        else:
            n_missing += 1
    priv_slots = base_pages * pool.page_size - n_shared_min
    return -(-priv_slots // pool.page_size), n_missing


def check_partition(
    pool: PagedKVPool, store: Optional[SharedBlockStore] = None
) -> None:
    """Allocator + store invariant: every page (except scratch page 0)
    is owned by exactly one of {free list, one request's page table, the
    shared store}; slot-table entries only reference pages the request
    owns or the store holds; store blocks are internally consistent.
    Raises AssertionError on violation (tests call this after each op).
    """
    owner: Dict[int, str] = {}

    def claim(page: int, who: str) -> None:
        assert page != 0, f"{who} owns the scratch page"
        assert page not in owner, f"page {page}: {owner[page]} and {who}"
        owner[page] = who

    for page in pool._free:
        claim(page, "free-list")
    for rid, pages in pool.page_tables.items():
        for page in pages:
            claim(page, f"request {rid}")
    store_pages = set()
    if store is not None:
        both = set(store.blocks) & set(store.spill)
        assert not both, f"keys in both device and spill tiers: {both}"
        for blk in store.blocks.values():
            assert blk.refcount >= 0, f"{blk.key}: negative refcount"
            assert len(blk.pages) == pool.pages_for(blk.n_tokens)
            for page in blk.pages:
                claim(page, f"store block {blk.key}")
                store_pages.add(page)
            assert set(blk.slots // pool.page_size) <= set(blk.pages)
    assert set(owner) == set(range(1, pool.n_pages)), (
        "pages leaked or double-freed: "
        f"{set(range(1, pool.n_pages)) ^ set(owner)}"
    )
    for rid, table in pool.slot_tables.items():
        own = set(pool.page_tables[rid])
        for page in np.unique(table // pool.page_size):
            assert int(page) in own or int(page) in store_pages, (
                f"request {rid} slot table references page {page} it "
                "neither owns nor shares"
            )
