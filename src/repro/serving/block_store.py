"""Cross-request beyond-prefix KV reuse: a shared block store over the pool.

This is the serving-path realization of the paper's **stratified
storage** (§III-B): KV blocks are content-addressed (blake2b over the
bytes that determine them), held in pool pages owned by the store, and
shared across concurrent requests through refcounts.  Two tiers:

* **user tier** — one pinned block per (instruction + user history)
  prefix, replicated per worker.  Its bytes are the *deterministic*
  part of the prefix KV: the always-fresh layer-0 plane (a pure
  function of the token ids — bitwise reproducible across padding
  buckets) plus the semantic-prototype deep layers.  Positions the
  selective pass recomputes vary per request and are never part of the
  block; each request overlays them privately.
* **item tier** — one block per item description, fed by the cluster's
  `StagedBlocks` / transfer ledger, holding the offline-precomputed
  block bytes for every layer (the offline layer-0 KV is bitwise equal
  to the online fresh layer-0 for the same tokens).  Unpinned:
  LRU-evicted when unreferenced and the pool is under pressure.

Because every stored byte equals what the no-reuse path would have
written for the same position, mapping a request's slot-table entries
at shared slots changes *where* decode reads, never *what* — decoded
tokens are bitwise identical with reuse on or off.  The store also
keeps the host-side block bytes, so a cluster worker whose store holds
an item block skips the cross-shard transfer entirely (a zero-latency
hit in the ledger's terms).
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.assembly import FROM_SEMANTIC, AssemblyPlan
from repro.serving.kv_pool import PagedKVPool

USER_TIER = "user"
ITEM_TIER = "item"
# The instruction prefix: identical recomputed rows for every request.
# Keyed by (digest, n_pad, r_pad) — the jit-bucket shape — because the
# rows come out of the selective stack's trace; within one trace shape
# they are bitwise request-invariant (and the batched↔loop parity test
# pins batch-size invariance).  This tier is what subsumes classic
# prefix caching inside the beyond-prefix store.
PREFIX_TIER = "prefix"


def content_key(kind: str, *arrays) -> Tuple[str, str]:
    """Content address: blake2b over the arrays that determine the bytes."""
    h = hashlib.blake2b(digest_size=16)
    for a in arrays:
        a = np.ascontiguousarray(a)
        h.update(str(a.dtype).encode())
        h.update(str(a.shape).encode())
        h.update(a.tobytes())
    return (kind, h.hexdigest())


@dataclass
class BlockRef:
    """One reusable block inside a request's prompt, as seen by the
    engine: where it lands (`positions`), which block rows those map to
    (`offsets`), and the host bytes to insert on a store miss."""

    key: Tuple[str, str]
    positions: np.ndarray
    offsets: np.ndarray
    k: Optional[np.ndarray] = None
    v: Optional[np.ndarray] = None
    tokens: Optional[np.ndarray] = None


@dataclass
class RequestReuse:
    """Per-request reuse metadata attached to a `BatchRequest`."""

    user_key: Optional[Tuple[str, str]] = None
    prefix_end: int = 0
    blocks: List[BlockRef] = field(default_factory=list)
    # instruction-prefix tier: content digest + how many leading tokens
    # it covers; the engine appends the (n_pad, r_pad) bucket at runtime
    prefix_key: Optional[Tuple[str, str]] = None
    prefix_len: int = 0


@dataclass(frozen=True)
class BlockPayload:
    """One store block as it rides a KV migration: the content key plus
    everything needed to re-insert on a destination-store miss, and the
    SOURCE store's physical slot ids so the importer can translate the
    migrating request's shared slot-table entries.  Content addressing
    is what makes this a *tier* rather than a copy: the key travels
    first, and a destination that already holds it never moves the
    bytes."""

    key: Tuple[str, str]
    kind: str
    slots: np.ndarray  # (n_tokens,) SOURCE physical slot ids
    host_k: np.ndarray
    host_v: np.ndarray
    tokens: Optional[np.ndarray] = None
    positions: Optional[np.ndarray] = None
    pinned: bool = False

    @property
    def nbytes(self) -> int:
        return self.host_k.nbytes + self.host_v.nbytes


@dataclass
class StoredBlock:
    key: Tuple[str, str]
    kind: str
    pages: List[int]
    slots: np.ndarray  # (n_tokens,) physical slot ids, block-row order
    host_k: np.ndarray  # host copies: staging + re-insert after eviction
    host_v: np.ndarray
    tokens: Optional[np.ndarray] = None
    positions: Optional[np.ndarray] = None  # user tier: covered positions
    pinned: bool = False
    refcount: int = 0
    last_used: int = 0
    hits: int = 0

    @property
    def n_tokens(self) -> int:
        return len(self.slots)


def user_reuse_positions(
    plan: AssemblyPlan, have: np.ndarray, prefix_end: int
) -> np.ndarray:
    """Prefix positions whose bytes are deterministic per user: semantic
    reuse hits inside [0, prefix_end).  Everything else in the prefix
    (markers, separators, instruction) is always recomputed."""
    pos = np.where((plan.source == FROM_SEMANTIC) & have)[0]
    return pos[pos < prefix_end]


class SharedBlockStore:
    """Content-addressed, ref-counted KV block sharing over a pool.

    Pages the store allocates (`pool.alloc_pages`) belong to the store
    until a block is evicted; requests reference them through slot-table
    entries and per-request refcounts (`acquire`/`release`).  Eviction
    only ever touches unpinned blocks with refcount 0, in LRU order.
    """

    def __init__(
        self,
        pool: PagedKVPool,
        max_pages: Optional[int] = None,
        max_user_pages: Optional[int] = None,
    ):
        self.pool = pool
        # the store must never crowd requests out of their own pool:
        # total budget is half the pages (LRU keeps the hot set), and
        # PINNED pages — which eviction can never reclaim, so they can
        # permanently wedge admission on a small pool — are capped at a
        # quarter across tiers (a too-small pool simply gets no prefix
        # tier rather than a deadlocked batcher)
        self.max_pages = (
            max_pages if max_pages is not None else max(pool.n_pages // 2, 1)
        )
        self.max_pinned_pages = max(pool.n_pages // 4, 1)
        self.max_user_pages = (
            max_user_pages
            if max_user_pages is not None
            else max(pool.n_pages // 4, 1)
        )
        self.blocks: Dict[Tuple[str, str], StoredBlock] = {}
        self._pending_writes: List[tuple] = []
        self._tick = 0
        # bumped on every insert/eviction: lets admission accounting
        # memoize per-request page bounds until the resident set changes
        self.version = 0
        self.counters = {
            "hits_user": 0,
            "hits_item": 0,
            "hits_prefix": 0,
            "misses_user": 0,
            "misses_item": 0,
            "misses_prefix": 0,
            "inserts": 0,
            "insert_skips": 0,
            "evictions": 0,
        }

    # ------------------------------- lookup --------------------------------
    def has(self, key) -> bool:
        return key in self.blocks

    def peek(self, key) -> Optional[StoredBlock]:
        """Lookup without touching LRU state or counters (admission)."""
        return self.blocks.get(key)

    def get(self, key) -> Optional[StoredBlock]:
        blk = self.blocks.get(key)
        if blk is not None:
            self._tick += 1
            blk.last_used = self._tick
        return blk

    def acquire(self, key) -> Optional[StoredBlock]:
        """Lookup + take a reference (protects the block from eviction
        for the holder's lifetime).  Counts a tier hit/miss."""
        blk = self.get(key)
        kind = key[0]
        if blk is None:
            self.counters[f"misses_{kind}"] += 1
            return None
        blk.refcount += 1
        self.count_hit(blk)
        return blk

    def count_hit(self, blk: StoredBlock) -> None:
        """Record a tier hit on an already-referenced block (the engine
        acquires refs batch-wide *before* resolving, so hit accounting
        happens separately at resolution time)."""
        self._tick += 1
        blk.last_used = self._tick
        blk.hits += 1
        self.counters[f"hits_{blk.kind}"] += 1

    def release(self, key) -> None:
        blk = self.blocks.get(key)
        if blk is not None and blk.refcount > 0:
            blk.refcount -= 1

    def release_all(self, keys: Sequence) -> None:
        for key in keys:
            self.release(key)

    # ------------------------------ capacity -------------------------------
    def pages_held(self, kind: Optional[str] = None) -> int:
        return sum(
            len(b.pages) for b in self.blocks.values() if kind is None or b.kind == kind
        )

    def reclaimable_pages(self, exclude: Sequence = ()) -> int:
        """Pages eviction could free right now: unpinned, unreferenced
        blocks whose key is not in `exclude` (blocks an admission
        candidate counts on must not double as reclaimable space)."""
        ex = set(exclude)
        return sum(
            len(b.pages)
            for b in self.blocks.values()
            if not b.pinned and b.refcount == 0 and b.key not in ex
        )

    def _evict_lru(self) -> bool:
        """Evict the least-recently-used unpinned, unreferenced block."""
        victims = [b for b in self.blocks.values() if not b.pinned and b.refcount == 0]
        if not victims:
            return False
        victim = min(victims, key=lambda b: b.last_used)
        del self.blocks[victim.key]
        self.pool.release_pages(victim.pages)
        self.counters["evictions"] += 1
        self.version += 1
        return True

    def evict_for(self, n_pages: int) -> bool:
        """LRU-evict until `n_pages` are free in the pool.  -> success."""
        while self.pool.free_pages < n_pages:
            if not self._evict_lru():
                return False
        return True

    # ------------------------------- insert --------------------------------
    def insert(
        self,
        key,
        kind: str,
        k: np.ndarray,
        v: np.ndarray,
        tokens: Optional[np.ndarray] = None,
        positions: Optional[np.ndarray] = None,
        pinned: bool = False,
        keep_free: int = 0,
        defer_write: bool = False,
    ) -> Optional[StoredBlock]:
        """Insert a block's bytes into store-owned pages.

        Insertion is *optional*: it returns None (and counts a skip)
        when the tier budget is exhausted or when taking the pages would
        leave fewer than `keep_free` free pages even after LRU eviction
        — the caller falls back to private writes.  k/v: (t, L, Hkv, Dh)
        pre-RoPE bytes, row order matching `BlockRef.offsets`.

        ``defer_write`` stages the arena scatter in `_pending_writes`
        instead of paying an eager full-arena copy per block; the engine
        calls `flush_writes` once per prefill batch (the bytes must land
        before anything reads the arena — decode does, prefill doesn't).
        """
        if key in self.blocks:
            return self.blocks[key]
        n = k.shape[0]
        if n == 0:
            return None
        need = self.pool.pages_for(n)
        if kind == USER_TIER:
            if self.pages_held(USER_TIER) + need > self.max_user_pages:
                self.counters["insert_skips"] += 1
                return None
        if pinned:
            held = sum(
                len(b.pages) for b in self.blocks.values() if b.pinned
            )
            if held + need > self.max_pinned_pages:
                self.counters["insert_skips"] += 1
                return None
        while self.pages_held() + need > self.max_pages:
            if not self._evict_lru():
                self.counters["insert_skips"] += 1
                return None
        if not self.evict_for(need + keep_free):
            self.counters["insert_skips"] += 1
            return None
        pages = self.pool.alloc_pages(need)
        slots = self.pool.page_slots(pages)[:n]
        host_k = np.asarray(k, np.float32)
        host_v = np.asarray(v, np.float32)
        if defer_write:
            self._pending_writes.append((slots, host_k, host_v))
        else:
            self.pool.write_slots(slots, host_k, host_v)
        self._tick += 1
        blk = StoredBlock(
            key=key,
            kind=kind,
            pages=pages,
            slots=slots,
            host_k=host_k,
            host_v=host_v,
            tokens=tokens,
            positions=positions,
            pinned=pinned,
            last_used=self._tick,
        )
        self.blocks[key] = blk
        self.counters["inserts"] += 1
        self.version += 1
        return blk

    def flush_writes(self) -> None:
        """Land every deferred insert's bytes in ONE fused arena scatter."""
        self.pool.write_slots_batch(self._pending_writes)
        self._pending_writes = []

    # ------------------------------ migration ------------------------------
    def export_payload(self, key) -> Optional["BlockPayload"]:
        """Snapshot one block as a migration payload riding its existing
        content key.  Read-only; None for a key this store doesn't hold."""
        blk = self.blocks.get(key)
        if blk is None:
            return None
        return BlockPayload(
            key=blk.key,
            kind=blk.kind,
            slots=np.asarray(blk.slots, np.int64),
            host_k=blk.host_k,
            host_v=blk.host_v,
            tokens=blk.tokens,
            positions=blk.positions,
            pinned=blk.pinned,
        )

    def import_payload(
        self, payload: "BlockPayload", keep_free: int = 0
    ) -> Tuple[Optional[StoredBlock], bool]:
        """Resolve a migration payload against THIS store.

        -> (block holding the bytes with one reference taken for the
        migrating request, digest_hit).  A digest hit — the destination
        already holds the content key — pays zero transfer: the payload
        bytes are dead weight the transport never had to move (the
        beyond-prefix reuse fast path).  On a miss the payload is
        inserted under its original key/tier/pinning (deferred write;
        the importer flushes once per migration); a budget refusal
        returns (None, False) and the caller privatizes those positions
        instead.
        """
        blk = self.get(payload.key)
        if blk is not None:
            blk.refcount += 1
            return blk, True
        blk = self.insert(
            payload.key,
            payload.kind,
            payload.host_k,
            payload.host_v,
            tokens=payload.tokens,
            positions=payload.positions,
            pinned=payload.pinned,
            keep_free=keep_free,
            defer_write=True,
        )
        if blk is None:
            return None, False
        blk.refcount += 1
        return blk, False

    # -------------------------------- stats --------------------------------
    def stats(self) -> dict:
        tiers = (USER_TIER, ITEM_TIER, PREFIX_TIER)
        hits = sum(self.counters[f"hits_{t}"] for t in tiers)
        misses = sum(self.counters[f"misses_{t}"] for t in tiers)
        return {
            "blocks": len(self.blocks),
            "pages_user": self.pages_held(USER_TIER),
            "pages_item": self.pages_held(ITEM_TIER),
            "pages_prefix": self.pages_held(PREFIX_TIER),
            "hit_rate": hits / max(hits + misses, 1),
            **self.counters,
        }


def recompute_base_and_topk(
    plan: AssemblyPlan, have: np.ndarray, sel
) -> Tuple[np.ndarray, int]:
    """The deterministic half of `engine.select_recompute`: the base
    recompute mask (misses + trailing window; instruction tokens have
    no cache entry so ~have covers them — and under a prefix-tier hit
    they really are cached) plus the per-class top-k COUNT the Eq. 3
    budgets will add.  The chosen top-k *set* is score-dependent, its
    size is not — this single helper is what admission accounting, the
    prefix-tier content key and benchmark bucket pre-warming all build
    on, so they cannot drift from the engine's selection rule.
    """
    n = plan.n
    base = ~np.asarray(have, bool)
    base[max(0, n - sel.window) :] = True
    k_top = 0
    for kind, budget in ((2, sel.r_item), (1, sel.r_rev)):
        cls = int(((plan.seg_kind == kind) & ~base).sum())
        if cls:
            k_top += int(np.ceil(budget * cls))
    return base, k_top


def shape_bucket(
    plan: AssemblyPlan, have: np.ndarray, sel, bucket: int = 64
) -> Tuple[int, int]:
    """The (n_pad, r_pad) jit bucket one request's selective prefill
    lands in — known without running layer 0 (`recompute_base_and_topk`).
    """
    base, k_top = recompute_base_and_topk(plan, have, sel)
    r_count = int(base.sum()) + k_top
    n_pad = -(-plan.n // bucket) * bucket
    return n_pad, max(64, -(-r_count // 64) * 64)


def admission_pages(
    pool: PagedKVPool,
    store: Optional[SharedBlockStore],
    plan: AssemblyPlan,
    have: np.ndarray,
    sel,
    reuse: Optional[RequestReuse],
    n_reserve: int,
    bucket: int = 64,
) -> Tuple[int, int]:
    """Upper bound on the private pages one request consumes at prefill.

    -> (private page bound, number of blocks it may insert).  Without a
    store this is the plain `pages_for` demand.  With one, positions
    mappable from resident blocks are credited, minus a worst-case
    allowance for the selective pass stealing mapped positions back to
    private (the recompute *count* is deterministic from the plan shape
    even though the chosen set is score-dependent), so the bound stays
    a true upper bound and batcher-admitted prefills can never hit
    `PoolExhausted`.  Inserts need no extra charge: they are optional,
    and the engine's keep_free gate refuses any insert that would eat
    mandatory demand.  Prefix-tier positions are credited without a
    steal allowance — their shared content IS the recomputed content.
    """
    base_pages = pool.pages_for(plan.n + n_reserve)
    if store is None or reuse is None:
        return base_pages, 0
    n = plan.n
    mappable = np.zeros(n, bool)
    n_missing = 0
    for ref in reuse.blocks:
        if store.has(ref.key):
            mappable[ref.positions] = True
        elif ref.k is not None:
            n_missing += 1
    u_pos = None
    if reuse.user_key is not None:
        u_pos = user_reuse_positions(plan, have, reuse.prefix_end)
        ublk = store.peek(reuse.user_key)
        if ublk is not None:
            mappable[u_pos[np.isin(u_pos, ublk.positions)]] = True
        elif len(u_pos):
            n_missing += 1
    base_rec, k_top = recompute_base_and_topk(plan, have, sel)
    steal = int(mappable[base_rec].sum())
    steal += min(k_top, int(mappable[~base_rec].sum()))
    n_shared_min = max(int(mappable.sum()) - steal, 0)
    # prefix tier: credited without a steal allowance — its shared
    # content IS the recomputed content, so selection can't unshare it
    if reuse.prefix_key is not None and reuse.prefix_len:
        full_key = reuse.prefix_key + shape_bucket(plan, have, sel, bucket)
        if store.has(full_key):
            n_shared_min += min(reuse.prefix_len, n)
        else:
            n_missing += 1
    priv_slots = base_pages * pool.page_size - n_shared_min
    return -(-priv_slots // pool.page_size), n_missing


def check_partition(
    pool: PagedKVPool, store: Optional[SharedBlockStore] = None
) -> None:
    """Allocator + store invariant: every page (except scratch page 0)
    is owned by exactly one of {free list, one request's page table, the
    shared store}; slot-table entries only reference pages the request
    owns or the store holds; store blocks are internally consistent.
    Raises AssertionError on violation (tests call this after each op).
    """
    owner: Dict[int, str] = {}

    def claim(page: int, who: str) -> None:
        assert page != 0, f"{who} owns the scratch page"
        assert page not in owner, f"page {page}: {owner[page]} and {who}"
        owner[page] = who

    for page in pool._free:
        claim(page, "free-list")
    for rid, pages in pool.page_tables.items():
        for page in pages:
            claim(page, f"request {rid}")
    store_pages = set()
    if store is not None:
        for blk in store.blocks.values():
            assert blk.refcount >= 0, f"{blk.key}: negative refcount"
            assert len(blk.pages) == pool.pages_for(blk.n_tokens)
            for page in blk.pages:
                claim(page, f"store block {blk.key}")
                store_pages.add(page)
            assert set(blk.slots // pool.page_size) <= set(blk.pages)
    assert set(owner) == set(range(1, pool.n_pages)), (
        "pages leaked or double-freed: "
        f"{set(range(1, pool.n_pages)) ^ set(owner)}"
    )
    for rid, table in pool.slot_tables.items():
        own = set(pool.page_tables[rid])
        for page in np.unique(table // pool.page_size):
            assert int(page) in own or int(page) in store_pages, (
                f"request {rid} slot table references page {page} it "
                "neither owns nor shares"
            )
