"""Multi-instance real serving: K JAX engines over sharded item caches.

This is the distributed half of the paper running on real engines rather
than the analytic simulator: `ClusterEngine` instantiates K
`serving.batch_engine.BatchEngine` workers — each with its own
`PagedKVPool`, its own continuous-batching queue and its own
Algorithm-1 item-cache shard (hot items replicated everywhere, long-tail
items resident only on their shard) — behind the Eq. 2 affinity
scheduler, which dispatches every arrival using *live* per-worker
backlog and the real placement map.

Residency is enforced, not simulated: a request routed to a worker whose
shard lacks one of its item blocks triggers an explicit transfer step —
the bytes are pulled from the holder shard through
`core.item_cache.ShardClient` (ledgered per block) and the worker's
clock is charged the modeled network time (`core.cost_model.fetch_time_s`
with the paper's 100 Gbps interconnect) — or, with `config.mesh`
enabled, the *measured* wall time of a real `jax.device_put`
device-to-device copy between the workers' home devices.  Routing
therefore changes *where* a request runs and what it costs, never
*what* it decodes: the
staged bytes are identical on every worker, which the parity tests pin
down.

Wall-clock semantics: the K engines execute serially on this host, but
each worker's clock accumulates only its own backend-reported step
seconds — the cluster models K instances running in parallel on
dedicated hardware (per-worker TTFT is each instance's own wall work).
"""
from __future__ import annotations

import time
import warnings
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core import assembly as ASM
from repro.core import cost_model as CM
from repro.core import engine as ENG
from repro.core import item_cache as IC
from repro.core import scheduler as SCH
from repro.data import synth as SY
from repro.serving import api as API
from repro.serving import workload as WL
from repro.serving.batch_engine import BatchEngine, RequestKV, migration_bytes
from repro.serving.batching import (
    ClusterBatcher,
    Completion,
    DecodeEntry,
    JaxEngineBackend,
    PendingRequest,
    WorkerState,
)
from repro.serving.kv_pool import PoolExhausted


class ClusterWorkerBackend(JaxEngineBackend):
    """`JaxEngineBackend` plus the explicit item-block transfer step.

    A request whose plan references blocks not resident on this worker's
    shard pays a modeled network transfer the first time it prefills;
    the bytes really were pulled from the peer shard (`ShardClient`
    ledger), so the step is measurable in both seconds and bytes.
    """

    def __init__(
        self,
        engine: BatchEngine,
        shard: Optional[IC.ShardClient] = None,
        mode: str = "rcllm",
        hw: CM.Hardware = CM.V5E_1,
    ):
        super().__init__(engine, mode=mode, plans={})
        self.shard = shard
        self.hw = hw
        self.pending_transfer_s: Dict[int, float] = {}  # rid -> seconds owed
        self.transfer_seconds = 0.0
        # cross-shard pulls skipped because the worker's shared block
        # store already held the (previously transferred) item bytes
        self.transfers_avoided = 0
        # KV-migration ledger (disaggregated serving): requests this
        # worker received mid-flight, the pages/bytes that moved, the
        # seconds billed, and store payloads skipped on a digest hit
        self.migrations_in = 0
        self.migrated_pages = 0
        self.migration_bytes = 0
        self.migration_seconds = 0.0
        self.migration_digest_hits = 0

    def prefill(self, batch: Sequence[PendingRequest]) -> float:
        dt = super().prefill(batch)
        moved = sum(self.pending_transfer_s.pop(r.rid, 0.0) for r in batch)
        self.transfer_seconds += moved
        return dt + moved

    def step(self, budget, decode_batch, prefill_queue):
        """Per-tick accounting for the chunked discipline: a request's
        owed transfer time is billed in the tick its first prefill
        chunk runs (the staged bytes must be resident before layer 0
        reads the cached KV), not as a whole-wave surcharge."""
        rep, dt = super().step(budget, decode_batch, prefill_queue)
        moved = sum(self.pending_transfer_s.pop(rid, 0.0) for rid in rep.started)
        self.transfer_seconds += moved
        return rep, dt + moved

    def finish(self, req: PendingRequest) -> None:
        # unlike the single-engine backend (caller owns and may reuse the
        # plans dict across passes), the cluster binds each plan exactly
        # once at dispatch — release its assembled KV with the request,
        # or a long run retains every request's (n, L, Hkv, Dh) arrays
        super().finish(req)
        self.plans.pop(req.rid, None)
        self.reuse.pop(req.rid, None)
        self.pending_transfer_s.pop(req.rid, None)

    def evacuate(self, rid: int) -> None:
        super().evacuate(rid)
        self.pending_transfer_s.pop(rid, None)


@dataclass
class WorkerReport:
    worker: int
    n_requests: int
    mean_hit_rate: Optional[float]   # None when no request ran here
    transfer_blocks: int
    transfer_tokens: int
    transfer_bytes: int
    transfer_seconds: float
    pool_peak_pages: int
    busy_seconds: float
    preempted: int = 0
    # shared-block-store tier stats when kv_reuse is on (None otherwise):
    # user/item tier hit rates + pages held + transfers avoided
    kv_reuse: Optional[dict] = None
    # disaggregated serving: KV migrations this worker received
    # (decode role) / handed off (prefill role), and what they cost
    migrations: int = 0
    migrated_out: int = 0
    migrated_pages: int = 0
    migration_bytes: int = 0
    migration_s: float = 0.0
    migration_digest_hits: int = 0
    # tiered store: device/spill occupancy and tier-traffic counters
    # (zero everywhere unless kv_reuse is on)
    device_blocks: int = 0
    spill_blocks: int = 0
    spill_hits: int = 0
    prefetch_promotions: int = 0
    dequant_s: float = 0.0


@dataclass
class ClusterReport:
    """What one cluster run produced, per request and per worker."""

    completions: List[Completion]
    assigned: Dict[int, int]  # rid -> worker
    hit_rate: Dict[int, float]  # rid -> item-cache hit rate on its worker
    generated: Dict[int, List[int]]  # rid -> decoded tokens
    workers: List[WorkerReport]
    policy: str

    def ttft(self) -> np.ndarray:
        done = sorted(self.completions, key=lambda c: c.rid)
        return np.asarray([c.first_token_s - c.arrival_s for c in done])

    def mean_hit_rate(self) -> float:
        return float(np.mean(list(self.hit_rate.values())))

    def summary(self) -> dict:
        ttft = self.ttft()
        return {
            "policy": self.policy,
            "requests": len(self.completions),
            "mean_hit_rate": round(self.mean_hit_rate(), 4),
            "ttft_p50_s": float(np.percentile(ttft, 50)),
            "ttft_p90_s": float(np.percentile(ttft, 90)),
            "ttft_mean_s": float(ttft.mean()),
            "transfer_blocks": sum(w.transfer_blocks for w in self.workers),
            "transfer_mbytes": round(
                sum(w.transfer_bytes for w in self.workers) / 1e6, 3
            ),
            "transfer_seconds": round(
                sum(w.transfer_seconds for w in self.workers), 6
            ),
        }


class ClusterEngine:
    """K real engine workers behind the Eq. 2 affinity dispatcher.

    `system` is an `RcLLMSystem` whose placement was built with
    `k_instances == config.k`; each worker w serves placement shard w.
    `config.mode` selects the prefill path ("rcllm" beyond-prefix
    selective, or "full" recompute — the latter never touches the item
    cache, so transfers and hit rates degenerate to the placement map
    only).

    Construction takes one `api.ServeConfig` — every engine / scheduler
    / backend / kernel / reuse knob lives there, validated up front.
    The historical per-knob keywords (``ClusterEngine(system, k=2,
    kv_reuse=True, ...)``) still work through a deprecation shim that
    folds them into a `ServeConfig`, with one `DeprecationWarning`.
    """

    #: legacy per-knob keywords the shim folds into a ServeConfig
    LEGACY_KW = frozenset(API.ServeConfig.LEGACY_FLAGS.values()) | {"max_decode_batch"}

    def __init__(
        self,
        system,
        config: Optional[API.ServeConfig] = None,
        *,
        sel: Optional[ENG.SelectiveConfig] = None,
        hw: CM.Hardware = CM.V5E_1,
        seed: int = 0,
        alpha: float = 0.7,
        beta: float = 0.3,
        **legacy,
    ):
        if legacy:
            unknown = sorted(set(legacy) - self.LEGACY_KW)
            if unknown:
                raise TypeError(f"unknown ClusterEngine kwargs: {unknown}")
            keys = ",".join(
                f"{k}={API.render_value(v)}"
                for k, v in sorted(legacy.items())
                if v is not None
            )
            warnings.warn(
                "per-knob ClusterEngine keywords are deprecated; pass one "
                f"api.ServeConfig (--config {keys})",
                DeprecationWarning,
                stacklevel=2,
            )
            legacy = {k: v for k, v in legacy.items() if v is not None}
            if isinstance(legacy.get("kv_reuse"), str):
                legacy["kv_reuse"] = legacy["kv_reuse"] == "on"
            config = (config or API.ServeConfig()).replace(**legacy)
        if config is None:
            raise TypeError("ClusterEngine needs an api.ServeConfig (or legacy kwargs)")
        if config.engine != "jax":
            raise ValueError(
                f"ClusterEngine runs real engines; config.engine="
                f"{config.engine!r} (the simulator cluster is "
                "launch/serve.py run_sim)"
            )
        k, mode = config.k, config.mode
        if system.placement.k != k:
            raise ValueError(
                f"placement has {system.placement.k} shards, cluster wants "
                f"{k} workers: rebuild the system with k_instances={k}"
            )
        if mode == "rcllm" and system.item_store is None:
            raise ValueError(
                "mode='rcllm' needs the system's item store (the sharded "
                "item-KV pool); build the system with one, or use "
                "mode='full'"
            )
        self.system = system
        self.config = config
        self.k = k
        self.mode = mode
        self.hw = hw
        # the attention-backend seam: workers run the system's model under
        # the config's attention implementation (jnp reference vs the
        # Pallas kernels) — the offline caches were built once with the
        # system's config and are backend-invariant (pre-RoPE bytes)
        self.cfg = config.apply_to(system.cfg)
        self.kv_reuse = config.kv_reuse
        self._item_keys: Dict[int, tuple] = {}
        # under a real mesh each worker gets a home device (round-robin
        # over the host's devices): cross-shard item pulls become real
        # jax.device_put device-to-device copies whose *measured* wall
        # time is billed instead of the modeled network time
        self.worker_devices = None
        if config.mesh.enabled:
            import jax

            devs = jax.devices()
            self.worker_devices = [devs[w % len(devs)] for w in range(k)]
        self.backends: List[ClusterWorkerBackend] = []
        for w in range(k):
            engine = API.build_engine(system.params, system.cfg, config, sel=sel)
            shard = None
            if system.item_store is not None:
                shard = IC.ShardClient(
                    system.item_store, w, devices=self.worker_devices
                )
            backend = ClusterWorkerBackend(engine, shard, mode=mode, hw=hw)
            self.backends.append(backend)
        self.scheduler = SCH.ClusterScheduler(
            system.placement,
            policy=config.policy,
            alpha=alpha,
            beta=beta,
            seed=seed,
        )
        self.batcher = ClusterBatcher(
            self.backends,
            dispatch=self._dispatch,
            max_batch_tokens=config.max_batch_tokens,
            max_decode_batch=config.max_decode_batch,
            sched=config.sched,
            chunk_tokens=config.chunk_tokens,
            step_tokens=config.step_tokens,
        )
        # disaggregated serving: type every worker, route admissions to
        # the prefill side, and register the migration hook that hands
        # finished prefills to a decode worker over the block-store
        # transport (unified config leaves every worker untyped)
        self.disagg = config.disagg
        self._prefill_ids = list(range(k))
        self._decode_ids: List[int] = []
        if self.disagg.enabled:
            self._prefill_ids = [
                w for w in range(k) if self.disagg.role_of(w) == "prefill"
            ]
            self._decode_ids = [
                w for w in range(k) if self.disagg.role_of(w) == "decode"
            ]
            for w, worker in enumerate(self.batcher.workers):
                worker.role = self.disagg.role_of(w)
                if worker.role == "prefill":
                    worker.migrate = self._migrate
        self._trace_by_rid: Dict[int, object] = {}
        self.assigned: Dict[int, int] = {}
        self.hit_rate: Dict[int, float] = {}

    # ------------------------------ dispatch ------------------------------
    def _dispatch(
        self, req: PendingRequest, t: float, workers: List[WorkerState]
    ) -> int:
        rq = self._trace_by_rid[req.rid]
        if self.disagg.enabled:
            wid = self._dispatch_prefill(rq, t, workers)
        else:
            depths = [w.backlog_seconds(t) for w in workers]
            wid = self.scheduler.dispatch(rq.candidate_items, depths)
        self._bind(req, rq, wid)
        return wid

    def _dispatch_prefill(
        self, rq, t: float, workers: List[WorkerState]
    ) -> int:
        """Admission routing under disaggregation: the configured policy
        runs over the prefill workers only (decode workers never admit —
        they receive requests through migration)."""
        inds = self._prefill_ids
        sch = self.scheduler
        if sch.policy == "round_robin":
            wid = inds[sch.state.rr_next % len(inds)]
            sch.state.rr_next += 1
            return wid
        if sch.policy == "random":
            return int(sch.rng.choice(inds))
        depths = np.asarray(
            [workers[w].backlog_seconds(t) for w in inds], float
        )
        if sch.policy == "least_loaded":
            return inds[int(np.argmin(depths))]
        hits = SCH.hit_vector(
            np.asarray(rq.candidate_items), self.system.placement
        )[inds]
        hi = depths.max()
        load = depths / hi if hi > 0 else np.zeros_like(depths)
        if sch.policy == "hit_only":
            score = hits - 1e-9 * load
        elif sch.policy == "load_only":
            score = -load
        else:
            score = sch.alpha * hits + sch.beta * (1.0 - load)  # Eq. 2
        return inds[int(np.argmax(score))]

    # ------------------------------ migration ------------------------------
    def _migrate(
        self, src: WorkerState, entry: DecodeEntry, admitted_s: float
    ) -> bool:
        """Hand one finished prefill from `src` to a decode worker.

        Destination choice extends the Eq. 2 affinity score with a
        migration-byte term: `mig_gamma * (1 - bytes/max_bytes)` where
        each candidate's bytes are what it would *actually* move
        (`batch_engine.migration_bytes` — a worker whose shared block
        store already holds a payload's content key pays nothing for
        it).  Candidates are tried best-first; `PoolExhausted` on import
        rolls back and falls through to the next.  Returns False when no
        decode worker can take the request, in which case it simply
        decodes on the prefill worker (unified fallback).
        """
        rid = entry.req.rid
        src_backend = self.backends[src.wid]
        rec = src_backend.export_request_kv(rid)
        rq = self._trace_by_rid[rid]
        inds = self._decode_ids
        t = src.clock
        depths = np.asarray(
            [self.batcher.workers[w].backlog_seconds(t) for w in inds], float
        )
        hi = depths.max()
        load = depths / hi if hi > 0 else np.zeros_like(depths)
        hits = SCH.hit_vector(
            np.asarray(rq.candidate_items), self.system.placement
        )[inds]
        nbytes = np.asarray(
            [
                float(migration_bytes(rec, self.backends[w].engine.store))
                for w in inds
            ]
        )
        bmax = nbytes.max()
        bnorm = nbytes / bmax if bmax > 0 else np.zeros_like(nbytes)
        sch = self.scheduler
        score = (
            sch.alpha * hits
            + sch.beta * (1.0 - load)
            + self.disagg.mig_gamma * (1.0 - bnorm)
        )
        order = sorted(range(len(inds)), key=lambda i: (-score[i], inds[i]))
        for i in order:
            wid = inds[i]
            dst_backend = self.backends[wid]
            # snapshot what would travel BEFORE the import inserts the
            # missed payloads into the destination store
            store_d = dst_backend.engine.store
            moved = [rec.export.page_k, rec.export.page_v]
            for key, payload in rec.payloads.items():
                # resident() covers the spill tier too: a spilled key
                # re-stages from host RAM, so the transport moves nothing
                if store_d is None or not store_d.resident(key):
                    moved += [payload.host_k, payload.host_v]
            try:
                counters = dst_backend.import_request_kv(rec)
            except PoolExhausted:
                continue
            mig_s = self._migration_seconds(moved, src.wid, wid, counters)
            dst_backend.migrations_in += 1
            dst_backend.migrated_pages += counters["pages"]
            dst_backend.migration_bytes += counters["bytes"]
            dst_backend.migration_seconds += mig_s
            dst_backend.migration_digest_hits += counters["digest_hits"]
            self.batcher.workers[wid].receive_migration(
                entry,
                src.clock + mig_s,
                admitted_s,
                prefilling=rec.prefill is not None,
            )
            src_backend.evacuate(rid)
            return True
        return False

    def _migration_seconds(
        self, arrs: List[np.ndarray], src_wid: int, dst_wid: int,
        counters: Dict,
    ) -> float:
        """Bill one migration's transfer time: under a real mesh, the
        measured wall time of `jax.device_put` moving the travelling
        arrays (`arrs`, snapshotted pre-import) between the two workers'
        home devices (the `ShardClient` pull idiom); otherwise the
        modeled network time for the moved bytes on the paper's
        interconnect.  Digest-hit payloads never travel, so they cost
        nothing either way."""
        if counters["bytes"] == 0:
            return 0.0
        if self.worker_devices is not None:
            import jax

            src_dev = self.worker_devices[src_wid]
            dst_dev = self.worker_devices[dst_wid]
            staged = [jax.device_put(a, src_dev) for a in arrs if a.size]
            jax.block_until_ready(staged)
            t0 = time.perf_counter()
            moved = [jax.device_put(a, dst_dev) for a in staged]
            jax.block_until_ready(moved)
            return time.perf_counter() - t0
        cfg = self.system.cfg
        row_bytes = (
            2 * cfg.n_layers * cfg.n_kv_heads * cfg.resolved_head_dim * 4
        )
        moved_tokens = int(np.ceil(counters["bytes"] / row_bytes))
        return CM.fetch_time_s(cfg, self.hw, 0, moved_tokens)

    def _item_key(self, item: int) -> tuple:
        """Memoized content key of one catalog item's block (same token
        derivation as the offline `build_item_store`: SEP + item text)."""
        it = int(item)
        key = self._item_keys.get(it)
        if key is None:
            doc = np.concatenate(
                [[SY.ITEM_SEP], self.system.catalog.item_tokens[it]]
            ).astype(np.int64)
            key = WL.item_block_key(doc)
            self._item_keys[it] = key
        return key

    def _bind(self, req: PendingRequest, rq, wid: int) -> None:
        """Build the request's plan *for the chosen worker*, stage its
        item blocks against that worker's shard (recording transfers),
        and hand plan + assembled KV to the worker's backend.

        With `kv_reuse` on, staging consults the worker's shared block
        store first: an item whose bytes the store already holds is
        staged from the store's host copy — for a non-resident item that
        means the cross-shard pull (and its modeled network time) is
        skipped entirely, the ledgered transfer having been paid exactly
        once when the block first entered the store.
        """
        system = self.system
        backend = self.backends[wid]
        plan = system.plan_for(rq, wid)
        req.tokens = plan.tokens
        req.n_tokens = plan.n
        self.assigned[req.rid] = wid
        n_item = plan.n_local + plan.n_remote + plan.n_miss
        self.hit_rate[req.rid] = plan.n_local / max(n_item, 1)
        if self.mode != "rcllm":
            return
        items = np.unique(plan.block_item[plan.source == ASM.FROM_ITEM])
        store = backend.engine.store
        staged: Dict[int, IC.ItemBlock] = {}
        to_stage = []
        hint_keys = []
        for it in items:
            it = int(it)
            key = self._item_key(it) if store else None
            if store is not None and store.spill_cap > 0:
                # declare this request's item keys to the store now (the
                # Eq. 2 router just fixed the destination worker): a key
                # already in the spill tier queues for prefetch promotion,
                # a still-resident one registers interest so churn before
                # this request's admission auto-queues the hint
                hint_keys.append(key)
            blk_s = store.peek(key) if store else None
            if blk_s is None and store is not None:
                # spill tier: the bytes are still on this worker's host
                # RAM — stage from there (no cross-shard pull)
                blk_s = store.spill_peek(key)
            if blk_s is not None:
                staged[it] = IC.ItemBlock(
                    item_id=it,
                    tokens=blk_s.tokens,
                    k=blk_s.host_k,
                    v=blk_s.host_v,
                )
                if not backend.shard.resident(it):
                    backend.transfers_avoided += 1
            else:
                to_stage.append(it)
        if hint_keys:
            store.hint(hint_keys)
        pulled, moved_tokens = backend.shard.stage(to_stage)
        staged.update(pulled)
        ck, cv, have = ASM.gather_cached_kv(
            plan,
            IC.StagedBlocks(staged),
            system.semantic,
            wid,
            system.cfg.n_layers,
            system.cfg.n_kv_heads,
            system.cfg.resolved_head_dim,
        )
        backend.plans[req.rid] = (plan, ck, cv, have)
        if store is not None:
            backend.reuse[req.rid] = WL.build_request_reuse(
                plan,
                have,
                staged,
                WL.user_prefix_key(system.instruction, rq),
                len(system.instruction) + len(rq.history_tokens),
                item_keys=self._item_keys,
                instr_len=len(system.instruction),
            )
        if moved_tokens:
            if backend.shard.measures:
                # real device-to-device copies: bill what the wall clock
                # actually measured for this dispatch's pulls
                backend.pending_transfer_s[req.rid] = (
                    backend.shard.take_measured_s()
                )
            else:
                backend.pending_transfer_s[req.rid] = CM.fetch_time_s(
                    system.cfg, self.hw, 0, moved_tokens
                )

    # -------------------------------- run ---------------------------------
    def run(self, trace: Sequence, decode_steps: int = 4) -> ClusterReport:
        """Serve a synthetic request trace end to end. -> ClusterReport."""
        pend = []
        for rid, rq in enumerate(trace):
            self._trace_by_rid[rid] = rq
            req = PendingRequest(
                arrival_s=float(rq.arrival_s),
                rid=rid,
                n_tokens=0,  # set at dispatch, once the plan exists
                decode_steps=decode_steps,
            )
            pend.append(req)
        completions = self.batcher.run(pend)
        generated = {}
        workers = []
        for w, backend in enumerate(self.backends):
            generated.update(backend.generated)
            rids = [r for r, i in self.assigned.items() if i == w]
            shard = backend.shard
            hit = None
            if rids:
                hit = float(np.mean([self.hit_rate[r] for r in rids]))
            store = backend.engine.store
            reuse_stats = None
            if store is not None:
                reuse_stats = dict(store.stats())
                reuse_stats["transfers_avoided"] = backend.transfers_avoided
                for tier in ("user", "item", "prefix"):
                    h = reuse_stats[f"hits_{tier}"]
                    m = reuse_stats[f"misses_{tier}"]
                    reuse_stats[f"{tier}_hit_rate"] = h / max(h + m, 1)
            report = WorkerReport(
                worker=w,
                n_requests=len(rids),
                mean_hit_rate=hit,
                transfer_blocks=len(shard.transfers) if shard else 0,
                transfer_tokens=shard.transferred_tokens() if shard else 0,
                transfer_bytes=shard.transferred_bytes() if shard else 0,
                transfer_seconds=backend.transfer_seconds,
                pool_peak_pages=backend.engine.pool.peak_pages,
                busy_seconds=self.batcher.workers[w].busy_seconds,
                preempted=self.batcher.workers[w].preempted,
                kv_reuse=reuse_stats,
                migrations=backend.migrations_in,
                migrated_out=self.batcher.workers[w].migrated_out,
                migrated_pages=backend.migrated_pages,
                migration_bytes=backend.migration_bytes,
                migration_s=backend.migration_seconds,
                migration_digest_hits=backend.migration_digest_hits,
                device_blocks=(
                    reuse_stats["device_blocks"] if reuse_stats else 0
                ),
                spill_blocks=(
                    reuse_stats["spill_blocks"] if reuse_stats else 0
                ),
                spill_hits=reuse_stats["spill_hits"] if reuse_stats else 0,
                prefetch_promotions=(
                    reuse_stats["prefetch_promotions"] if reuse_stats else 0
                ),
                dequant_s=reuse_stats["dequant_s"] if reuse_stats else 0.0,
            )
            workers.append(report)
        return ClusterReport(
            completions=completions,
            assigned=dict(self.assigned),
            hit_rate=dict(self.hit_rate),
            generated=generated,
            workers=workers,
            policy=self.scheduler.policy,
        )
