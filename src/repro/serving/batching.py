"""Continuous batching for the serving path — single instance or cluster.

Requests arrive asynchronously; the batcher schedules them under one of
two disciplines:

* ``sched="wave"`` — the classic prefill-prioritized loop (vLLM's
  default shape): each step runs either one whole-prefill batch under a
  token budget or one decode iteration.  A long prompt therefore stalls
  every running request's decode and every arrival's TTFT for its full
  prefill — the long-sequence head-of-line problem.

* ``sched="chunked"`` — the unified budgeted step: every tick packs one
  decode token for each running request PLUS fixed-size prefill chunks
  (and selective finalizes) for admitted requests, under a global
  ``step_tokens`` budget.  Prefill becomes chunk-resumable
  (`serving.batch_engine.PrefillState`), admission charges chunks
  rather than whole prompts, and backpressure / preemption are
  reasoned per tick.  Decode never waits out a prefill wave, and a
  short prompt admitted behind a long one finishes in proportion to
  its own length.

The *same loop* drives both execution targets through the
`EngineBackend` seam:

* `SimBackend` — the analytic cost model as a virtual clock (tests,
  scheduling/benchmark sweeps; the seed behaviour; wave-only);
* `JaxEngineBackend` — the real batched JAX engine + paged KV pool
  (`serving.batch_engine`), timed on the wall clock.  The engine's
  `cfg.attn_backend` (threaded from `launch/serve.py --attn-backend`)
  picks jnp vs Pallas attention inside its jitted steps; the batcher is
  agnostic and surfaces the choice via `JaxEngineBackend.attn_backend`
  for reporting.

A backend returns the seconds each step took; the loop only ever adds
those to a clock, so scheduling policy is identical in both worlds.

The loop state lives in `WorkerState` — one serving instance's clock,
FIFO admission queue, prefilling set and decode set — so the same step
logic scales from one backend (`ContinuousBatcher`) to K concurrent
backends behind a dispatch policy (`ClusterBatcher`): per-worker
clocks, per-worker KV-pool backpressure, one shared arrival stream.
`serving.cluster` plugs the Eq. 2 affinity router into the dispatch
hook.  Every worker keeps a per-tick `TickRecord` log (token charges by
kind, wall seconds), which is what the budget property test and the
launcher's latency attribution read.
"""

from __future__ import annotations

import bisect
import math
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Protocol, Sequence

import numpy as np

from repro.serving.api import GREEDY, SamplingParams, match_stop, sample_token
from repro.serving.kv_pool import PoolExhausted


@dataclass(order=True)
class PendingRequest:
    arrival_s: float
    rid: int = field(compare=False)
    n_tokens: int = field(compare=False)
    decode_steps: int = field(compare=False, default=4)
    # real-engine payload (None for the simulator)
    tokens: Optional[np.ndarray] = field(compare=False, default=None)


@dataclass
class DecodeEntry:
    """One running request in a worker's decode set (rid-keyed —
    `PendingRequest` equality compares only ``arrival_s``, so identity
    or equality lookups alias equal-arrival requests)."""

    req: PendingRequest
    ttft_s: float
    steps_left: int


@dataclass
class Completion:
    rid: int
    arrival_s: float
    first_token_s: float  # TTFT
    done_s: float
    worker: int = 0  # serving instance that ran the request
    # when prefill work for this request first started (wave: its
    # prefill batch launched; chunked: it was admitted into the
    # prefilling set) — splits latency into queue-wait vs compute
    admitted_s: float = 0.0
    # why generation ended: "length" (token budget) or "stop" (a stop
    # sequence matched; see api.SubmitRequest)
    reason: str = "length"

    @property
    def queue_wait_s(self) -> float:
        return self.admitted_s - self.arrival_s

    @property
    def prefill_s(self) -> float:
        return self.first_token_s - self.admitted_s

    @property
    def decode_s(self) -> float:
        return self.done_s - self.first_token_s


@dataclass(frozen=True)
class TickRecord:
    """One scheduling tick's token accounting (chunked sched)."""

    t: float  # clock when the tick completed
    seconds: float  # backend-reported wall/virtual step time
    decode_tokens: int
    chunk_tokens: int
    finalize_tokens: int
    oversized: bool  # a single indivisible item exceeded the budget


class EngineBackend(Protocol):
    """What the batching loop needs from an execution target.

    The chunked discipline additionally needs `begin_prefill` /
    `step` / `preempt_prefill` (see `JaxEngineBackend`); a backend
    without them is wave-only and `WorkerState` rejects it up front.
    """

    def prefill(self, batch: Sequence[PendingRequest]) -> float:
        """Run one prefill batch; -> seconds it took."""

    def decode(self, batch: Sequence[PendingRequest]) -> float:
        """Run one decode iteration for `batch`; -> seconds it took."""

    def can_admit(
        self, req: PendingRequest, batch: Sequence[PendingRequest] = ()
    ) -> bool:
        """Room for this request *on top of* the forming `batch`?  False
        defers admission (backpressure) until running requests finish
        and free capacity."""

    def finish(self, req: PendingRequest) -> None:
        """Request left the decode set — release its resources."""

    def preempt(self, req: PendingRequest) -> None:
        """Request was evicted mid-decode and will re-prefill: release
        its execution resources but KEEP whatever the backend needs to
        run it again (plans, staged KV)."""


class SimBackend:
    """Virtual clock: analytic prefill/decode time functions."""

    def __init__(
        self,
        prefill_time_fn: Callable[[int], float],
        decode_time_fn: Callable[[int], float],
    ):
        self.prefill_time_fn = prefill_time_fn
        self.decode_time_fn = decode_time_fn

    def prefill(self, batch: Sequence[PendingRequest]) -> float:
        return self.prefill_time_fn(sum(r.n_tokens for r in batch))

    def decode(self, batch: Sequence[PendingRequest]) -> float:
        return self.decode_time_fn(len(batch))

    def can_admit(
        self, req: PendingRequest, batch: Sequence[PendingRequest] = ()
    ) -> bool:
        return True

    def finish(self, req: PendingRequest) -> None:
        pass

    def preempt(self, req: PendingRequest) -> None:
        pass


class JaxEngineBackend:
    """Real hardware: the batched JAX engine behind the same seam.

    `mode="full"` prefills every prompt exactly; `mode="rcllm"` runs the
    beyond-prefix selective path (requests then need `.plan`/cached KV —
    supply them via `plans`).  Generated tokens are kept per request for
    inspection.

    Token selection is greedy argmax unless a session registered
    per-request `api.SamplingParams` via `set_session` — then the token
    is drawn with a per-request PRNG seeded from the params, and the
    generated stream is checked against the session's stop sequences
    after every append (`early_stop` tells the scheduling loop to retire
    the request before its token budget runs out).
    """

    def __init__(
        self,
        engine,
        mode: str = "full",
        plans: Optional[Dict] = None,
        reuse: Optional[Dict] = None,
    ):
        self.engine = engine
        self.mode = mode
        self.plans = plans if plans is not None else {}
        # rid -> block_store.RequestReuse, for a store-backed engine
        self.reuse = reuse if reuse is not None else {}
        # rid -> (store.version, bound, n_ins): admission bounds are
        # immutable until the store's resident set changes
        self._admit_cache: Dict[int, tuple] = {}
        self.last_token: Dict[int, int] = {}
        self.generated: Dict[int, List[int]] = {}
        # session state (api.py front end): per-request sampling params,
        # stop sequences, lazily-built PRNGs, and the reason a request's
        # generation ended early ("stop"); absent rids sample greedily
        self.sampling: Dict[int, SamplingParams] = {}
        self.stop_seqs: Dict[int, tuple] = {}
        self._rngs: Dict[int, np.random.Generator] = {}
        self.finish_reason: Dict[int, str] = {}

    def set_session(
        self,
        rid: int,
        sampling: SamplingParams = GREEDY,
        stop: Sequence[Sequence[int]] = (),
    ) -> None:
        """Register session semantics for a request before it is served."""
        if not sampling.greedy:
            self.sampling[rid] = sampling
        if stop:
            self.stop_seqs[rid] = tuple(tuple(s) for s in stop)

    def _pick(self, rid: int, lg) -> int:
        params = self.sampling.get(rid, GREEDY)
        if params.greedy:
            return int(np.argmax(lg))
        rng = self._rngs.get(rid)
        if rng is None:
            # per-request stream: (seed, rid) so two sessions with the
            # same params still draw independently, yet one (seed, rid,
            # prompt) triple replays exactly — including after a
            # preemption re-prefills the request from scratch
            rng = np.random.default_rng((params.seed, rid))
            self._rngs[rid] = rng
        return sample_token(np.asarray(lg), params, rng)

    def _append(self, rid: int, tok: int, first: bool = False) -> None:
        self.last_token[rid] = tok
        if first:
            self.generated[rid] = [tok]
        else:
            self.generated[rid].append(tok)
        stops = self.stop_seqs.get(rid)
        if stops and match_stop(self.generated[rid], stops):
            self.finish_reason[rid] = "stop"

    def early_stop(self, rid: int) -> bool:
        """Did this request hit a stop sequence (retire it now)?"""
        return rid in self.finish_reason

    @property
    def attn_backend(self) -> str:
        """Attention implementation the wrapped engine runs (jnp/pallas)."""
        return getattr(self.engine.cfg, "attn_backend", "jnp")

    def _batch_requests(self, batch: Sequence[PendingRequest]):
        from repro.serving.batch_engine import BatchRequest

        out = []
        for r in batch:
            if r.tokens is None:
                raise ValueError(f"request {r.rid}: real engine needs tokens")
            # decode appends decode_steps-1 KV slots: the first output
            # token comes from prefill and the last sampled token is
            # never written back
            br = BatchRequest(
                rid=r.rid,
                tokens=r.tokens,
                n_reserve=max(r.decode_steps - 1, 0),
            )
            if self.mode == "rcllm":
                plan, ck, cv, have = self.plans[r.rid]
                br.plan, br.cached_k, br.cached_v, br.have = plan, ck, cv, have
                br.reuse = self.reuse.get(r.rid)
            out.append(br)
        return out

    def prefill(self, batch: Sequence[PendingRequest]) -> float:
        t0 = time.perf_counter()
        logits = self.engine.prefill(self._batch_requests(batch), self.mode)
        for r, lg in zip(batch, logits):
            self._append(r.rid, self._pick(r.rid, lg), first=True)
        return time.perf_counter() - t0

    def can_admit(
        self, req: PendingRequest, batch: Sequence[PendingRequest] = ()
    ) -> bool:
        # pages for the prompt + the decode tokens it will append, on top
        # of what the rest of the forming batch will claim
        pool = self.engine.pool
        store = getattr(self.engine, "store", None)
        if store is None or self.mode != "rcllm":
            need = sum(
                pool.pages_for(r.n_tokens + max(r.decode_steps - 1, 0))
                for r in (*batch, req)
            )
            return need <= pool.free_pages
        # cross-request reuse: count only private pages against the
        # free list plus what LRU eviction could reclaim (excluding the
        # blocks these very requests count on mapping).  Store inserts
        # are NOT charged: they are optional and the engine's keep_free
        # gate already refuses any insert that would eat the batch's
        # remaining mandatory demand
        from repro.serving import block_store as BS

        need = 0
        hit_keys = set()
        for r in (*batch, req):
            reuse = self.reuse.get(r.rid)
            entry = self._admit_cache.get(r.rid)
            if entry is not None and entry[0] == store.version:
                _, bound, n_ins = entry
            else:
                plan, _, _, have = self.plans[r.rid]
                bound, n_ins = BS.admission_pages(
                    pool,
                    store,
                    plan,
                    have,
                    self.engine.sel,
                    reuse,
                    max(r.decode_steps - 1, 0),
                    bucket=self.engine.bucket,
                )
                self._admit_cache[r.rid] = (store.version, bound, n_ins)
            need += bound
            if reuse is not None:
                for ref in reuse.blocks:
                    if store.has(ref.key):
                        hit_keys.add(ref.key)
                if reuse.user_key is not None and store.has(reuse.user_key):
                    hit_keys.add(reuse.user_key)
        free = pool.free_pages + store.reclaimable_pages(exclude=hit_keys)
        return need <= free

    def decode(self, batch: Sequence[PendingRequest]) -> float:
        t0 = time.perf_counter()
        rids = [r.rid for r in batch]
        logits = self.engine.decode(rids, [self.last_token[r] for r in rids])
        for rid, lg in zip(rids, logits):
            self._append(rid, self._pick(rid, lg))
        return time.perf_counter() - t0

    def _release(self, rid: int) -> None:
        self.engine.release(rid)
        self.last_token.pop(rid, None)
        self._admit_cache.pop(rid, None)

    def finish(self, req: PendingRequest) -> None:
        self._release(req.rid)
        self.sampling.pop(req.rid, None)
        self.stop_seqs.pop(req.rid, None)
        self._rngs.pop(req.rid, None)
        # finish_reason is kept: the session server reads it after the
        # completion is retired to label the terminal StreamEvent

    def preempt(self, req: PendingRequest) -> None:
        """Release pages/refs for a mid-decode eviction, keeping the
        request re-runnable (subclasses that drop plans in `finish`
        must NOT drop them here — the victim re-prefills).  Sampling
        params and stop sequences are kept too; the PRNG is reset so the
        re-run replays the identical token stream from its seed."""
        JaxEngineBackend._release(self, req.rid)
        self._rngs.pop(req.rid, None)
        self.finish_reason.pop(req.rid, None)

    # ----------------------------- migration ------------------------------
    def export_request_kv(self, rid: int):
        """Snapshot one request as an engine `RequestKV` record with the
        backend's sampling watermarks attached (generated stream, rng
        state, stop criteria, plan/reuse payloads) — everything a
        different backend needs to continue the request mid-stream.
        Read-only; call `evacuate` only after the import succeeded."""
        rec = self.engine.export_request_kv(rid)
        rec.session = {
            "last_token": self.last_token.get(rid),
            "generated": self.generated.get(rid),
            "sampling": self.sampling.get(rid),
            "stop": self.stop_seqs.get(rid),
            "rng": self._rngs.get(rid),
            "finish_reason": self.finish_reason.get(rid),
            "plan": self.plans.get(rid),
            "reuse": self.reuse.get(rid),
        }
        return rec

    def import_request_kv(self, rec) -> Dict[str, int]:
        """Install a migrated request: engine-side pages/store refs plus
        the session watermarks.  -> the engine's migration counters.
        Transactional through the engine (`PoolExhausted` rolls back)."""
        counters = self.engine.import_request_kv(rec)
        rid = rec.rid
        s = rec.session or {}
        if s.get("last_token") is not None:
            self.last_token[rid] = s["last_token"]
        if s.get("generated") is not None:
            self.generated[rid] = list(s["generated"])
        for key, store in (
            ("sampling", self.sampling),
            ("stop", self.stop_seqs),
            ("rng", self._rngs),
            ("finish_reason", self.finish_reason),
            ("plan", self.plans),
            ("reuse", self.reuse),
        ):
            if s.get(key) is not None:
                store[rid] = s[key]
        return counters

    def evacuate(self, rid: int) -> None:
        """Source-side cleanup after a successful migration: drop every
        trace of the request here (pages, store refs, chunk state,
        session maps) — the destination backend owns it now."""
        self.engine.abort_prefill(rid)
        for store in (
            self.last_token,
            self.generated,
            self._admit_cache,
            self.sampling,
            self.stop_seqs,
            self._rngs,
            self.finish_reason,
            self.plans,
            self.reuse,
        ):
            store.pop(rid, None)

    # ------------------------- chunked discipline -------------------------
    def begin_prefill(self, req: PendingRequest) -> None:
        """Admit one request into chunk-resumable prefill (claims its
        pool pages and resolves the block store — see
        `BatchEngine.begin_prefill`)."""
        if self.mode != "rcllm":
            raise ValueError(
                "sched='chunked' drives the beyond-prefix selective "
                "prefill; mode='full' has no chunk-resumable path"
            )
        self.engine.begin_prefill(self._batch_requests([req])[0])

    def step(
        self,
        budget: int,
        decode_batch: Sequence[PendingRequest],
        prefill_queue: Sequence[PendingRequest],
    ):
        """One unified engine tick; -> (StepReport, seconds).  Samples
        greedy tokens for whatever the tick produced (decode logits for
        the running set, first tokens for finalized prefills)."""
        t0 = time.perf_counter()
        rids = [r.rid for r in decode_batch]
        rep = self.engine.step(
            budget,
            rids,
            [self.last_token[r] for r in rids],
            [r.rid for r in prefill_queue],
        )
        if rep.decode_logits is not None:
            for rid, lg in zip(rids, rep.decode_logits):
                self._append(rid, self._pick(rid, lg))
        for rid, lg in rep.finalized.items():
            self._append(rid, self._pick(rid, lg), first=True)
        return rep, time.perf_counter() - t0

    def preempt_prefill(self, req: PendingRequest) -> None:
        """Roll back a mid-prefill preemption: the engine drops the
        chunk state and frees pages + store refs; plans are KEPT so the
        victim can re-prefill after readmission."""
        self.engine.abort_prefill(req.rid)
        self.last_token.pop(req.rid, None)
        self._admit_cache.pop(req.rid, None)
        self._rngs.pop(req.rid, None)
        self.finish_reason.pop(req.rid, None)


class WorkerState:
    """One serving instance inside a (possibly multi-worker) batching loop.

    Owns its backend, FIFO admission queue, prefilling set (chunked
    sched), decode set and clock.  The loop only ever adds
    backend-reported step seconds to `clock`, so K workers model K
    instances running in parallel regardless of how their steps actually
    execute (virtual clock, or serialized on one host's wall clock).
    Backpressure is per worker and — under the chunked discipline — per
    tick: a full KV pool stalls this worker's admission queue at the
    tick boundary and nobody else's.
    """

    def __init__(
        self,
        backend: EngineBackend,
        wid: int = 0,
        max_batch_tokens: int = 8192,
        max_decode_batch: int = 64,
        sched: str = "wave",
        chunk_tokens: int = 128,
        step_tokens: Optional[int] = None,
        role: str = "unified",
    ):
        if sched not in ("wave", "chunked"):
            raise ValueError(f"unknown sched {sched!r}")
        if role not in ("unified", "prefill", "decode"):
            raise ValueError(f"unknown worker role {role!r}")
        if sched == "chunked" and not hasattr(backend, "begin_prefill"):
            raise ValueError(
                "sched='chunked' needs a chunk-capable backend "
                "(JaxEngineBackend); the simulator is wave-only"
            )
        self.backend = backend
        self.wid = wid
        # role-typed tick phases (prefill/decode disaggregation): a
        # 'prefill' worker admits and prefills, then hands each finished
        # request to `migrate` instead of entering its own decode set; a
        # 'decode' worker never admits — it receives migrated requests
        # through `receive_migration`.  'unified' (the default) runs
        # both phases exactly as before.
        self.role = role
        # migration hook, set by the cluster: (worker, entry, admitted_s)
        # -> True when the request was handed off to a decode worker
        self.migrate: Optional[Callable] = None
        # migrated requests awaiting their transfer-delayed start:
        # (available_t, DecodeEntry, admitted_s)
        self.inbound: List[tuple] = []
        self.migrated_out = 0
        # rids a decode-role worker preempted itself and may re-admit
        self._preempt_ok: set = set()
        self.max_batch_tokens = max_batch_tokens
        self.max_decode_batch = max_decode_batch
        self.sched = sched
        self.chunk_tokens = chunk_tokens
        # the per-tick token budget: room for one chunk per default
        # decode batch plus slack, so decode alone can't starve prefill
        self.step_tokens = (
            step_tokens
            if step_tokens is not None
            else max(4 * chunk_tokens, 512)
        )
        self.clock = 0.0
        self.busy_seconds = 0.0  # step time only, no idle gaps
        self.preempted = 0  # decode-time pool-pressure victims
        self._preempt_counts: Dict[int, int] = {}
        self.waiting: List[PendingRequest] = []
        self.prefilling: List[PendingRequest] = []  # chunked sched only
        # decode set, rid-keyed (insertion-ordered, so batch slicing is
        # FIFO); equality/identity lookups on PendingRequest alias
        # equal-arrival requests — rids are the only safe key
        self.decoding: Dict[int, DecodeEntry] = {}
        self.done: List[Completion] = []
        self.ticks: List[TickRecord] = []
        self.tbt: List[float] = []  # time-between-tokens samples
        self._admit_t: Dict[int, float] = {}
        self._last_tok_t: Dict[int, float] = {}
        # measured service rates (EWMA over observed steps) — these feed
        # the router's live queue-depth estimate, so load balancing uses
        # what this worker actually costs, not an a-priori model
        self._prefill_s_per_tok = 0.0
        self._decode_s_per_step = 0.0

    def has_work(self) -> bool:
        return bool(
            self.waiting or self.prefilling or self.decoding or self.inbound
        )

    def ready_time(self) -> float:
        """Earliest instant this worker can take its next step."""
        if self.decoding or self.prefilling:
            return self.clock
        due = []
        if self.waiting:
            due.append(self.waiting[0].arrival_s)
        if self.inbound:
            due.append(min(x[0] for x in self.inbound))
        if not due:
            return self.clock
        return max(self.clock, min(due))

    def receive_migration(
        self,
        entry: DecodeEntry,
        available_t: float,
        admitted_s: float,
        prefilling: bool = False,
    ) -> None:
        """Accept a migrated request: it joins the decode set at
        `available_t` (the source's handoff time plus the billed
        transfer seconds), carrying its already-sampled first token.
        A chunk-partial handoff (`prefilling=True` — the live
        `PrefillState` rode the KV record) joins the prefilling set
        instead and resumes chunking on this worker's engine."""
        self.inbound.append((available_t, entry, admitted_s, prefilling))

    def _accept_inbound(self) -> None:
        """Move transfer-complete migrations into the decode (or, for
        chunk-partial handoffs, prefilling) set."""
        due = [x for x in self.inbound if x[0] <= self.clock]
        if not due:
            return
        self.inbound = [x for x in self.inbound if x[0] > self.clock]
        for t, entry, admitted_s, prefilling in due:
            rid = entry.req.rid
            self._admit_t[rid] = admitted_s
            if prefilling:
                self.prefilling.append(entry.req)
                continue
            self._last_tok_t[rid] = t
            self.decoding[rid] = entry

    def _check_role_waiting(self) -> None:
        """Decode-role workers never take dispatched admissions; the one
        exception is a migrated request this worker itself preempted
        under pool pressure (it re-prefills locally from the plan the
        import installed)."""
        if self.role != "decode":
            return
        bad = [r for r in self.waiting if r.rid not in self._preempt_ok]
        if bad:
            raise RuntimeError(
                f"decode-role worker {self.wid} was dispatched request "
                f"{bad[0].rid}: admissions must route to prefill workers"
            )

    def backlog_seconds(self, t: float) -> float:
        """Estimated seconds of outstanding work as seen at time `t`:
        busy time already on the clock plus queued work at this worker's
        measured service rates (0 until the first step is observed)."""
        est = max(self.clock - t, 0.0)
        est += sum(r.n_tokens for r in self.waiting) * self._prefill_s_per_tok
        est += sum(r.n_tokens for r in self.prefilling) * self._prefill_s_per_tok
        if self.decoding:
            est += (
                max(e.steps_left for e in self.decoding.values())
                * self._decode_s_per_step
            )
        return est

    @staticmethod
    def _ewma(old: float, new: float) -> float:
        return new if old == 0.0 else 0.5 * old + 0.5 * new

    def _stopped(self, rid: int) -> bool:
        es = getattr(self.backend, "early_stop", None)
        return es is not None and es(rid)

    def step(self) -> None:
        if self.sched == "chunked":
            self._step_chunked()
        else:
            self._step_wave()

    # ------------------------------ wave sched ------------------------------
    def _step_wave(self) -> None:
        """One scheduling step: a prefill batch if one can form under the
        token budget and pool capacity, else one decode iteration
        (prefill-prioritized, identical to the seed single-instance loop).
        """
        self.clock = self.ready_time()
        self._accept_inbound()
        batch: List[PendingRequest] = []
        tok = 0
        self._check_role_waiting()
        for r in self.waiting:
            if r.arrival_s > self.clock:
                break
            if tok + r.n_tokens > self.max_batch_tokens and batch:
                break
            if not self.backend.can_admit(r, batch):
                # strict FCFS under backpressure: never admit a
                # younger request past one waiting on capacity
                # (head-of-line wait beats unbounded starvation)
                break
            batch.append(r)
            tok += r.n_tokens
        if not batch and not self.decoding:
            if not self.waiting:
                return  # only future inbound migrations
            raise RuntimeError(
                f"request {self.waiting[0].rid} ({self.waiting[0].n_tokens} "
                "tokens) can never be admitted: KV pool too small "
                "even with no other request running"
            )
        if batch:
            admitted = self.clock
            # remove by rid: PendingRequest equality compares only
            # arrival_s (the sort key), so equal-arrival requests would
            # alias under list.remove
            picked = {r.rid for r in batch}
            self.waiting = [r for r in self.waiting if r.rid not in picked]
            dt = self.backend.prefill(batch)
            self.clock += dt
            self.busy_seconds += dt
            self._prefill_s_per_tok = self._ewma(
                self._prefill_s_per_tok, dt / max(tok, 1)
            )
            for r in batch:
                stopped = self._stopped(r.rid)
                if r.decode_steps <= 1 or stopped:  # TTFT token was the output
                    self.done.append(
                        Completion(
                            r.rid,
                            r.arrival_s,
                            self.clock,
                            self.clock,
                            self.wid,
                            admitted_s=admitted,
                            reason="stop" if stopped else "length",
                        )
                    )
                    self.backend.finish(r)
                else:
                    entry = DecodeEntry(
                        r, self.clock - r.arrival_s, r.decode_steps - 1
                    )
                    if (
                        self.role == "prefill"
                        and self.migrate is not None
                        and self.migrate(self, entry, admitted)
                    ):
                        self.migrated_out += 1
                        continue  # a decode worker owns it now
                    self._admit_t[r.rid] = admitted
                    self._last_tok_t[r.rid] = self.clock
                    self.decoding[r.rid] = entry
        else:
            while True:
                db = list(self.decoding.values())[: self.max_decode_batch]
                try:
                    dt = self.backend.decode([e.req for e in db])
                    break
                except PoolExhausted:
                    # decode could not claim a KV slot for every running
                    # request: preempt the youngest (free its pages,
                    # requeue it for a fresh prefill) instead of letting
                    # the error kill the worker and leak every running
                    # request's pages — then retry so the survivors step
                    # past the growth boundary *before* the next prefill
                    # can re-admit the victim into the same conflict
                    self._preempt_youngest()
                    if not self.decoding:
                        return
            self.clock += dt
            self.busy_seconds += dt
            self._decode_s_per_step = self._ewma(self._decode_s_per_step, dt)
            for e in db:
                e.steps_left -= 1
                if self._stopped(e.req.rid):
                    e.steps_left = 0
                self._sample_tbt(e.req.rid)
            self._retire_decoded()

    # ---------------------------- chunked sched ----------------------------
    def _step_chunked(self) -> None:
        """One unified tick: admit what fits, then run one budgeted
        engine step packing decode tokens for every running request
        plus prefill chunks/finalizes for the admitted set."""
        self.clock = self.ready_time()
        self._accept_inbound()
        self._admit_chunked()
        if not self.decoding and not self.prefilling:
            return  # only future inbound migrations
        while True:
            db = list(self.decoding.values())[: self.max_decode_batch]
            try:
                rep, dt = self.backend.step(
                    self.step_tokens,
                    [e.req for e in db],
                    self.prefilling,
                )
                break
            except PoolExhausted:
                # same retry contract as the wave loop, per tick: evict
                # the youngest request (mid-prefill victims roll their
                # chunk state back; mid-decode victims free their pages)
                # and retry before any prefill work runs
                self._preempt_youngest()
                if not self.decoding and not self.prefilling:
                    return
        self.clock += dt
        self.busy_seconds += dt
        # apportion the tick's seconds across work kinds by token charge
        # so the router's backlog estimate prices queued/mid-scan prompt
        # tokens and decode steps separately (a single EWMA over whole
        # ticks would report zero prefill cost and blind Eq. 2 dispatch
        # to prompt backlog)
        charge = max(rep.charged, 1)
        pf_tokens = rep.charge_chunks + rep.charge_finalize
        if pf_tokens:
            self._prefill_s_per_tok = self._ewma(self._prefill_s_per_tok, dt / charge)
        if rep.charge_decode:
            self._decode_s_per_step = self._ewma(
                self._decode_s_per_step, dt * rep.charge_decode / charge
            )
        self.ticks.append(
            TickRecord(
                t=self.clock,
                seconds=dt,
                decode_tokens=rep.charge_decode,
                chunk_tokens=rep.charge_chunks,
                finalize_tokens=rep.charge_finalize,
                oversized=rep.oversized,
            )
        )
        if rep.decode_logits is not None:
            for e in db:
                e.steps_left -= 1
                if self._stopped(e.req.rid):
                    e.steps_left = 0
                self._sample_tbt(e.req.rid)
            self._retire_decoded()
        finalized = [r for r in self.prefilling if r.rid in rep.finalized]
        self.prefilling = [r for r in self.prefilling if r.rid not in rep.finalized]
        for req in finalized:
            admitted = self._admit_t.get(req.rid, req.arrival_s)
            stopped = self._stopped(req.rid)
            if req.decode_steps <= 1 or stopped:
                self._admit_t.pop(req.rid, None)
                self.done.append(
                    Completion(
                        req.rid,
                        req.arrival_s,
                        self.clock,
                        self.clock,
                        self.wid,
                        admitted_s=admitted,
                        reason="stop" if stopped else "length",
                    )
                )
                self.backend.finish(req)
            else:
                entry = DecodeEntry(
                    req, self.clock - req.arrival_s, req.decode_steps - 1
                )
                if (
                    self.role == "prefill"
                    and self.migrate is not None
                    and self.migrate(self, entry, admitted)
                ):
                    self.migrated_out += 1
                    self._admit_t.pop(req.rid, None)
                    continue  # a decode worker owns it now
                self._last_tok_t[req.rid] = self.clock
                self.decoding[req.rid] = entry

    def _admit_chunked(self) -> None:
        """Move due arrivals into the prefilling set, FIFO, while pool
        capacity allows — admission charges chunks, so an admitted
        request competes for the step budget from this tick on."""
        self._check_role_waiting()
        while self.waiting:
            r = self.waiting[0]
            if r.arrival_s > self.clock:
                break
            if not self.backend.can_admit(r):
                break
            try:
                self.backend.begin_prefill(r)
            except PoolExhausted:
                break
            self.waiting.pop(0)
            self.prefilling.append(r)
            self._admit_t[r.rid] = self.clock
        if not self.decoding and not self.prefilling and self.waiting:
            raise RuntimeError(
                f"request {self.waiting[0].rid} ({self.waiting[0].n_tokens} "
                "tokens) can never be admitted: KV pool too small "
                "even with no other request running"
            )

    # ------------------------------- shared -------------------------------
    def _sample_tbt(self, rid: int) -> None:
        last = self._last_tok_t.get(rid)
        if last is not None:
            self.tbt.append(self.clock - last)
        self._last_tok_t[rid] = self.clock

    def _retire_decoded(self) -> None:
        spent = [rid for rid, e in self.decoding.items() if e.steps_left <= 0]
        for rid in spent:
            e = self.decoding.pop(rid)
            req = e.req
            self.done.append(
                Completion(
                    req.rid,
                    req.arrival_s,
                    req.arrival_s + e.ttft_s,
                    self.clock,
                    self.wid,
                    admitted_s=self._admit_t.pop(rid, req.arrival_s),
                    reason="stop" if self._stopped(rid) else "length",
                )
            )
            self._last_tok_t.pop(rid, None)
            self.backend.finish(req)

    def _preempt_youngest(self) -> None:
        """Evict the youngest running request under pool pressure:
        release its resources and put it back in the arrival queue (it
        will re-prefill — deterministic sampling regenerates the same
        tokens, so only its latency suffers).  Under the chunked
        discipline the victim set includes mid-prefill requests; their
        chunk state rolls back cleanly (`preempt_prefill`) and the plan
        is kept."""
        cands = [e.req for e in self.decoding.values()] + list(self.prefilling)
        req = max(cands, key=lambda r: (r.arrival_s, r.rid))
        self._preempt_counts[req.rid] = self._preempt_counts.get(req.rid, 0) + 1
        if self._preempt_counts[req.rid] > 8:
            raise RuntimeError(
                f"request {req.rid} preempted {self._preempt_counts[req.rid]}"
                " times: the pool cannot hold its decode tokens even "
                "alone — backend decode-page reservation is broken"
            )
        if any(r.rid == req.rid for r in self.prefilling):
            if (
                self.role == "prefill"
                and self.migrate is not None
                and self.migrate(
                    self,
                    DecodeEntry(req, 0.0, req.decode_steps),
                    self._admit_t.get(req.rid, req.arrival_s),
                )
            ):
                # chunk-partial handoff instead of preemption: the live
                # PrefillState rode the KV record to a decode worker,
                # which resumes chunking there; the migrate hook's
                # evacuate already freed this worker's pages, so pool
                # pressure is relieved without losing the scan progress
                self.prefilling = [r for r in self.prefilling if r.rid != req.rid]
                self._admit_t.pop(req.rid, None)
                self.migrated_out += 1
                return
            self.prefilling = [r for r in self.prefilling if r.rid != req.rid]
            self._admit_t.pop(req.rid, None)
            self.backend.preempt_prefill(req)
        else:
            self.decoding.pop(req.rid)
            self._last_tok_t.pop(req.rid, None)
            self._admit_t.pop(req.rid, None)
            self.backend.preempt(req)
        self.preempted += 1
        if self.role == "decode":
            # a migrated request evicted here re-prefills locally — its
            # plan/session already live on this backend, and its source
            # prefill worker evacuated it at handoff
            self._preempt_ok.add(req.rid)
        bisect.insort(self.waiting, req)

    def cancel(self, rid: int) -> Optional[str]:
        """Cancel a request wherever it currently lives, rolling pool
        state back through the same seams preemption uses: a waiting
        request is simply dequeued; a mid-prefill request drops its
        chunk state, pages and store refs (`preempt_prefill`); a
        mid-decode request releases through `finish`.  -> the stage it
        was cancelled in, or None if unknown here (already completed, or
        dispatched to a different worker).  Call only at a tick boundary
        (never mid-`step`)."""
        for i, r in enumerate(self.waiting):
            if r.rid == rid:
                del self.waiting[i]
                return "waiting"
        for i, r in enumerate(self.prefilling):
            if r.rid == rid:
                del self.prefilling[i]
                self._admit_t.pop(rid, None)
                self.backend.preempt_prefill(r)
                self.backend.finish(r)  # release is idempotent; drops
                return "prefilling"  # plans + session state for good
        e = self.decoding.pop(rid, None)
        if e is not None:
            self._admit_t.pop(rid, None)
            self._last_tok_t.pop(rid, None)
            self.backend.finish(e.req)
            return "decoding"
        return None


# dispatch hook: (request, arrival time, workers) -> worker index
DispatchFn = Callable[[PendingRequest, float, List[WorkerState]], int]


def least_backlog_dispatch(
    req: PendingRequest, t: float, workers: List[WorkerState]
) -> int:
    """Default dispatch: the worker with the least estimated backlog."""
    return min(range(len(workers)), key=lambda i: (workers[i].backlog_seconds(t), i))


class ClusterBatcher:
    """Continuous batching across K workers sharing one arrival stream.

    Each worker is an independent `WorkerState` over its own backend
    (own KV pool, own clock, own backpressure); `dispatch` assigns every
    arrival to a worker *at its arrival time*, seeing live worker state —
    the Eq. 2 affinity router plugs in here (`serving.cluster`).  Events
    are processed in global time order: an arrival is dispatched only
    once every busy worker's next step lies at or after it, so queue
    depths observed by the router are exactly what a real global
    scheduler would see.
    """

    def __init__(
        self,
        backends: Sequence[EngineBackend],
        dispatch: Optional[DispatchFn] = None,
        max_batch_tokens: int = 8192,
        max_decode_batch: int = 64,
        sched: str = "wave",
        chunk_tokens: int = 128,
        step_tokens: Optional[int] = None,
    ):
        self.workers = [
            WorkerState(
                b,
                wid=i,
                max_batch_tokens=max_batch_tokens,
                max_decode_batch=max_decode_batch,
                sched=sched,
                chunk_tokens=chunk_tokens,
                step_tokens=step_tokens,
            )
            for i, b in enumerate(backends)
        ]
        self.dispatch = dispatch or least_backlog_dispatch

    def run(self, requests: Sequence[PendingRequest]) -> List[Completion]:
        # every per-request map in the loop (decode set, admit times,
        # backend plans/sessions) is rid-keyed, so duplicate rids would
        # silently cross streams — reject them up front
        seen: set = set()
        for r in requests:
            if r.rid in seen:
                raise ValueError(f"duplicate request rid {r.rid}")
            seen.add(r.rid)
        pending = sorted(requests)
        i = 0
        while i < len(pending) or any(w.has_work() for w in self.workers):
            busy = [w for w in self.workers if w.has_work()]
            t_work = min((w.ready_time() for w in busy), default=math.inf)
            t_arr = pending[i].arrival_s if i < len(pending) else math.inf
            if t_arr <= t_work:
                req = pending[i]
                i += 1
                wid = int(self.dispatch(req, t_arr, self.workers))
                self.workers[wid].waiting.append(req)
            else:
                min(busy, key=lambda w: (w.ready_time(), w.wid)).step()
        done = [c for w in self.workers for c in w.done]
        done.sort(key=lambda c: c.done_s)  # stable: in-step order kept
        return done


class ContinuousBatcher:
    """Single-instance continuous batching over an `EngineBackend`.

    Backward-compatible construction: passing `prefill_time_fn` /
    `decode_time_fn` (the seed API) wraps them in a `SimBackend`.
    Internally this is a one-worker `ClusterBatcher`.
    """

    def __init__(
        self,
        prefill_time_fn: Optional[Callable[[int], float]] = None,
        decode_time_fn: Optional[Callable[[int], float]] = None,
        max_batch_tokens: int = 8192,
        max_decode_batch: int = 64,
        backend: Optional[EngineBackend] = None,
        sched: str = "wave",
        chunk_tokens: int = 128,
        step_tokens: Optional[int] = None,
    ):
        if backend is None:
            if prefill_time_fn is None or decode_time_fn is None:
                raise ValueError("need a backend or both time functions")
            backend = SimBackend(prefill_time_fn, decode_time_fn)
        self.backend = backend
        self.max_batch_tokens = max_batch_tokens
        self.max_decode_batch = max_decode_batch
        self.sched = sched
        self.chunk_tokens = chunk_tokens
        self.step_tokens = step_tokens
        self.workers: List[WorkerState] = []

    def run(self, requests: List[PendingRequest]) -> List[Completion]:
        cb = ClusterBatcher(
            [self.backend],
            dispatch=lambda req, t, ws: 0,
            max_batch_tokens=self.max_batch_tokens,
            max_decode_batch=self.max_decode_batch,
            sched=self.sched,
            chunk_tokens=self.chunk_tokens,
            step_tokens=self.step_tokens,
        )
        self.workers = cb.workers
        return cb.run(requests)
