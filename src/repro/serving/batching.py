"""Continuous batching for the serving path — single instance or cluster.

Requests arrive asynchronously; the batcher forms prefill batches under a
token budget and interleaves decode iterations (prefill-prioritized, like
vLLM's default).  The *same loop* drives both execution targets through
the `EngineBackend` seam:

* `SimBackend` — the analytic cost model as a virtual clock (tests,
  scheduling/benchmark sweeps; the seed behaviour);
* `JaxEngineBackend` — the real batched JAX engine + paged KV pool
  (`serving.batch_engine`), timed on the wall clock.  The engine's
  `cfg.attn_backend` (threaded from `launch/serve.py --attn-backend`)
  picks jnp vs Pallas attention inside its jitted steps; the batcher is
  agnostic and surfaces the choice via `JaxEngineBackend.attn_backend`
  for reporting.

A backend returns the seconds each step took; the loop only ever adds
those to a clock, so scheduling policy is identical in both worlds.

The loop state lives in `WorkerState` — one serving instance's clock,
FIFO admission queue and decode set — so the same step logic scales from
one backend (`ContinuousBatcher`) to K concurrent backends behind a
dispatch policy (`ClusterBatcher`): per-worker clocks, per-worker KV-pool
backpressure, one shared arrival stream.  `serving.cluster` plugs the
Eq. 2 affinity router into the dispatch hook.
"""
from __future__ import annotations

import bisect
import math
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Protocol, Sequence

import numpy as np

from repro.serving.kv_pool import PoolExhausted


@dataclass(order=True)
class PendingRequest:
    arrival_s: float
    rid: int = field(compare=False)
    n_tokens: int = field(compare=False)
    decode_steps: int = field(compare=False, default=4)
    # real-engine payload (None for the simulator)
    tokens: Optional[np.ndarray] = field(compare=False, default=None)


@dataclass
class Completion:
    rid: int
    arrival_s: float
    first_token_s: float      # TTFT
    done_s: float
    worker: int = 0           # serving instance that ran the request


class EngineBackend(Protocol):
    """What the batching loop needs from an execution target."""

    def prefill(self, batch: Sequence[PendingRequest]) -> float:
        """Run one prefill batch; -> seconds it took."""

    def decode(self, batch: Sequence[PendingRequest]) -> float:
        """Run one decode iteration for `batch`; -> seconds it took."""

    def can_admit(self, req: PendingRequest,
                  batch: Sequence[PendingRequest] = ()) -> bool:
        """Room for this request *on top of* the forming `batch`?  False
        defers admission (backpressure) until running requests finish
        and free capacity."""

    def finish(self, req: PendingRequest) -> None:
        """Request left the decode set — release its resources."""

    def preempt(self, req: PendingRequest) -> None:
        """Request was evicted mid-decode and will re-prefill: release
        its execution resources but KEEP whatever the backend needs to
        run it again (plans, staged KV)."""


class SimBackend:
    """Virtual clock: analytic prefill/decode time functions."""

    def __init__(self, prefill_time_fn: Callable[[int], float],
                 decode_time_fn: Callable[[int], float]):
        self.prefill_time_fn = prefill_time_fn
        self.decode_time_fn = decode_time_fn

    def prefill(self, batch: Sequence[PendingRequest]) -> float:
        return self.prefill_time_fn(sum(r.n_tokens for r in batch))

    def decode(self, batch: Sequence[PendingRequest]) -> float:
        return self.decode_time_fn(len(batch))

    def can_admit(self, req: PendingRequest,
                  batch: Sequence[PendingRequest] = ()) -> bool:
        return True

    def finish(self, req: PendingRequest) -> None:
        pass

    def preempt(self, req: PendingRequest) -> None:
        pass


class JaxEngineBackend:
    """Real hardware: the batched JAX engine behind the same seam.

    `mode="full"` prefills every prompt exactly; `mode="rcllm"` runs the
    beyond-prefix selective path (requests then need `.plan`/cached KV —
    supply them via `plans`).  Greedy sampling; generated tokens are kept
    per request for inspection.
    """

    def __init__(self, engine, mode: str = "full", plans: Optional[Dict]
                 = None, reuse: Optional[Dict] = None):
        self.engine = engine
        self.mode = mode
        self.plans = plans if plans is not None else {}
        # rid -> block_store.RequestReuse, for a store-backed engine
        self.reuse = reuse if reuse is not None else {}
        # rid -> (store.version, bound, n_ins): admission bounds are
        # immutable until the store's resident set changes
        self._admit_cache: Dict[int, tuple] = {}
        self.last_token: Dict[int, int] = {}
        self.generated: Dict[int, List[int]] = {}

    @property
    def attn_backend(self) -> str:
        """Attention implementation the wrapped engine runs (jnp/pallas)."""
        return getattr(self.engine.cfg, "attn_backend", "jnp")

    def _batch_requests(self, batch: Sequence[PendingRequest]):
        from repro.serving.batch_engine import BatchRequest
        out = []
        for r in batch:
            if r.tokens is None:
                raise ValueError(f"request {r.rid}: real engine needs tokens")
            # decode appends decode_steps-1 KV slots: the first output
            # token comes from prefill and the last sampled token is
            # never written back
            br = BatchRequest(rid=r.rid, tokens=r.tokens,
                              n_reserve=max(r.decode_steps - 1, 0))
            if self.mode == "rcllm":
                plan, ck, cv, have = self.plans[r.rid]
                br.plan, br.cached_k, br.cached_v, br.have = plan, ck, cv, have
                br.reuse = self.reuse.get(r.rid)
            out.append(br)
        return out

    def prefill(self, batch: Sequence[PendingRequest]) -> float:
        t0 = time.perf_counter()
        logits = self.engine.prefill(self._batch_requests(batch), self.mode)
        for r, lg in zip(batch, logits):
            tok = int(np.argmax(lg))
            self.last_token[r.rid] = tok
            self.generated[r.rid] = [tok]
        return time.perf_counter() - t0

    def can_admit(self, req: PendingRequest,
                  batch: Sequence[PendingRequest] = ()) -> bool:
        # pages for the prompt + the decode tokens it will append, on top
        # of what the rest of the forming batch will claim
        pool = self.engine.pool
        store = getattr(self.engine, "store", None)
        if store is None or self.mode != "rcllm":
            need = sum(
                pool.pages_for(r.n_tokens + max(r.decode_steps - 1, 0))
                for r in (*batch, req))
            return need <= pool.free_pages
        # cross-request reuse: count only private pages against the
        # free list plus what LRU eviction could reclaim (excluding the
        # blocks these very requests count on mapping).  Store inserts
        # are NOT charged: they are optional and the engine's keep_free
        # gate already refuses any insert that would eat the batch's
        # remaining mandatory demand
        from repro.serving import block_store as BS
        need = 0
        hit_keys = set()
        for r in (*batch, req):
            reuse = self.reuse.get(r.rid)
            entry = self._admit_cache.get(r.rid)
            if entry is not None and entry[0] == store.version:
                _, bound, n_ins = entry
            else:
                plan, _, _, have = self.plans[r.rid]
                bound, n_ins = BS.admission_pages(
                    pool, store, plan, have, self.engine.sel, reuse,
                    max(r.decode_steps - 1, 0), bucket=self.engine.bucket)
                self._admit_cache[r.rid] = (store.version, bound, n_ins)
            need += bound
            if reuse is not None:
                for ref in reuse.blocks:
                    if store.has(ref.key):
                        hit_keys.add(ref.key)
                if reuse.user_key is not None and store.has(reuse.user_key):
                    hit_keys.add(reuse.user_key)
        free = pool.free_pages + store.reclaimable_pages(exclude=hit_keys)
        return need <= free

    def decode(self, batch: Sequence[PendingRequest]) -> float:
        t0 = time.perf_counter()
        rids = [r.rid for r in batch]
        logits = self.engine.decode(rids, [self.last_token[r] for r in rids])
        for rid, lg in zip(rids, logits):
            tok = int(np.argmax(lg))
            self.last_token[rid] = tok
            self.generated[rid].append(tok)
        return time.perf_counter() - t0

    def finish(self, req: PendingRequest) -> None:
        self.engine.release(req.rid)
        self.last_token.pop(req.rid, None)
        self._admit_cache.pop(req.rid, None)

    def preempt(self, req: PendingRequest) -> None:
        """Release pages/refs for a mid-decode eviction, keeping the
        request re-runnable (subclasses that drop plans in `finish`
        must NOT drop them here — the victim re-prefills)."""
        JaxEngineBackend.finish(self, req)


class WorkerState:
    """One serving instance inside a (possibly multi-worker) batching loop.

    Owns its backend, FIFO admission queue, decode set and clock.  The
    loop only ever adds backend-reported step seconds to `clock`, so K
    workers model K instances running in parallel regardless of how their
    steps actually execute (virtual clock, or serialized on one host's
    wall clock).  Backpressure is per worker: a full KV pool stalls this
    worker's admission queue and nobody else's.
    """

    def __init__(self, backend: EngineBackend, wid: int = 0,
                 max_batch_tokens: int = 8192, max_decode_batch: int = 64):
        self.backend = backend
        self.wid = wid
        self.max_batch_tokens = max_batch_tokens
        self.max_decode_batch = max_decode_batch
        self.clock = 0.0
        self.busy_seconds = 0.0          # step time only, no idle gaps
        self.preempted = 0               # decode-time pool-pressure victims
        self._preempt_counts: Dict[int, int] = {}
        self.waiting: List[PendingRequest] = []
        # decode set entries: [req, ttft_s, decode_steps_left]
        self.decoding: List[list] = []
        self.done: List[Completion] = []
        # measured service rates (EWMA over observed steps) — these feed
        # the router's live queue-depth estimate, so load balancing uses
        # what this worker actually costs, not an a-priori model
        self._prefill_s_per_tok = 0.0
        self._decode_s_per_step = 0.0

    def has_work(self) -> bool:
        return bool(self.waiting or self.decoding)

    def ready_time(self) -> float:
        """Earliest instant this worker can take its next step."""
        if self.decoding:
            return self.clock
        return max(self.clock, self.waiting[0].arrival_s)

    def backlog_seconds(self, t: float) -> float:
        """Estimated seconds of outstanding work as seen at time `t`:
        busy time already on the clock plus queued work at this worker's
        measured service rates (0 until the first step is observed)."""
        est = max(self.clock - t, 0.0)
        est += sum(r.n_tokens for r in self.waiting) * self._prefill_s_per_tok
        if self.decoding:
            est += max(e[2] for e in self.decoding) * self._decode_s_per_step
        return est

    @staticmethod
    def _ewma(old: float, new: float) -> float:
        return new if old == 0.0 else 0.5 * old + 0.5 * new

    def step(self) -> None:
        """One scheduling step: a prefill batch if one can form under the
        token budget and pool capacity, else one decode iteration
        (prefill-prioritized, identical to the seed single-instance loop).
        """
        self.clock = self.ready_time()
        batch: List[PendingRequest] = []
        tok = 0
        for r in self.waiting:
            if r.arrival_s > self.clock:
                break
            if tok + r.n_tokens > self.max_batch_tokens and batch:
                break
            if not self.backend.can_admit(r, batch):
                # strict FCFS under backpressure: never admit a younger
                # request past one waiting on capacity (head-of-line
                # wait beats unbounded starvation)
                break
            batch.append(r)
            tok += r.n_tokens
        if not batch and not self.decoding:
            raise RuntimeError(
                f"request {self.waiting[0].rid} ({self.waiting[0].n_tokens} "
                "tokens) can never be admitted: KV pool too small "
                "even with no other request running")
        if batch:
            for r in batch:
                self.waiting.remove(r)
            dt = self.backend.prefill(batch)
            self.clock += dt
            self.busy_seconds += dt
            self._prefill_s_per_tok = self._ewma(self._prefill_s_per_tok,
                                                 dt / max(tok, 1))
            for r in batch:
                if r.decode_steps <= 1:      # TTFT token was the output
                    self.done.append(Completion(r.rid, r.arrival_s,
                                                self.clock, self.clock,
                                                self.wid))
                    self.backend.finish(r)
                else:
                    self.decoding.append([r, self.clock - r.arrival_s,
                                          r.decode_steps - 1])
        else:
            while True:
                db = self.decoding[:self.max_decode_batch]
                try:
                    dt = self.backend.decode([e[0] for e in db])
                    break
                except PoolExhausted:
                    # decode could not claim a KV slot for every running
                    # request: preempt the youngest (free its pages,
                    # requeue it for a fresh prefill) instead of letting
                    # the error kill the worker and leak every running
                    # request's pages — then retry so the survivors step
                    # past the growth boundary *before* the next prefill
                    # can re-admit the victim into the same conflict
                    self._preempt_youngest()
                    if not self.decoding:
                        return
            self.clock += dt
            self.busy_seconds += dt
            self._decode_s_per_step = self._ewma(self._decode_s_per_step, dt)
            for e in db:
                e[2] -= 1
            keep = []
            for e in self.decoding:
                if e[2] <= 0:
                    self.done.append(Completion(e[0].rid, e[0].arrival_s,
                                                e[0].arrival_s + e[1],
                                                self.clock, self.wid))
                    self.backend.finish(e[0])
                else:
                    keep.append(e)
            self.decoding = keep


    def _preempt_youngest(self) -> None:
        """Evict the youngest decoding request under decode-time pool
        pressure: release its resources and put it back in the arrival
        queue (it will re-prefill — greedy decode regenerates the same
        tokens, so only its latency suffers)."""
        e = max(self.decoding, key=lambda e: (e[0].arrival_s, e[0].rid))
        req = e[0]
        self._preempt_counts[req.rid] = \
            self._preempt_counts.get(req.rid, 0) + 1
        if self._preempt_counts[req.rid] > 8:
            raise RuntimeError(
                f"request {req.rid} preempted {self._preempt_counts[req.rid]}"
                " times: the pool cannot hold its decode tokens even "
                "alone — backend decode-page reservation is broken")
        self.decoding.remove(e)
        self.backend.preempt(req)
        self.preempted += 1
        bisect.insort(self.waiting, req)


# dispatch hook: (request, arrival time, workers) -> worker index
DispatchFn = Callable[[PendingRequest, float, List[WorkerState]], int]


def least_backlog_dispatch(req: PendingRequest, t: float,
                           workers: List[WorkerState]) -> int:
    """Default dispatch: the worker with the least estimated backlog."""
    return min(range(len(workers)),
               key=lambda i: (workers[i].backlog_seconds(t), i))


class ClusterBatcher:
    """Continuous batching across K workers sharing one arrival stream.

    Each worker is an independent `WorkerState` over its own backend
    (own KV pool, own clock, own backpressure); `dispatch` assigns every
    arrival to a worker *at its arrival time*, seeing live worker state —
    the Eq. 2 affinity router plugs in here (`serving.cluster`).  Events
    are processed in global time order: an arrival is dispatched only
    once every busy worker's next step lies at or after it, so queue
    depths observed by the router are exactly what a real global
    scheduler would see.
    """

    def __init__(self, backends: Sequence[EngineBackend],
                 dispatch: Optional[DispatchFn] = None,
                 max_batch_tokens: int = 8192, max_decode_batch: int = 64):
        self.workers = [WorkerState(b, wid=i,
                                    max_batch_tokens=max_batch_tokens,
                                    max_decode_batch=max_decode_batch)
                        for i, b in enumerate(backends)]
        self.dispatch = dispatch or least_backlog_dispatch

    def run(self, requests: Sequence[PendingRequest]) -> List[Completion]:
        pending = sorted(requests)
        i = 0
        while i < len(pending) or any(w.has_work() for w in self.workers):
            busy = [w for w in self.workers if w.has_work()]
            t_work = min((w.ready_time() for w in busy), default=math.inf)
            t_arr = pending[i].arrival_s if i < len(pending) else math.inf
            if t_arr <= t_work:
                req = pending[i]
                i += 1
                wid = int(self.dispatch(req, t_arr, self.workers))
                self.workers[wid].waiting.append(req)
            else:
                min(busy, key=lambda w: (w.ready_time(), w.wid)).step()
        done = [c for w in self.workers for c in w.done]
        done.sort(key=lambda c: c.done_s)       # stable: in-step order kept
        return done


class ContinuousBatcher:
    """Single-instance continuous batching over an `EngineBackend`.

    Backward-compatible construction: passing `prefill_time_fn` /
    `decode_time_fn` (the seed API) wraps them in a `SimBackend`.
    Internally this is a one-worker `ClusterBatcher`.
    """

    def __init__(self, prefill_time_fn: Optional[Callable[[int], float]]
                 = None,
                 decode_time_fn: Optional[Callable[[int], float]] = None,
                 max_batch_tokens: int = 8192,
                 max_decode_batch: int = 64,
                 backend: Optional[EngineBackend] = None):
        if backend is None:
            if prefill_time_fn is None or decode_time_fn is None:
                raise ValueError("need a backend or both time functions")
            backend = SimBackend(prefill_time_fn, decode_time_fn)
        self.backend = backend
        self.max_batch_tokens = max_batch_tokens
        self.max_decode_batch = max_decode_batch

    def run(self, requests: List[PendingRequest]) -> List[Completion]:
        return ClusterBatcher(
            [self.backend], dispatch=lambda req, t, ws: 0,
            max_batch_tokens=self.max_batch_tokens,
            max_decode_batch=self.max_decode_batch).run(requests)
