"""Continuous batching for the serving path.

Requests arrive asynchronously; the batcher forms prefill batches under a
token budget and interleaves decode iterations (prefill-prioritized, like
vLLM's default).  Drives the simulator clock in tests/benchmarks; on real
hardware the same loop drives the jitted prefill/decode steps.
"""
from __future__ import annotations

import dataclasses
import heapq
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np


@dataclass(order=True)
class PendingRequest:
    arrival_s: float
    rid: int = field(compare=False)
    n_tokens: int = field(compare=False)
    decode_steps: int = field(compare=False, default=4)


@dataclass
class Completion:
    rid: int
    arrival_s: float
    first_token_s: float      # TTFT
    done_s: float


class ContinuousBatcher:
    """Single-instance continuous batching over a virtual clock."""

    def __init__(self, prefill_time_fn: Callable[[int], float],
                 decode_time_fn: Callable[[int], float],
                 max_batch_tokens: int = 8192,
                 max_decode_batch: int = 64):
        self.prefill_time_fn = prefill_time_fn
        self.decode_time_fn = decode_time_fn
        self.max_batch_tokens = max_batch_tokens
        self.max_decode_batch = max_decode_batch

    def run(self, requests: List[PendingRequest]) -> List[Completion]:
        pending = sorted(requests)
        waiting: List[PendingRequest] = []
        decoding: List[Tuple[PendingRequest, float, int]] = []  # (req, ttft, left)
        done: List[Completion] = []
        t = 0.0
        i = 0
        while i < len(pending) or waiting or decoding:
            # admit arrivals
            while i < len(pending) and pending[i].arrival_s <= t:
                waiting.append(pending[i])
                i += 1
            if not waiting and not decoding:
                t = pending[i].arrival_s
                continue
            if waiting:
                # prefill-priority: batch under the token budget
                batch, tok = [], 0
                for r in list(waiting):
                    if tok + r.n_tokens > self.max_batch_tokens and batch:
                        break
                    batch.append(r)
                    tok += r.n_tokens
                for r in batch:
                    waiting.remove(r)
                dt = self.prefill_time_fn(tok)
                t += dt
                for r in batch:
                    decoding.append((r, t - r.arrival_s, r.decode_steps))
            else:
                # one decode iteration for the running batch
                batch = decoding[:self.max_decode_batch]
                t += self.decode_time_fn(len(batch))
                keep = []
                for r, ttft, left in decoding:
                    if (r, ttft, left) in batch or left > 0:
                        pass
                    left2 = left - 1 if (r, ttft, left) in batch else left
                    if left2 <= 0:
                        done.append(Completion(r.rid, r.arrival_s,
                                               r.arrival_s + ttft, t))
                    else:
                        keep.append((r, ttft, left2))
                decoding = keep
        return done
