"""Continuous batching for the serving path.

Requests arrive asynchronously; the batcher forms prefill batches under a
token budget and interleaves decode iterations (prefill-prioritized, like
vLLM's default).  The *same loop* drives both execution targets through
the `EngineBackend` seam:

* `SimBackend` — the analytic cost model as a virtual clock (tests,
  scheduling/benchmark sweeps; the seed behaviour);
* `JaxEngineBackend` — the real batched JAX engine + paged KV pool
  (`serving.batch_engine`), timed on the wall clock.

A backend returns the seconds each step took; the batcher only ever adds
those to its clock, so scheduling policy is identical in both worlds.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Protocol, Sequence

import numpy as np


@dataclass(order=True)
class PendingRequest:
    arrival_s: float
    rid: int = field(compare=False)
    n_tokens: int = field(compare=False)
    decode_steps: int = field(compare=False, default=4)
    # real-engine payload (None for the simulator)
    tokens: Optional[np.ndarray] = field(compare=False, default=None)


@dataclass
class Completion:
    rid: int
    arrival_s: float
    first_token_s: float      # TTFT
    done_s: float


class EngineBackend(Protocol):
    """What the batching loop needs from an execution target."""

    def prefill(self, batch: Sequence[PendingRequest]) -> float:
        """Run one prefill batch; -> seconds it took."""

    def decode(self, batch: Sequence[PendingRequest]) -> float:
        """Run one decode iteration for `batch`; -> seconds it took."""

    def can_admit(self, req: PendingRequest,
                  batch: Sequence[PendingRequest] = ()) -> bool:
        """Room for this request *on top of* the forming `batch`?  False
        defers admission (backpressure) until running requests finish
        and free capacity."""

    def finish(self, req: PendingRequest) -> None:
        """Request left the decode set — release its resources."""


class SimBackend:
    """Virtual clock: analytic prefill/decode time functions."""

    def __init__(self, prefill_time_fn: Callable[[int], float],
                 decode_time_fn: Callable[[int], float]):
        self.prefill_time_fn = prefill_time_fn
        self.decode_time_fn = decode_time_fn

    def prefill(self, batch: Sequence[PendingRequest]) -> float:
        return self.prefill_time_fn(sum(r.n_tokens for r in batch))

    def decode(self, batch: Sequence[PendingRequest]) -> float:
        return self.decode_time_fn(len(batch))

    def can_admit(self, req: PendingRequest,
                  batch: Sequence[PendingRequest] = ()) -> bool:
        return True

    def finish(self, req: PendingRequest) -> None:
        pass


class JaxEngineBackend:
    """Real hardware: the batched JAX engine behind the same seam.

    `mode="full"` prefills every prompt exactly; `mode="rcllm"` runs the
    beyond-prefix selective path (requests then need `.plan`/cached KV —
    supply them via `plans`).  Greedy sampling; generated tokens are kept
    per request for inspection.
    """

    def __init__(self, engine, mode: str = "full", plans: Optional[Dict]
                 = None):
        self.engine = engine
        self.mode = mode
        self.plans = plans or {}
        self.last_token: Dict[int, int] = {}
        self.generated: Dict[int, List[int]] = {}

    def _batch_requests(self, batch: Sequence[PendingRequest]):
        from repro.serving.batch_engine import BatchRequest
        out = []
        for r in batch:
            if r.tokens is None:
                raise ValueError(f"request {r.rid}: real engine needs tokens")
            # decode appends decode_steps-1 KV slots: the first output
            # token comes from prefill and the last sampled token is
            # never written back
            br = BatchRequest(rid=r.rid, tokens=r.tokens,
                              n_reserve=max(r.decode_steps - 1, 0))
            if self.mode == "rcllm":
                plan, ck, cv, have = self.plans[r.rid]
                br.plan, br.cached_k, br.cached_v, br.have = plan, ck, cv, have
            out.append(br)
        return out

    def prefill(self, batch: Sequence[PendingRequest]) -> float:
        t0 = time.perf_counter()
        logits = self.engine.prefill(self._batch_requests(batch), self.mode)
        for r, lg in zip(batch, logits):
            tok = int(np.argmax(lg))
            self.last_token[r.rid] = tok
            self.generated[r.rid] = [tok]
        return time.perf_counter() - t0

    def can_admit(self, req: PendingRequest,
                  batch: Sequence[PendingRequest] = ()) -> bool:
        # pages for the prompt + the decode tokens it will append, on top
        # of what the rest of the forming batch will claim
        pool = self.engine.pool
        need = sum(pool.pages_for(r.n_tokens + max(r.decode_steps - 1, 0))
                   for r in (*batch, req))
        return need <= pool.free_pages

    def decode(self, batch: Sequence[PendingRequest]) -> float:
        t0 = time.perf_counter()
        rids = [r.rid for r in batch]
        logits = self.engine.decode(rids, [self.last_token[r] for r in rids])
        for rid, lg in zip(rids, logits):
            tok = int(np.argmax(lg))
            self.last_token[rid] = tok
            self.generated[rid].append(tok)
        return time.perf_counter() - t0

    def finish(self, req: PendingRequest) -> None:
        self.engine.release(req.rid)
        self.last_token.pop(req.rid, None)


class ContinuousBatcher:
    """Single-instance continuous batching over an `EngineBackend`.

    Backward-compatible construction: passing `prefill_time_fn` /
    `decode_time_fn` (the seed API) wraps them in a `SimBackend`.
    """

    def __init__(self, prefill_time_fn: Optional[Callable[[int], float]]
                 = None,
                 decode_time_fn: Optional[Callable[[int], float]] = None,
                 max_batch_tokens: int = 8192,
                 max_decode_batch: int = 64,
                 backend: Optional[EngineBackend] = None):
        if backend is None:
            if prefill_time_fn is None or decode_time_fn is None:
                raise ValueError("need a backend or both time functions")
            backend = SimBackend(prefill_time_fn, decode_time_fn)
        self.backend = backend
        self.max_batch_tokens = max_batch_tokens
        self.max_decode_batch = max_decode_batch

    def run(self, requests: List[PendingRequest]) -> List[Completion]:
        pending = sorted(requests)
        waiting: List[PendingRequest] = []
        # decode set entries: [req, ttft_s, decode_steps_left]
        decoding: List[list] = []
        done: List[Completion] = []
        t = 0.0
        i = 0
        while i < len(pending) or waiting or decoding:
            # admit arrivals
            while i < len(pending) and pending[i].arrival_s <= t:
                waiting.append(pending[i])
                i += 1
            if not waiting and not decoding:
                t = pending[i].arrival_s
                continue
            batch, tok = [], 0
            if waiting:
                # prefill-priority: batch under the token budget; requests
                # the backend has no capacity for wait (KV-pool backpressure)
                for r in list(waiting):
                    if tok + r.n_tokens > self.max_batch_tokens and batch:
                        break
                    if not self.backend.can_admit(r, batch):
                        # strict FCFS under backpressure: never admit a
                        # younger request past one waiting on capacity
                        # (head-of-line wait beats unbounded starvation)
                        break
                    batch.append(r)
                    tok += r.n_tokens
                if not batch and not decoding:
                    raise RuntimeError(
                        f"request {waiting[0].rid} ({waiting[0].n_tokens} "
                        "tokens) can never be admitted: KV pool too small "
                        "even with no other request running")
            if batch:
                for r in batch:
                    waiting.remove(r)
                t += self.backend.prefill(batch)
                for r in batch:
                    if r.decode_steps <= 1:      # TTFT token was the output
                        done.append(Completion(r.rid, r.arrival_s,
                                               t, t))
                        self.backend.finish(r)
                    else:
                        decoding.append([r, t - r.arrival_s,
                                         r.decode_steps - 1])
            else:
                # one decode iteration for the running batch
                batch = decoding[:self.max_decode_batch]
                t += self.backend.decode([e[0] for e in batch])
                for e in batch:
                    e[2] -= 1
                keep = []
                for e in decoding:
                    if e[2] <= 0:
                        done.append(Completion(e[0].rid, e[0].arrival_s,
                                               e[0].arrival_s + e[1], t))
                        self.backend.finish(e[0])
                    else:
                        keep.append(e)
                decoding = keep
        return done
