"""Serving workload construction shared by the launcher, benchmarks and
tests: a synthetic request trace becomes batcher requests plus the
per-request assembly artifacts the rcllm prefill path needs.

Keeping this in one place means the (plan, cached_k, cached_v, have)
tuple shape consumed by `JaxEngineBackend` has a single producer — and
the same holds for the cross-request reuse metadata
(`block_store.RequestReuse`): `build_request_reuse` is the one place
that derives content keys and block refs from a plan, used by both the
single-instance path (`rcllm_reuse_info`) and the cluster's dispatch
binding (`serving.cluster`).
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.assembly import FROM_ITEM, AssemblyPlan
from repro.serving.batch_engine import BatchRequest
from repro.serving.batching import PendingRequest
from repro.serving.block_store import BlockRef, RequestReuse, content_key


def rcllm_workload(
    system, trace: Sequence, decode_steps: int = 4
) -> Tuple[List[PendingRequest], Dict[int, tuple]]:
    """Route each traced request, build its assembly plan and gather its
    cached KV.  -> (pending requests for `ContinuousBatcher`,
    {rid: (plan, cached_k, cached_v, have)} for `JaxEngineBackend`)."""
    plans: Dict[int, tuple] = {}
    pend: List[PendingRequest] = []
    for rid, rq in enumerate(trace):
        inst = system.best_instance(rq)
        plan = system.plan_for(rq, inst)
        ck, cv, have = system.cached_kv(plan, inst)
        plans[rid] = (plan, ck, cv, have)
        pend.append(
            PendingRequest(
                arrival_s=float(rq.arrival_s),
                rid=rid,
                n_tokens=plan.n,
                decode_steps=decode_steps,
                tokens=plan.tokens,
            )
        )
    return pend, plans


def rcllm_batch_requests(
    system, trace: Sequence, n_reserve: int = 0
) -> List[BatchRequest]:
    """Direct `BatchEngine.prefill(mode="rcllm")` inputs for a trace —
    the no-batcher variant used by parity tests and microbenchmarks."""
    _, plans = rcllm_workload(system, trace)
    return [
        BatchRequest(
            rid=rid,
            tokens=plan.tokens,
            plan=plan,
            cached_k=ck,
            cached_v=cv,
            have=have,
            n_reserve=n_reserve,
        )
        for rid, (plan, ck, cv, have) in sorted(plans.items())
    ]


# ------------------------- cross-request reuse -------------------------
def item_block_key(tokens: np.ndarray) -> tuple:
    """Content address of one item block: determined entirely by its
    token ids (the offline KV bytes are a pure function of them)."""
    return content_key("item", np.asarray(tokens, np.int64))


def user_prefix_key(instruction: np.ndarray, request) -> tuple:
    """Content address of one user's prompt prefix (instruction + history
    + instance-specific markers) — what the pinned user tier is keyed by."""
    return content_key(
        "user",
        np.asarray(instruction, np.int64),
        np.asarray(request.history_tokens, np.int64),
        np.asarray(request.history_marker_mask, np.int64),
    )


def build_request_reuse(
    plan: AssemblyPlan,
    have: np.ndarray,
    staged: Dict[int, object],
    user_key: Optional[tuple],
    prefix_end: int,
    item_keys: Optional[Dict[int, tuple]] = None,
    instr_len: int = 0,
) -> RequestReuse:
    """Derive one request's shareable-block metadata from its plan.

    `staged` maps item id -> block (any object with .tokens/.k/.v — an
    `item_cache.ItemBlock` or a store host block); blocks absent from it
    produce no ref (nothing to insert, nothing to map).  `item_keys`
    short-circuits per-item digests the caller already computed.
    `instr_len` > 0 enables the prefix tier over the leading instruction
    tokens (identical, always-recomputed rows shared across requests).
    """
    refs: List[BlockRef] = []
    item_mask = (plan.source == FROM_ITEM) & have
    for it in np.unique(plan.block_item[item_mask]):
        it = int(it)
        blk = staged.get(it)
        if blk is None:
            continue
        positions = np.where(item_mask & (plan.block_item == it))[0]
        key = (
            item_keys[it]
            if item_keys is not None and it in item_keys
            else item_block_key(blk.tokens)
        )
        refs.append(
            BlockRef(
                key=key,
                positions=positions,
                offsets=plan.block_offset[positions].astype(np.int64),
                k=blk.k,
                v=blk.v,
                tokens=blk.tokens,
            )
        )
    prefix_key = None
    if instr_len > 0:
        prefix_key = content_key(
            "prefix", np.asarray(plan.tokens[:instr_len], np.int64)
        )
    return RequestReuse(
        user_key=user_key,
        prefix_end=prefix_end,
        blocks=refs,
        prefix_key=prefix_key,
        prefix_len=instr_len,
    )


def rcllm_reuse_info(
    system, trace: Sequence, plans: Dict[int, tuple]
) -> Dict[int, RequestReuse]:
    """Reuse metadata for every request of a single-instance workload:
    item refs point at the system's item store blocks (the same bytes
    `gather_cached_kv` staged), the user key covers instruction+history."""
    out: Dict[int, RequestReuse] = {}
    n_instr = len(system.instruction)
    key_of: Dict[int, tuple] = {}
    for rid, rq in enumerate(trace):
        plan, _, _, have = plans[rid]
        staged = {}
        item_mask = (plan.source == FROM_ITEM) & have
        for it in np.unique(plan.block_item[item_mask]):
            blk = system.item_store.get_block(int(it), 0)
            if blk is not None:
                staged[int(it)] = blk
                if int(it) not in key_of:
                    key_of[int(it)] = item_block_key(blk.tokens)
        out[rid] = build_request_reuse(
            plan,
            have,
            staged,
            user_prefix_key(system.instruction, rq),
            n_instr + len(rq.history_tokens),
            item_keys=key_of,
            instr_len=n_instr,
        )
    return out


def heavy_tail_trace(
    catalog,
    pool,
    profile,
    n_requests: int,
    qps: float,
    n_users: int,
    long_prompt_frac: float = 0.15,
    long_prompt_reviews: int = 8,
    n_candidates: int = 8,
    reviews_per_user: int = 1,
    seed: int = 2,
) -> List:
    """Heavy-tail prompt-length workload: a `long_prompt_frac` fraction
    of users carries a lognormal pile of extra reviews, so their
    requests arrive with prompts several times the base length — the
    long-sequence head-of-line interference shape where the chunked
    unified-step scheduler (`serve.py --sched chunked`) pays off.
    Single producer for benches and the launcher, so both measure the
    same mix."""
    from repro.data import synth as SY

    return SY.make_trace(
        catalog,
        pool,
        profile,
        n_requests,
        qps=qps,
        n_users=n_users,
        n_candidates=n_candidates,
        reviews_per_user=reviews_per_user,
        seed=seed,
        long_prompt_frac=long_prompt_frac,
        long_prompt_reviews=long_prompt_reviews,
    )


def zipf_repeat_trace(
    catalog,
    pool,
    profile,
    n_requests: int,
    qps: float,
    n_users: int,
    zipf_a: float = 1.2,
    n_candidates: int = 8,
    reviews_per_user: int = 2,
    seed: int = 2,
) -> List:
    """Repeat-user workload: user ids drawn Zipf(a) so a handful of heavy
    users dominate the stream (plus the catalog's own Zipf popularity on
    candidates) — the shape where the stratified store's pinned user tier
    and LRU item tier both earn their keep."""
    from repro.data import synth as SY

    return SY.make_trace(
        catalog,
        pool,
        profile,
        n_requests,
        qps=qps,
        n_users=n_users,
        n_candidates=n_candidates,
        reviews_per_user=reviews_per_user,
        seed=seed,
        user_zipf_a=zipf_a,
    )
