"""Serving workload construction shared by the launcher, benchmarks and
tests: a synthetic request trace becomes batcher requests plus the
per-request assembly artifacts the rcllm prefill path needs.

Keeping this in one place means the (plan, cached_k, cached_v, have)
tuple shape consumed by `JaxEngineBackend` has a single producer.
"""
from __future__ import annotations

from typing import Dict, List, Sequence, Tuple

from repro.serving.batch_engine import BatchRequest
from repro.serving.batching import PendingRequest


def rcllm_workload(system, trace: Sequence, decode_steps: int = 4
                   ) -> Tuple[List[PendingRequest], Dict[int, tuple]]:
    """Route each traced request, build its assembly plan and gather its
    cached KV.  -> (pending requests for `ContinuousBatcher`,
    {rid: (plan, cached_k, cached_v, have)} for `JaxEngineBackend`)."""
    plans: Dict[int, tuple] = {}
    pend: List[PendingRequest] = []
    for rid, rq in enumerate(trace):
        inst = system.best_instance(rq)
        plan = system.plan_for(rq, inst)
        ck, cv, have = system.cached_kv(plan, inst)
        plans[rid] = (plan, ck, cv, have)
        pend.append(PendingRequest(
            arrival_s=float(rq.arrival_s), rid=rid, n_tokens=plan.n,
            decode_steps=decode_steps, tokens=plan.tokens))
    return pend, plans


def rcllm_batch_requests(system, trace: Sequence, n_reserve: int = 0
                         ) -> List[BatchRequest]:
    """Direct `BatchEngine.prefill(mode="rcllm")` inputs for a trace —
    the no-batcher variant used by parity tests and microbenchmarks."""
    _, plans = rcllm_workload(system, trace)
    return [BatchRequest(rid=rid, tokens=plan.tokens, plan=plan,
                         cached_k=ck, cached_v=cv, have=have,
                         n_reserve=n_reserve)
            for rid, (plan, ck, cv, have) in sorted(plans.items())]
