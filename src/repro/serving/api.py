"""Typed public serving API — the one front door to the serving stack.

Every serving entry point (``launch/serve.py``, the asyncio session
server, the cluster engine, the benchmarks) historically re-listed the
same ~15 knobs as positional/keyword arguments threaded through three
layers (``serve.py -> ClusterEngine -> JaxEngineBackend ->
BatchEngine``), so adding one knob was a five-file diff and invalid
combinations surfaced as deep crashes.  This module replaces that relay
with one validated dataclass plus the frozen request/response types the
session server speaks:

* `ServeConfig` — every engine/scheduler/backend/kernel/reuse knob in
  one frozen dataclass, validated at construction (an invalid combo
  like ``decode_kernel="paged"`` with ``engine="sim"`` raises
  immediately with a message naming both knobs, instead of failing five
  layers down).  `ServeConfig.from_args` maps the legacy ``serve.py``
  flag namespace into the dataclass — the deprecation shim that keeps
  old invocations working.

* `SubmitRequest` / `StreamEvent` / `Completion` — the typed session
  protocol: a client submits a frozen request (prompt tokens, token
  budget, stop sequences, sampling params) and consumes an async
  iterator of `StreamEvent`s ending in exactly one ``finished`` event;
  `Completion` is the materialized terminal view.

* `SamplingParams` / `sample_token` — per-sequence sampling with an
  explicit PRNG seed.  ``temperature == 0`` is greedy argmax (the
  parity-test mode: every scheduler/backend/reuse combination decodes
  bitwise-identical tokens); ``temperature > 0`` draws from the
  (optionally top-k truncated) softmax using a per-request
  ``numpy`` Generator, so a (seed, prompt) pair replays exactly.

* `build_engine` / `build_backend` / `build_batcher` — the sliced
  views: each consumes exactly the `ServeConfig` fields its layer needs,
  so the per-knob keyword plumbing between layers is gone.
"""

from __future__ import annotations

import dataclasses
import warnings
from dataclasses import dataclass, field
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

from repro.configs.base import ATTN_BACKENDS, DECODE_KERNELS

ENGINES = ("sim", "jax")
MODES = ("rcllm", "prefix", "full")
SCHEDS = ("wave", "chunked")
FINISH_REASONS = ("length", "stop", "cancelled", "rejected")


# --------------------------------------------------------------- config
@dataclass(frozen=True)
class MeshConfig:
    """The typed sharding surface: how the serving stack maps onto a real
    ``jax.sharding.Mesh``.

    ``tp`` shards attention heads / MLP hidden / the KV arena's kv-head
    axis over the mesh's ``model`` axis (Megatron-style tensor
    parallelism — GSPMD inserts the all-reduces); ``dp`` sizes the
    ``data`` axis (replica sets — serving arrays are replicated over it).
    ``mesh_shape=None`` derives the shape from ``tp``/``dp``; an explicit
    shape (``--config mesh.mesh_shape=2x4``) must agree with any
    explicitly-set ``tp``/``dp`` and fills them in otherwise.  The
    default ``MeshConfig()`` is *disabled*: the stack runs exactly as
    before, on the default device, with no mesh anywhere.  ``tp=1`` with
    ``mesh_shape=(1, 1)`` is the enabled-but-single-device mesh the
    bitwise parity tests pin (tokens identical to the unsharded path).
    """

    tp: int = 1
    dp: int = 1
    mesh_shape: Optional[Tuple[int, ...]] = None
    axis_names: Tuple[str, ...] = ("data", "model")

    def __post_init__(self):
        def bad(msg: str):
            raise ValueError(f"invalid MeshConfig: {msg}")

        if self.mesh_shape is not None:
            object.__setattr__(self, "mesh_shape", tuple(self.mesh_shape))
        object.__setattr__(self, "axis_names", tuple(self.axis_names))
        if self.tp < 1 or self.dp < 1:
            bad(f"tp={self.tp}/dp={self.dp} must be >= 1")
        names = self.axis_names
        if (
            not names
            or len(set(names)) != len(names)
            or not all(isinstance(a, str) and a for a in names)
        ):
            bad(f"axis_names={names!r} must be distinct non-empty strings")
        if "model" not in names:
            bad(
                f"axis_names={names!r} must include 'model' "
                "(the tensor-parallel axis every PartitionSpec names)"
            )
        if self.mesh_shape is None:
            if names != ("data", "model"):
                bad(
                    f"axis_names={names!r} needs an explicit mesh_shape "
                    "(only the default ('data', 'model') layout can be "
                    "derived from tp/dp)"
                )
            return
        shape = self.mesh_shape
        if len(shape) != len(names):
            bad(
                f"mesh_shape={shape} has {len(shape)} dims but "
                f"axis_names={names!r} has {len(names)}"
            )
        if any(int(s) < 1 for s in shape):
            bad(f"mesh_shape={shape} dims must be >= 1")
        shape = tuple(int(s) for s in shape)
        object.__setattr__(self, "mesh_shape", shape)
        derived_tp = shape[names.index("model")]
        derived_dp = 1
        for name, size in zip(names, shape):
            if name != "model":
                derived_dp *= size
        if self.tp not in (1, derived_tp):
            bad(
                f"mesh_shape={shape} puts {derived_tp} devices on the "
                f"model axis but tp={self.tp}: drop one of the two knobs "
                "or make them agree"
            )
        if self.dp not in (1, derived_dp):
            bad(
                f"mesh_shape={shape} puts {derived_dp} devices on the "
                f"data axes but dp={self.dp}: drop one of the two knobs "
                "or make them agree"
            )
        object.__setattr__(self, "tp", derived_tp)
        object.__setattr__(self, "dp", derived_dp)

    @property
    def enabled(self) -> bool:
        """Does this config ask for a mesh at all?  The default
        ``MeshConfig()`` is disabled — everything runs unsharded on the
        default device, byte-identical to the pre-mesh stack."""
        return self.mesh_shape is not None or self.tp > 1 or self.dp > 1

    @property
    def resolved_shape(self) -> Tuple[int, ...]:
        if self.mesh_shape is not None:
            return self.mesh_shape
        return (self.dp, self.tp)

    @property
    def n_devices(self) -> int:
        n = 1
        for s in self.resolved_shape:
            n *= s
        return n

    def build(self):
        """The real ``jax.sharding.Mesh``, or None when disabled.

        Raises the `launch.mesh` explicit-shape error when the host has
        fewer devices than the shape needs (on CPU, export
        ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` before
        the first jax import to force host devices)."""
        if not self.enabled:
            return None
        from repro.launch.mesh import make_production_mesh

        return make_production_mesh(
            shape=self.resolved_shape, axis_names=self.axis_names
        )


@dataclass(frozen=True)
class DisaggConfig:
    """The typed disaggregation surface: how many cluster workers serve
    each role, and how migration routing trades affinity against bytes.

    ``prefill_workers`` / ``decode_workers`` split the cluster's ``k``
    workers into role-typed halves: prefill workers admit and prefill
    (sampling each request's first token), then hand the finished — or
    chunk-partial — KV to a decode worker over the block-store
    transport; decode workers never admit.  The default
    ``DisaggConfig()`` is *disabled*: every worker is ``unified`` and
    the stack runs byte-for-byte as before.  ``mig_gamma`` weights the
    migration-byte term added to the Eq. 2 affinity score when choosing
    the decode worker (a candidate already holding the request's store
    blocks by digest moves fewer bytes and scores higher).
    """

    prefill_workers: int = 0
    decode_workers: int = 0
    mig_gamma: float = 0.25

    def __post_init__(self):
        def bad(msg: str):
            raise ValueError(f"invalid DisaggConfig: {msg}")

        if self.prefill_workers < 0 or self.decode_workers < 0:
            bad(
                f"prefill_workers={self.prefill_workers}/"
                f"decode_workers={self.decode_workers} must be >= 0"
            )
        if (self.prefill_workers > 0) != (self.decode_workers > 0):
            bad(
                f"prefill_workers={self.prefill_workers} and "
                f"decode_workers={self.decode_workers}: both roles need "
                "at least one worker (0/0 disables disaggregation)"
            )
        if self.mig_gamma < 0:
            bad(f"mig_gamma={self.mig_gamma} must be >= 0")

    @property
    def enabled(self) -> bool:
        """Does this config split roles at all?  The default
        ``DisaggConfig()`` is disabled — every worker is unified and
        every existing flow is preserved byte-for-byte."""
        return self.prefill_workers > 0

    @property
    def n_workers(self) -> int:
        return self.prefill_workers + self.decode_workers

    def role_of(self, wid: int) -> str:
        """Worker role by cluster index: the first ``prefill_workers``
        ids prefill, the rest decode; 'unified' when disabled."""
        if not self.enabled:
            return "unified"
        return "prefill" if wid < self.prefill_workers else "decode"


@dataclass(frozen=True)
class StoreConfig:
    """The typed tiered-store surface: how the shared block store holds
    its payload bytes and what happens to evicted blocks.

    ``kv_store_dtype='int8'`` quantizes user/item block payloads to
    symmetric per-(row, kv-head)-scaled int8 (~4x more catalog blocks
    per host byte; dequantized on assembly, accuracy-gated).
    ``spill_mb`` bounds a host-RAM spill tier that device-tier evictions
    demote to instead of dropping; 0 keeps the legacy drop-on-evict.
    ``prefetch_pages_per_tick`` budgets background promotion of
    router-hinted spill blocks back to device pages, per chunked tick
    (0 disables prefetch — spill hits then promote at insert time).
    The default ``StoreConfig()`` is *disabled*: fp32 payloads,
    drop-on-evict, no prefetch — byte-for-byte the pre-tier store.
    """

    kv_store_dtype: str = "fp32"
    spill_mb: int = 0
    prefetch_pages_per_tick: int = 0

    def __post_init__(self):
        def bad(msg: str):
            raise ValueError(f"invalid StoreConfig: {msg}")

        if self.kv_store_dtype not in ("fp32", "int8"):
            bad(
                f"kv_store_dtype={self.kv_store_dtype!r} not in "
                "('fp32', 'int8')"
            )
        if self.spill_mb < 0:
            bad(f"spill_mb={self.spill_mb} must be >= 0")
        if self.prefetch_pages_per_tick < 0:
            bad(
                f"prefetch_pages_per_tick={self.prefetch_pages_per_tick} "
                "must be >= 0"
            )
        if self.prefetch_pages_per_tick > 0 and self.spill_mb == 0:
            bad(
                f"prefetch_pages_per_tick={self.prefetch_pages_per_tick} "
                "needs spill_mb > 0 (there is no spill tier to prefetch "
                "from)"
            )

    @property
    def enabled(self) -> bool:
        """Does this config change the store at all?  The default
        ``StoreConfig()`` is disabled — fp32 payloads and drop-on-evict,
        preserving every existing bitwise invariant."""
        return self.kv_store_dtype != "fp32" or self.spill_mb > 0


@dataclass(frozen=True)
class ServeConfig:
    """Every serving knob, validated once, threaded everywhere.

    The fields mirror the historical ``launch/serve.py`` flags; see
    `from_args` for the exact mapping.  ``step_tokens=None`` resolves to
    ``max(4 * chunk_tokens, 512)`` (the chunked scheduler's default
    budget) via `resolved_step_tokens`.
    """

    engine: str = "jax"
    k: int = 1
    mode: str = "rcllm"
    policy: str = "affinity"
    sched: str = "wave"
    attn_backend: str = "jnp"
    decode_kernel: str = "auto"
    kv_reuse: bool = False
    chunk_tokens: int = 128
    step_tokens: Optional[int] = None
    max_batch_tokens: int = 4096
    max_decode_batch: int = 64
    page_size: int = 16
    n_pages: int = 512
    decode_steps: int = 4
    r_item: float = 0.3
    r_rev: float = 0.3
    mesh: MeshConfig = field(default_factory=MeshConfig)
    disagg: DisaggConfig = field(default_factory=DisaggConfig)
    store: StoreConfig = field(default_factory=StoreConfig)

    def __post_init__(self):
        def bad(msg: str):
            raise ValueError(f"invalid ServeConfig: {msg}")

        for name, val, choices in (
            ("engine", self.engine, ENGINES),
            ("mode", self.mode, MODES),
            ("sched", self.sched, SCHEDS),
            ("attn_backend", self.attn_backend, ATTN_BACKENDS),
            ("decode_kernel", self.decode_kernel, DECODE_KERNELS),
        ):
            if val not in choices:
                bad(f"{name}={val!r} not in {choices}")
        if self.engine == "sim":
            # the analytic simulator has no attention, no pool and no
            # chunk-resumable prefill: any real-engine knob is a
            # configuration error, caught here rather than five layers in
            if self.decode_kernel != "auto":
                bad(
                    f"decode_kernel={self.decode_kernel!r} needs engine='jax' "
                    "(the simulator has no decode kernel)"
                )
            if self.attn_backend != "jnp":
                bad(
                    f"attn_backend={self.attn_backend!r} needs engine='jax' "
                    "(the simulator runs no attention)"
                )
            if self.kv_reuse:
                bad("kv_reuse=True needs engine='jax' (no pool to share)")
            if self.sched == "chunked":
                bad("sched='chunked' needs engine='jax' (the simulator is wave-only)")
        else:
            if self.mode == "prefix":
                bad(
                    "mode='prefix' is a simulator-only baseline; "
                    "engine='jax' supports mode in ('rcllm', 'full')"
                )
        if self.kv_reuse and self.mode != "rcllm":
            bad(
                f"kv_reuse=True needs mode='rcllm' (the shared block store "
                f"holds beyond-prefix blocks), got mode={self.mode!r}"
            )
        if self.sched == "chunked" and self.mode != "rcllm":
            bad(
                "sched='chunked' drives the beyond-prefix selective prefill; "
                f"mode={self.mode!r} has no chunk-resumable path"
            )
        if self.k < 1:
            bad(f"k={self.k} must be >= 1")
        if self.chunk_tokens < 1:
            bad(f"chunk_tokens={self.chunk_tokens} must be >= 1")
        if self.step_tokens is not None and self.step_tokens < 1:
            bad(f"step_tokens={self.step_tokens} must be >= 1 (or None)")
        if self.page_size < 1 or self.n_pages < 2:
            bad(
                f"page_size={self.page_size} must be >= 1 and "
                f"n_pages={self.n_pages} >= 2 (page 0 is the scratch page)"
            )
        if self.decode_steps < 1:
            bad(f"decode_steps={self.decode_steps} must be >= 1")
        if not (0.0 <= self.r_item <= 1.0 and 0.0 <= self.r_rev <= 1.0):
            bad(f"r_item={self.r_item}/r_rev={self.r_rev} must be in [0, 1]")
        if not isinstance(self.mesh, MeshConfig):
            bad(f"mesh must be a MeshConfig, got {type(self.mesh).__name__}")
        if self.mesh.enabled and self.engine != "jax":
            bad(
                f"mesh.tp={self.mesh.tp}/mesh.dp={self.mesh.dp} needs "
                f"engine='jax' (engine={self.engine!r} runs no devices)"
            )
        if not isinstance(self.disagg, DisaggConfig):
            bad(
                f"disagg must be a DisaggConfig, got "
                f"{type(self.disagg).__name__}"
            )
        if self.disagg.enabled:
            if self.engine != "jax":
                bad(
                    f"disagg.prefill_workers={self.disagg.prefill_workers} "
                    f"needs engine='jax' (engine={self.engine!r} has no KV "
                    "to migrate)"
                )
            if self.k != self.disagg.n_workers:
                bad(
                    f"k={self.k} must equal disagg.prefill_workers + "
                    f"disagg.decode_workers = {self.disagg.n_workers} "
                    "(every cluster worker gets exactly one role)"
                )
        if not isinstance(self.store, StoreConfig):
            bad(
                f"store must be a StoreConfig, got "
                f"{type(self.store).__name__}"
            )
        if self.store.enabled:
            if self.engine != "jax":
                bad(
                    f"store.kv_store_dtype={self.store.kv_store_dtype!r}/"
                    f"store.spill_mb={self.store.spill_mb} needs "
                    f"engine='jax' (engine={self.engine!r} has no block "
                    "store)"
                )
            if not self.kv_reuse:
                bad(
                    "store tiering configures the shared block store: "
                    "set kv_reuse=on (the default store config is a "
                    "no-op without it)"
                )
        if self.mesh.tp > 1:
            # the Mosaic/Pallas kernels are single-device programs: under
            # tensor parallelism GSPMD partitions the jnp reference paths
            # instead (decode_kernel='auto' resolves to the gather oracle,
            # see `apply_to`) until sharded kernels land
            if self.attn_backend == "pallas":
                bad(
                    f"attn_backend='pallas' with mesh.tp={self.mesh.tp}: "
                    "the Pallas kernels are single-device; tensor "
                    "parallelism needs attn_backend='jnp'"
                )
            if self.decode_kernel == "paged":
                bad(
                    f"decode_kernel='paged' with mesh.tp={self.mesh.tp}: "
                    "the fused paged kernel is single-device; use "
                    "decode_kernel='auto' (resolves to the jnp gather "
                    "oracle under tp>1)"
                )

    @property
    def resolved_step_tokens(self) -> int:
        if self.step_tokens is not None:
            return self.step_tokens
        return max(4 * self.chunk_tokens, 512)

    def replace(self, **kw) -> "ServeConfig":
        """A modified copy, re-validated."""
        return dataclasses.replace(self, **kw)

    def apply_to(self, lm_cfg):
        """Slice the model-execution knobs onto an `LMConfig`.

        Under ``mesh.tp > 1`` a ``decode_kernel='auto'`` resolves to the
        jnp gather oracle explicitly (the paged Pallas kernel is
        single-device), so the engine never has to re-derive the routing
        from the mesh."""
        decode_kernel = self.decode_kernel
        if self.mesh.tp > 1 and decode_kernel == "auto":
            decode_kernel = "gather"
        return dataclasses.replace(
            lm_cfg,
            attn_backend=self.attn_backend,
            decode_kernel=decode_kernel,
        )

    # ------------------------- legacy flag shim -------------------------
    #: ``argparse`` attribute -> ServeConfig field for the historical
    #: per-knob ``launch/serve.py`` flags (`--pages` became ``n_pages``;
    #: ``--kv-reuse off|on`` becomes the bool).
    LEGACY_FLAGS = {
        "engine": "engine",
        "k": "k",
        "mode": "mode",
        "policy": "policy",
        "sched": "sched",
        "attn_backend": "attn_backend",
        "decode_kernel": "decode_kernel",
        "kv_reuse": "kv_reuse",
        "chunk_tokens": "chunk_tokens",
        "step_tokens": "step_tokens",
        "max_batch_tokens": "max_batch_tokens",
        "page_size": "page_size",
        "pages": "n_pages",
        "decode_steps": "decode_steps",
        "r_item": "r_item",
        "r_rev": "r_rev",
    }

    @classmethod
    def from_args(
        cls, args, base: Optional["ServeConfig"] = None, warn: bool = True
    ) -> "ServeConfig":
        """Map a legacy ``serve.py`` argparse namespace into a config.

        Only attributes that are present *and not None* override — the
        launcher declares every legacy flag with ``default=None`` so a
        flag the user never typed falls through to `base` (or the
        dataclass default).  When any legacy flag was typed, one
        `DeprecationWarning` names them all (a single warning path, not
        one per flag).
        """
        overrides: Dict[str, object] = {}
        used = []
        for attr, fld in cls.LEGACY_FLAGS.items():
            val = getattr(args, attr, None)
            if val is None:
                continue
            if fld == "kv_reuse" and isinstance(val, str):
                val = val == "on"
            overrides[fld] = val
            used.append(f"--{attr.replace('_', '-')} -> {fld}={render_value(val)}")
        if used and warn:
            warnings.warn(
                f"per-knob serve flags are deprecated; pass --config "
                f"{','.join(f'{f}={render_value(v)}' for f, v in overrides.items())}"
                f" instead ({'; '.join(used)})",
                DeprecationWarning,
                stacklevel=2,
            )
        base = base if base is not None else cls()
        return base.replace(**overrides) if overrides else base

    @classmethod
    def parse(cls, spec: str, base: Optional["ServeConfig"] = None) -> "ServeConfig":
        """Build a config from a compact ``key=value,key=value`` string —
        the launcher's new-style ``--config`` flag.  Values are coerced
        by the field's declared type; booleans accept on/off/true/false.
        Sub-config fields nest with a dot (``mesh.tp=4``,
        ``mesh.mesh_shape=2x4``, ``mesh.axis_names=data+model``,
        ``disagg.prefill_workers=2``, ``store.spill_mb=64``); the
        grammar is total — `render` emits a string this method parses
        back to an equal config.
        """
        base = base if base is not None else cls()
        if not spec.strip():
            return base
        fields = {f.name: f for f in dataclasses.fields(cls)}
        subs = {"mesh": MeshConfig, "disagg": DisaggConfig, "store": StoreConfig}
        sub_fields = {
            name: {f.name: f for f in dataclasses.fields(t)}
            for name, t in subs.items()
        }
        overrides: Dict[str, object] = {}
        sub_overrides: Dict[str, Dict[str, object]] = {n: {} for n in subs}
        for part in spec.split(","):
            part = part.strip()
            if not part:
                continue
            if "=" not in part:
                raise ValueError(f"--config entry {part!r} is not key=value")
            key, val = part.split("=", 1)
            key = key.strip()
            prefix = key.split(".", 1)[0]
            if "." in key and prefix in subs:
                sub = key[len(prefix) + 1 :]
                flds = sub_fields[prefix]
                if sub not in flds:
                    raise ValueError(
                        f"--config key {key!r} is not a "
                        f"{subs[prefix].__name__} field (choose from "
                        f"{sorted(prefix + '.' + f for f in flds)})"
                    )
                sub_overrides[prefix][sub] = _coerce(flds[sub], val.strip())
                continue
            if key in subs:
                examples = {
                    "mesh": "mesh.tp=4, mesh.dp=2, mesh.mesh_shape=2x4, "
                    "mesh.axis_names=data+model",
                    "disagg": "disagg.prefill_workers=2, "
                    "disagg.decode_workers=2, disagg.mig_gamma=0.25",
                    "store": "store.kv_store_dtype=int8, store.spill_mb=64, "
                    "store.prefetch_pages_per_tick=8",
                }
                raise ValueError(
                    f"--config {key} is a sub-config: set its fields as "
                    f"{examples[key]}"
                )
            if key not in fields:
                raise ValueError(
                    f"--config key {key!r} is not a ServeConfig field "
                    f"(choose from {sorted(fields)})"
                )
            overrides[key] = _coerce(fields[key], val.strip())
        for name, ov in sub_overrides.items():
            if ov:
                overrides[name] = dataclasses.replace(
                    getattr(base, name), **ov
                )
        return base.replace(**overrides) if overrides else base

    def render(self) -> str:
        """The ``--config`` string reproducing this config exactly:
        ``ServeConfig.parse(cfg.render()) == cfg`` for every valid
        config (the round-trip the grammar tests pin)."""
        parts = []
        subs = {"mesh": MeshConfig, "disagg": DisaggConfig, "store": StoreConfig}
        for f in dataclasses.fields(self):
            v = getattr(self, f.name)
            if f.name in subs:
                for mf in dataclasses.fields(subs[f.name]):
                    parts.append(
                        f"{f.name}.{mf.name}={render_value(getattr(v, mf.name))}"
                    )
            else:
                parts.append(f"{f.name}={render_value(v)}")
        return ",".join(parts)


def render_value(v) -> str:
    """One value in the ``--config`` grammar (`_coerce`'s inverse):
    booleans as on/off, None as none, int tuples ``x``-joined (mesh
    shapes, ``2x4``), string tuples ``+``-joined (axis names,
    ``data+model``)."""
    if isinstance(v, bool):
        return "on" if v else "off"
    if v is None:
        return "none"
    if isinstance(v, tuple):
        if all(isinstance(x, int) for x in v):
            return "x".join(str(x) for x in v)
        return "+".join(str(x) for x in v)
    return str(v)


def _coerce(fld: dataclasses.Field, val: str):
    t = fld.type
    if "Tuple" in t:
        if val.lower() == "none" and "Optional" in t:
            return None
        if "int" in t:
            try:
                return tuple(int(x) for x in val.split("x"))
            except ValueError:
                raise ValueError(
                    f"--config {fld.name}={val!r}: expected an "
                    "'x'-separated int tuple like 2x4"
                ) from None
        return tuple(s for s in val.split("+") if s)
    if "bool" in t:
        low = val.lower()
        if low in ("on", "true", "1", "yes"):
            return True
        if low in ("off", "false", "0", "no"):
            return False
        raise ValueError(f"--config {fld.name}={val!r}: expected on/off")
    if val.lower() == "none":
        return None
    if "int" in t:
        return int(val)
    if "float" in t:
        return float(val)
    return val


# ------------------------------------------------------------- sampling
@dataclass(frozen=True)
class SamplingParams:
    """Per-sequence sampling.  ``temperature == 0`` is greedy argmax —
    the default, and the mode every bitwise parity test pins.  With
    ``temperature > 0`` the token is drawn from the softmax of
    ``logits / temperature`` (optionally truncated to the ``top_k``
    highest logits) using a per-request PRNG seeded with ``seed``, so
    one (seed, prompt) pair replays the exact same stream."""

    temperature: float = 0.0
    top_k: int = 0
    seed: int = 0

    def __post_init__(self):
        if self.temperature < 0:
            raise ValueError(f"temperature={self.temperature} must be >= 0")
        if self.top_k < 0:
            raise ValueError(f"top_k={self.top_k} must be >= 0")

    @property
    def greedy(self) -> bool:
        return self.temperature == 0.0


GREEDY = SamplingParams()


def sample_token(
    logits: np.ndarray,
    params: SamplingParams = GREEDY,
    rng: Optional[np.random.Generator] = None,
) -> int:
    """One token from one row of logits under `params`."""
    logits = np.asarray(logits, np.float64)
    if params.greedy or rng is None:
        return int(np.argmax(logits))
    z = logits / params.temperature
    if params.top_k and params.top_k < len(z):
        kth = np.partition(z, -params.top_k)[-params.top_k]
        z = np.where(z >= kth, z, -np.inf)
    z = z - np.max(z)
    p = np.exp(z)
    p /= p.sum()
    return int(rng.choice(len(p), p=p))


def match_stop(generated: Sequence[int], stops: Sequence[Tuple[int, ...]]) -> bool:
    """Does the generated stream end with any stop sequence?"""
    for s in stops:
        n = len(s)
        if n and len(generated) >= n and tuple(generated[-n:]) == tuple(s):
            return True
    return False


# ------------------------------------------------------ session protocol
@dataclass(frozen=True)
class SubmitRequest:
    """One client request to the session server.

    ``tokens`` is the prompt (int32 ids).  ``max_tokens`` bounds the
    generated stream (prefill's first token included); ``stop`` is a
    tuple of token-id sequences — generation ends the moment the stream
    *ends with* one of them (the matching tokens are kept, vLLM-style
    inclusive semantics for token-id stops).  ``context`` carries the
    rcllm assembly payload — ``(plan, cached_k, cached_v, have)`` — and
    ``reuse`` the cross-request block metadata; both are None for
    mode='full' prompts.
    """

    rid: int
    tokens: np.ndarray
    max_tokens: int = 4
    stop: Tuple[Tuple[int, ...], ...] = ()
    sampling: SamplingParams = GREEDY
    context: Optional[tuple] = field(default=None, repr=False)
    reuse: Optional[object] = field(default=None, repr=False)

    def __post_init__(self):
        if self.max_tokens < 1:
            raise ValueError(f"max_tokens={self.max_tokens} must be >= 1")
        if any(len(s) == 0 for s in self.stop):
            raise ValueError("empty stop sequence")


@dataclass(frozen=True)
class StreamEvent:
    """One element of a session's event stream.  Exactly one event per
    stream has ``finished=True`` (its ``token`` may still carry the
    final sampled id); ``reason`` is then one of `FINISH_REASONS`."""

    rid: int
    index: int  # 0-based position in the generated stream
    token: Optional[int]
    t_s: float  # server wall clock (seconds since server start)
    finished: bool = False
    reason: Optional[str] = None


@dataclass(frozen=True)
class Completion:
    """Terminal view of one session: every generated token plus the
    latency split the closed-loop runner reports."""

    rid: int
    tokens: Tuple[int, ...]
    reason: str
    submitted_s: float
    first_token_s: Optional[float]
    done_s: float

    @property
    def ttft_s(self) -> Optional[float]:
        if self.first_token_s is None:
            return None
        return self.first_token_s - self.submitted_s


# ------------------------------------------------------- sliced builders
def build_engine(params, lm_cfg, config: ServeConfig, pool=None, sel=None):
    """`BatchEngine` from the config's engine/pool/reuse/mesh slice.  The
    returned engine's `cfg` carries the attention backend and decode
    kernel; `pool`/`sel` override only when a caller needs a bespoke
    pool (tests) or selective budget.

    With ``config.mesh`` enabled this is the one place the mesh becomes
    physical: the param tree is placed by the `sharding.specs`
    PartitionSpec trees and the paged KV arena is sharded over the
    mesh's model axis — the jitted prefill/decode steps are unchanged
    (GSPMD propagates the shardings and inserts the collectives)."""
    from repro.core import engine as ENG
    from repro.serving.batch_engine import BatchEngine
    from repro.serving.block_store import SharedBlockStore
    from repro.serving.kv_pool import pool_for

    cfg = config.apply_to(lm_cfg)
    mesh = config.mesh.build()
    if mesh is not None:
        from repro.sharding.specs import shard_lm_params

        params = shard_lm_params(params, cfg, mesh)
    if pool is None:
        pool = pool_for(
            cfg, page_size=config.page_size, n_pages=config.n_pages, mesh=mesh
        )
    if sel is None:
        sel = ENG.SelectiveConfig(r_item=config.r_item, r_rev=config.r_rev)
    return BatchEngine(
        params,
        cfg,
        pool=pool,
        sel=sel,
        store=SharedBlockStore(
            pool,
            kv_store_dtype=config.store.kv_store_dtype,
            spill_mb=config.store.spill_mb,
            prefetch_pages_per_tick=config.store.prefetch_pages_per_tick,
        )
        if config.kv_reuse
        else None,
        chunk_tokens=config.chunk_tokens,
        mesh=mesh,
    )


def build_backend(engine, config: ServeConfig, plans=None, reuse=None):
    """`JaxEngineBackend` over a built engine (mode slice)."""
    from repro.serving.batching import JaxEngineBackend

    return JaxEngineBackend(engine, mode=config.mode, plans=plans, reuse=reuse)


def build_batcher(backend, config: ServeConfig):
    """`ContinuousBatcher` over a backend (scheduler slice)."""
    from repro.serving.batching import ContinuousBatcher

    return ContinuousBatcher(
        backend=backend,
        max_batch_tokens=config.max_batch_tokens,
        max_decode_batch=config.max_decode_batch,
        sched=config.sched,
        chunk_tokens=config.chunk_tokens,
        step_tokens=config.step_tokens,
    )
