"""Typed public serving API — the one front door to the serving stack.

Every serving entry point (``launch/serve.py``, the asyncio session
server, the cluster engine, the benchmarks) historically re-listed the
same ~15 knobs as positional/keyword arguments threaded through three
layers (``serve.py -> ClusterEngine -> JaxEngineBackend ->
BatchEngine``), so adding one knob was a five-file diff and invalid
combinations surfaced as deep crashes.  This module replaces that relay
with one validated dataclass plus the frozen request/response types the
session server speaks:

* `ServeConfig` — every engine/scheduler/backend/kernel/reuse knob in
  one frozen dataclass, validated at construction (an invalid combo
  like ``decode_kernel="paged"`` with ``engine="sim"`` raises
  immediately with a message naming both knobs, instead of failing five
  layers down).  `ServeConfig.from_args` maps the legacy ``serve.py``
  flag namespace into the dataclass — the deprecation shim that keeps
  old invocations working.

* `SubmitRequest` / `StreamEvent` / `Completion` — the typed session
  protocol: a client submits a frozen request (prompt tokens, token
  budget, stop sequences, sampling params) and consumes an async
  iterator of `StreamEvent`s ending in exactly one ``finished`` event;
  `Completion` is the materialized terminal view.

* `SamplingParams` / `sample_token` — per-sequence sampling with an
  explicit PRNG seed.  ``temperature == 0`` is greedy argmax (the
  parity-test mode: every scheduler/backend/reuse combination decodes
  bitwise-identical tokens); ``temperature > 0`` draws from the
  (optionally top-k truncated) softmax using a per-request
  ``numpy`` Generator, so a (seed, prompt) pair replays exactly.

* `build_engine` / `build_backend` / `build_batcher` — the sliced
  views: each consumes exactly the `ServeConfig` fields its layer needs,
  so the per-knob keyword plumbing between layers is gone.
"""

from __future__ import annotations

import dataclasses
import warnings
from dataclasses import dataclass, field
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

from repro.configs.base import ATTN_BACKENDS, DECODE_KERNELS

ENGINES = ("sim", "jax")
MODES = ("rcllm", "prefix", "full")
SCHEDS = ("wave", "chunked")
FINISH_REASONS = ("length", "stop", "cancelled", "rejected")


# --------------------------------------------------------------- config
@dataclass(frozen=True)
class ServeConfig:
    """Every serving knob, validated once, threaded everywhere.

    The fields mirror the historical ``launch/serve.py`` flags; see
    `from_args` for the exact mapping.  ``step_tokens=None`` resolves to
    ``max(4 * chunk_tokens, 512)`` (the chunked scheduler's default
    budget) via `resolved_step_tokens`.
    """

    engine: str = "jax"
    k: int = 1
    mode: str = "rcllm"
    policy: str = "affinity"
    sched: str = "wave"
    attn_backend: str = "jnp"
    decode_kernel: str = "auto"
    kv_reuse: bool = False
    chunk_tokens: int = 128
    step_tokens: Optional[int] = None
    max_batch_tokens: int = 4096
    max_decode_batch: int = 64
    page_size: int = 16
    n_pages: int = 512
    decode_steps: int = 4
    r_item: float = 0.3
    r_rev: float = 0.3

    def __post_init__(self):
        def bad(msg: str):
            raise ValueError(f"invalid ServeConfig: {msg}")

        for name, val, choices in (
            ("engine", self.engine, ENGINES),
            ("mode", self.mode, MODES),
            ("sched", self.sched, SCHEDS),
            ("attn_backend", self.attn_backend, ATTN_BACKENDS),
            ("decode_kernel", self.decode_kernel, DECODE_KERNELS),
        ):
            if val not in choices:
                bad(f"{name}={val!r} not in {choices}")
        if self.engine == "sim":
            # the analytic simulator has no attention, no pool and no
            # chunk-resumable prefill: any real-engine knob is a
            # configuration error, caught here rather than five layers in
            if self.decode_kernel != "auto":
                bad(
                    f"decode_kernel={self.decode_kernel!r} needs engine='jax' "
                    "(the simulator has no decode kernel)"
                )
            if self.attn_backend != "jnp":
                bad(
                    f"attn_backend={self.attn_backend!r} needs engine='jax' "
                    "(the simulator runs no attention)"
                )
            if self.kv_reuse:
                bad("kv_reuse=True needs engine='jax' (no pool to share)")
            if self.sched == "chunked":
                bad("sched='chunked' needs engine='jax' (the simulator is wave-only)")
        else:
            if self.mode == "prefix":
                bad(
                    "mode='prefix' is a simulator-only baseline; "
                    "engine='jax' supports mode in ('rcllm', 'full')"
                )
        if self.kv_reuse and self.mode != "rcllm":
            bad(
                f"kv_reuse=True needs mode='rcllm' (the shared block store "
                f"holds beyond-prefix blocks), got mode={self.mode!r}"
            )
        if self.sched == "chunked" and self.mode != "rcllm":
            bad(
                "sched='chunked' drives the beyond-prefix selective prefill; "
                f"mode={self.mode!r} has no chunk-resumable path"
            )
        if self.k < 1:
            bad(f"k={self.k} must be >= 1")
        if self.chunk_tokens < 1:
            bad(f"chunk_tokens={self.chunk_tokens} must be >= 1")
        if self.step_tokens is not None and self.step_tokens < 1:
            bad(f"step_tokens={self.step_tokens} must be >= 1 (or None)")
        if self.page_size < 1 or self.n_pages < 2:
            bad(
                f"page_size={self.page_size} must be >= 1 and "
                f"n_pages={self.n_pages} >= 2 (page 0 is the scratch page)"
            )
        if self.decode_steps < 1:
            bad(f"decode_steps={self.decode_steps} must be >= 1")
        if not (0.0 <= self.r_item <= 1.0 and 0.0 <= self.r_rev <= 1.0):
            bad(f"r_item={self.r_item}/r_rev={self.r_rev} must be in [0, 1]")

    @property
    def resolved_step_tokens(self) -> int:
        if self.step_tokens is not None:
            return self.step_tokens
        return max(4 * self.chunk_tokens, 512)

    def replace(self, **kw) -> "ServeConfig":
        """A modified copy, re-validated."""
        return dataclasses.replace(self, **kw)

    def apply_to(self, lm_cfg):
        """Slice the model-execution knobs onto an `LMConfig`."""
        return dataclasses.replace(
            lm_cfg,
            attn_backend=self.attn_backend,
            decode_kernel=self.decode_kernel,
        )

    # ------------------------- legacy flag shim -------------------------
    #: ``argparse`` attribute -> ServeConfig field for the historical
    #: per-knob ``launch/serve.py`` flags (`--pages` became ``n_pages``;
    #: ``--kv-reuse off|on`` becomes the bool).
    LEGACY_FLAGS = {
        "engine": "engine",
        "k": "k",
        "mode": "mode",
        "policy": "policy",
        "sched": "sched",
        "attn_backend": "attn_backend",
        "decode_kernel": "decode_kernel",
        "kv_reuse": "kv_reuse",
        "chunk_tokens": "chunk_tokens",
        "step_tokens": "step_tokens",
        "max_batch_tokens": "max_batch_tokens",
        "page_size": "page_size",
        "pages": "n_pages",
        "decode_steps": "decode_steps",
        "r_item": "r_item",
        "r_rev": "r_rev",
    }

    @classmethod
    def from_args(
        cls, args, base: Optional["ServeConfig"] = None, warn: bool = True
    ) -> "ServeConfig":
        """Map a legacy ``serve.py`` argparse namespace into a config.

        Only attributes that are present *and not None* override — the
        launcher declares every legacy flag with ``default=None`` so a
        flag the user never typed falls through to `base` (or the
        dataclass default).  When any legacy flag was typed, one
        `DeprecationWarning` names them all (a single warning path, not
        one per flag).
        """
        overrides: Dict[str, object] = {}
        used = []
        for attr, fld in cls.LEGACY_FLAGS.items():
            val = getattr(args, attr, None)
            if val is None:
                continue
            if fld == "kv_reuse" and isinstance(val, str):
                val = val == "on"
            overrides[fld] = val
            used.append("--" + attr.replace("_", "-"))
        if used and warn:
            warnings.warn(
                f"per-knob serve flags ({', '.join(used)}) are deprecated; "
                "pass one --config key=value[,key=value...] ServeConfig "
                "instead",
                DeprecationWarning,
                stacklevel=2,
            )
        base = base if base is not None else cls()
        return base.replace(**overrides) if overrides else base

    @classmethod
    def parse(cls, spec: str, base: Optional["ServeConfig"] = None) -> "ServeConfig":
        """Build a config from a compact ``key=value,key=value`` string —
        the launcher's new-style ``--config`` flag.  Values are coerced
        by the field's declared type; booleans accept on/off/true/false.
        """
        base = base if base is not None else cls()
        if not spec.strip():
            return base
        fields = {f.name: f for f in dataclasses.fields(cls)}
        overrides: Dict[str, object] = {}
        for part in spec.split(","):
            part = part.strip()
            if not part:
                continue
            if "=" not in part:
                raise ValueError(f"--config entry {part!r} is not key=value")
            key, val = part.split("=", 1)
            key = key.strip()
            if key not in fields:
                raise ValueError(
                    f"--config key {key!r} is not a ServeConfig field "
                    f"(choose from {sorted(fields)})"
                )
            overrides[key] = _coerce(fields[key], val.strip())
        return base.replace(**overrides)


def _coerce(fld: dataclasses.Field, val: str):
    t = fld.type
    if "bool" in t:
        low = val.lower()
        if low in ("on", "true", "1", "yes"):
            return True
        if low in ("off", "false", "0", "no"):
            return False
        raise ValueError(f"--config {fld.name}={val!r}: expected on/off")
    if val.lower() == "none":
        return None
    if "int" in t:
        return int(val)
    if "float" in t:
        return float(val)
    return val


# ------------------------------------------------------------- sampling
@dataclass(frozen=True)
class SamplingParams:
    """Per-sequence sampling.  ``temperature == 0`` is greedy argmax —
    the default, and the mode every bitwise parity test pins.  With
    ``temperature > 0`` the token is drawn from the softmax of
    ``logits / temperature`` (optionally truncated to the ``top_k``
    highest logits) using a per-request PRNG seeded with ``seed``, so
    one (seed, prompt) pair replays the exact same stream."""

    temperature: float = 0.0
    top_k: int = 0
    seed: int = 0

    def __post_init__(self):
        if self.temperature < 0:
            raise ValueError(f"temperature={self.temperature} must be >= 0")
        if self.top_k < 0:
            raise ValueError(f"top_k={self.top_k} must be >= 0")

    @property
    def greedy(self) -> bool:
        return self.temperature == 0.0


GREEDY = SamplingParams()


def sample_token(
    logits: np.ndarray,
    params: SamplingParams = GREEDY,
    rng: Optional[np.random.Generator] = None,
) -> int:
    """One token from one row of logits under `params`."""
    logits = np.asarray(logits, np.float64)
    if params.greedy or rng is None:
        return int(np.argmax(logits))
    z = logits / params.temperature
    if params.top_k and params.top_k < len(z):
        kth = np.partition(z, -params.top_k)[-params.top_k]
        z = np.where(z >= kth, z, -np.inf)
    z = z - np.max(z)
    p = np.exp(z)
    p /= p.sum()
    return int(rng.choice(len(p), p=p))


def match_stop(generated: Sequence[int], stops: Sequence[Tuple[int, ...]]) -> bool:
    """Does the generated stream end with any stop sequence?"""
    for s in stops:
        n = len(s)
        if n and len(generated) >= n and tuple(generated[-n:]) == tuple(s):
            return True
    return False


# ------------------------------------------------------ session protocol
@dataclass(frozen=True)
class SubmitRequest:
    """One client request to the session server.

    ``tokens`` is the prompt (int32 ids).  ``max_tokens`` bounds the
    generated stream (prefill's first token included); ``stop`` is a
    tuple of token-id sequences — generation ends the moment the stream
    *ends with* one of them (the matching tokens are kept, vLLM-style
    inclusive semantics for token-id stops).  ``context`` carries the
    rcllm assembly payload — ``(plan, cached_k, cached_v, have)`` — and
    ``reuse`` the cross-request block metadata; both are None for
    mode='full' prompts.
    """

    rid: int
    tokens: np.ndarray
    max_tokens: int = 4
    stop: Tuple[Tuple[int, ...], ...] = ()
    sampling: SamplingParams = GREEDY
    context: Optional[tuple] = field(default=None, repr=False)
    reuse: Optional[object] = field(default=None, repr=False)

    def __post_init__(self):
        if self.max_tokens < 1:
            raise ValueError(f"max_tokens={self.max_tokens} must be >= 1")
        if any(len(s) == 0 for s in self.stop):
            raise ValueError("empty stop sequence")


@dataclass(frozen=True)
class StreamEvent:
    """One element of a session's event stream.  Exactly one event per
    stream has ``finished=True`` (its ``token`` may still carry the
    final sampled id); ``reason`` is then one of `FINISH_REASONS`."""

    rid: int
    index: int  # 0-based position in the generated stream
    token: Optional[int]
    t_s: float  # server wall clock (seconds since server start)
    finished: bool = False
    reason: Optional[str] = None


@dataclass(frozen=True)
class Completion:
    """Terminal view of one session: every generated token plus the
    latency split the closed-loop runner reports."""

    rid: int
    tokens: Tuple[int, ...]
    reason: str
    submitted_s: float
    first_token_s: Optional[float]
    done_s: float

    @property
    def ttft_s(self) -> Optional[float]:
        if self.first_token_s is None:
            return None
        return self.first_token_s - self.submitted_s


# ------------------------------------------------------- sliced builders
def build_engine(params, lm_cfg, config: ServeConfig, pool=None, sel=None):
    """`BatchEngine` from the config's engine/pool/reuse slice.  The
    returned engine's `cfg` carries the attention backend and decode
    kernel; `pool`/`sel` override only when a caller needs a bespoke
    pool (tests) or selective budget."""
    from repro.core import engine as ENG
    from repro.serving.batch_engine import BatchEngine
    from repro.serving.block_store import SharedBlockStore
    from repro.serving.kv_pool import pool_for

    cfg = config.apply_to(lm_cfg)
    if pool is None:
        pool = pool_for(cfg, page_size=config.page_size, n_pages=config.n_pages)
    if sel is None:
        sel = ENG.SelectiveConfig(r_item=config.r_item, r_rev=config.r_rev)
    return BatchEngine(
        params,
        cfg,
        pool=pool,
        sel=sel,
        store=SharedBlockStore(pool) if config.kv_reuse else None,
        chunk_tokens=config.chunk_tokens,
    )


def build_backend(engine, config: ServeConfig, plans=None, reuse=None):
    """`JaxEngineBackend` over a built engine (mode slice)."""
    from repro.serving.batching import JaxEngineBackend

    return JaxEngineBackend(engine, mode=config.mode, plans=plans, reuse=reuse)


def build_batcher(backend, config: ServeConfig):
    """`ContinuousBatcher` over a backend (scheduler slice)."""
    from repro.serving.batching import ContinuousBatcher

    return ContinuousBatcher(
        backend=backend,
        max_batch_tokens=config.max_batch_tokens,
        max_decode_batch=config.max_decode_batch,
        sched=config.sched,
        chunk_tokens=config.chunk_tokens,
        step_tokens=config.step_tokens,
    )
