"""Batched prefill/decode over the real JAX engine (the serving tentpole).

Two jitted steps drive every request:

* **prefill** — a padded multi-request step.  ``mode="full"`` runs the
  Full-Recompute batch (`core.engine._jit_batched_prefill`); ``mode=
  "rcllm"`` runs the beyond-prefix selective path *batched*
  (`core.engine.selective_prefill_batch`): requests are bucketed by
  (padded length, padded recompute budget), their plans and cached KV
  stacked, and one jitted layer-0 + one jitted selective step run per
  bucket — the same Eq. 3 scoring and layer stack as the single-request
  engine, shared code, not a copy.  Either way the prompt's pre-RoPE KV
  lands in the paged pool: cached spans are inserted block-granularly
  from the assembly plan, then only the recomputed tokens' fresh KV is
  scattered on top.

* **decode** — a single-token batched step that reads K/V *through the
  page tables*: one arena gather per step, keys realigned to their
  request positions by RoPE's group property, GQA attention over the
  variable-length batch, and the new token's KV written back into the
  arena inside the jit.

`cfg.attn_backend` selects the attention implementation inside both
steps: ``jnp`` (masked-einsum reference) or ``pallas`` — the flash /
selective kernels from `repro.kernels`, interpret mode off-TPU and real
Mosaic lowering on TPU.  Decode's ragged batch rides into the flash
kernel as a `kv_valid` bitmap (causality is implied: the new token is
the newest position in its row).

Shapes are bucketed (sequence bucket for prefill, page/batch buckets for
decode) so steady-state serving retraces O(1) times.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import LMConfig
from repro.core import engine as ENG
from repro.core.assembly import RECOMPUTE, AssemblyPlan, plan_spans
from repro.kernels import default_interpret
from repro.kernels.flash_attention.ops import mha_flash
from repro.models import layers as L
from repro.serving.kv_pool import PagedKVPool, pool_for

# Decode runs one query per request: a small q tile keeps the padded
# query block cheap while kv tiles stay MXU-sized.
DECODE_Q_BLOCK = 8


@dataclass
class BatchRequest:
    """One prompt for the batched engine.  `plan` + cached KV arrays are
    required for the selective (rcllm) path and ignored for full prefill.
    `n_reserve` pre-reserves page capacity for that many decode tokens so
    decode never has to grab pages from the free list mid-flight."""

    rid: int
    tokens: np.ndarray
    plan: Optional[AssemblyPlan] = None
    cached_k: Optional[np.ndarray] = None
    cached_v: Optional[np.ndarray] = None
    have: Optional[np.ndarray] = None
    n_reserve: int = 0


def _decode_attn(q, k_l, v_l, kv_valid, cfg: LMConfig):
    """One decode-layer attention: q (N, Hq, Dh) vs rotated k_l/v_l
    (N, S+1, Hkv, Dh) under the per-row `kv_valid` (N, S+1) mask.

    Causality never needs positions here: the new token is the newest in
    its row, so the key-liveness mask IS the causal mask — which is what
    lets the pallas route use the flash kernel with ``causal=False``.
    """
    if cfg.attn_backend == "pallas":
        return mha_flash(
            q[:, None],
            k_l,
            v_l,
            kv_valid=kv_valid,
            causal=False,
            q_block=DECODE_Q_BLOCK,
            kv_block=ENG.PALLAS_KV_BLOCK,
            interpret=default_interpret(),
        )[:, 0]
    N = q.shape[0]
    Hkv = cfg.n_kv_heads
    G = cfg.n_heads // Hkv
    scale = 1.0 / (cfg.resolved_head_dim**0.5)
    qr = q.reshape(N, Hkv, G, -1)
    s = jnp.einsum("nhgd,nshd->nhgs", qr, k_l, preferred_element_type=jnp.float32)
    s = jnp.where(kv_valid[:, None, None, :], s * scale, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("nhgs,nshd->nhgd", p.astype(v_l.dtype), v_l)
    return o.reshape(N, cfg.n_heads, -1)


def _decode_step(
    params,
    toks,
    page_tables,
    seq_lens,
    new_pages,
    new_slots,
    arena_k,
    arena_v,
    cfg: LMConfig,
):
    """One decode token per request, K/V read through page tables.

    toks: (N,) last sampled token ids; page_tables: (N, P) page ids;
    seq_lens: (N,) tokens resident *before* this step (= the new token's
    position); new_pages/new_slots: (N,) physical slot claimed for the
    new token's KV.  -> (logits (N, V), arena_k', arena_v').

    Jitted below with the arenas donated on TPU/GPU so the update is
    in-place; CPU doesn't implement donation, so there each step copies
    the arenas (fine at test scale).
    """
    N = toks.shape[0]
    page = arena_k.shape[1]
    S = page_tables.shape[1] * page

    x = params["embed"][toks].astype(jnp.dtype(cfg.dtype))  # (N, D)
    if cfg.tie_embeddings:
        x = x * (cfg.d_model**0.5)
    pos_new = seq_lens.astype(jnp.int32)  # (N,)

    # one arena gather per step: (N, P, page, L, Hkv, Dh) -> (N, S, L, ...)
    kg = arena_k[page_tables].reshape(N, S, cfg.n_layers, *arena_k.shape[3:])
    vg = arena_v[page_tables].reshape(N, S, cfg.n_layers, *arena_v.shape[3:])
    slot_pos = jnp.arange(S)
    kv_pos = jnp.concatenate(
        [jnp.broadcast_to(slot_pos[None], (N, S)), pos_new[:, None]], axis=1
    )
    kv_valid = jnp.concatenate(
        [slot_pos[None, :] < seq_lens[:, None], jnp.ones((N, 1), bool)],
        axis=1,
    )  # (N, S+1)

    for layer in range(cfg.n_layers):
        lp = ENG.layer_params(params, layer)
        h = L.rms_norm(x, lp["attn_norm"], cfg.norm_eps)
        q = jnp.einsum("nd,dhe->nhe", h, lp["wq"])
        k_new = jnp.einsum("nd,dhe->nhe", h, lp["wk"])  # pre-RoPE
        v_new = jnp.einsum("nd,dhe->nhe", h, lp["wv"])
        arena_k = arena_k.at[new_pages, new_slots, layer].set(
            k_new.astype(arena_k.dtype)
        )
        arena_v = arena_v.at[new_pages, new_slots, layer].set(
            v_new.astype(arena_v.dtype)
        )

        q = L.apply_rope(q[:, None], pos_new[:, None], cfg.rope_theta)[:, 0]
        k_l = jnp.concatenate([kg[:, :, layer], k_new[:, None]], axis=1)
        v_l = jnp.concatenate([vg[:, :, layer], v_new[:, None]], axis=1)
        k_l = L.apply_rope(k_l, kv_pos, cfg.rope_theta)  # realign

        o = _decode_attn(q, k_l, v_l, kv_valid, cfg)
        x = x + jnp.einsum("nhe,hed->nd", o, lp["wo"])
        x = x + ENG.mlp_block(
            L.rms_norm(x, lp["mlp_norm"], cfg.norm_eps), lp, cfg
        )

    xf = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return xf @ head, arena_k, arena_v


if jax.default_backend() in ("tpu", "gpu"):
    _jit_decode_step = jax.jit(
        _decode_step, static_argnums=(8,), donate_argnums=(6, 7)
    )
else:
    _jit_decode_step = jax.jit(_decode_step, static_argnums=(8,))


class BatchEngine:
    """Multi-request prefill + paged continuous decode on real hardware.

    ``batched_selective`` switches the rcllm prefill between the bucketed
    batched path (`engine.selective_prefill_batch`, the default) and the
    legacy per-request loop — kept for parity tests and the
    `bench_attn_backend` batched-vs-loop comparison.
    """

    def __init__(
        self,
        params,
        cfg: LMConfig,
        pool: Optional[PagedKVPool] = None,
        sel: Optional[ENG.SelectiveConfig] = None,
        bucket: int = 64,
        decode_bucket: int = 8,
        batched_selective: bool = True,
    ):
        self.params = params
        self.cfg = cfg
        self.pool = pool if pool is not None else pool_for(cfg)
        self.sel = sel or ENG.SelectiveConfig()
        self.bucket = bucket
        self.decode_bucket = decode_bucket
        self.batched_selective = batched_selective
        self.last_stats: Dict[int, ENG.EngineStats] = {}

    # ------------------------------ prefill --------------------------------
    def prefill(self, reqs: Sequence[BatchRequest], mode: str = "full") -> np.ndarray:
        """Prefill a batch; KV lands in the pool.  -> logits (N, V)."""
        if mode == "full":
            return self._prefill_full(reqs)
        if mode == "rcllm":
            if self.batched_selective:
                return self._prefill_selective_batch(reqs)
            return np.stack([self._prefill_selective(r) for r in reqs])
        raise ValueError(mode)

    def _prefill_full(self, reqs: Sequence[BatchRequest]) -> np.ndarray:
        lens = [len(r.tokens) for r in reqs]
        S = max(self.bucket, -(-max(lens) // self.bucket) * self.bucket)
        # batch dim is a traced shape too: pad it to a bucket so varying
        # batch compositions reuse compiled steps (pad rows: one PAD
        # token at position 0, logits discarded, nothing pooled)
        N = -(-len(reqs) // self.decode_bucket) * self.decode_bucket
        toks = np.zeros((N, S), np.int32)
        for i, r in enumerate(reqs):
            toks[i, : lens[i]] = r.tokens
        last = np.zeros(N, np.int32)
        last[: len(reqs)] = [n - 1 for n in lens]
        logits, k, v = ENG._jit_batched_prefill(
            self.params, jnp.asarray(toks), jnp.asarray(last), self.cfg
        )
        k = np.asarray(k, np.float32)
        v = np.asarray(v, np.float32)
        for i, r in enumerate(reqs):
            self.pool.alloc(r.rid, lens[i] + r.n_reserve)
            self.pool.write_prompt(r.rid, k[i, : lens[i]], v[i, : lens[i]])
        return np.asarray(logits, np.float32)[: len(reqs)]

    @staticmethod
    def _check_plan(r: BatchRequest) -> None:
        if r.plan is None:
            raise ValueError(f"request {r.rid}: rcllm prefill needs a plan")

    @staticmethod
    def _selective_rows(r: BatchRequest, stats: ENG.EngineStats, k_all, v_all):
        """Final pool rows for one selectively-prefilled request.

        Block-granular semantics with host-side merging: cached span
        values first (one contiguous run per plan span), then the
        recomputed tokens' fresh KV overwriting them — resolved *before*
        the arena scatter so the fused write sees unique positions
        (duplicate slots in one XLA scatter have undefined order).
        -> (positions, k rows, v rows).
        """
        plan = r.plan
        write = np.zeros(plan.n, bool)
        for s in plan_spans(plan):
            if s.source != RECOMPUTE:
                write[s.start : s.end] = True
        kw = np.array(r.cached_k, np.float32)
        vw = np.array(r.cached_v, np.float32)
        rec = stats.recompute_mask
        kw[rec] = k_all[rec]
        vw[rec] = v_all[rec]
        write |= rec
        pos = np.where(write)[0]
        return pos, kw[pos], vw[pos]

    def _insert_selective(
        self,
        r: BatchRequest,
        stats: ENG.EngineStats,
        k_all: np.ndarray,
        v_all: np.ndarray,
    ) -> None:
        """Pool insertion for one selectively-prefilled request: one
        fused scatter for cached spans + recomputed KV, and one for the
        always-fresh layer-0 plane (HH identification runs layer 0 in
        full, so its KV is exact for every token)."""
        self.last_stats[r.rid] = stats
        n = r.plan.n
        self.pool.alloc(r.rid, n + r.n_reserve)
        pos, kw, vw = self._selective_rows(r, stats, k_all, v_all)
        self.pool.write_at(r.rid, pos, kw, vw)
        self.pool.write_at(
            r.rid, np.arange(n), k_all[:, 0], v_all[:, 0], layer=0
        )

    def _prefill_selective_batch(self, reqs: Sequence[BatchRequest]) -> np.ndarray:
        """Batched rcllm prefill: bucketed stacked requests, one jitted
        selective step per bucket (`engine.selective_prefill_batch`),
        then ONE fused pool scatter for the whole batch (plus one for
        the layer-0 planes) instead of per-request arena copies."""
        for r in reqs:
            self._check_plan(r)
        results = ENG.selective_prefill_batch(
            self.params,
            self.cfg,
            [(r.plan, r.cached_k, r.cached_v, r.have) for r in reqs],
            self.sel,
            bucket=self.bucket,
        )
        out = []
        entries, entries_l0 = [], []
        for r, (logits, stats, k_all, v_all) in zip(reqs, results):
            self.last_stats[r.rid] = stats
            n = r.plan.n
            self.pool.alloc(r.rid, n + r.n_reserve)
            pos, kw, vw = self._selective_rows(r, stats, k_all, v_all)
            entries.append((r.rid, pos, kw, vw))
            entries_l0.append((r.rid, np.arange(n), k_all[:, 0], v_all[:, 0]))
            out.append(logits)
        self.pool.write_at_batch(entries)
        self.pool.write_at_batch(entries_l0, layer=0)
        return np.stack(out)

    def _prefill_selective(self, r: BatchRequest) -> np.ndarray:
        """Legacy one-request-at-a-time selective prefill (parity and
        benchmark reference for the batched path)."""
        self._check_plan(r)
        logits, stats, k_all, v_all = ENG.selective_prefill_with_kv(
            self.params,
            self.cfg,
            r.plan,
            r.cached_k,
            r.cached_v,
            r.have,
            self.sel,
            bucket=self.bucket,
        )
        self._insert_selective(r, stats, k_all, v_all)
        return logits

    # ------------------------------- decode --------------------------------
    def decode(self, rids: Sequence[int], last_tokens: Sequence[int]) -> np.ndarray:
        """One token for each running request.  -> logits (N, V)."""
        n = len(rids)
        n_pad = -(-n // self.decode_bucket) * self.decode_bucket
        tables, lens = self.pool.batch_tables(rids)
        pages, slots = self.pool.append_slots(rids)
        toks = np.zeros(n_pad, np.int32)
        toks[:n] = np.asarray(last_tokens, np.int32)
        tables_p = np.zeros((n_pad, tables.shape[1]), np.int32)
        tables_p[:n] = tables
        lens_p = np.zeros(n_pad, np.int32)
        lens_p[:n] = lens
        pages_p = np.zeros(n_pad, np.int32)  # pad rows: scratch page 0
        slots_p = np.zeros(n_pad, np.int32)
        pages_p[:n], slots_p[:n] = pages, slots
        logits, ak, av = _jit_decode_step(
            self.params,
            jnp.asarray(toks),
            jnp.asarray(tables_p),
            jnp.asarray(lens_p),
            jnp.asarray(pages_p),
            jnp.asarray(slots_p),
            self.pool.arena_k,
            self.pool.arena_v,
            self.cfg,
        )
        self.pool.update_arenas(ak, av)
        return np.asarray(logits, np.float32)[:n]

    def release(self, rid: int) -> None:
        self.pool.free(rid)
        self.last_stats.pop(rid, None)
