"""Batched prefill/decode over the real JAX engine (the serving tentpole).

Two jitted steps drive every request:

* **prefill** — a padded multi-request step.  ``mode="full"`` runs the
  Full-Recompute batch (`core.engine._jit_batched_prefill`); ``mode=
  "rcllm"`` runs the beyond-prefix selective path per request
  (`core.engine.selective_prefill_with_kv` — the same Eq. 3 scoring and
  layer stack as the single-request engine, not a copy).  Either way the
  prompt's pre-RoPE KV lands in the paged pool: cached spans are inserted
  block-granularly from the assembly plan, then only the recomputed
  tokens' fresh KV is scattered on top.

* **decode** — a single-token batched step that reads K/V *through the
  page tables*: one arena gather per step, keys realigned to their
  request positions by RoPE's group property, GQA attention over the
  variable-length batch, and the new token's KV written back into the
  arena inside the jit.

Shapes are bucketed (sequence bucket for prefill, page/batch buckets for
decode) so steady-state serving retraces O(1) times.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import LMConfig
from repro.core import engine as ENG
from repro.core.assembly import AssemblyPlan
from repro.models import layers as L
from repro.serving.kv_pool import PagedKVPool, pool_for


@dataclass
class BatchRequest:
    """One prompt for the batched engine.  `plan` + cached KV arrays are
    required for the selective (rcllm) path and ignored for full prefill.
    `n_reserve` pre-reserves page capacity for that many decode tokens so
    decode never has to grab pages from the free list mid-flight."""
    rid: int
    tokens: np.ndarray
    plan: Optional[AssemblyPlan] = None
    cached_k: Optional[np.ndarray] = None
    cached_v: Optional[np.ndarray] = None
    have: Optional[np.ndarray] = None
    n_reserve: int = 0


def _decode_step(params, toks, page_tables, seq_lens, new_pages,
                 new_slots, arena_k, arena_v, cfg: LMConfig):
    """One decode token per request, K/V read through page tables.

    toks: (N,) last sampled token ids; page_tables: (N, P) page ids;
    seq_lens: (N,) tokens resident *before* this step (= the new token's
    position); new_pages/new_slots: (N,) physical slot claimed for the
    new token's KV.  -> (logits (N, V), arena_k', arena_v').

    Jitted below with the arenas donated on TPU/GPU so the update is
    in-place; CPU doesn't implement donation, so there each step copies
    the arenas (fine at test scale).
    """
    N = toks.shape[0]
    page = arena_k.shape[1]
    S = page_tables.shape[1] * page

    x = params["embed"][toks].astype(jnp.dtype(cfg.dtype))     # (N, D)
    if cfg.tie_embeddings:
        x = x * (cfg.d_model ** 0.5)
    pos_new = seq_lens.astype(jnp.int32)                       # (N,)

    # one arena gather per step: (N, P, page, L, Hkv, Dh) -> (N, S, L, ...)
    kg = arena_k[page_tables].reshape(N, S, cfg.n_layers,
                                      *arena_k.shape[3:])
    vg = arena_v[page_tables].reshape(N, S, cfg.n_layers,
                                      *arena_v.shape[3:])
    slot_pos = jnp.arange(S)
    kv_pos = jnp.concatenate(
        [jnp.broadcast_to(slot_pos[None], (N, S)), pos_new[:, None]], axis=1)
    kv_valid = jnp.concatenate(
        [slot_pos[None, :] < seq_lens[:, None],
         jnp.ones((N, 1), bool)], axis=1)                      # (N, S+1)

    scale = 1.0 / (cfg.resolved_head_dim ** 0.5)
    Hkv = cfg.n_kv_heads
    G = cfg.n_heads // Hkv
    for l in range(cfg.n_layers):
        lp = ENG.layer_params(params, l)
        h = L.rms_norm(x, lp["attn_norm"], cfg.norm_eps)
        q = jnp.einsum("nd,dhe->nhe", h, lp["wq"])
        k_new = jnp.einsum("nd,dhe->nhe", h, lp["wk"])         # pre-RoPE
        v_new = jnp.einsum("nd,dhe->nhe", h, lp["wv"])
        arena_k = arena_k.at[new_pages, new_slots, l].set(
            k_new.astype(arena_k.dtype))
        arena_v = arena_v.at[new_pages, new_slots, l].set(
            v_new.astype(arena_v.dtype))

        q = L.apply_rope(q[:, None], pos_new[:, None], cfg.rope_theta)[:, 0]
        k_l = jnp.concatenate([kg[:, :, l], k_new[:, None]], axis=1)
        v_l = jnp.concatenate([vg[:, :, l], v_new[:, None]], axis=1)
        k_l = L.apply_rope(k_l, kv_pos, cfg.rope_theta)        # realign

        qr = q.reshape(N, Hkv, G, -1)
        s = jnp.einsum("nhgd,nshd->nhgs", qr, k_l,
                       preferred_element_type=jnp.float32) * scale
        s = jnp.where(kv_valid[:, None, None, :], s, -1e30)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("nhgs,nshd->nhgd", p.astype(v_l.dtype), v_l)
        o = o.reshape(N, cfg.n_heads, -1)
        x = x + jnp.einsum("nhe,hed->nd", o, lp["wo"])
        x = x + ENG.mlp_block(L.rms_norm(x, lp["mlp_norm"], cfg.norm_eps),
                              lp, cfg)

    xf = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return xf @ head, arena_k, arena_v


if jax.default_backend() in ("tpu", "gpu"):
    _jit_decode_step = jax.jit(_decode_step, static_argnums=(8,),
                               donate_argnums=(6, 7))
else:
    _jit_decode_step = jax.jit(_decode_step, static_argnums=(8,))


class BatchEngine:
    """Multi-request prefill + paged continuous decode on real hardware."""

    def __init__(self, params, cfg: LMConfig, pool: Optional[PagedKVPool]
                 = None, sel: Optional[ENG.SelectiveConfig] = None,
                 bucket: int = 64, decode_bucket: int = 8):
        self.params = params
        self.cfg = cfg
        self.pool = pool if pool is not None else pool_for(cfg)
        self.sel = sel or ENG.SelectiveConfig()
        self.bucket = bucket
        self.decode_bucket = decode_bucket
        self.last_stats: Dict[int, ENG.EngineStats] = {}

    # ------------------------------ prefill --------------------------------
    def prefill(self, reqs: Sequence[BatchRequest], mode: str = "full"
                ) -> np.ndarray:
        """Prefill a batch; KV lands in the pool.  -> logits (N, V)."""
        if mode == "full":
            return self._prefill_full(reqs)
        if mode == "rcllm":
            return np.stack([self._prefill_selective(r) for r in reqs])
        raise ValueError(mode)

    def _prefill_full(self, reqs: Sequence[BatchRequest]) -> np.ndarray:
        lens = [len(r.tokens) for r in reqs]
        S = max(self.bucket,
                -(-max(lens) // self.bucket) * self.bucket)
        # batch dim is a traced shape too: pad it to a bucket so varying
        # batch compositions reuse compiled steps (pad rows: one PAD
        # token at position 0, logits discarded, nothing pooled)
        N = -(-len(reqs) // self.decode_bucket) * self.decode_bucket
        toks = np.zeros((N, S), np.int32)
        for i, r in enumerate(reqs):
            toks[i, :lens[i]] = r.tokens
        last = np.zeros(N, np.int32)
        last[:len(reqs)] = [n - 1 for n in lens]
        logits, k, v = ENG._jit_batched_prefill(
            self.params, jnp.asarray(toks), jnp.asarray(last), self.cfg)
        k = np.asarray(k, np.float32)
        v = np.asarray(v, np.float32)
        for i, r in enumerate(reqs):
            self.pool.alloc(r.rid, lens[i] + r.n_reserve)
            self.pool.write_prompt(r.rid, k[i, :lens[i]], v[i, :lens[i]])
        return np.asarray(logits, np.float32)[:len(reqs)]

    def _prefill_selective(self, r: BatchRequest) -> np.ndarray:
        if r.plan is None:
            raise ValueError(f"request {r.rid}: rcllm prefill needs a plan")
        logits, stats, k_all, v_all = ENG.selective_prefill_with_kv(
            self.params, self.cfg, r.plan, r.cached_k, r.cached_v,
            r.have, self.sel, bucket=self.bucket)
        self.last_stats[r.rid] = stats
        n = r.plan.n
        self.pool.alloc(r.rid, n + r.n_reserve)
        # block-granular insertion of the assembled cache spans...
        self.pool.write_plan(r.rid, r.plan, r.cached_k, r.cached_v)
        # ...fresh KV scattered over the recompute set only...
        r_pos = np.where(stats.recompute_mask)[0]
        self.pool.write_at(r.rid, r_pos, k_all[r_pos], v_all[r_pos])
        # ...and layer 0 is always computed fully (HH identification), so
        # its plane is fresh for every token.
        self.pool.write_at(r.rid, np.arange(n), k_all[:, 0], v_all[:, 0],
                           layer=0)
        return logits

    # ------------------------------- decode --------------------------------
    def decode(self, rids: Sequence[int], last_tokens: Sequence[int]
               ) -> np.ndarray:
        """One token for each running request.  -> logits (N, V)."""
        n = len(rids)
        n_pad = -(-n // self.decode_bucket) * self.decode_bucket
        tables, lens = self.pool.batch_tables(rids)
        pages, slots = self.pool.append_slots(rids)
        toks = np.zeros(n_pad, np.int32)
        toks[:n] = np.asarray(last_tokens, np.int32)
        tables_p = np.zeros((n_pad, tables.shape[1]), np.int32)
        tables_p[:n] = tables
        lens_p = np.zeros(n_pad, np.int32)
        lens_p[:n] = lens
        pages_p = np.zeros(n_pad, np.int32)     # pad rows: scratch page 0
        slots_p = np.zeros(n_pad, np.int32)
        pages_p[:n], slots_p[:n] = pages, slots
        logits, ak, av = _jit_decode_step(
            self.params, jnp.asarray(toks), jnp.asarray(tables_p),
            jnp.asarray(lens_p), jnp.asarray(pages_p),
            jnp.asarray(slots_p), self.pool.arena_k, self.pool.arena_v,
            self.cfg)
        self.pool.update_arenas(ak, av)
        return np.asarray(logits, np.float32)[:n]

    def release(self, rid: int) -> None:
        self.pool.free(rid)
        self.last_stats.pop(rid, None)
