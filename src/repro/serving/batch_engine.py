"""Batched prefill/decode over the real JAX engine (the serving tentpole).

Two jitted steps drive every request:

* **prefill** — a padded multi-request step.  ``mode="full"`` runs the
  Full-Recompute batch (`core.engine._jit_batched_prefill`); ``mode=
  "rcllm"`` runs the beyond-prefix selective path *batched*
  (`core.engine.selective_prefill_batch`): requests are bucketed by
  (padded length, padded recompute budget), their plans and cached KV
  stacked, and one jitted layer-0 + one jitted selective step run per
  bucket — the same Eq. 3 scoring and layer stack as the single-request
  engine, shared code, not a copy.  Either way the prompt's pre-RoPE KV
  lands in the paged pool: cached spans are inserted block-granularly
  from the assembly plan, then only the recomputed tokens' fresh KV is
  scattered on top.

* **decode** — a single-token batched step that reads K/V *through the
  page tables*: one arena gather per step, keys realigned to their
  request positions by RoPE's group property, GQA attention over the
  variable-length batch, and the new token's KV written back into the
  arena inside the jit.

`cfg.attn_backend` selects the attention implementation inside both
steps: ``jnp`` (masked-einsum reference) or ``pallas`` — the selective
kernels for prefill and the **fused paged-decode attention kernel**
(`repro.kernels.paged_attention`) for decode, interpret mode off-TPU
and real Mosaic lowering on TPU.  Under the paged kernel no gather is
materialized at all: the per-request page view (`kv_pool.page_views`)
is scalar-prefetched and the kernel's BlockSpec index maps read the
referenced arena pages directly, with per-slot logical positions
doubling as the liveness mask and the fused RoPE realignment angles.
The jnp gather path stays on as the bitwise oracle (causality is
implied either way: the new token is the newest position in its row);
`cfg.decode_kernel` can pin either decode path independently of the
backend (`core.engine.decode_uses_paged`).

Shapes are bucketed (sequence bucket for prefill, page/batch buckets for
decode) so steady-state serving retraces O(1) times.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import LMConfig
from repro.core import engine as ENG
from repro.core.assembly import RECOMPUTE, AssemblyPlan, plan_spans
from repro.kernels import default_interpret
from repro.kernels.paged_attention.ops import paged_decode_mha
from repro.kernels.paged_attention.ref import masked_decode_attention_ref
from repro.models import layers as L
from repro.serving import block_store as BS
from repro.serving.kv_pool import (
    KVExport,
    PagedKVPool,
    PoolExhausted,
    page_views,
    pool_for,
)

# Decode runs one query per request: a small q tile keeps the padded
# query block cheap while kv tiles stay MXU-sized.
DECODE_Q_BLOCK = 8


@dataclass
class BatchRequest:
    """One prompt for the batched engine.  `plan` + cached KV arrays are
    required for the selective (rcllm) path and ignored for full prefill.
    `n_reserve` pre-reserves page capacity for that many decode tokens so
    decode never has to grab pages from the free list mid-flight.
    `reuse` (optional) names the request's shareable blocks for a
    store-backed engine; without it the request stays fully private."""

    rid: int
    tokens: np.ndarray
    plan: Optional[AssemblyPlan] = None
    cached_k: Optional[np.ndarray] = None
    cached_v: Optional[np.ndarray] = None
    have: Optional[np.ndarray] = None
    n_reserve: int = 0
    reuse: Optional[BS.RequestReuse] = None


@dataclass
class PrefillState:
    """One request's chunk-resumable prefill, engine-side.

    Wraps the pure-compute `engine.ChunkedPrefill` with the pool and
    block-store bookkeeping the serving path needs: which logical
    positions were mapped at store slots when the request was admitted
    (`mapped_mask` — un-shared again at finalize for positions Eq. 3
    selects to recompute), and which store inserts are still owed once
    the request's fresh bytes exist (prefix/user tiers need computed
    KV, so their misses insert at finalize, unlike item blocks whose
    offline bytes insert at admission)."""

    req: BatchRequest
    cp: ENG.ChunkedPrefill
    mapped_mask: np.ndarray
    pending_prefix: Optional[tuple] = None
    pending_user: Optional[tuple] = None  # (key, u_pos)
    started: bool = False
    # buffered layer-0 rows awaiting the finalize scatter (lazy mode):
    # (positions, k0, v0) per completed chunk
    l0_buf: List[tuple] = field(default_factory=list)


@dataclass
class RequestKV:
    """One request's engine-side state as a handoff record — the unit a
    KV migration moves between workers.

    Everything `BatchEngine` used to keep implicitly per-request is
    factored out here: the pool snapshot (`export` — private page bytes
    + slot table), the store blocks the request references (`payloads`,
    riding their content keys so a destination holding a digest pays
    zero transfer), the engine stats, and — for a chunk-partial handoff
    — the live `PrefillState` (owed prefix/user inserts, mapped-mask,
    buffered layer-0 rows, chunk scan position), so `finalize_prefill`
    can run on a *different* engine than `begin_prefill`.  The serving
    layer adds the sampling watermarks (`session`: generated tokens,
    rng state, stop criteria) before routing.

    The pool/store payloads are self-contained host bytes; a partial
    handoff's `prefill.cp` additionally references the model params,
    which migration assumes are replicated across workers (they are —
    every cluster worker serves the same model).
    """

    rid: int
    export: "KVExport"
    held: List[tuple] = field(default_factory=list)  # store keys, w/ dups
    payloads: Dict[tuple, BS.BlockPayload] = field(default_factory=dict)
    stats: Optional[ENG.EngineStats] = None
    prefill: Optional["PrefillState"] = None
    session: Optional[dict] = None  # backend sampling watermarks

    @property
    def nbytes(self) -> int:
        """Worst-case payload: private pages + every store block."""
        return self.export.nbytes + sum(
            p.nbytes for p in self.payloads.values()
        )


def migration_bytes(rec: RequestKV, store: Optional[BS.SharedBlockStore]) -> int:
    """Bytes a worker holding `store` would actually move to import
    `rec`: the private pages always travel; a store payload travels only
    when its content key misses (the digest fast path)."""
    moved = rec.export.nbytes
    for key, payload in rec.payloads.items():
        if store is None or not store.resident(key):
            moved += payload.nbytes
    return moved


@dataclass
class StepReport:
    """What one unified `BatchEngine.step` tick executed and charged."""

    decode_logits: Optional[np.ndarray] = None
    finalized: Dict[int, np.ndarray] = field(default_factory=dict)
    started: List[int] = field(default_factory=list)
    chunked: List[int] = field(default_factory=list)
    charge_decode: int = 0
    charge_chunks: int = 0
    charge_finalize: int = 0
    oversized: bool = False

    @property
    def charged(self) -> int:
        return self.charge_decode + self.charge_chunks + self.charge_finalize


def _decode_attn(q, k_l, v_l, kv_valid):
    """One decode-layer attention on the gather path: q (N, Hq, Dh) vs
    rotated k_l/v_l (N, S+1, Hkv, Dh) under the per-row `kv_valid`
    (N, S+1) mask.

    Causality never needs positions here: the new token is the newest in
    its row, so the key-liveness mask IS the causal mask.  The body is
    `paged_attention.ref.masked_decode_attention_ref` — the SAME helper
    the paged kernel's oracle calls, so the two oracles (and their
    masking constant / dtype discipline) cannot drift apart.
    """
    return masked_decode_attention_ref(q, k_l, v_l, kv_valid)


def _decode_step(
    params,
    toks,
    slot_tables,
    seq_lens,
    new_pages,
    new_slots,
    page_ids,
    slot_pos,
    arena_k,
    arena_v,
    cfg: LMConfig,
):
    """One decode token per request, K/V read through slot tables.

    toks: (N,) last sampled token ids; slot_tables: (N, S) physical slot
    ids (logical order — entries may point into shared store pages);
    seq_lens: (N,) tokens resident *before* this step (= the new token's
    position); new_pages/new_slots: (N,) physical slot claimed for the
    new token's KV; page_ids/slot_pos: the page-granular view
    (`kv_pool.page_views`) the paged kernel consumes — tiny dummies on
    the gather path, where they are dead code.
    -> (logits (N, V), arena_k', arena_v').

    The paged route writes each layer's fresh K/V into the arena
    *before* attention, so the kernel reads the new token (tagged with
    logical position len) through the same page view as every cached
    token — the gather path's explicit concat disappears.

    Jitted below with the arenas donated on TPU/GPU so the update is
    in-place; CPU doesn't implement donation, so there each step copies
    the arenas (fine at test scale).
    """
    N = toks.shape[0]
    page = arena_k.shape[1]
    S = slot_tables.shape[1]

    x = params["embed"][toks].astype(jnp.dtype(cfg.dtype))  # (N, D)
    if cfg.tie_embeddings:
        x = x * (cfg.d_model**0.5)
    pos_new = seq_lens.astype(jnp.int32)  # (N,)

    paged = ENG.decode_uses_paged(cfg)
    if not paged:
        # one arena gather per step: slot-granular, so a row may
        # interleave private pages with store-shared pages
        # -> (N, S, L, Hkv, Dh)
        kg = arena_k[slot_tables // page, slot_tables % page]
        vg = arena_v[slot_tables // page, slot_tables % page]
        slot_idx = jnp.arange(S)
        kv_pos = jnp.concatenate(
            [jnp.broadcast_to(slot_idx[None], (N, S)), pos_new[:, None]],
            axis=1,
        )
        kv_valid = jnp.concatenate(
            [slot_idx[None, :] < seq_lens[:, None], jnp.ones((N, 1), bool)],
            axis=1,
        )  # (N, S+1)

    for layer in range(cfg.n_layers):
        lp = ENG.layer_params(params, layer)
        h = L.rms_norm(x, lp["attn_norm"], cfg.norm_eps)
        q = jnp.einsum("nd,dhe->nhe", h, lp["wq"])
        k_new = jnp.einsum("nd,dhe->nhe", h, lp["wk"])  # pre-RoPE
        v_new = jnp.einsum("nd,dhe->nhe", h, lp["wv"])
        arena_k = arena_k.at[new_pages, new_slots, layer].set(
            k_new.astype(arena_k.dtype)
        )
        arena_v = arena_v.at[new_pages, new_slots, layer].set(
            v_new.astype(arena_v.dtype)
        )

        q = L.apply_rope(q[:, None], pos_new[:, None], cfg.rope_theta)[:, 0]
        if paged:
            o = paged_decode_mha(
                q,
                arena_k,
                arena_v,
                page_ids,
                slot_pos,
                layer=layer,
                rope_theta=cfg.rope_theta,
                q_block=DECODE_Q_BLOCK,
                interpret=default_interpret(),
            )
        else:
            k_l = jnp.concatenate([kg[:, :, layer], k_new[:, None]], axis=1)
            v_l = jnp.concatenate([vg[:, :, layer], v_new[:, None]], axis=1)
            k_l = L.apply_rope(k_l, kv_pos, cfg.rope_theta)  # realign
            o = _decode_attn(q, k_l, v_l, kv_valid)
        x = x + jnp.einsum("nhe,hed->nd", o, lp["wo"])
        x = x + ENG.mlp_block(
            L.rms_norm(x, lp["mlp_norm"], cfg.norm_eps), lp, cfg
        )

    xf = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return xf @ head, arena_k, arena_v


if jax.default_backend() in ("tpu", "gpu"):
    _jit_decode_step = jax.jit(
        _decode_step, static_argnums=(10,), donate_argnums=(8, 9)
    )
else:
    _jit_decode_step = jax.jit(_decode_step, static_argnums=(10,))


class BatchEngine:
    """Multi-request prefill + paged continuous decode on real hardware.

    ``batched_selective`` switches the rcllm prefill between the bucketed
    batched path (`engine.selective_prefill_batch`, the default) and the
    legacy per-request loop — kept for parity tests and the
    `bench_attn_backend` batched-vs-loop comparison.

    ``store`` (a `block_store.SharedBlockStore` over this engine's pool)
    turns on cross-request KV reuse for the rcllm path: prefill *compute*
    is unchanged, but pool insertion maps shareable positions at the
    store's pages and writes only the private remainder — decoded tokens
    are bitwise identical with or without it.
    """

    def __init__(
        self,
        params,
        cfg: LMConfig,
        pool: Optional[PagedKVPool] = None,
        sel: Optional[ENG.SelectiveConfig] = None,
        bucket: int = 64,
        decode_bucket: int = 8,
        batched_selective: bool = True,
        store: Optional[BS.SharedBlockStore] = None,
        chunk_tokens: int = 128,
        eager_kv_writes: Optional[bool] = None,
        mesh=None,
    ):
        # `mesh` is the jax.sharding.Mesh the params/arenas were placed on
        # (None = the classic unsharded engine).  The jitted steps need no
        # mesh plumbing — GSPMD propagates the input shardings — so the
        # engine only records it and rejects the single-device Pallas
        # decode route, which cannot run over sharded arenas.
        if (
            mesh is not None
            and dict(mesh.shape).get("model", 1) > 1
            and ENG.decode_uses_paged(cfg)
        ):
            raise ValueError(
                f"decode_kernel={cfg.decode_kernel!r} routes decode through "
                f"the single-device paged kernel, but the mesh model axis "
                f"has {dict(mesh.shape)['model']} devices: use "
                "decode_kernel='auto'/'gather' under tensor parallelism"
            )
        self.mesh = mesh
        self.params = params
        self.cfg = cfg
        self.pool = pool if pool is not None else pool_for(cfg, mesh=mesh)
        self.sel = sel or ENG.SelectiveConfig()
        self.bucket = bucket
        self.decode_bucket = decode_bucket
        self.batched_selective = batched_selective
        self.store = store
        self.chunk_tokens = chunk_tokens
        # chunked prefill writes each chunk's fresh layer-0 KV into the
        # pool as it completes.  With arena donation (TPU/GPU) the write
        # is in-place and eager per-tick writes are the natural
        # incremental mode; on CPU every eager scatter is a full-arena
        # copy, so the rows are buffered host-side and fused into the
        # finalize scatter instead — nothing reads a request's rows
        # before its decode starts, so the two modes are byte-identical.
        if eager_kv_writes is None:
            eager_kv_writes = jax.default_backend() in ("tpu", "gpu")
        self.eager_kv_writes = eager_kv_writes
        self.store_refs: Dict[int, list] = {}
        self.last_stats: Dict[int, ENG.EngineStats] = {}
        self.prefill_states: Dict[int, PrefillState] = {}

    # ------------------------------ prefill --------------------------------
    def prefill(self, reqs: Sequence[BatchRequest], mode: str = "full") -> np.ndarray:
        """Prefill a batch; KV lands in the pool.  -> logits (N, V)."""
        if mode == "full":
            return self._prefill_full(reqs)
        if mode == "rcllm":
            if self.store is not None:
                return self._prefill_selective_shared(reqs)
            if self.batched_selective:
                return self._prefill_selective_batch(reqs)
            return np.stack([self._prefill_selective(r) for r in reqs])
        raise ValueError(mode)

    def admission_pages(self, r: BatchRequest) -> tuple:
        """(private-page bound, possible inserts) for one request — the
        batcher's `can_admit` accounting under cross-request reuse."""
        return BS.admission_pages(
            self.pool,
            self.store,
            r.plan,
            r.have,
            self.sel,
            r.reuse,
            r.n_reserve,
            bucket=self.bucket,
        )

    def _prefill_full(self, reqs: Sequence[BatchRequest]) -> np.ndarray:
        lens = [len(r.tokens) for r in reqs]
        S = max(self.bucket, -(-max(lens) // self.bucket) * self.bucket)
        # batch dim is a traced shape too: pad it to a bucket so varying
        # batch compositions reuse compiled steps (pad rows: one PAD
        # token at position 0, logits discarded, nothing pooled)
        N = -(-len(reqs) // self.decode_bucket) * self.decode_bucket
        toks = np.zeros((N, S), np.int32)
        for i, r in enumerate(reqs):
            toks[i, : lens[i]] = r.tokens
        last = np.zeros(N, np.int32)
        last[: len(reqs)] = [n - 1 for n in lens]
        logits, k, v = ENG._jit_batched_prefill(
            self.params, jnp.asarray(toks), jnp.asarray(last), self.cfg
        )
        k = np.asarray(k, np.float32)
        v = np.asarray(v, np.float32)
        for i, r in enumerate(reqs):
            self.pool.alloc(r.rid, lens[i] + r.n_reserve)
            self.pool.write_prompt(r.rid, k[i, : lens[i]], v[i, : lens[i]])
        return np.asarray(logits, np.float32)[: len(reqs)]

    @staticmethod
    def _check_plan(r: BatchRequest) -> None:
        if r.plan is None:
            raise ValueError(f"request {r.rid}: rcllm prefill needs a plan")

    @staticmethod
    def _selective_rows(r: BatchRequest, stats: ENG.EngineStats, k_all, v_all):
        """Final pool rows for one selectively-prefilled request.

        Block-granular semantics with host-side merging: cached span
        values first (one contiguous run per plan span), then the
        recomputed tokens' fresh KV overwriting them — resolved *before*
        the arena scatter so the fused write sees unique positions
        (duplicate slots in one XLA scatter have undefined order).
        -> (positions, k rows, v rows).
        """
        plan = r.plan
        write = np.zeros(plan.n, bool)
        for s in plan_spans(plan):
            if s.source != RECOMPUTE:
                write[s.start : s.end] = True
        kw = np.array(r.cached_k, np.float32)
        vw = np.array(r.cached_v, np.float32)
        rec = stats.recompute_mask
        kw[rec] = k_all[rec]
        vw[rec] = v_all[rec]
        write |= rec
        pos = np.where(write)[0]
        return pos, kw[pos], vw[pos]

    def _insert_selective(
        self,
        r: BatchRequest,
        stats: ENG.EngineStats,
        k_all: np.ndarray,
        v_all: np.ndarray,
    ) -> None:
        """Pool insertion for one selectively-prefilled request: one
        fused scatter for cached spans + recomputed KV, and one for the
        always-fresh layer-0 plane (HH identification runs layer 0 in
        full, so its KV is exact for every token)."""
        self.last_stats[r.rid] = stats
        n = r.plan.n
        self.pool.alloc(r.rid, n + r.n_reserve)
        pos, kw, vw = self._selective_rows(r, stats, k_all, v_all)
        self.pool.write_at(r.rid, pos, kw, vw)
        self.pool.write_at(
            r.rid, np.arange(n), k_all[:, 0], v_all[:, 0], layer=0
        )

    def _prefill_selective_batch(self, reqs: Sequence[BatchRequest]) -> np.ndarray:
        """Batched rcllm prefill: bucketed stacked requests, one jitted
        selective step per bucket (`engine.selective_prefill_batch`),
        then ONE fused pool scatter for the whole batch (plus one for
        the layer-0 planes) instead of per-request arena copies."""
        for r in reqs:
            self._check_plan(r)
        results = ENG.selective_prefill_batch(
            self.params,
            self.cfg,
            [(r.plan, r.cached_k, r.cached_v, r.have) for r in reqs],
            self.sel,
            bucket=self.bucket,
        )
        out = []
        entries, entries_l0 = [], []
        for r, (logits, stats, k_all, v_all) in zip(reqs, results):
            self.last_stats[r.rid] = stats
            n = r.plan.n
            self.pool.alloc(r.rid, n + r.n_reserve)
            pos, kw, vw = self._selective_rows(r, stats, k_all, v_all)
            entries.append((r.rid, pos, kw, vw))
            entries_l0.append((r.rid, np.arange(n), k_all[:, 0], v_all[:, 0]))
            out.append(logits)
        self.pool.write_at_batch(entries)
        self.pool.write_at_batch(entries_l0, layer=0)
        return np.stack(out)

    def _prefill_selective(self, r: BatchRequest) -> np.ndarray:
        """Legacy one-request-at-a-time selective prefill (parity and
        benchmark reference for the batched path)."""
        self._check_plan(r)
        logits, stats, k_all, v_all = ENG.selective_prefill_with_kv(
            self.params,
            self.cfg,
            r.plan,
            r.cached_k,
            r.cached_v,
            r.have,
            self.sel,
            bucket=self.bucket,
        )
        self._insert_selective(r, stats, k_all, v_all)
        return logits

    # --------------------------- shared insertion ---------------------------
    def _prefix_full_key(self, r: BatchRequest):
        """The prefix tier's content key for one request: instruction
        digest + the (n_pad, r_pad) jit bucket its rows came out of
        (computed from the *original* plan shape, so hit and miss
        requests derive the same key)."""
        reuse = r.reuse
        if reuse is None or reuse.prefix_key is None or not reuse.prefix_len:
            return None
        return reuse.prefix_key + BS.shape_bucket(
            r.plan, r.have, self.sel, self.bucket
        )

    def _prefill_selective_shared(self, reqs: Sequence[BatchRequest]) -> np.ndarray:
        """rcllm prefill against the shared block store.

        A **prefix-tier hit** is injected *before* compute: the stored
        instruction rows — byte-for-byte what this request's selective
        pass would recompute — are handed to the engine as cached KV
        with `have` set, so the instruction drops out of the recompute
        set entirely (real FLOP savings, not just skipped writes).  For
        every other tier the compute is identical to the private path;
        pool insertion then maps store-resident blocks instead of
        re-writing their bytes.
        """
        for r in reqs:
            self._check_plan(r)
        store = self.store
        prefix_hits: Dict[int, tuple] = {}
        items_in = []
        for r in reqs:
            ck, cv, have = r.cached_k, r.cached_v, r.have
            key = self._prefix_full_key(r)
            blk = store.get(key) if key is not None else None
            if blk is not None:
                # held until release(rid); recorded via prefix_hits
                blk.refcount += 1
                prefix_hits[r.rid] = (key, blk)
                npfx = min(blk.n_tokens, r.plan.n)
                ck = np.array(ck, np.float32)
                cv = np.array(cv, np.float32)
                have = have.copy()
                ck[:npfx] = blk.host_k[:npfx]
                cv[:npfx] = blk.host_v[:npfx]
                have[:npfx] = True
            items_in.append((r.plan, ck, cv, have))
        if self.batched_selective:
            results = ENG.selective_prefill_batch(
                self.params, self.cfg, items_in, self.sel, bucket=self.bucket
            )
        else:
            results = [
                ENG.selective_prefill_with_kv(
                    self.params, self.cfg, *item, self.sel, bucket=self.bucket
                )
                for item in items_in
            ]
        return self._insert_batch_shared(reqs, results, prefix_hits)

    def _insert_batch_shared(self, reqs, results, prefix_hits=None) -> np.ndarray:
        """Map store hits, insert missing blocks, write the private rest.

        Phase A acquires a reference on every resident block any request
        in the batch will map, *before* any insertion can trigger LRU
        eviction — so a block one batch member counts on can never be
        evicted to make room for another's insert.  Phase B then, per
        request: inserts missing blocks (optional — gated so the batch's
        remaining mandatory private allocations keep their pages), maps
        the hit positions that survived recompute selection, allocates
        the private remainder and stages its rows for the fused scatter.
        """
        store = self.store
        prefix_hits = prefix_hits if prefix_hits is not None else {}
        held: Dict[int, list] = {r.rid: [] for r in reqs}
        blocks: Dict[int, dict] = {r.rid: {} for r in reqs}
        # prefix refs were already taken pre-compute (the hit changed the
        # recompute set); record them so release(rid) drops them too
        for rid, (key, blk) in prefix_hits.items():
            held[rid].append(key)
            blocks[rid][key] = blk
        # phase A: silently acquire refs on resident blocks, batch-wide,
        # before any insertion can evict (hit/miss accounting happens at
        # resolution time in phase B, where same-batch inserts count as
        # the hits they are)
        for r in reqs:
            reuse = r.reuse if r.reuse is not None else BS.RequestReuse()
            keys = [ref.key for ref in reuse.blocks]
            if reuse.user_key is not None and len(
                BS.user_reuse_positions(r.plan, r.have, reuse.prefix_end)
            ):
                keys.append(reuse.user_key)
            for key in keys:
                blk = store.get(key)
                if blk is not None:
                    blk.refcount += 1
                    held[r.rid].append(key)
                    blocks[r.rid][key] = blk
        # private-page demand still owed to unprocessed batch members:
        # optional inserts must never eat into it
        bounds = {r.rid: self.admission_pages(r)[0] for r in reqs}
        remaining = sum(bounds.values())
        out = []
        entries, entries_l0 = [], []
        for r, (logits, stats, k_all, v_all) in zip(reqs, results):
            self.last_stats[r.rid] = stats
            n = r.plan.n
            rec = stats.recompute_mask
            reuse = r.reuse if r.reuse is not None else BS.RequestReuse()
            pos_parts, slot_parts = [], []
            # --- prefix tier: the instruction's recomputed rows, shared
            # by every request in this (n_pad, r_pad) bucket, pinned ---
            key = self._prefix_full_key(r)
            if key is not None:
                pblk = None
                if r.rid in prefix_hits:
                    pblk = prefix_hits[r.rid][1]
                    store.count_hit(pblk)
                else:
                    pblk = store.acquire(key)
                    if pblk is not None:
                        held[r.rid].append(key)
                    else:
                        # this request recomputed the instruction rows
                        # itself — they become the shared block
                        npfx = min(reuse.prefix_len, n)
                        pblk = store.insert(
                            key,
                            BS.PREFIX_TIER,
                            k_all[:npfx],
                            v_all[:npfx],
                            pinned=True,
                            keep_free=remaining,
                            defer_write=True,
                        )
                        if pblk is not None:
                            pblk.refcount += 1
                            held[r.rid].append(key)
                if pblk is not None:
                    npfx = min(pblk.n_tokens, n)
                    pos_parts.append(np.arange(npfx))
                    slot_parts.append(pblk.slots[:npfx])
            # --- item tier: offline block bytes, LRU-evictable ---
            for ref in reuse.blocks:
                blk = blocks[r.rid].get(ref.key)
                if blk is not None:
                    store.count_hit(blk)
                else:
                    # an earlier request in this batch may have inserted
                    # it since phase A — that is a hit too
                    blk = store.acquire(ref.key)
                    if blk is not None:
                        held[r.rid].append(ref.key)
                    elif ref.k is not None:
                        blk = store.insert(
                            ref.key,
                            BS.ITEM_TIER,
                            ref.k,
                            ref.v,
                            tokens=ref.tokens,
                            keep_free=remaining,
                            defer_write=True,
                        )
                        if blk is not None:
                            blk.refcount += 1
                            held[r.rid].append(ref.key)
                if blk is None:
                    continue
                use = ~rec[ref.positions]
                pos_parts.append(ref.positions[use])
                slot_parts.append(blk.slots[ref.offsets[use]])
            # --- user tier: fresh layer-0 + semantic deep layers, pinned ---
            u_pos = None
            if reuse.user_key is not None:
                u_pos = BS.user_reuse_positions(r.plan, r.have, reuse.prefix_end)
            if u_pos is not None and len(u_pos):
                ublk = blocks[r.rid].get(reuse.user_key)
                if ublk is not None:
                    store.count_hit(ublk)
                else:
                    ublk = store.acquire(reuse.user_key)
                    if ublk is not None:
                        held[r.rid].append(reuse.user_key)
                    else:
                        ku = np.concatenate(
                            [k_all[u_pos, :1], r.cached_k[u_pos, 1:]], axis=1
                        )
                        vu = np.concatenate(
                            [v_all[u_pos, :1], r.cached_v[u_pos, 1:]], axis=1
                        )
                        ublk = store.insert(
                            reuse.user_key,
                            BS.USER_TIER,
                            ku,
                            vu,
                            positions=u_pos,
                            pinned=True,
                            keep_free=remaining,
                            defer_write=True,
                        )
                        if ublk is not None:
                            ublk.refcount += 1
                            held[r.rid].append(reuse.user_key)
                if ublk is not None:
                    common = np.intersect1d(u_pos, ublk.positions)
                    common = common[~rec[common]]
                    pos_parts.append(common)
                    slot_parts.append(
                        ublk.slots[np.searchsorted(ublk.positions, common)]
                    )
            mapped_pos = (
                np.concatenate(pos_parts)
                if pos_parts
                else np.zeros(0, np.int64)
            )
            mapped_slots = (
                np.concatenate(slot_parts)
                if slot_parts
                else np.zeros(0, np.int64)
            )
            cap = self.pool.pages_for(n + r.n_reserve) * self.pool.page_size
            need = -(-(cap - len(mapped_pos)) // self.pool.page_size)
            if self.pool.free_pages < need:
                store.evict_for(need)
            self.pool.alloc_mapped(r.rid, n + r.n_reserve, mapped_pos, mapped_slots)
            remaining -= bounds[r.rid]
            self.store_refs[r.rid] = held[r.rid]
            mapped_mask = np.zeros(n, bool)
            mapped_mask[mapped_pos] = True
            pos, kw, vw = self._selective_rows(r, stats, k_all, v_all)
            keep = ~mapped_mask[pos]
            entries.append((r.rid, pos[keep], kw[keep], vw[keep]))
            l0_pos = np.where(~mapped_mask)[0]
            entries_l0.append((r.rid, l0_pos, k_all[l0_pos, 0], v_all[l0_pos, 0]))
            out.append(logits)
        store.flush_writes()
        self.pool.write_at_batch(entries)
        self.pool.write_at_batch(entries_l0, layer=0)
        return np.stack(out)

    # ------------------------ chunk-resumable prefill ------------------------
    def begin_prefill(self, r: BatchRequest) -> None:
        """Admit one request into chunk-resumable prefill.

        Resolves the shared block store *now* (a prefix-tier hit is
        injected before any compute, exactly like the wave path, so
        Eq. 3 selection later drops the instruction from the recompute
        set; item/user hits map their positions at store slots) and
        claims the request's full admission-bound private pages up
        front, so neither the incremental chunk writes nor the finalize
        remap can hit `PoolExhausted` mid-prefill.
        """
        self._check_plan(r)
        if r.rid in self.prefill_states:
            raise KeyError(f"request {r.rid} already prefilling")
        plan, n = r.plan, r.plan.n
        ck, cv, have = r.cached_k, r.cached_v, r.have
        store = self.store
        held: List = []
        pos_parts, slot_parts = [], []
        pending_prefix = pending_user = None
        if store is not None:
            reuse = r.reuse if r.reuse is not None else BS.RequestReuse()
            # --- prefix tier: inject a hit before compute ---
            key = self._prefix_full_key(r)
            if key is not None:
                pblk = store.acquire(key)
                if pblk is not None:
                    held.append(key)
                    npfx = min(pblk.n_tokens, n)
                    ck = np.array(ck, np.float32)
                    cv = np.array(cv, np.float32)
                    have = have.copy()
                    ck[:npfx] = pblk.host_k[:npfx]
                    cv[:npfx] = pblk.host_v[:npfx]
                    have[:npfx] = True
                    pos_parts.append(np.arange(npfx))
                    slot_parts.append(pblk.slots[:npfx])
                else:
                    pending_prefix = key
            # --- item tier: offline bytes exist now, so misses insert
            # at admission (later arrivals hit them; this request keeps
            # its own private rows — the bytes are identical either way)
            for ref in reuse.blocks:
                blk = store.acquire(ref.key)
                if blk is None and ref.k is not None:
                    blk = store.insert(
                        ref.key,
                        BS.ITEM_TIER,
                        ref.k,
                        ref.v,
                        tokens=ref.tokens,
                        defer_write=True,
                    )
                    if blk is not None:
                        blk.refcount += 1
                if blk is not None:
                    held.append(ref.key)
                    pos_parts.append(ref.positions)
                    slot_parts.append(blk.slots[ref.offsets])
            # --- user tier (fresh bytes needed: miss inserts at finalize)
            if reuse.user_key is not None:
                u_pos = BS.user_reuse_positions(plan, r.have, reuse.prefix_end)
                if len(u_pos):
                    ublk = store.acquire(reuse.user_key)
                    if ublk is not None:
                        held.append(reuse.user_key)
                        common = np.intersect1d(u_pos, ublk.positions)
                        pos_parts.append(common)
                        slot_parts.append(
                            ublk.slots[np.searchsorted(ublk.positions, common)]
                        )
                    else:
                        pending_user = (reuse.user_key, u_pos)
        mapped_pos = np.concatenate(pos_parts) if pos_parts else np.zeros(0, np.int64)
        mapped_slots = (
            np.concatenate(slot_parts) if slot_parts else np.zeros(0, np.int64)
        )
        # claim the full admission bound: the pages actually needed now,
        # plus spare headroom covering the worst-case finalize remap
        bound, _ = self.admission_pages(r)
        total_slots = self.pool.pages_for(n + r.n_reserve) * self.pool.page_size
        n_priv = max(total_slots - len(mapped_pos), 0)
        begin_need = -(-n_priv // self.pool.page_size)
        extra = max(bound - begin_need, 0)
        if store is not None and self.pool.free_pages < begin_need + extra:
            store.evict_for(begin_need + extra)
        try:
            self.pool.alloc_mapped(
                r.rid, n + r.n_reserve, mapped_pos, mapped_slots,
                extra_pages=extra,
            )
        except PoolExhausted:
            if store is not None:
                store.release_all(held)
            raise
        if store is not None:
            self.store_refs[r.rid] = held
        mapped_mask = np.zeros(n, bool)
        mapped_mask[mapped_pos[mapped_pos < n].astype(np.int64)] = True
        cp = ENG.ChunkedPrefill(
            self.params, self.cfg, plan, ck, cv, have, self.sel,
            chunk_tokens=self.chunk_tokens, bucket=self.bucket,
        )
        self.prefill_states[r.rid] = PrefillState(
            req=r,
            cp=cp,
            mapped_mask=mapped_mask,
            pending_prefix=pending_prefix,
            pending_user=pending_user,
        )

    def abort_prefill(self, rid: int) -> None:
        """Roll back a mid-prefill preemption: drop the chunk state and
        release pages + store refs.  The caller keeps the plan, so the
        victim can re-prefill from scratch (greedy decode regenerates
        the same tokens)."""
        self.prefill_states.pop(rid, None)
        self.release(rid)

    # ------------------------------ migration ------------------------------
    def export_request_kv(self, rid: int) -> RequestKV:
        """Snapshot one request (finished OR chunk-partial prefill) as a
        `RequestKV` handoff record.  Read-only: the source engine keeps
        serving the request until the destination's import succeeds,
        after which the caller evacuates it here (`abort_prefill` /
        `release`)."""
        export = self.pool.export_request(rid)
        held = list(self.store_refs.get(rid, []))
        payloads: Dict[tuple, BS.BlockPayload] = {}
        if self.store is not None:
            for key in held:
                if key not in payloads:
                    payload = self.store.export_payload(key)
                    if payload is not None:
                        payloads[key] = payload
        return RequestKV(
            rid=rid,
            export=export,
            held=held,
            payloads=payloads,
            stats=self.last_stats.get(rid),
            prefill=self.prefill_states.get(rid),
        )

    def import_request_kv(self, rec: RequestKV) -> Dict[str, int]:
        """Materialize a migrated request in THIS engine without any
        recompute.

        Store payloads resolve first (digest hit -> zero bytes moved;
        miss -> insert under the original key; budget refusal -> the
        referenced rows are privatized into fresh pages), building the
        shared-slot translation map the pool import needs.  Transactional:
        a `PoolExhausted` anywhere rolls back every page and store
        reference this call took, so the caller can retry on another
        worker and `check_partition` holds on both sides either way.

        -> counters: pages/bytes moved, digest fast-path hits.
        """
        rid, export = rec.rid, rec.export
        store = self.store
        fmap: Dict[int, int] = {}
        held_new: List[tuple] = []
        raw_pages: List[int] = []
        refused: Dict[tuple, BS.BlockPayload] = {}
        counters = {
            "pages": export.n_pages,
            "bytes": export.nbytes,
            "digest_hits": 0,
        }
        foreign = set(
            int(s) for s in export.foreign_slots[export.owner_page < 0]
        )
        priv_old: set = set()
        try:
            if store is not None:
                seen: set = set()
                for key in rec.held:
                    payload = rec.payloads.get(key)
                    if payload is None:
                        continue
                    if key in refused:
                        continue
                    blk, hit = store.import_payload(
                        payload, keep_free=export.n_pages
                    )
                    if blk is None:
                        refused[key] = payload
                        continue
                    held_new.append(key)
                    if key in seen:
                        continue
                    seen.add(key)
                    if hit:
                        counters["digest_hits"] += 1
                    else:
                        counters["bytes"] += payload.nbytes
                    for old, new in zip(payload.slots, blk.slots):
                        fmap[int(old)] = int(new)
                # budget-refused payloads: privatize the rows the slot
                # table actually references (fresh pages owned by the
                # request; the bytes travel like a payload miss)
                for payload in refused.values():
                    rows = [
                        i
                        for i, s in enumerate(payload.slots)
                        if int(s) in foreign and int(s) not in fmap
                    ]
                    if not rows:
                        continue
                    pages = self.pool.alloc_pages(
                        self.pool.pages_for(len(rows))
                    )
                    raw_pages.extend(pages)
                    slots = self.pool.page_slots(pages)[: len(rows)]
                    for i, s in zip(rows, slots):
                        fmap[int(payload.slots[i])] = int(s)
                        priv_old.add(int(payload.slots[i]))
                    self.pool.write_slots(
                        slots, payload.host_k[rows], payload.host_v[rows]
                    )
                    counters["bytes"] += (
                        payload.host_k[rows].nbytes
                        + payload.host_v[rows].nbytes
                    )
                    counters["pages"] += len(pages)
            self.pool.import_request(export, fmap)
        except PoolExhausted:
            if raw_pages:
                self.pool.release_pages(raw_pages)
            if store is not None:
                store.release_all(held_new)
            raise
        if raw_pages:
            self.pool.page_tables[rid].extend(raw_pages)
        if store is not None:
            self.store_refs[rid] = held_new
            store.flush_writes()
        if rec.stats is not None:
            self.last_stats[rid] = rec.stats
        if rec.prefill is not None:
            st = rec.prefill
            if priv_old:
                # privatized positions are no longer store-mapped: clear
                # the mask so finalize writes (not remaps) them
                for pos in np.where(export.owner_page < 0)[0]:
                    if (
                        int(export.foreign_slots[pos]) in priv_old
                        and pos < len(st.mapped_mask)
                    ):
                        st.mapped_mask[pos] = False
            self.prefill_states[rid] = st
        return counters

    def _finalize_store(self, st: PrefillState, k_all, v_all, rec) -> np.ndarray:
        """Store bookkeeping for one finalizing request: insert the
        fresh-byte tiers whose keys missed at admission, then un-share
        every mapped position Eq. 3 selected for recomputation (its
        fresh KV must land privately — writing through the shared slot
        would corrupt the store's block).  -> remapped positions."""
        store, r = self.store, st.req
        n = st.cp.n
        reuse = r.reuse if r.reuse is not None else BS.RequestReuse()
        held = self.store_refs.setdefault(r.rid, [])
        if st.pending_prefix is not None:
            npfx = min(reuse.prefix_len, n)
            pblk = store.insert(
                st.pending_prefix,
                BS.PREFIX_TIER,
                k_all[:npfx],
                v_all[:npfx],
                pinned=True,
                defer_write=True,
            )
            if pblk is not None:
                pblk.refcount += 1
                held.append(st.pending_prefix)
        if st.pending_user is not None:
            ukey, u_pos = st.pending_user
            ku = np.concatenate([k_all[u_pos, :1], r.cached_k[u_pos, 1:]], axis=1)
            vu = np.concatenate([v_all[u_pos, :1], r.cached_v[u_pos, 1:]], axis=1)
            ublk = store.insert(
                ukey,
                BS.USER_TIER,
                ku,
                vu,
                positions=u_pos,
                pinned=True,
                defer_write=True,
            )
            if ublk is not None:
                ublk.refcount += 1
                held.append(ukey)
        remap = np.where(st.mapped_mask & rec)[0]
        self.pool.remap_private(r.rid, remap)
        st.mapped_mask[remap] = False
        return remap

    def finalize_prefill(self, rids: Sequence[int]) -> Dict[int, np.ndarray]:
        """Selective layers + pool insertion for fully-scanned requests.

        One bucketed batched dispatch (`engine.selective_layers_batch`
        — the same kernel the wave path uses, so chunked and monolithic
        prefill decode bitwise-identical tokens), then one fused
        deep-layer pool scatter for the whole batch; the layer-0 plane
        already landed incrementally as chunks completed.
        """
        states = [self.prefill_states[rid] for rid in rids]
        sel_out = ENG.selective_layers_batch(
            self.params, self.cfg, [st.cp.sel_item() for st in states]
        )
        out: Dict[int, np.ndarray] = {}
        entries_deep, entries_l0 = [], []
        for st, (logits, k_rest, v_rest) in zip(states, sel_out):
            r, cp = st.req, st.cp
            n = cp.n
            stats = cp.stats
            self.last_stats[r.rid] = stats
            k_all = np.concatenate([cp.k0_full()[:, None], k_rest[:n]], axis=1)
            v_all = np.concatenate([cp.v0_full()[:, None], v_rest[:n]], axis=1)
            rec = stats.recompute_mask
            for positions, k0, v0 in st.l0_buf:  # lazy-mode chunk rows
                entries_l0.append((r.rid, positions, k0, v0))
            if self.store is not None:
                remap = self._finalize_store(st, k_all, v_all, rec)
                if len(remap):
                    # un-shared positions never got the incremental
                    # layer-0 write (they were mapped then) — their
                    # fresh plane lands with the finalize scatter
                    entries_l0.append((r.rid, remap, k_all[remap, 0], v_all[remap, 0]))
            pos, kw, vw = self._selective_rows(r, stats, k_all, v_all)
            keep = ~st.mapped_mask[pos]
            entries_deep.append((r.rid, pos[keep], kw[keep][:, 1:], vw[keep][:, 1:]))
            out[r.rid] = logits
            del self.prefill_states[r.rid]
        if self.store is not None:
            self.store.flush_writes()
        self.pool.write_at_batch(entries_deep, deep=True)
        self.pool.write_at_batch(entries_l0, layer=0)
        return out

    def step(
        self,
        budget: int,
        decode_rids: Sequence[int],
        decode_tokens: Sequence[int],
        prefill_rids: Sequence[int],
    ) -> StepReport:
        """One unified serving tick under a global token budget.

        Decode always runs first (one token per running request — and
        first so a `PoolExhausted` preemption can retry before any
        prefill work executes); the remaining budget packs prefill work
        over `prefill_rids` in admission order: requests whose scan is
        complete finalize (charged their padded recompute budget),
        everyone else gets layer-0 chunks round-robin — one chunk per
        request per cycle, so a short prompt admitted behind a long one
        finishes scanning in proportion to its own length instead of
        waiting out the long scan (the head-of-line fix).  When nothing
        fits the remaining budget, the single head work item runs
        anyway (`oversized` tick) — an indivisible selective finalize
        can exceed any fixed budget and must not starve.
        """
        rep = StepReport()
        if self.store is not None:
            # drain router-hinted spill promotions (budgeted demand-swap:
            # LRU refcount-0 victims demote to the spill tier to make
            # room; a no-op unless store.prefetch_pages_per_tick>0),
            # then land their deferred writes with this tick's flush
            self.store.prefetch()
            self.store.flush_writes()
        if decode_rids:
            rep.decode_logits = self.decode(decode_rids, decode_tokens)
            rep.charge_decode = len(decode_rids)
        left = budget - rep.charge_decode
        active = [rid for rid in prefill_rids if rid in self.prefill_states]
        packed = False
        finalize: List[int] = []
        l0_entries: List[tuple] = []

        def try_finalize(rid) -> None:
            nonlocal left, packed
            fc = self.prefill_states[rid].cp.finalize_charge()
            if fc <= left or (not packed and not decode_rids):
                if fc > left:
                    rep.oversized = True
                finalize.append(rid)
                rep.charge_finalize += fc
                left -= fc
                packed = True

        # pass 1 (admission order): fully-scanned requests finalize first
        for rid in active:
            if self.prefill_states[rid].cp.scan_done:
                try_finalize(rid)
        # pass 2: round-robin chunks; a request finishing its scan gets
        # to finalize in the same tick if the budget still allows
        progress = True
        while progress:
            progress = False
            for rid in active:
                st = self.prefill_states[rid]
                if st.cp.scan_done:
                    continue
                c = st.cp.next_chunk_tokens()
                if c > left and (packed or decode_rids):
                    continue
                if c > left:
                    rep.oversized = True
                positions, k0, v0 = st.cp.run_chunk()
                keep = ~st.mapped_mask[positions]
                if self.eager_kv_writes:
                    l0_entries.append((rid, positions[keep], k0[keep], v0[keep]))
                else:
                    st.l0_buf.append((positions[keep], k0[keep], v0[keep]))
                rep.charge_chunks += c
                left -= c
                packed = True
                progress = True
                if not st.started:
                    st.started = True
                    rep.started.append(rid)
                if rid not in rep.chunked:
                    rep.chunked.append(rid)
                if st.cp.scan_done and rid not in finalize:
                    try_finalize(rid)
        self.pool.write_at_batch(l0_entries, layer=0)
        if finalize:
            rep.finalized = self.finalize_prefill(finalize)
        return rep

    # ------------------------------- decode --------------------------------
    def decode(self, rids: Sequence[int], last_tokens: Sequence[int]) -> np.ndarray:
        """One token for each running request.  -> logits (N, V)."""
        n = len(rids)
        n_pad = -(-n // self.decode_bucket) * self.decode_bucket
        tables, lens = self.pool.batch_tables(rids)
        pages, slots = self.pool.append_slots(rids)
        toks = np.zeros(n_pad, np.int32)
        toks[:n] = np.asarray(last_tokens, np.int32)
        tables_p = np.zeros((n_pad, tables.shape[1]), np.int32)
        tables_p[:n] = tables
        lens_p = np.zeros(n_pad, np.int32)
        lens_p[:n] = lens
        pages_p = np.zeros(n_pad, np.int32)  # pad rows: scratch page 0
        slots_p = np.zeros(n_pad, np.int32)
        pages_p[:n], slots_p[:n] = pages, slots
        if ENG.decode_uses_paged(self.cfg):
            pg_ids, sl_pos = page_views(
                tables_p, lens_p, pages_p, slots_p, self.pool.page_size
            )
        else:
            # dead inputs on the gather path; keep them tiny and
            # shape-stable so they never force a retrace
            pg_ids = np.zeros((n_pad, 1), np.int32)
            sl_pos = np.full((n_pad, 1, self.pool.page_size), -1, np.int32)
        logits, ak, av = _jit_decode_step(
            self.params,
            jnp.asarray(toks),
            jnp.asarray(tables_p),
            jnp.asarray(lens_p),
            jnp.asarray(pages_p),
            jnp.asarray(slots_p),
            jnp.asarray(pg_ids),
            jnp.asarray(sl_pos),
            self.pool.arena_k,
            self.pool.arena_v,
            self.cfg,
        )
        self.pool.update_arenas(ak, av)
        return np.asarray(logits, np.float32)[:n]

    def release(self, rid: int) -> None:
        """Free a request's private pages and drop its shared-block
        references.  Idempotent — releasing an unknown or already-freed
        rid is a no-op (a duplicate `finish()` must not crash the loop)."""
        self.pool.free(rid)
        if self.store is not None:
            self.store.release_all(self.store_refs.pop(rid, []))
        self.last_stats.pop(rid, None)
