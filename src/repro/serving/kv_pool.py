"""Paged KV cache pool for the batched serving path (vLLM-style).

One preallocated device arena holds every request's per-layer KV in
fixed-size pages; a free-list allocator hands pages to requests and a
per-request **slot table** maps logical token positions to physical
slots (page * page_size + in-page slot).  K and V are stored
**pre-RoPE** — the same convention as the item / semantic cache pools —
so a page written from an assembled cache block needs no rewrite, and
decode realigns keys to their request positions with one rotation
(RoPE's group property, §III-C3).

Slot tables are what make **cross-request sharing** possible: a page can
be owned by the `serving.block_store.SharedBlockStore` instead of a
request, and any request may point slot-table entries at the store's
slots at *any* logical alignment (block content never has to land
page-aligned).  Private pages are packed densely: a request's private
slots need not sit at their logical positions.  Allocation stays
page-granular — every page is owned by exactly one of {free list, one
request's `page_tables` entry, the block store} — and `pages_for` keeps
one capacity formula for both the reuse and no-reuse paths so decode
shapes (and therefore decoded tokens) are identical either way.

Insertion is block-granular: `write_plan` walks the assembly plan's
contiguous spans (`core.assembly.plan_spans`) and fuses every cached
block's run into one scatter; the selective engine merges the
recomputed tokens' fresh KV host-side and inserts whole *batches* with
`write_at_batch` — one arena update per batch instead of one per span.

Host-side writes use eager ``.at[].set`` (a full-arena copy per call on
CPU, which is why fusing matters); the decode hot loop instead threads
the arenas through the jitted decode step (`serving.batch_engine`) and
installs the returned buffers, so the new tokens' KV lands in-step (the
arenas are donated on TPU/GPU, making the update in-place; CPU lacks
donation and copies).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import LMConfig
from repro.core.assembly import RECOMPUTE, AssemblyPlan, plan_spans


class PoolExhausted(RuntimeError):
    """No free pages left — caller should defer admission (backpressure)."""


# Arena scatters are eager XLA ops compiled per *shape*: without
# padding, every distinct row count a batch composition produces
# triggers a fresh ~100ms scatter compile — composition is wall-clock
# sensitive, so steady-state serving would keep recompiling.  Padding
# the fused scatters to row-count buckets caps that at O(log) compiles.
# Pad rows target the scratch page (0, 0) with zero values: duplicates
# in one scatter are only ever these identical zero writes, and the
# scratch page is never read.
WRITE_ROW_BUCKET = 512


def _pad_scatter(pages, slots, k, v):
    t = len(pages)
    t_pad = -(-max(t, 1) // WRITE_ROW_BUCKET) * WRITE_ROW_BUCKET
    if t_pad == t:
        return pages, slots, k, v
    extra = t_pad - t
    pages = np.concatenate([pages, np.zeros(extra, pages.dtype)])
    slots = np.concatenate([slots, np.zeros(extra, slots.dtype)])
    zrow = np.zeros((extra,) + k.shape[1:], k.dtype)
    return pages, slots, np.concatenate([k, zrow]), np.concatenate([v, zrow])


@dataclass(frozen=True)
class PoolStats:
    n_pages: int
    page_size: int
    pages_in_use: int
    n_requests: int
    tokens_resident: int

    @property
    def utilization(self) -> float:
        return self.pages_in_use / max(self.n_pages, 1)

    @property
    def internal_fragmentation(self) -> float:
        """Fraction of allocated slots holding no token."""
        cap = self.pages_in_use * self.page_size
        return 1.0 - self.tokens_resident / max(cap, 1)


@dataclass(frozen=True)
class KVExport:
    """One request's pool state as a self-contained host-side record —
    the page-granular unit of KV migration between workers.

    The slot table is stored page-relatively: private entries carry an
    (index into the exported pages, in-page offset) pair so they can be
    rebound to whatever pages the destination pool hands out;
    store-shared entries (`owner_page == -1`) carry the SOURCE pool's
    physical slot id in `foreign_slots` and must be translated by the
    importer through a source-slot -> destination-slot map (built from
    the destination store's blocks).  `page_k`/`page_v` are the private
    pages' full bytes, (P, page_size, L, Hkv, Dh) pre-RoPE — unused
    slots ride along so the import is one fused scatter and the
    round-trip is bitwise.
    """

    rid: int
    seq_len: int
    page_size: int
    owner_page: np.ndarray     # (n_slots,) exported-page index, -1=shared
    owner_off: np.ndarray      # (n_slots,) in-page offset where owned
    foreign_slots: np.ndarray  # (n_slots,) source slot id where shared
    spare_page: np.ndarray     # (n_spare,) exported-page index
    spare_off: np.ndarray      # (n_spare,)
    page_k: np.ndarray         # (P, page_size, L, Hkv, Dh)
    page_v: np.ndarray

    @property
    def n_pages(self) -> int:
        return self.page_k.shape[0]

    @property
    def nbytes(self) -> int:
        """Private-page payload bytes (the part migration must move)."""
        return self.page_k.nbytes + self.page_v.nbytes


class PagedKVPool:
    """Fixed-page KV arena + free-list allocator + per-request slot tables.

    Arena layout: (n_pages, page_size, n_layers, n_kv_heads, head_dim)
    for K and V separately, dtype float32 (pre-RoPE values).
    """

    def __init__(self, n_layers: int, n_kv_heads: int, head_dim: int,
                 page_size: int = 16, n_pages: int = 512,
                 dtype: str = "float32", mesh=None):
        self.page_size = int(page_size)
        self.n_pages = int(n_pages)
        self.n_layers = n_layers
        self.mesh = mesh
        shape = (self.n_pages, self.page_size, n_layers, n_kv_heads, head_dim)
        arena_k = jnp.zeros(shape, jnp.dtype(dtype))
        arena_v = jnp.zeros(shape, jnp.dtype(dtype))
        if mesh is not None:
            # per-device arena planes: each device holds every page but
            # only its slice of the kv-head axis (the wk/wv head split).
            # Slot tables and page bookkeeping below stay host-side numpy
            # and device-agnostic; eager `.at[].set` scatters and decode
            # gathers on the placed arenas preserve this sharding, so no
            # write/read path changes
            from repro.sharding.specs import serving_arena_spec

            msz = dict(mesh.shape).get("model", 1)
            if n_kv_heads % msz:
                raise ValueError(
                    f"arena kv-head axis of {n_kv_heads} cannot shard over "
                    f"the mesh model axis of {msz} devices (mesh.tp={msz}): "
                    f"pick a tp dividing n_kv_heads")
            sharding = jax.sharding.NamedSharding(mesh, serving_arena_spec())
            arena_k = jax.device_put(arena_k, sharding)
            arena_v = jax.device_put(arena_v, sharding)
        self.arena_k = arena_k
        self.arena_v = arena_v
        # page 0 is reserved as scratch: padded decode-batch rows write
        # their dummy token there, and padded slot-table entries point at
        # it (reads are masked by seq_lens).  It is never allocated.
        self._free: List[int] = list(range(self.n_pages - 1, 0, -1))
        # private pages owned by each request (page-granular ownership)
        self.page_tables: Dict[int, List[int]] = {}
        # logical position -> physical slot, per request.  Entries may
        # point into private pages *or* store-owned shared pages.
        self.slot_tables: Dict[int, np.ndarray] = {}
        self.seq_lens: Dict[int, int] = {}
        # claimed-but-unassigned private slots, per request: the slack a
        # mapped allocation reserves so mid-prefill remaps (`remap_private`)
        # never have to race other requests for free pages
        self._spare: Dict[int, List[int]] = {}
        self.peak_pages = 0

    # ------------------------------ allocator ------------------------------
    def pages_for(self, n_tokens: int) -> int:
        return -(-max(n_tokens, 1) // self.page_size)

    @property
    def bytes_per_token(self) -> int:
        """fp32 K+V bytes one token row occupies across all layers —
        the unit spill-tier capacity and transfer modeling price in."""
        return int(
            2 * self.arena_k.dtype.itemsize * np.prod(self.arena_k.shape[2:])
        )

    @property
    def free_pages(self) -> int:
        return len(self._free)

    def can_admit(self, n_tokens: int) -> bool:
        return len(self._free) >= self.pages_for(n_tokens)

    def page_slots(self, pages: Sequence[int]) -> np.ndarray:
        """Physical slot ids covered by `pages`, in page order."""
        pages = np.asarray(pages, np.int64)
        return (pages[:, None] * self.page_size
                + np.arange(self.page_size)[None, :]).reshape(-1)

    def _bump_peak(self) -> None:
        self.peak_pages = max(self.peak_pages,
                              self.n_pages - 1 - len(self._free))

    def alloc_pages(self, n: int) -> List[int]:
        """Raw page grab with no request bookkeeping — the block store's
        allocation path.  The caller owns the pages until it hands them
        back through `release_pages`."""
        if n > len(self._free):
            raise PoolExhausted(f"need {n} pages, {len(self._free)} free")
        pages = [self._free.pop() for _ in range(n)]
        self._bump_peak()
        return pages

    def release_pages(self, pages: Sequence[int]) -> None:
        self._free.extend(pages)

    def alloc(self, rid: int, n_tokens: int) -> List[int]:
        """Reserve private pages for `n_tokens` slots; seq_len starts at 0."""
        if rid in self.page_tables:
            raise KeyError(f"request {rid} already allocated")
        need = self.pages_for(n_tokens)
        if need > len(self._free):
            raise PoolExhausted(
                f"need {need} pages, {len(self._free)} free")
        pages = [self._free.pop() for _ in range(need)]
        self.page_tables[rid] = pages
        self.slot_tables[rid] = self.page_slots(pages).astype(np.int64)
        self.seq_lens[rid] = 0
        self._bump_peak()
        return pages

    def alloc_mapped(self, rid: int, n_tokens: int,
                     mapped_positions: np.ndarray,
                     mapped_slots: np.ndarray,
                     extra_pages: int = 0) -> List[int]:
        """Reserve capacity for `n_tokens` slots with some logical
        positions pointing at *shared* physical slots (store-owned pages).

        Capacity is `pages_for(n_tokens) * page_size` slots — the same
        formula as `alloc` — but only the non-mapped slots consume
        private pages, packed densely (the last private page's unused
        slots are fragmentation, bounded by page_size - 1 per request).
        The shared slots are NOT owned by this request: `free` returns
        only the private pages, and the caller is responsible for the
        store-side refcounts.

        ``extra_pages`` claims additional private pages whose slots go
        to the request's spare list — headroom a chunk-resumable prefill
        reserves up front so `remap_private` (un-sharing positions the
        selective pass later decides to recompute) can never hit
        `PoolExhausted` mid-flight.
        """
        if rid in self.page_tables:
            raise KeyError(f"request {rid} already allocated")
        mapped_positions = np.asarray(mapped_positions, np.int64)
        mapped_slots = np.asarray(mapped_slots, np.int64)
        total_slots = self.pages_for(n_tokens) * self.page_size
        n_priv = total_slots - len(mapped_positions)
        need = -(-n_priv // self.page_size) if n_priv > 0 else 0
        need += max(int(extra_pages), 0)
        if need > len(self._free):
            raise PoolExhausted(
                f"need {need} pages, {len(self._free)} free")
        pages = [self._free.pop() for _ in range(need)]
        table = np.full(total_slots, -1, np.int64)
        table[mapped_positions] = mapped_slots
        all_slots = self.page_slots(pages)
        priv = all_slots[:max(n_priv, 0)]
        table[table < 0] = priv
        self.page_tables[rid] = pages
        self.slot_tables[rid] = table
        self._spare[rid] = list(all_slots[max(n_priv, 0):])
        self.seq_lens[rid] = (int(mapped_positions.max()) + 1
                              if len(mapped_positions) else 0)
        self._bump_peak()
        return pages

    def remap_private(self, rid: int, positions: np.ndarray) -> None:
        """Point store-mapped logical `positions` at this request's own
        private slots instead — the mid-prefill incremental append: a
        chunk-resumable prefill maps every store-resident position at
        admission, and un-shares the ones Eq. 3 selection later marks
        for recomputation (their fresh KV must land privately; writing
        through the shared slot would corrupt the store's block).

        Draws from the spare slots reserved at `alloc_mapped` first and
        only then claims new pages, so a request that reserved its
        admission bound as ``extra_pages`` can never fail here."""
        positions = np.asarray(positions, np.int64)
        if len(positions) == 0:
            return
        spare = self._spare.setdefault(rid, [])
        short = len(positions) - len(spare)
        if short > 0:
            n_new = -(-short // self.page_size)
            if n_new > len(self._free):
                raise PoolExhausted(
                    f"remap needs {n_new} pages, {len(self._free)} free")
            pages = [self._free.pop() for _ in range(n_new)]
            self.page_tables[rid].extend(pages)
            spare.extend(self.page_slots(pages))
            self._bump_peak()
        table = self.slot_tables[rid]
        table[positions] = [spare.pop(0) for _ in range(len(positions))]

    def free(self, rid: int) -> None:
        """Release a request's private pages.  Idempotent: freeing an
        unknown (or already-freed) rid is a no-op, so a duplicate
        `finish()` can never crash the batcher loop."""
        pages = self.page_tables.pop(rid, None)
        if pages is None:
            return
        self._free.extend(pages)
        self.slot_tables.pop(rid, None)
        self.seq_lens.pop(rid, None)
        self._spare.pop(rid, None)

    def stats(self) -> PoolStats:
        in_use = sum(len(t) for t in self.page_tables.values())
        return PoolStats(n_pages=self.n_pages, page_size=self.page_size,
                         pages_in_use=in_use,
                         n_requests=len(self.page_tables),
                         tokens_resident=sum(self.seq_lens.values()))

    # ------------------------------- writes --------------------------------
    def _phys(self, rid: int, positions: np.ndarray
              ) -> Tuple[np.ndarray, np.ndarray]:
        """Logical token slots -> (page ids, in-page slots), growing the
        slot table by one private page if a position lands past current
        capacity."""
        table = self.slot_tables[rid]
        top = int(positions.max())
        while top >= len(table):
            if not self._free:
                raise PoolExhausted("decode append: no free pages")
            page = self._free.pop()
            self.page_tables[rid].append(page)
            table = np.concatenate([table, self.page_slots([page])])
            self.slot_tables[rid] = table
            self._bump_peak()
        slots = table[positions]
        return ((slots // self.page_size).astype(np.int64),
                (slots % self.page_size).astype(np.int64))

    def write_at(self, rid: int, positions: np.ndarray,
                 k: np.ndarray, v: np.ndarray,
                 layer: Optional[int] = None) -> None:
        """Scatter pre-RoPE (k, v) into logical slots.

        k/v: (t, L, Hkv, Dh), or (t, Hkv, Dh) when `layer` selects a
        single layer plane (e.g. the always-fresh layer-0 KV from the
        selective engine).
        """
        self.write_at_batch([(rid, positions, k, v)], layer=layer)

    def write_at_batch(self, entries: Sequence[tuple],
                       layer: Optional[int] = None,
                       deep: bool = False) -> None:
        """Fused multi-request scatter: ONE arena update for any number
        of requests' writes.

        entries: sequence of (rid, positions, k, v).  Positions must be
        unique within an entry (duplicate physical slots across a single
        scatter have undefined write order under XLA).  Entries with no
        positions are skipped (a fully store-mapped request writes
        nothing).  Arena updates are eager copies on CPU (`.at[].set`),
        so fusing a batch's insertions into one scatter is what makes
        the batched prefill's pool insertion O(1) copies instead of
        O(requests · spans).

        ``deep`` writes only layer planes 1..L-1 from (t, L-1, ...) rows
        — the chunk-resumable prefill's finalize path, whose layer-0
        plane already landed incrementally as chunks completed.
        """
        pages_all, slots_all, ks, vs = [], [], [], []
        for rid, positions, k, v in entries:
            positions = np.asarray(positions, np.int64)
            if len(positions) == 0:
                continue
            pages, slots = self._phys(rid, positions)
            pages_all.append(pages)
            slots_all.append(slots)
            ks.append(np.asarray(k))
            vs.append(np.asarray(v))
            self.seq_lens[rid] = max(self.seq_lens[rid],
                                     int(positions.max()) + 1)
        if not pages_all:
            return
        pages = np.concatenate(pages_all)
        slots = np.concatenate(slots_all)
        k = np.concatenate(ks)
        v = np.concatenate(vs)
        pages, slots, k, v = _pad_scatter(pages, slots, k, v)
        if deep:
            self.arena_k = self.arena_k.at[pages, slots, 1:].set(k)
            self.arena_v = self.arena_v.at[pages, slots, 1:].set(v)
        elif layer is None:
            self.arena_k = self.arena_k.at[pages, slots].set(k)
            self.arena_v = self.arena_v.at[pages, slots].set(v)
        else:
            self.arena_k = self.arena_k.at[pages, slots, layer].set(k)
            self.arena_v = self.arena_v.at[pages, slots, layer].set(v)

    def write_slots(self, slot_ids: np.ndarray,
                    k: np.ndarray, v: np.ndarray) -> None:
        """Direct physical-slot scatter (no request bookkeeping) — the
        block store's insertion path.  k/v: (t, L, Hkv, Dh)."""
        self.write_slots_batch([(slot_ids, k, v)])

    def write_slots_batch(self, entries: Sequence[tuple]) -> None:
        """Fused multi-block physical-slot scatter: ONE arena update for
        any number of (slot_ids, k, v) writes.  Arena updates are eager
        full copies on CPU, so the store flushes a whole prefill batch's
        block insertions through here instead of paying one copy per
        block."""
        if not entries:
            return
        slot_ids = np.concatenate(
            [np.asarray(s, np.int64) for s, _, _ in entries])
        k = np.concatenate([np.asarray(k) for _, k, _ in entries])
        v = np.concatenate([np.asarray(v) for _, _, v in entries])
        pages = slot_ids // self.page_size
        slots = slot_ids % self.page_size
        pages, slots, k, v = _pad_scatter(pages, slots, k, v)
        self.arena_k = self.arena_k.at[pages, slots].set(k)
        self.arena_v = self.arena_v.at[pages, slots].set(v)

    def write_prompt(self, rid: int, k: np.ndarray, v: np.ndarray) -> None:
        """Insert a full prompt cache (n, L, Hkv, Dh) starting at slot 0."""
        self.write_at(rid, np.arange(k.shape[0]), k, v)

    def write_plan(self, rid: int, plan: AssemblyPlan,
                   cached_k: np.ndarray, cached_v: np.ndarray) -> int:
        """Block-granular insertion of an assembly plan's cached spans.

        cached_k/v: (n, L, Hkv, Dh) pre-RoPE as returned by
        `assembly.gather_cached_kv`.  RECOMPUTE spans are skipped (the
        engine scatters fresh KV there after the selective pass).
        -> number of tokens inserted from cache blocks.
        """
        pos_runs = [np.arange(s.start, s.end) for s in plan_spans(plan)
                    if s.source != RECOMPUTE]
        if not pos_runs:
            return 0
        # one fused scatter for all spans (each span is still one
        # contiguous block-granular run; fusing just avoids paying a
        # full-arena copy per span on CPU)
        pos = np.concatenate(pos_runs)
        self.write_at(rid, pos, cached_k[pos], cached_v[pos])
        return len(pos)

    def append_slots(self, rids: Sequence[int]
                     ) -> Tuple[np.ndarray, np.ndarray]:
        """Claim the next physical slot for each request's new decode token.

        Grows slot tables across page boundaries and bumps seq_lens; the
        actual KV write happens inside the jitted decode step (which owns
        the arena buffers).  -> (pages (N,), slots (N,)) int32.

        Transactional: if any request's growth hits `PoolExhausted`, every
        mutation this call already made (seq_len bumps, appended pages)
        is rolled back before the exception propagates, so the batcher
        can preempt a request and retry without leaked pages or
        phantom-length sequences.
        """
        pages = np.zeros(len(rids), np.int32)
        slots = np.zeros(len(rids), np.int32)
        done: List[tuple] = []          # (rid, n_pages_appended)
        try:
            for i, rid in enumerate(rids):
                before = len(self.page_tables[rid])
                pos = np.asarray([self.seq_lens[rid]])
                pg, sl = self._phys(rid, pos)
                pages[i], slots[i] = pg[0], sl[0]
                self.seq_lens[rid] += 1
                done.append((rid, len(self.page_tables[rid]) - before))
        except PoolExhausted:
            for rid, n_new in done:
                self.seq_lens[rid] -= 1
                for _ in range(n_new):
                    self._free.append(self.page_tables[rid].pop())
                    self.slot_tables[rid] = \
                        self.slot_tables[rid][:-self.page_size]
            raise
        return pages, slots

    def update_arenas(self, arena_k, arena_v) -> None:
        """Install arenas returned by the (donating) jitted decode step."""
        self.arena_k = arena_k
        self.arena_v = arena_v

    # ------------------------------ migration ------------------------------
    def export_request(self, rid: int) -> "KVExport":
        """Read-only snapshot of one request's pool state for migration.

        Captures the private pages' bytes (host readback), the slot
        table re-expressed page-relatively (private entries become
        (exported-page index, in-page offset) pairs; store-shared
        entries stay as source-pool physical slot ids the importer must
        translate), the spare-slot list and seq_len.  Nothing in the
        source pool is mutated — the caller frees the source side only
        after a successful `import_request` on the destination.
        """
        pages = self.page_tables[rid]
        index = {p: i for i, p in enumerate(pages)}
        table = self.slot_tables[rid]
        t_page = table // self.page_size
        t_off = table % self.page_size
        owner_page = np.asarray(
            [index.get(int(p), -1) for p in t_page], np.int64)
        owner_off = np.where(owner_page >= 0, t_off, 0).astype(np.int64)
        foreign_slots = np.where(owner_page < 0, table, -1).astype(np.int64)
        spare = np.asarray(self._spare.get(rid, []), np.int64)
        spare_page = np.asarray(
            [index[int(s) // self.page_size] for s in spare], np.int64)
        spare_off = (spare % self.page_size if len(spare)
                     else np.zeros(0, np.int64))
        page_idx = np.asarray(pages, np.int64)
        page_k = np.asarray(self.arena_k[page_idx], np.float32) \
            if len(pages) else np.zeros(
                (0,) + self.arena_k.shape[1:], np.float32)
        page_v = np.asarray(self.arena_v[page_idx], np.float32) \
            if len(pages) else np.zeros(
                (0,) + self.arena_v.shape[1:], np.float32)
        return KVExport(rid=rid, seq_len=self.seq_lens[rid],
                        page_size=self.page_size, owner_page=owner_page,
                        owner_off=owner_off, foreign_slots=foreign_slots,
                        spare_page=spare_page, spare_off=spare_off,
                        page_k=page_k, page_v=page_v)

    def import_request(self, export: "KVExport",
                       foreign_slot_map: Optional[Dict[int, int]] = None
                       ) -> List[int]:
        """Materialize an exported request in THIS pool.

        Allocates fresh private pages for every exported page, rewrites
        the slot table against them, lands the page bytes in one fused
        scatter and restores seq_len + spare slots.  Store-shared
        entries are translated through `foreign_slot_map` (source
        physical slot -> destination physical slot, built by the store
        layer from its own blocks).  Transactional: every failure path
        (`PoolExhausted`, an unmapped foreign slot, a duplicate rid) is
        checked before the first mutation, so a failed import leaves the
        destination pool untouched and `check_partition` holds on both
        pools either way.
        """
        rid = export.rid
        if export.page_size != self.page_size:
            raise ValueError(
                f"page_size mismatch: export {export.page_size}, "
                f"pool {self.page_size}")
        if rid in self.page_tables:
            raise KeyError(f"request {rid} already allocated")
        fmap = foreign_slot_map or {}
        foreign = export.foreign_slots[export.owner_page < 0]
        missing = [int(s) for s in foreign if int(s) not in fmap]
        if missing:
            raise KeyError(
                f"import of request {rid}: no destination mapping for "
                f"shared slots {missing[:4]}")
        need = export.n_pages
        if need > len(self._free):
            raise PoolExhausted(
                f"import needs {need} pages, {len(self._free)} free")
        pages = [self._free.pop() for _ in range(need)]
        page_arr = np.asarray(pages, np.int64)
        table = np.empty(len(export.owner_page), np.int64)
        owned = export.owner_page >= 0
        table[owned] = (page_arr[export.owner_page[owned]] * self.page_size
                        + export.owner_off[owned])
        table[~owned] = [fmap[int(s)] for s in export.foreign_slots[~owned]]
        self.page_tables[rid] = pages
        self.slot_tables[rid] = table
        self.seq_lens[rid] = export.seq_len
        self._spare[rid] = list(page_arr[export.spare_page] * self.page_size
                                + export.spare_off)
        if need:
            self.write_slots(self.page_slots(pages),
                             export.page_k.reshape(
                                 (-1,) + export.page_k.shape[2:]),
                             export.page_v.reshape(
                                 (-1,) + export.page_v.shape[2:]))
        self._bump_peak()
        return pages

    # -------------------------------- reads --------------------------------
    def seq_len(self, rid: int) -> int:
        return self.seq_lens[rid]

    def gather(self, rid: int) -> Tuple[np.ndarray, np.ndarray]:
        """Host-side readback of one request's (k, v): (S, L, Hkv, Dh)."""
        n = self.seq_lens[rid]
        sl = self.slot_tables[rid][:n]
        pages, slots = sl // self.page_size, sl % self.page_size
        k = np.asarray(self.arena_k[pages, slots])
        v = np.asarray(self.arena_v[pages, slots])
        return k, v

    def batch_tables(self, rids: Sequence[int], pad_pages_to: int = 4
                     ) -> Tuple[np.ndarray, np.ndarray]:
        """Padded slot-table batch for the jitted decode step.

        -> (tables (N, S) int32 physical slot ids, seq_lens (N,) int32).
        S is padded to a multiple of `pad_pages_to * page_size` slots to
        bound jit retraces; pad entries point at slot 0 (the scratch
        page) and are masked by seq_lens.
        """
        chunk = pad_pages_to * self.page_size
        max_s = max(len(self.slot_tables[r]) for r in rids)
        max_s = -(-max_s // chunk) * chunk
        tables = np.zeros((len(rids), max_s), np.int32)
        lens = np.zeros(len(rids), np.int32)
        for i, r in enumerate(rids):
            t = self.slot_tables[r]
            tables[i, :len(t)] = t
            lens[i] = self.seq_lens[r]
        return tables, lens


def page_views(tables: np.ndarray, lens: np.ndarray,
               new_pages: np.ndarray, new_slots: np.ndarray,
               page_size: int, pad_pages_to: int = 4
               ) -> Tuple[np.ndarray, np.ndarray]:
    """Page-granular decode views for the fused paged-attention kernel.

    Slot tables are slot-granular (a row may interleave private and
    store-shared slots at arbitrary alignment, store runs need not be
    page-aligned), so a classic per-request *block table* doesn't exist.
    What does exist: the set of physical pages a row touches, with each
    in-page slot tagged by the logical position it serves.  Attention is
    permutation-invariant over keys, so the kernel can stream pages in
    any order as long as every live slot carries its true position — the
    position drives both the RoPE realignment and the liveness mask.

    tables: (N, S) physical slot ids in logical order (`batch_tables`
    layout, pad entries masked by `lens`); lens: (N,) tokens resident
    before this step (= the new token's logical position);
    new_pages/new_slots: (N,) the physical slot claimed for this step's
    token (`append_slots`) — included in the view at position len, so
    the kernel reads the new token's KV from the arena the decode step
    just wrote, no concat needed.

    -> (page_ids (N, Pmax) int32, slot_pos (N, Pmax, page_size) int32):
    `page_ids[i, j]` is the j-th distinct physical page row i touches
    (first-appearance order); `slot_pos[i, j, t]` is the logical
    position slot t of that page serves for row i, or -1 when it serves
    none (other requests' tokens, pad slots).  Pmax is padded to a
    `pad_pages_to` multiple; pad columns reference the scratch page 0
    with all-(-1) positions.  A pad decode row (len 0, new slot at the
    scratch page) yields exactly one live slot, so its softmax is never
    empty.
    """
    tables = np.asarray(tables)
    n = tables.shape[0]
    lens = np.asarray(lens, np.int64)
    new_slot_ids = (np.asarray(new_pages, np.int64) * page_size
                    + np.asarray(new_slots, np.int64))
    per_row = []
    for i in range(n):
        ln = int(lens[i])
        slots = np.empty(ln + 1, np.int64)
        slots[:ln] = tables[i, :ln]
        slots[ln] = new_slot_ids[i]
        pages = slots // page_size
        offs = slots % page_size
        uniq, first, inv = np.unique(pages, return_index=True,
                                     return_inverse=True)
        order = np.argsort(first, kind="stable")
        rank = np.empty(len(uniq), np.int64)
        rank[order] = np.arange(len(uniq))
        spos = np.full((len(uniq), page_size), -1, np.int32)
        # distinct logical positions live in distinct physical slots, so
        # the (page-rank, offset) pairs are unique — no write collides
        spos[rank[inv], offs] = np.arange(ln + 1)
        per_row.append((uniq[order].astype(np.int32), spos))
    pmax = max(len(p) for p, _ in per_row)
    pmax = max(-(-pmax // pad_pages_to) * pad_pages_to, pad_pages_to)
    page_ids = np.zeros((n, pmax), np.int32)
    slot_pos = np.full((n, pmax, page_size), -1, np.int32)
    for i, (p, sp) in enumerate(per_row):
        page_ids[i, :len(p)] = p
        slot_pos[i, :len(p)] = sp
    return page_ids, slot_pos


def pool_for(cfg: LMConfig, page_size: int = 16, n_pages: int = 512,
             mesh=None) -> PagedKVPool:
    """Pool sized from a model config (serving launcher convenience).
    With `mesh`, the arenas are sharded over its model axis."""
    return PagedKVPool(cfg.n_layers, cfg.n_kv_heads, cfg.resolved_head_dim,
                       page_size=page_size, n_pages=n_pages, mesh=mesh)
