"""Paged KV cache pool for the batched serving path (vLLM-style).

One preallocated device arena holds every request's per-layer KV in
fixed-size pages; a free-list allocator hands pages to requests and a
per-request page table maps logical token slots to (page, slot) physical
locations.  K and V are stored **pre-RoPE** — the same convention as the
item / semantic cache pools — so a page written from an assembled cache
block needs no rewrite, and decode realigns keys to their request
positions with one rotation (RoPE's group property, §III-C3).

Insertion is block-granular: `write_plan` walks the assembly plan's
contiguous spans (`core.assembly.plan_spans`) and fuses every cached
block's run into one scatter; the selective engine merges the
recomputed tokens' fresh KV host-side and inserts whole *batches* with
`write_at_batch` — one arena update per batch instead of one per span.

Host-side writes use eager ``.at[].set`` (a full-arena copy per call on
CPU, which is why fusing matters); the decode hot loop instead threads
the arenas through the jitted decode step (`serving.batch_engine`) and
installs the returned buffers, so the new tokens' KV lands in-step (the
arenas are donated on TPU/GPU, making the update in-place; CPU lacks
donation and copies).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.configs.base import LMConfig
from repro.core.assembly import RECOMPUTE, AssemblyPlan, plan_spans


class PoolExhausted(RuntimeError):
    """No free pages left — caller should defer admission (backpressure)."""


@dataclass(frozen=True)
class PoolStats:
    n_pages: int
    page_size: int
    pages_in_use: int
    n_requests: int
    tokens_resident: int

    @property
    def utilization(self) -> float:
        return self.pages_in_use / max(self.n_pages, 1)

    @property
    def internal_fragmentation(self) -> float:
        """Fraction of allocated slots holding no token."""
        cap = self.pages_in_use * self.page_size
        return 1.0 - self.tokens_resident / max(cap, 1)


class PagedKVPool:
    """Fixed-page KV arena + free-list allocator + per-request page tables.

    Arena layout: (n_pages, page_size, n_layers, n_kv_heads, head_dim)
    for K and V separately, dtype float32 (pre-RoPE values).
    """

    def __init__(self, n_layers: int, n_kv_heads: int, head_dim: int,
                 page_size: int = 16, n_pages: int = 512,
                 dtype: str = "float32"):
        self.page_size = int(page_size)
        self.n_pages = int(n_pages)
        self.n_layers = n_layers
        shape = (self.n_pages, self.page_size, n_layers, n_kv_heads, head_dim)
        self.arena_k = jnp.zeros(shape, jnp.dtype(dtype))
        self.arena_v = jnp.zeros(shape, jnp.dtype(dtype))
        # page 0 is reserved as scratch: padded decode-batch rows write
        # their dummy token there, and padded page-table entries point at
        # it (reads are masked by seq_lens).  It is never allocated.
        self._free: List[int] = list(range(self.n_pages - 1, 0, -1))
        self.page_tables: Dict[int, List[int]] = {}
        self.seq_lens: Dict[int, int] = {}
        self.peak_pages = 0

    # ------------------------------ allocator ------------------------------
    def pages_for(self, n_tokens: int) -> int:
        return -(-max(n_tokens, 1) // self.page_size)

    @property
    def free_pages(self) -> int:
        return len(self._free)

    def can_admit(self, n_tokens: int) -> bool:
        return len(self._free) >= self.pages_for(n_tokens)

    def alloc(self, rid: int, n_tokens: int) -> List[int]:
        """Reserve pages for `n_tokens` slots; seq_len starts at 0."""
        if rid in self.page_tables:
            raise KeyError(f"request {rid} already allocated")
        need = self.pages_for(n_tokens)
        if need > len(self._free):
            raise PoolExhausted(
                f"need {need} pages, {len(self._free)} free")
        pages = [self._free.pop() for _ in range(need)]
        self.page_tables[rid] = pages
        self.seq_lens[rid] = 0
        self.peak_pages = max(self.peak_pages,
                              self.n_pages - 1 - len(self._free))
        return pages

    def free(self, rid: int) -> None:
        for p in self.page_tables.pop(rid):
            self._free.append(p)
        del self.seq_lens[rid]

    def stats(self) -> PoolStats:
        in_use = sum(len(t) for t in self.page_tables.values())
        return PoolStats(n_pages=self.n_pages, page_size=self.page_size,
                         pages_in_use=in_use,
                         n_requests=len(self.page_tables),
                         tokens_resident=sum(self.seq_lens.values()))

    # ------------------------------- writes --------------------------------
    def _phys(self, rid: int, positions: np.ndarray
              ) -> Tuple[np.ndarray, np.ndarray]:
        """Logical token slots -> (page ids, in-page slots), growing the
        page table if a position lands past current capacity."""
        table = self.page_tables[rid]
        top = int(positions.max())
        while top >= len(table) * self.page_size:
            if not self._free:
                raise PoolExhausted("decode append: no free pages")
            table.append(self._free.pop())
            self.peak_pages = max(self.peak_pages,
                                  self.n_pages - 1 - len(self._free))
        pt = np.asarray(table, np.int32)
        return pt[positions // self.page_size], positions % self.page_size

    def write_at(self, rid: int, positions: np.ndarray,
                 k: np.ndarray, v: np.ndarray,
                 layer: Optional[int] = None) -> None:
        """Scatter pre-RoPE (k, v) into logical slots.

        k/v: (t, L, Hkv, Dh), or (t, Hkv, Dh) when `layer` selects a
        single layer plane (e.g. the always-fresh layer-0 KV from the
        selective engine).
        """
        self.write_at_batch([(rid, positions, k, v)], layer=layer)

    def write_at_batch(self, entries: Sequence[tuple],
                       layer: Optional[int] = None) -> None:
        """Fused multi-request scatter: ONE arena update for any number
        of requests' writes.

        entries: sequence of (rid, positions, k, v).  Positions must be
        unique within an entry (duplicate physical slots across a single
        scatter have undefined write order under XLA).  Arena updates
        are eager copies on CPU (`.at[].set`), so fusing a batch's
        insertions into one scatter is what makes the batched prefill's
        pool insertion O(1) copies instead of O(requests · spans).
        """
        pages_all, slots_all, ks, vs = [], [], [], []
        for rid, positions, k, v in entries:
            positions = np.asarray(positions, np.int64)
            pages, slots = self._phys(rid, positions)
            pages_all.append(pages)
            slots_all.append(slots)
            ks.append(np.asarray(k))
            vs.append(np.asarray(v))
            self.seq_lens[rid] = max(self.seq_lens[rid],
                                     int(positions.max()) + 1)
        pages = np.concatenate(pages_all)
        slots = np.concatenate(slots_all)
        k = np.concatenate(ks)
        v = np.concatenate(vs)
        if layer is None:
            self.arena_k = self.arena_k.at[pages, slots].set(k)
            self.arena_v = self.arena_v.at[pages, slots].set(v)
        else:
            self.arena_k = self.arena_k.at[pages, slots, layer].set(k)
            self.arena_v = self.arena_v.at[pages, slots, layer].set(v)

    def write_prompt(self, rid: int, k: np.ndarray, v: np.ndarray) -> None:
        """Insert a full prompt cache (n, L, Hkv, Dh) starting at slot 0."""
        self.write_at(rid, np.arange(k.shape[0]), k, v)

    def write_plan(self, rid: int, plan: AssemblyPlan,
                   cached_k: np.ndarray, cached_v: np.ndarray) -> int:
        """Block-granular insertion of an assembly plan's cached spans.

        cached_k/v: (n, L, Hkv, Dh) pre-RoPE as returned by
        `assembly.gather_cached_kv`.  RECOMPUTE spans are skipped (the
        engine scatters fresh KV there after the selective pass).
        -> number of tokens inserted from cache blocks.
        """
        pos_runs = [np.arange(s.start, s.end) for s in plan_spans(plan)
                    if s.source != RECOMPUTE]
        if not pos_runs:
            return 0
        # one fused scatter for all spans (each span is still one
        # contiguous block-granular run; fusing just avoids paying a
        # full-arena copy per span on CPU)
        pos = np.concatenate(pos_runs)
        self.write_at(rid, pos, cached_k[pos], cached_v[pos])
        return len(pos)

    def append_slots(self, rids: Sequence[int]
                     ) -> Tuple[np.ndarray, np.ndarray]:
        """Claim the next physical slot for each request's new decode token.

        Grows page tables across page boundaries and bumps seq_lens; the
        actual KV write happens inside the jitted decode step (which owns
        the arena buffers).  -> (pages (N,), slots (N,)) int32.
        """
        pages = np.zeros(len(rids), np.int32)
        slots = np.zeros(len(rids), np.int32)
        for i, rid in enumerate(rids):
            pos = np.asarray([self.seq_lens[rid]])
            pg, sl = self._phys(rid, pos)
            pages[i], slots[i] = pg[0], sl[0]
            self.seq_lens[rid] += 1
        return pages, slots

    def update_arenas(self, arena_k, arena_v) -> None:
        """Install arenas returned by the (donating) jitted decode step."""
        self.arena_k = arena_k
        self.arena_v = arena_v

    # -------------------------------- reads --------------------------------
    def seq_len(self, rid: int) -> int:
        return self.seq_lens[rid]

    def gather(self, rid: int) -> Tuple[np.ndarray, np.ndarray]:
        """Host-side readback of one request's (k, v): (S, L, Hkv, Dh)."""
        n = self.seq_lens[rid]
        pt = np.asarray(self.page_tables[rid], np.int32)
        k = np.asarray(self.arena_k[pt]).reshape(
            -1, *self.arena_k.shape[2:])[:n]
        v = np.asarray(self.arena_v[pt]).reshape(
            -1, *self.arena_v.shape[2:])[:n]
        return k, v

    def batch_tables(self, rids: Sequence[int], pad_pages_to: int = 4
                     ) -> Tuple[np.ndarray, np.ndarray]:
        """Padded page-table batch for the jitted decode step.

        -> (tables (N, P) int32, seq_lens (N,) int32).  P is padded to a
        multiple of `pad_pages_to` to bound jit retraces; pad entries
        point at page 0 and are masked by seq_lens.
        """
        max_p = max(len(self.page_tables[r]) for r in rids)
        max_p = -(-max_p // pad_pages_to) * pad_pages_to
        tables = np.zeros((len(rids), max_p), np.int32)
        lens = np.zeros(len(rids), np.int32)
        for i, r in enumerate(rids):
            t = self.page_tables[r]
            tables[i, :len(t)] = t
            lens[i] = self.seq_lens[r]
        return tables, lens


def pool_for(cfg: LMConfig, page_size: int = 16, n_pages: int = 512
             ) -> PagedKVPool:
    """Pool sized from a model config (serving launcher convenience)."""
    return PagedKVPool(cfg.n_layers, cfg.n_kv_heads, cfg.resolved_head_dim,
                       page_size=page_size, n_pages=n_pages)
