"""Host-side data pipeline: sharded, prefetching batch iterator.

Each data-parallel host feeds only its slice of the global batch (per-host
batch = global / n_hosts); a background thread keeps `prefetch` batches
ready so step time is never input-bound.
"""
from __future__ import annotations

import queue
import threading
from typing import Callable, Dict, Iterator

import numpy as np


class BatchPipeline:
    def __init__(self, make_batch: Callable[[np.random.Generator], Dict],
                 seed: int = 0, prefetch: int = 2,
                 host_index: int = 0, n_hosts: int = 1):
        self.make_batch = make_batch
        self.rng = np.random.default_rng(seed + host_index * 9973)
        self.host_index = host_index
        self.n_hosts = n_hosts
        self._q: "queue.Queue" = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        while not self._stop.is_set():
            batch = self.make_batch(self.rng)
            if self.n_hosts > 1:
                batch = {k: self._host_slice(v) for k, v in batch.items()}
            try:
                self._q.put(batch, timeout=0.5)
            except queue.Full:
                continue

    def _host_slice(self, arr: np.ndarray) -> np.ndarray:
        per = arr.shape[0] // self.n_hosts
        lo = self.host_index * per
        return arr[lo:lo + per]

    def __iter__(self) -> Iterator[Dict]:
        return self

    def __next__(self) -> Dict:
        return self._q.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2)


def lm_synthetic_batches(vocab_size: int, batch: int, seq: int):
    """Synthetic LM token stream (shifted-label causal LM)."""
    def make(rng: np.random.Generator) -> Dict:
        toks = rng.integers(1, vocab_size, (batch, seq + 1), dtype=np.int64)
        return {"tokens": toks[:, :-1].astype(np.int32),
                "labels": toks[:, 1:].astype(np.int32)}
    return make
