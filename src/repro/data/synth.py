"""Synthetic recommendation corpus generators.

Shaped to the paper's published statistics (no raw Amazon/Yelp/Goodreads
offline): item token lengths ~87/76/124 (§III-B), Zipf popularity (Fig. 5),
co-occurrence clusters ("books in a series"), reviews drawn from a limited
semantic phrase pool (Insight 1: >93% of history tokens have a near-identical
match in a static pool), 207-token shared system prompt, median prefill
2.2–3.0K tokens with items 66–82% / history 11–26% of the mass (§IV-B).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional

import numpy as np

# token-id layout for the synthetic vocabulary
PAD, BOS, ITEM_SEP, REVIEW_SEP, RANK_QUERY = 0, 1, 2, 3, 4
SLOT_BASE = 8                 # slot tokens 8..8+64: "answer = candidate #k"
N_SLOTS = 64
N_SPECIAL = SLOT_BASE + N_SLOTS


@dataclass
class Catalog:
    n_items: int
    item_tokens: List[np.ndarray]          # per-item token arrays (immutable)
    popularity: np.ndarray                 # unnormalized access frequency
    cluster_of: np.ndarray                 # co-occurrence cluster id per item
    vocab_size: int

    def item_len(self, i: int) -> int:
        return len(self.item_tokens[i])


@dataclass
class DatasetProfile:
    name: str
    mean_item_tokens: int
    mean_review_tokens: int
    n_items: int
    n_clusters: int
    zipf_a: float = 1.1


PROFILES = {
    "amazon": DatasetProfile("amazon", 87, 80, 20000, 400),
    "yelp": DatasetProfile("yelp", 76, 178, 15000, 300),
    "goodreads": DatasetProfile("goodreads", 124, 95, 18000, 350),
}


def make_catalog(profile: DatasetProfile, vocab_size: int = 8192,
                 seed: int = 0) -> Catalog:
    rng = np.random.default_rng(seed)
    n = profile.n_items
    # each cluster shares a token sub-pool: co-occurring items look alike
    cluster_of = rng.integers(0, profile.n_clusters, n).astype(np.int32)
    lens = np.maximum(8, rng.poisson(profile.mean_item_tokens, n))
    # item tokens live in [N_SPECIAL, vocab/2); reviews own the top half
    item_region = vocab_size // 2 - N_SPECIAL
    pool_per_cluster = min(400, max(32, item_region // 8))
    items = []
    for i in range(n):
        base = N_SPECIAL + (cluster_of[i] * 37) % (item_region - pool_per_cluster)
        toks = base + rng.integers(0, pool_per_cluster, lens[i])
        items.append(toks.astype(np.int32))
    # Zipf popularity over a random item order
    ranks = rng.permutation(n) + 1
    popularity = 1.0 / ranks ** profile.zipf_a
    return Catalog(n_items=n, item_tokens=items, popularity=popularity,
                   cluster_of=cluster_of, vocab_size=vocab_size)


@dataclass
class ReviewPool:
    """Limited semantic phrase pool — reviews are concatenations of shared
    phrases (Insight 1: strong semantic locality in user histories)."""
    phrases: List[np.ndarray]
    sentiment_of: np.ndarray


def make_review_pool(vocab_size: int = 8192, n_phrases: int = 600,
                     seed: int = 1) -> ReviewPool:
    rng = np.random.default_rng(seed)
    phrases, sent = [], []
    band = (vocab_size - vocab_size // 2) // 5     # 5 sentiment bands
    for p in range(n_phrases):
        s = p % 5                                   # 1..5-star sentiment bands
        base = vocab_size // 2 + s * band
        ln = rng.integers(3, 9)
        phrases.append((base + rng.integers(0, max(band - 8, 8), ln))
                       .astype(np.int32))
        sent.append(s)
    return ReviewPool(phrases=phrases, sentiment_of=np.asarray(sent))


def make_review(pool: ReviewPool, mean_tokens: int,
                rng: np.random.Generator) -> np.ndarray:
    toks: List[np.ndarray] = []
    total = 0
    sentiment = rng.integers(0, 5)
    while total < mean_tokens:
        # 80% of phrases drawn from the matching sentiment band
        if rng.random() < 0.8:
            cands = np.where(pool.sentiment_of == sentiment)[0]
        else:
            cands = np.arange(len(pool.phrases))
        ph = pool.phrases[rng.choice(cands)]
        toks.append(ph)
        total += len(ph)
    return np.concatenate(toks)[:int(mean_tokens * 1.5)]


@dataclass
class Request:
    user_id: int
    history_tokens: np.ndarray             # review text (reusable, approx)
    history_marker_mask: np.ndarray        # True at instance-specific tokens
    candidate_items: np.ndarray            # item ids, permuted per request
    arrival_s: float = 0.0

    def prompt_segments(self, catalog: Catalog, instruction: np.ndarray):
        """-> (tokens, seg_kind, seg_id): seg_kind 0=instr 1=history 2=item,
        seg_id = item id for item tokens, -1 otherwise."""
        parts = [instruction]
        kinds = [np.zeros(len(instruction), np.int32)]
        ids = [np.full(len(instruction), -1, np.int32)]
        parts.append(self.history_tokens)
        kinds.append(np.ones(len(self.history_tokens), np.int32))
        ids.append(np.full(len(self.history_tokens), -1, np.int32))
        for slot, it in enumerate(self.candidate_items):
            # slot marker is request-specific (candidates are permuted) →
            # its own segment kind 0: always recomputed, never cached
            parts.append(np.asarray([SLOT_BASE + slot], np.int32))
            kinds.append(np.zeros(1, np.int32))
            ids.append(np.full(1, -1, np.int32))
            toks = np.concatenate([[ITEM_SEP], catalog.item_tokens[it]])
            parts.append(toks.astype(np.int32))
            kinds.append(np.full(len(toks), 2, np.int32))
            ids.append(np.full(len(toks), it, np.int32))
        tail = np.asarray([RANK_QUERY], np.int32)
        parts.append(tail)
        kinds.append(np.zeros(1, np.int32))
        ids.append(np.full(1, -1, np.int32))
        return (np.concatenate(parts), np.concatenate(kinds),
                np.concatenate(ids))


def make_instruction(n_tokens: int = 207, vocab_size: int = 8192,
                     seed: int = 7) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return np.concatenate([[BOS], N_SPECIAL +
                           rng.integers(0, 200, n_tokens - 1)]).astype(np.int32)


def sample_candidates(catalog: Catalog, n: int, rng: np.random.Generator,
                      cluster_bias: float = 0.7) -> np.ndarray:
    """Candidate sets exhibit co-occurrence: most candidates come from a few
    clusters (this is what similarity-aware placement exploits)."""
    p = catalog.popularity / catalog.popularity.sum()
    anchor = rng.choice(catalog.n_items, p=p)
    anchor_cluster = catalog.cluster_of[anchor]
    out = [anchor]
    while len(out) < n:
        if rng.random() < cluster_bias:
            same = np.where(catalog.cluster_of == anchor_cluster)[0]
            pick = rng.choice(same)
        else:
            pick = rng.choice(catalog.n_items, p=p)
        if pick not in out:
            out.append(int(pick))
    perm = rng.permutation(n)
    return np.asarray(out, np.int32)[perm]


def make_trace(catalog: Catalog, pool: ReviewPool, profile: DatasetProfile,
               n_requests: int, qps: float, n_users: int = 2000,
               n_candidates: int = 20, reviews_per_user: int = 3,
               seed: int = 2, cluster_bias: float = 0.7,
               user_zipf_a: Optional[float] = None,
               long_prompt_frac: float = 0.0,
               long_prompt_reviews: int = 8) -> List[Request]:
    """Synthetic request trace.  `user_zipf_a` switches user sampling
    from uniform to Zipfian (rank r drawn ∝ r^-a): a few heavy repeat
    users dominate the stream — the workload shape where cross-request
    user-history KV reuse pays (serving/workload.zipf_repeat_trace).

    `long_prompt_frac` adds a heavy prompt-length tail: that fraction of
    users carries a lognormal-distributed pile of extra reviews (mean
    `long_prompt_reviews`), so their requests arrive with prompts a few
    times longer than the base population — the long-sequence
    head-of-line interference shape the chunked unified-step scheduler
    targets (serving/workload.heavy_tail_trace).  The default 0.0 draws
    nothing extra from the rng, keeping every pre-existing trace
    byte-identical."""
    rng = np.random.default_rng(seed)
    p_user = None
    if user_zipf_a is not None:
        ranks = np.arange(1, n_users + 1, dtype=np.float64)
        p_user = ranks ** -float(user_zipf_a)
        p_user /= p_user.sum()
    # persistent per-user histories (re-appear across that user's requests)
    user_hist = {}
    for u in range(n_users):
        n_rev = reviews_per_user
        if long_prompt_frac and rng.random() < long_prompt_frac:
            n_rev += max(1, int(rng.lognormal(
                np.log(max(long_prompt_reviews, 1)), 0.5)))
        revs = []
        marks = []
        for _ in range(n_rev):
            r = make_review(pool, profile.mean_review_tokens, rng)
            m = np.zeros(len(r) + 1, bool)
            m[0] = True                       # REVIEW_SEP is instance-specific
            revs.append(np.concatenate([[REVIEW_SEP], r]).astype(np.int32))
            marks.append(m)
        user_hist[u] = (np.concatenate(revs), np.concatenate(marks))

    t = 0.0
    reqs = []
    for _ in range(n_requests):
        t += rng.exponential(1.0 / qps)
        if p_user is None:
            u = int(rng.integers(0, n_users))
        else:
            u = int(rng.choice(n_users, p=p_user))
        hist, mark = user_hist[u]
        reqs.append(Request(
            user_id=u, history_tokens=hist, history_marker_mask=mark,
            candidate_items=sample_candidates(catalog, n_candidates, rng,
                                              cluster_bias=cluster_bias),
            arrival_s=t))
    return reqs
