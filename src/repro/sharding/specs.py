"""Per-family sharding rules: params, optimizer state, inputs, KV caches.

Rules are expressed as PartitionSpec trees matching the param structures in
repro.models / repro.recsys / repro.gnn.  See DESIGN.md §5 for the rationale
per tensor.  These are the *baseline* layouts; §Perf hillclimbs mutate them.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import LMConfig, RecsysConfig
from repro.launch.mesh import axis_size, data_axes


def _ns(mesh, spec):
    return NamedSharding(mesh, spec)


def tree_shardings(mesh, spec_tree):
    return jax.tree_util.tree_map(
        lambda s: _ns(mesh, s), spec_tree, is_leaf=lambda x: isinstance(x, P)
    )


# ---------------------------------------------------------------------------
# LM family
# ---------------------------------------------------------------------------

def lm_param_specs(cfg: LMConfig, mesh, *, mode: str = "train") -> Dict[str, Any]:
    """PartitionSpec tree matching transformer.init_params structure.

    `mode='serve'` additionally shards attention/embedding weights over the
    data axis (ZeRO-3-style gather-on-use) so 1T-param MoE checkpoints fit
    for inference without a DP replica per data shard.
    """
    m = "model"
    msz = mesh.shape[m]
    dax = data_axes(mesh)
    kv_heads_div = cfg.n_kv_heads % msz == 0

    # serve mode: shard the d_model (input) dim of projections over data
    din = dax if (mode == "serve" and cfg.is_moe) else None

    layer = {
        "attn_norm": P(None, None),
        "mlp_norm": P(None, None),
        "wq": P(None, din, m, None),
        "wk": P(None, din, m, None) if kv_heads_div else P(None, din, None, m),
        "wv": P(None, din, m, None) if kv_heads_div else P(None, din, None, m),
        "wo": P(None, m, None, din),
    }
    if cfg.moe is not None:
        # experts over model (EP) + expert-ff over data: both axes carry the
        # (potentially TB-scale) expert weights even during training.
        moe = {
            "router": P(None, None, None),
            "w_up": P(None, m, None, dax),
            "w_down": P(None, m, dax, None),
        }
        if cfg.mlp_type in ("swiglu", "geglu"):
            moe["w_gate"] = P(None, m, None, dax)
        layer["moe"] = moe
    else:
        mlp = {"w_up": P(None, None, m), "w_down": P(None, m, None)}
        if cfg.mlp_type in ("swiglu", "geglu"):
            mlp["w_gate"] = P(None, None, m)
        layer["mlp"] = mlp

    specs: Dict[str, Any] = {
        "embed": P(m, None),
        "layers": layer,
        "final_norm": P(None),
    }
    if not cfg.tie_embeddings:
        specs["lm_head"] = P(None, m)
    return specs


def serving_arena_spec() -> P:
    """Paged KV arena (n_pages, page_size, L, Hkv, Dh): kv heads over the
    model axis — the same head split as wk/wv, so the decode gather and
    the per-layer arena scatters stay local to each device's plane.
    Pages/slots replicate (slot tables are host-side numpy and
    device-agnostic: one logical page id addresses every device's slice
    of that page)."""
    return P(None, None, None, "model", None)


def check_serving_divisibility(cfg: LMConfig, mesh) -> None:
    """Serving tensor parallelism splits whole heads: both head counts
    must divide by the model-axis size (no padded-shard fallback — a
    config error here names the two knobs instead of degrading)."""
    msz = mesh.shape["model"]
    if cfg.n_heads % msz or cfg.n_kv_heads % msz:
        raise ValueError(
            f"mesh model axis of {msz} devices (mesh.tp={msz}) must divide "
            f"n_heads={cfg.n_heads} and n_kv_heads={cfg.n_kv_heads}: pick a "
            f"tp dividing both, or a model with more kv heads"
        )


def shard_lm_params(params, cfg: LMConfig, mesh):
    """Place a host-resident LM param tree onto the mesh by
    `lm_param_specs` (dense serving layout).  The jitted engine steps
    need no changes — GSPMD propagates these shardings and inserts the
    tensor-parallel collectives."""
    check_serving_divisibility(cfg, mesh)
    return jax.device_put(params, tree_shardings(mesh, lm_param_specs(cfg, mesh)))


def zero_shard(spec_tree, shape_tree, mesh):
    """ZeRO-style sharding for optimizer moments: take each tensor's spec and
    shard the first still-replicated, divisible dim over the data axis."""
    dax = data_axes(mesh)
    dsz = axis_size(mesh, dax)

    def one(spec: P, sds) -> P:
        dims = list(spec) + [None] * (len(sds.shape) - len(spec))
        used = set()
        for d in dims:
            for a in (d if isinstance(d, tuple) else (d,)):
                used.add(a)
        if any(a in used for a in dax):  # already data-sharded somewhere
            return P(*dims)
        for i, (ax, size) in enumerate(zip(dims, sds.shape)):
            if ax is None and size % dsz == 0 and size >= dsz:
                dims[i] = dax
                return P(*dims)
        return P(*dims)

    return jax.tree_util.tree_map(
        one, spec_tree, shape_tree, is_leaf=lambda x: isinstance(x, P)
    )


def lm_opt_state_specs(opt_abstract, param_specs, params_abstract, mesh):
    """Match optimizer-state pytrees (moments shaped like params, or
    adafactor's reduced-rank factors) to sharding specs."""
    from repro.training.optimizer import OptState

    def spec_for(path_leaf, sds):
        # factored adafactor stats: match prefix dims of the param spec
        return None

    # moments shaped exactly like params reuse (zero-sharded) param specs
    zspecs = zero_shard(param_specs, params_abstract, mesh)

    def map_inner(inner):
        if isinstance(inner, dict) and set(inner) <= {"m", "v"}:
            return {k: zspecs for k in inner}
        # adafactor: per-leaf dict {"vr","vc"} or {"v"} — derive from param spec
        flat_p, tdef = jax.tree_util.tree_flatten(params_abstract)
        flat_spec = tdef.flatten_up_to(param_specs)
        flat_state = tdef.flatten_up_to(inner)

        def one(spec: P, sds, st):
            dims = list(spec) + [None] * (len(sds.shape) - len(spec))
            out = {}
            for key in st:
                if key == "v":
                    out["v"] = P(*dims)
                elif key == "vr":  # param dims minus last
                    out["vr"] = P(*dims[:-1])
                elif key == "vc":  # param dims minus second-to-last
                    out["vc"] = P(*(dims[:-2] + dims[-1:]))
            return out

        flat_out = [one(s, p, st) for s, p, st in zip(flat_spec, flat_p, flat_state)]
        return tdef.unflatten(flat_out)

    return OptState(step=P(), inner=map_inner(opt_abstract.inner))


def lm_input_specs(cfg: LMConfig, mesh, step: str, dims: Dict[str, int]):
    dax = data_axes(mesh)
    dsz = axis_size(mesh, dax)
    b = dims["batch"]
    if step == "train":
        return {"tokens": P(dax, None), "labels": P(dax, None)}
    if step == "prefill":
        return {"tokens": P(dax, None)}
    if step == "decode":
        return {
            "tokens": P(dax, None) if b % dsz == 0 else P(None, None),
            "cache": lm_cache_spec(cfg, mesh, b, dims["seq"]),
            "positions": P(dax) if b % dsz == 0 else P(None),
        }
    raise ValueError(step)


def lm_cache_spec(cfg: LMConfig, mesh, batch: int, seq: int):
    """KV cache (L, B, S, Hkv, Dh) sharding.  batch→data when divisible;
    kv-heads→model when divisible, else sequence→(remaining axes) —
    flash-decoding split-K, combined by XLA via all-reduce."""
    m = "model"
    msz = mesh.shape[m]
    dax = data_axes(mesh)
    dsz = axis_size(mesh, dax)
    if batch % dsz == 0:
        if cfg.n_kv_heads % msz == 0:
            spec = P(None, dax, None, m, None)
        else:
            spec = P(None, dax, m, None, None)  # shard sequence on model
    else:
        # tiny batch (long_500k): shard the sequence across everything
        all_ax = tuple(dax) + (m,)
        spec = P(None, None, all_ax, None, None)
    return {"k": spec, "v": spec}


# ---------------------------------------------------------------------------
# RecSys family
# ---------------------------------------------------------------------------

def recsys_param_specs(cfg: RecsysConfig, mesh) -> Dict[str, Any]:
    dax = data_axes(mesh)
    rows = tuple(dax) + ("model",)

    def spec_of(path, leaf):
        name = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        if "table" in name and leaf.ndim == 2 and leaf.shape[0] >= 4096:
            return P(rows, None)
        return P(*([None] * leaf.ndim))

    from repro.recsys import models as RM

    abstract = RM.abstract_params(cfg)
    return jax.tree_util.tree_map_with_path(spec_of, abstract)


def recsys_input_specs(cfg: RecsysConfig, mesh, step: str, dims: Dict[str, int]):
    dax = data_axes(mesh)
    dsz = axis_size(mesh, dax)
    b = dims["batch"]
    bspec = dax if b % dsz == 0 else None

    def leaf_spec(leaf_shape):
        return P(bspec, *([None] * (len(leaf_shape) - 1)))

    from repro.configs.registry import input_specs as reg_specs

    specs = reg_specs(cfg.name, _shape_name_of(cfg, step, dims))
    out = {}
    for k, v in specs.items():
        if k == "candidate_ids":
            # 1M candidates not divisible by 256/512 — replicate the (4 MB)
            # id vector; the gather + batched dot still run sharded via the
            # row-sharded table
            out[k] = P(None)
        elif k == "neg_samples":
            out[k] = P(None)
        else:
            out[k] = leaf_spec(v.shape)
    return out


def _shape_name_of(cfg, step, dims):
    from repro.configs.registry import SHAPES

    for name, s in SHAPES["recsys"].items():
        if s.step == step and s.dims.get("batch") == dims.get("batch"):
            return name
    raise KeyError((step, dims))


# ---------------------------------------------------------------------------
# GNN family
# ---------------------------------------------------------------------------

def gnn_param_specs(params_abstract, mesh):
    return jax.tree_util.tree_map(lambda l: P(*([None] * l.ndim)), params_abstract)


def gnn_input_specs(mesh, shape_name: str, spec_shapes: Dict[str, Any]):
    dax = data_axes(mesh)
    edge_ax = tuple(dax) + ("model",)
    esz = axis_size(mesh, edge_ax)
    out = {}
    for k, v in spec_shapes.items():
        if k.startswith("edge_"):
            if len(v.shape) == 1:
                # shard flat edge arrays only when divisible (pjit argument
                # constraint); the step pads + re-shards internally otherwise
                out[k] = P(edge_ax) if v.shape[0] % esz == 0 else P(None)
            else:  # molecule regime: (B, E)
                out[k] = P(dax, None)
        elif k in ("atom_types", "positions", "targets") and shape_name == "molecule":
            out[k] = P(*([dax] + [None] * (len(v.shape) - 1)))
        elif (
            k == "node_feat"
            and v.shape[0] * v.shape[1] > 2**27
            and v.shape[0] % axis_size(mesh, dax) == 0
        ):
            out[k] = P(dax, None)  # huge node features, if divisible
        else:
            out[k] = P(*([None] * len(v.shape)))
    return out
