"""Trace-time sharding-hint context.

Model code stays mesh-agnostic: it calls ``hint(x, "tokens", ...)`` with a
*logical* spec; when a step function is traced inside ``axes(mesh)``, the
logical axes resolve to mesh axes and a with_sharding_constraint is emitted.
Outside any mesh (unit tests, single-device runs) hints are no-ops.

Logical axes:  "dp"  → ("pod","data") / ("data",)   "mp" → "model"
"""
from __future__ import annotations

import contextlib
import contextvars
from typing import Optional

import jax
from jax import lax
from jax.sharding import NamedSharding, PartitionSpec as P

_CTX: contextvars.ContextVar = contextvars.ContextVar("mesh_ctx", default=None)


@contextlib.contextmanager
def axes(mesh):
    """Activate sharding hints for code traced inside this block."""
    from repro.launch.mesh import data_axes
    token = _CTX.set((mesh, data_axes(mesh), "model"))
    try:
        yield
    finally:
        _CTX.reset(token)


def resolve(*logical) -> Optional[P]:
    ctx = _CTX.get()
    if ctx is None:
        return None
    _, dax, m = ctx
    out = []
    for ax in logical:
        if ax == "dp":
            out.append(tuple(dax))
        elif ax == "mp":
            out.append(m)
        elif ax == "dp+mp":
            out.append(tuple(dax) + (m,))
        else:
            out.append(None)
    return P(*out)


def hint(x: jax.Array, *logical) -> jax.Array:
    """with_sharding_constraint if a mesh context is active, else identity."""
    ctx = _CTX.get()
    if ctx is None:
        return x
    mesh = ctx[0]
    spec = resolve(*logical)
    return lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
