"""Planted-preference training for the accuracy prototype.

Creates a learnable ranking task: each request has a gold candidate whose
evidence is planted in the user's history (the user "reviewed" tokens from
the gold item), and the LM is trained to emit the gold candidate's slot
token after RANK_QUERY.  This gives Table III-style metrics real teeth —
an untrained model ranks randomly, so approximation error would be
invisible (see EXPERIMENTS.md §Accuracy for the protocol note).
"""
from __future__ import annotations

import dataclasses
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import LMConfig
from repro.data import synth as SY
from repro.models import transformer as T
from repro.training import optimizer as OPT


def make_planted_trace(catalog: SY.Catalog, pool: SY.ReviewPool,
                       profile: SY.DatasetProfile, n_requests: int,
                       n_candidates: int = 8, n_users: int = 50,
                       evidence_tokens: int = 12, seed: int = 11
                       ) -> Tuple[List[SY.Request], np.ndarray]:
    """Trace whose gold candidate is recoverable from the history."""
    rng = np.random.default_rng(seed)
    # low cluster bias → candidates span clusters, so the planted evidence
    # (gold-item tokens in the history) identifies a unique candidate
    base = SY.make_trace(catalog, pool, profile, n_requests=n_requests,
                         qps=10.0, n_users=n_users,
                         n_candidates=n_candidates, reviews_per_user=2,
                         seed=seed, cluster_bias=0.15)
    gold = np.zeros(len(base), np.int64)
    out = []
    for i, r in enumerate(base):
        g = int(rng.integers(0, n_candidates))
        gold[i] = g
        gold_item = int(r.candidate_items[g])
        ev = catalog.item_tokens[gold_item][:evidence_tokens]
        hist = np.concatenate(
            [r.history_tokens, [SY.REVIEW_SEP], ev]).astype(np.int32)
        mark = np.concatenate(
            [r.history_marker_mask, [True],
             np.zeros(len(ev), bool)])
        out.append(dataclasses.replace(r, history_tokens=hist,
                                       history_marker_mask=mark))
    return out, gold


def _batchify(requests, gold, catalog, instruction, pad_to: int):
    toks, lastpos, labels = [], [], []
    for r, g in zip(requests, gold):
        t, _, _ = r.prompt_segments(catalog, instruction)
        t = t[:pad_to]
        lastpos.append(len(t) - 1)
        toks.append(np.pad(t, (0, pad_to - len(t))))
        labels.append(SY.SLOT_BASE + int(g))
    return (np.stack(toks).astype(np.int32), np.asarray(lastpos, np.int32),
            np.asarray(labels, np.int32))


def train_ranker(params, cfg: LMConfig, catalog: SY.Catalog,
                 instruction: np.ndarray, requests, gold: np.ndarray,
                 steps: int = 200, batch_size: int = 8, lr: float = 3e-3,
                 seed: int = 0, log_every: int = 50):
    """Train the tiny LM to rank (CE on the gold slot token at RANK_QUERY)."""
    pad_to = max(len(r.prompt_segments(catalog, instruction)[0])
                 for r in requests)
    pad_to = ((pad_to + 63) // 64) * 64
    toks_all, last_all, lab_all = _batchify(requests, gold, catalog,
                                            instruction, pad_to)
    init_opt, update_opt = OPT.get("adamw", lr=lr, weight_decay=0.0)
    opt_state = init_opt(params)
    rng = np.random.default_rng(seed)

    @jax.jit
    def step(params, opt_state, toks, lastpos, labels):
        def loss_fn(p):
            logits, _ = T.forward(p, toks, cfg)
            sel = jnp.take_along_axis(
                logits, lastpos[:, None, None], axis=1)[:, 0]  # (B, V)
            sel = sel.astype(jnp.float32)
            logz = jax.nn.logsumexp(sel, axis=-1)
            gold_lp = jnp.take_along_axis(sel, labels[:, None], 1)[:, 0]
            return (logz - gold_lp).mean()

        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, opt_state, gnorm = update_opt(grads, opt_state, params)
        return params, opt_state, loss

    history = []
    for s in range(steps):
        idx = rng.choice(len(requests), batch_size, replace=False)
        params, opt_state, loss = step(
            params, opt_state, jnp.asarray(toks_all[idx]),
            jnp.asarray(last_all[idx]), jnp.asarray(lab_all[idx]))
        if s % log_every == 0 or s == steps - 1:
            history.append((s, float(loss)))
    return params, history
