"""Semantic-history KV pool (§III-B, first pool).

Three-stage offline pipeline:
  1. Position-aware embedding  e_{t,p} = token_embed[t] ⊕ pos_features(p)
  2. LSH clustering (random-hyperplane signs) → bounded prototype set
  3. KV materialization: each prototype's representative token keeps its
     layer-wise KV states from a real corpus context.

The pool is compact (paper: ~1e5 prototypes ≈ 30 GB for Qwen3-8B, CPU-
resident, replicated on every node — here scaled with the synthetic corpus).
At inference each history token retrieves its nearest prototype; >93% of
tokens in new reviews match near-identically (Insight 1 / Fig. 3b).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np


def position_features(positions: np.ndarray, n_feat: int = 8,
                      base: float = 10_000.0) -> np.ndarray:
    """Low-dim sinusoidal position encoding used for position-aware hashing
    (coarse: nearby positions hash together, distant ones do not)."""
    freqs = 1.0 / base ** (np.arange(n_feat // 2) / (n_feat // 2))
    ang = positions[:, None] * freqs[None, :] * 0.02
    return np.concatenate([np.sin(ang), np.cos(ang)], axis=-1)


@dataclass
class LSH:
    planes: np.ndarray                      # (d, n_bits)

    @staticmethod
    def make(d: int, n_bits: int, seed: int = 0) -> "LSH":
        rng = np.random.default_rng(seed)
        return LSH(planes=rng.normal(size=(d, n_bits)).astype(np.float32))

    def codes(self, x: np.ndarray) -> np.ndarray:
        bits = (x @ self.planes) > 0
        weights = (1 << np.arange(bits.shape[1], dtype=np.uint64))
        return (bits.astype(np.uint64) * weights).sum(axis=1)


@dataclass
class SemanticCache:
    lsh: LSH
    pos_buckets: int
    bucket_to_proto: Dict[Tuple[int, int], int]   # (pos_bucket, code) -> pid
    proto_embed: np.ndarray                 # (P, d) centroid embeddings
    proto_token: np.ndarray                 # (P,) representative token id
    proto_position: np.ndarray              # (P,) canonical position
    # layer-wise KV of representatives: (P, L, Hkv, Dh), pre-RoPE keys
    proto_k: Optional[np.ndarray] = None
    proto_v: Optional[np.ndarray] = None

    @property
    def n_prototypes(self) -> int:
        return len(self.proto_token)

    def size_bytes(self) -> int:
        n = 0
        for a in (self.proto_k, self.proto_v):
            if a is not None:
                n += a.nbytes
        return n

    def match(self, tokens: np.ndarray, positions: np.ndarray,
              embed: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """-> (proto_id or -1, cosine sim) per token."""
        pb = np.minimum(positions // self.bucket_size, self.pos_buckets - 1)
        codes = self.lsh.codes(embed)
        pid = np.full(len(tokens), -1, np.int64)
        sim = np.zeros(len(tokens))
        for i in range(len(tokens)):
            p = self.bucket_to_proto.get((int(pb[i]), int(codes[i])), -1)
            pid[i] = p
            if p >= 0:
                a, b = embed[i], self.proto_embed[p]
                sim[i] = float(a @ b /
                               (np.linalg.norm(a) * np.linalg.norm(b) + 1e-9))
        return pid, sim

    bucket_size: int = 64


def build_semantic_cache(
    corpus_tokens: List[np.ndarray],
    token_embed: np.ndarray,                # (V, d) model embedding table
    n_bits: int = 12,
    pos_bucket: int = 64,
    max_position: int = 4096,
    min_count: int = 2,
    seed: int = 0,
) -> SemanticCache:
    """Stages 1–2: position-aware embedding + LSH clustering."""
    d = token_embed.shape[1]
    nf = 8
    lsh = LSH.make(d + nf, n_bits, seed)
    pos_buckets = max(1, max_position // pos_bucket)

    sums: Dict[Tuple[int, int], np.ndarray] = {}
    counts: Dict[Tuple[int, int], int] = {}
    rep: Dict[Tuple[int, int], Tuple[int, int, int]] = {}  # (tok, pos, doc)
    for doc_id, toks in enumerate(corpus_tokens):
        pos = np.arange(len(toks))
        emb = np.concatenate([token_embed[toks],
                              position_features(pos, nf)], axis=-1)
        pb = np.minimum(pos // pos_bucket, pos_buckets - 1)
        codes = lsh.codes(emb)
        for i in range(len(toks)):
            key = (int(pb[i]), int(codes[i]))
            if key not in sums:
                sums[key] = emb[i].copy()
                counts[key] = 1
                rep[key] = (int(toks[i]), int(pos[i]), doc_id)
            else:
                sums[key] += emb[i]
                counts[key] += 1

    keys = [k for k, c in counts.items() if c >= min_count]
    bucket_to_proto = {k: i for i, k in enumerate(keys)}
    proto_embed = np.stack([sums[k] / counts[k] for k in keys]) \
        if keys else np.zeros((0, d + nf), np.float32)
    proto_token = np.asarray([rep[k][0] for k in keys], np.int32)
    proto_position = np.asarray([rep[k][1] for k in keys], np.int32)
    cache = SemanticCache(lsh=lsh, pos_buckets=pos_buckets,
                          bucket_to_proto=bucket_to_proto,
                          proto_embed=proto_embed.astype(np.float32),
                          proto_token=proto_token,
                          proto_position=proto_position)
    cache.bucket_size = pos_bucket
    cache._rep_docs = [rep[k][2] for k in keys]     # for KV materialization
    cache._rep_offsets = [rep[k][1] for k in keys]
    return cache


def materialize_kv(cache: SemanticCache, corpus_tokens: List[np.ndarray],
                   kv_of_sequence: Optional[Callable] = None,
                   kv_by_doc: Optional[Callable[[int], Tuple[np.ndarray, np.ndarray]]] = None,
                   ) -> None:
    """Stage 3: run the model over each representative's original review and
    keep the representative token's per-layer (pre-RoPE) K/V.

    Pass either `kv_of_sequence(tokens)` or a precomputed `kv_by_doc(idx)`.
    """
    ks, vs = [], []
    doc_cache: Dict[int, Tuple[np.ndarray, np.ndarray]] = {}
    for pid in range(cache.n_prototypes):
        doc = cache._rep_docs[pid]
        off = cache._rep_offsets[pid]
        if doc not in doc_cache:
            doc_cache[doc] = kv_by_doc(doc) if kv_by_doc is not None \
                else kv_of_sequence(corpus_tokens[doc])
        k_all, v_all = doc_cache[doc]        # (S, L, Hkv, Dh)
        ks.append(k_all[off])
        vs.append(v_all[off])
    cache.proto_k = np.stack(ks) if ks else None
    cache.proto_v = np.stack(vs) if vs else None


def embed_tokens_for_match(tokens: np.ndarray, positions: np.ndarray,
                           token_embed: np.ndarray) -> np.ndarray:
    return np.concatenate([token_embed[tokens],
                           position_features(positions, 8)], axis=-1)
