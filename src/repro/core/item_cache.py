"""Candidate-item KV pool (§III-B, second pool).

Per-item KV blocks are precomputed offline at canonical position 0 (keys
stored pre-RoPE so assembly can rotate them to any request position — the
group property of RoPE makes this exact, §III-C3 'Alignment') and sharded
across instances by the Algorithm-1 placement.  At terabyte catalog scale
only the per-instance shard (plus hot replicas) is resident — Fig. 9b's
per-replica footprint.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.placement import Placement


@dataclass
class ItemBlock:
    item_id: int
    tokens: np.ndarray                     # block token ids (SEP + item text)
    k: np.ndarray                          # (S, L, Hkv, Dh) pre-RoPE
    v: np.ndarray                          # (S, L, Hkv, Dh)

    def nbytes(self) -> int:
        return self.k.nbytes + self.v.nbytes


@dataclass
class ItemCacheShard:
    """The blocks resident on one instance (its partition + hot replicas)."""
    instance: int
    blocks: Dict[int, ItemBlock]

    def nbytes(self) -> int:
        return sum(b.nbytes() for b in self.blocks.values())

    def n_tokens(self) -> int:
        return sum(len(b.tokens) for b in self.blocks.values())


@dataclass
class ItemKVStore:
    placement: Placement
    shards: List[ItemCacheShard]
    token_count: np.ndarray                # per-item block length

    def lookup(self, items: Sequence[int], instance: int
               ) -> Tuple[List[int], List[int], List[int]]:
        """-> (local hits, remote hits, misses) by item id."""
        local, remote, miss = [], [], []
        shard = self.shards[instance]
        for it in items:
            it = int(it)
            if it in shard.blocks:
                local.append(it)
            else:
                holders = [h for h in self.placement.holders(it)
                           if it in self.shards[h].blocks]
                (remote if holders else miss).append(it)
        return local, remote, miss

    def get_block(self, item: int, instance: int) -> Optional[ItemBlock]:
        b = self.shards[instance].blocks.get(int(item))
        if b is not None:
            return b
        for h in self.placement.holders(int(item)):
            b = self.shards[h].blocks.get(int(item))
            if b is not None:
                return b
        return None

    def footprint_tokens_per_replica(self) -> float:
        return float(np.mean([s.n_tokens() for s in self.shards]))


def build_item_store(
    item_tokens: List[np.ndarray],
    placement: Placement,
    kv_of_sequence: Optional[Callable] = None,
    kv_list: Optional[List[Tuple[np.ndarray, np.ndarray]]] = None,
    coverage: float = 1.0,
    seed: int = 0,
) -> ItemKVStore:
    """Precompute KV blocks for (a subset of) the catalog and lay them out
    by the placement.  `coverage < 1` models a partially-warmed cache."""
    rng = np.random.default_rng(seed)
    n = len(item_tokens)
    cached = np.ones(n, bool) if coverage >= 1.0 else \
        rng.random(n) < coverage
    shards = [ItemCacheShard(instance=i, blocks={})
              for i in range(placement.k)]
    token_count = np.zeros(n, np.int32)
    for it in range(n):
        token_count[it] = len(item_tokens[it])
        if not cached[it]:
            continue
        k, v = kv_list[it] if kv_list is not None \
            else kv_of_sequence(item_tokens[it])
        blk = ItemBlock(item_id=it, tokens=item_tokens[it], k=k, v=v)
        for holder in placement.holders(it):
            shards[holder].blocks[it] = blk
    return ItemKVStore(placement=placement, shards=shards,
                       token_count=token_count)
