"""Candidate-item KV pool (§III-B, second pool).

Per-item KV blocks are precomputed offline at canonical position 0 (keys
stored pre-RoPE so assembly can rotate them to any request position — the
group property of RoPE makes this exact, §III-C3 'Alignment') and sharded
across instances by the Algorithm-1 placement.  At terabyte catalog scale
only the per-instance shard (plus hot replicas) is resident — Fig. 9b's
per-replica footprint.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.placement import Placement


@dataclass
class ItemBlock:
    item_id: int
    tokens: np.ndarray                     # block token ids (SEP + item text)
    k: np.ndarray                          # (S, L, Hkv, Dh) pre-RoPE
    v: np.ndarray                          # (S, L, Hkv, Dh)

    def nbytes(self) -> int:
        return self.k.nbytes + self.v.nbytes


@dataclass
class ItemCacheShard:
    """The blocks resident on one instance (its partition + hot replicas)."""
    instance: int
    blocks: Dict[int, ItemBlock]

    def nbytes(self) -> int:
        return sum(b.nbytes() for b in self.blocks.values())

    def n_tokens(self) -> int:
        return sum(len(b.tokens) for b in self.blocks.values())


@dataclass
class ItemKVStore:
    placement: Placement
    shards: List[ItemCacheShard]
    token_count: np.ndarray                # per-item block length

    def lookup(self, items: Sequence[int], instance: int
               ) -> Tuple[List[int], List[int], List[int]]:
        """-> (local hits, remote hits, misses) by item id."""
        local, remote, miss = [], [], []
        shard = self.shards[instance]
        for it in items:
            it = int(it)
            if it in shard.blocks:
                local.append(it)
            else:
                holders = [h for h in self.placement.holders(it)
                           if it in self.shards[h].blocks]
                (remote if holders else miss).append(it)
        return local, remote, miss

    def get_block(self, item: int, instance: int) -> Optional[ItemBlock]:
        b = self.shards[instance].blocks.get(int(item))
        if b is not None:
            return b
        for h in self.placement.holders(int(item)):
            b = self.shards[h].blocks.get(int(item))
            if b is not None:
                return b
        return None

    def footprint_tokens_per_replica(self) -> float:
        return float(np.mean([s.n_tokens() for s in self.shards]))


@dataclass(frozen=True)
class TransferRecord:
    """One explicit cross-shard block movement (the measurable unit the
    cluster's transfer step is billed in).  ``measured_s`` is the wall
    clock of the real `jax.device_put` device-to-device copy when the
    client runs with per-instance home devices, 0.0 on the ledger-only
    path (no devices — the cluster then bills the modeled
    `cost_model.fetch_time_s` instead)."""
    item_id: int
    src_instance: int
    n_tokens: int
    n_bytes: int
    measured_s: float = 0.0


class ShardClient:
    """Runtime-facing handle on one instance's resident item shard.

    `ItemKVStore.get_block` silently falls back to peer shards — a
    simulator convenience a real instance does not have.  A ShardClient
    makes residency explicit: `resident()` answers from this shard only,
    and every non-resident access goes through `pull()`, which fetches
    the block from its holder *and records a TransferRecord*, so each
    cross-shard byte is accounted for (and can be cost-modeled by the
    serving layer).  Blocks whose items no shard holds stay misses — the
    engine recomputes them, as in the paper.

    ``devices`` (a per-instance home-device list, indexable by instance
    id) turns the ledger physical: every pull stages the holder's block
    bytes on the holder's device (once, cached) and then runs a real
    `jax.device_put` device-to-device copy onto this instance's device,
    recording the *measured* wall seconds in the TransferRecord — the
    cluster bills that instead of the modeled network time.  The block
    contents are unchanged either way (the copy moves the same bytes),
    so routing still never changes what a request decodes.
    """

    def __init__(self, store: ItemKVStore, instance: int, devices=None):
        self.store = store
        self.instance = instance
        self.devices = list(devices) if devices else None
        self.transfers: List[TransferRecord] = []
        self.n_local_blocks = 0
        self.n_miss_blocks = 0
        # holder-device-resident staging cache: item -> (k_dev, v_dev);
        # the host->device upload is paid once per item, every pull's
        # device-to-device hop is then measured cleanly
        self._dev_blocks: Dict[int, tuple] = {}
        self._measured_pending = 0.0

    @property
    def measures(self) -> bool:
        """Does this client measure real device-to-device transfers?"""
        return self.devices is not None

    def home_device(self, instance: int):
        return self.devices[instance % len(self.devices)]

    def _measured_copy(self, blk: ItemBlock, src_instance: int) -> float:
        import jax

        kd, vd = self._dev_blocks.get(blk.item_id, (None, None))
        if kd is None:
            src = self.home_device(src_instance)
            kd = jax.device_put(blk.k, src)
            vd = jax.device_put(blk.v, src)
            jax.block_until_ready((kd, vd))
            self._dev_blocks[blk.item_id] = (kd, vd)
        dst = self.home_device(self.instance)
        t0 = time.perf_counter()
        k2 = jax.device_put(kd, dst)
        v2 = jax.device_put(vd, dst)
        jax.block_until_ready((k2, v2))
        return time.perf_counter() - t0

    def take_measured_s(self) -> float:
        """Measured seconds accumulated since the last take (the cluster
        drains this right after each `stage` to bill the dispatch)."""
        s, self._measured_pending = self._measured_pending, 0.0
        return s

    def resident(self, item: int) -> bool:
        return int(item) in self.store.shards[self.instance].blocks

    def local_block(self, item: int) -> Optional[ItemBlock]:
        return self.store.shards[self.instance].blocks.get(int(item))

    def pull(self, item: int) -> Optional[ItemBlock]:
        """Explicit cross-shard fetch of a non-resident block (recorded)."""
        it = int(item)
        for h in self.store.placement.holders(it):
            if h == self.instance:
                continue
            blk = self.store.shards[h].blocks.get(it)
            if blk is not None:
                measured = 0.0
                if self.devices is not None:
                    measured = self._measured_copy(blk, h)
                    self._measured_pending += measured
                self.transfers.append(TransferRecord(
                    item_id=it, src_instance=h,
                    n_tokens=len(blk.tokens), n_bytes=blk.nbytes(),
                    measured_s=measured))
                return blk
        return None

    def stage(self, items: Sequence[int]
              ) -> Tuple[Dict[int, ItemBlock], int]:
        """Resolve one request's unique item set against this shard:
        resident blocks come straight from it, non-resident ones via
        `pull()`.  -> ({item: block}, tokens moved over the network)."""
        staged: Dict[int, ItemBlock] = {}
        moved_tokens = 0
        for it in items:
            it = int(it)
            if it in staged:
                continue
            blk = self.local_block(it)
            if blk is not None:
                self.n_local_blocks += 1
            else:
                blk = self.pull(it)
                if blk is None:
                    self.n_miss_blocks += 1
                    continue
                moved_tokens += len(blk.tokens)
            staged[it] = blk
        return staged, moved_tokens

    def transferred_bytes(self) -> int:
        return sum(t.n_bytes for t in self.transfers)

    def transferred_tokens(self) -> int:
        return sum(t.n_tokens for t in self.transfers)

    def measured_seconds(self) -> float:
        return sum(t.measured_s for t in self.transfers)


class StagedBlocks:
    """A request's staged item blocks behind the `get_block` interface
    `assembly.gather_cached_kv` consumes — only what `ShardClient.stage`
    resolved is visible, so nothing materializes silently."""

    def __init__(self, blocks: Dict[int, ItemBlock]):
        self.blocks = blocks

    def get_block(self, item: int, instance: int = 0) -> Optional[ItemBlock]:
        return self.blocks.get(int(item))


def build_item_store(
    item_tokens: List[np.ndarray],
    placement: Placement,
    kv_of_sequence: Optional[Callable] = None,
    kv_list: Optional[List[Tuple[np.ndarray, np.ndarray]]] = None,
    coverage: float = 1.0,
    seed: int = 0,
) -> ItemKVStore:
    """Precompute KV blocks for (a subset of) the catalog and lay them out
    by the placement.  `coverage < 1` models a partially-warmed cache."""
    rng = np.random.default_rng(seed)
    n = len(item_tokens)
    cached = np.ones(n, bool) if coverage >= 1.0 else \
        rng.random(n) < coverage
    shards = [ItemCacheShard(instance=i, blocks={})
              for i in range(placement.k)]
    token_count = np.zeros(n, np.int32)
    for it in range(n):
        token_count[it] = len(item_tokens[it])
        if not cached[it]:
            continue
        k, v = kv_list[it] if kv_list is not None \
            else kv_of_sequence(item_tokens[it])
        blk = ItemBlock(item_id=it, tokens=item_tokens[it], k=k, v=v)
        for holder in placement.holders(it):
            shards[holder].blocks[it] = blk
    return ItemKVStore(placement=placement, shards=shards,
                       token_count=token_count)
