"""Analytic serving cost model (the simulator's clock).

The paper's Vidur-based engine models A100s + 100 Gbps Ethernet; we
re-parameterize for the TPU v5e target using the same roofline constants as
§Roofline (197 TFLOP/s bf16, 819 GB/s HBM) plus host/interconnect terms.
TTFT for a request = queue wait + max(KV fetch, layer-0 pass) [the §III-C3
overlap] + selective prefill compute + LM head.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.configs.base import LMConfig


@dataclass
class Hardware:
    peak_flops: float = 197e12          # bf16 / chip
    mfu: float = 0.45                   # realistic prefill efficiency
    hbm_bw: float = 819e9
    host_to_device_bw: float = 32e9     # host DRAM → HBM DMA (PCIe-class)
    network_bw: float = 12.5e9          # 100 Gbps inter-instance (paper)
    network_rtt: float = 200e-6
    chips_per_instance: int = 1         # TP degree within an instance


V5E_1 = Hardware()
V5E_TP4 = Hardware(chips_per_instance=4)   # 72B-class model instances


def kv_bytes_per_token(cfg: LMConfig, dtype_bytes: int = 2) -> int:
    return 2 * cfg.n_layers * cfg.n_kv_heads * cfg.resolved_head_dim * dtype_bytes


def prefill_flops(cfg: LMConfig, n_total: int, n_recompute: int,
                  layer0_full: bool = True) -> float:
    """FLOPs for selective prefill: dense work only for recomputed tokens,
    attention for recomputed queries over all keys, plus one full layer-0
    pass for heavy-hitter identification."""
    d = cfg.d_model
    dh = cfg.resolved_head_dim
    attn_proj = 2 * d * dh * (2 * cfg.n_heads + 2 * cfg.n_kv_heads)
    if cfg.moe is not None:
        ffn = 3 * 2 * d * cfg.moe.d_ff * cfg.moe.top_k
    else:
        n_mats = 3 if cfg.mlp_type in ("swiglu", "geglu") else 2
        ffn = n_mats * 2 * d * cfg.d_ff
    dense_per_tok_layer = attn_proj + ffn
    attn_per_q_layer = 2 * 2 * cfg.n_heads * dh * n_total   # QK^T + PV

    layers_sel = cfg.n_layers - (1 if layer0_full else 0)
    fl = n_recompute * layers_sel * (dense_per_tok_layer + attn_per_q_layer)
    if layer0_full:
        fl += n_total * (dense_per_tok_layer + attn_per_q_layer)
    fl += 2 * d * cfg.vocab_size                            # LM head, 1 token
    return float(fl)


def prefill_time_s(cfg: LMConfig, hw: Hardware, n_total: int,
                   n_recompute: int, layer0_full: bool = True) -> float:
    fl = prefill_flops(cfg, n_total, n_recompute, layer0_full)
    return fl / (hw.peak_flops * hw.chips_per_instance * hw.mfu)


def fetch_time_s(cfg: LMConfig, hw: Hardware, n_local_tokens: int,
                 n_remote_tokens: int) -> float:
    """Cache-block staging: local = host-DRAM→HBM DMA; remote adds a network
    hop.  Zero-copy assembly means no extra device-side copy."""
    b = kv_bytes_per_token(cfg)
    t_local = n_local_tokens * b / hw.host_to_device_bw
    t_remote = 0.0
    if n_remote_tokens > 0:
        t_remote = hw.network_rtt + n_remote_tokens * b / hw.network_bw \
            + n_remote_tokens * b / hw.host_to_device_bw
    return t_local + t_remote


def ttft_s(cfg: LMConfig, hw: Hardware, n_total: int, n_recompute: int,
           n_local_tokens: int, n_remote_tokens: int,
           layer0_full: bool = True) -> float:
    """§III-C3 pipeline: the layer-0 pass overlaps the PCIe/network staging."""
    t_fetch = fetch_time_s(cfg, hw, n_local_tokens, n_remote_tokens)
    t_layer0 = prefill_time_s(cfg, hw, n_total, 0, layer0_full=True) \
        if layer0_full else 0.0
    t_rest = prefill_time_s(cfg, hw, n_total, n_recompute,
                            layer0_full=False) * (cfg.n_layers - 1) / cfg.n_layers
    return max(t_fetch, t_layer0) + t_rest


def full_prefill_ttft_s(cfg: LMConfig, hw: Hardware, n_total: int) -> float:
    return prefill_time_s(cfg, hw, n_total, n_total, layer0_full=False)


def prefix_cache_ttft_s(cfg: LMConfig, hw: Hardware, n_total: int,
                        n_prefix_hit: int) -> float:
    """Industrial prefix caching: only the shared leading segment is free."""
    return prefill_time_s(cfg, hw, n_total, n_total - n_prefix_hit,
                          layer0_full=False)


def decode_step_time_s(cfg: LMConfig, hw: Hardware, batch_size: int,
                       mean_context: int = 1024) -> float:
    """One continuous-batching decode iteration (one token per request).

    Memory-bound roofline: the active weights stream once per iteration
    (amortized over the batch) plus each running request's KV; compared
    against the batch's matmul FLOPs, whichever dominates."""
    wb = cfg.active_param_count() * 2                       # bf16 weights
    kv = batch_size * mean_context * kv_bytes_per_token(cfg)
    t_mem = (wb + kv) / (hw.hbm_bw * hw.chips_per_instance)
    flops = batch_size * 2 * cfg.active_param_count()
    t_fl = flops / (hw.peak_flops * hw.chips_per_instance * hw.mfu)
    return float(max(t_mem, t_fl))
