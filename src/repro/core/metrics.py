"""Ranking metrics: HR@K, MRR, NDCG@K (Table III)."""
from __future__ import annotations


import numpy as np


def ranks_from_scores(scores: np.ndarray) -> np.ndarray:
    """Higher score = better rank (0 = top)."""
    order = np.argsort(-scores)
    ranks = np.empty_like(order)
    ranks[order] = np.arange(len(scores))
    return ranks


def hr_at_k(rank_of_gold: np.ndarray, k: int) -> float:
    return float((rank_of_gold < k).mean())


def mrr(rank_of_gold: np.ndarray) -> float:
    return float((1.0 / (rank_of_gold + 1)).mean())


def ndcg_at_k(rank_of_gold: np.ndarray, k: int) -> float:
    """Single-relevant-item NDCG (ideal DCG = 1)."""
    gains = np.where(rank_of_gold < k,
                     1.0 / np.log2(rank_of_gold + 2), 0.0)
    return float(gains.mean())


def table_iii_metrics(rank_of_gold: np.ndarray) -> dict:
    return {
        "HR@1": hr_at_k(rank_of_gold, 1),
        "HR@3": hr_at_k(rank_of_gold, 3),
        "HR@5": hr_at_k(rank_of_gold, 5),
        "HR@10": hr_at_k(rank_of_gold, 10),
        "MRR": mrr(rank_of_gold),
        "NDCG@5": ndcg_at_k(rank_of_gold, 5),
        "NDCG@10": ndcg_at_k(rank_of_gold, 10),
        "NDCG@20": ndcg_at_k(rank_of_gold, 20),
    }


def ranking_agreement_ndcg(ref_scores: np.ndarray, approx_scores: np.ndarray,
                           k: int = 10) -> float:
    """Fidelity of an approximate ranking vs the Full-Recompute ranking:
    NDCG@k of the approx order using the reference order as graded truth."""
    n = len(ref_scores)
    ref_rank = ranks_from_scores(ref_scores)
    rel = np.maximum(0.0, np.log2(n) - np.log2(ref_rank + 1))  # graded rel
    order = np.argsort(-approx_scores)
    dcg = sum(rel[order[i]] / np.log2(i + 2) for i in range(min(k, n)))
    ideal_order = np.argsort(-rel)
    idcg = sum(rel[ideal_order[i]] / np.log2(i + 2) for i in range(min(k, n)))
    return float(dcg / max(idcg, 1e-9))
