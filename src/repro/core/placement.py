"""Algorithm 1: similarity-aware item placement with global hot replicas.

Phase 1  compute item popularity from historical requests
Phase 2  replicate the top 0.1% hottest items on every instance
Phase 3  long-tail items become graph nodes
Phase 4  edge weights = co-occurrence counts in historical requests
Phase 5  k-way partition minimizing edge cut under a balance constraint

METIS is not available offline, so Phase 5 is our own multilevel-flavored
partitioner: LDG-style weighted greedy streaming (heavy items first) followed
by boundary Kernighan–Lin refinement sweeps.  Same objective, same contract.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Sequence, Tuple

import numpy as np


@dataclass
class Placement:
    k: int
    hot_items: np.ndarray                  # replicated everywhere
    shard_of: np.ndarray                   # (n_items,) int32; -1 for hot
    edge_cut: float
    balance: np.ndarray                    # heat per shard

    def holders(self, item: int) -> Sequence[int]:
        if self.shard_of[item] < 0:
            return range(self.k)
        return (int(self.shard_of[item]),)

    def is_local(self, item: int, instance: int) -> bool:
        s = self.shard_of[item]
        return s < 0 or s == instance

    def items_on(self, instance: int) -> np.ndarray:
        return np.where((self.shard_of == instance) | (self.shard_of < 0))[0]

    def hit_rate(self, items: Sequence[int], instance: int) -> float:
        """Fraction of `items` resident on `instance` (hot replicas hit
        everywhere).  Runtime-facing: the cluster reports this per worker."""
        if len(items) == 0:
            return 1.0
        return float(np.mean([self.is_local(int(i), instance)
                              for i in items]))


def popularity_from_requests(n_items: int,
                             request_items: Sequence[np.ndarray]) -> np.ndarray:
    h = np.zeros(n_items, np.float64)
    for items in request_items:
        np.add.at(h, items, 1.0)
    return h


def cooccurrence_graph(n_items: int, request_items: Sequence[np.ndarray],
                       max_pairs_per_request: int = 64,
                       seed: int = 0) -> Dict[Tuple[int, int], float]:
    """Edge weights = co-occurrence counts (sampled pairs for long requests)."""
    rng = np.random.default_rng(seed)
    edges: Dict[Tuple[int, int], float] = {}
    for items in request_items:
        it = np.unique(items)
        n = len(it)
        pairs = [(int(it[i]), int(it[j]))
                 for i in range(n) for j in range(i + 1, n)]
        if len(pairs) > max_pairs_per_request:
            idx = rng.choice(len(pairs), max_pairs_per_request, replace=False)
            pairs = [pairs[i] for i in idx]
        for a, b in pairs:
            e = (a, b) if a < b else (b, a)
            edges[e] = edges.get(e, 0.0) + 1.0
    return edges


def partition(n_items: int, popularity: np.ndarray,
              edges: Dict[Tuple[int, int], float], k: int,
              hot_frac: float = 0.001, balance_slack: float = 1.1,
              refine_sweeps: int = 2, seed: int = 0) -> Placement:
    """Algorithm 1, Phases 1–5."""
    order = np.argsort(-popularity)
    n_hot = max(1, int(np.ceil(hot_frac * n_items)))
    hot = order[:n_hot]
    hot_set = set(int(h) for h in hot)

    # adjacency over cold items only
    adj: List[Dict[int, float]] = [dict() for _ in range(n_items)]
    for (a, b), w in edges.items():
        if a in hot_set or b in hot_set:
            continue                        # hot replicas cut no edges
        adj[a][b] = adj[a].get(b, 0.0) + w
        adj[b][a] = adj[b].get(a, 0.0) + w

    shard_of = np.full(n_items, -2, np.int32)
    shard_of[hot] = -1
    heat = np.zeros(k, np.float64)
    cap = popularity[order[n_hot:]].sum() / k * balance_slack + 1e-9

    # Phase 5a: LDG greedy streaming in BFS order over the similarity graph
    # (neighbors stream consecutively so the locality gain term is live;
    # components are seeded in popularity order — heavy clusters first).
    cold_order = []
    visited = np.zeros(n_items, bool)
    visited[hot] = True
    import collections
    for seed_i in order[n_hot:]:
        seed_i = int(seed_i)
        if visited[seed_i]:
            continue
        dq = collections.deque([seed_i])
        visited[seed_i] = True
        while dq:
            u = dq.popleft()
            cold_order.append(u)
            nbrs = sorted(adj[u].items(), key=lambda kv: -kv[1])
            for vtx, _w in nbrs:
                if not visited[vtx]:
                    visited[vtx] = True
                    dq.append(vtx)
    for i in cold_order:
        i = int(i)
        gain = np.zeros(k)
        for j, w in adj[i].items():
            if shard_of[j] >= 0:
                gain[shard_of[j]] += w
        penalty = heat / cap
        score = gain + 1e-6 - penalty * (1e-6 + gain.mean() + 1.0)
        score[heat + popularity[i] > cap] = -np.inf
        tgt = int(np.argmax(score))
        if not np.isfinite(score[tgt]):
            tgt = int(np.argmin(heat))
        shard_of[i] = tgt
        heat[tgt] += popularity[i]

    # Phase 5b: KL-style boundary refinement
    cold = [int(i) for i in order[n_hot:]]
    for _ in range(refine_sweeps):
        moved = 0
        for i in cold:
            s = shard_of[i]
            gain = np.zeros(k)
            for j, w in adj[i].items():
                if shard_of[j] >= 0:
                    gain[shard_of[j]] += w
            best = int(np.argmax(gain))
            if best != s and gain[best] > gain[s] and \
               heat[best] + popularity[i] <= cap:
                shard_of[i] = best
                heat[s] -= popularity[i]
                heat[best] += popularity[i]
                moved += 1
        if moved == 0:
            break

    cut = 0.0
    for (a, b), w in edges.items():
        sa, sb = shard_of[a], shard_of[b]
        if sa >= 0 and sb >= 0 and sa != sb:
            cut += w
    return Placement(k=k, hot_items=np.sort(hot).astype(np.int32),
                     shard_of=shard_of, edge_cut=cut, balance=heat)


def place(n_items: int, request_items: Sequence[np.ndarray], k: int,
          **kw) -> Placement:
    """Full Algorithm-1 pipeline from a historical request log."""
    pop = popularity_from_requests(n_items, request_items)
    edges = cooccurrence_graph(n_items, request_items)
    return partition(n_items, pop, edges, k, **kw)


def random_placement(n_items: int, popularity: np.ndarray, k: int,
                     hot_frac: float = 0.001, seed: int = 0) -> Placement:
    """Ablation baseline: hash-random sharding (no similarity awareness)."""
    rng = np.random.default_rng(seed)
    order = np.argsort(-popularity)
    n_hot = max(1, int(np.ceil(hot_frac * n_items)))
    shard_of = rng.integers(0, k, n_items).astype(np.int32)
    shard_of[order[:n_hot]] = -1
    heat = np.zeros(k)
    for i in range(n_items):
        if shard_of[i] >= 0:
            heat[shard_of[i]] += popularity[i]
    return Placement(k=k, hot_items=np.sort(order[:n_hot]).astype(np.int32),
                     shard_of=shard_of, edge_cut=float("nan"), balance=heat)


def needs_refresh(old_pop: np.ndarray, new_pop: np.ndarray,
                  drift_threshold: float = 0.25) -> bool:
    """Popularity-drift trigger for background re-execution of Algorithm 1."""
    a = old_pop / max(old_pop.sum(), 1e-9)
    b = new_pop / max(new_pop.sum(), 1e-9)
    return float(np.abs(a - b).sum()) / 2.0 > drift_threshold
