"""Algorithmic baselines re-implemented for the accuracy comparison
(Table III): CacheBlend [EuroSys'25] and EPIC [ICML'25].

Both reuse the same assembled cache blocks as RcLLM but differ in how they
correct (or fail to correct) the approximation:

* CacheBlend: recompute tokens ranked purely by KV deviation (Eq. 3 with
  λ=1, one global budget), treats chunks as unstructured context — no
  heavy-hitter structure protection, and reuses cached KV at the blocks'
  ORIGINAL positions (no RoPE realignment of the stitched layout — the
  positional misalignment the paper blames for its ranking degradation).
* EPIC: position-independent blocks with a STATIC recompute pattern — the
  first `k_link` tokens of every block (AttnLink) — no per-request
  adaptivity, no divergence correction.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.configs.base import LMConfig
from repro.core.assembly import FROM_ITEM, FROM_SEMANTIC, RECOMPUTE, AssemblyPlan
from repro.core.engine import EngineStats, _jit_layer0, _pad_to, run_selective_layers


def _layer0(params, cfg, plan, cached_k, cached_v, bucket=128):
    n = plan.n
    n_pad = ((n + bucket - 1) // bucket) * bucket
    toks = _pad_to(plan.tokens.astype(np.int32), n_pad)
    ckp = _pad_to(cached_k.astype(np.float32), n_pad)
    cvp = _pad_to(cached_v.astype(np.float32), n_pad)
    valid = np.zeros(n_pad, bool)
    valid[:n] = True
    x, attn_mass, div_raw = _jit_layer0(
        params, jnp.asarray(toks), jnp.asarray(valid),
        jnp.asarray(ckp[:, 0]), jnp.asarray(cvp[:, 0]), cfg)
    return x, np.asarray(div_raw)[:n], ckp, cvp


def _stats(plan, recompute):
    return EngineStats(
        n_tokens=plan.n, n_recomputed=int(recompute.sum()),
        n_reused_item=int(((plan.source == FROM_ITEM) & ~recompute).sum()),
        n_reused_semantic=int(((plan.source == FROM_SEMANTIC)
                               & ~recompute).sum()),
        n_heavy_hitters=0, layer0_full=True)


def cacheblend_prefill_logits(params, cfg: LMConfig, plan: AssemblyPlan,
                              cached_k, cached_v, have_cache,
                              r: float = 0.15):
    """CacheBlend: single global budget, deviation-only selection, cached KV
    kept at the block's original position (no realignment of the stitch)."""
    n = plan.n
    x, dev, ckp, cvp = _layer0(params, cfg, plan, cached_k, cached_v)
    dev = dev * have_cache.astype(np.float32)

    recompute = ~have_cache.copy()
    recompute |= plan.seg_kind == 0        # true prefix = real prefix hit
    cand = np.where(~recompute)[0]
    k_top = int(np.ceil(r * n))
    top = cand[np.argsort(-dev[cand])[:min(k_top, len(cand))]]
    recompute[top] = True

    # ORIGINAL positions: blocks stay where they were cached (item blocks at
    # offset-0-based positions, prototypes at their canonical position)
    realign = np.where(plan.source == RECOMPUTE, np.arange(n),
                       np.arange(n) - plan.rope_delta)
    logits = run_selective_layers(params, cfg, x, recompute, ckp, cvp, n,
                                  key_positions=realign)
    return logits, _stats(plan, recompute)


def epic_prefill_logits(params, cfg: LMConfig, plan: AssemblyPlan,
                        cached_k, cached_v, have_cache, k_link: int = 2):
    """EPIC: position-independent reuse; static AttnLink recompute of the
    first k_link tokens of every reused block; no adaptive correction."""
    n = plan.n
    x, _, ckp, cvp = _layer0(params, cfg, plan, cached_k, cached_v)

    recompute = ~have_cache.copy()
    recompute |= plan.seg_kind == 0
    starts = np.zeros(n, bool)
    prev_src, prev_item = RECOMPUTE, -2
    for i in range(n):
        if plan.source[i] == FROM_ITEM:
            if plan.block_item[i] != prev_item:
                starts[i] = True
        elif plan.source[i] == FROM_SEMANTIC and prev_src != FROM_SEMANTIC:
            starts[i] = True
        prev_src = plan.source[i]
        prev_item = plan.block_item[i] if plan.source[i] == FROM_ITEM else -2
    for i in np.where(starts)[0]:
        recompute[i:i + k_link] = True

    # EPIC's contribution IS position independence → keys realigned
    logits = run_selective_layers(params, cfg, x, recompute, ckp, cvp, n)
    return logits, _stats(plan, recompute)
