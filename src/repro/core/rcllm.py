"""RcLLM system façade: offline build (both cache pools + placement) and
online ranking (full / rcllm / cacheblend / epic paths).

This is the public API the examples and accuracy benchmarks drive; the
distributed latency path is `repro.core.simulator`.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.configs.base import LMConfig
from repro.core import assembly as ASM
from repro.core import baselines as BASE
from repro.core import engine as ENG
from repro.core import item_cache as IC
from repro.core import placement as PL
from repro.core import semantic_cache as SC
from repro.core.engine import SelectiveConfig
from repro.data import synth as SY


@dataclass
class RcLLMSystem:
    cfg: LMConfig
    params: Dict
    catalog: SY.Catalog
    instruction: np.ndarray
    token_embed: np.ndarray
    semantic: Optional[SC.SemanticCache]
    item_store: Optional[IC.ItemKVStore]
    placement: PL.Placement

    # ----------------------------- offline -----------------------------
    @staticmethod
    def build(params, cfg: LMConfig, catalog: SY.Catalog,
              review_corpus: List[np.ndarray], history_requests,
              k_instances: int = 4, n_instruction: int = 207,
              item_coverage: float = 1.0, lsh_bits: int = 12,
              seed: int = 0) -> "RcLLMSystem":
        instruction = SY.make_instruction(n_instruction, catalog.vocab_size)
        token_embed = np.asarray(params["embed"], np.float32)

        # placement from the historical request log (Algorithm 1)
        req_items = [r.candidate_items for r in history_requests]
        placement = PL.place(catalog.n_items, req_items, k_instances)

        # batched, length-bucketed offline KV materialization
        corpus_kv = ENG.precompute_kv_batch(params, cfg, review_corpus)
        corpus_lookup = lambda i: corpus_kv[i]

        semantic = SC.build_semantic_cache(
            review_corpus, token_embed, n_bits=lsh_bits, seed=seed)
        SC.materialize_kv(semantic, review_corpus,
                          lambda toks, _i=None: None,
                          kv_by_doc=corpus_lookup)

        item_docs = [np.concatenate([[SY.ITEM_SEP], t]).astype(np.int32)
                     for t in catalog.item_tokens]
        item_kv = ENG.precompute_kv_batch(params, cfg, item_docs)
        item_store = IC.build_item_store(
            item_docs, placement,
            kv_of_sequence=None, kv_list=item_kv,
            coverage=item_coverage, seed=seed)
        return RcLLMSystem(cfg=cfg, params=params, catalog=catalog,
                           instruction=instruction, token_embed=token_embed,
                           semantic=semantic, item_store=item_store,
                           placement=placement)

    # ----------------------------- online ------------------------------
    def plan_for(self, request: SY.Request, instance: int = 0
                 ) -> ASM.AssemblyPlan:
        tokens, kind, ids = request.prompt_segments(self.catalog,
                                                    self.instruction)
        n_instr = len(self.instruction)
        marker = np.zeros(len(tokens), bool)
        hist_start = n_instr
        hm = request.history_marker_mask
        marker[hist_start:hist_start + len(hm)] = hm
        return ASM.build_plan(
            tokens, kind, ids,
            marker_mask=hm, item_store=self.item_store,
            semantic=self.semantic, token_embed=self.token_embed,
            instance=instance)

    def cached_kv(self, plan: ASM.AssemblyPlan, instance: int = 0):
        """Materialized assembled (k, v, have) for a plan on one instance."""
        return ASM.gather_cached_kv(
            plan, self.item_store, self.semantic, instance,
            self.cfg.n_layers, self.cfg.n_kv_heads,
            self.cfg.resolved_head_dim)

    _cached_kv = cached_kv                  # backward-compatible alias

    def best_instance(self, request: SY.Request) -> int:
        """Affinity routing (idle cluster → pure cache affinity)."""
        from repro.core import scheduler as SCH
        return int(np.argmax(SCH.hit_vector(request.candidate_items,
                                            self.placement)))

    def rank(self, request: SY.Request, method: str = "rcllm",
             sel: Optional[SelectiveConfig] = None,
             instance: Optional[int] = None
             ) -> Tuple[np.ndarray, Optional[ENG.EngineStats]]:
        """-> (scores over the request's candidate slots, stats)."""
        sel = sel or SelectiveConfig()
        if instance is None:
            instance = self.best_instance(request)
        n_cand = len(request.candidate_items)
        tokens, kind, ids = request.prompt_segments(self.catalog,
                                                    self.instruction)
        if method == "full":
            logits = ENG.full_prefill_logits(self.params, self.cfg, tokens)
            return logits[SY.SLOT_BASE:SY.SLOT_BASE + n_cand], None

        plan = self.plan_for(request, instance)
        ck, cv, have = self._cached_kv(plan, instance)
        if method == "rcllm":
            logits, stats = ENG.selective_prefill_logits(
                self.params, self.cfg, plan, ck, cv, have, sel)
        elif method == "cacheblend":
            logits, stats = BASE.cacheblend_prefill_logits(
                self.params, self.cfg, plan, ck, cv, have,
                r=(sel.r_item + sel.r_rev) / 2)
        elif method == "epic":
            logits, stats = BASE.epic_prefill_logits(
                self.params, self.cfg, plan, ck, cv, have)
        else:
            raise ValueError(method)
        return logits[SY.SLOT_BASE:SY.SLOT_BASE + n_cand], stats


def make_tiny_system(profile_name: str = "amazon", n_items: int = 300,
                     k_instances: int = 4, n_requests_hist: int = 200,
                     seed: int = 0, n_layers: int = 4, d_model: int = 64,
                     item_coverage: float = 1.0, n_heads: int = 4,
                     n_kv_heads: int = 2):
    """A small end-to-end RcLLM instance for tests/benchmarks on CPU.
    ``n_heads``/``n_kv_heads`` are overridable so the mesh parity tests
    can build a model whose head counts divide higher tp degrees."""
    from repro.models import transformer as T

    prof = dataclasses.replace(SY.PROFILES[profile_name], n_items=n_items,
                               n_clusters=max(6, n_items // 50),
                               mean_item_tokens=24, mean_review_tokens=20)
    catalog = SY.make_catalog(prof, vocab_size=4096, seed=seed)
    pool = SY.make_review_pool(vocab_size=4096, n_phrases=120, seed=seed + 1)
    hist = SY.make_trace(catalog, pool, prof, n_requests=n_requests_hist,
                         qps=10.0, n_users=40, n_candidates=8,
                         reviews_per_user=2, seed=seed + 2)
    corpus = []
    seen = set()
    for r in hist:
        if r.user_id not in seen:
            corpus.append(r.history_tokens)
            seen.add(r.user_id)

    cfg = LMConfig(name="rcllm-tiny", n_layers=n_layers, d_model=d_model,
                   n_heads=n_heads, n_kv_heads=n_kv_heads, head_dim=16, d_ff=128,
                   vocab_size=4096, mlp_type="swiglu", dtype="float32",
                   attn_q_chunk=64, attn_kv_chunk=64, remat=False)
    params = T.init_params(jax.random.PRNGKey(seed), cfg)
    system = RcLLMSystem.build(params, cfg, catalog, corpus, hist,
                               k_instances=k_instances,
                               item_coverage=item_coverage, seed=seed)
    return system, pool, prof, hist
