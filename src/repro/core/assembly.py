"""Zero-copy KV assembly (§III-C2a + §III-C3).

A request's logical prompt is mapped onto scattered physical KV blocks:
instruction tokens are always recomputed; review tokens resolve to semantic
prototypes; item tokens resolve to item blocks (local / remote / miss).
Nothing is physically concatenated here — the plan is an index table
(logical position → block ref + offset + RoPE delta), exactly what the
`block_gather` Pallas kernel consumes on TPU, where 'zero-copy' materializes
as block-table indirection in HBM instead of a CPU↔GPU UVA path.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.item_cache import ItemKVStore
from repro.core.semantic_cache import (SemanticCache,
                                       embed_tokens_for_match)

# token sources
RECOMPUTE, FROM_ITEM, FROM_SEMANTIC = 0, 1, 2


@dataclass
class AssemblyPlan:
    tokens: np.ndarray                 # (n,) prompt token ids
    seg_kind: np.ndarray               # 0 instr / 1 history / 2 item
    source: np.ndarray                 # RECOMPUTE / FROM_ITEM / FROM_SEMANTIC
    block_item: np.ndarray             # item id for FROM_ITEM tokens, -1 else
    block_offset: np.ndarray           # offset inside the item block
    proto_id: np.ndarray               # prototype id for FROM_SEMANTIC, -1
    rope_delta: np.ndarray             # target_pos − cached_pos (realignment)
    n_local: int = 0
    n_remote: int = 0
    n_miss: int = 0

    @property
    def n(self) -> int:
        return len(self.tokens)

    def reuse_fraction(self) -> float:
        return float((self.source != RECOMPUTE).mean())


def build_plan(tokens: np.ndarray, seg_kind: np.ndarray, seg_id: np.ndarray,
               marker_mask: Optional[np.ndarray],
               item_store: Optional[ItemKVStore],
               semantic: Optional[SemanticCache],
               token_embed: Optional[np.ndarray],
               instance: int = 0,
               min_semantic_sim: float = 0.85) -> AssemblyPlan:
    """Decompose one prompt into its reuse plan (§III-C2a i–iii)."""
    n = len(tokens)
    source = np.zeros(n, np.int32)
    block_item = np.full(n, -1, np.int32)
    block_offset = np.zeros(n, np.int32)
    proto_id = np.full(n, -1, np.int32)
    rope_delta = np.zeros(n, np.int32)
    n_local = n_remote = n_miss = 0

    # --- candidate item tokens: exact blocks by item id ---
    if item_store is not None:
        item_positions: Dict[int, List[int]] = {}
        for i in np.where(seg_kind == 2)[0]:
            item_positions.setdefault(int(seg_id[i]), []).append(int(i))
        items = list(item_positions)
        local, remote, miss = item_store.lookup(items, instance)
        status = {it: "local" for it in local}
        status.update({it: "remote" for it in remote})
        status.update({it: "miss" for it in miss})
        for it, positions in item_positions.items():
            st = status[it]
            blk = item_store.get_block(it, instance)
            if st == "miss" or blk is None:
                n_miss += len(positions)
                continue                     # stays RECOMPUTE
            if st == "local":
                n_local += len(positions)
            else:
                n_remote += len(positions)
            for off, pos in enumerate(positions):
                if off >= len(blk.tokens):
                    continue
                source[pos] = FROM_ITEM
                block_item[pos] = it
                block_offset[pos] = off
                rope_delta[pos] = pos - off   # block cached at canonical 0

    # --- history/review tokens: nearest semantic prototype ---
    if semantic is not None and token_embed is not None:
        hist = np.where(seg_kind == 1)[0]
        if len(hist) > 0:
            # instance-specific fields (timestamps, separators) never reuse
            reusable = np.ones(len(hist), bool)
            if marker_mask is not None:
                reusable &= ~marker_mask[:len(hist)]
            # match at history-RELATIVE positions: the cache's
            # (pos_bucket, code) keys were built from review docs at
            # doc-relative positions, while the history sits behind the
            # instruction in the prompt — hashing with absolute prompt
            # positions lands every token in a position bucket the cache
            # never populated, silently disabling semantic reuse.  RoPE
            # realignment below still uses absolute positions.
            pos = (hist - hist[0]).astype(np.int64)
            emb = embed_tokens_for_match(tokens[hist], pos, token_embed)
            pid, sim = semantic.match(tokens[hist], pos, emb)
            ok = reusable & (pid >= 0) & (sim >= min_semantic_sim) \
                & (semantic.proto_k is not None)
            for j in np.where(ok)[0]:
                i = hist[j]
                source[i] = FROM_SEMANTIC
                proto_id[i] = pid[j]
                rope_delta[i] = i - semantic.proto_position[pid[j]]

    return AssemblyPlan(tokens=tokens, seg_kind=seg_kind, source=source,
                        block_item=block_item, block_offset=block_offset,
                        proto_id=proto_id, rope_delta=rope_delta,
                        n_local=n_local, n_remote=n_remote, n_miss=n_miss)


@dataclass(frozen=True)
class PlanSpan:
    """A maximal contiguous run of one physical KV block inside a plan."""
    start: int                         # logical token range [start, end)
    end: int
    source: int                        # RECOMPUTE / FROM_ITEM / FROM_SEMANTIC
    block_id: int                      # item id / prototype id / -1

    @property
    def n(self) -> int:
        return self.end - self.start


def plan_spans(plan: AssemblyPlan) -> List[PlanSpan]:
    """Decompose a plan into contiguous block spans.

    The paged serving pool consumes these for block-granular insertion:
    each FROM_ITEM / FROM_SEMANTIC span is one slice-copy out of a cached
    block, and RECOMPUTE spans are filled later by the selective engine.
    Spans partition [0, plan.n) exactly.
    """
    spans: List[PlanSpan] = []
    n = plan.n
    i = 0
    while i < n:
        src = int(plan.source[i])
        if src == FROM_ITEM:
            bid = int(plan.block_item[i])
        elif src == FROM_SEMANTIC:
            bid = int(plan.proto_id[i])
        else:
            bid = -1
        j = i + 1
        while j < n and int(plan.source[j]) == src:
            if src == FROM_ITEM and (int(plan.block_item[j]) != bid or
                                     int(plan.block_offset[j]) !=
                                     int(plan.block_offset[j - 1]) + 1):
                break
            if src == FROM_SEMANTIC and int(plan.proto_id[j]) != bid:
                break
            j += 1
        spans.append(PlanSpan(start=i, end=j, source=src, block_id=bid))
        i = j
    return spans


def gather_cached_kv(plan: AssemblyPlan, item_store: Optional[ItemKVStore],
                     semantic: Optional[SemanticCache], instance: int,
                     n_layers: int, n_kv: int, head_dim: int
                     ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Materialize the assembled (pre-RoPE) cached KV for every reuse token.

    -> (k, v): (n, L, Hkv, Dh) float arrays (zeros where RECOMPUTE),
       have_cache: (n,) bool.  The TPU execution path does this gather inside
       the attention kernel (repro/kernels/block_gather); this host version
       is the engine/ref implementation.
    """
    n = plan.n
    k = np.zeros((n, n_layers, n_kv, head_dim), np.float32)
    v = np.zeros((n, n_layers, n_kv, head_dim), np.float32)
    have = np.zeros(n, bool)
    for i in range(n):
        if plan.source[i] == FROM_ITEM and item_store is not None:
            blk = item_store.get_block(int(plan.block_item[i]), instance)
            off = int(plan.block_offset[i])
            if blk is not None and off < blk.k.shape[0]:
                k[i] = blk.k[off]
                v[i] = blk.v[off]
                have[i] = True
        elif plan.source[i] == FROM_SEMANTIC and semantic is not None \
                and semantic.proto_k is not None:
            pid = int(plan.proto_id[i])
            k[i] = semantic.proto_k[pid]
            v[i] = semantic.proto_v[pid]
            have[i] = True
    return k, v, have
