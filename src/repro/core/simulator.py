"""Discrete-event cluster simulator (the paper's Vidur-based engine, §III-D).

K stateful instances (each holding an item-KV shard + the replicated
semantic pool), a global scheduler routing by Eq. 2, per-instance FIFO
queues, and the analytic cost model as the clock.  Supports node failures
(requests re-routed; instance restored after repair — the serving-side face
of fault tolerance), stragglers (slowdown factors), and hedged requests.

Outputs per-request TTFT → P50/P90/P99 + CDFs (Figs. 6, 8, 10, 11), cache
hit rates and per-replica footprints (Fig. 9).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.configs.base import LMConfig
from repro.core import cost_model as CM
from repro.core.placement import Placement
from repro.core.scheduler import SchedulerState, route


@dataclass
class SimRequest:
    arrival_s: float
    n_total: int                     # prompt tokens
    n_instr: int
    item_ids: np.ndarray
    item_token_counts: np.ndarray
    n_history: int
    history_reuse_frac: float        # fraction of history tokens matched


@dataclass
class SimConfig:
    policy: str = "affinity"
    alpha: float = 0.7
    beta: float = 0.3
    mode: str = "rcllm"              # rcllm | full | prefix
    r_item: float = 0.3
    r_rev: float = 0.3
    window: int = 32
    # §III-C2a iii: item-cache misses are recomputed on-the-fly (the paper
    # never fetches item KV across nodes).  remote_fetch=True is our
    # beyond-paper option that pulls peer blocks over the interconnect.
    remote_fetch: bool = False
    hedge_ms: Optional[float] = None     # straggler mitigation: backup send
    seed: int = 0


@dataclass
class NodeFault:
    instance: int
    t_fail_s: float
    t_repair_s: float


@dataclass
class SimResult:
    ttft_s: np.ndarray
    hit_rates: np.ndarray
    per_instance_load: np.ndarray
    n_requests: int

    def pct(self, q: float) -> float:
        return float(np.percentile(self.ttft_s, q))

    def summary(self) -> Dict[str, float]:
        return {"p50": self.pct(50), "p90": self.pct(90), "p99": self.pct(99),
                "mean": float(self.ttft_s.mean()),
                "mean_hit": float(self.hit_rates.mean())}


def _service_time(cfg: LMConfig, hw: CM.Hardware, req: SimRequest,
                  placement: Placement, instance: int, sim: SimConfig,
                  slow: float) -> Tuple[float, float]:
    """-> (service seconds, hit_rate)."""
    if sim.mode == "full":
        return slow * CM.full_prefill_ttft_s(cfg, hw, req.n_total), 0.0
    if sim.mode == "prefix":
        return slow * CM.prefix_cache_ttft_s(cfg, hw, req.n_total,
                                             req.n_instr), 0.0

    # RcLLM: resolve item blocks against this instance's shard
    local_t = remote_t = miss_t = 0
    for it, tc in zip(req.item_ids, req.item_token_counts):
        s = placement.shard_of[int(it)]
        if s < 0 or s == instance:
            local_t += int(tc)
        elif sim.remote_fetch:
            remote_t += int(tc)
        else:
            miss_t += int(tc)            # recomputed on-the-fly (paper)
    hist_hit = int(req.history_reuse_frac * req.n_history)
    local_t += hist_hit                  # semantic pool is replicated

    n_cached_items = local_t - hist_hit + remote_t
    n_rec = (req.n_instr
             + int(sim.r_item * n_cached_items) + miss_t
             + int(sim.r_rev * hist_hit) + (req.n_history - hist_hit)
             + sim.window)
    n_rec = min(n_rec, req.n_total)
    t = CM.ttft_s(cfg, hw, req.n_total, n_rec, local_t, remote_t)
    hit = (local_t + remote_t) / max(req.n_total - req.n_instr, 1)
    return slow * t, hit


def simulate(cfg: LMConfig, hw: CM.Hardware, requests: Sequence[SimRequest],
             placement: Placement, sim: SimConfig,
             straggler_factors: Optional[np.ndarray] = None,
             faults: Sequence[NodeFault] = ()) -> SimResult:
    k = placement.k
    state = SchedulerState.fresh(k)
    rng = np.random.default_rng(sim.seed)
    free_at = np.zeros(k)                      # next idle time per instance
    slow = straggler_factors if straggler_factors is not None else np.ones(k)
    ttfts, hits = [], []
    load_count = np.zeros(k)

    def is_down(p: int, t: float) -> bool:
        return any(f.instance == p and f.t_fail_s <= t < f.t_repair_s
                   for f in faults)

    for req in requests:
        t = req.arrival_s
        # scheduler sees queue depth in seconds of outstanding work
        state.queue_depth = np.maximum(free_at - t, 0.0)
        for p in range(k):
            if is_down(p, t):
                state.queue_depth[p] = 1e9    # effectively unroutable
        p = route(req.item_ids, placement, state, policy=sim.policy,
                  alpha=sim.alpha, beta=sim.beta, rng=rng)
        if is_down(p, t):                      # re-route around the fault
            up = [i for i in range(k) if not is_down(i, t)]
            p = up[int(np.argmin(free_at[np.asarray(up)]))] if up else p

        svc, hit = _service_time(cfg, hw, req, placement, p, sim, slow[p])
        start = max(t, free_at[p])

        if sim.hedge_ms is not None:
            # straggler mitigation: if the primary hasn't started within the
            # hedge deadline, a backup instance races it (use the earlier).
            deadline = t + sim.hedge_ms * 1e-3
            if start > deadline:
                alt = int(np.argmin(free_at))
                if alt != p and not is_down(alt, t):
                    svc_alt, hit_alt = _service_time(
                        cfg, hw, req, placement, alt, sim, slow[alt])
                    start_alt = max(t, free_at[alt])
                    if start_alt + svc_alt < start + svc:
                        p, svc, hit, start = alt, svc_alt, hit_alt, start_alt

        finish = start + svc
        free_at[p] = finish
        load_count[p] += 1
        ttfts.append(finish - t)
        hits.append(hit)

    return SimResult(ttft_s=np.asarray(ttfts), hit_rates=np.asarray(hits),
                     per_instance_load=load_count, n_requests=len(requests))


def make_sim_setup(profile_name: str = "amazon", k: int = 40,
                   n_requests: int = 2000, qps: float = 80.0,
                   n_candidates: int = 20, n_users: int = 500,
                   n_items: Optional[int] = None, seed: int = 0,
                   placement_kind: str = "similarity"):
    """Paper-scale simulation inputs (numpy-only — no model, no KV arrays):
    a profile-shaped catalog, a request trace with the paper's prompt
    composition (median prefill 2.2–3.0K tokens, 207-token instruction),
    and an Algorithm-1 placement built from a separate history trace."""
    import dataclasses as _dc

    from repro.core import placement as PL
    from repro.data import synth as SY

    prof = SY.PROFILES[profile_name]
    if n_items is not None:
        # keep ~50 items per co-occurrence cluster (the profile default) so
        # candidate sets remain coverable by one replica at smaller catalogs
        prof = _dc.replace(prof, n_items=n_items,
                           n_clusters=max(8, n_items // 50))
    catalog = SY.make_catalog(prof, seed=seed)
    pool = SY.make_review_pool(seed=seed + 1)
    hist = SY.make_trace(catalog, pool, prof, n_requests=max(500, k * 20),
                         qps=qps, n_users=n_users, n_candidates=n_candidates,
                         seed=seed + 2, cluster_bias=0.85)
    req_items = [r.candidate_items for r in hist]
    if placement_kind == "similarity":
        placement = PL.place(catalog.n_items, req_items, k)
    else:
        pop = PL.popularity_from_requests(catalog.n_items, req_items)
        # independent seed: sharing the catalog RNG stream makes "random"
        # accidentally cluster-aligned (identical underlying uniforms)
        placement = PL.random_placement(catalog.n_items, pop, k,
                                        seed=seed + 7919)
    trace = SY.make_trace(catalog, pool, prof, n_requests=n_requests,
                          qps=qps, n_users=n_users,
                          n_candidates=n_candidates, seed=seed + 3,
                          cluster_bias=0.85)
    reqs = requests_from_trace(trace, catalog, n_instr=207)
    return reqs, placement, catalog


def requests_from_trace(trace, catalog, n_instr: int,
                        history_reuse_frac: float = 0.93) -> List[SimRequest]:
    """Convert synthetic trace Requests (repro.data.synth) to sim inputs.
    history_reuse_frac defaults to the paper's ≥93% match rate (Fig. 3b)."""
    out = []
    for r in trace:
        counts = np.asarray([len(catalog.item_tokens[i]) + 1
                             for i in r.candidate_items])
        out.append(SimRequest(
            arrival_s=r.arrival_s,
            n_total=n_instr + len(r.history_tokens) + int(counts.sum()) + 1,
            n_instr=n_instr,
            item_ids=np.asarray(r.candidate_items),
            item_token_counts=counts,
            n_history=len(r.history_tokens),
            history_reuse_frac=history_reuse_frac))
    return out
