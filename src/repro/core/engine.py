"""RcLLM local execution engine (§III-C2b, §III-C3) — the accuracy prototype.

Runs a real JAX transformer whose attention is modified for beyond-prefix
reuse:  layer 0 computes full attention for every token (cheap: 1/L of the
FLOPs) and scores tokens with Eq. 3

    S_i = (1−λ)·‖A_i‖₁ + λ·Σ_{M∈{K,V}} ‖M_i^new − M_i^cached‖₁

Heavy hitters, instruction tokens, instance-specific markers, cache misses
and the trailing local window are recomputed exactly through layers 1..L−1;
every other token's deeper-layer K/V comes from the assembled cache blocks
(pre-RoPE, rotated to the request position — exact positional realignment
by RoPE's group property).  This mirrors the paper's HuggingFace prototype,
in JAX.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import LMConfig
from repro.core.assembly import FROM_ITEM, FROM_SEMANTIC, AssemblyPlan
from repro.kernels import default_interpret
from repro.kernels.flash_attention.ops import mha_flash
from repro.kernels.selective_attention.ops import (build_block_liveness,
                                                  selective_mha)
from repro.models import layers as L

# Pallas tile sizes for the serving-path kernels.  The engine's shape
# buckets are multiples of 64, so these tiles add no padding on the tiny
# CI models while still being MXU-shaped (padded to 128 lanes by Mosaic)
# on real hardware.
PALLAS_Q_BLOCK = 64
PALLAS_KV_BLOCK = 64


def decode_uses_paged(cfg: LMConfig) -> bool:
    """Resolve `cfg.decode_kernel` for the serving decode step: does it
    read K/V through the fused paged-attention kernel (True) or the jnp
    arena gather (False)?  "auto" ties the choice to the attention
    backend — pallas decodes paged, jnp keeps the gather path as the
    bitwise oracle; "paged"/"gather" pin either path explicitly (the
    parity tests run the kernel under the jnp backend this way, so a
    decode-only diff can't hide behind prefill differences)."""
    if cfg.decode_kernel == "paged":
        return True
    if cfg.decode_kernel == "gather":
        return False
    if cfg.decode_kernel != "auto":
        raise ValueError(
            f"decode_kernel={cfg.decode_kernel!r}: want auto|gather|paged")
    return cfg.attn_backend == "pallas"

# Placeholder liveness map for the jnp backend: the jitted selective
# entry points take `live` positionally so the pallas/jnp traces share
# one signature; the jnp trace never reads it.
_NO_LIVE = np.zeros((1, 1, 1), np.int32)


@dataclass
class SelectiveConfig:
    r_item: float = 0.3               # recompute budget over item tokens
    r_rev: float = 0.3                # recompute budget over history tokens
    lam: float = 0.5                  # Eq. 3 λ (divergence weight)
    window: int = 32                  # trailing local window, always exact
    layer0_full: bool = True          # identify HH with full first layer


# ---------------------------------------------------------------------------
# Shared per-request building blocks.  `serving/batch_engine.py` reuses these
# (and the jitted entry points below) rather than duplicating the math, so
# the single-request and batched paths cannot drift apart.
# ---------------------------------------------------------------------------

def layer_params(params, l: int):
    return jax.tree_util.tree_map(lambda a: a[l], params["layers"])


def qkv_proj(h, lp, cfg: LMConfig, positions):
    """h: (S, D) -> rotated (q, k), pre-RoPE k_raw, and v: (S, H, Dh)."""
    q = jnp.einsum("sd,dhe->she", h, lp["wq"])
    k_raw = jnp.einsum("sd,dhe->she", h, lp["wk"])
    v = jnp.einsum("sd,dhe->she", h, lp["wv"])
    q = L.apply_rope(q[None], positions, cfg.rope_theta)[0]
    k = L.apply_rope(k_raw[None], positions, cfg.rope_theta)[0]
    return q, k, k_raw, v


def full_attn(q, k, v, cfg: LMConfig, q_pos, k_pos, return_probs=False,
              k_valid=None, contiguous=False):
    """Single-request attention: q (Sq, Hq, Dh) vs k/v (Sk, Hkv, Dh).

    `cfg.attn_backend` picks the implementation.  The pallas route
    (flash kernel) needs `contiguous=True` — the caller's assertion that
    q_pos/k_pos are the standard aranges, which the kernel's iota-based
    causal mask assumes — and cannot return probabilities (flash never
    materializes P), so Eq. 3 layer-0 scoring always takes the jnp path.
    """
    if cfg.attn_backend == "pallas" and contiguous and not return_probs:
        kv_valid = None if k_valid is None else k_valid[None]
        o = mha_flash(q[None], k[None], v[None], kv_valid=kv_valid,
                      causal=True, q_block=PALLAS_Q_BLOCK,
                      kv_block=PALLAS_KV_BLOCK,
                      interpret=default_interpret())[0]
        return o
    Hq, Hkv = q.shape[1], k.shape[1]
    G = Hq // Hkv
    scale = 1.0 / (q.shape[-1] ** 0.5)
    qr = q.reshape(q.shape[0], Hkv, G, -1)
    s = jnp.einsum("qhgd,khd->hgqk", qr, k,
                   preferred_element_type=jnp.float32) * scale
    mask = q_pos[:, None] >= k_pos[None, :]
    if k_valid is not None:
        mask = mask & k_valid[None, :]
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("hgqk,khd->qhgd", p.astype(v.dtype), v)
    o = o.reshape(q.shape[0], Hq, -1)
    if return_probs:
        return o, p
    return o


def full_attn_batched(q, k, v, cfg: LMConfig, q_pos, k_pos,
                      return_probs=False, k_valid=None):
    """Batched jnp attention: q (B, Sq, Hq, Dh) vs k/v (B, Sk, Hkv, Dh).

    q_pos/k_pos: (Sq,)/(Sk,) shared or (B, Sq)/(B, Sk) per row;
    k_valid: optional (B, Sk) bool.  The jnp reference for the batched
    selective path (the pallas route goes through `selective_mha`).
    """
    B, Sq = q.shape[:2]
    Sk = k.shape[1]
    Hq, Hkv = q.shape[2], k.shape[2]
    G = Hq // Hkv
    scale = 1.0 / (q.shape[-1] ** 0.5)
    qr = q.reshape(B, Sq, Hkv, G, -1)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qr, k,
                   preferred_element_type=jnp.float32) * scale
    qp = q_pos if q_pos.ndim == 2 else jnp.broadcast_to(q_pos[None], (B, Sq))
    kp = k_pos if k_pos.ndim == 2 else jnp.broadcast_to(k_pos[None], (B, Sk))
    mask = qp[:, :, None] >= kp[:, None, :]                 # (B, Sq, Sk)
    if k_valid is not None:
        mask = mask & k_valid[:, None, :]
    s = jnp.where(mask[:, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p.astype(v.dtype), v)
    o = o.reshape(B, Sq, Hq, -1)
    if return_probs:
        return o, p
    return o


def mlp_block(h, lp, cfg: LMConfig):
    """Dense/MoE MLP over a flat (T, D) or (S, D) token matrix."""
    from repro.models.layers import mlp_apply, moe_apply
    if cfg.moe is not None:
        y, _ = moe_apply(h, lp["moe"], n_experts=cfg.moe.n_experts,
                         top_k=cfg.moe.top_k,
                         capacity_factor=cfg.moe.capacity_factor,
                         mlp_type=cfg.mlp_type)
        return y
    return mlp_apply(h, lp["mlp"], cfg.mlp_type)


# Backward-compatible aliases (baselines.py and older call sites).
_layer_params = layer_params
_qkv = qkv_proj
_full_attn = full_attn
_mlp = mlp_block


def _batched_forward(params, toks, valid, cfg: LMConfig):
    """Shared padded (N, S) forward pass.

    -> (x, k_all, v_all): the final residual stream (N, S, D) plus the
    pre-RoPE per-layer caches (N, S, L, Hkv, Dh).  Invalid (padding) keys
    are masked out of the in-context attention via `valid` (N, S) bool.
    """
    N, S = toks.shape
    pos = jnp.arange(S)
    x = params["embed"][toks].astype(jnp.dtype(cfg.dtype))
    if cfg.tie_embeddings:
        x = x * (cfg.d_model ** 0.5)
    ks, vs = [], []
    for l in range(cfg.n_layers):
        lp = layer_params(params, l)
        h = L.rms_norm(x, lp["attn_norm"], cfg.norm_eps)
        q = jnp.einsum("nsd,dhe->nshe", h, lp["wq"])
        k_raw = jnp.einsum("nsd,dhe->nshe", h, lp["wk"])
        v = jnp.einsum("nsd,dhe->nshe", h, lp["wv"])
        ks.append(k_raw)
        vs.append(v)
        q = L.apply_rope(q, pos, cfg.rope_theta)
        k = L.apply_rope(k_raw, pos, cfg.rope_theta)
        if cfg.attn_backend == "pallas":
            o = mha_flash(q, k, v, kv_valid=valid, causal=True,
                          q_block=PALLAS_Q_BLOCK, kv_block=PALLAS_KV_BLOCK,
                          interpret=default_interpret())
        else:
            o = L.chunked_attention(q, k, v, causal=True, q_positions=pos,
                                    kv_positions=pos, kv_valid=valid,
                                    q_chunk=min(cfg.attn_q_chunk, S),
                                    kv_chunk=min(cfg.attn_kv_chunk, S))
        x = x + jnp.einsum("nshe,hed->nsd", o, lp["wo"])
        x = x + mlp_block_batched(L.rms_norm(x, lp["mlp_norm"], cfg.norm_eps),
                                  lp, cfg)
    k_all = jnp.stack(ks, axis=2)                          # (N, S, L, Hkv, Dh)
    v_all = jnp.stack(vs, axis=2)
    return x, k_all, v_all


@functools.partial(jax.jit, static_argnums=(2,))
def _batched_kv_jit(params, toks, cfg: LMConfig):
    """toks: (N, S) padded with PAD=0 → pre-RoPE (k, v): (N, S, L, Hkv, Dh).
    Padding keys are masked out of the in-context attention."""
    _, k_all, v_all = _batched_forward(params, toks, toks != 0, cfg)
    return k_all, v_all


@functools.partial(jax.jit, static_argnums=(3,))
def _jit_batched_prefill(params, toks, last_idx, cfg: LMConfig):
    """Padded multi-request full prefill for the batched serving engine.

    toks: (N, S) padded; last_idx: (N,) index of each request's final real
    token.  -> (logits (N, V), pre-RoPE k, v (N, S, L, Hkv, Dh)).
    """
    N, S = toks.shape
    valid = jnp.arange(S)[None, :] <= last_idx[:, None]
    x, k_all, v_all = _batched_forward(params, toks, valid, cfg)
    x_last = x[jnp.arange(N), last_idx]                    # (N, D)
    xf = L.rms_norm(x_last, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return xf @ head, k_all, v_all


def mlp_block_batched(h, lp, cfg: LMConfig):
    if cfg.moe is not None:
        N, S, D = h.shape
        y, _ = L.moe_apply(h.reshape(N * S, D), lp["moe"],
                           n_experts=cfg.moe.n_experts, top_k=cfg.moe.top_k,
                           capacity_factor=cfg.moe.capacity_factor,
                           mlp_type=cfg.mlp_type)
        return y.reshape(N, S, D)
    return L.mlp_apply(h, lp["mlp"], cfg.mlp_type)


_mlp_batched = mlp_block_batched


def precompute_kv_batch(params, cfg: LMConfig, docs, bucket: int = 64):
    """Batched offline KV materialization with length bucketing (keeps jit
    retraces bounded).  -> list of (S_i, L, Hkv, Dh) pre-RoPE (k, v)."""
    order = np.argsort([len(d) for d in docs])
    out = [None] * len(docs)
    i = 0
    while i < len(order):
        max_len = ((len(docs[order[i]]) + bucket - 1) // bucket) * bucket
        group = [j for j in order[i:i + 64]
                 if len(docs[j]) <= max_len]
        batch = np.zeros((len(group), max_len), np.int32)
        for gi, j in enumerate(group):
            batch[gi, :len(docs[j])] = docs[j]
        k, v = _batched_kv_jit(params, jnp.asarray(batch), cfg)
        k = np.asarray(k, np.float32)
        v = np.asarray(v, np.float32)
        for gi, j in enumerate(group):
            s = len(docs[j])
            out[j] = (k[gi, :s], v[gi, :s])
        i += len(group)
    return out


def precompute_kv(params, cfg: LMConfig, tokens: np.ndarray
                  ) -> Tuple[np.ndarray, np.ndarray]:
    """Offline KV materialization: run the model over one sequence at
    canonical positions and return PRE-RoPE per-layer K and V:
    (S, n_layers, Hkv, Dh).  Used to build both cache pools."""
    toks = jnp.asarray(tokens)
    S = toks.shape[0]
    pos = jnp.arange(S)
    x = params["embed"][toks].astype(jnp.dtype(cfg.dtype))
    if cfg.tie_embeddings:
        x = x * (cfg.d_model ** 0.5)
    ks, vs = [], []
    for l in range(cfg.n_layers):
        lp = _layer_params(params, l)
        h = L.rms_norm(x, lp["attn_norm"], cfg.norm_eps)
        q, k, k_raw, v = _qkv(h, lp, cfg, pos)
        ks.append(np.asarray(k_raw, np.float32))
        vs.append(np.asarray(v, np.float32))
        o = _full_attn(q, k, v, cfg, pos, pos, contiguous=True)
        x = x + jnp.einsum("she,hed->sd", o, lp["wo"])
        x = x + _mlp(L.rms_norm(x, lp["mlp_norm"], cfg.norm_eps), lp, cfg)
    k_all = np.stack(ks, axis=1)
    v_all = np.stack(vs, axis=1)
    return k_all, v_all


@functools.partial(jax.jit, static_argnums=(3,))
def _jit_full_prefill(params, toks, last, cfg: LMConfig):
    from repro.models import transformer as T
    logits, _ = T.forward(params, toks[None], cfg)
    return logits[0, last]


def full_prefill_logits(params, cfg: LMConfig, tokens: np.ndarray,
                        bucket: int = 128) -> np.ndarray:
    """Full-Recompute oracle: exact final-position logits (padded + jitted;
    padding is causally invisible to the final real token)."""
    n = len(tokens)
    n_pad = ((n + bucket - 1) // bucket) * bucket
    toks = np.pad(np.asarray(tokens, np.int32), (0, n_pad - n))
    logits = _jit_full_prefill(params, jnp.asarray(toks), n - 1, cfg)
    return np.asarray(logits, np.float32)


@dataclass
class EngineStats:
    n_tokens: int
    n_recomputed: int
    n_reused_item: int
    n_reused_semantic: int
    n_heavy_hitters: int
    layer0_full: bool
    # (n,) bool — which tokens went through layers 1..L-1 exactly; the
    # serving path uses it to scatter fresh KV over the paged pool.
    recompute_mask: Optional[np.ndarray] = None

    def recompute_fraction(self) -> float:
        return self.n_recomputed / max(self.n_tokens, 1)


def _pad_to(x: np.ndarray, n: int, fill=0):
    if len(x) >= n:
        return x[:n]
    return np.concatenate([x, np.full((n - len(x),) + x.shape[1:], fill,
                                      x.dtype)])


def _layer0_impl(params, toks, valid, ck0, cv0, cfg: LMConfig):
    n = toks.shape[0]
    pos = jnp.arange(n)
    x = params["embed"][toks].astype(jnp.dtype(cfg.dtype))
    if cfg.tie_embeddings:
        x = x * (cfg.d_model ** 0.5)
    lp = layer_params(params, 0)
    h = L.rms_norm(x, lp["attn_norm"], cfg.norm_eps)
    q, k, k_raw, v = qkv_proj(h, lp, cfg, pos)
    o, probs = full_attn(q, k, v, cfg, pos, pos, return_probs=True,
                         k_valid=valid)
    # A_i: attention mass received by key i from *valid* queries
    attn_mass = (probs * valid[None, None, :, None]).mean(axis=(0, 1)).sum(axis=0)
    dk = jnp.abs(k_raw - ck0).sum(axis=(1, 2))
    dv = jnp.abs(v - cv0).sum(axis=(1, 2))
    x = x + jnp.einsum("she,hed->sd", o, lp["wo"])
    x = x + mlp_block(L.rms_norm(x, lp["mlp_norm"], cfg.norm_eps), lp, cfg)
    return x, attn_mass, dk + dv, k_raw, v


@functools.partial(jax.jit, static_argnums=(5,))
def _jit_layer0(params, toks, valid, ck0, cv0, cfg: LMConfig):
    """Layer-0 full pass (padded): -> (x_after_l0, attn_mass, divergence)."""
    x, attn_mass, div, _, _ = _layer0_impl(params, toks, valid, ck0, cv0, cfg)
    return x, attn_mass, div


@functools.partial(jax.jit, static_argnums=(5,))
def _jit_layer0_kv(params, toks, valid, ck0, cv0, cfg: LMConfig):
    """Layer-0 full pass that also returns the fresh pre-RoPE (k, v) —
    the serving path stores them in the paged KV pool for decode."""
    return _layer0_impl(params, toks, valid, ck0, cv0, cfg)




def _sel_attn(qr, k_l, v_l, cfg: LMConfig, r_pos, pos, valid, live):
    """One selective-layer attention: recomputed queries vs assembled keys.

    Backend seam: jnp runs the batched masked-softmax reference; pallas
    runs `selective_mha` with every valid key marked attendable (window
    0 + hh = the key-validity mask ⇒ causal attention over valid keys,
    exactly the reference's mask) and the precomputed block-liveness map
    `live`, which keeps the wrapper jit-traceable.
    qr: (B, R, Hq, Dh); k_l/v_l: (B, S, Hkv, Dh); r_pos: (B, R);
    valid: (B, S) bool; live: (B, nq, nk) int32 (unused under jnp).
    """
    if cfg.attn_backend == "pallas":
        return selective_mha(qr, r_pos, k_l, v_l, valid.astype(jnp.int8),
                             live=live, window=0, q_block=PALLAS_Q_BLOCK,
                             kv_block=PALLAS_KV_BLOCK,
                             interpret=default_interpret())
    return full_attn_batched(qr, k_l, v_l, cfg, r_pos, pos, k_valid=valid)


def _selective_layers_impl(params, x, r_idx, r_valid, ck, cv, valid,
                           key_rot_pos, final_slot, cfg: LMConfig,
                           live, collect_kv: bool):
    """Batched layers 1..L-1 over the recompute sets.

    x: (B, n, D); r_idx/r_valid: (B, R); ck/cv: (B, n, L, Hkv, Dh);
    valid: (B, n); key_rot_pos: (n,) shared or (B, n); final_slot: (B,).
    -> logits (B, V) [+ merged pre-RoPE (k, v): (B, n, L-1, Hkv, Dh)].
    """
    B, n, _ = x.shape
    pos = jnp.arange(n)
    rows = jnp.arange(B)
    r_pos = jnp.clip(r_idx, 0, n - 1)                          # (B, R)
    xr = jnp.take_along_axis(x, r_pos[..., None], axis=1)      # (B, R, D)
    ks, vs = [], []
    for l in range(1, cfg.n_layers):
        lp = layer_params(params, l)
        hr = L.rms_norm(xr, lp["attn_norm"], cfg.norm_eps)
        qr = jnp.einsum("brd,dhe->brhe", hr, lp["wq"])
        kr_raw = jnp.einsum("brd,dhe->brhe", hr, lp["wk"])
        vr = jnp.einsum("brd,dhe->brhe", hr, lp["wv"])
        qr = L.apply_rope(qr, r_pos, cfg.rope_theta)
        kr = L.apply_rope(kr_raw, r_pos, cfg.rope_theta)
        # assembled keys: cached pre-RoPE keys rotated per key_rot_pos
        k_l = L.apply_rope(ck[:, :, l], key_rot_pos, cfg.rope_theta)
        v_l = cv[:, :, l]
        widx = jnp.where(r_valid, r_idx, n)                    # n → dropped
        k_l = k_l.at[rows[:, None], widx].set(kr, mode="drop")
        v_l = v_l.at[rows[:, None], widx].set(vr.astype(v_l.dtype),
                                              mode="drop")
        if collect_kv:
            # merged pre-RoPE cache: cached blocks + fresh recomputed keys
            ks.append(ck[:, :, l].at[rows[:, None], widx].set(kr_raw,
                                                              mode="drop"))
            vs.append(v_l)
        o = _sel_attn(qr, k_l, v_l.astype(kr.dtype), cfg, r_pos, pos,
                      valid, live)
        xr = xr + jnp.einsum("brhe,hed->brd", o, lp["wo"])
        xr = xr + mlp_block_batched(
            L.rms_norm(xr, lp["mlp_norm"], cfg.norm_eps), lp, cfg)

    xf = L.rms_norm(xr[rows, final_slot], params["final_norm"],
                    cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = xf @ head                                         # (B, V)
    if collect_kv:
        return logits, jnp.stack(ks, axis=2), jnp.stack(vs, axis=2)
    return logits


@functools.partial(jax.jit, static_argnums=(9,))
def _jit_selective_layers(params, x, r_idx, r_valid, ck, cv, valid,
                          key_rot_pos, final_slot, cfg: LMConfig,
                          live=_NO_LIVE):
    """Layers 1..L-1 computed only for the (padded) recompute sets; final
    logits at the recompute slot `final_slot` (each prompt's last token).
    `key_rot_pos` rotates cached pre-RoPE keys (RcLLM: the request position
    = exact realignment; CacheBlend baseline: the block's original position).
    All array args carry a leading batch dim — the single-request path is
    the B=1 special case."""
    return _selective_layers_impl(params, x, r_idx, r_valid, ck, cv, valid,
                                  key_rot_pos, final_slot, cfg, live,
                                  collect_kv=False)


@functools.partial(jax.jit, static_argnums=(9,))
def _jit_selective_layers_kv(params, x, r_idx, r_valid, ck, cv, valid,
                             key_rot_pos, final_slot, cfg: LMConfig,
                             live=_NO_LIVE):
    """As `_jit_selective_layers`, but also returns the merged pre-RoPE
    (k, v) for layers 1..L-1: (B, n, L-1, Hkv, Dh) — cached blocks with
    the recomputed tokens' fresh keys scattered in."""
    return _selective_layers_impl(params, x, r_idx, r_valid, ck, cv, valid,
                                  key_rot_pos, final_slot, cfg, live,
                                  collect_kv=True)


def _liveness_for(cfg: LMConfig, r_idx_p: np.ndarray, valid: np.ndarray
                  ) -> np.ndarray:
    """Host-side block-liveness for the selective pallas route.

    r_idx_p: (B, R) padded recompute indices; valid: (B, n) key-validity.
    Under the jnp backend returns the shared placeholder (the trace never
    reads it), so both backends call the jitted entry points identically.
    """
    if cfg.attn_backend != "pallas":
        return _NO_LIVE
    n = valid.shape[1]
    r_pos = np.clip(np.asarray(r_idx_p, np.int64), 0, n - 1)
    return build_block_liveness(r_pos, valid.astype(np.int8), window=0,
                                q_block=PALLAS_Q_BLOCK,
                                kv_block=PALLAS_KV_BLOCK)


def run_selective_layers(params, cfg, x, recompute: np.ndarray,
                         ck, cv, n_valid: int, bucket: int = 64,
                         key_positions: Optional[np.ndarray] = None,
                         return_kv: bool = False):
    """Pad the recompute set + sequence, dispatch the jitted layer stack.

    Single-request wrapper over the batched (B=1) selective stack.  With
    ``return_kv`` the merged pre-RoPE caches for layers 1..L-1 come
    back too: -> (logits, k (n, L-1, Hkv, Dh), v) — the serving engine's
    source for paged-pool insertion.
    """
    n = x.shape[0]
    r_idx = np.where(recompute)[0]
    r_count = len(r_idx)
    r_pad = max(bucket, ((r_count + bucket - 1) // bucket) * bucket)
    r_valid = np.zeros(r_pad, bool)
    r_valid[:r_count] = True
    r_idx_p = _pad_to(r_idx.astype(np.int32), r_pad, fill=n_valid - 1)
    valid = np.zeros(n, bool)
    valid[:n_valid] = True
    if key_positions is None:
        key_positions = np.arange(n)
    else:
        key_positions = _pad_to(key_positions.astype(np.int64), n)
    final_slot = r_count - 1          # last recomputed token = prompt tail
    live = _liveness_for(cfg, r_idx_p[None], valid[None])
    args = (params, x[None], jnp.asarray(r_idx_p[None]),
            jnp.asarray(r_valid[None]), jnp.asarray(ck)[None],
            jnp.asarray(cv)[None], jnp.asarray(valid[None]),
            jnp.asarray(key_positions), jnp.asarray([final_slot]), cfg,
            jnp.asarray(live))
    if return_kv:
        logits, k_m, v_m = _jit_selective_layers_kv(*args)
        return (np.asarray(logits[0], np.float32),
                np.asarray(k_m[0], np.float32),
                np.asarray(v_m[0], np.float32))
    logits = _jit_selective_layers(*args)
    return np.asarray(logits[0], np.float32)


def selective_prefill_logits(
    params, cfg: LMConfig, plan: AssemblyPlan,
    cached_k: np.ndarray, cached_v: np.ndarray, have_cache: np.ndarray,
    sel: SelectiveConfig, bucket: int = 128,
) -> Tuple[np.ndarray, EngineStats]:
    """Beyond-prefix prefill with selective recomputation.

    cached_k/v: (n, n_layers, Hkv, Dh) pre-RoPE assembled blocks
    (zeros where RECOMPUTE / miss).  Sequences are padded to `bucket`
    multiples so the jitted engine retraces O(1) times.
    """
    logits, stats, _, _ = _selective_prefill(
        params, cfg, plan, cached_k, cached_v, have_cache, sel, bucket,
        return_kv=False)
    return logits, stats


def selective_prefill_with_kv(
    params, cfg: LMConfig, plan: AssemblyPlan,
    cached_k: np.ndarray, cached_v: np.ndarray, have_cache: np.ndarray,
    sel: SelectiveConfig, bucket: int = 128,
) -> Tuple[np.ndarray, EngineStats, np.ndarray, np.ndarray]:
    """Selective prefill that also materializes the request's full merged
    pre-RoPE KV cache (n, L, Hkv, Dh): layer 0 fresh, layers 1..L-1 cached
    blocks with recomputed tokens scattered in.  The batched serving engine
    writes this into the paged pool so decode can attend to the prompt.
    """
    return _selective_prefill(params, cfg, plan, cached_k, cached_v,
                              have_cache, sel, bucket, return_kv=True)


def select_recompute(plan: AssemblyPlan, have: np.ndarray,
                     attn_mass, div_raw, sel: SelectiveConfig
                     ) -> Tuple[np.ndarray, EngineStats]:
    """Eq. 3 scoring + heavy-hitter selection under per-class budgets.

    attn_mass/div_raw: layer-0 outputs (padded; only [:n] is read).
    Shared by the single-request and batched selective prefills, so the
    two paths cannot drift on *which* tokens they recompute.
    -> (recompute mask (n,), EngineStats).
    """
    n = plan.n
    attn_mass = np.asarray(attn_mass)[:n]
    a_norm = attn_mass / max(attn_mass.max(), 1e-9)
    div = np.asarray(div_raw)[:n] * have.astype(np.float32)
    div = div / max(div.max(), 1e-9)
    s_score = (1.0 - sel.lam) * a_norm + sel.lam * div              # Eq. 3

    src = plan.source
    recompute = ~have.copy()                                 # misses
    # instructions: always recomputed — unless their exact KV is already
    # cached (`have`), which only the serving block store's prefix tier
    # sets (its bytes ARE the recomputed rows, so skipping is lossless;
    # offline flows never mark seg0 tokens as cached)
    recompute |= (plan.seg_kind == 0) & ~have
    recompute[max(0, n - sel.window):] = True                # local window
    n_hh = 0
    for kind, budget in ((2, sel.r_item), (1, sel.r_rev)):
        cls = np.where((plan.seg_kind == kind) & ~recompute)[0]
        if len(cls) == 0:
            continue
        k_top = int(np.ceil(budget * len(cls)))
        top = cls[np.argsort(-s_score[cls])[:k_top]]
        recompute[top] = True
        n_hh += len(top)

    stats = EngineStats(
        n_tokens=n, n_recomputed=int(recompute.sum()),
        n_reused_item=int(((src == FROM_ITEM) & ~recompute).sum()),
        n_reused_semantic=int(((src == FROM_SEMANTIC) & ~recompute).sum()),
        n_heavy_hitters=n_hh, layer0_full=sel.layer0_full,
        recompute_mask=recompute.copy())
    return recompute, stats


def _selective_prefill(
    params, cfg: LMConfig, plan: AssemblyPlan,
    cached_k: np.ndarray, cached_v: np.ndarray, have_cache: np.ndarray,
    sel: SelectiveConfig, bucket: int = 128, return_kv: bool = False,
):
    n = plan.n
    n_pad = ((n + bucket - 1) // bucket) * bucket
    toks = _pad_to(plan.tokens.astype(np.int32), n_pad)
    ckp = _pad_to(cached_k.astype(np.float32), n_pad)
    cvp = _pad_to(cached_v.astype(np.float32), n_pad)
    have = have_cache
    valid = np.zeros(n_pad, bool)
    valid[:n] = True

    # ---- layer 0 (jitted): full attention + Eq. 3 terms ----
    layer0 = _jit_layer0_kv if return_kv else _jit_layer0
    out0 = layer0(params, jnp.asarray(toks), jnp.asarray(valid),
                  jnp.asarray(ckp[:, 0]), jnp.asarray(cvp[:, 0]), cfg)
    if return_kv:
        x, attn_mass, div_raw, k0_raw, v0 = out0
    else:
        x, attn_mass, div_raw = out0
        k0_raw = v0 = None
    recompute, stats = select_recompute(plan, have, attn_mass, div_raw, sel)

    if not return_kv:
        logits = run_selective_layers(params, cfg, x, recompute, ckp, cvp, n)
        return logits, stats, None, None

    logits, k_rest, v_rest = run_selective_layers(
        params, cfg, x, recompute, ckp, cvp, n, return_kv=True)
    k_all = np.concatenate(
        [np.asarray(k0_raw, np.float32)[:, None], k_rest], axis=1)[:n]
    v_all = np.concatenate(
        [np.asarray(v0, np.float32)[:, None], v_rest], axis=1)[:n]
    return logits, stats, k_all, v_all


def _pow2(n: int) -> int:
    return 1 << (n - 1).bit_length()


def selective_layers_batch(params, cfg: LMConfig, items,
                           r_bucket: int = 64, return_kv: bool = True):
    """Bucketed batched selective-layer pass (phase 2 of the selective
    prefill): requests are grouped by (padded length, padded recompute
    budget), stacked with the batch axis padded to the next power of
    two, and ONE jitted selective step runs per bucket.

    items: sequence of (plan, x (n_pad, D), recompute (n,), ckp, cvp)
    with ckp/cvp padded to n_pad.  -> list of (logits (V,), k_rest,
    v_rest) per item in input order (k_rest/v_rest are the merged
    pre-RoPE layers 1..L-1, (n_pad, L-1, Hkv, Dh); None unless
    ``return_kv``).

    This is THE selective dispatch for every serving path — the wave
    batched prefill and the chunked unified-step finalize both land
    here, so their logits (and decoded tokens) cannot drift apart.
    """
    results = [None] * len(items)
    by_shape: Dict[tuple, list] = {}
    for i, (plan, x, recompute, ckp, cvp) in enumerate(items):
        n_pad = ckp.shape[0]
        r_count = int(recompute.sum())
        r_pad = max(r_bucket, ((r_count + r_bucket - 1) // r_bucket)
                    * r_bucket)
        by_shape.setdefault((n_pad, r_pad), []).append(i)
    for (n_pad, r_pad), idxs in sorted(by_shape.items()):
        B = _pow2(len(idxs))
        r_idx_p = np.zeros((B, r_pad), np.int32)
        r_valid = np.zeros((B, r_pad), bool)
        valid = np.zeros((B, n_pad), bool)
        final_slot = np.zeros(B, np.int32)
        for bi, i in enumerate(idxs):
            plan = items[i][0]
            r_idx = np.where(items[i][2])[0]
            r_idx_p[bi] = _pad_to(r_idx.astype(np.int32), r_pad,
                                  fill=plan.n - 1)
            r_valid[bi, :len(r_idx)] = True
            valid[bi, :plan.n] = True
            final_slot[bi] = len(r_idx) - 1
        live = _liveness_for(cfg, r_idx_p, valid)
        zrow_x = jnp.zeros_like(items[idxs[0]][1])
        zrow_ck = np.zeros_like(items[idxs[0]][3])
        xs = [items[i][1] for i in idxs] + [zrow_x] * (B - len(idxs))
        cks = [items[i][3] for i in idxs] + [zrow_ck] * (B - len(idxs))
        cvs = [items[i][4] for i in idxs] + [zrow_ck] * (B - len(idxs))
        args = (params, jnp.stack(xs),
                jnp.asarray(r_idx_p), jnp.asarray(r_valid),
                jnp.asarray(np.stack(cks)), jnp.asarray(np.stack(cvs)),
                jnp.asarray(valid), jnp.arange(n_pad),
                jnp.asarray(final_slot), cfg, jnp.asarray(live))
        if return_kv:
            logits, k_rest, v_rest = _jit_selective_layers_kv(*args)
            k_rest = np.asarray(k_rest, np.float32)
            v_rest = np.asarray(v_rest, np.float32)
        else:
            logits = _jit_selective_layers(*args)
            k_rest = v_rest = None
        logits = np.asarray(logits, np.float32)
        for bi, i in enumerate(idxs):
            kr = k_rest[bi] if return_kv else None
            vr = v_rest[bi] if return_kv else None
            results[i] = (logits[bi], kr, vr)
    return results


def selective_prefill_batch(
    params, cfg: LMConfig, items: Sequence, sel: SelectiveConfig,
    bucket: int = 128, r_bucket: int = 64, return_kv: bool = True,
):
    """Batched beyond-prefix prefill over many requests at once.

    Phase 1 runs layer 0 + Eq. 3 scoring per request — the *identical*
    padded dispatches as the single-request path, so the batched
    prefill's selection and activations are bit-for-bit the loop's.
    (Stacking layer 0 buys no compute: it materializes (B, H, G, S, S)
    probability tensors that thrash CPU caches, and its dispatch count
    is not the bottleneck.)  Phase 2 is where batching pays: ONE jitted
    selective-layer step per (padded length, padded recompute budget)
    bucket over the stacked recompute sets, with the batch axis padded
    to the next power of two — so steady-state serving retraces
    O(#distinct buckets · log batch) regardless of how the continuous
    batcher composes batches, at ≤ 2× padded-row waste.

    items: sequence of (plan, cached_k, cached_v, have) tuples.
    -> list of (logits (V,), EngineStats, k_all (n, L, Hkv, Dh), v_all)
    per request, in input order (k_all/v_all None unless ``return_kv``).
    """
    if not items:
        return []
    # ---- phase 1: per-request layer 0 + host-side Eq. 3 selection ----
    x_of, rec_of, stats_of, k0_of, v0_of, ckp_of, cvp_of = (
        {}, {}, {}, {}, {}, {}, {})
    layer0 = _jit_layer0_kv if return_kv else _jit_layer0
    for i, (plan, ck, cv, have) in enumerate(items):
        n_pad = ((plan.n + bucket - 1) // bucket) * bucket
        toks = _pad_to(plan.tokens.astype(np.int32), n_pad)
        valid = np.zeros(n_pad, bool)
        valid[:plan.n] = True
        ckp = _pad_to(ck.astype(np.float32), n_pad)
        cvp = _pad_to(cv.astype(np.float32), n_pad)
        out0 = layer0(params, jnp.asarray(toks), jnp.asarray(valid),
                      jnp.asarray(ckp[:, 0]), jnp.asarray(cvp[:, 0]), cfg)
        if return_kv:
            x, attn_mass, div_raw, k0, v0 = out0
            k0_of[i] = np.asarray(k0, np.float32)
            v0_of[i] = np.asarray(v0, np.float32)
        else:
            x, attn_mass, div_raw = out0
            k0_of[i] = v0_of[i] = None
        rec_of[i], stats_of[i] = select_recompute(
            plan, have, attn_mass, div_raw, sel)
        x_of[i] = x
        ckp_of[i], cvp_of[i] = ckp, cvp

    # ---- phase 2: selective layers per (n_pad, r_pad) bucket ----
    sel_items = [(items[i][0], x_of[i], rec_of[i], ckp_of[i], cvp_of[i])
                 for i in range(len(items))]
    sel_out = selective_layers_batch(params, cfg, sel_items,
                                     r_bucket=r_bucket, return_kv=return_kv)
    results = []
    for i, (logits, k_rest, v_rest) in enumerate(sel_out):
        n = items[i][0].n
        k_all = v_all = None
        if return_kv:
            k_all = np.concatenate(
                [k0_of[i][:, None], k_rest], axis=1)[:n]
            v_all = np.concatenate(
                [v0_of[i][:, None], v_rest], axis=1)[:n]
        results.append((logits, stats_of[i], k_all, v_all))
    return results


# ---------------------------------------------------------------------------
# Chunk-resumable layer 0 (the unified-step serving path).
#
# The monolithic selective prefill runs layer 0 over the whole prompt in
# one dispatch; under load that makes a long prompt stall every running
# request for its full n^2 scan.  The chunked pass processes the prompt
# in fixed-size query chunks against a full-length key buffer: chunk c
# computes q/k/v for its tokens, appends its rotated keys into the
# buffer, and attends causally over everything scanned so far.  Because
# every per-token quantity (projections, divergence, post-layer-0
# residual, pre-RoPE k0/v0) is row-independent and the attention softmax
# reduces over the same zero-extended key axis, each chunk's rows are
# bitwise identical to the monolithic pass's rows — verified by
# tests/test_chunked.py.  The one cross-token reduction, Eq. 3's
# attention mass (a sum over queries), is accumulated as per-query rows
# and summed once at finalize through `_jit_mass_sum`, reproducing the
# monolithic XLA reduction bitwise (a host-side numpy sum does NOT).
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnums=(8,))
def _jit_layer0_chunk(params, toks_c, offset, valid, ck0_c, cv0_c,
                      kbuf, vbuf, cfg: LMConfig):
    """One layer-0 chunk: queries [offset, offset+C) vs all scanned keys.

    toks_c: (C,) chunk token ids (0-padded past the prompt); offset:
    scalar int32 (traced, so one compile serves every chunk index);
    valid: (nbuf,) key validity (True at real prompt positions);
    ck0_c/cv0_c: (C, Hkv, Dh) cached layer-0 rows for Eq. 3 divergence;
    kbuf/vbuf: (nbuf, Hkv, Dh) accumulated rotated-key / value buffers.
    -> (x_c, m_c, div_c, k0_c, v0_c, kbuf', vbuf') where m_c (C, nbuf)
    holds per-query head-mean attention probabilities (the Eq. 3 mass
    rows) and k0_c/v0_c are the chunk's fresh pre-RoPE layer-0 KV.

    Unscanned keys (positions >= offset+C) are zeros in the buffers but
    causally invisible to every chunk query, so the standard causal +
    validity mask is exactly the monolithic mask.
    """
    C = toks_c.shape[0]
    pos_c = offset + jnp.arange(C)
    x = params["embed"][toks_c].astype(jnp.dtype(cfg.dtype))
    if cfg.tie_embeddings:
        x = x * (cfg.d_model ** 0.5)
    lp = layer_params(params, 0)
    h = L.rms_norm(x, lp["attn_norm"], cfg.norm_eps)
    q, k, k_raw, v = qkv_proj(h, lp, cfg, pos_c)
    kbuf = jax.lax.dynamic_update_slice(kbuf, k, (offset, 0, 0))
    vbuf = jax.lax.dynamic_update_slice(vbuf, v, (offset, 0, 0))
    k_pos = jnp.arange(kbuf.shape[0])
    # layer-0 scoring needs materialized probabilities, so this always
    # takes the jnp path — same as the monolithic layer 0 (`_layer0_impl`)
    o, probs = full_attn(q, kbuf, vbuf, cfg, pos_c, k_pos,
                         return_probs=True, k_valid=valid)
    qvalid = jax.lax.dynamic_slice(valid, (offset,), (C,))
    m_c = (probs * qvalid[None, None, :, None]).mean(axis=(0, 1))
    dk = jnp.abs(k_raw - ck0_c).sum(axis=(1, 2))
    dv = jnp.abs(v - cv0_c).sum(axis=(1, 2))
    x = x + jnp.einsum("she,hed->sd", o, lp["wo"])
    x = x + mlp_block(L.rms_norm(x, lp["mlp_norm"], cfg.norm_eps), lp, cfg)
    return x, m_c, dk + dv, k_raw, v, kbuf, vbuf


@jax.jit
def _jit_mass_sum(m):
    """Eq. 3 attention-mass finalize: sum the accumulated per-query rows
    over the query axis.  Must run through XLA — the monolithic layer 0
    reduces this sum inside its jit, and only the same XLA reduction
    reproduces it bitwise."""
    return m.sum(axis=0)


class ChunkedPrefill:
    """Resumable selective prefill state for ONE request.

    Drives the prompt scan in `chunk_tokens`-sized steps (`run_chunk`),
    finalizes Eq. 3 recompute selection once the prompt is fully
    scanned, and hands the selective-layer pass to the SAME bucketed
    dispatch as the wave path (`selective_layers_batch`) — so chunked
    and monolithic prefill decode bitwise-identical tokens.

    The serving engine (`serving.batch_engine.PrefillState`) wraps this
    with pool/store bookkeeping; this class is pure compute + state.
    """

    def __init__(self, params, cfg: LMConfig, plan: AssemblyPlan,
                 cached_k: np.ndarray, cached_v: np.ndarray,
                 have: np.ndarray, sel: SelectiveConfig,
                 chunk_tokens: int, bucket: int = 128):
        self.params = params
        self.cfg = cfg
        self.plan = plan
        self.have = have
        self.sel = sel
        self.chunk = int(chunk_tokens)
        n = plan.n
        self.n = n
        self.n_pad = ((n + bucket - 1) // bucket) * bucket
        # the key buffers are sized to n_pad — the monolithic layer-0
        # shape — so every chunk's attention reduces over the exact
        # reduction axis the monolithic pass uses (zero-extending the
        # key axis past n_pad is NOT bitwise-safe).  The scan grid
        # covers n_pad in `chunk`-wide steps with a ragged final chunk
        # (n_pad and chunk are both multiples of the 64-token engine
        # bucket, so tail widths stay on the same O(1) shape grid).
        self.toks = _pad_to(plan.tokens.astype(np.int32), self.n_pad)
        self.valid = np.zeros(self.n_pad, bool)
        self.valid[:n] = True
        self.ckp = _pad_to(cached_k.astype(np.float32), self.n_pad)
        self.cvp = _pad_to(cached_v.astype(np.float32), self.n_pad)
        Hkv, Dh = cfg.n_kv_heads, cfg.resolved_head_dim
        self.kbuf = jnp.zeros((self.n_pad, Hkv, Dh), jnp.float32)
        self.vbuf = jnp.zeros((self.n_pad, Hkv, Dh), jnp.float32)
        self.offset = 0
        self._xs: list = []
        self._ms: list = []
        self._divs: list = []
        self._k0s: list = []
        self._v0s: list = []
        self.recompute: Optional[np.ndarray] = None
        self.stats: Optional[EngineStats] = None

    @property
    def scan_done(self) -> bool:
        return self.offset >= self.n_pad

    def pending_tokens(self) -> int:
        """Chunk-grid tokens still to scan (padded — what a budget is
        charged for, since the dispatch width is the work)."""
        return self.n_pad - self.offset

    def next_chunk_tokens(self) -> int:
        """Dispatch width of the next chunk (ragged at the tail)."""
        return min(self.chunk, self.n_pad - self.offset)

    def finalize_charge(self) -> int:
        """Token charge of the selective finalize dispatch (the padded
        recompute budget) — known as soon as the scan completes."""
        if self.recompute is None:
            raise RuntimeError("finalize_charge before scan completed")
        r_count = int(self.recompute.sum())
        return max(64, -(-r_count // 64) * 64)

    def run_chunk(self):
        """Scan the next chunk.  -> (positions, k0_rows, v0_rows): the
        real prompt positions covered and their fresh pre-RoPE layer-0
        KV, ready for incremental pool insertion (empty on an all-pad
        tail chunk).  Completing the scan finalizes Eq. 3 selection."""
        if self.scan_done:
            raise RuntimeError("prompt fully scanned")
        off = self.offset
        C = self.next_chunk_tokens()
        x_c, m_c, div_c, k0_c, v0_c, self.kbuf, self.vbuf = \
            _jit_layer0_chunk(
                self.params, jnp.asarray(self.toks[off:off + C]),
                jnp.asarray(off, jnp.int32), jnp.asarray(self.valid),
                jnp.asarray(self.ckp[off:off + C, 0]),
                jnp.asarray(self.cvp[off:off + C, 0]),
                self.kbuf, self.vbuf, self.cfg)
        self._xs.append(x_c)
        self._ms.append(np.asarray(m_c))
        self._divs.append(np.asarray(div_c))
        k0 = np.asarray(k0_c, np.float32)
        v0 = np.asarray(v0_c, np.float32)
        self._k0s.append(k0)
        self._v0s.append(v0)
        self.offset = off + C
        lo, hi = off, min(off + C, self.n)
        if self.scan_done:
            self._select()
        if hi <= lo:
            return np.zeros(0, np.int64), k0[:0], v0[:0]
        return np.arange(lo, hi), k0[:hi - lo], v0[:hi - lo]

    def _select(self) -> None:
        attn_mass = _jit_mass_sum(jnp.asarray(np.concatenate(self._ms)))
        div = np.concatenate(self._divs)[:self.n_pad]
        self.recompute, self.stats = select_recompute(
            self.plan, self.have, np.asarray(attn_mass), div, self.sel)
        # the mass rows are O(n_pad^2) host floats per request and many
        # requests sit mid-scan concurrently — free them the moment the
        # scan-wide reduction has consumed them
        self._ms = []
        self._divs = []

    def x_full(self):
        """Post-layer-0 residual stream (n_pad, D), assembled from the
        chunk outputs — the selective pass's input."""
        return jnp.concatenate(self._xs)[:self.n_pad]

    def k0_full(self) -> np.ndarray:
        """Fresh pre-RoPE layer-0 K (n, Hkv, Dh) over the real prompt."""
        return np.concatenate(self._k0s)[:self.n]

    def v0_full(self) -> np.ndarray:
        return np.concatenate(self._v0s)[:self.n]

    def sel_item(self) -> tuple:
        """This request's `selective_layers_batch` entry."""
        if self.recompute is None:
            raise RuntimeError("selective pass before scan completed")
        return (self.plan, self.x_full(), self.recompute, self.ckp,
                self.cvp)
