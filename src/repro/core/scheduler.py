"""Cache-aware global scheduling (§III-C1).

Affinity(R, p) = α · Hit(R, p) + β · (1 − Load(p))          (Eq. 2)

Hit(R, p) = |I(R) ∩ C(p)| / |I(R)| from the placement map;
Load(p) = normalized queue depth.  Single-objective ablations (Hit-Only,
Load-Only) and stateless baselines (round-robin, least-loaded) included —
they are the policies of Fig. 10.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence

import numpy as np

from repro.core.placement import Placement


@dataclass
class SchedulerState:
    k: int
    queue_depth: np.ndarray                 # outstanding work per instance (s)
    rr_next: int = 0

    @staticmethod
    def fresh(k: int) -> "SchedulerState":
        return SchedulerState(k=k, queue_depth=np.zeros(k))


def hit_ratio(items: np.ndarray, placement: Placement, instance: int) -> float:
    if len(items) == 0:
        return 1.0
    local = sum(1 for it in items if placement.is_local(int(it), instance))
    return local / len(items)


def hit_vector(items: np.ndarray, placement: Placement) -> np.ndarray:
    """Hit(R, p) for all p at once."""
    k = placement.k
    hits = np.zeros(k)
    n = max(len(items), 1)
    for it in items:
        s = placement.shard_of[int(it)]
        if s < 0:
            hits += 1.0
        else:
            hits[s] += 1.0
    return hits / n


def load_vector(state: SchedulerState) -> np.ndarray:
    q = state.queue_depth
    hi = q.max()
    return q / hi if hi > 0 else np.zeros_like(q)


def route(items: np.ndarray, placement: Placement, state: SchedulerState,
          policy: str = "affinity", alpha: float = 0.7, beta: float = 0.3,
          rng: Optional[np.random.Generator] = None) -> int:
    """Pick the serving instance for one request."""
    if policy == "round_robin":
        p = state.rr_next % state.k
        state.rr_next += 1
        return p
    if policy == "random":
        return int((rng or np.random.default_rng()).integers(0, state.k))
    if policy == "least_loaded":
        return int(np.argmin(state.queue_depth))

    hits = hit_vector(items, placement)
    load = load_vector(state)
    if policy == "hit_only":
        score = hits - 1e-9 * load            # tie-break on load
    elif policy == "load_only":
        score = -load
    elif policy == "affinity":
        score = alpha * hits + beta * (1.0 - load)       # Eq. 2
    else:
        raise ValueError(policy)
    return int(np.argmax(score))


POLICIES = ("affinity", "hit_only", "load_only", "round_robin",
            "least_loaded", "random")


class ClusterScheduler:
    """Runtime-facing Eq. 2 dispatcher over *live* worker load.

    The simulator rebuilds queue depths analytically each event; real
    serving instead hands the scheduler measured per-worker backlog at
    every arrival (`serving.batching.WorkerState.backlog_seconds`).  The
    object is stateful so round-robin and the RNG behave across calls.
    """

    def __init__(self, placement: Placement, policy: str = "affinity",
                 alpha: float = 0.7, beta: float = 0.3, seed: int = 0):
        if policy not in POLICIES:
            raise ValueError(f"unknown policy {policy!r}; one of {POLICIES}")
        self.placement = placement
        self.policy = policy
        self.alpha = alpha
        self.beta = beta
        self.state = SchedulerState.fresh(placement.k)
        self.rng = np.random.default_rng(seed)

    def dispatch(self, items: Sequence[int],
                 queue_depth: Sequence[float]) -> int:
        """Route one request given its item set and live queue depths."""
        self.state.queue_depth = np.asarray(queue_depth, float)
        return route(np.asarray(items), self.placement, self.state,
                     policy=self.policy, alpha=self.alpha, beta=self.beta,
                     rng=self.rng)
