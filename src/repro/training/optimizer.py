"""Optimizers: AdamW and Adafactor (factored second moments).

Adafactor is the default for the 1T-param MoE config: Adam's two fp32
moments alone are 8 TB there — factored row/col statistics cut optimizer
state to O(rows + cols) per matrix (see DESIGN.md §8).
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class OptState(NamedTuple):
    step: jax.Array
    inner: Any          # pytree matching params


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree_util.tree_map(lambda g: g * scale, grads), norm


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

def adamw(lr: float = 1e-4, b1: float = 0.9, b2: float = 0.95,
          eps: float = 1e-8, weight_decay: float = 0.01,
          grad_clip: float = 1.0):
    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        inner = {"m": jax.tree_util.tree_map(zeros, params),
                 "v": jax.tree_util.tree_map(zeros, params)}
        return OptState(step=jnp.zeros((), jnp.int32), inner=inner)

    def update(grads, state: OptState, params):
        grads, gnorm = clip_by_global_norm(grads, grad_clip)
        t = state.step + 1
        bc1 = 1 - b1 ** t.astype(jnp.float32)
        bc2 = 1 - b2 ** t.astype(jnp.float32)

        def upd(p, g, m, v):
            g = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            step = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            new_p = p.astype(jnp.float32) - lr * (step + weight_decay * p.astype(jnp.float32))
            return new_p.astype(p.dtype), m, v

        flat_p, tdef = jax.tree_util.tree_flatten(params)
        flat_g = tdef.flatten_up_to(grads)
        flat_m = tdef.flatten_up_to(state.inner["m"])
        flat_v = tdef.flatten_up_to(state.inner["v"])
        outs = [upd(p, g, m, v)
                for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
        new_p = tdef.unflatten([o[0] for o in outs])
        new_m = tdef.unflatten([o[1] for o in outs])
        new_v = tdef.unflatten([o[2] for o in outs])
        return new_p, OptState(step=t, inner={"m": new_m, "v": new_v}), gnorm

    return init, update


# ---------------------------------------------------------------------------
# Adafactor (Shazeer & Stern, arXiv:1804.04235) — factored, momentum-free
# ---------------------------------------------------------------------------

def _factored(shape) -> bool:
    return len(shape) >= 2 and shape[-1] > 1 and shape[-2] > 1


def adafactor(lr: float = 1e-3, decay: float = 0.8, eps1: float = 1e-30,
              eps2: float = 1e-3, clip_threshold: float = 1.0,
              grad_clip: float = 1.0):
    def init(params):
        def zero_state(p):
            if _factored(p.shape):
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        inner = jax.tree_util.tree_map(zero_state, params)
        return OptState(step=jnp.zeros((), jnp.int32), inner=inner)

    def update(grads, state: OptState, params):
        grads, gnorm = clip_by_global_norm(grads, grad_clip)
        t = state.step + 1
        beta = 1.0 - (t.astype(jnp.float32) + 1.0) ** -decay

        def upd(p, g, s):
            g = g.astype(jnp.float32)
            g2 = g * g + eps1
            if _factored(p.shape):
                vr = beta * s["vr"] + (1 - beta) * g2.mean(axis=-1)
                vc = beta * s["vc"] + (1 - beta) * g2.mean(axis=-2)
                denom = jnp.maximum(vr.mean(axis=-1, keepdims=True), eps1)
                u = g * jax.lax.rsqrt(vr[..., None] / denom[..., None]) \
                      * jax.lax.rsqrt(vc[..., None, :])
                new_s = {"vr": vr, "vc": vc}
            else:
                v = beta * s["v"] + (1 - beta) * g2
                u = g * jax.lax.rsqrt(v)
                new_s = {"v": v}
            rms_u = jnp.sqrt(jnp.mean(u * u) + eps1)
            u = u / jnp.maximum(1.0, rms_u / clip_threshold)
            scale = jnp.maximum(eps2, jnp.sqrt(jnp.mean(jnp.square(
                p.astype(jnp.float32)))))
            new_p = p.astype(jnp.float32) - lr * scale * u
            return new_p.astype(p.dtype), new_s

        flat_p, tdef = jax.tree_util.tree_flatten(params)
        flat_g = tdef.flatten_up_to(grads)
        flat_s = tdef.flatten_up_to(state.inner)
        outs = [upd(p, g, s) for p, g, s in zip(flat_p, flat_g, flat_s)]
        new_p = tdef.unflatten([o[0] for o in outs])
        new_s = tdef.unflatten([o[1] for o in outs])
        return new_p, OptState(step=t, inner=new_s), gnorm

    return init, update


def sgd(lr: float = 1e-2, grad_clip: float = 1.0):
    def init(params):
        return OptState(step=jnp.zeros((), jnp.int32), inner=())

    def update(grads, state, params):
        grads, gnorm = clip_by_global_norm(grads, grad_clip)
        new_p = jax.tree_util.tree_map(
            lambda p, g: (p.astype(jnp.float32) - lr * g.astype(jnp.float32)).astype(p.dtype),
            params, grads)
        return new_p, OptState(step=state.step + 1, inner=()), gnorm

    return init, update


def get(name: str, **kw) -> Tuple[Callable, Callable]:
    return {"adamw": adamw, "adafactor": adafactor, "sgd": sgd}[name](**kw)


def abstract_opt_state(init_fn, params_abstract):
    return jax.eval_shape(init_fn, params_abstract)
