"""Fault-tolerant training loop.

Features exercised by tests/examples:
  * grad-accumulation microbatching (jit-scan over microbatches)
  * checkpoint/restart: async checkpoints every N steps, auto-resume from
    the latest on (re)start, survives injected step failures with bounded
    retries (the single-process analogue of node-failure restart)
  * gradient compression hooks (int8 / top-k + error feedback) for the
    DCN-crossing data-parallel axis
  * metric history
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Iterator, Optional

import jax
import jax.numpy as jnp

from repro.checkpoint import checkpoint as CKPT
from repro.training import optimizer as OPT


@dataclass
class TrainConfig:
    steps: int = 100
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    keep_ckpts: int = 3
    optimizer: str = "adamw"
    lr: float = 1e-3
    microbatches: int = 1
    max_retries: int = 3
    grad_compression: Optional[str] = None   # None | int8 | topk
    topk_frac: float = 0.05


# --------------------------- gradient compression ---------------------------

def compress_int8(g: jax.Array) -> jax.Array:
    """Simulated int8 all-reduce payload: quantize → dequantize (the wire
    format halves→quarters DCN bytes; numerics preserved via per-tensor
    scale)."""
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q.astype(g.dtype) * scale


def compress_topk(g: jax.Array, frac: float, err: jax.Array):
    """Top-k sparsification with error feedback (momentum-correct)."""
    flat = (g + err).reshape(-1)
    k = max(1, int(frac * flat.shape[0]))
    thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
    mask = jnp.abs(flat) >= thresh
    sent = jnp.where(mask, flat, 0.0)
    new_err = (flat - sent).reshape(g.shape)
    return sent.reshape(g.shape), new_err


def apply_compression(grads, cfg: TrainConfig, err_state):
    if cfg.grad_compression is None:
        return grads, err_state
    if cfg.grad_compression == "int8":
        return jax.tree_util.tree_map(compress_int8, grads), err_state
    if cfg.grad_compression == "topk":
        flat_g, tdef = jax.tree_util.tree_flatten(grads)
        flat_e = tdef.flatten_up_to(err_state)
        out = [compress_topk(g, cfg.topk_frac, e)
               for g, e in zip(flat_g, flat_e)]
        return (tdef.unflatten([o[0] for o in out]),
                tdef.unflatten([o[1] for o in out]))
    raise ValueError(cfg.grad_compression)


# --------------------------------- loop -------------------------------------

def make_train_step(loss_fn: Callable, cfg: TrainConfig, update_opt):
    """loss_fn(params, batch) -> scalar.  Returns jitted
    (params, opt_state, err, batch) -> (params, opt_state, err, metrics),
    with microbatch grad accumulation when cfg.microbatches > 1."""

    def step(params, opt_state, err_state, batch):
        if cfg.microbatches > 1:
            def micro(carry, mb):
                acc, = carry
                loss, g = jax.value_and_grad(loss_fn)(params, mb)
                acc = jax.tree_util.tree_map(jnp.add, acc, g)
                return (acc,), loss
            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            mbs = jax.tree_util.tree_map(
                lambda a: a.reshape((cfg.microbatches,
                                     a.shape[0] // cfg.microbatches)
                                    + a.shape[1:]), batch)
            (gsum,), losses = jax.lax.scan(micro, (zeros,), mbs)
            grads = jax.tree_util.tree_map(
                lambda g: g / cfg.microbatches, gsum)
            loss = losses.mean()
        else:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        grads, err_state = apply_compression(grads, cfg, err_state)
        params, opt_state, gnorm = update_opt(grads, opt_state, params)
        return params, opt_state, err_state, {"loss": loss, "gnorm": gnorm}

    return jax.jit(step, donate_argnums=(0, 1, 2))


def train(params, loss_fn: Callable, data_iter: Iterator, cfg: TrainConfig,
          fail_injector: Optional[Callable[[int], None]] = None):
    """Run the loop; auto-resume; bounded per-step retries on failure."""
    init_opt, update_opt = OPT.get(cfg.optimizer, lr=cfg.lr)
    opt_state = init_opt(params)
    err_state = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params) \
        if cfg.grad_compression == "topk" else ()
    start_step = 0
    ckpt = None
    if cfg.ckpt_dir:
        ckpt = CKPT.AsyncCheckpointer(cfg.ckpt_dir, keep=cfg.keep_ckpts)
        last = CKPT.latest_step(cfg.ckpt_dir)
        if last is not None:
            state = CKPT.restore(cfg.ckpt_dir, last,
                                 {"params": params, "opt": opt_state})
            params, opt_state = state["params"], state["opt"]
            start_step = last
    step_fn = make_train_step(loss_fn, cfg, update_opt)

    history = []
    step = start_step
    while step < cfg.steps:
        batch = next(data_iter)
        retries = 0
        while True:
            try:
                if fail_injector is not None:
                    fail_injector(step)      # may raise (simulated failure)
                params, opt_state, err_state, metrics = step_fn(
                    params, opt_state, err_state, batch)
                break
            except RuntimeError:
                retries += 1
                if retries > cfg.max_retries:
                    # unrecoverable on this "node": resume from checkpoint
                    if ckpt is None:
                        raise
                    ckpt.wait()
                    last = CKPT.latest_step(cfg.ckpt_dir)
                    if last is None:
                        raise
                    state = CKPT.restore(cfg.ckpt_dir, last,
                                         {"params": params, "opt": opt_state})
                    params, opt_state = state["params"], state["opt"]
                    step = last
                    retries = 0
        history.append({k: float(v) for k, v in metrics.items()})
        step += 1
        if ckpt is not None and step % cfg.ckpt_every == 0:
            ckpt.save_async(step, {"params": params, "opt": opt_state})
    if ckpt is not None:
        ckpt.save_async(cfg.steps, {"params": params, "opt": opt_state})
        ckpt.close()
    return params, opt_state, history
