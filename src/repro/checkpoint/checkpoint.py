"""Sharded checkpointing with async writes and elastic restore.

Format: one msgpack+zstd file per save holding flattened leaves (keyed by
pytree path) + a JSON manifest.  Restore re-shards onto whatever mesh the
restoring job uses (elastic scaling: a checkpoint written on 256 chips
restores on 16 or 512 — leaves are stored unsharded-logical, layout is
reapplied via device_put with the target sharding).

On a multi-host cluster each host writes only its addressable shard slice;
in this single-process container that degenerates to a single writer, but
the API (save/restore/gc/async) is the production one.
"""
from __future__ import annotations

import json
import os
import queue
import threading
import time
from typing import Any, Dict, Optional

import jax
import numpy as np

try:
    import msgpack
    import zstandard as zstd
    _HAVE_MSGPACK = True
except Exception:                                    # pragma: no cover
    _HAVE_MSGPACK = False


def _encode_flat(leaves: Dict[str, bytes]) -> bytes:
    """Minimal length-prefixed container for {key: bytes} — deliberately
    not pickle, so restoring a checkpoint can never execute code."""
    import struct
    out = [struct.pack("<I", len(leaves))]
    for k, v in leaves.items():
        kb = k.encode()
        out.append(struct.pack("<I", len(kb)))
        out.append(kb)
        out.append(struct.pack("<Q", len(v)))
        out.append(v)
    return b"".join(out)


def _decode_flat(data: bytes) -> Dict[str, bytes]:
    import struct
    n, off = struct.unpack_from("<I", data)[0], 4
    leaves = {}
    for _ in range(n):
        kl = struct.unpack_from("<I", data, off)[0]
        off += 4
        k = data[off:off + kl].decode()
        off += kl
        vl = struct.unpack_from("<Q", data, off)[0]
        off += 8
        leaves[k] = data[off:off + vl]
        off += vl
    return leaves


def _pack(obj: Dict) -> bytes:
    """msgpack+zstd when available, stdlib zlib + a length-prefixed flat
    container otherwise.  A one-byte magic header keeps the two formats
    mutually readable (given the right libs installed)."""
    if _HAVE_MSGPACK:
        return b"Z" + zstd.ZstdCompressor(level=3).compress(
            msgpack.packb(obj))
    import zlib
    return b"F" + zlib.compress(_encode_flat(obj["leaves"]), 3)


def _unpack(data: bytes) -> Dict:
    if data[:1] == b"F":
        import zlib
        return {"leaves": _decode_flat(zlib.decompress(data[1:]))}
    if not _HAVE_MSGPACK:
        raise RuntimeError(
            "checkpoint was written with msgpack+zstd; install msgpack "
            "and zstandard to restore it")
    if data[:1] == b"Z":
        data = data[1:]
    # headerless data = pre-magic checkpoints (always msgpack+zstd)
    return msgpack.unpackb(zstd.ZstdDecompressor().decompress(data))


def _path_str(path) -> str:
    out = []
    for p in path:
        out.append(str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p)))))
    return "/".join(out)


def save(ckpt_dir: str, step: int, tree: Any, extra: Optional[Dict] = None
         ) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    leaves = {}
    meta = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _path_str(path)
        arr = np.asarray(leaf)
        leaves[key] = arr.tobytes()
        meta[key] = {"dtype": str(arr.dtype), "shape": list(arr.shape)}
    comp = _pack({"leaves": leaves})
    path = os.path.join(ckpt_dir, f"step_{step:08d}.ckpt")
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(comp)
    os.replace(tmp, path)                    # atomic publish
    manifest = {"step": step, "time": time.time(), "meta": meta,
                "extra": extra or {}}
    with open(path + ".json", "w") as f:
        json.dump(manifest, f)
    return path


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(f[5:13]) for f in os.listdir(ckpt_dir)
             if f.startswith("step_") and f.endswith(".ckpt")]
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, like: Any,
            shardings: Any = None) -> Any:
    """Restore into the structure of `like`; if `shardings` given, leaves are
    device_put with the new layout (elastic re-sharding)."""
    path = os.path.join(ckpt_dir, f"step_{step:08d}.ckpt")
    with open(path, "rb") as f:
        blob = _unpack(f.read())
    with open(path + ".json") as f:
        meta = json.load(f)["meta"]

    flat_like, tdef = jax.tree_util.tree_flatten_with_path(like)
    flat_sh = None
    if shardings is not None:
        flat_sh = jax.tree_util.tree_flatten(shardings)[0]
    leaves = []
    for i, (p, leaf) in enumerate(flat_like):
        key = _path_str(p)
        m = meta[key]
        arr = np.frombuffer(blob["leaves"][key],
                            dtype=np.dtype(m["dtype"])).reshape(m["shape"])
        if flat_sh is not None:
            leaves.append(jax.device_put(arr, flat_sh[i]))
        else:
            leaves.append(jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(like), leaves)


def gc_old(ckpt_dir: str, keep: int = 3) -> None:
    if not os.path.isdir(ckpt_dir):
        return
    steps = sorted([int(f[5:13]) for f in os.listdir(ckpt_dir)
                    if f.startswith("step_") and f.endswith(".ckpt")])
    for s in steps[:-keep]:
        for suffix in (".ckpt", ".ckpt.json"):
            try:
                os.remove(os.path.join(ckpt_dir, f"step_{s:08d}{suffix}"))
            except FileNotFoundError:
                pass


class AsyncCheckpointer:
    """Off-critical-path writer: save() snapshots to host memory and returns;
    a worker thread serializes + writes.  wait() joins pending writes."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._q: "queue.Queue" = queue.Queue()
        self._err: Optional[BaseException] = None
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        while True:
            item = self._q.get()
            if item is None:
                return
            step, tree, extra = item
            try:
                save(self.ckpt_dir, step, tree, extra)
                gc_old(self.ckpt_dir, self.keep)
            except BaseException as e:       # surfaced on wait()
                self._err = e
            finally:
                self._q.task_done()

    def save_async(self, step: int, tree: Any, extra: Optional[Dict] = None):
        host_tree = jax.tree_util.tree_map(lambda a: np.asarray(a), tree)
        self._q.put((step, host_tree, extra))

    def wait(self):
        self._q.join()
        if self._err is not None:
            raise self._err

    def close(self):
        self.wait()
        self._q.put(None)
        self._thread.join(timeout=5)
