"""Training launcher: --arch <id> [--smoke] [--steps N].

Reduced configs execute on CPU; full configs are lowered/compiled via the
dry-run (real execution requires the TPU pod this repo targets).

    PYTHONPATH=src python -m repro.launch.train --arch gemma-7b --smoke

XLA latency-hiding flags for real TPU runs (comm/compute overlap — §Perf):
    LIBTPU_INIT_ARGS="--xla_tpu_enable_async_collective_fusion=true
        --xla_tpu_enable_async_collective_fusion_fuse_all_gather=true"
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import registry as R


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(R.ARCHS))
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config, runs on CPU")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--compression", default=None,
                    choices=["int8", "topk"])
    args = ap.parse_args()

    fam = R.family_of(args.arch) if args.arch in R.ASSIGNED else "lm"
    if not args.smoke:
        from repro.launch.dryrun import run_cell
        shape = {"lm": "train_4k", "recsys": "train_batch",
                 "gnn": "full_graph_sm"}[fam]
        run_cell(args.arch, shape, multi_pod=False,
                 out_dir="results/dryrun", skip_existing=False)
        return

    cfg = R.get_config(args.arch, smoke=True)
    from repro.training.train_loop import TrainConfig, train
    if fam == "lm":
        from repro.data.pipeline import BatchPipeline, lm_synthetic_batches
        from repro.models import transformer as T
        params = T.init_params(jax.random.PRNGKey(0), cfg)
        loss_fn = lambda p, b: T.loss_fn(p, b["tokens"], b["labels"], cfg)[0]
        pipe = BatchPipeline(lm_synthetic_batches(cfg.vocab_size, args.batch,
                                                  args.seq))
        data = iter(pipe)
    elif fam == "recsys":
        from repro.recsys import models as RM
        rng = np.random.default_rng(0)
        params = RM.init_params(jax.random.PRNGKey(0), cfg)
        loss_fn = lambda p, b: RM.train_loss(p, b, cfg)

        def gen():
            import jax.numpy as jnp
            B = args.batch
            while True:
                if cfg.kind in ("wide_deep", "autoint"):
                    yield {"dense": jnp.ones((B, 13)),
                           "sparse_ids": jnp.asarray(
                               rng.integers(0, 100, (B, len(cfg.field_vocabs))),
                               jnp.int32),
                           "labels": jnp.asarray(rng.integers(0, 2, B),
                                                 jnp.float32)}
                elif cfg.kind == "dien":
                    T_ = cfg.seq_len
                    yield {"hist_items": jnp.zeros((B, T_), jnp.int32),
                           "hist_cates": jnp.zeros((B, T_), jnp.int32),
                           "hist_mask": jnp.ones((B, T_), bool),
                           "target_item": jnp.zeros((B,), jnp.int32),
                           "target_cate": jnp.zeros((B,), jnp.int32),
                           "labels": jnp.asarray(rng.integers(0, 2, B),
                                                 jnp.float32)}
                else:
                    T_ = cfg.seq_len
                    yield {"item_seq": jnp.zeros((B, T_), jnp.int32),
                           "seq_mask": jnp.ones((B, T_), bool),
                           "mlm_positions": jnp.zeros((B, 2), jnp.int32),
                           "mlm_labels": jnp.ones((B, 2), jnp.int32),
                           "neg_samples": jnp.arange(16, dtype=jnp.int32)}
        data = gen()
        pipe = None
    else:
        raise SystemExit("use tests/examples for GNN training demos")

    _, _, hist = train(params, loss_fn, data,
                       TrainConfig(steps=args.steps, ckpt_dir=args.ckpt,
                                   optimizer=getattr(cfg, "optimizer",
                                                     "adamw"),
                                   lr=1e-3,
                                   grad_compression=args.compression))
    if pipe is not None:
        pipe.close()
    print(f"{args.arch}: loss {hist[0]['loss']:.4f} -> "
          f"{hist[-1]['loss']:.4f} over {len(hist)} steps")


if __name__ == "__main__":
    main()
