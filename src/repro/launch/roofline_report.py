"""Builds the EXPERIMENTS.md §Roofline table from dry-run JSONs + the
analytic model.  Usage: PYTHONPATH=src python -m repro.launch.roofline_report
"""
from __future__ import annotations

import glob
import json
import os

from repro.configs import registry as R
from repro.launch.roofline import model_flops
from repro.launch.roofline_analytic import lm_analytic


def build_rows():
    rows = []
    for f in sorted(glob.glob("results/dryrun/*pod_16x16.json")):
        rec = json.load(open(f))
        if not rec.get("ok"):
            continue
        arch, shape = rec["arch"], rec["shape"]
        spec = R.shapes_of(arch)[shape]
        fam = R.family_of(arch)
        if fam == "lm":
            t = lm_analytic(R.ARCHS[arch], spec.step, spec.dims)
            mf = model_flops(arch, spec.dims, spec.step) / 256
            useful = mf / t["flops_per_device"]
            src = "analytic"
        else:
            t = rec["roofline"]
            t = {"compute_s": t["compute_s"], "memory_s": t["memory_s"],
                 "collective_s": t["collective_s"],
                 "bottleneck": t["bottleneck"]}
            dom = max(t["compute_s"], t["memory_s"], t["collective_s"])
            t["roofline_fraction"] = t["compute_s"] / dom if dom else 0.0
            useful = float("nan")
            src = "hlo"
            if arch == "dien":
                src = "hlo(+GRU note)"
        rows.append({
            "arch": arch, "shape": shape, "src": src,
            "compute_s": t["compute_s"], "memory_s": t["memory_s"],
            "collective_s": t["collective_s"],
            "bottleneck": t["bottleneck"],
            "fraction": t.get("roofline_fraction", float("nan")),
            "useful": useful,
            "hlo_flops": rec["flops_per_device"],
            "hlo_bytes": rec["bytes_per_device"],
            "hlo_coll": rec["collectives"]["total_bytes"],
            "temp_gb": (rec["memory"]["temp_size"] or 0) / 1e9
            if rec["memory"].get("temp_size") else None,
        })
    return rows


def markdown(rows) -> str:
    out = ["| arch | shape | src | compute s | memory s | collective s | "
           "bottleneck | useful MF/HLO |",
           "|---|---|---|---|---|---|---|---|"]
    for r in rows:
        u = f"{r['useful']:.2f}" if r["useful"] == r["useful"] else "—"
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['src']} | "
            f"{r['compute_s']:.3e} | {r['memory_s']:.3e} | "
            f"{r['collective_s']:.3e} | {r['bottleneck']} | {u} |")
    return "\n".join(out)


if __name__ == "__main__":
    rows = build_rows()
    os.makedirs("results", exist_ok=True)
    with open("results/roofline_table.json", "w") as f:
        json.dump(rows, f, indent=1)
    print(markdown(rows))
