import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# The two lines above MUST run before any jax import — jax locks the device
# count at first init.  Everything else follows.
import argparse          # noqa: E402
import json              # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402

import jax               # noqa: E402

from repro.configs import registry as R                    # noqa: E402
from repro.launch import steps as STEPS                    # noqa: E402
from repro.launch.mesh import make_production_mesh         # noqa: E402
from repro.launch.roofline import (collective_bytes_from_hlo,  # noqa: E402
                                   roofline_terms)


def run_cell(arch: str, shape: str, multi_pod: bool, out_dir: str,
             skip_existing: bool = True) -> dict:
    mesh_name = "multipod_2x16x16" if multi_pod else "pod_16x16"
    out_path = os.path.join(out_dir, f"{arch}__{shape}__{mesh_name}.json")
    if skip_existing and os.path.exists(out_path):
        with open(out_path) as f:
            rec = json.load(f)
        if rec.get("ok"):
            print(f"[skip] {arch} × {shape} × {mesh_name} (cached)")
            return rec

    rec = {"arch": arch, "shape": shape, "mesh": mesh_name, "ok": False}
    t0 = time.time()
    try:
        # the dry-run models the paper's fixed v5e topology, not this
        # host: name the shape explicitly (auto-factoring would size the
        # mesh to the 512 forced host devices instead)
        mesh = make_production_mesh(
            multi_pod=multi_pod,
            shape=(2, 16, 16) if multi_pod else (16, 16))
        fn, args, in_sh, out_sh = STEPS.build(arch, shape, mesh)
        with mesh:
            lowered = jax.jit(fn, in_shardings=in_sh,
                              out_shardings=out_sh).lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

            mem = compiled.memory_analysis()
            print(mem)                     # proves it fits (bytes per device)
            cost = compiled.cost_analysis()
            print({k: v for k, v in cost.items()
                   if k in ("flops", "bytes accessed")})
            hlo = compiled.as_text()
            coll = collective_bytes_from_hlo(hlo)

        n_chips = 512 if multi_pod else 256
        rec.update(
            ok=True,
            lower_s=round(t_lower, 1), compile_s=round(t_compile, 1),
            flops_per_device=float(cost.get("flops", -1.0)),
            bytes_per_device=float(cost.get("bytes accessed", -1.0)),
            collectives=coll,
            memory={
                "argument_size": getattr(mem, "argument_size_in_bytes", None),
                "output_size": getattr(mem, "output_size_in_bytes", None),
                "temp_size": getattr(mem, "temp_size_in_bytes", None),
                "generated_code_size": getattr(
                    mem, "generated_code_size_in_bytes", None),
            },
            n_chips=n_chips,
        )
        rec["roofline"] = roofline_terms(rec)
    except Exception as e:       # record the failure for triage, then re-raise
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
        print(f"[FAIL] {arch} × {shape} × {mesh_name}: {rec['error']}")
    os.makedirs(out_dir, exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(rec, f, indent=1)
    status = "ok" if rec["ok"] else "FAIL"
    print(f"[{status}] {arch} × {shape} × {mesh_name} "
          f"({time.time() - t0:.0f}s)")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    cells = list(R.cells())
    if args.arch != "all":
        cells = [c for c in cells if c[0] == args.arch]
    if args.shape != "all":
        cells = [c for c in cells if c[1] == args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    n_ok = n_fail = 0
    for arch, shape in cells:
        for mp in meshes:
            rec = run_cell(arch, shape, mp, args.out,
                           skip_existing=not args.force)
            n_ok += rec["ok"]
            n_fail += not rec["ok"]
    print(f"\ndry-run complete: {n_ok} ok, {n_fail} failed")
    return 0 if n_fail == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
