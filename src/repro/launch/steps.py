"""Builds the jit-able step function + shardings + abstract args for every
(architecture × input-shape) dry-run cell.

Returned bundle: (fn, args_abstract, in_shardings, out_shardings) ready for
``jax.jit(fn, in_shardings=..., out_shardings=...).lower(*args).compile()``.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs import registry as R
from repro.configs.base import GNNConfig, LMConfig, RecsysConfig
from repro.launch.mesh import data_axes
from repro.sharding import ctx as SHCTX
from repro.sharding import specs as SH
from repro.training import optimizer as OPT


def _shardings(mesh, spec_tree):
    return SH.tree_shardings(mesh, spec_tree)


# ---------------------------------------------------------------------------
# LM cells
# ---------------------------------------------------------------------------

def _lm_bundle(cfg: LMConfig, shape: R.ShapeSpec, mesh):
    from repro.models import transformer as T
    params_abs = T.abstract_params(cfg)
    pspecs = SH.lm_param_specs(
        cfg, mesh, mode="serve" if shape.step != "train" else "train")
    psh = _shardings(mesh, pspecs)
    inputs_abs = R.input_specs(cfg.name, shape.name)
    ispecs = SH.lm_input_specs(cfg, mesh, shape.step, shape.dims)
    ish = _shardings(mesh, ispecs)

    if shape.step == "train":
        init_opt, update_opt = OPT.get(cfg.optimizer)
        opt_abs = OPT.abstract_opt_state(init_opt, params_abs)
        ospecs = SH.lm_opt_state_specs(opt_abs, pspecs, params_abs, mesh)
        osh = _shardings(mesh, ospecs)

        def train_step(params, opt_state, batch):
            with SHCTX.axes(mesh):
                (loss, nll), grads = jax.value_and_grad(
                    T.loss_fn, has_aux=True)(params, batch["tokens"],
                                             batch["labels"], cfg)
                params, opt_state, gnorm = update_opt(grads, opt_state, params)
            return params, opt_state, {"loss": loss, "nll": nll, "gnorm": gnorm}

        args = (params_abs, opt_abs, inputs_abs)
        in_sh = (psh, osh, ish)
        out_sh = (psh, osh, None)
        return train_step, args, in_sh, out_sh

    if shape.step == "prefill":
        def prefill_step(params, batch):
            with SHCTX.axes(mesh):
                return T.prefill(params, batch["tokens"], cfg)

        cache_spec = SH.lm_cache_spec(cfg, mesh, shape.dims["batch"],
                                      shape.dims["seq"])
        out_sh = (_shardings(mesh, P(data_axes(mesh), None)),
                  _shardings(mesh, cache_spec))
        return prefill_step, (params_abs, inputs_abs), (psh, ish), out_sh

    if shape.step == "decode":
        def serve_step(params, batch):
            with SHCTX.axes(mesh):
                return T.decode_step(params, batch["tokens"], batch["cache"],
                                     batch["positions"], cfg)

        cache_sh = ish["cache"]
        logits_spec = ispecs["tokens"][0]
        out_sh = (_shardings(mesh, P(logits_spec, None)), cache_sh)
        return serve_step, (params_abs, inputs_abs), (psh, ish), out_sh

    raise ValueError(shape.step)


# ---------------------------------------------------------------------------
# RecSys cells
# ---------------------------------------------------------------------------

def _recsys_bundle(cfg: RecsysConfig, shape: R.ShapeSpec, mesh):
    from repro.recsys import models as RM
    params_abs = RM.abstract_params(cfg)
    pspecs = SH.recsys_param_specs(cfg, mesh)
    psh = _shardings(mesh, pspecs)
    inputs_abs = R.input_specs(cfg.name, shape.name)
    ispecs = SH.recsys_input_specs(cfg, mesh, shape.step, shape.dims)
    ish = _shardings(mesh, ispecs)
    dax = data_axes(mesh)

    if shape.step == "train":
        init_opt, update_opt = OPT.get("adamw")
        opt_abs = OPT.abstract_opt_state(init_opt, params_abs)
        zspecs = SH.zero_shard(pspecs, params_abs, mesh)
        ospecs = OPT.OptState(step=P(), inner={"m": zspecs, "v": zspecs})
        osh = _shardings(mesh, ospecs)

        def train_step(params, opt_state, batch):
            loss, grads = jax.value_and_grad(RM.train_loss)(params, batch, cfg)
            params, opt_state, gnorm = update_opt(grads, opt_state, params)
            return params, opt_state, {"loss": loss, "gnorm": gnorm}

        return (train_step, (params_abs, opt_abs, inputs_abs),
                (psh, osh, ish), (psh, osh, None))

    if shape.step == "score":
        def score_step(params, batch):
            return RM.score(params, batch, cfg)

        b = shape.dims["batch"]
        from repro.launch.mesh import axis_size
        bspec = dax if b % axis_size(mesh, dax) == 0 else None
        out_sh = _shardings(mesh, P(bspec))
        return score_step, (params_abs, inputs_abs), (psh, ish), out_sh

    if shape.step == "retrieval":
        def retrieval_step(params, batch):
            return RM.retrieval_scores(params, batch, cfg)

        # (1, 1M) scores: let the partitioner pick the output layout
        return retrieval_step, (params_abs, inputs_abs), (psh, ish), None

    raise ValueError(shape.step)


# ---------------------------------------------------------------------------
# GNN cells
# ---------------------------------------------------------------------------

def _gnn_bundle(cfg: GNNConfig, shape: R.ShapeSpec, mesh):
    from repro.gnn import schnet as G
    d = shape.dims
    if shape.name == "molecule":
        params_abs = G.abstract_params(cfg)
    else:
        params_abs = G.abstract_params(cfg, d_feat=d["d_feat"],
                                       n_classes=d["n_classes"])
    pspecs = SH.gnn_param_specs(params_abs, mesh)
    psh = _shardings(mesh, pspecs)
    inputs_abs = R.input_specs(cfg.name, shape.name)
    ispecs = SH.gnn_input_specs(mesh, shape.name, inputs_abs)
    ish = _shardings(mesh, ispecs)

    init_opt, update_opt = OPT.get("adamw")
    opt_abs = OPT.abstract_opt_state(init_opt, params_abs)
    ospecs = OPT.OptState(step=P(), inner={"m": pspecs, "v": pspecs})
    osh = _shardings(mesh, ospecs)

    # huge non-divisible edge lists (ogb_products: 61.9M) arrive replicated,
    # then get padded to a shard boundary and re-sharded on-device so the
    # message/scatter compute runs edge-parallel across the whole mesh.
    from repro.launch.mesh import axis_size
    edge_ax = tuple(data_axes(mesh)) + ("model",)
    esz = axis_size(mesh, edge_ax)
    e_abs = inputs_abs.get("edge_src")
    pad_edges = (e_abs is not None and e_abs.ndim == 1 and
                 e_abs.shape[0] % esz != 0 and e_abs.shape[0] > 1_000_000)
    n_nodes = d.get("n_nodes", 0)

    def _prep(batch):
        if not pad_edges:
            return batch
        batch = dict(batch)
        e = batch["edge_src"].shape[0]
        pad = (-e) % esz
        wsc = jax.lax.with_sharding_constraint
        batch["edge_src"] = wsc(jnp.pad(batch["edge_src"], (0, pad)),
                                _shardings(mesh, P(edge_ax)))
        # pad dst with n_nodes: out-of-range segment ids are dropped by scatter
        batch["edge_dst"] = wsc(
            jnp.pad(batch["edge_dst"], (0, pad), constant_values=n_nodes),
            _shardings(mesh, P(edge_ax)))
        return batch

    def train_step(params, opt_state, batch):
        batch = _prep(batch)
        loss, grads = jax.value_and_grad(G.train_loss)(params, batch, cfg)
        params, opt_state, gnorm = update_opt(grads, opt_state, params)
        return params, opt_state, {"loss": loss, "gnorm": gnorm}

    return (train_step, (params_abs, opt_abs, inputs_abs),
            (psh, osh, ish), (psh, osh, None))


# ---------------------------------------------------------------------------

def build(arch: str, shape_name: str, mesh) -> Tuple[Any, tuple, Any, Any]:
    cfg = R.ARCHS[arch]
    shape = R.shapes_of(arch)[shape_name]
    fam = R.family_of(arch)
    if fam == "lm":
        return _lm_bundle(cfg, shape, mesh)
    if fam == "recsys":
        return _recsys_bundle(cfg, shape, mesh)
    if fam == "gnn":
        return _gnn_bundle(cfg, shape, mesh)
    raise KeyError(arch)
